//! Experiment-grid scheduler.
//!
//! Experiments are grids of independent cells (quantizer × rank × scope ×
//! …). `PjRtClient` is not `Send`, so the scheduler spawns worker threads
//! that each construct their *own* PJRT runtime and pull cell indices from
//! a shared atomic work queue; results flow back over a channel and are
//! re-ordered by cell index. Worker count defaults to a conservative
//! fraction of the cores because each CPU PJRT client runs its own
//! intra-op thread pool.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::runtime::Runtime;

/// Run `n_cells` independent cells; `work(runtime, cell_idx)` is executed
/// exactly once per cell on some worker. Results come back in cell order.
pub fn run_grid<T: Send + 'static>(
    artifact_dir: &str,
    n_cells: usize,
    n_workers: usize,
    work: impl Fn(&Runtime, usize) -> Result<T> + Send + Sync + 'static,
) -> Result<Vec<T>> {
    if n_cells == 0 {
        return Ok(Vec::new());
    }
    let n_workers = n_workers.max(1).min(n_cells);
    let work = Arc::new(work);
    let next = Arc::new(AtomicUsize::new(0));
    let (tx, rx) = channel::<(usize, Result<T>)>();
    let dir = artifact_dir.to_string();

    let mut handles = Vec::new();
    for w in 0..n_workers {
        let work = work.clone();
        let next = next.clone();
        let tx = tx.clone();
        let dir = dir.clone();
        handles.push(std::thread::Builder::new()
            .name(format!("rilq-worker-{w}"))
            .spawn(move || {
                let rt = match Runtime::new(&dir) {
                    Ok(rt) => rt,
                    Err(e) => {
                        // poison every remaining cell with the error
                        loop {
                            let i = next.fetch_add(1, Ordering::SeqCst);
                            if i >= n_cells {
                                return;
                            }
                            let _ = tx.send((i, Err(anyhow!("worker runtime: {e:?}"))));
                        }
                    }
                };
                loop {
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= n_cells {
                        return;
                    }
                    let r = work(&rt, i);
                    if tx.send((i, r)).is_err() {
                        return;
                    }
                }
            })
            .expect("spawn worker"));
    }
    drop(tx);

    let mut slots: Vec<Option<Result<T>>> = (0..n_cells).map(|_| None).collect();
    for (i, r) in rx {
        slots[i] = Some(r);
    }
    for h in handles {
        h.join().map_err(|_| anyhow!("worker panicked"))?;
    }
    slots
        .into_iter()
        .enumerate()
        .map(|(i, s)| s.ok_or_else(|| anyhow!("cell {i} never ran"))?)
        .collect()
}

/// Default worker count: half the cores, capped (each worker spins a PJRT
/// CPU client with its own intra-op pool).
pub fn default_workers() -> usize {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    (cores / 2).clamp(1, 4)
}

#[cfg(test)]
mod tests {
    use super::*;

    // run_grid without artifacts requires Runtime::new to succeed; these
    // tests only run when artifacts exist (like the integration tests).
    fn have_artifacts() -> bool {
        std::path::Path::new("artifacts/manifest.json").exists()
    }

    #[test]
    fn all_cells_run_exactly_once_in_order() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let out = run_grid("artifacts", 9, 3, |_rt, i| Ok(i * 10)).unwrap();
        assert_eq!(out, (0..9).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn cell_error_propagates() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let res = run_grid("artifacts", 3, 2, |_rt, i| {
            if i == 1 {
                Err(anyhow!("boom"))
            } else {
                Ok(i)
            }
        });
        assert!(res.is_err());
    }

    #[test]
    fn empty_grid_ok() {
        let out: Vec<usize> = run_grid("artifacts", 0, 4, |_rt, i| Ok(i)).unwrap();
        assert!(out.is_empty());
    }
}
