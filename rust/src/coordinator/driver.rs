//! Calibration / pretraining loop drivers.
//!
//! One PJRT execute per step (the train-step artifacts fuse fwd + bwd +
//! Adam), with the paper's schedule semantics: fixed learning rate,
//! early stopping when the loss stops improving (patience on a smoothed
//! loss), everything seeded.

use std::time::Instant;

use anyhow::Result;

use crate::data::Profile;
use crate::data::Vocab;
use crate::eval::{BackendScorer, HloScorer, Scorer};
use crate::lqec::AdapterSet;
use crate::model::backend::BackendKind;
use crate::model::{ModelDims, StudentWeights, TeacherParams};
use crate::runtime::bindings::{
    output_adapter_flat, output_scalar, output_teacher_flat, Bindings,
};
use crate::runtime::Runtime;

use super::batcher::BatchStream;

/// Calibration (LQEC) loop configuration. Defaults mirror the paper's
/// setup scaled to simulation size: Adam, fixed lr, early stopping.
#[derive(Clone, Debug)]
pub struct CalibConfig {
    pub max_steps: usize,
    pub lr: f32,
    /// stop after `patience` consecutive steps without improving the best
    /// smoothed loss by `min_delta`
    pub patience: usize,
    pub min_delta: f32,
    /// number of calibration samples (batches = samples / batch)
    pub n_samples: usize,
    pub seed: u64,
    pub profile: Profile,
}

impl Default for CalibConfig {
    fn default() -> Self {
        CalibConfig {
            max_steps: 400,
            lr: 1e-3,
            patience: 60,
            min_delta: 1e-5,
            n_samples: 256,
            seed: 1234,
            profile: Profile::C4Sim, // the paper calibrates on C4
        }
    }
}

/// Result of a calibration run.
#[derive(Clone, Debug)]
pub struct CalibResult {
    pub adapters_flat: Vec<Vec<f32>>,
    pub losses: Vec<f32>,
    pub model_losses: Vec<f32>,
    pub gt_losses: Vec<f32>,
    pub steps: usize,
    pub wall_secs: f64,
    pub stopped_early: bool,
}

/// The coordinator-side training driver owning a runtime reference plus
/// the execution-backend choice used for any scorer it builds.
pub struct Driver<'r> {
    pub rt: &'r Runtime,
    /// Execution engine for student evaluation (see
    /// [`crate::model::backend`]). Calibration itself always runs the
    /// train-step artifacts; the backend selects how the resulting
    /// (student, adapters) pair *executes* at eval/serving time.
    pub backend: BackendKind,
}

impl<'r> Driver<'r> {
    pub fn new(rt: &'r Runtime) -> Driver<'r> {
        Driver { rt, backend: BackendKind::Dense }
    }

    /// Select the execution backend for scorers built by this driver.
    pub fn with_backend(mut self, backend: BackendKind) -> Driver<'r> {
        self.backend = backend;
        self
    }

    /// Build the evaluation scorer for a (student, adapters) pair under
    /// this driver's backend — the single place execution selection
    /// lives:
    ///
    /// * `dense` prefers the lowered HLO artifact (PJRT) when present,
    ///   falling back to the native dense engine;
    /// * `packed` / `merged` always run the native execution engine
    ///   (`packed` is the fused streaming-dequant W2A16 serving form).
    pub fn student_scorer(
        &self,
        dims: &ModelDims,
        teacher: &TeacherParams,
        student: &StudentWeights,
        adapters: &AdapterSet,
    ) -> Result<Box<dyn Scorer + 'r>> {
        if self.backend == BackendKind::Dense {
            let name = format!("student_fwd_{}_r{}", dims.name, adapters.rank);
            if self.rt.manifest.artifact(&name).is_ok() {
                let flat = adapters.to_flat();
                let sc = HloScorer::new(self.rt, &name, |b| {
                    b.teacher(teacher).qweights(student).adapters("ad.", &flat);
                })?;
                return Ok(Box::new(sc));
            }
            log::debug!(
                "artifact student_fwd_{}_r{} not lowered; using the native dense engine",
                dims.name,
                adapters.rank
            );
        }
        let sc = BackendScorer::new(dims, teacher, student, Some(adapters), self.backend)?;
        Ok(Box::new(sc))
    }

    /// Run LQEC calibration: tune `adapters` on `train_step_<cfg>_r<r>_<scope>`
    /// using a corpus-sampled calibration set (the paper's C4 protocol).
    pub fn calibrate(
        &self,
        dims: &ModelDims,
        teacher: &TeacherParams,
        student: &StudentWeights,
        adapters: &AdapterSet,
        scope: &str,
        cfg: &CalibConfig,
    ) -> Result<CalibResult> {
        let n_batches = (cfg.n_samples / dims.batch).max(1);
        let mut stream = BatchStream::spawn(
            Vocab::new(dims.vocab, cfg.seed),
            cfg.profile,
            cfg.seed,
            dims.batch,
            dims.seq,
            n_batches,
            4,
        );
        // materialize the finite calibration set (paper: 256 samples),
        // then cycle it across steps
        let calib: Vec<Vec<Vec<u32>>> =
            (0..n_batches).filter_map(|_| stream.next()).collect();
        self.calibrate_on(dims, teacher, student, adapters, scope, cfg, &calib)
    }

    /// Calibration / task-specific fine-tuning over explicit batches
    /// (cycled when `max_steps` exceeds the epoch).
    pub fn calibrate_on(
        &self,
        dims: &ModelDims,
        teacher: &TeacherParams,
        student: &StudentWeights,
        adapters: &AdapterSet,
        scope: &str,
        cfg: &CalibConfig,
        calib: &[Vec<Vec<u32>>],
    ) -> Result<CalibResult> {
        let artifact = format!("train_step_{}_r{}_{}", dims.name, adapters.rank, scope);
        let spec = self.rt.manifest.artifact(&artifact)?.clone();
        let t0 = Instant::now();
        assert!(!calib.is_empty(), "empty calibration set");

        // static bindings (teacher + frozen quantized weights) go to the
        // device once; adapters/moments/tokens upload per step (§Perf)
        let mut base = Bindings::new();
        base.teacher(teacher).qweights(student);
        let dev = base.to_device(
            self.rt,
            &spec,
            &["ad.", "m.", "v.", "t", "lr", "tokens"],
        )?;

        let mut ad_flat = adapters.to_flat();
        let mut m_flat = adapters.zeros_like_flat();
        let mut v_flat = adapters.zeros_like_flat();

        let mut losses = Vec::new();
        let mut model_losses = Vec::new();
        let mut gt_losses = Vec::new();
        let mut best = f32::INFINITY;
        let mut since_best = 0usize;
        let mut ema = f32::NAN;
        let mut stopped_early = false;

        for step in 0..cfg.max_steps {
            let batch = &calib[step % calib.len()];
            let mut b = Bindings::new();
            b.adapters("ad.", &ad_flat)
                .adapters("m.", &m_flat)
                .adapters("v.", &v_flat)
                .step_lr((step + 1) as f32, cfg.lr)
                .tokens(batch, dims);
            let asm = dev.assemble(self.rt, &spec, &b)?;
            let outs = self.rt.run_b(&artifact, &asm.refs())?;
            let loss = output_scalar(&spec, &outs, "loss")?;
            model_losses.push(output_scalar(&spec, &outs, "model_loss")?);
            gt_losses.push(output_scalar(&spec, &outs, "gt_loss")?);
            losses.push(loss);
            ad_flat = output_adapter_flat(&spec, &outs, "ad.")?;
            m_flat = output_adapter_flat(&spec, &outs, "m.")?;
            v_flat = output_adapter_flat(&spec, &outs, "v.")?;

            // smoothed early stopping (the paper stops when loss plateaus)
            ema = if ema.is_nan() { loss } else { 0.9 * ema + 0.1 * loss };
            if ema < best - cfg.min_delta {
                best = ema;
                since_best = 0;
            } else {
                since_best += 1;
                if since_best >= cfg.patience {
                    stopped_early = true;
                    break;
                }
            }
        }

        Ok(CalibResult {
            adapters_flat: ad_flat,
            steps: losses.len(),
            losses,
            model_losses,
            gt_losses,
            wall_secs: t0.elapsed().as_secs_f64(),
            stopped_early,
        })
    }

    /// Pretrain the fp teacher with the causal-LM objective. Returns the
    /// trained parameters and the loss curve.
    pub fn pretrain(
        &self,
        dims: &ModelDims,
        init: &TeacherParams,
        cfg: &PretrainConfig,
    ) -> Result<(TeacherParams, Vec<f32>)> {
        let artifact = format!("pretrain_step_{}", dims.name);
        let spec = self.rt.manifest.artifact(&artifact)?.clone();

        let mut stream = BatchStream::spawn(
            Vocab::new(dims.vocab, cfg.seed),
            cfg.profile,
            cfg.seed,
            dims.batch,
            dims.seq,
            cfg.steps,
            4,
        );

        let mut p_flat = init.to_flat();
        let mut m_flat: Vec<Vec<f32>> = p_flat.iter().map(|b| vec![0.0; b.len()]).collect();
        let mut v_flat = m_flat.clone();
        let mut losses = Vec::with_capacity(cfg.steps);

        for step in 0..cfg.steps {
            let batch = stream.next().expect("stream covers cfg.steps");
            // warmup then constant lr
            let lr = if step < cfg.warmup {
                cfg.lr * (step + 1) as f32 / cfg.warmup as f32
            } else {
                cfg.lr
            };
            let mut b = Bindings::new();
            b.teacher_shaped("", &p_flat)
                .teacher_shaped("m.", &m_flat)
                .teacher_shaped("v.", &v_flat)
                .step_lr((step + 1) as f32, lr)
                .tokens(&batch, dims);
            let outs = self.rt.run(&artifact, &b.to_literals(&spec)?)?;
            losses.push(output_scalar(&spec, &outs, "loss")?);
            p_flat = output_teacher_flat(&spec, &outs, "p.")?;
            m_flat = output_teacher_flat(&spec, &outs, "m.")?;
            v_flat = output_teacher_flat(&spec, &outs, "v.")?;
            if cfg.log_every > 0 && step % cfg.log_every == 0 {
                log::info!("pretrain[{}] step {step} loss {:.4}", dims.name, losses[step]);
            }
        }

        Ok((TeacherParams::from_flat(dims, &p_flat)?, losses))
    }
}

/// Pretraining configuration.
#[derive(Clone, Debug)]
pub struct PretrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub warmup: usize,
    pub seed: u64,
    pub profile: Profile,
    pub log_every: usize,
}

impl Default for PretrainConfig {
    fn default() -> Self {
        PretrainConfig {
            steps: 600,
            lr: 3e-3,
            warmup: 30,
            seed: 99,
            profile: Profile::WikiSim,
            log_every: 50,
        }
    }
}
