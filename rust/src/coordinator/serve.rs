//! Continuous-batching serving loop over a shared [`Scorer`].
//!
//! RILQ's deliverable is an adapter-merged weight-quantized model meant
//! for *serving*: requests arrive one at a time, ragged, and the engine
//! wants them coalesced so each `LinearBackend::forward` runs once per
//! layer over the whole batch (see
//! [`crate::model::forward::forward_trace_batch`]). This module is the
//! loop that does the coalescing:
//!
//! * requests enter a **bounded** queue (`sync_channel` — the same
//!   backpressure idiom as [`super::batcher::BatchStream`]: submitters
//!   block when the queue is full, so server memory stays constant no
//!   matter how fast clients push);
//! * the serve loop blocks for the first request, then **greedily drains**
//!   whatever else is already queued (up to `max_batch`) — under light
//!   load a request never waits for a batch to fill, under heavy load
//!   batches fill to `max_batch` automatically;
//! * the coalesced ragged batch goes through `Scorer::score_batch` as the
//!   real sequences only — **no PAD-dummy filler is ever forwarded**
//!   (pinned by `tests/serve_loop.rs` via the token counters);
//! * per-request failures (e.g. a sequence longer than the model window)
//!   answer that request with `Err` without poisoning its batchmates or
//!   the loop.
//!
//! ## Decode scheduling (KV cache)
//!
//! On cache-capable scorers ([`Scorer::supports_cache`]) the same loop
//! also runs **incremental greedy decode**: [`ServeClient::generate`]
//! submits a prompt plus a token budget, the loop prefills all freshly
//! admitted prompts as one coalesced cached forward, then advances every
//! active sequence **one token per iteration in lockstep round-robin** —
//! each step coalesces the active sequences' next tokens into a single
//! `[n_active, d_model]` forward, so the packed group-tile dequant keeps
//! amortizing across the decode batch. Cache residency is accounted
//! against the bounded queue: at most `max_active` KV caches are ever
//! resident, and the loop **stops draining the queue** while its decode
//! slots (or the score batch) are full, so backpressure propagates to
//! submitters instead of ballooning server memory. Gauges
//! (`serve.active_decodes`, `serve.kv_bytes`, `serve.queue_depth`) make
//! the scheduler observable.
//!
//! Throughput and latency land in a [`Metrics`] sink (`serve.requests`,
//! `serve.batches`, `serve.tokens`, `serve.errors`, latency
//! observations with p50/p95, timers `serve.forward` / `serve.prefill` /
//! `serve.decode_step`), summarized by [`ServeSummary`]. The CLI exposes
//! the loop as `rilq serve-bench`.

use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use crate::eval::scorer::{argmax_logp, check_input, greedy_decode_recompute};
use crate::eval::{BackendScorer, Scorer};
use crate::model::kv::KvCache;
use crate::tensor::Rng;

use super::Metrics;

/// Serving-loop knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Coalesce at most this many scoring requests into one forward.
    pub max_batch: usize,
    /// Bounded request-queue depth (backpressure: submit blocks beyond it).
    pub queue_capacity: usize,
    /// Maximum concurrently resident decode sequences (KV caches). The
    /// loop stops draining the queue while every slot is taken, so
    /// excess generate requests wait in the bounded queue.
    pub max_active: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_batch: 8, queue_capacity: 32, max_active: 8 }
    }
}

/// One queued scoring request.
struct Request {
    tokens: Vec<u32>,
    enqueued: Instant,
    resp: Sender<Result<Vec<f32>>>,
}

/// One queued greedy-generation request.
struct GenRequest {
    prompt: Vec<u32>,
    max_new: usize,
    enqueued: Instant,
    resp: Sender<Result<Generated>>,
}

/// A finished greedy generation: the decoded tokens and each one's
/// log-prob under the distribution it was sampled from.
#[derive(Clone, Debug)]
pub struct Generated {
    pub tokens: Vec<u32>,
    pub logps: Vec<f32>,
}

enum Msg {
    Req(Request),
    Gen(GenRequest),
    Shutdown,
}

/// A submitted request's pending response (one-shot).
pub struct Pending<T = Vec<f32>> {
    rx: Receiver<Result<T>>,
}

impl<T> Pending<T> {
    /// Block until the server answers (the scored log-probs or generated
    /// tokens), or the per-request error.
    pub fn wait(self) -> Result<T> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("server shut down before answering this request"))?
    }
}

/// Cheap, cloneable submission handle.
#[derive(Clone)]
pub struct ServeClient {
    tx: SyncSender<Msg>,
    metrics: Arc<Metrics>,
}

impl ServeClient {
    /// Enqueue a sequence for scoring. Blocks while the bounded queue is
    /// full (backpressure); errs once the server has shut down.
    pub fn submit(&self, tokens: Vec<u32>) -> Result<Pending> {
        let (resp, rx) = channel();
        self.metrics.gauge_add("serve.queue_depth", 1.0);
        let send = self
            .tx
            .send(Msg::Req(Request { tokens, enqueued: Instant::now(), resp }));
        if send.is_err() {
            self.metrics.gauge_add("serve.queue_depth", -1.0);
            return Err(anyhow!("server stopped"));
        }
        Ok(Pending { rx })
    }

    /// Submit and block for the answer.
    pub fn score(&self, tokens: Vec<u32>) -> Result<Vec<f32>> {
        self.submit(tokens)?.wait()
    }

    /// Enqueue a greedy-decode request: prefill `prompt` once, then
    /// generate up to `max_new` tokens incrementally (KV cache). Errs at
    /// admission when the scorer has no cache support or
    /// `prompt + max_new - 1` exceeds the model window.
    pub fn generate(&self, prompt: Vec<u32>, max_new: usize) -> Result<Pending<Generated>> {
        let (resp, rx) = channel();
        self.metrics.gauge_add("serve.queue_depth", 1.0);
        let send = self
            .tx
            .send(Msg::Gen(GenRequest { prompt, max_new, enqueued: Instant::now(), resp }));
        if send.is_err() {
            self.metrics.gauge_add("serve.queue_depth", -1.0);
            return Err(anyhow!("server stopped"));
        }
        Ok(Pending { rx })
    }
}

/// The running server: a dedicated loop thread owning the scorer queue.
/// Dropping the `Server` initiates shutdown: requests already queued are
/// drained and answered, later submissions err.
pub struct Server {
    tx: Option<SyncSender<Msg>>,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    cfg: ServeConfig,
}

impl Server {
    /// Spawn the serve loop over an owned scorer.
    pub fn start<S: Scorer + Send + Sync + 'static>(scorer: S, cfg: ServeConfig) -> Server {
        Server::start_shared(Arc::new(scorer), cfg)
    }

    /// Spawn the serve loop over a shared scorer (e.g. one
    /// [`crate::eval::BackendScorer`] also used elsewhere — the engine is
    /// read-only at serving time).
    pub fn start_shared(scorer: Arc<dyn Scorer + Send + Sync>, cfg: ServeConfig) -> Server {
        let (tx, rx) = sync_channel(cfg.queue_capacity.max(1));
        let metrics = Arc::new(Metrics::new());
        let m = metrics.clone();
        let c = cfg.clone();
        let worker = std::thread::Builder::new()
            .name("rilq-serve".into())
            .spawn(move || serve_loop(scorer, rx, c, m))
            .expect("spawn serve loop");
        Server { tx: Some(tx), worker: Some(worker), metrics, cfg }
    }

    pub fn client(&self) -> ServeClient {
        ServeClient {
            tx: self.tx.as_ref().expect("server running").clone(),
            metrics: self.metrics.clone(),
        }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Snapshot of the throughput/latency counters.
    pub fn summary(&self) -> ServeSummary {
        ServeSummary::from_metrics(&self.metrics)
    }

    /// Drain the queue, stop the loop, and return the final counters.
    pub fn shutdown(mut self) -> ServeSummary {
        self.stop();
        ServeSummary::from_metrics(&self.metrics)
    }

    fn stop(&mut self) {
        if let Some(tx) = self.tx.take() {
            // the sentinel queues behind every already-submitted request,
            // so shutdown drains gracefully; send only errs if the loop
            // is already gone
            let _ = tx.send(Msg::Shutdown);
            drop(tx);
        }
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// One in-flight decode sequence: its KV cache, the tokens generated so
/// far (the last one not yet fed back), and the response channel.
struct ActiveGen {
    cache: KvCache,
    tokens: Vec<u32>,
    logps: Vec<f32>,
    max_new: usize,
    enqueued: Instant,
    resp: Sender<Result<Generated>>,
}

fn finish_gen(a: ActiveGen, metrics: &Metrics) {
    metrics.add("serve.gen_requests", 1.0);
    metrics.add("serve.gen_tokens", a.tokens.len() as f64);
    metrics.observe("serve.latency_secs", a.enqueued.elapsed().as_secs_f64());
    let _ = a.resp.send(Ok(Generated { tokens: a.tokens, logps: a.logps }));
}

fn serve_loop(
    scorer: Arc<dyn Scorer + Send + Sync>,
    rx: Receiver<Msg>,
    cfg: ServeConfig,
    metrics: Arc<Metrics>,
) {
    let max_batch = cfg.max_batch.max(1);
    let max_active = cfg.max_active.max(1);
    let dims = scorer.dims().clone();
    let supports_cache = scorer.supports_cache();
    let mut active: Vec<ActiveGen> = Vec::new();
    let mut shutting_down = false;

    // admit one message: malformed requests (over-window, out-of-vocab,
    // no cache support, generation past the window) are answered without
    // touching the model — and without poisoning their batchmates.
    // Returns false when the shutdown sentinel was seen.
    let admit = |msg: Msg, reqs: &mut Vec<Request>, fresh: &mut Vec<GenRequest>| -> bool {
        match msg {
            Msg::Shutdown => false,
            Msg::Req(req) => {
                metrics.gauge_add("serve.queue_depth", -1.0);
                match check_input(&dims, std::slice::from_ref(&req.tokens)) {
                    Ok(()) => reqs.push(req),
                    Err(e) => {
                        metrics.incr("serve.errors");
                        let _ = req.resp.send(Err(e));
                    }
                }
                true
            }
            Msg::Gen(g) => {
                metrics.gauge_add("serve.queue_depth", -1.0);
                if !supports_cache {
                    metrics.incr("serve.errors");
                    let _ = g.resp.send(Err(anyhow!(
                        "this scorer has no KV-cache support; generate needs a \
                         native backend scorer"
                    )));
                } else if g.prompt.is_empty() {
                    metrics.incr("serve.errors");
                    let _ = g.resp.send(Err(anyhow!("generate needs a non-empty prompt")));
                } else if let Err(e) = check_input(&dims, std::slice::from_ref(&g.prompt)) {
                    metrics.incr("serve.errors");
                    let _ = g.resp.send(Err(e));
                } else if g.prompt.len() + g.max_new.saturating_sub(1) > dims.seq {
                    metrics.incr("serve.errors");
                    let _ = g.resp.send(Err(anyhow!(
                        "generating {} tokens from a {}-token prompt exceeds the \
                         model window of {}",
                        g.max_new,
                        g.prompt.len(),
                        dims.seq
                    )));
                } else if g.max_new == 0 {
                    // nothing to decode: answer immediately
                    metrics.add("serve.gen_requests", 1.0);
                    metrics.observe("serve.latency_secs", g.enqueued.elapsed().as_secs_f64());
                    let _ = g.resp.send(Ok(Generated { tokens: Vec::new(), logps: Vec::new() }));
                } else {
                    fresh.push(g);
                }
                true
            }
        }
    };

    loop {
        // ---- intake ----------------------------------------------------
        let mut reqs: Vec<Request> = Vec::with_capacity(max_batch);
        let mut fresh: Vec<GenRequest> = Vec::new();
        if !shutting_down {
            if active.is_empty() {
                // completely idle: block for the next message
                match rx.recv() {
                    Ok(msg) => {
                        if !admit(msg, &mut reqs, &mut fresh) {
                            shutting_down = true;
                        }
                    }
                    Err(_) => break,
                }
            }
            // greedy coalesce: take whatever is already queued — but stop
            // while the score batch or the decode slots are full, leaving
            // the rest in the bounded queue (cache-capacity accounting:
            // backpressure reaches submitters instead of server memory)
            while !shutting_down
                && reqs.len() < max_batch
                && active.len() + fresh.len() < max_active
            {
                match rx.try_recv() {
                    Ok(msg) => {
                        if !admit(msg, &mut reqs, &mut fresh) {
                            shutting_down = true;
                        }
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        shutting_down = true;
                        break;
                    }
                }
            }
        }

        // ---- prefill freshly admitted decode sequences -----------------
        if !fresh.is_empty() {
            let news: Vec<Vec<u32>> =
                fresh.iter_mut().map(|g| std::mem::take(&mut g.prompt)).collect();
            let mut caches: Vec<KvCache> =
                news.iter().map(|_| KvCache::new(&dims)).collect();
            let scored = metrics.time("serve.prefill", || {
                let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
                scorer.cache_forward_batch(&news, &mut refs)
            });
            match scored {
                Ok(lgs) => {
                    metrics.add(
                        "serve.prefill_tokens",
                        news.iter().map(Vec::len).sum::<usize>() as f64,
                    );
                    let mut caches = caches.into_iter();
                    for (i, g) in fresh.into_iter().enumerate() {
                        let cache = caches.next().expect("one cache per prefill");
                        let (tok, lp) = argmax_logp(lgs[i].row(news[i].len() - 1));
                        let st = ActiveGen {
                            cache,
                            tokens: vec![tok],
                            logps: vec![lp],
                            max_new: g.max_new,
                            enqueued: g.enqueued,
                            resp: g.resp,
                        };
                        if st.tokens.len() >= st.max_new {
                            finish_gen(st, &metrics);
                        } else {
                            active.push(st);
                        }
                    }
                }
                Err(e) => {
                    metrics.add("serve.errors", fresh.len() as f64);
                    let msg = format!("{e:#}");
                    for g in fresh {
                        let _ = g.resp.send(Err(anyhow!("{msg}")));
                    }
                }
            }
            metrics.gauge_set("serve.active_decodes", active.len() as f64);
            metrics.gauge_set(
                "serve.kv_bytes",
                active.iter().map(|a| a.cache.bytes()).sum::<usize>() as f64,
            );
        }

        // ---- one coalesced scoring forward -----------------------------
        if !reqs.is_empty() {
            // move the tokens out (they are not needed for the response)
            let batch: Vec<Vec<u32>> =
                reqs.iter_mut().map(|r| std::mem::take(&mut r.tokens)).collect();
            let n_tokens: usize = batch.iter().map(Vec::len).sum();
            let scored = metrics.time("serve.forward", || scorer.score_batch(&batch));
            match scored {
                Ok(outs) => {
                    metrics.incr("serve.batches");
                    metrics.add("serve.requests", reqs.len() as f64);
                    metrics.add("serve.tokens", n_tokens as f64);
                    for (req, out) in reqs.into_iter().zip(outs) {
                        metrics
                            .observe("serve.latency_secs", req.enqueued.elapsed().as_secs_f64());
                        let _ = req.resp.send(Ok(out));
                    }
                }
                Err(e) => {
                    // batch-level failure: answer every member, keep serving
                    metrics.add("serve.errors", reqs.len() as f64);
                    let msg = format!("{e:#}");
                    for req in reqs {
                        let _ = req.resp.send(Err(anyhow!("{msg}")));
                    }
                }
            }
        }

        // ---- one lockstep decode step for every active sequence --------
        if !active.is_empty() {
            let news: Vec<Vec<u32>> = active
                .iter()
                .map(|a| vec![*a.tokens.last().expect("active has a sampled token")])
                .collect();
            let scored = metrics.time("serve.decode_step", || {
                let mut refs: Vec<&mut KvCache> =
                    active.iter_mut().map(|a| &mut a.cache).collect();
                scorer.cache_forward_batch(&news, &mut refs)
            });
            match scored {
                Ok(lgs) => {
                    metrics.incr("serve.decode_steps");
                    metrics.add("serve.decode_tokens", active.len() as f64);
                    for (a, lg) in active.iter_mut().zip(&lgs) {
                        let (tok, lp) = argmax_logp(lg.row(0));
                        a.tokens.push(tok);
                        a.logps.push(lp);
                    }
                    let mut i = 0;
                    while i < active.len() {
                        if active[i].tokens.len() >= active[i].max_new {
                            finish_gen(active.swap_remove(i), &metrics);
                        } else {
                            i += 1;
                        }
                    }
                }
                Err(e) => {
                    // step-level failure: answer every active sequence,
                    // free their caches, keep serving
                    metrics.add("serve.errors", active.len() as f64);
                    let msg = format!("{e:#}");
                    for a in active.drain(..) {
                        let _ = a.resp.send(Err(anyhow!("{msg}")));
                    }
                }
            }
            metrics.gauge_set("serve.active_decodes", active.len() as f64);
            metrics.gauge_set(
                "serve.kv_bytes",
                active.iter().map(|a| a.cache.bytes()).sum::<usize>() as f64,
            );
        }

        if shutting_down && active.is_empty() {
            break;
        }
    }
    // loop exit: any messages still queued were submitted after shutdown
    // began; dropping their response senders errs the callers' `wait()`.
}

/// Aggregated serving counters, derived from the loop's [`Metrics`].
#[derive(Clone, Debug)]
pub struct ServeSummary {
    pub requests: f64,
    pub batches: f64,
    pub tokens: f64,
    pub errors: f64,
    /// wall seconds spent inside `score_batch`
    pub forward_secs: f64,
    /// mean request latency (enqueue → response), seconds
    pub mean_latency_secs: f64,
    /// median request latency, seconds
    pub latency_p50_secs: f64,
    /// 95th-percentile request latency, seconds
    pub latency_p95_secs: f64,
    /// high-water mark of the request queue depth
    pub queue_depth_peak: f64,
    /// scored tokens per forward second
    pub tokens_per_sec: f64,
    /// mean requests per executed batch
    pub mean_occupancy: f64,
    /// answered generate requests
    pub gen_requests: f64,
    /// tokens produced by greedy decode
    pub gen_tokens: f64,
    /// prompt tokens prefilled into KV caches
    pub prefill_tokens: f64,
    /// lockstep decode-step forwards executed
    pub decode_steps: f64,
    /// high-water mark of resident KV-cache bytes
    pub kv_bytes_peak: f64,
}

impl ServeSummary {
    pub fn from_metrics(m: &Metrics) -> ServeSummary {
        let requests = m.counter("serve.requests");
        let batches = m.counter("serve.batches");
        let tokens = m.counter("serve.tokens");
        let forward_secs = m.timer_total("serve.forward");
        let n_lat = m.observation_count("serve.latency_secs");
        ServeSummary {
            requests,
            batches,
            tokens,
            errors: m.counter("serve.errors"),
            forward_secs,
            mean_latency_secs: if n_lat > 0 {
                m.observation_sum("serve.latency_secs") / n_lat as f64
            } else {
                0.0
            },
            latency_p50_secs: m.percentile("serve.latency_secs", 0.5),
            latency_p95_secs: m.percentile("serve.latency_secs", 0.95),
            queue_depth_peak: m.gauge_peak("serve.queue_depth"),
            tokens_per_sec: if forward_secs > 0.0 { tokens / forward_secs } else { 0.0 },
            mean_occupancy: if batches > 0.0 { requests / batches } else { 0.0 },
            gen_requests: m.counter("serve.gen_requests"),
            gen_tokens: m.counter("serve.gen_tokens"),
            prefill_tokens: m.counter("serve.prefill_tokens"),
            decode_steps: m.counter("serve.decode_steps"),
            kv_bytes_peak: m.gauge_peak("serve.kv_bytes"),
        }
    }
}

impl std::fmt::Display for ServeSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} requests in {} batches (mean occupancy {:.2}), {} tokens, \
             {:.0} tok/s, latency mean {:.2} / p50 {:.2} / p95 {:.2} ms, \
             queue peak {:.0}, {} errors",
            self.requests,
            self.batches,
            self.mean_occupancy,
            self.tokens,
            self.tokens_per_sec,
            self.mean_latency_secs * 1e3,
            self.latency_p50_secs * 1e3,
            self.latency_p95_secs * 1e3,
            self.queue_depth_peak,
            self.errors
        )?;
        if self.gen_requests > 0.0 {
            write!(
                f,
                "; decode: {} generations, {} tokens over {} steps \
                 ({} prompt tokens prefilled, KV peak {:.1} KiB)",
                self.gen_requests,
                self.gen_tokens,
                self.decode_steps,
                self.prefill_tokens,
                self.kv_bytes_peak / 1024.0
            )?;
        }
        Ok(())
    }
}

/// Result of [`probe_throughput`]: one batched-vs-per-sequence serving
/// comparison over the same engine.
#[derive(Clone, Debug)]
pub struct ServeProbe {
    pub total_tokens: usize,
    /// wall seconds scoring every request with its own full forward
    pub per_seq_secs: f64,
    /// wall seconds answering the same requests through the serve loop
    pub serve_secs: f64,
    pub summary: ServeSummary,
}

impl ServeProbe {
    pub fn speedup(&self) -> f64 {
        self.per_seq_secs / self.serve_secs.max(1e-12)
    }

    pub fn sequential_tok_per_sec(&self) -> f64 {
        self.total_tokens as f64 / self.per_seq_secs.max(1e-12)
    }

    pub fn batched_tok_per_sec(&self) -> f64 {
        self.total_tokens as f64 / self.serve_secs.max(1e-12)
    }
}

/// The measurement behind `rilq serve-bench` and the serve section of
/// `bench_runtime` (one implementation so the two can't drift): generate
/// a seeded ragged request mix (lengths in `[seq/2, seq]`), score it
/// once per-sequence and once through a [`Server`], and cross-check the
/// answers (logp parity vs the sequential path) and the token counters
/// (forwarded tokens == Σ request lengths — no PAD-dummy waste) before
/// reporting throughput.
pub fn probe_throughput(
    scorer: Arc<BackendScorer>,
    n_requests: usize,
    max_batch: usize,
    seed: u64,
) -> Result<ServeProbe> {
    let dims = scorer.dims.clone();
    let mut rng = Rng::seed(seed);
    let requests: Vec<Vec<u32>> = (0..n_requests.max(1))
        .map(|_| {
            let len = (dims.seq / 2).max(1) + rng.below(dims.seq / 2 + 1);
            (0..len).map(|_| rng.below(dims.vocab) as u32).collect()
        })
        .collect();
    let total_tokens: usize = requests.iter().map(Vec::len).sum();

    // warm the worker pool and caches before either timed section
    scorer.score_sequential(&requests[..1])?;

    let t0 = Instant::now();
    let baseline = scorer.score_sequential(&requests)?;
    let per_seq_secs = t0.elapsed().as_secs_f64();

    let server = Server::start_shared(
        scorer,
        ServeConfig {
            max_batch,
            queue_capacity: max_batch.max(1) * 2,
            max_active: max_batch.max(1),
        },
    );
    let client = server.client();
    let t0 = Instant::now();
    let pendings: Vec<Pending> = requests
        .iter()
        .map(|r| client.submit(r.clone()))
        .collect::<Result<_>>()?;
    let answers: Vec<Vec<f32>> =
        pendings.into_iter().map(|p| p.wait()).collect::<Result<_>>()?;
    let serve_secs = t0.elapsed().as_secs_f64();
    drop(client);
    let summary = server.shutdown();

    for (a, b) in baseline.iter().zip(&answers) {
        ensure!(a.len() == b.len(), "serve loop dropped logp positions");
        for (x, y) in a.iter().zip(b) {
            ensure!(
                (x - y).abs() < 1e-4,
                "serve loop diverged from the sequential path: {x} vs {y}"
            );
        }
    }
    ensure!(
        summary.tokens as usize == total_tokens,
        "serve loop forwarded {} tokens but the requests total {total_tokens} \
         (PAD-dummy waste?)",
        summary.tokens
    );
    Ok(ServeProbe { total_tokens, per_seq_secs, serve_secs, summary })
}

/// Result of [`probe_decode`]: prefill-once + incremental steps vs the
/// quadratic repeated-full-forward baseline, over one greedy generation.
#[derive(Clone, Debug)]
pub struct DecodeProbe {
    pub prompt_tokens: usize,
    pub gen_tokens: usize,
    /// wall seconds: greedy decode via repeated full forwards (O(S²) rows)
    pub full_secs: f64,
    /// wall seconds: the single prompt prefill
    pub prefill_secs: f64,
    /// wall seconds: the incremental single-token decode steps
    pub step_secs: f64,
}

impl DecodeProbe {
    /// Prefill + steps: the whole incremental path.
    pub fn incremental_secs(&self) -> f64 {
        self.prefill_secs + self.step_secs
    }

    /// How much faster prefill-once + incremental steps is than
    /// recomputing the full forward for every generated token.
    pub fn speedup(&self) -> f64 {
        self.full_secs / self.incremental_secs().max(1e-12)
    }

    pub fn full_tok_per_sec(&self) -> f64 {
        self.gen_tokens as f64 / self.full_secs.max(1e-12)
    }

    pub fn incremental_tok_per_sec(&self) -> f64 {
        self.gen_tokens as f64 / self.incremental_secs().max(1e-12)
    }

    pub fn prefill_tok_per_sec(&self) -> f64 {
        self.prompt_tokens as f64 / self.prefill_secs.max(1e-12)
    }
}

/// The measurement behind the decode sections of `rilq serve-bench` and
/// `bench_runtime`: greedy-generate `gen_len` tokens from a seeded
/// `prompt_len`-token prompt twice — once recomputing the full forward
/// per token, once with prefill + KV-cache steps — and cross-check that
/// both paths produced the same tokens and log-probs before reporting.
pub fn probe_decode(
    scorer: &BackendScorer,
    prompt_len: usize,
    gen_len: usize,
    seed: u64,
) -> Result<DecodeProbe> {
    let dims = scorer.dims.clone();
    ensure!(
        prompt_len >= 1 && gen_len >= 1,
        "probe_decode needs a prompt and at least one generated token"
    );
    ensure!(
        prompt_len + gen_len <= dims.seq,
        "prompt {prompt_len} + generation {gen_len} exceeds the model window {}",
        dims.seq
    );
    let mut rng = Rng::seed(seed);
    let prompt: Vec<u32> = (0..prompt_len).map(|_| rng.below(dims.vocab) as u32).collect();

    // warm the worker pool and caches before either timed section
    scorer.forward_logits(&prompt)?;

    let t0 = Instant::now();
    let (full_toks, full_lps) = greedy_decode_recompute(scorer, &prompt, gen_len)?;
    let full_secs = t0.elapsed().as_secs_f64();

    let mut cache = scorer.new_cache();
    let t0 = Instant::now();
    let lg = scorer.cache_forward(&prompt, &mut cache)?;
    let prefill_secs = t0.elapsed().as_secs_f64();
    let (mut tok, mut lp) = argmax_logp(lg.row(prompt_len - 1));
    let mut toks = vec![tok];
    let mut lps = vec![lp];
    let t0 = Instant::now();
    while toks.len() < gen_len {
        let lg = scorer.cache_forward(&[tok], &mut cache)?;
        (tok, lp) = argmax_logp(lg.row(0));
        toks.push(tok);
        lps.push(lp);
    }
    let step_secs = t0.elapsed().as_secs_f64();

    ensure!(
        toks == full_toks,
        "incremental decode diverged from the full-recompute decode"
    );
    for (a, b) in lps.iter().zip(&full_lps) {
        ensure!((a - b).abs() < 1e-5, "incremental logp diverged: {a} vs {b}");
    }
    Ok(DecodeProbe {
        prompt_tokens: prompt_len,
        gen_tokens: gen_len,
        full_secs,
        prefill_secs,
        step_secs,
    })
}
