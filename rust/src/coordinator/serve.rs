//! Serving compatibility layer and benchmark probes over the
//! [`crate::engine`] request-lifecycle engine.
//!
//! The continuous-batching loop that used to live here was rebuilt as
//! [`crate::engine::Engine`]: typed [`crate::engine::Request`]s, a
//! two-queue admission scheduler (score traffic is served *between*
//! decode iterations instead of head-of-line blocking behind full
//! decode slots), chunked prefill, sampling, and streaming. This module
//! keeps:
//!
//! * [`Server`] / [`ServeClient`] — thin **deprecated** shims so
//!   pre-engine callers keep compiling; they delegate verb-for-verb to
//!   [`crate::engine::EngineClient`] (`score` → `Request::Score`,
//!   `generate` → `Request::Generate` with greedy
//!   [`crate::engine::SamplingParams`]);
//! * [`ServeSummary`] — the aggregated serving counters, derived from
//!   the engine's [`Metrics`];
//! * [`probe_throughput`] / [`probe_decode`] — the shared measurement
//!   harnesses behind `rilq serve-bench` and `bench_runtime`.

// R1 no-panic serving surface (see the invariant catalog in the crate
// docs); test modules are excused via clippy.toml.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::engine::{Engine, EngineConfig, SamplingParams};
use crate::eval::scorer::greedy_decode_recompute;
use crate::eval::{argmax_logp, BackendScorer, Scorer};
use crate::tensor::Rng;

use super::Metrics;

// Compatibility re-exports: these types moved into the engine.
pub use crate::engine::EngineConfig as ServeConfig;
pub use crate::engine::{Generated, Pending};

/// The running serve loop — a compatibility wrapper over a
/// single-replica [`Engine`]. New code should construct the engine
/// directly ([`Engine::start`]) and use its typed client.
pub struct Server {
    engine: Engine,
}

impl Server {
    /// Spawn the serve loop over an owned scorer.
    pub fn start<S: Scorer + Send + Sync + 'static>(scorer: S, cfg: ServeConfig) -> Server {
        Server { engine: Engine::start(scorer, cfg) }
    }

    /// Spawn the serve loop over a shared scorer (e.g. one
    /// [`BackendScorer`] also used elsewhere — the engine is read-only
    /// at serving time).
    pub fn start_shared(scorer: Arc<dyn Scorer + Send + Sync>, cfg: ServeConfig) -> Server {
        Server { engine: Engine::start_shared(scorer, cfg) }
    }

    /// The underlying engine (the non-deprecated surface).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn client(&self) -> ServeClient {
        ServeClient { inner: self.engine.client() }
    }

    pub fn metrics(&self) -> &Metrics {
        self.engine.metrics()
    }

    pub fn config(&self) -> &ServeConfig {
        self.engine.config()
    }

    /// Snapshot of the throughput/latency counters.
    pub fn summary(&self) -> ServeSummary {
        self.engine.summary()
    }

    /// Drain the queue, stop the loop, and return the final counters.
    pub fn shutdown(self) -> ServeSummary {
        self.engine.shutdown()
    }
}

/// Cheap, cloneable submission handle — the pre-engine verbs, kept as
/// deprecated shims over [`crate::engine::EngineClient`].
#[derive(Clone)]
pub struct ServeClient {
    inner: crate::engine::EngineClient,
}

impl ServeClient {
    /// The typed client this shim delegates to.
    pub fn engine(&self) -> &crate::engine::EngineClient {
        &self.inner
    }

    /// Enqueue a sequence for scoring.
    #[deprecated(note = "use EngineClient::score (Request::Score lifecycle)")]
    pub fn submit(&self, tokens: Vec<u32>) -> Result<Pending<Vec<f32>>> {
        self.inner.score(tokens)
    }

    /// Submit and block for the answer.
    #[deprecated(note = "use EngineClient::score(..)?.wait()")]
    pub fn score(&self, tokens: Vec<u32>) -> Result<Vec<f32>> {
        self.inner.score(tokens)?.wait()
    }

    /// Greedy generation with a token budget.
    #[deprecated(note = "use EngineClient::generate with SamplingParams (greedy/sampled/streamed)")]
    pub fn generate(&self, prompt: Vec<u32>, max_new: usize) -> Result<Pending<Generated>> {
        self.inner.generate(prompt, SamplingParams::greedy(max_new))
    }
}

/// Aggregated serving counters, derived from the engine's [`Metrics`].
#[derive(Clone, Debug)]
pub struct ServeSummary {
    pub requests: f64,
    pub batches: f64,
    pub tokens: f64,
    pub errors: f64,
    /// wall seconds spent inside scoring forwards
    pub forward_secs: f64,
    /// mean request latency (enqueue → response), seconds
    pub mean_latency_secs: f64,
    /// median request latency, seconds (`None` until something is observed)
    pub latency_p50_secs: Option<f64>,
    /// 95th-percentile request latency, seconds (`None` until observed)
    pub latency_p95_secs: Option<f64>,
    /// high-water mark of the request queue depth
    pub queue_depth_peak: f64,
    /// scored tokens per forward second
    pub tokens_per_sec: f64,
    /// mean requests per executed batch
    pub mean_occupancy: f64,
    /// answered generate requests
    pub gen_requests: f64,
    /// tokens produced by decode (greedy or sampled)
    pub gen_tokens: f64,
    /// answered choice-scoring requests
    pub choice_requests: f64,
    /// prompt tokens prefilled into KV caches
    pub prefill_tokens: f64,
    /// fused prefill/decode scheduler steps executed
    pub decode_steps: f64,
    /// high-water mark of resident KV-cache bytes — *blocks in use*
    /// across the active generations, not the full-capacity worst case
    pub kv_bytes_peak: f64,
    /// high-water mark of KV arena blocks held by active generations
    pub kv_blocks_peak: f64,
    /// low-water mark companion: free arena blocks at the last sample
    pub kv_blocks_free: f64,
    /// generations evicted from the arena (later resumed bit-exact via
    /// replay prefill)
    pub preemptions: f64,
    /// Generate admissions whose prompt matched a cached prefix in the
    /// cross-request [`crate::engine::PrefixIndex`]
    pub prefix_hits: f64,
    /// Generate admissions that found no cached prefix
    pub prefix_misses: f64,
    /// prompt tokens *not* forwarded because their KV blocks were
    /// attached from the prefix cache (the PR-3 `rows_forwarded` idiom,
    /// now fleet-wide)
    pub prefix_tokens_saved: f64,
    /// whole cached-prefix entries' blocks released under arena pressure
    /// (always before any generation is preempted)
    pub prefix_evictions: f64,
    /// KV arena blocks currently held by the prefix index (0 after a
    /// clean shutdown — the refcount-leak canary)
    pub kv_blocks_pinned: f64,
    /// median compute rate of the quantized linears across timed
    /// forwards (GFLOP/s over `ModelDims::linear_flops_per_token` —
    /// the `serve.kernel_gflops` series; `None` until a forward ran)
    pub kernel_gflops_p50: Option<f64>,
    /// queued requests shed at a deadline before costing any forward
    pub shed: f64,
    /// requests abandoned by the caller (`Pending::cancel` or drop)
    pub cancelled: f64,
    /// scorer-fault retries (local re-queues and peer failovers)
    pub retries: f64,
    /// generations aborted mid-decode by an expired deadline
    pub deadline_aborts: f64,
    /// routable replicas at the last health change (fleet size while
    /// everything is healthy)
    pub replicas_healthy: f64,
    /// median time-to-first-token of generations, seconds (the SLO
    /// series; `None` until a generation sampled its first token)
    pub ttft_p50_secs: Option<f64>,
    /// 99th-percentile time-to-first-token, seconds
    pub ttft_p99_secs: Option<f64>,
    /// 99th-percentile TTFT of the high-priority class alone — the
    /// number overload must not move more than 2× (`None` while no
    /// high-priority generation ran)
    pub ttft_high_p99_secs: Option<f64>,
    /// 99th-percentile per-token decode latency, seconds (fused-step
    /// wall time amortized over tokens committed that step)
    pub tok_latency_p99_secs: Option<f64>,
    /// requests answered `Ok` *within their deadline* — goodput, vs the
    /// raw token throughput that also counts work nobody waited for
    pub goodput_requests: f64,
    /// admissions rejected by the queue high-watermark (typed
    /// `Overloaded` answers, all priorities)
    pub overload_sheds: f64,
    /// the high-priority slice of `overload_sheds` — the serve-bench
    /// overload run asserts this stays 0 while the low class sheds
    pub overload_sheds_high: f64,
    /// admissions rejected by a tenant's empty token bucket
    pub rate_limited: f64,
    /// low-priority generations admitted with a brownout-capped
    /// `max_new`
    pub brownouts: f64,
    /// timed forwards over `EngineConfig::slow_forward_threshold` (the
    /// slow-replica watchdog's trigger count)
    pub slow_forwards: f64,
}

impl ServeSummary {
    pub fn from_metrics(m: &Metrics) -> ServeSummary {
        let requests = m.counter("serve.requests");
        let batches = m.counter("serve.batches");
        let tokens = m.counter("serve.tokens");
        let forward_secs = m.timer_total("serve.forward");
        let n_lat = m.observation_count("serve.latency_secs");
        ServeSummary {
            requests,
            batches,
            tokens,
            errors: m.counter("serve.errors"),
            forward_secs,
            mean_latency_secs: if n_lat > 0 {
                m.observation_sum("serve.latency_secs") / n_lat as f64
            } else {
                0.0
            },
            // empty and singleton series are both well-defined: no
            // observations -> None, one observation -> that sample for
            // every percentile (regression-tested below)
            latency_p50_secs: m.percentile("serve.latency_secs", 0.5),
            latency_p95_secs: m.percentile("serve.latency_secs", 0.95),
            queue_depth_peak: m.gauge_peak("serve.queue_depth"),
            tokens_per_sec: if forward_secs > 0.0 { tokens / forward_secs } else { 0.0 },
            mean_occupancy: if batches > 0.0 { requests / batches } else { 0.0 },
            gen_requests: m.counter("serve.gen_requests"),
            gen_tokens: m.counter("serve.gen_tokens"),
            choice_requests: m.counter("serve.choice_requests"),
            prefill_tokens: m.counter("serve.prefill_tokens"),
            decode_steps: m.counter("serve.decode_steps"),
            kv_bytes_peak: m.gauge_peak("serve.kv_bytes"),
            kv_blocks_peak: m.gauge_peak("serve.kv_blocks_used"),
            kv_blocks_free: m.gauge("serve.kv_blocks_free"),
            preemptions: m.counter("serve.preemptions"),
            prefix_hits: m.counter("serve.prefix_hits"),
            prefix_misses: m.counter("serve.prefix_misses"),
            prefix_tokens_saved: m.counter("serve.prefix_tokens_saved"),
            prefix_evictions: m.counter("serve.prefix_evictions"),
            kv_blocks_pinned: m.gauge("serve.kv_blocks_pinned"),
            kernel_gflops_p50: m.percentile("serve.kernel_gflops", 0.5),
            shed: m.counter("serve.shed"),
            cancelled: m.counter("serve.cancelled"),
            retries: m.counter("serve.retries"),
            deadline_aborts: m.counter("serve.deadline_aborts"),
            replicas_healthy: m.gauge("serve.replicas_healthy"),
            ttft_p50_secs: m.percentile("serve.ttft_secs", 0.5),
            ttft_p99_secs: m.percentile("serve.ttft_secs", 0.99),
            ttft_high_p99_secs: m.percentile("serve.ttft_high_secs", 0.99),
            tok_latency_p99_secs: m.percentile("serve.tok_latency_secs", 0.99),
            goodput_requests: m.counter("serve.goodput_requests"),
            overload_sheds: m.counter("serve.overload_sheds"),
            overload_sheds_high: m.counter("serve.overload_sheds_high"),
            rate_limited: m.counter("serve.rate_limited"),
            brownouts: m.counter("serve.brownouts"),
            slow_forwards: m.counter("serve.slow_forwards"),
        }
    }
}

fn fmt_ms(v: Option<f64>) -> String {
    match v {
        Some(s) => format!("{:.2}", s * 1e3),
        None => "-".to_string(),
    }
}

impl std::fmt::Display for ServeSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} requests in {} batches (mean occupancy {:.2}), {} tokens, \
             {:.0} tok/s, latency mean {:.2} / p50 {} / p95 {} ms, \
             queue peak {:.0}, {} errors",
            self.requests,
            self.batches,
            self.mean_occupancy,
            self.tokens,
            self.tokens_per_sec,
            self.mean_latency_secs * 1e3,
            fmt_ms(self.latency_p50_secs),
            fmt_ms(self.latency_p95_secs),
            self.queue_depth_peak,
            self.errors
        )?;
        if let Some(g) = self.kernel_gflops_p50 {
            write!(f, ", kernel {g:.2} GFLOP/s (p50)")?;
        }
        if self.gen_requests > 0.0 {
            write!(
                f,
                "; decode: {} generations, {} tokens over {} scheduler steps \
                 ({} prompt tokens prefilled, KV peak {:.1} KiB / {:.0} blocks, \
                 {} preemptions)",
                self.gen_requests,
                self.gen_tokens,
                self.decode_steps,
                self.prefill_tokens,
                self.kv_bytes_peak / 1024.0,
                self.kv_blocks_peak,
                self.preemptions
            )?;
        }
        // the prefix-cache clause only appears once the index saw
        // traffic, so cache-off (or all-cold) runs read as before
        if self.prefix_hits + self.prefix_misses > 0.0 {
            write!(
                f,
                "; prefix cache: {} hits / {} misses, {} tokens saved, \
                 {} evictions, {:.0} blocks pinned",
                self.prefix_hits,
                self.prefix_misses,
                self.prefix_tokens_saved,
                self.prefix_evictions,
                self.kv_blocks_pinned
            )?;
        }
        // fault-tolerance counters only appear once something fired, so
        // the steady-state summary line stays unchanged
        if self.shed + self.cancelled + self.retries + self.deadline_aborts > 0.0 {
            write!(
                f,
                "; faults: {} shed, {} cancelled, {} retries, {} deadline aborts",
                self.shed, self.cancelled, self.retries, self.deadline_aborts
            )?;
        }
        // SLO clause: appears once a generation produced a first token
        if self.ttft_p50_secs.is_some() {
            write!(
                f,
                "; slo: ttft p50 {} / p99 {} ms (high p99 {}), tok p99 {} ms, \
                 {} goodput",
                fmt_ms(self.ttft_p50_secs),
                fmt_ms(self.ttft_p99_secs),
                fmt_ms(self.ttft_high_p99_secs),
                fmt_ms(self.tok_latency_p99_secs),
                self.goodput_requests
            )?;
        }
        // overload clause: appears once admission control rejected or
        // dimmed anything, so uncontended runs read as before
        if self.overload_sheds + self.rate_limited + self.brownouts + self.slow_forwards > 0.0 {
            write!(
                f,
                "; overload: {} sheds ({} high), {} rate-limited, {} brownouts, \
                 {} slow forwards",
                self.overload_sheds,
                self.overload_sheds_high,
                self.rate_limited,
                self.brownouts,
                self.slow_forwards
            )?;
        }
        if self.replicas_healthy > 0.0 {
            write!(f, ", {:.0} replicas healthy", self.replicas_healthy)?;
        }
        Ok(())
    }
}

/// Result of [`probe_throughput`]: one batched-vs-per-sequence serving
/// comparison over the same engine.
#[derive(Clone, Debug)]
pub struct ServeProbe {
    pub total_tokens: usize,
    /// wall seconds scoring every request with its own full forward
    pub per_seq_secs: f64,
    /// wall seconds answering the same requests through the engine
    pub serve_secs: f64,
    pub summary: ServeSummary,
}

impl ServeProbe {
    pub fn speedup(&self) -> f64 {
        self.per_seq_secs / self.serve_secs.max(1e-12)
    }

    pub fn sequential_tok_per_sec(&self) -> f64 {
        self.total_tokens as f64 / self.per_seq_secs.max(1e-12)
    }

    pub fn batched_tok_per_sec(&self) -> f64 {
        self.total_tokens as f64 / self.serve_secs.max(1e-12)
    }
}

/// The measurement behind `rilq serve-bench` and the serve section of
/// `bench_runtime` (one implementation so the two can't drift): generate
/// a seeded ragged request mix (lengths in `[seq/2, seq]`), score it
/// once per-sequence and once through an [`Engine`], and cross-check the
/// answers (logp parity vs the sequential path) and the token counters
/// (forwarded tokens == Σ request lengths — no PAD-dummy waste) before
/// reporting throughput.
// lint: allow(indexing) — `requests` has `n_requests.max(1) >= 1` entries, so
// the warmup slice `[..1]` is always in bounds
pub fn probe_throughput(
    scorer: Arc<BackendScorer>,
    n_requests: usize,
    max_batch: usize,
    seed: u64,
) -> Result<ServeProbe> {
    let dims = scorer.dims.clone();
    let mut rng = Rng::seed(seed);
    let requests: Vec<Vec<u32>> = (0..n_requests.max(1))
        .map(|_| {
            let len = (dims.seq / 2).max(1) + rng.below(dims.seq / 2 + 1);
            (0..len).map(|_| rng.below(dims.vocab) as u32).collect()
        })
        .collect();
    let total_tokens: usize = requests.iter().map(Vec::len).sum();

    // warm the worker pool and caches before either timed section
    scorer.score_sequential(&requests[..1])?;

    let t0 = Instant::now();
    let baseline = scorer.score_sequential(&requests)?;
    let per_seq_secs = t0.elapsed().as_secs_f64();

    let engine = Engine::start_shared(
        scorer,
        EngineConfig {
            max_batch,
            queue_capacity: max_batch.max(1) * 2,
            max_active: max_batch.max(1),
            ..EngineConfig::default()
        },
    );
    let client = engine.client();
    let t0 = Instant::now();
    let pendings: Vec<Pending<Vec<f32>>> = requests
        .iter()
        .map(|r| client.score(r.clone()))
        .collect::<Result<_>>()?;
    let answers: Vec<Vec<f32>> =
        pendings.into_iter().map(|p| p.wait()).collect::<Result<_>>()?;
    let serve_secs = t0.elapsed().as_secs_f64();
    drop(client);
    let summary = engine.shutdown();

    for (a, b) in baseline.iter().zip(&answers) {
        ensure!(a.len() == b.len(), "serve loop dropped logp positions");
        for (x, y) in a.iter().zip(b) {
            ensure!(
                (x - y).abs() < 1e-4,
                "serve loop diverged from the sequential path: {x} vs {y}"
            );
        }
    }
    ensure!(
        summary.tokens as usize == total_tokens,
        "serve loop forwarded {} tokens but the requests total {total_tokens} \
         (PAD-dummy waste?)",
        summary.tokens
    );
    Ok(ServeProbe { total_tokens, per_seq_secs, serve_secs, summary })
}

/// Result of [`probe_decode`]: prefill-once + incremental steps vs the
/// quadratic repeated-full-forward baseline, over one greedy generation.
#[derive(Clone, Debug)]
pub struct DecodeProbe {
    pub prompt_tokens: usize,
    pub gen_tokens: usize,
    /// wall seconds: greedy decode via repeated full forwards (O(S²) rows)
    pub full_secs: f64,
    /// wall seconds: the single prompt prefill
    pub prefill_secs: f64,
    /// wall seconds: the incremental single-token decode steps
    pub step_secs: f64,
    /// KV bytes resident at the end of the decode (blocks actually held)
    pub kv_resident_bytes: usize,
    /// KV bytes a full-window cache would hold (the pre-paged constant)
    pub kv_capacity_bytes: usize,
}

impl DecodeProbe {
    /// Prefill + steps: the whole incremental path.
    pub fn incremental_secs(&self) -> f64 {
        self.prefill_secs + self.step_secs
    }

    /// How much faster prefill-once + incremental steps is than
    /// recomputing the full forward for every generated token.
    pub fn speedup(&self) -> f64 {
        self.full_secs / self.incremental_secs().max(1e-12)
    }

    pub fn full_tok_per_sec(&self) -> f64 {
        self.gen_tokens as f64 / self.full_secs.max(1e-12)
    }

    pub fn incremental_tok_per_sec(&self) -> f64 {
        self.gen_tokens as f64 / self.incremental_secs().max(1e-12)
    }

    pub fn prefill_tok_per_sec(&self) -> f64 {
        self.prompt_tokens as f64 / self.prefill_secs.max(1e-12)
    }

    /// Resident KV bytes amortized per generated token — the paged
    /// memory cost of decode, reported so the paged-vs-contiguous win is
    /// a number in the bench record rather than a claim.
    pub fn kv_bytes_per_gen_token(&self) -> f64 {
        self.kv_resident_bytes as f64 / self.gen_tokens.max(1) as f64
    }
}

/// The measurement behind the decode sections of `rilq serve-bench` and
/// `bench_runtime`: greedy-generate `gen_len` tokens from a seeded
/// `prompt_len`-token prompt twice — once recomputing the full forward
/// per token, once with prefill + KV-cache steps — and cross-check that
/// both paths produced the same tokens and log-probs before reporting.
pub fn probe_decode(
    scorer: &BackendScorer,
    prompt_len: usize,
    gen_len: usize,
    seed: u64,
) -> Result<DecodeProbe> {
    let dims = scorer.dims.clone();
    ensure!(
        prompt_len >= 1 && gen_len >= 1,
        "probe_decode needs a prompt and at least one generated token"
    );
    ensure!(
        prompt_len + gen_len <= dims.seq,
        "prompt {prompt_len} + generation {gen_len} exceeds the model window {}",
        dims.seq
    );
    let mut rng = Rng::seed(seed);
    let prompt: Vec<u32> = (0..prompt_len).map(|_| rng.below(dims.vocab) as u32).collect();

    // warm the worker pool and caches before either timed section
    scorer.forward_logits(&prompt)?;

    let t0 = Instant::now();
    let (full_toks, full_lps) = greedy_decode_recompute(scorer, &prompt, gen_len)?;
    let full_secs = t0.elapsed().as_secs_f64();

    let mut cache = scorer.new_cache();
    let t0 = Instant::now();
    let lg = scorer.cache_forward(&prompt, &mut cache)?;
    let prefill_secs = t0.elapsed().as_secs_f64();
    let (mut tok, mut lp) = argmax_logp(lg.row(prompt_len - 1));
    let mut toks = vec![tok];
    let mut lps = vec![lp];
    let t0 = Instant::now();
    while toks.len() < gen_len {
        let lg = scorer.cache_forward(&[tok], &mut cache)?;
        (tok, lp) = argmax_logp(lg.row(0));
        toks.push(tok);
        lps.push(lp);
    }
    let step_secs = t0.elapsed().as_secs_f64();
    let kv_resident_bytes = cache.bytes();
    let kv_capacity_bytes = cache.capacity_bytes();

    ensure!(
        toks == full_toks,
        "incremental decode diverged from the full-recompute decode"
    );
    for (a, b) in lps.iter().zip(&full_lps) {
        ensure!((a - b).abs() < 1e-5, "incremental logp diverged: {a} vs {b}");
    }
    Ok(DecodeProbe {
        prompt_tokens: prompt_len,
        gen_tokens: gen_len,
        full_secs,
        prefill_secs,
        step_secs,
        kv_resident_bytes,
        kv_capacity_bytes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_from_empty_metrics_reports_none_percentiles() {
        // regression: a summary over a fresh (or latency-free) metrics
        // sink must not panic or fabricate percentiles
        let m = Metrics::new();
        let s = ServeSummary::from_metrics(&m);
        assert_eq!(s.latency_p50_secs, None);
        assert_eq!(s.latency_p95_secs, None);
        assert_eq!(s.mean_latency_secs, 0.0);
        // the Display path must render the None percentiles too
        let text = format!("{s}");
        assert!(text.contains("p50 -"), "{text}");
    }

    #[test]
    fn summary_reports_kernel_gflops_when_observed() {
        // None until a timed forward fed the series; then the median
        // sample surfaces through the summary and its Display line
        let m = Metrics::new();
        assert_eq!(ServeSummary::from_metrics(&m).kernel_gflops_p50, None);
        let empty = format!("{}", ServeSummary::from_metrics(&m));
        assert!(!empty.contains("GFLOP/s"), "{empty}");
        m.observe("serve.kernel_gflops", 12.5);
        let s = ServeSummary::from_metrics(&m);
        assert_eq!(s.kernel_gflops_p50, Some(12.5));
        let text = format!("{s}");
        assert!(text.contains("kernel 12.50 GFLOP/s"), "{text}");
    }

    #[test]
    fn summary_zero_fault_counters_stay_quiet() {
        // a fault-free run reads exactly like it did before the
        // fault-tolerance layer existed: no "faults:" clause at all
        let m = Metrics::new();
        let s = ServeSummary::from_metrics(&m);
        assert_eq!(s.shed, 0.0);
        assert_eq!(s.cancelled, 0.0);
        assert_eq!(s.retries, 0.0);
        assert_eq!(s.deadline_aborts, 0.0);
        assert_eq!(s.replicas_healthy, 0.0);
        let text = format!("{s}");
        assert!(!text.contains("faults:"), "{text}");
        assert!(!text.contains("replicas healthy"), "{text}");
    }

    #[test]
    fn summary_surfaces_fault_tolerance_counters() {
        let m = Metrics::new();
        m.incr("serve.shed");
        m.add("serve.cancelled", 2.0);
        m.add("serve.retries", 3.0);
        m.incr("serve.deadline_aborts");
        m.gauge_set("serve.replicas_healthy", 2.0);
        let s = ServeSummary::from_metrics(&m);
        assert_eq!(s.shed, 1.0);
        assert_eq!(s.cancelled, 2.0);
        assert_eq!(s.retries, 3.0);
        assert_eq!(s.deadline_aborts, 1.0);
        assert_eq!(s.replicas_healthy, 2.0);
        let text = format!("{s}");
        assert!(
            text.contains("faults: 1 shed, 2 cancelled, 3 retries, 1 deadline aborts"),
            "{text}"
        );
        assert!(text.contains("2 replicas healthy"), "{text}");
    }

    #[test]
    fn summary_surfaces_prefix_cache_counters_only_when_traffic_fired() {
        // silent while the index saw no admissions (cache off, or no
        // Generate traffic at all) — the steady-state line is unchanged
        let m = Metrics::new();
        let quiet = format!("{}", ServeSummary::from_metrics(&m));
        assert!(!quiet.contains("prefix cache:"), "{quiet}");
        m.add("serve.prefix_hits", 3.0);
        m.incr("serve.prefix_misses");
        m.add("serve.prefix_tokens_saved", 24.0);
        m.add("serve.prefix_evictions", 2.0);
        m.gauge_set("serve.kv_blocks_pinned", 5.0);
        let s = ServeSummary::from_metrics(&m);
        assert_eq!(s.prefix_hits, 3.0);
        assert_eq!(s.prefix_misses, 1.0);
        assert_eq!(s.prefix_tokens_saved, 24.0);
        assert_eq!(s.prefix_evictions, 2.0);
        assert_eq!(s.kv_blocks_pinned, 5.0);
        let text = format!("{s}");
        assert!(
            text.contains(
                "prefix cache: 3 hits / 1 misses, 24 tokens saved, \
                 2 evictions, 5 blocks pinned"
            ),
            "{text}"
        );
    }

    #[test]
    fn summary_slo_and_overload_clauses_appear_only_with_traffic() {
        // a run with no generations and no admission-control rejections
        // renders exactly as it did before the overload layer existed
        let m = Metrics::new();
        let quiet = format!("{}", ServeSummary::from_metrics(&m));
        assert!(!quiet.contains("slo:"), "{quiet}");
        assert!(!quiet.contains("overload:"), "{quiet}");
        m.observe("serve.ttft_secs", 0.010);
        m.observe("serve.ttft_high_secs", 0.008);
        m.observe("serve.tok_latency_secs", 0.002);
        m.add("serve.goodput_requests", 7.0);
        m.add("serve.overload_sheds", 4.0);
        m.add("serve.overload_sheds_low", 4.0);
        m.add("serve.rate_limited", 2.0);
        m.incr("serve.brownouts");
        m.add("serve.slow_forwards", 3.0);
        let s = ServeSummary::from_metrics(&m);
        assert_eq!(s.ttft_p50_secs, Some(0.010));
        assert_eq!(s.ttft_p99_secs, Some(0.010));
        assert_eq!(s.ttft_high_p99_secs, Some(0.008));
        assert_eq!(s.tok_latency_p99_secs, Some(0.002));
        assert_eq!(s.goodput_requests, 7.0);
        assert_eq!(s.overload_sheds, 4.0);
        assert_eq!(s.overload_sheds_high, 0.0, "only the low class shed");
        assert_eq!(s.rate_limited, 2.0);
        assert_eq!(s.brownouts, 1.0);
        assert_eq!(s.slow_forwards, 3.0);
        let text = format!("{s}");
        assert!(
            text.contains("slo: ttft p50 10.00 / p99 10.00 ms (high p99 8.00)"),
            "{text}"
        );
        assert!(text.contains("7 goodput"), "{text}");
        assert!(
            text.contains("overload: 4 sheds (0 high), 2 rate-limited, 1 brownouts"),
            "{text}"
        );
        assert!(text.contains("3 slow forwards"), "{text}");
    }

    #[test]
    fn summary_from_singleton_series_reports_that_sample() {
        let m = Metrics::new();
        m.observe("serve.latency_secs", 0.25);
        let s = ServeSummary::from_metrics(&m);
        assert_eq!(s.latency_p50_secs, Some(0.25));
        assert_eq!(s.latency_p95_secs, Some(0.25));
        assert!((s.mean_latency_secs - 0.25).abs() < 1e-12);
        let text = format!("{s}");
        assert!(text.contains("p50 250.00"), "{text}");
    }
}
