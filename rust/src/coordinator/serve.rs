//! Continuous-batching serving loop over a shared [`Scorer`].
//!
//! RILQ's deliverable is an adapter-merged weight-quantized model meant
//! for *serving*: requests arrive one at a time, ragged, and the engine
//! wants them coalesced so each `LinearBackend::forward` runs once per
//! layer over the whole batch (see
//! [`crate::model::forward::forward_trace_batch`]). This module is the
//! loop that does the coalescing:
//!
//! * requests enter a **bounded** queue (`sync_channel` — the same
//!   backpressure idiom as [`super::batcher::BatchStream`]: submitters
//!   block when the queue is full, so server memory stays constant no
//!   matter how fast clients push);
//! * the serve loop blocks for the first request, then **greedily drains**
//!   whatever else is already queued (up to `max_batch`) — under light
//!   load a request never waits for a batch to fill, under heavy load
//!   batches fill to `max_batch` automatically;
//! * the coalesced ragged batch goes through `Scorer::score_batch` as the
//!   real sequences only — **no PAD-dummy filler is ever forwarded**
//!   (pinned by `tests/serve_loop.rs` via the token counters);
//! * per-request failures (e.g. a sequence longer than the model window)
//!   answer that request with `Err` without poisoning its batchmates or
//!   the loop.
//!
//! Throughput and latency land in a [`Metrics`] sink
//! (`serve.requests`, `serve.batches`, `serve.tokens`, `serve.errors`,
//! `serve.latency_secs`, timer `serve.forward`), summarized by
//! [`ServeSummary`]. The CLI exposes the loop as `rilq serve-bench`.

use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use crate::eval::scorer::check_input;
use crate::eval::{BackendScorer, Scorer};
use crate::tensor::Rng;

use super::Metrics;

/// Serving-loop knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Coalesce at most this many requests into one forward.
    pub max_batch: usize,
    /// Bounded request-queue depth (backpressure: submit blocks beyond it).
    pub queue_capacity: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_batch: 8, queue_capacity: 32 }
    }
}

/// One queued scoring request.
struct Request {
    tokens: Vec<u32>,
    enqueued: Instant,
    resp: Sender<Result<Vec<f32>>>,
}

enum Msg {
    Req(Request),
    Shutdown,
}

/// A submitted request's pending response (one-shot).
pub struct Pending {
    rx: Receiver<Result<Vec<f32>>>,
}

impl Pending {
    /// Block until the server answers: the `[len-1]` next-token log-probs,
    /// or the per-request error.
    pub fn wait(self) -> Result<Vec<f32>> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("server shut down before answering this request"))?
    }
}

/// Cheap, cloneable submission handle.
#[derive(Clone)]
pub struct ServeClient {
    tx: SyncSender<Msg>,
}

impl ServeClient {
    /// Enqueue a sequence for scoring. Blocks while the bounded queue is
    /// full (backpressure); errs once the server has shut down.
    pub fn submit(&self, tokens: Vec<u32>) -> Result<Pending> {
        let (resp, rx) = channel();
        self.tx
            .send(Msg::Req(Request { tokens, enqueued: Instant::now(), resp }))
            .map_err(|_| anyhow!("server stopped"))?;
        Ok(Pending { rx })
    }

    /// Submit and block for the answer.
    pub fn score(&self, tokens: Vec<u32>) -> Result<Vec<f32>> {
        self.submit(tokens)?.wait()
    }
}

/// The running server: a dedicated loop thread owning the scorer queue.
/// Dropping the `Server` initiates shutdown: requests already queued are
/// drained and answered, later submissions err.
pub struct Server {
    tx: Option<SyncSender<Msg>>,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    cfg: ServeConfig,
}

impl Server {
    /// Spawn the serve loop over an owned scorer.
    pub fn start<S: Scorer + Send + Sync + 'static>(scorer: S, cfg: ServeConfig) -> Server {
        Server::start_shared(Arc::new(scorer), cfg)
    }

    /// Spawn the serve loop over a shared scorer (e.g. one
    /// [`crate::eval::BackendScorer`] also used elsewhere — the engine is
    /// read-only at serving time).
    pub fn start_shared(scorer: Arc<dyn Scorer + Send + Sync>, cfg: ServeConfig) -> Server {
        let (tx, rx) = sync_channel(cfg.queue_capacity.max(1));
        let metrics = Arc::new(Metrics::new());
        let m = metrics.clone();
        let c = cfg.clone();
        let worker = std::thread::Builder::new()
            .name("rilq-serve".into())
            .spawn(move || serve_loop(scorer, rx, c, m))
            .expect("spawn serve loop");
        Server { tx: Some(tx), worker: Some(worker), metrics, cfg }
    }

    pub fn client(&self) -> ServeClient {
        ServeClient { tx: self.tx.as_ref().expect("server running").clone() }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Snapshot of the throughput/latency counters.
    pub fn summary(&self) -> ServeSummary {
        ServeSummary::from_metrics(&self.metrics)
    }

    /// Drain the queue, stop the loop, and return the final counters.
    pub fn shutdown(mut self) -> ServeSummary {
        self.stop();
        ServeSummary::from_metrics(&self.metrics)
    }

    fn stop(&mut self) {
        if let Some(tx) = self.tx.take() {
            // the sentinel queues behind every already-submitted request,
            // so shutdown drains gracefully; send only errs if the loop
            // is already gone
            let _ = tx.send(Msg::Shutdown);
            drop(tx);
        }
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_loop(
    scorer: Arc<dyn Scorer + Send + Sync>,
    rx: Receiver<Msg>,
    cfg: ServeConfig,
    metrics: Arc<Metrics>,
) {
    let max_batch = cfg.max_batch.max(1);
    let dims = scorer.dims().clone();
    // answer a malformed request (over-window, out-of-vocab) without
    // touching the model — and without poisoning its batchmates
    let admit = |req: Request, reqs: &mut Vec<Request>| {
        match check_input(&dims, std::slice::from_ref(&req.tokens)) {
            Ok(()) => reqs.push(req),
            Err(e) => {
                metrics.incr("serve.errors");
                let _ = req.resp.send(Err(e));
            }
        }
    };
    let mut shutting_down = false;
    while !shutting_down {
        let first = match rx.recv() {
            Ok(Msg::Req(r)) => r,
            Ok(Msg::Shutdown) | Err(_) => break,
        };
        let mut reqs = Vec::with_capacity(max_batch);
        admit(first, &mut reqs);
        // greedy coalesce: take whatever is already queued, never wait
        while reqs.len() < max_batch {
            match rx.try_recv() {
                Ok(Msg::Req(r)) => admit(r, &mut reqs),
                Ok(Msg::Shutdown) => {
                    shutting_down = true;
                    break;
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    shutting_down = true;
                    break;
                }
            }
        }
        if reqs.is_empty() {
            continue;
        }
        // move the tokens out (they are not needed for the response)
        let batch: Vec<Vec<u32>> =
            reqs.iter_mut().map(|r| std::mem::take(&mut r.tokens)).collect();
        let n_tokens: usize = batch.iter().map(Vec::len).sum();
        let scored = metrics.time("serve.forward", || scorer.score_batch(&batch));
        match scored {
            Ok(outs) => {
                metrics.incr("serve.batches");
                metrics.add("serve.requests", reqs.len() as f64);
                metrics.add("serve.tokens", n_tokens as f64);
                for (req, out) in reqs.into_iter().zip(outs) {
                    metrics.add("serve.latency_secs", req.enqueued.elapsed().as_secs_f64());
                    let _ = req.resp.send(Ok(out));
                }
            }
            Err(e) => {
                // batch-level failure: answer every member, keep serving
                metrics.add("serve.errors", reqs.len() as f64);
                let msg = format!("{e:#}");
                for req in reqs {
                    let _ = req.resp.send(Err(anyhow!("{msg}")));
                }
            }
        }
    }
    // loop exit: any messages still queued were submitted after shutdown
    // began; dropping their response senders errs the callers' `wait()`.
}

/// Aggregated serving counters, derived from the loop's [`Metrics`].
#[derive(Clone, Debug)]
pub struct ServeSummary {
    pub requests: f64,
    pub batches: f64,
    pub tokens: f64,
    pub errors: f64,
    /// wall seconds spent inside `score_batch`
    pub forward_secs: f64,
    /// mean request latency (enqueue → response), seconds
    pub mean_latency_secs: f64,
    /// scored tokens per forward second
    pub tokens_per_sec: f64,
    /// mean requests per executed batch
    pub mean_occupancy: f64,
}

impl ServeSummary {
    pub fn from_metrics(m: &Metrics) -> ServeSummary {
        let requests = m.counter("serve.requests");
        let batches = m.counter("serve.batches");
        let tokens = m.counter("serve.tokens");
        let forward_secs = m.timer_total("serve.forward");
        ServeSummary {
            requests,
            batches,
            tokens,
            errors: m.counter("serve.errors"),
            forward_secs,
            mean_latency_secs: if requests > 0.0 {
                m.counter("serve.latency_secs") / requests
            } else {
                0.0
            },
            tokens_per_sec: if forward_secs > 0.0 { tokens / forward_secs } else { 0.0 },
            mean_occupancy: if batches > 0.0 { requests / batches } else { 0.0 },
        }
    }
}

impl std::fmt::Display for ServeSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} requests in {} batches (mean occupancy {:.2}), {} tokens, \
             {:.0} tok/s, mean latency {:.2} ms, {} errors",
            self.requests,
            self.batches,
            self.mean_occupancy,
            self.tokens,
            self.tokens_per_sec,
            self.mean_latency_secs * 1e3,
            self.errors
        )
    }
}

/// Result of [`probe_throughput`]: one batched-vs-per-sequence serving
/// comparison over the same engine.
#[derive(Clone, Debug)]
pub struct ServeProbe {
    pub total_tokens: usize,
    /// wall seconds scoring every request with its own full forward
    pub per_seq_secs: f64,
    /// wall seconds answering the same requests through the serve loop
    pub serve_secs: f64,
    pub summary: ServeSummary,
}

impl ServeProbe {
    pub fn speedup(&self) -> f64 {
        self.per_seq_secs / self.serve_secs.max(1e-12)
    }

    pub fn sequential_tok_per_sec(&self) -> f64 {
        self.total_tokens as f64 / self.per_seq_secs.max(1e-12)
    }

    pub fn batched_tok_per_sec(&self) -> f64 {
        self.total_tokens as f64 / self.serve_secs.max(1e-12)
    }
}

/// The measurement behind `rilq serve-bench` and the serve section of
/// `bench_runtime` (one implementation so the two can't drift): generate
/// a seeded ragged request mix (lengths in `[seq/2, seq]`), score it
/// once per-sequence and once through a [`Server`], and cross-check the
/// answers (logp parity vs the sequential path) and the token counters
/// (forwarded tokens == Σ request lengths — no PAD-dummy waste) before
/// reporting throughput.
pub fn probe_throughput(
    scorer: Arc<BackendScorer>,
    n_requests: usize,
    max_batch: usize,
    seed: u64,
) -> Result<ServeProbe> {
    let dims = scorer.dims.clone();
    let mut rng = Rng::seed(seed);
    let requests: Vec<Vec<u32>> = (0..n_requests.max(1))
        .map(|_| {
            let len = (dims.seq / 2).max(1) + rng.below(dims.seq / 2 + 1);
            (0..len).map(|_| rng.below(dims.vocab) as u32).collect()
        })
        .collect();
    let total_tokens: usize = requests.iter().map(Vec::len).sum();

    // warm the worker pool and caches before either timed section
    scorer.score_sequential(&requests[..1])?;

    let t0 = Instant::now();
    let baseline = scorer.score_sequential(&requests)?;
    let per_seq_secs = t0.elapsed().as_secs_f64();

    let server = Server::start_shared(
        scorer,
        ServeConfig { max_batch, queue_capacity: max_batch.max(1) * 2 },
    );
    let client = server.client();
    let t0 = Instant::now();
    let pendings: Vec<Pending> = requests
        .iter()
        .map(|r| client.submit(r.clone()))
        .collect::<Result<_>>()?;
    let answers: Vec<Vec<f32>> =
        pendings.into_iter().map(|p| p.wait()).collect::<Result<_>>()?;
    let serve_secs = t0.elapsed().as_secs_f64();
    drop(client);
    let summary = server.shutdown();

    for (a, b) in baseline.iter().zip(&answers) {
        ensure!(a.len() == b.len(), "serve loop dropped logp positions");
        for (x, y) in a.iter().zip(b) {
            ensure!(
                (x - y).abs() < 1e-4,
                "serve loop diverged from the sequential path: {x} vs {y}"
            );
        }
    }
    ensure!(
        summary.tokens as usize == total_tokens,
        "serve loop forwarded {} tokens but the requests total {total_tokens} \
         (PAD-dummy waste?)",
        summary.tokens
    );
    Ok(ServeProbe { total_tokens, per_seq_secs, serve_secs, summary })
}
