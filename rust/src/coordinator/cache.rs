//! Content-keyed run cache.
//!
//! Expensive stages (teacher pretraining, quantization, calibration) are
//! cached under `runs/<fnv64(key)>/` so every experiment that shares a
//! stage reuses it. Keys are explicit human-readable config strings; the
//! directory keeps both the key (`key.txt`, for auditing) and the stage's
//! tensors (`data.bin`, [`TensorFile`]) plus optional JSON metadata.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::Result;

use crate::model::weights::TensorFile;
use crate::report::Json;

/// FNV-1a 64-bit, stable across runs/platforms (cache-key hash).
pub fn fnv64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// A run-cache rooted at some directory.
#[derive(Clone, Debug)]
pub struct RunCache {
    root: PathBuf,
}

impl RunCache {
    pub fn new(root: impl AsRef<Path>) -> RunCache {
        RunCache { root: root.as_ref().to_path_buf() }
    }

    pub fn dir_for(&self, key: &str) -> PathBuf {
        self.root.join(format!("{:016x}", fnv64(key)))
    }

    pub fn contains(&self, key: &str) -> bool {
        self.dir_for(key).join("data.bin").exists()
    }

    /// Load the cached tensors for a key, or compute + persist them.
    ///
    /// Persisting is atomic: the tensors are written to a unique temp
    /// file in the same directory and `rename`d onto `data.bin`, so a
    /// concurrent grid worker polling [`RunCache::contains`] (or racing
    /// its own `get_or_compute` of the same key) can never load a
    /// partially-written entry. Racing writers are idempotent — both
    /// compute the same content-keyed payload; the last rename wins.
    pub fn get_or_compute(
        &self,
        key: &str,
        compute: impl FnOnce() -> Result<TensorFile>,
    ) -> Result<TensorFile> {
        let dir = self.dir_for(key);
        let data = dir.join("data.bin");
        if data.exists() {
            log::debug!("cache hit: {key}");
            return TensorFile::load(&data);
        }
        let tf = compute()?;
        std::fs::create_dir_all(&dir)?;
        std::fs::write(dir.join("key.txt"), key)?;
        // unique per process AND per call: two threads of one grid worker
        // may race the same key
        static NONCE: AtomicU64 = AtomicU64::new(0);
        let tmp = dir.join(format!(
            ".data.{}.{}.tmp",
            std::process::id(),
            NONCE.fetch_add(1, Ordering::Relaxed)
        ));
        tf.save(&tmp)?;
        if let Err(e) = std::fs::rename(&tmp, &data) {
            std::fs::remove_file(&tmp).ok();
            return Err(e.into());
        }
        Ok(tf)
    }

    /// Attach JSON metadata to a cached entry.
    pub fn put_meta(&self, key: &str, meta: &Json) -> Result<()> {
        let dir = self.dir_for(key);
        std::fs::create_dir_all(&dir)?;
        std::fs::write(dir.join("meta.json"), meta.to_string_pretty())?;
        Ok(())
    }

    pub fn get_meta(&self, key: &str) -> Option<Json> {
        let text = std::fs::read_to_string(self.dir_for(key).join("meta.json")).ok()?;
        Json::parse(&text).ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_stable() {
        assert_eq!(fnv64("abc"), fnv64("abc"));
        assert_ne!(fnv64("abc"), fnv64("abd"));
        // pinned value so cache layouts survive refactors
        assert_eq!(fnv64(""), 0xcbf29ce484222325);
    }

    #[test]
    fn compute_once_then_hit() {
        let root = std::env::temp_dir().join(format!("rilq_cache_{}", std::process::id()));
        let cache = RunCache::new(&root);
        let mut calls = 0;
        for _ in 0..3 {
            let tf = cache
                .get_or_compute("stage:test:v1", || {
                    calls += 1;
                    let mut tf = TensorFile::new();
                    tf.insert("x", vec![2], vec![1.0, 2.0]);
                    Ok(tf)
                })
                .unwrap();
            assert_eq!(tf.get("x").unwrap().1, vec![1.0, 2.0]);
        }
        assert_eq!(calls, 1);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn concurrent_reader_never_sees_partial_entry() {
        // writer persists a large entry while a reader polls `contains` +
        // load as fast as it can: with write-then-rename the reader either
        // sees nothing or the complete file — a torn read would fail
        // TensorFile::load (bad magic / short read) or give wrong data.
        let root = std::env::temp_dir().join(format!("rilq_cache_race_{}", std::process::id()));
        std::fs::remove_dir_all(&root).ok();
        let cache = RunCache::new(&root);
        let key = "stage:race:v1";
        let n = 1 << 20; // 4 MiB payload: large enough to expose torn writes
        let payload: Vec<f32> = (0..n).map(|i| i as f32).collect();

        std::thread::scope(|s| {
            let writer = {
                let cache = cache.clone();
                let payload = payload.clone();
                s.spawn(move || {
                    let tf = cache
                        .get_or_compute(key, || {
                            let mut tf = TensorFile::new();
                            tf.insert("x", vec![n], payload);
                            Ok(tf)
                        })
                        .unwrap();
                    assert_eq!(tf.get("x").unwrap().1.len(), n);
                })
            };
            let reader = {
                let cache = cache.clone();
                let payload = payload.clone();
                s.spawn(move || {
                    let mut seen = false;
                    for _ in 0..200_000 {
                        if cache.contains(key) {
                            // visible => must be complete and correct
                            let tf = cache
                                .get_or_compute(key, || panic!("hit expected once visible"))
                                .unwrap();
                            let (dims, data) = tf.get("x").unwrap();
                            assert_eq!(dims, &vec![n]);
                            assert_eq!(data.len(), n);
                            assert_eq!(data[n - 1], payload[n - 1]);
                            seen = true;
                            break;
                        }
                        std::hint::spin_loop();
                    }
                    seen
                })
            };
            writer.join().unwrap();
            let seen = reader.join().unwrap();
            // after the writer finished the entry must be visible even if
            // the reader's poll window closed first
            assert!(seen || cache.contains(key));
        });
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn meta_roundtrip() {
        let root = std::env::temp_dir().join(format!("rilq_cache_m_{}", std::process::id()));
        let cache = RunCache::new(&root);
        cache
            .put_meta("k", &Json::obj(vec![("ppl", Json::num(9.5))]))
            .unwrap();
        let m = cache.get_meta("k").unwrap();
        assert_eq!(m.req("ppl").unwrap().as_f64(), Some(9.5));
        std::fs::remove_dir_all(&root).ok();
    }
}
