//! Lightweight metrics registry: named counters, wall-clock timers,
//! level gauges (with high-water marks), and raw observation series
//! (for latency percentiles), rendered to JSON for EXPERIMENTS.md §Perf
//! accounting and the serve-loop summaries.
//!
//! The sink is string-keyed by convention, not schema: the engine's
//! serving series all live under `serve.*` (e.g. the cross-request
//! prefix-cache set — `serve.prefix_hits` / `serve.prefix_misses` /
//! `serve.prefix_tokens_saved` / `serve.prefix_evictions` counters and
//! the `serve.kv_blocks_pinned` gauge) and are aggregated into
//! [`crate::coordinator::serve::ServeSummary`] by name.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::report::Json;
use crate::tensor::quantile;

/// Retained samples per observation series. A long-running serve loop
/// observes one latency per request forever; beyond the cap the series
/// becomes a rolling window (percentiles reflect recent traffic, which is
/// what a latency gauge should report) while `sum`/`count` stay all-time.
const SERIES_CAP: usize = 4096;

#[derive(Default)]
struct Series {
    /// all-time sum (for the mean), not just the retained window
    sum: f64,
    /// all-time sample count
    count: u64,
    /// bounded sample window (ring once `SERIES_CAP` is reached)
    samples: Vec<f64>,
    next: usize,
}

impl Series {
    fn push(&mut self, v: f64) {
        self.sum += v;
        self.count += 1;
        if self.samples.len() < SERIES_CAP {
            self.samples.push(v);
        } else {
            self.samples[self.next] = v;
            self.next = (self.next + 1) % SERIES_CAP;
        }
    }
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, f64>,
    timers: BTreeMap<String, (f64, u64)>, // (total secs, count)
    gauges: BTreeMap<String, (f64, f64)>, // (current, peak)
    observations: BTreeMap<String, Series>,
}

/// Thread-safe metrics sink.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn add(&self, name: &str, v: f64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0.0) += v;
    }

    pub fn incr(&self, name: &str) {
        self.add(name, 1.0);
    }

    /// Time a closure under a named timer.
    pub fn time<R>(&self, name: &str, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.timer_add(name, t0.elapsed().as_secs_f64());
        r
    }

    /// Add one externally measured duration to a named timer — for call
    /// sites that need the elapsed value themselves (e.g. the engine
    /// loop derives a `serve.kernel_gflops` observation from the same
    /// measurement it books under `serve.forward`).
    pub fn timer_add(&self, name: &str, secs: f64) {
        let mut g = self.inner.lock().unwrap();
        let e = g.timers.entry(name.to_string()).or_insert((0.0, 0));
        e.0 += secs;
        e.1 += 1;
    }

    pub fn counter(&self, name: &str) -> f64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0.0)
    }

    pub fn timer_total(&self, name: &str) -> f64 {
        self.inner.lock().unwrap().timers.get(name).map(|t| t.0).unwrap_or(0.0)
    }

    /// How many times a named timer fired (e.g. forwards executed by the
    /// serve loop).
    pub fn timer_count(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().timers.get(name).map(|t| t.1).unwrap_or(0)
    }

    /// Move a level gauge by `delta` (e.g. queue depth +1 on submit, -1
    /// on dequeue). The high-water mark is tracked automatically.
    pub fn gauge_add(&self, name: &str, delta: f64) {
        let mut g = self.inner.lock().unwrap();
        let e = g.gauges.entry(name.to_string()).or_insert((0.0, 0.0));
        e.0 += delta;
        e.1 = e.1.max(e.0);
    }

    /// Set a level gauge to an absolute value (e.g. resident KV bytes).
    pub fn gauge_set(&self, name: &str, v: f64) {
        let mut g = self.inner.lock().unwrap();
        let e = g.gauges.entry(name.to_string()).or_insert((0.0, 0.0));
        e.0 = v;
        e.1 = e.1.max(v);
    }

    /// Current gauge level (0.0 when never touched).
    pub fn gauge(&self, name: &str) -> f64 {
        self.inner.lock().unwrap().gauges.get(name).map(|g| g.0).unwrap_or(0.0)
    }

    /// Gauge high-water mark (0.0 when never touched).
    pub fn gauge_peak(&self, name: &str) -> f64 {
        self.inner.lock().unwrap().gauges.get(name).map(|g| g.1).unwrap_or(0.0)
    }

    /// Record one sample of a distribution (e.g. a request latency) for
    /// later percentile queries. Memory is bounded: each series keeps at
    /// most [`SERIES_CAP`] samples (rolling window), while sum/count stay
    /// all-time.
    pub fn observe(&self, name: &str, v: f64) {
        let mut g = self.inner.lock().unwrap();
        g.observations.entry(name.to_string()).or_default().push(v);
    }

    /// All-time sample count of a series.
    pub fn observation_count(&self, name: &str) -> usize {
        self.inner.lock().unwrap().observations.get(name).map(|s| s.count as usize).unwrap_or(0)
    }

    /// All-time sum of a series (mean = sum / count).
    pub fn observation_sum(&self, name: &str) -> f64 {
        self.inner.lock().unwrap().observations.get(name).map(|s| s.sum).unwrap_or(0.0)
    }

    /// Percentile over the retained sample window (`q` in `[0, 1]`).
    /// `None` when the series has no samples — an empty series has no
    /// percentiles, and fabricating `0.0` misreports a latency summary
    /// (a singleton series reports its one sample for every `q`). The
    /// sort runs on a copy outside any hot path — the window is capped
    /// at [`SERIES_CAP`] samples.
    pub fn percentile(&self, name: &str, q: f64) -> Option<f64> {
        let mut sorted = {
            let g = self.inner.lock().unwrap();
            match g.observations.get(name) {
                Some(s) if !s.samples.is_empty() => s.samples.clone(),
                _ => return None,
            }
        };
        sorted.sort_by(f64::total_cmp);
        Some(quantile(&sorted, q))
    }

    pub fn to_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let counters = Json::Obj(
            g.counters.iter().map(|(k, &v)| (k.clone(), Json::num(v))).collect(),
        );
        let timers = Json::Obj(
            g.timers
                .iter()
                .map(|(k, &(total, n))| {
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("total_secs", Json::num(total)),
                            ("count", Json::num(n as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        let gauges = Json::Obj(
            g.gauges
                .iter()
                .map(|(k, &(cur, peak))| {
                    (
                        k.clone(),
                        Json::obj(vec![("value", Json::num(cur)), ("peak", Json::num(peak))]),
                    )
                })
                .collect(),
        );
        let observations = Json::Obj(
            g.observations
                .iter()
                .map(|(k, series)| {
                    let mut sorted = series.samples.clone();
                    sorted.sort_by(f64::total_cmp);
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("count", Json::num(series.count as f64)),
                            ("p50", Json::num(quantile(&sorted, 0.5))),
                            ("p95", Json::num(quantile(&sorted, 0.95))),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("counters", counters),
            ("timers", timers),
            ("gauges", gauges),
            ("observations", observations),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("steps");
        m.add("steps", 2.0);
        assert_eq!(m.counter("steps"), 3.0);
    }

    #[test]
    fn timers_record() {
        let m = Metrics::new();
        let out = m.time("work", || 7);
        assert_eq!(out, 7);
        assert!(m.timer_total("work") >= 0.0);
        let j = m.to_json();
        assert!(j.req("timers").unwrap().get("work").is_some());
    }

    #[test]
    fn gauges_track_level_and_peak() {
        let m = Metrics::new();
        assert_eq!(m.gauge("depth"), 0.0);
        m.gauge_add("depth", 3.0);
        m.gauge_add("depth", 2.0);
        m.gauge_add("depth", -4.0);
        assert_eq!(m.gauge("depth"), 1.0);
        assert_eq!(m.gauge_peak("depth"), 5.0);
        m.gauge_set("bytes", 100.0);
        m.gauge_set("bytes", 40.0);
        assert_eq!(m.gauge("bytes"), 40.0);
        assert_eq!(m.gauge_peak("bytes"), 100.0);
    }

    #[test]
    fn observation_window_is_bounded() {
        let m = Metrics::new();
        for i in 0..(SERIES_CAP + 100) {
            m.observe("lat", i as f64);
        }
        // count/sum are all-time, the percentile window is capped
        assert_eq!(m.observation_count("lat"), SERIES_CAP + 100);
        let n = (SERIES_CAP + 100) as f64;
        assert_eq!(m.observation_sum("lat"), n * (n - 1.0) / 2.0);
        // oldest samples were overwritten: the window min is >= 100
        assert!(m.percentile("lat", 0.0).unwrap() >= 100.0);
        assert_eq!(m.percentile("lat", 1.0), Some(n - 1.0));
    }

    #[test]
    fn observations_yield_percentiles() {
        let m = Metrics::new();
        // empty and singleton series are both well-defined
        assert_eq!(m.percentile("lat", 0.5), None);
        m.observe("lat", 7.0);
        assert_eq!(m.percentile("lat", 0.5), Some(7.0));
        assert_eq!(m.percentile("lat", 0.95), Some(7.0));
        let m = Metrics::new();
        for v in [5.0, 1.0, 3.0, 2.0, 4.0] {
            m.observe("lat", v);
        }
        assert_eq!(m.observation_count("lat"), 5);
        assert_eq!(m.observation_sum("lat"), 15.0);
        assert_eq!(m.percentile("lat", 0.5), Some(3.0));
        assert!(m.percentile("lat", 0.95).unwrap() > 4.0);
        assert_eq!(m.percentile("lat", 1.0), Some(5.0));
        let j = m.to_json();
        assert!(j.req("observations").unwrap().get("lat").is_some());
    }
}
