//! Lightweight metrics registry: named counters and wall-clock timers,
//! rendered to JSON for EXPERIMENTS.md §Perf accounting.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::report::Json;

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, f64>,
    timers: BTreeMap<String, (f64, u64)>, // (total secs, count)
}

/// Thread-safe metrics sink.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn add(&self, name: &str, v: f64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0.0) += v;
    }

    pub fn incr(&self, name: &str) {
        self.add(name, 1.0);
    }

    /// Time a closure under a named timer.
    pub fn time<R>(&self, name: &str, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        let dt = t0.elapsed().as_secs_f64();
        let mut g = self.inner.lock().unwrap();
        let e = g.timers.entry(name.to_string()).or_insert((0.0, 0));
        e.0 += dt;
        e.1 += 1;
        r
    }

    pub fn counter(&self, name: &str) -> f64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0.0)
    }

    pub fn timer_total(&self, name: &str) -> f64 {
        self.inner.lock().unwrap().timers.get(name).map(|t| t.0).unwrap_or(0.0)
    }

    /// How many times a named timer fired (e.g. forwards executed by the
    /// serve loop).
    pub fn timer_count(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().timers.get(name).map(|t| t.1).unwrap_or(0)
    }

    pub fn to_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let counters = Json::Obj(
            g.counters.iter().map(|(k, &v)| (k.clone(), Json::num(v))).collect(),
        );
        let timers = Json::Obj(
            g.timers
                .iter()
                .map(|(k, &(total, n))| {
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("total_secs", Json::num(total)),
                            ("count", Json::num(n as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![("counters", counters), ("timers", timers)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("steps");
        m.add("steps", 2.0);
        assert_eq!(m.counter("steps"), 3.0);
    }

    #[test]
    fn timers_record() {
        let m = Metrics::new();
        let out = m.time("work", || 7);
        assert_eq!(out, 7);
        assert!(m.timer_total("work") >= 0.0);
        let j = m.to_json();
        assert!(j.req("timers").unwrap().get("work").is_some());
    }
}
