//! Layer-3 coordinator: the machinery that turns artifacts + data into
//! experiments.
//!
//! * [`batcher`] — streaming calibration batcher: a producer thread
//!   tokenizes batches into a bounded channel (backpressure), the train
//!   loop consumes;
//! * [`driver`] — the calibration/pretraining loop drivers (Adam schedule,
//!   early stopping, loss history) over PJRT train-step artifacts;
//! * [`cache`] — content-keyed run cache (`runs/<key>/`) so expensive
//!   stages (pretraining, quantization, compensation) are shared across
//!   experiments;
//! * [`scheduler`] — multi-threaded experiment-grid runner (one PJRT
//!   runtime per worker, since `PjRtClient` is not `Send`);
//! * [`serve`] — the serving compatibility layer + benchmark probes
//!   over the [`crate::engine`] request-lifecycle engine (which owns
//!   the continuous-batching/decode scheduler now): deprecated
//!   `Server`/`ServeClient` shims, [`serve::ServeSummary`], and the
//!   `probe_throughput`/`probe_decode` harnesses behind `rilq
//!   serve-bench`;
//! * [`metrics`] — lightweight named counters/timers, level gauges, and
//!   latency-percentile observations for §Perf accounting.

pub mod batcher;
pub mod cache;
pub mod driver;
pub mod metrics;
pub mod scheduler;
pub mod serve;

pub use batcher::BatchStream;
pub use cache::RunCache;
pub use driver::{CalibConfig, CalibResult, Driver, PretrainConfig};
pub use metrics::Metrics;
pub use scheduler::run_grid;
pub use serve::{
    probe_decode, probe_throughput, DecodeProbe, Generated, Pending, ServeClient, ServeConfig,
    ServeProbe, ServeSummary, Server,
};
// The serving loop itself lives in `crate::engine` now; these stay
// importable from the coordinator for pre-engine callers.
