//! Streaming calibration batcher with backpressure.
//!
//! A producer thread samples token batches from a seeded corpus into a
//! bounded `sync_channel`; the consumer (train loop) pulls batches as PJRT
//! steps complete. The bounded channel is the backpressure mechanism: the
//! producer blocks when the queue is full, so memory stays constant no
//! matter how slow the consumer is.
//!
//! Invariants (property-tested in `rust/tests/prop_coordinator.rs`):
//! determinism given a seed, exact batch geometry, no token loss across
//! the channel, bounded queue occupancy.

use std::sync::mpsc::{sync_channel, Receiver, TryRecvError};
use std::thread::JoinHandle;

use crate::data::{Corpus, Profile, Vocab};

/// A stream of `[batch, seq]` token batches.
pub struct BatchStream {
    /// `Option` so `Drop` can close the channel before joining the
    /// producer (see below).
    rx: Option<Receiver<Vec<Vec<u32>>>>,
    handle: Option<JoinHandle<()>>,
    produced_limit: usize,
}

impl BatchStream {
    /// Spawn a producer generating `limit` batches (deterministic stream
    /// for a given `(vocab, profile, seed)`), with at most `capacity`
    /// batches buffered.
    pub fn spawn(
        vocab: Vocab,
        profile: Profile,
        seed: u64,
        batch: usize,
        seq: usize,
        limit: usize,
        capacity: usize,
    ) -> BatchStream {
        let (tx, rx) = sync_channel(capacity.max(1));
        let handle = std::thread::spawn(move || {
            let mut corpus = Corpus::new(vocab, profile, seed);
            for _ in 0..limit {
                let b = corpus.sample_batch(batch, seq);
                if tx.send(b).is_err() {
                    return; // consumer dropped — stop producing
                }
            }
        });
        BatchStream { rx: Some(rx), handle: Some(handle), produced_limit: limit }
    }

    /// Next batch; `None` when the stream is exhausted.
    pub fn next(&mut self) -> Option<Vec<Vec<u32>>> {
        self.rx.as_ref().and_then(|rx| rx.recv().ok())
    }

    /// Non-blocking poll (used by tests to observe backpressure).
    pub fn try_next(&mut self) -> Option<Vec<Vec<u32>>> {
        match self.rx.as_ref()?.try_recv() {
            Ok(b) => Some(b),
            Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => None,
        }
    }

    pub fn limit(&self) -> usize {
        self.produced_limit
    }
}

impl Drop for BatchStream {
    fn drop(&mut self) {
        // Dropping the receiver closes the channel, which unblocks a
        // producer stuck on a full queue (its send errs and it exits) —
        // no draining needed before the join.
        drop(self.rx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vocab() -> Vocab {
        Vocab::new(256, 1)
    }

    #[test]
    fn yields_exact_geometry_and_count() {
        let mut s = BatchStream::spawn(vocab(), Profile::C4Sim, 3, 4, 32, 5, 2);
        let mut n = 0;
        while let Some(b) = s.next() {
            assert_eq!(b.len(), 4);
            assert!(b.iter().all(|seq| seq.len() == 32));
            n += 1;
        }
        assert_eq!(n, 5);
    }

    #[test]
    fn deterministic_across_streams() {
        let a: Vec<_> = {
            let mut s = BatchStream::spawn(vocab(), Profile::WikiSim, 9, 2, 16, 3, 1);
            std::iter::from_fn(|| s.next()).collect()
        };
        let b: Vec<_> = {
            let mut s = BatchStream::spawn(vocab(), Profile::WikiSim, 9, 2, 16, 3, 1);
            std::iter::from_fn(|| s.next()).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn drop_mid_stream_does_not_hang() {
        let mut s = BatchStream::spawn(vocab(), Profile::C4Sim, 3, 4, 32, 1000, 2);
        let _ = s.next();
        drop(s); // must not deadlock on the blocked producer
    }
}
