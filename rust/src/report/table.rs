//! Markdown / CSV table builder used by every experiment to emit the
//! paper-table reproductions under `reports/`.

use std::fs;
use std::path::Path;

use anyhow::Result;

/// A simple column-aligned table with a title and footnotes.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn note(&mut self, s: impl Into<String>) -> &mut Self {
        self.notes.push(s.into());
        self
    }

    /// Render as github-flavored markdown.
    pub fn to_markdown(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("## {}\n\n", self.title);
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for i in 0..ncol {
                let cell = cells.get(i).map(String::as_str).unwrap_or("");
                line.push_str(&format!(" {:<w$} |", cell, w = widths[i]));
            }
            line
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("\n> {n}\n"));
        }
        out
    }

    /// Render as CSV (RFC-4180-ish quoting).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write `<dir>/<stem>.md` and `<dir>/<stem>.csv`.
    pub fn save(&self, dir: impl AsRef<Path>, stem: &str) -> Result<()> {
        let dir = dir.as_ref();
        fs::create_dir_all(dir)?;
        fs::write(dir.join(format!("{stem}.md")), self.to_markdown())?;
        fs::write(dir.join(format!("{stem}.csv")), self.to_csv())?;
        Ok(())
    }
}

/// Format helper: fixed-precision float cell.
pub fn f(x: f64, prec: usize) -> String {
    format!("{:.*}", prec, x)
}

/// Format helper: percentage cell.
pub fn pct(x: f64) -> String {
    format!("{:.2}", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("## T"));
        assert!(md.lines().filter(|l| l.starts_with('|')).count() == 3);
    }

    #[test]
    fn csv_quoting() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["x,y".into()]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
