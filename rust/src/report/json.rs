//! Minimal JSON value + recursive-descent parser + writer.
//!
//! Exists because the offline crate set lacks `serde`. Supports the full
//! JSON grammar minus exotic escapes (\u surrogate pairs are decoded on a
//! best-effort basis); good enough for `artifacts/manifest.json`, run
//! metadata, and report emission.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn str_of(&self, key: &str) -> Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| anyhow!("'{key}' not a string"))
    }

    pub fn usize_of(&self, key: &str) -> Result<usize> {
        self.req(key)?.as_usize().ok_or_else(|| anyhow!("'{key}' not a number"))
    }

    pub fn arr_of(&self, key: &str) -> Result<&[Json]> {
        self.req(key)?.as_arr().ok_or_else(|| anyhow!("'{key}' not an array"))
    }

    // -- writer --------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    pub fn to_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !items.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !map.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }

    // -- constructors ---------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", b as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => bail!("unexpected end of input"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let chunk = &self.bytes[start..start + len];
                    s.push_str(std::str::from_utf8(chunk)?);
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if matches!(b, b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(j.arr_of("a").unwrap().len(), 3);
        assert_eq!(j.arr_of("a").unwrap()[2].str_of("b").unwrap(), "c");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"x","shape":[1,2,3],"dtype":"float32","n":0.5,"ok":true}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_compact()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""A""#).unwrap();
        assert_eq!(j, Json::Str("A".into()));
    }
}
