//! Reporting substrate: a minimal JSON parser/writer (the offline crate set
//! has no `serde`), markdown/CSV table emission, and a criterion-style
//! micro-benchmark harness used by `cargo bench`.

pub mod bench;
pub mod json;
pub mod table;

pub use bench::Bench;
pub use json::Json;
pub use table::Table;
