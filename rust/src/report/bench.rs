//! Criterion-style micro-benchmark harness (criterion itself is not in the
//! offline crate set). Warmup + timed iterations + summary statistics, with
//! a stable text output format that `cargo bench` targets print.

use std::time::Instant;

use crate::tensor::Summary;

/// A named benchmark group collecting timing samples.
pub struct Bench {
    name: String,
    warmup_iters: usize,
    sample_iters: usize,
}

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub group: String,
    pub case: String,
    pub summary: Summary,
    /// optional throughput denominator (elements/bytes per iteration)
    pub throughput: Option<f64>,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Bench {
        Bench { name: name.into(), warmup_iters: 3, sample_iters: 10 }
    }

    pub fn iters(mut self, warmup: usize, samples: usize) -> Bench {
        self.warmup_iters = warmup;
        self.sample_iters = samples;
        self
    }

    /// Run `f` and record per-iteration wall time in seconds.
    pub fn run<R>(&self, case: &str, mut f: impl FnMut() -> R) -> BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.sample_iters);
        for _ in 0..self.sample_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let res = BenchResult {
            group: self.name.clone(),
            case: case.to_string(),
            summary: Summary::of(&samples),
            throughput: None,
        };
        print_result(&res);
        res
    }

    /// Like `run`, with a throughput denominator (ops per iteration);
    /// reported as ops/s based on the median.
    pub fn run_throughput<R>(
        &self,
        case: &str,
        ops_per_iter: f64,
        f: impl FnMut() -> R,
    ) -> BenchResult {
        let mut res = self.run_quiet(case, f);
        res.throughput = Some(ops_per_iter);
        print_result(&res);
        res
    }

    fn run_quiet<R>(&self, case: &str, mut f: impl FnMut() -> R) -> BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.sample_iters);
        for _ in 0..self.sample_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        BenchResult {
            group: self.name.clone(),
            case: case.to_string(),
            summary: Summary::of(&samples),
            throughput: None,
        }
    }
}

fn print_result(r: &BenchResult) {
    let s = &r.summary;
    let mut line = format!(
        "bench {:<40} p50 {:>12}  mean {:>12}  p95 {:>12}  (n={})",
        format!("{}/{}", r.group, r.case),
        fmt_time(s.p50),
        fmt_time(s.mean),
        fmt_time(s.p95),
        s.n
    );
    if let Some(ops) = r.throughput {
        if s.p50 > 0.0 {
            line.push_str(&format!("  {:>12.0} ops/s", ops / s.p50));
        }
    }
    println!("{line}");
}

/// Human-readable duration.
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.2}s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_samples() {
        let b = Bench::new("t").iters(1, 5);
        let r = b.run("noop", || 1 + 1);
        assert_eq!(r.summary.n, 5);
        assert!(r.summary.min >= 0.0);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-6).ends_with("µs"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with('s'));
    }
}
