//! Multiple-choice log-likelihood ranking (lm-eval-harness CSQA protocol)
//! and gsm-sim accuracy.

use anyhow::{bail, Result};

use crate::data::tasks::{GsmItem, McItem};
use crate::data::tokenizer::DIGIT0;

use super::scorer::Scorer;

/// Accuracy of choosing the candidate continuation with the highest total
/// log-likelihood (`acc` in lm-eval-harness; set `length_norm` for
/// `acc_norm`).
///
/// Scorers declaring KV-cache prefix reuse (`caps().prefix_reuse`, see
/// [`crate::engine::EngineCaps`]) prefill each item's shared prompt
/// **once** and score every choice's suffix incrementally — `prompt +
/// Σ choice` forwarded rows per item instead of `choices × (prompt +
/// choice)` — with bitwise-identical totals (pinned by
/// `tests/kv_cache.rs`). Fixed-geometry scorers keep the flattened
/// full-sequence path.
pub fn mc_accuracy(scorer: &dyn Scorer, items: &[McItem], length_norm: bool) -> Result<f64> {
    for (ii, item) in items.iter().enumerate() {
        for (ci, choice) in item.choices.iter().enumerate() {
            let len = item.prompt.len() + choice.len();
            if len > scorer.dims().seq {
                bail!(
                    "item {ii} choice {ci}: {len} tokens exceed the model window of {}",
                    scorer.dims().seq
                );
            }
        }
    }

    if scorer.caps().prefix_reuse {
        // shared-prompt path: one prefill per item, one suffix per choice
        let mut correct = 0usize;
        for item in items {
            let lps = scorer.score_choices(&item.prompt, &item.choices)?;
            let mut best = (f64::NEG_INFINITY, usize::MAX);
            for (ci, lp) in lps.iter().enumerate() {
                let mut total: f64 = lp.iter().map(|&x| x as f64).sum();
                if length_norm {
                    total /= item.choices[ci].len() as f64;
                }
                if total > best.0 {
                    best = (total, ci);
                }
            }
            if best.1 == item.correct {
                correct += 1;
            }
        }
        return Ok(correct as f64 / items.len() as f64);
    }

    // flatten all (item, choice) into one scoring pass
    let mut seqs: Vec<Vec<u32>> = Vec::new();
    let mut meta: Vec<(usize, usize, usize, usize)> = Vec::new(); // (item, choice, start, len)
    for (ii, item) in items.iter().enumerate() {
        for (ci, choice) in item.choices.iter().enumerate() {
            let mut seq = item.prompt.clone();
            let start = seq.len();
            seq.extend(choice);
            meta.push((ii, ci, start, choice.len()));
            seqs.push(seq);
        }
    }
    let scored = scorer.score_all(&seqs)?;

    let mut best: Vec<(f64, usize)> = vec![(f64::NEG_INFINITY, usize::MAX); items.len()];
    for (k, &(ii, ci, start, len)) in meta.iter().enumerate() {
        // token at position p is predicted by logp[p-1]
        let lp = &scored[k];
        let mut total = 0.0f64;
        for p in start..start + len {
            total += lp[p - 1] as f64;
        }
        if length_norm {
            total /= len as f64;
        }
        if total > best[ii].0 {
            best[ii] = (total, ci);
        }
    }
    let correct = items
        .iter()
        .enumerate()
        .filter(|(ii, item)| best[*ii].1 == item.correct)
        .count();
    Ok(correct as f64 / items.len() as f64)
}

/// Per-task accuracy map for a suite of task sets; returns (labels, accs).
pub fn suite_accuracy(
    scorer: &dyn Scorer,
    suite: &[(&'static str, Vec<McItem>)],
) -> Result<Vec<(&'static str, f64)>> {
    let mut out = Vec::new();
    for (label, items) in suite {
        out.push((*label, mc_accuracy(scorer, items, false)?));
    }
    Ok(out)
}

/// gsm-sim accuracy: the model "generates" its answer by ranking the ten
/// digit tokens as continuations of the `… =` prompt (greedy single-token
/// decode is exactly argmax over these ten scores).
pub fn gsm_accuracy(scorer: &dyn Scorer, items: &[GsmItem]) -> Result<f64> {
    let as_mc: Vec<McItem> = items
        .iter()
        .map(|it| McItem {
            prompt: it.prompt.clone(),
            choices: (0..10u32).map(|d| vec![DIGIT0 + d]).collect(),
            correct: (it.answer - DIGIT0) as usize,
        })
        .collect();
    mc_accuracy(scorer, &as_mc, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::{gen_gsm, gen_mc, TaskKind};
    use crate::data::tokenizer::Vocab;
    use crate::eval::scorer::NativeScorer;
    use crate::model::{ModelDims, TeacherParams};
    use crate::tensor::Rng;

    fn dims() -> ModelDims {
        ModelDims {
            name: "unit".into(),
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            vocab: 256,
            seq: 32,
            batch: 4,
            group_size: 8,
        }
    }

    #[test]
    fn random_model_mc_accuracy_near_chance() {
        let d = dims();
        let mut rng = Rng::seed(171);
        let teacher = TeacherParams::init(&d, &mut rng);
        let sc = NativeScorer { dims: d.clone(), teacher, dense: None };
        let v = Vocab::new(256, 1);
        let items = gen_mc(TaskKind::WgSim, &v, 60, 5);
        let acc = mc_accuracy(&sc, &items, false).unwrap();
        // binary task, untrained model: near 0.5
        assert!(acc > 0.2 && acc < 0.8, "acc={acc}");
    }

    #[test]
    fn gsm_accuracy_on_random_model_near_chance() {
        let d = dims();
        let mut rng = Rng::seed(172);
        let teacher = TeacherParams::init(&d, &mut rng);
        let sc = NativeScorer { dims: d.clone(), teacher, dense: None };
        let v = Vocab::new(256, 1);
        let items = gen_gsm(&v, 40, 1, 5);
        let acc = gsm_accuracy(&sc, &items).unwrap();
        assert!(acc < 0.5, "acc={acc}");
    }

    #[test]
    fn oracle_scorer_gets_perfect_accuracy() {
        // a scorer that loves the correct continuation must score 1.0
        struct Oracle {
            d: ModelDims,
            items: Vec<McItem>,
        }
        impl Scorer for Oracle {
            fn dims(&self) -> &ModelDims {
                &self.d
            }
            fn score_batch(&self, batch: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
                // +1 logp wherever the sequence matches prompt+correct of
                // some item; this abuses knowledge of the flattening order
                Ok(batch
                    .iter()
                    .map(|seq| {
                        let good = self.items.iter().any(|it| {
                            let mut want = it.prompt.clone();
                            want.extend(&it.choices[it.correct]);
                            seq[..want.len().min(seq.len())] == want[..want.len().min(seq.len())]
                                && want.len() <= seq.len()
                        });
                        vec![if good { -0.1 } else { -5.0 }; self.d.seq - 1]
                    })
                    .collect())
            }
        }
        let v = Vocab::new(256, 1);
        let items = gen_mc(TaskKind::ArcESim, &v, 20, 9);
        let o = Oracle { d: dims(), items: items.clone() };
        let acc = mc_accuracy(&o, &items, false).unwrap();
        assert_eq!(acc, 1.0);
    }
}
