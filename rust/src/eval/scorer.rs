//! Scorer implementations.

use anyhow::Result;

use crate::data::tokenizer::PAD;
use crate::lqec::AdapterSet;
use crate::model::backend::{model_weight_bytes, student_backends, BackendKind, LinearBackend};
use crate::model::forward::{forward_trace, token_logp};
use crate::model::{ModelDims, StudentWeights, TeacherParams};
use crate::runtime::bindings::{output_f32, Bindings, DeviceBindings};
use crate::runtime::{ArtifactSpec, Runtime};
use crate::tensor::Mat;

/// Batch scorer: log-prob of each realized next token.
pub trait Scorer {
    fn dims(&self) -> &ModelDims;

    /// `batch.len() == dims().batch`, every sequence exactly `dims().seq`
    /// tokens. Returns one `[seq-1]` logp vector per sequence.
    fn score_batch(&self, batch: &[Vec<u32>]) -> Result<Vec<Vec<f32>>>;

    /// Score arbitrarily many sequences of arbitrary length (pads each to
    /// `seq` with PAD and pads the final batch with dummy sequences).
    fn score_all(&self, seqs: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        let d = self.dims().clone();
        let mut out = Vec::with_capacity(seqs.len());
        let mut i = 0;
        while i < seqs.len() {
            let n = (seqs.len() - i).min(d.batch);
            let mut batch: Vec<Vec<u32>> = Vec::with_capacity(d.batch);
            for seq in &seqs[i..i + n] {
                assert!(seq.len() <= d.seq, "sequence longer than model window");
                let mut s = seq.clone();
                s.resize(d.seq, PAD);
                batch.push(s);
            }
            while batch.len() < d.batch {
                batch.push(vec![PAD; d.seq]);
            }
            let scored = self.score_batch(&batch)?;
            for (k, seq) in seqs[i..i + n].iter().enumerate() {
                // only the realized (unpadded) positions are meaningful
                let keep = seq.len().saturating_sub(1);
                out.push(scored[k][..keep].to_vec());
            }
            i += n;
        }
        Ok(out)
    }
}

/// Production scorer: a forward artifact on the PJRT runtime. The
/// per-call bindings (weights, adapters) are captured once; only the token
/// batch changes between calls.
pub struct HloScorer<'r> {
    rt: &'r Runtime,
    artifact: String,
    spec: ArtifactSpec,
    dims: ModelDims,
    /// static inputs (weights, adapters) cached as device buffers —
    /// only the token batch is uploaded per call (see §Perf)
    dev: DeviceBindings,
}

impl<'r> HloScorer<'r> {
    /// `bind` must populate everything except `tokens`.
    pub fn new(
        rt: &'r Runtime,
        artifact: &str,
        mut bind: impl FnMut(&mut Bindings),
    ) -> Result<HloScorer<'r>> {
        let spec = rt.manifest.artifact(artifact)?.clone();
        let dims = rt.manifest.dims(&spec.config)?.clone();
        let mut base = Bindings::new();
        bind(&mut base);
        // eagerly compile + upload statics to device
        rt.load(artifact)?;
        let dev = base.to_device(rt, &spec, &["tokens"])?;
        Ok(HloScorer { rt, artifact: artifact.to_string(), spec, dims, dev })
    }
}

impl Scorer for HloScorer<'_> {
    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    fn score_batch(&self, batch: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        // tokens are the only per-call upload; every weight tensor is
        // already resident as a device buffer
        let mut dynb = Bindings::new();
        let mut buf = Vec::with_capacity(self.dims.batch * self.dims.seq);
        for seq in batch {
            buf.extend(seq.iter().map(|&t| t as i32));
        }
        dynb.set_i32("tokens", buf);
        let asm = self.dev.assemble(self.rt, &self.spec, &dynb)?;
        let outs = self.rt.run_b(&self.artifact, &asm.refs())?;
        let logp = output_f32(&self.spec, &outs, "logp")?;
        let per = self.dims.seq - 1;
        Ok((0..self.dims.batch)
            .map(|i| logp[i * per..(i + 1) * per].to_vec())
            .collect())
    }
}

/// Reference scorer over the pure-Rust forward (teacher or merged student).
pub struct NativeScorer {
    pub dims: ModelDims,
    pub teacher: TeacherParams,
    /// dense per-(family, layer) replacement weights (None = teacher fp)
    pub dense: Option<Vec<Vec<Mat>>>,
}

impl Scorer for NativeScorer {
    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    fn score_batch(&self, batch: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(batch.len());
        for seq in batch {
            let trace = match &self.dense {
                Some(d) => forward_trace(&self.dims, &self.teacher.view_with(d), seq),
                None => forward_trace(&self.dims, &self.teacher.view(), seq),
            };
            out.push(token_logp(&trace.logits, seq));
        }
        Ok(out)
    }
}

/// Scorer over the native [`LinearBackend`] execution engine: the seven
/// quantized linear families run through the selected form (dense /
/// packed / merged) while embed, norms, and the LM head stay fp (the
/// paper quantizes only the linears). This is the PJRT-free serving
/// path — the packed form never materializes dense f32 weights, and the
/// retained teacher slice holds only embed/norms/head (the dense fp32
/// linears are dropped from the clone, so they don't silently re-enter
/// resident memory alongside the packed codes).
pub struct BackendScorer {
    pub dims: ModelDims,
    pub kind: BackendKind,
    /// embed/norms/head only — linears are empty (see
    /// [`TeacherParams::without_linears`])
    teacher: TeacherParams,
    linears: Vec<Vec<Box<dyn LinearBackend>>>,
}

impl BackendScorer {
    /// Build the execution engine for a (student, adapters) pair.
    /// Fails for `BackendKind::Packed` when the quantizer produced no
    /// scalar codes (rotation/VQ methods).
    pub fn new(
        dims: &ModelDims,
        teacher: &TeacherParams,
        student: &StudentWeights,
        adapters: Option<&AdapterSet>,
        kind: BackendKind,
    ) -> Result<BackendScorer> {
        Ok(BackendScorer {
            dims: dims.clone(),
            kind,
            teacher: teacher.without_linears(),
            linears: student_backends(student, adapters, kind)?,
        })
    }

    /// Resident weight memory of the quantized linears (bytes).
    pub fn weight_bytes(&self) -> usize {
        model_weight_bytes(&self.linears)
    }
}

impl Scorer for BackendScorer {
    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    fn score_batch(&self, batch: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        let view = self.teacher.view_backends(&self.linears);
        let mut out = Vec::with_capacity(batch.len());
        for seq in batch {
            let trace = forward_trace(&self.dims, &view, seq);
            out.push(token_logp(&trace.logits, seq));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn dims() -> ModelDims {
        ModelDims {
            name: "unit".into(),
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            vocab: 64,
            seq: 16,
            batch: 2,
            group_size: 8,
        }
    }

    #[test]
    fn native_scorer_scores_and_pads() {
        let d = dims();
        let mut rng = Rng::seed(151);
        let teacher = TeacherParams::init(&d, &mut rng);
        let sc = NativeScorer { dims: d.clone(), teacher, dense: None };
        // 3 seqs of odd lengths -> 2 batches with padding
        let seqs: Vec<Vec<u32>> = vec![
            (0..10).map(|_| rng.below(64) as u32).collect(),
            (0..16).map(|_| rng.below(64) as u32).collect(),
            (0..5).map(|_| rng.below(64) as u32).collect(),
        ];
        let scored = sc.score_all(&seqs).unwrap();
        assert_eq!(scored.len(), 3);
        assert_eq!(scored[0].len(), 9);
        assert_eq!(scored[1].len(), 15);
        assert_eq!(scored[2].len(), 4);
        assert!(scored.iter().flatten().all(|&x| x < 0.0));
    }

    #[test]
    fn padding_does_not_change_prefix_scores() {
        let d = dims();
        let mut rng = Rng::seed(152);
        let teacher = TeacherParams::init(&d, &mut rng);
        let sc = NativeScorer { dims: d.clone(), teacher, dense: None };
        let short: Vec<u32> = (0..8).map(|_| rng.below(64) as u32).collect();
        let a = sc.score_all(std::slice::from_ref(&short)).unwrap();
        // same prefix inside a longer (manually padded) sequence
        let mut long = short.clone();
        long.resize(16, PAD);
        let b = sc.score_all(&[long]).unwrap();
        for (x, y) in a[0].iter().zip(&b[0][..7]) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }
}
