//! Scorer implementations.

use anyhow::{bail, Result};

use crate::data::tokenizer::PAD;
use crate::lqec::AdapterSet;
use crate::model::backend::{model_weight_bytes, student_backends, BackendKind, LinearBackend};
use crate::model::forward::{forward_trace_batch, token_logp};
use crate::model::{ModelDims, StudentWeights, TeacherParams};
use crate::runtime::bindings::{output_f32, Bindings, DeviceBindings};
use crate::runtime::{ArtifactSpec, Runtime};
use crate::tensor::Mat;

/// `Err` (not panic) on malformed input — a sequence exceeding the model
/// window, or a token id outside the vocabulary (either would otherwise
/// panic deep inside the forward via an out-of-range embedding row). A
/// serving path must never abort the process on bad input.
pub fn check_input(dims: &ModelDims, seqs: &[Vec<u32>]) -> Result<()> {
    for (i, s) in seqs.iter().enumerate() {
        if s.len() > dims.seq {
            bail!(
                "sequence {i} has {} tokens, exceeding the model window of {}",
                s.len(),
                dims.seq
            );
        }
        if let Some(&t) = s.iter().find(|&&t| t as usize >= dims.vocab) {
            bail!(
                "sequence {i} contains token id {t}, outside the vocabulary of {}",
                dims.vocab
            );
        }
    }
    Ok(())
}

/// Batch scorer: log-prob of each realized next token.
pub trait Scorer {
    fn dims(&self) -> &ModelDims;

    /// True when the implementation only accepts the exact lowered
    /// geometry — `batch.len() == dims().batch`, every sequence exactly
    /// `dims().seq` tokens (the HLO artifact path). Native scorers return
    /// false and accept ragged batches of any size directly.
    fn fixed_geometry(&self) -> bool {
        false
    }

    /// Score one batch. Fixed-geometry scorers ([`Self::fixed_geometry`])
    /// require exactly `[dims().batch, dims().seq]` tokens and return one
    /// `[seq-1]` logp vector per sequence; ragged scorers accept any
    /// number of sequences of any length `<= dims().seq` (longer is an
    /// `Err`) and return one `[len_i-1]` vector per sequence.
    fn score_batch(&self, batch: &[Vec<u32>]) -> Result<Vec<Vec<f32>>>;

    /// Score arbitrarily many sequences of arbitrary length, in chunks of
    /// `dims().batch`. Sequences longer than the model window are an
    /// `Err`. Only fixed-geometry scorers see PAD: ragged scorers are
    /// handed the real sequences, so no cycles are burned forwarding
    /// PAD-only dummy rows.
    fn score_all(&self, seqs: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        let d = self.dims().clone();
        check_input(&d, seqs)?;
        let mut out = Vec::with_capacity(seqs.len());
        let mut i = 0;
        while i < seqs.len() {
            let n = (seqs.len() - i).min(d.batch);
            let scored = if self.fixed_geometry() {
                // pad each sequence to `seq`, and the final short batch
                // with PAD-only dummies, to match the lowered geometry
                let mut batch: Vec<Vec<u32>> = Vec::with_capacity(d.batch);
                for seq in &seqs[i..i + n] {
                    let mut s = seq.clone();
                    s.resize(d.seq, PAD);
                    batch.push(s);
                }
                while batch.len() < d.batch {
                    batch.push(vec![PAD; d.seq]);
                }
                self.score_batch(&batch)?
            } else {
                self.score_batch(&seqs[i..i + n])?
            };
            for (k, seq) in seqs[i..i + n].iter().enumerate() {
                // only the realized (unpadded) positions are meaningful
                let keep = seq.len().saturating_sub(1);
                out.push(scored[k][..keep].to_vec());
            }
            i += n;
        }
        Ok(out)
    }
}

/// Production scorer: a forward artifact on the PJRT runtime. The
/// per-call bindings (weights, adapters) are captured once; only the token
/// batch changes between calls.
pub struct HloScorer<'r> {
    rt: &'r Runtime,
    artifact: String,
    spec: ArtifactSpec,
    dims: ModelDims,
    /// static inputs (weights, adapters) cached as device buffers —
    /// only the token batch is uploaded per call (see §Perf)
    dev: DeviceBindings,
}

impl<'r> HloScorer<'r> {
    /// `bind` must populate everything except `tokens`.
    pub fn new(
        rt: &'r Runtime,
        artifact: &str,
        mut bind: impl FnMut(&mut Bindings),
    ) -> Result<HloScorer<'r>> {
        let spec = rt.manifest.artifact(artifact)?.clone();
        let dims = rt.manifest.dims(&spec.config)?.clone();
        let mut base = Bindings::new();
        bind(&mut base);
        // eagerly compile + upload statics to device
        rt.load(artifact)?;
        let dev = base.to_device(rt, &spec, &["tokens"])?;
        Ok(HloScorer { rt, artifact: artifact.to_string(), spec, dims, dev })
    }
}

impl Scorer for HloScorer<'_> {
    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    /// The artifact is lowered for one exact `[batch, seq]` — `score_all`
    /// must pad for it.
    fn fixed_geometry(&self) -> bool {
        true
    }

    fn score_batch(&self, batch: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        // the artifact reads a fixed [batch, seq] token buffer; a ragged
        // or short batch here would silently upload misaligned tokens
        // (per-sequence check — compensating ragged lengths must not pass)
        if batch.len() != self.dims.batch || batch.iter().any(|s| s.len() != self.dims.seq) {
            bail!(
                "HloScorer needs exactly [{}, {}] token geometry, got {:?} \
                 (use score_all, which pads for fixed-geometry scorers)",
                self.dims.batch,
                self.dims.seq,
                batch.iter().map(Vec::len).collect::<Vec<_>>()
            );
        }
        // tokens are the only per-call upload; every weight tensor is
        // already resident as a device buffer
        let mut dynb = Bindings::new();
        let mut buf = Vec::with_capacity(self.dims.batch * self.dims.seq);
        for seq in batch {
            buf.extend(seq.iter().map(|&t| t as i32));
        }
        dynb.set_i32("tokens", buf);
        let asm = self.dev.assemble(self.rt, &self.spec, &dynb)?;
        let outs = self.rt.run_b(&self.artifact, &asm.refs())?;
        let logp = output_f32(&self.spec, &outs, "logp")?;
        let per = self.dims.seq - 1;
        Ok((0..self.dims.batch)
            .map(|i| logp[i * per..(i + 1) * per].to_vec())
            .collect())
    }
}

/// Reference scorer over the pure-Rust forward (teacher or merged student).
pub struct NativeScorer {
    pub dims: ModelDims,
    pub teacher: TeacherParams,
    /// dense per-(family, layer) replacement weights (None = teacher fp)
    pub dense: Option<Vec<Vec<Mat>>>,
}

impl Scorer for NativeScorer {
    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    fn score_batch(&self, batch: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        check_input(&self.dims, batch)?;
        let logits = match &self.dense {
            Some(d) => forward_trace_batch(&self.dims, &self.teacher.view_with(d), batch),
            None => forward_trace_batch(&self.dims, &self.teacher.view(), batch),
        };
        Ok(batch.iter().zip(&logits).map(|(seq, lg)| token_logp(lg, seq)).collect())
    }
}

/// Scorer over the native [`LinearBackend`] execution engine: the seven
/// quantized linear families run through the selected form (dense /
/// packed / merged) while embed, norms, and the LM head stay fp (the
/// paper quantizes only the linears). This is the PJRT-free serving
/// path — the packed form never materializes dense f32 weights, and the
/// retained teacher slice holds only embed/norms/head (the dense fp32
/// linears are dropped from the clone, so they don't silently re-enter
/// resident memory alongside the packed codes).
pub struct BackendScorer {
    pub dims: ModelDims,
    pub kind: BackendKind,
    /// embed/norms/head only — linears are empty (see
    /// [`TeacherParams::without_linears`])
    teacher: TeacherParams,
    linears: Vec<Vec<Box<dyn LinearBackend>>>,
}

impl BackendScorer {
    /// Build the execution engine for a (student, adapters) pair.
    /// Fails for `BackendKind::Packed` when the quantizer produced no
    /// scalar codes (rotation/VQ methods).
    pub fn new(
        dims: &ModelDims,
        teacher: &TeacherParams,
        student: &StudentWeights,
        adapters: Option<&AdapterSet>,
        kind: BackendKind,
    ) -> Result<BackendScorer> {
        Ok(BackendScorer {
            dims: dims.clone(),
            kind,
            teacher: teacher.without_linears(),
            linears: student_backends(student, adapters, kind)?,
        })
    }

    /// Resident weight memory of the quantized linears (bytes).
    pub fn weight_bytes(&self) -> usize {
        model_weight_bytes(&self.linears)
    }

    /// Score each sequence with its own full forward — the pre-batching
    /// serving path, kept as the baseline the `serve-bench` speedup is
    /// measured against.
    pub fn score_sequential(&self, batch: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        check_input(&self.dims, batch)?;
        let view = self.teacher.view_backends(&self.linears);
        let mut out = Vec::with_capacity(batch.len());
        for seq in batch {
            let trace = crate::model::forward::forward_trace(&self.dims, &view, seq);
            out.push(token_logp(&trace.logits, seq));
        }
        Ok(out)
    }
}

impl Scorer for BackendScorer {
    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    /// One coalesced forward for the whole (ragged) batch: every
    /// [`LinearBackend::forward`] sees a `[Σ len_i, d_model]` activation
    /// matrix, amortizing pool dispatch and the packed group-tile dequant
    /// across all sequences.
    fn score_batch(&self, batch: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        check_input(&self.dims, batch)?;
        let view = self.teacher.view_backends(&self.linears);
        let logits = forward_trace_batch(&self.dims, &view, batch);
        Ok(batch.iter().zip(&logits).map(|(seq, lg)| token_logp(lg, seq)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn dims() -> ModelDims {
        ModelDims {
            name: "unit".into(),
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            vocab: 64,
            seq: 16,
            batch: 2,
            group_size: 8,
        }
    }

    #[test]
    fn overlong_sequence_is_err_not_panic() {
        // a serving path must not abort the process on bad input
        let d = dims();
        let mut rng = Rng::seed(153);
        let teacher = TeacherParams::init(&d, &mut rng);
        let sc = NativeScorer { dims: d.clone(), teacher, dense: None };
        let ok: Vec<u32> = (0..8).map(|_| rng.below(64) as u32).collect();
        let too_long: Vec<u32> = (0..d.seq + 1).map(|_| rng.below(64) as u32).collect();
        let err = sc.score_all(&[ok, too_long]).unwrap_err();
        assert!(format!("{err}").contains("window"), "{err}");
    }

    #[test]
    fn native_scorer_scores_ragged_lengths() {
        let d = dims();
        let mut rng = Rng::seed(151);
        let teacher = TeacherParams::init(&d, &mut rng);
        let sc = NativeScorer { dims: d.clone(), teacher, dense: None };
        // 3 seqs of odd lengths -> 2 ragged chunks, scored without padding
        let seqs: Vec<Vec<u32>> = vec![
            (0..10).map(|_| rng.below(64) as u32).collect(),
            (0..16).map(|_| rng.below(64) as u32).collect(),
            (0..5).map(|_| rng.below(64) as u32).collect(),
        ];
        let scored = sc.score_all(&seqs).unwrap();
        assert_eq!(scored.len(), 3);
        assert_eq!(scored[0].len(), 9);
        assert_eq!(scored[1].len(), 15);
        assert_eq!(scored[2].len(), 4);
        assert!(scored.iter().flatten().all(|&x| x < 0.0));
    }

    #[test]
    fn padding_does_not_change_prefix_scores() {
        let d = dims();
        let mut rng = Rng::seed(152);
        let teacher = TeacherParams::init(&d, &mut rng);
        let sc = NativeScorer { dims: d.clone(), teacher, dense: None };
        let short: Vec<u32> = (0..8).map(|_| rng.below(64) as u32).collect();
        let a = sc.score_all(std::slice::from_ref(&short)).unwrap();
        // same prefix inside a longer (manually padded) sequence
        let mut long = short.clone();
        long.resize(16, PAD);
        let b = sc.score_all(&[long]).unwrap();
        for (x, y) in a[0].iter().zip(&b[0][..7]) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }
}
