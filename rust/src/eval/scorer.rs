//! Scorer implementations.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::data::tokenizer::PAD;
use crate::engine::EngineCaps;
use crate::lqec::AdapterSet;
use crate::model::backend::{model_weight_bytes, student_backends, BackendKind, LinearBackend};
use crate::model::forward::{
    forward_batch_with_cache, forward_trace_batch, forward_trace_with_cache, row_logp, token_logp,
    WeightView,
};
use crate::model::kv::KvCache;
use crate::model::{ModelDims, StudentWeights, TeacherParams};
use crate::runtime::bindings::{output_f32, Bindings, DeviceBindings};
use crate::runtime::{ArtifactSpec, Runtime};
use crate::tensor::Mat;

/// `Err` (not panic) on malformed input — a sequence exceeding the model
/// window, or a token id outside the vocabulary (either would otherwise
/// panic deep inside the forward via an out-of-range embedding row). A
/// serving path must never abort the process on bad input.
pub fn check_input(dims: &ModelDims, seqs: &[Vec<u32>]) -> Result<()> {
    for (i, s) in seqs.iter().enumerate() {
        check_seq(dims, i, s)?;
    }
    Ok(())
}

/// Single-sequence form of [`check_input`] — lets per-sequence callers
/// (incremental decode, the recompute baseline) validate a borrowed slice
/// without cloning it into a one-element batch.
pub fn check_seq(dims: &ModelDims, i: usize, s: &[u32]) -> Result<()> {
    if s.len() > dims.seq {
        bail!("sequence {i} has {} tokens, exceeding the model window of {}", s.len(), dims.seq);
    }
    if let Some(&t) = s.iter().find(|&&t| t as usize >= dims.vocab) {
        bail!("sequence {i} contains token id {t}, outside the vocabulary of {}", dims.vocab);
    }
    Ok(())
}

/// Batch scorer: log-prob of each realized next token.
pub trait Scorer {
    fn dims(&self) -> &ModelDims;

    /// What this implementation can execute, declared **once** as an
    /// [`EngineCaps`] descriptor — the engine's admission scheduler and
    /// the eval harness consult it instead of probing per-capability
    /// booleans (the pre-engine `fixed_geometry` / `supports_cache` /
    /// `supports_prefix_reuse` sprawl). The default is a ragged batch
    /// scorer with no cache support; the HLO path declares
    /// [`EngineCaps::fixed`], the native backends
    /// [`EngineCaps::incremental`].
    fn caps(&self) -> EngineCaps {
        EngineCaps::ragged()
    }

    /// Score one batch. Fixed-geometry scorers (`caps().fixed_geometry`)
    /// require exactly `[dims().batch, dims().seq]` tokens and return one
    /// `[seq-1]` logp vector per sequence; ragged scorers accept any
    /// number of sequences of any length `<= dims().seq` (longer is an
    /// `Err`) and return one `[len_i-1]` vector per sequence.
    fn score_batch(&self, batch: &[Vec<u32>]) -> Result<Vec<Vec<f32>>>;

    /// Incremental forward against a per-sequence [`KvCache`]: push only
    /// `new_tokens`, return their `[new, V]` logits, extend the cache.
    /// Default errs — only native backend scorers own a cached forward.
    fn cache_forward(&self, _new_tokens: &[u32], _cache: &mut KvCache) -> Result<Mat> {
        bail!("this scorer has no KV-cache support (fixed-geometry HLO path)")
    }

    /// Batched incremental forward over independent sequences. The
    /// default loops [`Scorer::cache_forward`]; native scorers override
    /// it with one coalesced `[Σ new_i, d_model]` forward so the packed
    /// group-tile dequant amortizes across the decode batch.
    fn cache_forward_batch(
        &self,
        news: &[Vec<u32>],
        caches: &mut [&mut KvCache],
    ) -> Result<Vec<Mat>> {
        ensure!(
            news.len() == caches.len(),
            "cache_forward_batch: {} token lists but {} caches",
            news.len(),
            caches.len()
        );
        news.iter().zip(caches.iter_mut()).map(|(n, c)| self.cache_forward(n, c)).collect()
    }

    /// Score several candidate continuations of one shared prompt:
    /// returns, per choice, the `[choice_len]` log-probs of the choice
    /// tokens given everything before them. The default recomputes
    /// `prompt + choice` from scratch per choice via [`Scorer::score_all`];
    /// prefix-reuse scorers (`caps().prefix_reuse`) prefill the prompt
    /// once instead.
    fn score_choices(&self, prompt: &[u32], choices: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        ensure!(
            !prompt.is_empty(),
            "score_choices needs a non-empty prompt (the first choice token \
             has no conditioning position otherwise)"
        );
        let seqs: Vec<Vec<u32>> = choices
            .iter()
            .map(|c| {
                let mut s = prompt.to_vec();
                s.extend(c);
                s
            })
            .collect();
        let scored = self.score_all(&seqs)?;
        Ok(scored
            .iter()
            .zip(choices)
            .map(|(lp, c)| lp[prompt.len() - 1..prompt.len() - 1 + c.len()].to_vec())
            .collect())
    }

    /// Score arbitrarily many sequences of arbitrary length, in chunks of
    /// `dims().batch`. Sequences longer than the model window are an
    /// `Err`. Only fixed-geometry scorers see PAD: ragged scorers are
    /// handed the real sequences, so no cycles are burned forwarding
    /// PAD-only dummy rows.
    fn score_all(&self, seqs: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        let d = self.dims().clone();
        check_input(&d, seqs)?;
        let mut out = Vec::with_capacity(seqs.len());
        let mut i = 0;
        while i < seqs.len() {
            let n = (seqs.len() - i).min(d.batch);
            let scored = if self.caps().fixed_geometry {
                // pad each sequence to `seq`, and the final short batch
                // with PAD-only dummies, to match the lowered geometry
                let mut batch: Vec<Vec<u32>> = Vec::with_capacity(d.batch);
                for seq in &seqs[i..i + n] {
                    let mut s = seq.clone();
                    s.resize(d.seq, PAD);
                    batch.push(s);
                }
                while batch.len() < d.batch {
                    batch.push(vec![PAD; d.seq]);
                }
                self.score_batch(&batch)?
            } else {
                self.score_batch(&seqs[i..i + n])?
            };
            for (k, seq) in seqs[i..i + n].iter().enumerate() {
                // only the realized (unpadded) positions are meaningful
                let keep = seq.len().saturating_sub(1);
                out.push(scored[k][..keep].to_vec());
            }
            i += n;
        }
        Ok(out)
    }
}

/// A shared scorer handle scores like the scorer it wraps. This lets one
/// set of weights serve several consumers at once — e.g. an
/// `Arc<BackendScorer>` driving the engine through a fault-injecting
/// [`crate::engine::ChaosScorer`] while a second clone of the same `Arc`
/// produces the fault-free baseline the chaos suite compares against
/// bitwise. Every method forwards (defaults included), so a wrapped
/// scorer's overrides are never shadowed by the trait defaults.
impl<S: Scorer + ?Sized> Scorer for Arc<S> {
    fn dims(&self) -> &ModelDims {
        (**self).dims()
    }

    fn caps(&self) -> EngineCaps {
        (**self).caps()
    }

    fn score_batch(&self, batch: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        (**self).score_batch(batch)
    }

    fn cache_forward(&self, new_tokens: &[u32], cache: &mut KvCache) -> Result<Mat> {
        (**self).cache_forward(new_tokens, cache)
    }

    fn cache_forward_batch(
        &self,
        news: &[Vec<u32>],
        caches: &mut [&mut KvCache],
    ) -> Result<Vec<Mat>> {
        (**self).cache_forward_batch(news, caches)
    }

    fn score_choices(&self, prompt: &[u32], choices: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        (**self).score_choices(prompt, choices)
    }

    fn score_all(&self, seqs: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        (**self).score_all(seqs)
    }
}

/// Prefix-reuse choice scoring over a weight view: prefill the shared
/// prompt once, then score each choice's suffix incrementally against the
/// cached prefix, truncating back to the prompt between choices. Rows
/// pushed through the linears: `prompt + Σ choice_len` instead of the
/// naive `Σ (prompt + choice_len)` — the saving `mc_accuracy` banks on
/// (CSQA scores 4–5 continuations of one shared prompt per item).
///
/// Truncation restores exact cache state, so results are bitwise-stable
/// across choice order and bitwise-identical to full-sequence scoring.
fn score_choices_cached(
    dims: &ModelDims,
    view: &WeightView<'_>,
    prompt: &[u32],
    choices: &[Vec<u32>],
) -> Result<Vec<Vec<f32>>> {
    ensure!(
        !prompt.is_empty(),
        "score_choices needs a non-empty prompt (the first choice token \
         has no conditioning position otherwise)"
    );
    for (ci, c) in choices.iter().enumerate() {
        if prompt.len() + c.len() > dims.seq {
            bail!(
                "choice {ci}: {} prompt + {} choice tokens exceed the model window of {}",
                prompt.len(),
                c.len(),
                dims.seq
            );
        }
    }
    let mut cache = KvCache::new(dims);
    let prefill = forward_trace_with_cache(dims, view, prompt, &mut cache)?;
    let base = prefill.row(prompt.len() - 1);
    let mut out = Vec::with_capacity(choices.len());
    for c in choices {
        if c.is_empty() {
            out.push(Vec::new());
            continue;
        }
        let mut lp = Vec::with_capacity(c.len());
        lp.push(row_logp(base, c[0]));
        let lg = forward_trace_with_cache(dims, view, c, &mut cache)?;
        for t in 1..c.len() {
            lp.push(row_logp(lg.row(t - 1), c[t]));
        }
        cache.truncate(prompt.len());
        out.push(lp);
    }
    Ok(out)
}

/// Greedy incremental decode over any cache-capable scorer: prefill the
/// prompt once, then feed the argmax token back one step at a time.
/// Returns the generated tokens and each one's log-prob under the
/// distribution it was sampled from.
pub fn greedy_decode(
    scorer: &dyn Scorer,
    prompt: &[u32],
    max_new: usize,
) -> Result<(Vec<u32>, Vec<f32>)> {
    let dims = scorer.dims().clone();
    ensure!(!prompt.is_empty(), "greedy_decode needs a non-empty prompt");
    if prompt.len() + max_new.saturating_sub(1) > dims.seq {
        bail!(
            "generating {max_new} tokens from a {}-token prompt exceeds the model window of {}",
            prompt.len(),
            dims.seq
        );
    }
    let mut tokens = Vec::with_capacity(max_new);
    let mut logps = Vec::with_capacity(max_new);
    if max_new == 0 {
        return Ok((tokens, logps));
    }
    let mut cache = KvCache::new(&dims);
    let lg = scorer.cache_forward(prompt, &mut cache)?;
    let (mut tok, mut lp) = argmax_logp(lg.row(prompt.len() - 1));
    tokens.push(tok);
    logps.push(lp);
    while tokens.len() < max_new {
        let lg = scorer.cache_forward(&[tok], &mut cache)?;
        (tok, lp) = argmax_logp(lg.row(0));
        tokens.push(tok);
        logps.push(lp);
    }
    Ok((tokens, logps))
}

/// The quadratic baseline [`greedy_decode`] is measured against: rerun a
/// full forward over the whole growing sequence for every generated
/// token. Same tokens bitwise (per-row forwards are batch-invariant),
/// O(S²) linear rows instead of O(S).
pub fn greedy_decode_recompute(
    scorer: &BackendScorer,
    prompt: &[u32],
    max_new: usize,
) -> Result<(Vec<u32>, Vec<f32>)> {
    ensure!(!prompt.is_empty(), "greedy_decode needs a non-empty prompt");
    if prompt.len() + max_new.saturating_sub(1) > scorer.dims.seq {
        bail!(
            "generating {max_new} tokens from a {}-token prompt exceeds the model window of {}",
            prompt.len(),
            scorer.dims.seq
        );
    }
    let mut seq = prompt.to_vec();
    let mut tokens = Vec::with_capacity(max_new);
    let mut logps = Vec::with_capacity(max_new);
    for _ in 0..max_new {
        let lg = scorer.forward_logits(&seq)?;
        let (tok, lp) = argmax_logp(lg.row(seq.len() - 1));
        tokens.push(tok);
        logps.push(lp);
        seq.push(tok);
    }
    Ok((tokens, logps))
}

// Greedy token selection lives with the sampling code now; re-exported
// here because every decode path in this module is defined in terms of
// it (ties deterministically break toward the lowest token id).
pub use crate::engine::sampling::argmax_logp;

/// Production scorer: a forward artifact on the PJRT runtime. The
/// per-call bindings (weights, adapters) are captured once; only the token
/// batch changes between calls.
pub struct HloScorer<'r> {
    rt: &'r Runtime,
    artifact: String,
    spec: ArtifactSpec,
    dims: ModelDims,
    /// static inputs (weights, adapters) cached as device buffers —
    /// only the token batch is uploaded per call (see §Perf)
    dev: DeviceBindings,
}

impl<'r> HloScorer<'r> {
    /// `bind` must populate everything except `tokens`.
    pub fn new(
        rt: &'r Runtime,
        artifact: &str,
        mut bind: impl FnMut(&mut Bindings),
    ) -> Result<HloScorer<'r>> {
        let spec = rt.manifest.artifact(artifact)?.clone();
        let dims = rt.manifest.dims(&spec.config)?.clone();
        let mut base = Bindings::new();
        bind(&mut base);
        // eagerly compile + upload statics to device
        rt.load(artifact)?;
        let dev = base.to_device(rt, &spec, &["tokens"])?;
        Ok(HloScorer { rt, artifact: artifact.to_string(), spec, dims, dev })
    }
}

impl Scorer for HloScorer<'_> {
    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    /// The artifact is lowered for one exact `[batch, seq]` — `score_all`
    /// must pad for it; no incremental execution.
    fn caps(&self) -> EngineCaps {
        EngineCaps::fixed()
    }

    fn score_batch(&self, batch: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        // the artifact reads a fixed [batch, seq] token buffer; a ragged
        // or short batch here would silently upload misaligned tokens
        // (per-sequence check — compensating ragged lengths must not pass)
        if batch.len() != self.dims.batch || batch.iter().any(|s| s.len() != self.dims.seq) {
            bail!(
                "HloScorer needs exactly [{}, {}] token geometry, got {:?} \
                 (use score_all, which pads for fixed-geometry scorers)",
                self.dims.batch,
                self.dims.seq,
                batch.iter().map(Vec::len).collect::<Vec<_>>()
            );
        }
        // tokens are the only per-call upload; every weight tensor is
        // already resident as a device buffer
        let mut dynb = Bindings::new();
        let mut buf = Vec::with_capacity(self.dims.batch * self.dims.seq);
        for seq in batch {
            buf.extend(seq.iter().map(|&t| t as i32));
        }
        dynb.set_i32("tokens", buf);
        let asm = self.dev.assemble(self.rt, &self.spec, &dynb)?;
        let outs = self.rt.run_b(&self.artifact, &asm.refs())?;
        let logp = output_f32(&self.spec, &outs, "logp")?;
        let per = self.dims.seq - 1;
        Ok((0..self.dims.batch)
            .map(|i| logp[i * per..(i + 1) * per].to_vec())
            .collect())
    }
}

/// Reference scorer over the pure-Rust forward (teacher or merged student).
pub struct NativeScorer {
    pub dims: ModelDims,
    pub teacher: TeacherParams,
    /// dense per-(family, layer) replacement weights (None = teacher fp)
    pub dense: Option<Vec<Vec<Mat>>>,
}

impl NativeScorer {
    fn view(&self) -> WeightView<'_> {
        match &self.dense {
            Some(d) => self.teacher.view_with(d),
            None => self.teacher.view(),
        }
    }
}

impl Scorer for NativeScorer {
    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    fn score_batch(&self, batch: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        check_input(&self.dims, batch)?;
        let logits = forward_trace_batch(&self.dims, &self.view(), batch);
        Ok(batch.iter().zip(&logits).map(|(seq, lg)| token_logp(lg, seq)).collect())
    }

    fn caps(&self) -> EngineCaps {
        EngineCaps::incremental()
    }

    fn cache_forward(&self, new_tokens: &[u32], cache: &mut KvCache) -> Result<Mat> {
        forward_trace_with_cache(&self.dims, &self.view(), new_tokens, cache)
    }

    fn cache_forward_batch(
        &self,
        news: &[Vec<u32>],
        caches: &mut [&mut KvCache],
    ) -> Result<Vec<Mat>> {
        forward_batch_with_cache(&self.dims, &self.view(), news, caches)
    }

    fn score_choices(&self, prompt: &[u32], choices: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        score_choices_cached(&self.dims, &self.view(), prompt, choices)
    }
}

/// Scorer over the native [`LinearBackend`] execution engine: the seven
/// quantized linear families run through the selected form (dense /
/// packed / merged) while embed, norms, and the LM head stay fp (the
/// paper quantizes only the linears). This is the PJRT-free serving
/// path — the packed form never materializes dense f32 weights, and the
/// retained teacher slice holds only embed/norms/head (the dense fp32
/// linears are dropped from the clone, so they don't silently re-enter
/// resident memory alongside the packed codes).
pub struct BackendScorer {
    pub dims: ModelDims,
    pub kind: BackendKind,
    /// embed/norms/head only — linears are empty (see
    /// [`TeacherParams::without_linears`])
    teacher: TeacherParams,
    linears: Vec<Vec<Box<dyn LinearBackend>>>,
    /// activation rows pushed through the model (every forward entry
    /// point adds the rows it actually forwarded) — the observable that
    /// proves prefix reuse does less work, same idiom as the serve
    /// loop's PAD-waste token counter.
    rows: AtomicUsize,
}

impl BackendScorer {
    /// Build the execution engine for a (student, adapters) pair.
    /// Fails for `BackendKind::Packed` when the quantizer produced no
    /// scalar codes (rotation/VQ methods).
    pub fn new(
        dims: &ModelDims,
        teacher: &TeacherParams,
        student: &StudentWeights,
        adapters: Option<&AdapterSet>,
        kind: BackendKind,
    ) -> Result<BackendScorer> {
        Ok(BackendScorer {
            dims: dims.clone(),
            kind,
            teacher: teacher.without_linears(),
            linears: student_backends(student, adapters, kind)?,
            rows: AtomicUsize::new(0),
        })
    }

    /// Resident weight memory of the quantized linears (bytes).
    pub fn weight_bytes(&self) -> usize {
        model_weight_bytes(&self.linears)
    }

    /// Total activation rows forwarded through the linears so far.
    pub fn rows_forwarded(&self) -> usize {
        self.rows.load(Ordering::Relaxed)
    }

    fn count_rows(&self, n: usize) {
        self.rows.fetch_add(n, Ordering::Relaxed);
    }

    /// Fresh KV cache sized for this scorer's model window.
    pub fn new_cache(&self) -> KvCache {
        KvCache::new(&self.dims)
    }

    /// Full-forward logits of one sequence — the recompute baseline the
    /// incremental decode path is benchmarked against.
    pub fn forward_logits(&self, tokens: &[u32]) -> Result<Mat> {
        check_seq(&self.dims, 0, tokens)?;
        self.count_rows(tokens.len());
        let view = self.teacher.view_backends(&self.linears);
        Ok(crate::model::forward::forward_trace(&self.dims, &view, tokens).logits)
    }

    /// Score each sequence with its own full forward — the pre-batching
    /// serving path, kept as the baseline the `serve-bench` speedup is
    /// measured against.
    pub fn score_sequential(&self, batch: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        check_input(&self.dims, batch)?;
        self.count_rows(batch.iter().map(Vec::len).sum());
        let view = self.teacher.view_backends(&self.linears);
        let mut out = Vec::with_capacity(batch.len());
        for seq in batch {
            let trace = crate::model::forward::forward_trace(&self.dims, &view, seq);
            out.push(token_logp(&trace.logits, seq));
        }
        Ok(out)
    }
}

impl Scorer for BackendScorer {
    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    /// One coalesced forward for the whole (ragged) batch: every
    /// [`LinearBackend::forward`] sees a `[Σ len_i, d_model]` activation
    /// matrix, amortizing pool dispatch and the packed group-tile dequant
    /// across all sequences.
    fn score_batch(&self, batch: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        check_input(&self.dims, batch)?;
        self.count_rows(batch.iter().map(Vec::len).sum());
        let view = self.teacher.view_backends(&self.linears);
        let logits = forward_trace_batch(&self.dims, &view, batch);
        Ok(batch.iter().zip(&logits).map(|(seq, lg)| token_logp(lg, seq)).collect())
    }

    fn caps(&self) -> EngineCaps {
        EngineCaps::incremental()
    }

    fn cache_forward(&self, new_tokens: &[u32], cache: &mut KvCache) -> Result<Mat> {
        let view = self.teacher.view_backends(&self.linears);
        let lg = forward_trace_with_cache(&self.dims, &view, new_tokens, cache)?;
        self.count_rows(new_tokens.len());
        Ok(lg)
    }

    fn cache_forward_batch(
        &self,
        news: &[Vec<u32>],
        caches: &mut [&mut KvCache],
    ) -> Result<Vec<Mat>> {
        let view = self.teacher.view_backends(&self.linears);
        let lgs = forward_batch_with_cache(&self.dims, &view, news, caches)?;
        self.count_rows(news.iter().map(Vec::len).sum());
        Ok(lgs)
    }

    /// Prefix reuse: prefill the shared prompt once, score each choice's
    /// suffix incrementally (see [`score_choices_cached`]).
    fn score_choices(&self, prompt: &[u32], choices: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        let view = self.teacher.view_backends(&self.linears);
        let out = score_choices_cached(&self.dims, &view, prompt, choices)?;
        self.count_rows(prompt.len() + choices.iter().map(Vec::len).sum::<usize>());
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn dims() -> ModelDims {
        ModelDims {
            name: "unit".into(),
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            vocab: 64,
            seq: 16,
            batch: 2,
            group_size: 8,
        }
    }

    #[test]
    fn overlong_sequence_is_err_not_panic() {
        // a serving path must not abort the process on bad input
        let d = dims();
        let mut rng = Rng::seed(153);
        let teacher = TeacherParams::init(&d, &mut rng);
        let sc = NativeScorer { dims: d.clone(), teacher, dense: None };
        let ok: Vec<u32> = (0..8).map(|_| rng.below(64) as u32).collect();
        let too_long: Vec<u32> = (0..d.seq + 1).map(|_| rng.below(64) as u32).collect();
        let err = sc.score_all(&[ok, too_long]).unwrap_err();
        assert!(format!("{err}").contains("window"), "{err}");
    }

    #[test]
    fn native_scorer_scores_ragged_lengths() {
        let d = dims();
        let mut rng = Rng::seed(151);
        let teacher = TeacherParams::init(&d, &mut rng);
        let sc = NativeScorer { dims: d.clone(), teacher, dense: None };
        // 3 seqs of odd lengths -> 2 ragged chunks, scored without padding
        let seqs: Vec<Vec<u32>> = vec![
            (0..10).map(|_| rng.below(64) as u32).collect(),
            (0..16).map(|_| rng.below(64) as u32).collect(),
            (0..5).map(|_| rng.below(64) as u32).collect(),
        ];
        let scored = sc.score_all(&seqs).unwrap();
        assert_eq!(scored.len(), 3);
        assert_eq!(scored[0].len(), 9);
        assert_eq!(scored[1].len(), 15);
        assert_eq!(scored[2].len(), 4);
        assert!(scored.iter().flatten().all(|&x| x < 0.0));
    }

    #[test]
    fn padding_does_not_change_prefix_scores() {
        let d = dims();
        let mut rng = Rng::seed(152);
        let teacher = TeacherParams::init(&d, &mut rng);
        let sc = NativeScorer { dims: d.clone(), teacher, dense: None };
        let short: Vec<u32> = (0..8).map(|_| rng.below(64) as u32).collect();
        let a = sc.score_all(std::slice::from_ref(&short)).unwrap();
        // same prefix inside a longer (manually padded) sequence
        let mut long = short.clone();
        long.resize(16, PAD);
        let b = sc.score_all(&[long]).unwrap();
        for (x, y) in a[0].iter().zip(&b[0][..7]) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }
}
