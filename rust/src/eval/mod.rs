//! Evaluation harness: perplexity, multiple-choice log-likelihood ranking
//! (the lm-eval-harness CSQA protocol), and gsm-sim answer accuracy.
//!
//! Everything is built over the [`Scorer`] abstraction — "given a batch of
//! fixed-length token sequences, return per-position log-probs of the
//! realized next tokens" — with two implementations:
//!
//! * [`scorer::HloScorer`] — the PJRT artifact path: a lowered HLO
//!   (teacher/student/packed forward) executed by the [`crate::runtime`];
//! * [`scorer::BackendScorer`] — the native execution engine: quantized
//!   linears run through a [`crate::model::backend::LinearBackend`]
//!   (dense / fused packed+LoRA / adapter-merged);
//! * [`scorer::NativeScorer`] — the pure-Rust reference model (teacher or
//!   pre-materialized dense weights; PJRT-free studies and tests).
//!
//! Every implementation declares what it can execute **once** via
//! [`Scorer::caps`] (an [`crate::engine::EngineCaps`] descriptor); the
//! engine scheduler and this harness branch on the descriptor instead of
//! probing per-capability methods. The native scorers declare
//! incremental KV-cache execution: cached forwards
//! ([`Scorer::cache_forward`], batched for the decode scheduler), greedy
//! decode ([`scorer::greedy_decode`]), and prefix-aware choice scoring
//! ([`Scorer::score_choices`]) — `mc_accuracy` prefills each item's
//! shared prompt once and scores every choice's suffix incrementally
//! instead of re-running the prompt per choice. Scoring can also run as
//! engine traffic ([`ppl::perplexity_client`]).

pub mod csqa;
pub mod ppl;
pub mod scorer;

pub use csqa::{gsm_accuracy, mc_accuracy};
pub use ppl::{perplexity, perplexity_client};
pub use scorer::{
    argmax_logp, greedy_decode, greedy_decode_recompute, BackendScorer, HloScorer, NativeScorer,
    Scorer,
};
