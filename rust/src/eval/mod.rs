//! Evaluation harness: perplexity, multiple-choice log-likelihood ranking
//! (the lm-eval-harness CSQA protocol), and gsm-sim answer accuracy.
//!
//! Everything is built over the [`Scorer`] abstraction — "given a batch of
//! fixed-length token sequences, return per-position log-probs of the
//! realized next tokens" — with two implementations:
//!
//! * [`scorer::HloScorer`] — the PJRT artifact path: a lowered HLO
//!   (teacher/student/packed forward) executed by the [`crate::runtime`];
//! * [`scorer::BackendScorer`] — the native execution engine: quantized
//!   linears run through a [`crate::model::backend::LinearBackend`]
//!   (dense / fused packed+LoRA / adapter-merged);
//! * [`scorer::NativeScorer`] — the pure-Rust reference model (teacher or
//!   pre-materialized dense weights; PJRT-free studies and tests).

pub mod csqa;
pub mod ppl;
pub mod scorer;

pub use csqa::{gsm_accuracy, mc_accuracy};
pub use ppl::perplexity;
pub use scorer::{BackendScorer, HloScorer, NativeScorer, Scorer};
