//! Evaluation harness: perplexity, multiple-choice log-likelihood ranking
//! (the lm-eval-harness CSQA protocol), and gsm-sim answer accuracy.
//!
//! Everything is built over the [`Scorer`] abstraction — "given a batch of
//! fixed-length token sequences, return per-position log-probs of the
//! realized next tokens" — with two implementations:
//!
//! * [`scorer::HloScorer`] — the production path: a PJRT artifact
//!   (teacher/student/packed forward) executed by the [`crate::runtime`];
//! * [`scorer::NativeScorer`] — the pure-Rust reference model (PJRT-free
//!   studies and tests).

pub mod csqa;
pub mod ppl;
pub mod scorer;

pub use csqa::{gsm_accuracy, mc_accuracy};
pub use ppl::perplexity;
pub use scorer::{HloScorer, NativeScorer, Scorer};
