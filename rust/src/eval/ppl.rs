//! Perplexity evaluation (the paper's WikiText-2 / C4 PPL columns).

use anyhow::{bail, Result};

use crate::engine::EngineClient;

use super::scorer::Scorer;

/// `exp( -Σ logp / #tokens )` over per-sequence logp vectors; `Err` when
/// no position was scoreable.
fn ppl_from_logps(scored: impl IntoIterator<Item = Vec<f32>>) -> Result<f64> {
    let mut total = 0.0f64;
    let mut count = 0usize;
    for lp in scored {
        for &x in &lp {
            total += x as f64;
            count += 1;
        }
    }
    if count == 0 {
        bail!("no tokens scored: perplexity needs at least one two-token sequence");
    }
    Ok((-total / count as f64).exp())
}

/// Corpus perplexity: `exp( -Σ logp / #tokens )` over all next-token
/// positions of all sequences (PAD-free sequences are assumed; `score_all`
/// already trims padding). Empty input (no scoreable token positions) is
/// an `Err`, not a process abort.
pub fn perplexity(scorer: &dyn Scorer, seqs: &[Vec<u32>]) -> Result<f64> {
    ppl_from_logps(scorer.score_all(seqs)?)
}

/// [`perplexity`] through a running [`crate::engine::Engine`]: every
/// sequence is submitted as a `Request::Score` (all of them in flight at
/// once, so the engine coalesces them into batched forwards) and the
/// aggregation is identical to the direct path. This is the eval-as-a-
/// workload form — the same engine can interleave this scoring traffic
/// with live generation.
pub fn perplexity_client(client: &EngineClient, seqs: &[Vec<u32>]) -> Result<f64> {
    let pendings: Vec<_> = seqs
        .iter()
        .map(|s| client.score(s.clone()))
        .collect::<Result<Vec<_>>>()?;
    let scored = pendings
        .into_iter()
        .map(|p| p.wait())
        .collect::<Result<Vec<_>>>()?;
    ppl_from_logps(scored)
}

/// Mean NLL (nats/token) — same data as [`perplexity`], linear scale.
pub fn mean_nll(scorer: &dyn Scorer, seqs: &[Vec<u32>]) -> Result<f64> {
    Ok(perplexity(scorer, seqs)?.ln())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::scorer::NativeScorer;
    use crate::model::{ModelDims, TeacherParams};
    use crate::tensor::Rng;

    fn dims() -> ModelDims {
        ModelDims {
            name: "unit".into(),
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            vocab: 64,
            seq: 16,
            batch: 2,
            group_size: 8,
        }
    }

    #[test]
    fn random_model_ppl_near_vocab() {
        // an untrained model ≈ uniform over 64 tokens -> PPL ≈ 64
        let d = dims();
        let mut rng = Rng::seed(161);
        let teacher = TeacherParams::init(&d, &mut rng);
        let sc = NativeScorer { dims: d.clone(), teacher, dense: None };
        let seqs: Vec<Vec<u32>> = (0..6)
            .map(|_| (0..16).map(|_| rng.below(64) as u32).collect())
            .collect();
        let ppl = perplexity(&sc, &seqs).unwrap();
        assert!(ppl > 20.0 && ppl < 200.0, "ppl={ppl}");
    }

    #[test]
    fn empty_input_is_err_not_panic() {
        let d = dims();
        let mut rng = Rng::seed(163);
        let teacher = TeacherParams::init(&d, &mut rng);
        let sc = NativeScorer { dims: d.clone(), teacher, dense: None };
        assert!(perplexity(&sc, &[]).is_err());
        // single-token sequences have no next-token positions either
        assert!(perplexity(&sc, &[vec![1u32]]).is_err());
    }

    #[test]
    fn engine_scoring_matches_direct_perplexity() {
        use crate::engine::{Engine, EngineConfig};
        let d = dims();
        let mut rng = Rng::seed(164);
        let teacher = TeacherParams::init(&d, &mut rng);
        let sc = NativeScorer { dims: d.clone(), teacher, dense: None };
        let seqs: Vec<Vec<u32>> = (0..5)
            .map(|_| (0..12).map(|_| rng.below(64) as u32).collect())
            .collect();
        let want = perplexity(&sc, &seqs).unwrap();
        let engine = Engine::start(sc, EngineConfig::default());
        let got = perplexity_client(&engine.client(), &seqs).unwrap();
        engine.shutdown();
        assert!(
            (want - got).abs() < 1e-9,
            "engine-path perplexity diverged: {want} vs {got}"
        );
    }

    #[test]
    fn ppl_positive_and_finite() {
        let d = dims();
        let mut rng = Rng::seed(162);
        let teacher = TeacherParams::init(&d, &mut rng);
        let sc = NativeScorer { dims: d.clone(), teacher, dense: None };
        let seqs = vec![(0..12).map(|_| rng.below(64) as u32).collect::<Vec<_>>()];
        let ppl = perplexity(&sc, &seqs).unwrap();
        assert!(ppl.is_finite() && ppl > 1.0);
    }
}
