//! Summary statistics used by metrics, reports, and benchmark harnesses.

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0 for fewer than two samples.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated quantile, `q` in `[0, 1]`.
pub fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Five-number-plus summary of a sample (used for bench reporting).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n: xs.len(),
            mean: mean(xs),
            std: std_dev(xs),
            min: sorted[0],
            p50: quantile(&sorted, 0.5),
            p95: quantile(&sorted, 0.95),
            max: *sorted.last().unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert!((quantile(&xs, 0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn summary_of_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
    }
}
