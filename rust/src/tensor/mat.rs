//! Row-major dense `f32` matrix.

use std::fmt;
use std::ops::{Index, IndexMut};

use super::{kernels, Rng};

/// Cache-block sizes shared by the matmul kernels: `BK` floats of a row
/// (256 B) and a `BJ x BK` RHS tile (16 KiB) fit L1 comfortably.
const BK: usize = 64;
const BJ: usize = 64;

/// Register-blocked micro-tile shared by [`Mat::matmul`] and
/// [`Mat::matmul_t`]: accumulate the output block
/// `rows [i_lo, i_hi) x cols [j0, j0+nb)` (`+=`) from LHS k-columns
/// `[k0, k1)` against RHS rows supplied by `brow(jj)` (each a `k1-k0`
/// slice — a packed panel row for `matmul`, a row slice of the
/// already-transposed RHS for `matmul_t`). Four LHS rows stream each RHS
/// row at once via [`kernels::dot4`]; since `dot4` is bitwise four
/// [`kernels::dot`]s, a row's value never depends on whether it ran in
/// the 4-row block or the remainder loop — the invariance that keeps
/// threaded/chunked/batched callers bit-identical per row.
// bitwise-pin: kernel_rows_are_chunk_invariant_bitwise, threaded_matmul_matches_single_threaded
// lint: hot — the register-blocked matmul inner tile; callers pre-pack panels
#[allow(clippy::too_many_arguments)]
fn micro_tile<'a>(
    a: &Mat,
    r0: usize,
    i_lo: usize,
    i_hi: usize,
    k0: usize,
    k1: usize,
    n: usize,
    j0: usize,
    nb: usize,
    brow: impl Fn(usize) -> &'a [f32],
    out: &mut [f32],
) {
    let mut i = i_lo;
    while i + 4 <= i_hi {
        let a0 = &a.row(i)[k0..k1];
        let a1 = &a.row(i + 1)[k0..k1];
        let a2 = &a.row(i + 2)[k0..k1];
        let a3 = &a.row(i + 3)[k0..k1];
        let base = (i - r0) * n + j0;
        for jj in 0..nb {
            let d = kernels::dot4(a0, a1, a2, a3, brow(jj));
            out[base + jj] += d[0];
            out[base + n + jj] += d[1];
            out[base + 2 * n + jj] += d[2];
            out[base + 3 * n + jj] += d[3];
        }
        i += 4;
    }
    while i < i_hi {
        let arow = &a.row(i)[k0..k1];
        let base = (i - r0) * n + j0;
        let orow = &mut out[base..base + nb];
        for (jj, o) in orow.iter_mut().enumerate() {
            *o += kernels::dot(arow, brow(jj));
        }
        i += 1;
    }
}

/// A dense, row-major `f32` matrix. Most algorithms in this crate operate on
/// weight matrices shaped `[rows = d_out, cols = d_in]` (PyTorch linear
/// convention) or activations shaped `[tokens, features]`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with a constant.
    pub fn full(rows: usize, cols: usize, value: f32) -> Self {
        Mat { rows, cols, data: vec![value; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a closure over `(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer length mismatch");
        Mat { rows, cols, data }
    }

    /// Standard-normal entries (Box–Muller over the PCG stream).
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(rng.next_gaussian());
        }
        Mat { rows, cols, data }
    }

    /// Uniform entries in `[lo, hi)`.
    pub fn rand_uniform(rows: usize, cols: usize, lo: f32, hi: f32, rng: &mut Rng) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(lo + (hi - lo) * rng.next_f32());
        }
        Mat { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Borrow row `r` as a slice.
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutably borrow row `r`.
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy column `c` out.
    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self[(r, c)]).collect()
    }

    /// Matrix transpose.
    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[(c, r)] = self[(r, c)];
            }
        }
        out
    }

    /// Blocked `self * other` kernel over the output-row range `[r0, r1)`,
    /// accumulating into `out` (`(r1-r0) * other.cols` zeroed floats).
    ///
    /// Each `BJ x BK` block of the RHS is first packed into a small
    /// *transposed panel* (16 KiB, L1-resident, thread-local — no
    /// allocation per call or per work-stealing chunk), so the K-loop
    /// inside the [`micro_tile`] is unit-stride on **both** operands —
    /// the panel is amortized across every LHS row of the chunk. Both
    /// the single-threaded and threaded products call this, and per-row
    /// results are independent of the chunking (see [`micro_tile`]), so
    /// they produce bit-identical results per output row.
    fn matmul_rows_into(&self, other: &Mat, r0: usize, r1: usize, out: &mut [f32]) {
        thread_local! {
            static PANEL: std::cell::RefCell<Vec<f32>> =
                std::cell::RefCell::new(vec![0.0f32; BJ * BK]);
        }
        let k = self.cols;
        let n = other.cols;
        PANEL.with(|cell| {
            let mut panel = cell.borrow_mut();
            for j0 in (0..n).step_by(BJ) {
                let j1 = (j0 + BJ).min(n);
                let nb = j1 - j0;
                for k0 in (0..k).step_by(BK) {
                    let k1 = (k0 + BK).min(k);
                    let bk = k1 - k0;
                    // pack the transposed panel: panel[jj][kk] = other[k0+kk, j0+jj]
                    for kk in k0..k1 {
                        let brow = &other.data[kk * n + j0..kk * n + j1];
                        for (jj, &b) in brow.iter().enumerate() {
                            panel[jj * bk + (kk - k0)] = b;
                        }
                    }
                    let p = &panel[..];
                    micro_tile(self, r0, r0, r1, k0, k1, n, j0, nb, |jj| &p[jj * bk..][..bk], out);
                }
            }
        });
    }

    /// The pre-vectorization scalar kernel, kept as the numerical
    /// reference the micro-tiled product is pinned against (≤1e-5).
    #[cfg(test)]
    fn matmul_rows_into_naive(&self, other: &Mat, r0: usize, r1: usize, out: &mut [f32]) {
        let k = self.cols;
        let n = other.cols;
        for k0 in (0..k).step_by(BK) {
            let k1 = (k0 + BK).min(k);
            for i in r0..r1 {
                let arow = self.row(i);
                let orow = &mut out[(i - r0) * n..(i - r0) * n + n];
                for kk in k0..k1 {
                    let a = arow[kk];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &other.data[kk * n..kk * n + n];
                    for (o, &b) in orow.iter_mut().zip(brow) {
                        *o += a * b;
                    }
                }
            }
        }
    }

    fn assert_matmul_shapes(&self, other: &Mat) {
        assert_eq!(
            self.cols, other.rows,
            "matmul shape mismatch {}x{} * {}x{}",
            self.rows, self.cols, other.rows, other.cols
        );
    }

    /// Matrix product `self * other` with a blocked, transposed-RHS inner loop.
    pub fn matmul(&self, other: &Mat) -> Mat {
        self.assert_matmul_shapes(other);
        let mut out = Mat::zeros(self.rows, other.cols);
        self.matmul_rows_into(other, 0, self.rows, &mut out.data);
        out
    }

    /// Multi-threaded tiled `self * other`: output rows are split into
    /// contiguous chunks dispatched to the persistent worker pool
    /// ([`super::parallel_rows`]); each chunk runs the same blocked kernel
    /// as [`Mat::matmul`], so results are identical to the single-threaded
    /// product. `workers <= 1` (or a single-row output) falls back inline.
    pub fn matmul_threaded(&self, other: &Mat, workers: usize) -> Mat {
        self.assert_matmul_shapes(other);
        let (m, n) = (self.rows, other.cols);
        let data = super::parallel_rows(m, n, workers, |r0, r1, out| {
            self.matmul_rows_into(other, r0, r1, out)
        });
        Mat { rows: m, cols: n, data }
    }

    /// Blocked `self * other_t^T` kernel over output-row range `[r0, r1)`.
    /// Tiles over both the j (RHS-row) and k (inner) dimensions so a
    /// `BJ x BK` block of `other_t` stays cache-hot across the LHS rows —
    /// this is the LoRA `X A B^T` hot path. The RHS is already row-major
    /// transposed, so no panel pack is needed: rows go straight into the
    /// 4-row [`micro_tile`] with unit stride on both operands.
    fn matmul_t_rows_into(&self, other_t: &Mat, r0: usize, r1: usize, out: &mut [f32]) {
        let k = self.cols;
        let n = other_t.rows;
        for j0 in (0..n).step_by(BJ) {
            let j1 = (j0 + BJ).min(n);
            for k0 in (0..k).step_by(BK) {
                let k1 = (k0 + BK).min(k);
                micro_tile(
                    self,
                    r0,
                    r0,
                    r1,
                    k0,
                    k1,
                    n,
                    j0,
                    j1 - j0,
                    |jj| &other_t.row(j0 + jj)[k0..k1],
                    out,
                );
            }
        }
    }

    /// The pre-vectorization scalar `matmul_t` kernel — the parity
    /// reference for the micro-tiled version.
    #[cfg(test)]
    fn matmul_t_rows_into_naive(&self, other_t: &Mat, r0: usize, r1: usize, out: &mut [f32]) {
        let k = self.cols;
        let n = other_t.rows;
        for j0 in (0..n).step_by(BJ) {
            let j1 = (j0 + BJ).min(n);
            for k0 in (0..k).step_by(BK) {
                let k1 = (k0 + BK).min(k);
                for i in r0..r1 {
                    let arow = &self.row(i)[k0..k1];
                    let orow = &mut out[(i - r0) * n..(i - r0) * n + n];
                    for j in j0..j1 {
                        let brow = &other_t.row(j)[k0..k1];
                        let mut acc = 0.0f32;
                        for (&x, &y) in arow.iter().zip(brow) {
                            acc += x * y;
                        }
                        orow[j] += acc;
                    }
                }
            }
        }
    }

    /// `self * other^T` (handy when the RHS is stored row-major already
    /// transposed, e.g. LoRA's `X A B^T`). Cache-blocked like [`Mat::matmul`].
    pub fn matmul_t(&self, other_t: &Mat) -> Mat {
        assert_eq!(self.cols, other_t.cols, "matmul_t inner-dim mismatch");
        let mut out = Mat::zeros(self.rows, other_t.rows);
        self.matmul_t_rows_into(other_t, 0, self.rows, &mut out.data);
        out
    }

    /// Multi-threaded tiled `self * other^T`; see [`Mat::matmul_threaded`].
    pub fn matmul_t_threaded(&self, other_t: &Mat, workers: usize) -> Mat {
        assert_eq!(self.cols, other_t.cols, "matmul_t inner-dim mismatch");
        let (m, n) = (self.rows, other_t.rows);
        let data = super::parallel_rows(m, n, workers, |r0, r1, out| {
            self.matmul_t_rows_into(other_t, r0, r1, out)
        });
        Mat { rows: m, cols: n, data }
    }

    /// Elementwise map into a new matrix.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// In-place elementwise map.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    /// Elementwise binary op into a new matrix.
    pub fn zip(&self, other: &Mat, f: impl Fn(f32, f32) -> f32) -> Mat {
        assert_eq!(self.shape(), other.shape(), "zip shape mismatch");
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
        }
    }

    pub fn add(&self, other: &Mat) -> Mat {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        self.zip(other, |a, b| a - b)
    }

    pub fn scale(&self, s: f32) -> Mat {
        self.map(|x| x * s)
    }

    /// In-place `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Mat) {
        assert_eq!(self.shape(), other.shape(), "axpy shape mismatch");
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        // lint: allow(reduce) — diagnostics-only metric; f64 accumulation, never on the bitwise-pinned path
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
    }

    /// Frobenius norm of `self - other`, without materializing the difference.
    pub fn fro_dist(&self, other: &Mat) -> f32 {
        assert_eq!(self.shape(), other.shape(), "fro_dist shape mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            // lint: allow(reduce) — diagnostics-only metric; f64 accumulation, never on the bitwise-pinned path
            .sum::<f64>()
            .sqrt() as f32
    }

    /// Largest absolute entry.
    pub fn abs_max(&self) -> f32 {
        // lint: allow(reduce) — max is an order-insensitive lattice fold; result is bit-exact regardless of order
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn min(&self) -> f32 {
        // lint: allow(reduce) — min is an order-insensitive lattice fold; result is bit-exact regardless of order
        self.data.iter().copied().fold(f32::INFINITY, f32::min)
    }

    pub fn max(&self) -> f32 {
        // lint: allow(reduce) — max is an order-insensitive lattice fold; result is bit-exact regardless of order
        self.data.iter().copied().fold(f32::NEG_INFINITY, f32::max)
    }

    /// Mean of all entries.
    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        // lint: allow(reduce) — diagnostics-only statistic; f64 accumulation, never on the bitwise-pinned path
        (self.data.iter().map(|&x| x as f64).sum::<f64>() / self.data.len() as f64) as f32
    }

    /// Sub-block copy `[r0..r0+nr, c0..c0+nc]`.
    pub fn block(&self, r0: usize, c0: usize, nr: usize, nc: usize) -> Mat {
        assert!(r0 + nr <= self.rows && c0 + nc <= self.cols, "block out of range");
        Mat::from_fn(nr, nc, |r, c| self[(r0 + r, c0 + c)])
    }

    /// Overwrite a sub-block starting at `(r0, c0)`.
    pub fn set_block(&mut self, r0: usize, c0: usize, block: &Mat) {
        assert!(
            r0 + block.rows <= self.rows && c0 + block.cols <= self.cols,
            "set_block out of range"
        );
        for r in 0..block.rows {
            for c in 0..block.cols {
                self[(r0 + r, c0 + c)] = block[(r, c)];
            }
        }
    }

    /// Stack two matrices vertically.
    pub fn vstack(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "vstack col mismatch");
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Mat { rows: self.rows + other.rows, cols: self.cols, data }
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(6);
        let show_c = self.cols.min(8);
        for r in 0..show_r {
            write!(f, "  ")?;
            for c in 0..show_c {
                write!(f, "{:>9.4} ", self[(r, c)])?;
            }
            writeln!(f, "{}", if self.cols > show_c { "..." } else { "" })?;
        }
        if self.rows > show_r {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::seed(1);
        let a = Mat::randn(5, 7, &mut rng);
        let i = Mat::eye(7);
        let b = a.matmul(&i);
        assert!(a.fro_dist(&b) < 1e-6);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_t_matches_matmul() {
        let mut rng = Rng::seed(2);
        let a = Mat::randn(4, 6, &mut rng);
        let b = Mat::randn(6, 3, &mut rng);
        let c1 = a.matmul(&b);
        let c2 = a.matmul_t(&b.t());
        assert!(c1.fro_dist(&c2) < 1e-4);
    }

    /// The blocked matmul_t must agree with matmul on shapes that exercise
    /// partial j/k tiles (dims straddling the BJ/BK block boundaries).
    #[test]
    fn matmul_t_blocked_odd_shapes() {
        let mut rng = Rng::seed(7);
        for (m, k, n) in [(3, 70, 65), (65, 64, 1), (1, 129, 67), (9, 191, 130)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let c1 = a.matmul(&b);
            let c2 = a.matmul_t(&b.t());
            let rel = c1.fro_dist(&c2) / c1.fro_norm().max(1e-6);
            assert!(rel < 1e-5, "m={m} k={k} n={n} rel={rel}");
        }
    }

    #[test]
    fn threaded_matmul_matches_single_threaded() {
        let mut rng = Rng::seed(8);
        for (m, k, n, w) in [(1, 8, 8, 4), (7, 33, 19, 3), (64, 65, 66, 4), (5, 4, 3, 16)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            // same row-kernel => bit-identical per output row
            assert_eq!(a.matmul(&b), a.matmul_threaded(&b, w), "m={m} k={k} n={n} w={w}");
            let bt = b.t();
            assert_eq!(a.matmul_t(&bt), a.matmul_t_threaded(&bt, w), "t: m={m} k={k} n={n} w={w}");
        }
    }

    /// Tentpole pin: the vectorized micro-tiled kernels match the scalar
    /// reference kernels ≤1e-5 (relative) across odd shapes straddling
    /// every blocking boundary (4-row micro-tile, 8-lane unroll, BK/BJ
    /// tiles) — the property-test grid from the PR-5 acceptance list.
    #[test]
    fn vectorized_matches_naive_reference() {
        let mut rng = Rng::seed(0x7e57);
        for &m in &[1usize, 3, 7, 64, 100] {
            for &k in &[1usize, 3, 7, 64, 100] {
                for &n in &[1usize, 3, 7, 64, 100] {
                    let a = Mat::randn(m, k, &mut rng);
                    let b = Mat::randn(k, n, &mut rng);
                    let got = a.matmul(&b);
                    let mut want = Mat::zeros(m, n);
                    a.matmul_rows_into_naive(&b, 0, m, &mut want.data);
                    let rel = got.fro_dist(&want) / want.fro_norm().max(1e-6);
                    assert!(rel < 1e-5, "matmul m={m} k={k} n={n} rel={rel}");

                    let bt = b.t();
                    let got_t = a.matmul_t(&bt);
                    let mut want_t = Mat::zeros(m, n);
                    a.matmul_t_rows_into_naive(&bt, 0, m, &mut want_t.data);
                    let rel = got_t.fro_dist(&want_t) / want_t.fro_norm().max(1e-6);
                    assert!(rel < 1e-5, "matmul_t m={m} k={k} n={n} rel={rel}");
                }
            }
        }
    }

    /// Bitwise row invariance: running the kernel over arbitrary row
    /// sub-ranges (including splits landing mid-micro-tile) reproduces
    /// the full-range rows exactly — the property the finer-grained
    /// work-stealing chunks, batched forwards, and chunked prefill all
    /// rest on.
    #[test]
    fn kernel_rows_are_chunk_invariant_bitwise() {
        let mut rng = Rng::seed(0x51ab);
        let (m, k, n) = (13usize, 37usize, 21usize);
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(k, n, &mut rng);
        let full = a.matmul(&b);
        let bt = b.t();
        let full_t = a.matmul_t(&bt);
        for split in [1usize, 2, 3, 5, 6] {
            let mut data = vec![0.0f32; m * n];
            let mut data_t = vec![0.0f32; m * n];
            let mut r0 = 0;
            while r0 < m {
                let r1 = (r0 + split).min(m);
                a.matmul_rows_into(&b, r0, r1, &mut data[r0 * n..r1 * n]);
                a.matmul_t_rows_into(&bt, r0, r1, &mut data_t[r0 * n..r1 * n]);
                r0 = r1;
            }
            assert_eq!(full.data(), &data[..], "matmul split={split}");
            assert_eq!(full_t.data(), &data_t[..], "matmul_t split={split}");
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::seed(3);
        let a = Mat::randn(3, 9, &mut rng);
        assert_eq!(a, a.t().t());
    }

    #[test]
    fn fro_norm_matches_manual() {
        let a = Mat::from_vec(1, 3, vec![3.0, 4.0, 0.0]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn block_roundtrip() {
        let mut rng = Rng::seed(4);
        let a = Mat::randn(6, 6, &mut rng);
        let b = a.block(2, 1, 3, 4);
        let mut c = Mat::zeros(6, 6);
        c.set_block(2, 1, &b);
        assert_eq!(c[(2, 1)], a[(2, 1)]);
        assert_eq!(c[(4, 4)], a[(4, 4)]);
        assert_eq!(c[(0, 0)], 0.0);
    }
}
