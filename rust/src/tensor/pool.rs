//! Persistent worker pool for the data-parallel kernels.
//!
//! The first generation of [`super::parallel_rows`] / [`super::parallel_map`]
//! spawned scoped std threads per call, which costs ~tens of microseconds
//! per matmul — visible on the small linears that dominate a serving
//! forward. This pool spawns `available_parallelism - 1` workers once
//! (lazily, on first parallel call) and dispatches borrowed closures to
//! them with a mutex + condvar, so a dispatch costs on the order of a
//! wakeup instead of a thread spawn.
//!
//! ## Execution model
//!
//! A call to [`Pool::run_indexed`]`(n, f)` publishes one *job*: the task
//! indices `0..n`, claimed dynamically by whoever gets there first. Both
//! the pool workers **and the calling thread** claim indices, so a job
//! never depends on pool workers being free: if every worker is busy (or
//! the call comes *from* a pool worker — nested dispatch), the caller
//! simply runs all tasks itself and the call degrades to a sequential
//! loop instead of deadlocking.
//!
//! The atomic claim cursor doubles as a work-stealing chunk queue:
//! [`super::parallel_rows`] publishes several small row chunks per lane
//! (instead of one static chunk each), so when per-task cost is ragged —
//! packed-group decode, attention rows whose cost grows with position —
//! fast lanes keep claiming chunks while a slow lane finishes its
//! current one, and the job no longer tail-stalls on the slowest static
//! split.
//!
//! ## Safety
//!
//! The closure handed to workers borrows the caller's stack (the kernel
//! and its output buffer). That borrow is erased to `'static` to cross
//! the queue, which is sound because `run_indexed` does not return until
//! (a) every task has finished and (b) no worker still holds a reference
//! to the job — the caller removes the job from the queue and waits for
//! the job's refcount to drain before its stack frame can die.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// One published unit of fan-out work: tasks `0..n_tasks`, claimed by
/// atomic counter. `run` really borrows the publishing caller's stack —
/// see the module-level safety note.
struct Job {
    n_tasks: usize,
    next: AtomicUsize,
    run: Box<dyn Fn(usize) + Send + Sync>,
    done: Mutex<usize>,
    done_cv: Condvar,
    /// first caught panic payload, re-raised on the publishing thread so
    /// the original message/location survives
    panic_payload: Mutex<Option<Box<dyn Any + Send>>>,
}

impl Job {
    /// Claim and run tasks until none are left. Called concurrently by
    /// pool workers and the publishing thread.
    fn work(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::SeqCst);
            if i >= self.n_tasks {
                return;
            }
            if let Err(p) = catch_unwind(AssertUnwindSafe(|| (self.run)(i))) {
                let mut slot = self.panic_payload.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(p);
                }
            }
            let mut done = self.done.lock().unwrap();
            *done += 1;
            if *done == self.n_tasks {
                self.done_cv.notify_all();
            }
        }
    }

    fn exhausted(&self) -> bool {
        self.next.load(Ordering::SeqCst) >= self.n_tasks
    }
}

struct Shared {
    queue: Mutex<VecDeque<Arc<Job>>>,
    cv: Condvar,
}

/// The persistent pool. Workers live for the process lifetime (they are
/// never joined; they sleep on the condvar between jobs).
pub struct Pool {
    shared: Arc<Shared>,
    workers: usize,
}

static POOL: OnceLock<Pool> = OnceLock::new();

/// The process-wide pool, spawned on first use with
/// `available_parallelism - 1` workers (the caller of every job is the
/// remaining lane).
pub fn global() -> &'static Pool {
    POOL.get_or_init(|| {
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Pool::new(hw.saturating_sub(1).max(1))
    })
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                // drop fully-claimed jobs at the front (their publisher
                // also removes them; this is opportunistic cleanup)
                while q.front().map_or(false, |j| j.exhausted()) {
                    q.pop_front();
                }
                match q.front() {
                    Some(j) => break j.clone(),
                    None => q = shared.cv.wait(q).unwrap(),
                }
            }
        };
        job.work();
    }
}

impl Pool {
    fn new(workers: usize) -> Pool {
        let shared = Arc::new(Shared { queue: Mutex::new(VecDeque::new()), cv: Condvar::new() });
        for w in 0..workers {
            let s = shared.clone();
            std::thread::Builder::new()
                .name(format!("rilq-pool-{w}"))
                .spawn(move || worker_loop(s))
                .expect("spawn pool worker");
        }
        Pool { shared, workers }
    }

    /// Pool worker count (excludes the calling thread's lane).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `f(i)` for every `i in 0..n_tasks` across the pool, blocking
    /// until all complete. The caller participates, so completion never
    /// depends on worker availability (nested calls degrade to inline
    /// execution). A panicking task poisons the job and the panic is
    /// re-raised here after every task has settled.
    pub fn run_indexed(&self, n_tasks: usize, f: impl Fn(usize) + Sync) {
        if n_tasks == 0 {
            return;
        }
        if n_tasks == 1 {
            f(0);
            return;
        }
        let fref = &f;
        let run: Box<dyn Fn(usize) + Send + Sync + '_> = Box::new(move |i| fref(i));
        // SAFETY: lifetime erasure to cross the queue; the tail of this
        // function guarantees no reference to `run` survives the frame
        // (completion wait + queue removal + refcount drain).
        let run: Box<dyn Fn(usize) + Send + Sync + 'static> =
            unsafe { std::mem::transmute(run) };
        let job = Arc::new(Job {
            n_tasks,
            next: AtomicUsize::new(0),
            run,
            done: Mutex::new(0),
            done_cv: Condvar::new(),
            panic_payload: Mutex::new(None),
        });
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(job.clone());
        }
        self.shared.cv.notify_all();
        job.work();
        let mut done = job.done.lock().unwrap();
        while *done < job.n_tasks {
            done = job.done_cv.wait(done).unwrap();
        }
        drop(done);
        // unpublish, then wait for workers to drop their handles so the
        // borrowed closure cannot outlive this frame
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.retain(|j| !Arc::ptr_eq(j, &job));
        }
        while Arc::strong_count(&job) > 1 {
            std::thread::yield_now();
        }
        if let Some(p) = job.panic_payload.lock().unwrap().take() {
            std::panic::resume_unwind(p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_indices_run_exactly_once() {
        let n = 100;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        global().run_indexed(n, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn concurrent_callers_do_not_interfere() {
        std::thread::scope(|s| {
            for seed in 0..4u64 {
                s.spawn(move || {
                    let n = 50 + seed as usize;
                    let sums: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                    global().run_indexed(n, |i| {
                        sums[i].store(i * 2 + 1, Ordering::SeqCst);
                    });
                    let total: usize = sums.iter().map(|v| v.load(Ordering::SeqCst)).sum();
                    assert_eq!(total, n * n); // sum of first n odd numbers
                });
            }
        });
    }

    #[test]
    fn nested_dispatch_completes() {
        // a task that itself fans out must not deadlock (the inner caller
        // self-executes when all workers are busy)
        let outer = 8;
        let acc = AtomicUsize::new(0);
        global().run_indexed(outer, |_| {
            global().run_indexed(8, |_| {
                acc.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(acc.load(Ordering::SeqCst), outer * 8);
    }

    #[test]
    fn panics_propagate_to_caller() {
        let r = std::panic::catch_unwind(|| {
            global().run_indexed(8, |i| {
                if i == 3 {
                    panic!("boom");
                }
            });
        });
        // the ORIGINAL payload must survive (not a generic pool message)
        let payload = r.unwrap_err();
        assert_eq!(payload.downcast_ref::<&str>().copied(), Some("boom"));
        // the pool must still be usable afterwards
        let ok = AtomicUsize::new(0);
        global().run_indexed(4, |_| {
            ok.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ok.load(Ordering::SeqCst), 4);
    }
}
