//! Minimal dense linear-algebra substrate.
//!
//! The repo is built offline against a fixed crate set (no `ndarray`,
//! `nalgebra`, or `rand`), so this module provides everything the
//! quantizers, LQEC methods, and the pure-Rust reference model need:
//! a row-major `f32` matrix type, a PCG-based RNG, Jacobi SVD,
//! Hadamard transforms, and summary statistics.

mod mat;
mod rng;
mod linalg;
mod stats;

pub use linalg::{hadamard_matrix, svd_jacobi, Svd};

/// Parallel map over an indexed domain using scoped std threads (the
/// offline crate set has no rayon). Results come back in input order.
pub fn parallel_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, workers: usize, f: F) -> Vec<T> {
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots_ptr = std::sync::Mutex::new(&mut slots);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                if i >= n {
                    return;
                }
                let v = f(i);
                slots_ptr.lock().unwrap()[i] = Some(v);
            });
        }
    });
    slots.into_iter().map(|s| s.expect("parallel_map slot")).collect()
}
pub use mat::Mat;
pub use rng::Rng;
pub use stats::{mean, quantile, std_dev, Summary};
