//! Minimal dense linear-algebra substrate.
//!
//! The repo is built offline against a fixed crate set (no `ndarray`,
//! `nalgebra`, or `rand`), so this module provides everything the
//! quantizers, LQEC methods, and the pure-Rust reference model need:
//! a row-major `f32` matrix type, a PCG-based RNG, Jacobi SVD,
//! Hadamard transforms, summary statistics, and a persistent worker
//! pool ([`pool`]) behind [`parallel_rows`] / [`parallel_map`].

pub mod kernels;
mod mat;
mod rng;
mod linalg;
pub mod pool;
mod stats;

pub use linalg::{hadamard_matrix, svd_jacobi, Svd};

/// Parallel map over an indexed domain on the persistent worker pool
/// ([`pool`]; the offline crate set has no rayon). Results come back in
/// input order. Items are claimed dynamically, so ragged per-item cost
/// load-balances across the pool.
pub fn parallel_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, workers: usize, f: F) -> Vec<T> {
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let base = slots.as_mut_ptr() as usize;
    pool::global().run_indexed(n, |i| {
        let v = f(i);
        // SAFETY: each task writes only slot `i` (disjoint), and
        // run_indexed blocks until every task has finished. The old value
        // is `None`, so overwriting without a drop is fine.
        unsafe { (base as *mut Option<T>).add(i).write(Some(v)) };
    });
    slots.into_iter().map(|s| s.expect("parallel_map slot")).collect()
}

/// Compute an `[m, n]` row-major buffer by splitting output rows into
/// contiguous chunks dispatched to the persistent worker pool ([`pool`]).
/// `kernel(r0, r1, out)` must fill `out` (zeroed, `(r1-r0)*n` long) with
/// rows `[r0, r1)`. Workers write disjoint slices of one allocation — no
/// per-worker buffers, no stitch copy, no per-call thread spawn. With
/// `workers <= 1` the kernel runs inline over the full range, so threaded
/// and single-threaded callers share one code path (and one
/// floating-point association order per row).
pub fn parallel_rows(
    m: usize,
    n: usize,
    workers: usize,
    kernel: impl Fn(usize, usize, &mut [f32]) + Sync,
) -> Vec<f32> {
    let mut data = vec![0.0f32; m * n];
    let workers = workers.max(1).min(m.max(1));
    if workers <= 1 || n == 0 {
        kernel(0, m, &mut data);
        return data;
    }
    // Finer-grained chunk queue: split into ~CHUNKS_PER_WORKER pieces per
    // lane instead of one static chunk each. The pool's atomic task
    // cursor then hands chunks to whichever lane is free, so a ragged
    // batch (per-row cost varies with sequence position, group count,
    // cache hits) no longer tail-stalls on the slowest static chunk.
    // The floor of MIN_CHUNK_ROWS keeps the 4-row register micro-tiles
    // of the matmul kernels populated; per-row results are chunk-
    // invariant bitwise (see `kernels`), so the split is free to move.
    const CHUNKS_PER_WORKER: usize = 4;
    const MIN_CHUNK_ROWS: usize = 4;
    let per = m.div_ceil(workers * CHUNKS_PER_WORKER).max(MIN_CHUNK_ROWS);
    let n_chunks = m.div_ceil(per);
    let base = data.as_mut_ptr() as usize;
    pool::global().run_indexed(n_chunks, |c| {
        let r0 = c * per;
        let r1 = (r0 + per).min(m);
        // SAFETY: chunk `c` owns rows [r0, r1) — the row ranges (and so
        // the `[r0*n, r1*n)` buffer ranges) are pairwise disjoint, and
        // run_indexed blocks until every chunk has finished.
        let out = unsafe {
            std::slice::from_raw_parts_mut((base as *mut f32).add(r0 * n), (r1 - r0) * n)
        };
        kernel(r0, r1, out);
    });
    data
}

/// Multiply-add count one worker lane must amortize before parallel
/// dispatch pays for itself. Recalibrated for the PR-5 vectorized
/// micro-kernels: serial throughput rose ~4x (8-wide unrolled FMA lanes
/// + register-blocked micro-tiles), so the break-even moved up 4x with
/// it — a lane now chews through ~2 MFLOP in the time the old scalar
/// kernel spent on ~0.5 MFLOP, while the pool-dispatch cost (a condvar
/// wakeup) stayed fixed.
pub const FLOPS_PER_WORKER: usize = 1 << 21;

/// Worker-lane count worth using for a kernel of `flops` fused
/// multiply-adds: 1 below `2 *` [`FLOPS_PER_WORKER`] (dispatch overhead
/// would eat the win), then one lane per [`FLOPS_PER_WORKER`] capped at
/// the hardware parallelism. Returns at least 1.
pub fn suggested_workers(flops: usize) -> usize {
    if flops < 2 * FLOPS_PER_WORKER {
        return 1;
    }
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    hw.min(flops / FLOPS_PER_WORKER).max(1)
}

pub use mat::Mat;
pub use rng::Rng;
pub use stats::{mean, quantile, std_dev, Summary};

#[cfg(test)]
mod tests {
    use super::*;

    /// Pins the recalibrated parallel-dispatch break-even (PR 5): serial
    /// stays serial below `2 * FLOPS_PER_WORKER`, lanes scale linearly
    /// with work above it, and the hardware cap always binds.
    #[test]
    fn suggested_workers_threshold_logic() {
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        assert_eq!(suggested_workers(0), 1);
        assert_eq!(suggested_workers(FLOPS_PER_WORKER), 1);
        assert_eq!(suggested_workers(2 * FLOPS_PER_WORKER - 1), 1);
        // at the break-even: one lane per FLOPS_PER_WORKER, hw-capped
        assert_eq!(suggested_workers(2 * FLOPS_PER_WORKER), hw.min(2));
        assert_eq!(suggested_workers(3 * FLOPS_PER_WORKER), hw.min(3));
        assert_eq!(suggested_workers(usize::MAX / 2), hw);
        // monotone: more work never suggests fewer lanes
        let mut prev = 0;
        for shift in 16..30 {
            let w = suggested_workers(1usize << shift);
            assert!(w >= prev, "non-monotone at 1<<{shift}");
            prev = w;
        }
    }

    /// The finer-grained chunk queue must still produce exactly the
    /// inline result for every (rows, workers) geometry — chunks are
    /// disjoint, cover all rows, and per-row output is chunk-invariant.
    #[test]
    fn parallel_rows_fine_chunks_match_inline() {
        for (m, n, workers) in [(1usize, 3usize, 4usize), (5, 2, 2), (16, 3, 4), (103, 7, 8)] {
            let inline = parallel_rows(m, n, 1, |r0, r1, out| {
                for r in r0..r1 {
                    for c in 0..n {
                        out[(r - r0) * n + c] = (r * n + c) as f32;
                    }
                }
            });
            let pooled = parallel_rows(m, n, workers, |r0, r1, out| {
                for r in r0..r1 {
                    for c in 0..n {
                        out[(r - r0) * n + c] = (r * n + c) as f32;
                    }
                }
            });
            assert_eq!(inline, pooled, "m={m} n={n} workers={workers}");
        }
    }
}
