//! Minimal dense linear-algebra substrate.
//!
//! The repo is built offline against a fixed crate set (no `ndarray`,
//! `nalgebra`, or `rand`), so this module provides everything the
//! quantizers, LQEC methods, and the pure-Rust reference model need:
//! a row-major `f32` matrix type, a PCG-based RNG, Jacobi SVD,
//! Hadamard transforms, and summary statistics.

mod mat;
mod rng;
mod linalg;
mod stats;

pub use linalg::{hadamard_matrix, svd_jacobi, Svd};

/// Parallel map over an indexed domain using scoped std threads (the
/// offline crate set has no rayon). Results come back in input order.
pub fn parallel_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, workers: usize, f: F) -> Vec<T> {
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots_ptr = std::sync::Mutex::new(&mut slots);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                if i >= n {
                    return;
                }
                let v = f(i);
                slots_ptr.lock().unwrap()[i] = Some(v);
            });
        }
    });
    slots.into_iter().map(|s| s.expect("parallel_map slot")).collect()
}
/// Compute an `[m, n]` row-major buffer by splitting output rows into
/// contiguous chunks across scoped worker threads. `kernel(r0, r1, out)`
/// must fill `out` (zeroed, `(r1-r0)*n` long) with rows `[r0, r1)`.
/// Workers write disjoint `chunks_mut` slices of one allocation — no
/// per-worker buffers, no stitch copy. With `workers <= 1` the kernel
/// runs inline over the full range, so threaded and single-threaded
/// callers share one code path (and one floating-point association
/// order per row).
pub fn parallel_rows(
    m: usize,
    n: usize,
    workers: usize,
    kernel: impl Fn(usize, usize, &mut [f32]) + Sync,
) -> Vec<f32> {
    let mut data = vec![0.0f32; m * n];
    let workers = workers.max(1).min(m.max(1));
    if workers <= 1 || n == 0 {
        kernel(0, m, &mut data);
        return data;
    }
    let per = m.div_ceil(workers);
    std::thread::scope(|scope| {
        let kernel = &kernel;
        for (c, chunk) in data.chunks_mut(per * n).enumerate() {
            scope.spawn(move || {
                let r0 = c * per;
                let r1 = (r0 + per).min(m);
                kernel(r0, r1, chunk);
            });
        }
    });
    data
}

/// Worker-thread count worth spawning for a kernel of `flops` fused
/// multiply-adds. Scoped-thread spawn costs tens of microseconds, so small
/// problems stay single-threaded; large ones scale up to the hardware
/// parallelism. Returns at least 1.
pub fn suggested_workers(flops: usize) -> usize {
    // ~2 MFLOP per worker amortizes thread spawn + result stitching
    const FLOPS_PER_WORKER: usize = 1 << 21;
    if flops < 2 * FLOPS_PER_WORKER {
        return 1;
    }
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    hw.min(flops / FLOPS_PER_WORKER).max(1)
}

pub use mat::Mat;
pub use rng::Rng;
pub use stats::{mean, quantile, std_dev, Summary};
