//! Minimal dense linear-algebra substrate.
//!
//! The repo is built offline against a fixed crate set (no `ndarray`,
//! `nalgebra`, or `rand`), so this module provides everything the
//! quantizers, LQEC methods, and the pure-Rust reference model need:
//! a row-major `f32` matrix type, a PCG-based RNG, Jacobi SVD,
//! Hadamard transforms, summary statistics, and a persistent worker
//! pool ([`pool`]) behind [`parallel_rows`] / [`parallel_map`].

mod mat;
mod rng;
mod linalg;
pub mod pool;
mod stats;

pub use linalg::{hadamard_matrix, svd_jacobi, Svd};

/// Parallel map over an indexed domain on the persistent worker pool
/// ([`pool`]; the offline crate set has no rayon). Results come back in
/// input order. Items are claimed dynamically, so ragged per-item cost
/// load-balances across the pool.
pub fn parallel_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, workers: usize, f: F) -> Vec<T> {
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let base = slots.as_mut_ptr() as usize;
    pool::global().run_indexed(n, |i| {
        let v = f(i);
        // SAFETY: each task writes only slot `i` (disjoint), and
        // run_indexed blocks until every task has finished. The old value
        // is `None`, so overwriting without a drop is fine.
        unsafe { (base as *mut Option<T>).add(i).write(Some(v)) };
    });
    slots.into_iter().map(|s| s.expect("parallel_map slot")).collect()
}

/// Compute an `[m, n]` row-major buffer by splitting output rows into
/// contiguous chunks dispatched to the persistent worker pool ([`pool`]).
/// `kernel(r0, r1, out)` must fill `out` (zeroed, `(r1-r0)*n` long) with
/// rows `[r0, r1)`. Workers write disjoint slices of one allocation — no
/// per-worker buffers, no stitch copy, no per-call thread spawn. With
/// `workers <= 1` the kernel runs inline over the full range, so threaded
/// and single-threaded callers share one code path (and one
/// floating-point association order per row).
pub fn parallel_rows(
    m: usize,
    n: usize,
    workers: usize,
    kernel: impl Fn(usize, usize, &mut [f32]) + Sync,
) -> Vec<f32> {
    let mut data = vec![0.0f32; m * n];
    let workers = workers.max(1).min(m.max(1));
    if workers <= 1 || n == 0 {
        kernel(0, m, &mut data);
        return data;
    }
    let per = m.div_ceil(workers);
    let n_chunks = m.div_ceil(per);
    let base = data.as_mut_ptr() as usize;
    pool::global().run_indexed(n_chunks, |c| {
        let r0 = c * per;
        let r1 = (r0 + per).min(m);
        // SAFETY: chunk `c` owns rows [r0, r1) — the row ranges (and so
        // the `[r0*n, r1*n)` buffer ranges) are pairwise disjoint, and
        // run_indexed blocks until every chunk has finished.
        let out = unsafe {
            std::slice::from_raw_parts_mut((base as *mut f32).add(r0 * n), (r1 - r0) * n)
        };
        kernel(r0, r1, out);
    });
    data
}

/// Worker-lane count worth using for a kernel of `flops` fused
/// multiply-adds. Dispatching to the persistent pool costs on the order
/// of a condvar wakeup (vs ~tens of µs for the old per-call thread
/// spawn), so the threshold sits well below the old 2 MFLOP/worker —
/// small serving matmuls now scale too. Returns at least 1.
pub fn suggested_workers(flops: usize) -> usize {
    // ~0.5 MFLOP per lane amortizes a pool dispatch comfortably
    const FLOPS_PER_WORKER: usize = 1 << 19;
    if flops < 2 * FLOPS_PER_WORKER {
        return 1;
    }
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    hw.min(flops / FLOPS_PER_WORKER).max(1)
}

pub use mat::Mat;
pub use rng::Rng;
pub use stats::{mean, quantile, std_dev, Summary};
