//! Vectorized micro-kernel primitives shared by every f32 hot loop.
//!
//! The serving hot path (packed dequant-fused matmul, dense/merged
//! matmuls, the attention row kernel) used to run scalar inner loops.
//! These primitives restructure them as 8-wide unrolled multiply-add
//! lanes over `chunks_exact(8)` — a shape stable-Rust LLVM reliably
//! auto-vectorizes to AVX/NEON packed ops without a `std::simd` nightly
//! dependency or `target-cpu` flags (plain `a * b + acc`, **not**
//! `f32::mul_add`, which lowers to a libm call on targets without a
//! guaranteed FMA unit).
//!
//! ## The bitwise row-invariance contract
//!
//! Every primitive computes a fixed floating-point reduction DAG per
//! *logical row*: the 8 partial lanes accumulate chunk-by-chunk, the
//! scalar tail accumulates in order, and `reduce8` folds the lanes in
//! one fixed pairwise tree. [`dot4`] interleaves four rows for register
//! blocking but performs, per row, *exactly* the ops of [`dot`] in the
//! same order — so a row's result never depends on whether it was
//! computed in a 4-row micro-tile, as a remainder row, or in a different
//! [`super::parallel_rows`] chunk. That invariance is what keeps
//! batched == per-sequence forwards, chunked == one-shot prefill, and
//! threaded == single-threaded matmuls **bitwise** identical (pinned in
//! `tests/engine_api.rs`, `model::forward` unit tests, and
//! [`super::Mat`]'s threaded-parity tests).

/// Unroll width of every kernel: 8 f32 lanes (one AVX register / two
/// NEON registers).
pub const LANES: usize = 8;

/// Fold the 8 partial lanes in a fixed pairwise tree. One association
/// order everywhere — part of the row-invariance contract above.
#[inline(always)]
fn reduce8(l: [f32; LANES]) -> f32 {
    let a = l[0] + l[4];
    let b = l[1] + l[5];
    let c = l[2] + l[6];
    let d = l[3] + l[7];
    (a + c) + (b + d)
}

/// 8-wide unrolled dot product. `a` and `b` must be the same length.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f32; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (x, y) in (&mut ca).zip(&mut cb) {
        for l in 0..LANES {
            lanes[l] += x[l] * y[l];
        }
    }
    let mut tail = 0.0f32;
    for (&x, &y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += x * y;
    }
    reduce8(lanes) + tail
}

/// Four dot products of four LHS rows against one shared RHS row — the
/// register-blocked micro-tile: `b` is loaded once per chunk and feeds
/// four accumulator sets. Each returned value is **bitwise identical**
/// to `dot(a_i, b)` (same per-row op sequence; see the module contract).
// bitwise-pin: dot4_is_bitwise_four_dots
#[inline]
pub fn dot4(a0: &[f32], a1: &[f32], a2: &[f32], a3: &[f32], b: &[f32]) -> [f32; 4] {
    debug_assert!(a0.len() == b.len() && a1.len() == b.len());
    debug_assert!(a2.len() == b.len() && a3.len() == b.len());
    let mut l0 = [0.0f32; LANES];
    let mut l1 = [0.0f32; LANES];
    let mut l2 = [0.0f32; LANES];
    let mut l3 = [0.0f32; LANES];
    let mut cb = b.chunks_exact(LANES);
    let mut c0 = a0.chunks_exact(LANES);
    let mut c1 = a1.chunks_exact(LANES);
    let mut c2 = a2.chunks_exact(LANES);
    let mut c3 = a3.chunks_exact(LANES);
    let lhs = (&mut c0).zip(&mut c1).zip(&mut c2).zip(&mut c3);
    for (y, (((x0, x1), x2), x3)) in (&mut cb).zip(lhs) {
        for l in 0..LANES {
            l0[l] += x0[l] * y[l];
            l1[l] += x1[l] * y[l];
            l2[l] += x2[l] * y[l];
            l3[l] += x3[l] * y[l];
        }
    }
    let mut t = [0.0f32; 4];
    let yr = cb.remainder();
    let (r0, r1, r2, r3) = (c0.remainder(), c1.remainder(), c2.remainder(), c3.remainder());
    for (i, &y) in yr.iter().enumerate() {
        t[0] += r0[i] * y;
        t[1] += r1[i] * y;
        t[2] += r2[i] * y;
        t[3] += r3[i] * y;
    }
    [reduce8(l0) + t[0], reduce8(l1) + t[1], reduce8(l2) + t[2], reduce8(l3) + t[3]]
}

/// 8-wide unrolled `out[j] += alpha * x[j]`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    let mut co = out.chunks_exact_mut(LANES);
    let mut cx = x.chunks_exact(LANES);
    for (o, v) in (&mut co).zip(&mut cx) {
        for l in 0..LANES {
            o[l] += alpha * v[l];
        }
    }
    for (o, &v) in co.into_remainder().iter_mut().zip(cx.remainder()) {
        *o += alpha * v;
    }
}

/// The packed-backend group combine, 8-wide:
/// `out[j] += s[j] * t[j] + xsum * z[j]` — scales, the code partial sum,
/// and the zero-point term fused in one pass (see
/// `model::backend::PackedLoraLinear`).
#[inline]
pub fn scale_zero_combine(out: &mut [f32], s: &[f32], t: &[f32], xsum: f32, z: &[f32]) {
    debug_assert!(s.len() == out.len() && t.len() == out.len() && z.len() == out.len());
    let mut co = out.chunks_exact_mut(LANES);
    let mut cs = s.chunks_exact(LANES);
    let mut ct = t.chunks_exact(LANES);
    let mut cz = z.chunks_exact(LANES);
    for (((o, sv), tv), zv) in (&mut co).zip(&mut cs).zip(&mut ct).zip(&mut cz) {
        for l in 0..LANES {
            o[l] += sv[l] * tv[l] + xsum * zv[l];
        }
    }
    let (sr, tr, zr) = (cs.remainder(), ct.remainder(), cz.remainder());
    for (i, o) in co.into_remainder().iter_mut().enumerate() {
        *o += sr[i] * tr[i] + xsum * zr[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn randv(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.next_gaussian()).collect()
    }

    fn dot_naive(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(&x, &y)| (x as f64) * (y as f64)).sum()
    }

    #[test]
    fn dot_matches_naive_across_lengths() {
        let mut rng = Rng::seed(0xd07);
        // lengths straddling the 8-lane boundary, incl. 0 and tail-only
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 64, 100, 257] {
            let a = randv(n, &mut rng);
            let b = randv(n, &mut rng);
            let got = dot(&a, &b) as f64;
            let want = dot_naive(&a, &b);
            let scale = a.iter().map(|x| x.abs() as f64).sum::<f64>().max(1.0);
            assert!((got - want).abs() / scale < 1e-5, "n={n}: {got} vs {want}");
        }
    }

    /// The register-blocked 4-row micro-tile must be BITWISE the single-row
    /// dot — the invariance every bitwise-parity test in the repo rests on.
    #[test]
    fn dot4_is_bitwise_four_dots() {
        let mut rng = Rng::seed(0xd04);
        for n in [0usize, 1, 7, 8, 9, 31, 64, 100] {
            let rows: Vec<Vec<f32>> = (0..4).map(|_| randv(n, &mut rng)).collect();
            let b = randv(n, &mut rng);
            let got = dot4(&rows[0], &rows[1], &rows[2], &rows[3], &b);
            for (i, r) in rows.iter().enumerate() {
                assert_eq!(got[i].to_bits(), dot(r, &b).to_bits(), "n={n} row={i}");
            }
        }
    }

    #[test]
    fn axpy_matches_scalar() {
        let mut rng = Rng::seed(0xa27);
        for n in [0usize, 1, 5, 8, 13, 100] {
            let x = randv(n, &mut rng);
            let mut out = randv(n, &mut rng);
            let mut want = out.clone();
            let alpha = 0.37f32;
            for (w, &v) in want.iter_mut().zip(&x) {
                *w += alpha * v;
            }
            axpy(alpha, &x, &mut out);
            for (g, w) in out.iter().zip(&want) {
                assert!((g - w).abs() <= 1e-6, "n={n}");
            }
        }
    }

    #[test]
    fn scale_zero_combine_matches_scalar() {
        let mut rng = Rng::seed(0x5c2);
        for n in [0usize, 1, 7, 8, 9, 100] {
            let s = randv(n, &mut rng);
            let t = randv(n, &mut rng);
            let z = randv(n, &mut rng);
            let xsum = 1.25f32;
            let mut out = randv(n, &mut rng);
            let mut want = out.clone();
            for j in 0..n {
                want[j] += s[j] * t[j] + xsum * z[j];
            }
            scale_zero_combine(&mut out, &s, &t, xsum, &z);
            for (g, w) in out.iter().zip(&want) {
                assert!((g - w).abs() <= 1e-6, "n={n}");
            }
        }
    }
}
