//! Numerical linear algebra: one-sided Jacobi SVD and Hadamard transforms.
//!
//! The SVD drives the Weight-SVD (LoftQ-style) LQEC baseline and the
//! singular-vector-magnitude analysis of Fig. 4(c); the Hadamard matrix
//! drives the QuaRot-style rotation quantizer.

use super::{kernels, Mat};

/// Thin SVD result: `a ≈ u * diag(s) * vt` with `u: m×k`, `s: k`, `vt: k×n`,
/// `k = min(m, n)`, singular values sorted descending.
#[derive(Clone, Debug)]
pub struct Svd {
    pub u: Mat,
    pub s: Vec<f32>,
    pub vt: Mat,
}

impl Svd {
    /// Rank-`r` truncated reconstruction `u[:, :r] * diag(s[:r]) * vt[:r, :]`.
    pub fn truncate(&self, r: usize) -> Mat {
        let r = r.min(self.s.len());
        let m = self.u.rows();
        let n = self.vt.cols();
        let mut out = Mat::zeros(m, n);
        for k in 0..r {
            let sk = self.s[k];
            if sk == 0.0 {
                continue;
            }
            for i in 0..m {
                let uik = self.u[(i, k)] * sk;
                if uik == 0.0 {
                    continue;
                }
                // rank-1 update row: 8-wide unrolled axpy (see `kernels`)
                let vrow = self.vt.row(k);
                kernels::axpy(uik, vrow, out.row_mut(i));
            }
        }
        out
    }

    /// Split a rank-`r` truncation into LoRA factors `(L1: m×r, L2: n×r)`
    /// such that `L1 * L2^T` equals [`Svd::truncate`]`(r)`. Singular values
    /// are split symmetrically (`sqrt(s)` on each side), the LoRA convention
    /// used by LoftQ.
    pub fn lora_factors(&self, r: usize) -> (Mat, Mat) {
        let r = r.min(self.s.len());
        let m = self.u.rows();
        let n = self.vt.cols();
        let mut l1 = Mat::zeros(m, r);
        let mut l2 = Mat::zeros(n, r);
        for k in 0..r {
            let sq = self.s[k].max(0.0).sqrt();
            for i in 0..m {
                l1[(i, k)] = self.u[(i, k)] * sq;
            }
            for j in 0..n {
                l2[(j, k)] = self.vt[(k, j)] * sq;
            }
        }
        (l1, l2)
    }

    /// Effective numerical rank at relative tolerance `rtol`.
    pub fn effective_rank(&self, rtol: f32) -> usize {
        let smax = self.s.first().copied().unwrap_or(0.0);
        if smax <= 0.0 {
            return 0;
        }
        self.s.iter().filter(|&&s| s > rtol * smax).count()
    }
}

/// One-sided Jacobi SVD (Hestenes). Robust and dependency-free; `O(n^3)` per
/// sweep which is fine at the matrix sizes used by the simulated models
/// (≤ ~2048 per side).
pub fn svd_jacobi(a: &Mat) -> Svd {
    // Work on the tall orientation; transpose back at the end.
    if a.rows() < a.cols() {
        let svd = svd_jacobi(&a.t());
        return Svd { u: svd.vt.t(), s: svd.s, vt: svd.u.t() };
    }
    let m = a.rows();
    let n = a.cols();
    let mut u = a.clone(); // columns will be rotated into u * diag(s)
    let mut v = Mat::eye(n);

    let eps = 1e-9f64;
    let max_sweeps = 30;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Compute the 2x2 Gram entries for columns p, q.
                let mut app = 0.0f64;
                let mut aqq = 0.0f64;
                let mut apq = 0.0f64;
                for i in 0..m {
                    let up = u[(i, p)] as f64;
                    let uq = u[(i, q)] as f64;
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation zeroing the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let up = u[(i, p)] as f64;
                    let uq = u[(i, q)] as f64;
                    u[(i, p)] = (c * up - s * uq) as f32;
                    u[(i, q)] = (s * up + c * uq) as f32;
                }
                for i in 0..n {
                    let vp = v[(i, p)] as f64;
                    let vq = v[(i, q)] as f64;
                    v[(i, p)] = (c * vp - s * vq) as f32;
                    v[(i, q)] = (s * vp + c * vq) as f32;
                }
            }
        }
        if off < eps {
            break;
        }
    }

    // Column norms are the singular values.
    let mut order: Vec<usize> = (0..n).collect();
    let mut sigma = vec![0.0f32; n];
    for j in 0..n {
        let norm: f64 = (0..m).map(|i| (u[(i, j)] as f64).powi(2)).sum::<f64>().sqrt();
        sigma[j] = norm as f32;
    }
    order.sort_by(|&a, &b| sigma[b].partial_cmp(&sigma[a]).unwrap());

    let mut us = Mat::zeros(m, n);
    let mut vt = Mat::zeros(n, n);
    let mut s = vec![0.0f32; n];
    for (k, &j) in order.iter().enumerate() {
        s[k] = sigma[j];
        let inv = if sigma[j] > 1e-12 { 1.0 / sigma[j] } else { 0.0 };
        for i in 0..m {
            us[(i, k)] = u[(i, j)] * inv;
        }
        for i in 0..n {
            vt[(k, i)] = v[(i, j)];
        }
    }
    Svd { u: us, s, vt }
}

/// Normalized Walsh–Hadamard matrix of size `n` (power of two), `H H^T = I`.
pub fn hadamard_matrix(n: usize) -> Mat {
    assert!(n.is_power_of_two(), "hadamard size must be a power of two, got {n}");
    let mut h = Mat::from_vec(1, 1, vec![1.0]);
    let mut size = 1;
    while size < n {
        let mut next = Mat::zeros(size * 2, size * 2);
        for r in 0..size {
            for c in 0..size {
                let v = h[(r, c)];
                next[(r, c)] = v;
                next[(r, c + size)] = v;
                next[(r + size, c)] = v;
                next[(r + size, c + size)] = -v;
            }
        }
        h = next;
        size *= 2;
    }
    let scale = 1.0 / (n as f32).sqrt();
    h.scale(scale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn reconstruct(svd: &Svd) -> Mat {
        svd.truncate(svd.s.len())
    }

    #[test]
    fn svd_reconstructs_random() {
        let mut rng = Rng::seed(11);
        for &(m, n) in &[(8usize, 8usize), (12, 5), (5, 12), (16, 16)] {
            let a = Mat::randn(m, n, &mut rng);
            let svd = svd_jacobi(&a);
            let r = reconstruct(&svd);
            let rel = a.fro_dist(&r) / a.fro_norm();
            assert!(rel < 1e-4, "{m}x{n} rel={rel}");
        }
    }

    #[test]
    fn svd_singular_values_sorted() {
        let mut rng = Rng::seed(12);
        let a = Mat::randn(10, 7, &mut rng);
        let svd = svd_jacobi(&a);
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
    }

    #[test]
    fn svd_orthonormal_u() {
        let mut rng = Rng::seed(13);
        let a = Mat::randn(9, 6, &mut rng);
        let svd = svd_jacobi(&a);
        let gram = svd.u.t().matmul(&svd.u);
        let eye = Mat::eye(6);
        assert!(gram.fro_dist(&eye) < 1e-3);
    }

    #[test]
    fn svd_rank_one() {
        let u = Mat::from_vec(4, 1, vec![1.0, 2.0, 3.0, 4.0]);
        let v = Mat::from_vec(1, 3, vec![1.0, 0.5, -1.0]);
        let a = u.matmul(&v);
        let svd = svd_jacobi(&a);
        assert!(svd.s[0] > 1e-3);
        assert!(svd.s[1] < 1e-4, "rank-1 matrix should have one singular value, s={:?}", svd.s);
        let r1 = svd.truncate(1);
        assert!(a.fro_dist(&r1) / a.fro_norm() < 1e-4);
    }

    #[test]
    fn lora_factors_match_truncation() {
        let mut rng = Rng::seed(14);
        let a = Mat::randn(10, 8, &mut rng);
        let svd = svd_jacobi(&a);
        let r = 3;
        let (l1, l2) = svd.lora_factors(r);
        let rec = l1.matmul(&l2.t());
        assert!(rec.fro_dist(&svd.truncate(r)) < 1e-4);
    }

    #[test]
    fn hadamard_orthonormal() {
        for &n in &[1usize, 2, 4, 8, 16, 64] {
            let h = hadamard_matrix(n);
            let gram = h.matmul(&h.t());
            assert!(gram.fro_dist(&Mat::eye(n)) < 1e-4, "n={n}");
        }
    }
}
