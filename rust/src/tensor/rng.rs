//! Deterministic PCG64-style RNG (no external `rand` dependency).
//!
//! Every stochastic component in the crate (synthetic corpus generation,
//! weight init, adapter init, property tests) draws from this generator so
//! experiments are bit-reproducible given a seed.

/// PCG-XSH-RR 64/32 with a 128-bit-ish state emulated via two 64-bit LCGs.
/// Statistical quality is far beyond what the simulations here need.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// Cached second Box–Muller output.
    gauss_spare: Option<f32>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Rng {
    /// Seeded construction; distinct seeds give independent streams.
    pub fn seed(seed: u64) -> Self {
        let mut rng = Rng { state: 0, inc: (seed << 1) | 1, gauss_spare: None };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(0x853c49e6748fea9b ^ seed);
        rng.next_u32();
        rng
    }

    /// Derive an independent sub-stream (for per-worker RNGs).
    pub fn split(&mut self, tag: u64) -> Rng {
        let s = self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15);
        Rng::seed(s)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with f64 resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_f64() * n as f64) as usize % n
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f32 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u = 2.0 * self.next_f32() - 1.0;
            let v = 2.0 * self.next_f32() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let k = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * k);
                return u * k;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn sample_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len().max(1));
        }
        let mut x = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seed(42);
        let mut b = Rng::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn distinct_seeds_differ() {
        let mut a = Rng::seed(1);
        let mut b = Rng::seed(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut rng = Rng::seed(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Rng::seed(8);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.next_gaussian() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_in_range() {
        let mut rng = Rng::seed(9);
        for _ in 0..1000 {
            assert!(rng.below(17) < 17);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seed(10);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
