//! Experiment catalog: every table and figure of the paper's evaluation,
//! regenerated end-to-end over the simulated stack (DESIGN.md §5 maps each
//! id to paper table/figure and modules).
//!
//! Run via `rilq experiment <id>` (or `all`); each writes
//! `reports/<id>.md` + `.csv`.

pub mod e2e;
pub mod figures;
pub mod pipeline;
pub mod tables_ablation;
pub mod tables_main;
pub mod tables_scale;

use anyhow::{anyhow, Result};

use crate::report::Table;
use crate::runtime::Runtime;

use pipeline::Lab;

/// One experiment: id, paper reference, runner.
pub struct Experiment {
    pub id: &'static str,
    pub paper_ref: &'static str,
    pub run: fn(&mut Lab) -> Result<Vec<Table>>,
}

/// The full catalog, in DESIGN.md §5 order.
pub fn catalog() -> Vec<Experiment> {
    // ordered cheap->expensive so partial runs still produce reports
    vec![
        Experiment { id: "fig3b", paper_ref: "Fig. 3(b)", run: figures::fig3b },
        Experiment { id: "fig3c", paper_ref: "Fig. 3(c)", run: figures::fig3c },
        Experiment { id: "table12", paper_ref: "Table 12", run: tables_scale::table12 },
        Experiment { id: "table7", paper_ref: "Table 7", run: tables_ablation::table7 },
        Experiment { id: "table11", paper_ref: "Table 11", run: tables_scale::table11 },
        Experiment { id: "fig4a", paper_ref: "Fig. 4(a)", run: figures::fig4a },
        Experiment { id: "fig4b", paper_ref: "Fig. 4(b)", run: figures::fig4b },
        Experiment { id: "fig4c", paper_ref: "Fig. 4(c)", run: figures::fig4c },
        Experiment { id: "fig3a", paper_ref: "Fig. 3(a)", run: figures::fig3a },
        Experiment { id: "table4", paper_ref: "Table 4", run: tables_ablation::table4 },
        Experiment { id: "table5", paper_ref: "Table 5", run: tables_ablation::table5 },
        Experiment { id: "table6", paper_ref: "Table 6", run: tables_ablation::table6 },
        Experiment { id: "table10", paper_ref: "Table 10", run: tables_scale::table10 },
        Experiment { id: "table1", paper_ref: "Table 1", run: tables_main::table1 },
        Experiment { id: "table8", paper_ref: "Table 8", run: tables_ablation::table8 },
        Experiment { id: "table2", paper_ref: "Table 2", run: tables_main::table2 },
        Experiment { id: "table3", paper_ref: "Table 3", run: tables_main::table3 },
        Experiment { id: "table9", paper_ref: "Table 9", run: tables_scale::table9 },
        Experiment { id: "e2e", paper_ref: "end-to-end driver", run: e2e::run },
    ]
}

/// Run one experiment id (or `all`), saving reports under `reports/`.
pub fn run_experiment(rt: &Runtime, id: &str, fast: bool) -> Result<()> {
    let cat = catalog();
    let targets: Vec<&Experiment> = if id == "all" {
        cat.iter().collect()
    } else {
        vec![cat
            .iter()
            .find(|e| e.id == id)
            .ok_or_else(|| anyhow!("unknown experiment '{id}' (see `rilq list`)"))?]
    };
    for exp in targets {
        let mut lab = Lab::new(rt);
        if fast {
            lab.calib.max_steps = 25;
            lab.calib.n_samples = 32;
        }
        let t0 = std::time::Instant::now();
        log::info!("running {} ({})", exp.id, exp.paper_ref);
        let tables = (exp.run)(&mut lab)?;
        for (i, t) in tables.iter().enumerate() {
            let stem = if tables.len() == 1 {
                exp.id.to_string()
            } else {
                format!("{}_{}", exp.id, i)
            };
            t.save("reports", &stem)?;
            println!("{}", t.to_markdown());
        }
        println!(
            "[{}] done in {:.1}s -> reports/{}*.md",
            exp.id,
            t0.elapsed().as_secs_f64(),
            exp.id
        );
    }
    Ok(())
}
