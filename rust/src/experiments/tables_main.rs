//! Main results: Table 1 (direct error compensation), Table 2
//! (task-specific fine-tuning), Table 3 (QA-LoRA integration).

use anyhow::Result;

use crate::coordinator::driver::Driver;
use crate::lqec::{AdapterSet, GroupedAdapterSet};
use crate::model::forward::effective_weights;
use crate::model::{ModelDims, StudentWeights, TeacherParams};
use crate::report::table::f;
use crate::report::Table;

use super::pipeline::{EvalBundle, Lab};

fn bundle_cells(b: &EvalBundle) -> Vec<String> {
    let mut row: Vec<String> = b.task_accs.iter().map(|(_, a)| f(a * 100.0, 2)).collect();
    row.push(f(b.avg_acc * 100.0, 2));
    row.push(f(b.ppl_wiki, 2));
    row.push(f(b.ppl_c4, 2));
    row
}

const HDRS: [&str; 11] = [
    "method", "bits", "RILQ", "WG", "PIQA", "HS", "Arc-c", "Arc-e", "Avg", "Wiki2-PPL", "C4-PPL",
];

/// Table 1: direct error compensation across quantizers and bit-widths.
pub fn table1(lab: &mut Lab) -> Result<Vec<Table>> {
    let (dims, teacher, _) = lab.teacher("small")?;
    let rank = 16;
    let mut t = Table::new("Table 1 — direct error compensation (config=small)", &HDRS);

    // fp16 baseline
    let base = {
        let sc = lab.teacher_scorer(&dims, &teacher)?;
        lab.evaluate(&sc, &dims)?
    };
    let mut row = vec!["16-bit baseline".to_string(), "16".into(), "".into()];
    row.extend(bundle_cells(&base));
    t.row(row);

    // LoftQ (NF2 + Weight-SVD): the paper's collapsing baseline
    {
        let (st, ad_svd) = lab.loftq(&dims, &teacher, "nf", 2, rank, 1)?;
        let minus = {
            let sc = lab.student_scorer(&dims, &teacher, &st, &ad_svd)?;
            lab.evaluate(&sc, &dims)?
        };
        let mut row = vec!["LoftQ".to_string(), "2".into(), "-".into()];
        row.extend(bundle_cells(&minus));
        t.row(row);
        // RILQ continues from the SVD init (paper Case 1 procedure)
        let (ad, _) = lab.compensate(&dims, &teacher, &st, &ad_svd, "model_gt", "loftq2-svdinit")?;
        let plus = {
            let sc = lab.student_scorer(&dims, &teacher, &st, &ad)?;
            lab.evaluate(&sc, &dims)?
        };
        let mut row = vec!["LoftQ".to_string(), "2".into(), "yes".into()];
        row.extend(bundle_cells(&plus));
        t.row(row);
    }

    // advanced quantizers at W2 and W3
    for bits in [2u8, 3] {
        for qname in ["omniquant", "quip", "quarot"] {
            let student = lab.quantize(&dims, &teacher, qname, bits)?;
            let zeros = AdapterSet::zeros(&dims, rank);
            let minus = {
                let sc = lab.student_scorer(&dims, &teacher, &student, &zeros)?;
                lab.evaluate(&sc, &dims)?
            };
            let mut row = vec![qname.to_string(), bits.to_string(), "-".into()];
            row.extend(bundle_cells(&minus));
            t.row(row);

            let init = lab.default_adapters(&dims, rank);
            let (ad, _) = lab.compensate(
                &dims,
                &teacher,
                &student,
                &init,
                "model_gt",
                &format!("{qname}{bits}"),
            )?;
            let plus = {
                let sc = lab.student_scorer(&dims, &teacher, &student, &ad)?;
                lab.evaluate(&sc, &dims)?
            };
            let mut row = vec![qname.to_string(), bits.to_string(), "yes".into()];
            row.extend(bundle_cells(&plus));
            t.row(row);
        }
    }
    t.note("paper shape: RILQ lifts every W2 quantizer by a large margin; W3 gains are small");
    Ok(vec![t])
}

/// Task-specific fine-tuning helper: FT adapters with GT loss on task data
/// starting from `init`, then evaluate the target task.
fn fine_tune(
    lab: &Lab,
    dims: &ModelDims,
    teacher: &TeacherParams,
    student: &StudentWeights,
    init: &AdapterSet,
    task: &str,
    steps: usize,
) -> Result<AdapterSet> {
    let seqs = lab.ft_seqs(dims, task, 16);
    let batches: Vec<Vec<Vec<u32>>> = seqs.chunks(dims.batch).map(|c| c.to_vec()).collect();
    let batches: Vec<_> = batches
        .into_iter()
        .filter(|b| b.len() == dims.batch)
        .collect();
    let mut cfg = lab.calib.clone();
    cfg.max_steps = steps;
    cfg.patience = steps; // fixed-epoch FT
    let res = Driver::new(lab.rt).calibrate_on(dims, teacher, student, init, "gt", &cfg, &batches)?;
    AdapterSet::from_flat(dims, init.rank, &res.adapters_flat)
}

/// Table 2: task-specific fine-tuning (CSQA suite + gsm-sim) with and
/// without RILQ initialization.
pub fn table2(lab: &mut Lab) -> Result<Vec<Table>> {
    let (dims, teacher, _) = lab.teacher("small")?;
    let rank = 16;
    let ft_steps = lab.calib.max_steps.min(40);
    let mut t = Table::new(
        "Table 2 — task-specific fine-tuning (config=small, W2)",
        &["method", "RILQ-init", "PIQA", "Arc-c", "Arc-e", "GSM-sim"],
    );

    // 16-bit LoRA fine-tuning reference: student weights = fp teacher
    {
        let fp_student = StudentWeights {
            q: teacher
                .linears
                .iter()
                .map(|ls| {
                    ls.iter()
                        .map(|w| crate::quant::QuantResult::Dense {
                            w: w.clone(),
                            bits: 16,
                            storage_bytes: w.len() * 2,
                        })
                        .collect()
                })
                .collect(),
            quantizer: "fp16".into(),
            bits: 16,
        };
        let init = lab.default_adapters(&dims, rank);
        let ft = fine_tune(lab, &dims, &teacher, &fp_student, &init, "csqa", ft_steps)?;
        let sc = lab.student_scorer(&dims, &teacher, &fp_student, &ft)?;
        let ev = lab.evaluate(&sc, &dims)?;
        let ft_g = fine_tune(lab, &dims, &teacher, &fp_student, &init, "gsm", ft_steps)?;
        let sc_g = lab.student_scorer(&dims, &teacher, &fp_student, &ft_g)?;
        let gsm = lab.evaluate_gsm(&sc_g, &dims)?;
        let acc = |l: &str| {
            ev.task_accs
                .iter()
                .find(|(n, _)| *n == l)
                .map(|(_, a)| f(a * 100.0, 2))
                .unwrap_or_default()
        };
        t.row(vec![
            "16-bit LoRA FT".into(),
            "".into(),
            acc("PIQA"),
            acc("Arc-c"),
            acc("Arc-e"),
            f(gsm * 100.0, 2),
        ]);
    }

    for qname in ["omniquant", "quip"] {
        let student = lab.quantize(&dims, &teacher, qname, 2)?;
        for rilq_init in [false, true] {
            let init = if rilq_init {
                let d = lab.default_adapters(&dims, rank);
                let (ad, _) = lab.compensate(
                    &dims,
                    &teacher,
                    &student,
                    &d,
                    "model_gt",
                    &format!("{qname}2"),
                )?;
                ad
            } else {
                lab.default_adapters(&dims, rank)
            };
            let ft = fine_tune(lab, &dims, &teacher, &student, &init, "csqa", ft_steps)?;
            let sc = lab.student_scorer(&dims, &teacher, &student, &ft)?;
            let ev = lab.evaluate(&sc, &dims)?;
            let ft_g = fine_tune(lab, &dims, &teacher, &student, &init, "gsm", ft_steps)?;
            let sc_g = lab.student_scorer(&dims, &teacher, &student, &ft_g)?;
            let gsm = lab.evaluate_gsm(&sc_g, &dims)?;
            let acc = |l: &str| {
                ev.task_accs
                    .iter()
                    .find(|(n, _)| *n == l)
                    .map(|(_, a)| f(a * 100.0, 2))
                    .unwrap_or_default()
            };
            t.row(vec![
                qname.to_string(),
                if rilq_init { "yes".into() } else { "-".into() },
                acc("PIQA"),
                acc("Arc-c"),
                acc("Arc-e"),
                f(gsm * 100.0, 2),
            ]);
        }
    }
    t.note("paper shape: RILQ initialization consistently improves downstream fine-tuning");
    Ok(vec![t])
}

/// Table 3: QA-LoRA integration — adapters constrained to the group-merge
/// form, RILQ-tuned then *merged exactly* into the quantized zero-points
/// (adapter-free inference).
pub fn table3(lab: &mut Lab) -> Result<Vec<Table>> {
    let (dims, teacher, _) = lab.teacher("small")?;
    let rank = 16;
    let student = lab.quantize(&dims, &teacher, "omniquant", 2)?;
    let mut t = Table::new(
        "Table 3 — QA-LoRA group-merged inference with RILQ (OmniQuant-sim W2)",
        &["RILQ", "CSQA avg", "Wiki2-PPL", "C4-PPL", "GSM-sim (after FT)"],
    );

    for rilq in [false, true] {
        // 1. obtain adapters (RILQ or none), 2. project to grouped form,
        // 3. merge exactly into zero-points, 4. evaluate adapter-free.
        let merged_student = {
            let mut st = student.clone();
            if rilq {
                let init = lab.default_adapters(&dims, rank);
                let (ad, _) =
                    lab.compensate(&dims, &teacher, &student, &init, "model_gt", "omni2")?;
                let grouped = GroupedAdapterSet::project(&dims, &ad);
                for fam in 0..st.q.len() {
                    for l in 0..dims.n_layers {
                        if let crate::quant::QuantResult::Scalar(q) = &mut st.q[fam][l] {
                            grouped.merge_into(fam, l, q);
                        }
                    }
                }
            }
            st
        };
        let zeros = AdapterSet::zeros(&dims, rank);
        let sc = lab.student_scorer(&dims, &teacher, &merged_student, &zeros)?;
        let ev = lab.evaluate(&sc, &dims)?;

        // FT: gsm fine-tune grouped adapters (expand for training), merge
        let gsm = {
            let init = if rilq {
                let d = lab.default_adapters(&dims, rank);
                let (ad, _) =
                    lab.compensate(&dims, &teacher, &student, &d, "model_gt", "omni2")?;
                GroupedAdapterSet::project(&dims, &ad).expand(&dims)
            } else {
                AdapterSet::zeros(&dims, rank)
            };
            let steps = lab.calib.max_steps.min(120);
            let ft = fine_tune(lab, &dims, &teacher, &student, &init, "gsm", steps)?;
            // project + merge for adapter-free eval
            let grouped = GroupedAdapterSet::project(&dims, &ft);
            let mut st = student.clone();
            for fam in 0..st.q.len() {
                for l in 0..dims.n_layers {
                    if let crate::quant::QuantResult::Scalar(q) = &mut st.q[fam][l] {
                        grouped.merge_into(fam, l, q);
                    }
                }
            }
            let _ = effective_weights(&st, None);
            let sc = lab.student_scorer(&dims, &teacher, &st, &zeros)?;
            lab.evaluate_gsm(&sc, &dims)?
        };

        t.row(vec![
            if rilq { "yes".into() } else { "-".into() },
            f(ev.avg_acc * 100.0, 2),
            f(ev.ppl_wiki, 2),
            f(ev.ppl_c4, 2),
            f(gsm * 100.0, 2),
        ]);
    }
    t.note("adapters are merged exactly into per-group zero-points (lqec::qalora merge test)");
    Ok(vec![t])
}
