//! Scaling / budget / analysis tables: Tables 9–12.

use anyhow::Result;

use crate::coordinator::driver::Driver;
use crate::lqec::AdapterSet;
use crate::model::ModelDims;
use crate::report::table::f;
use crate::report::Table;

use super::pipeline::{fp16_bytes, quantized_model_bytes, Lab};

/// Table 9: error compensation across model sizes (LLaMA-2 7B/13B/70B →
/// tiny/small/base), LoftQ-style NF2 base.
pub fn table9(lab: &mut Lab) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Table 9 — RILQ across model scales (NF2; LoftQ-style base)",
        &["config", "params", "RILQ", "Wiki2-PPL", "C4-PPL"],
    );
    // `base` is omitted from the recorded run: a memory-growth issue in the
    // per-step literal path at base scale (~250 MB/step transient) exhausts
    // the runner during its pretrain. tiny/small cover a 13x param span.
    let configs: Vec<&str> = match std::env::var("RILQ_TABLE9_CONFIGS") {
        Ok(c) => c.split(',').map(|s| Box::leak(s.to_string().into_boxed_str()) as &str).collect(),
        Err(_) => vec!["tiny", "small"],
    };
    for config in configs {
        if !lab.rt.manifest.configs.contains_key(config) {
            continue;
        }
        let (dims, teacher, _) = lab.teacher(config)?;
        let rank = *lab.rt.manifest.ranks[config].iter().min().unwrap();
        let (st, ad_svd) = lab.loftq(&dims, &teacher, "nf", 2, rank, 1)?;
        let minus = {
            let sc = lab.student_scorer(&dims, &teacher, &st, &ad_svd)?;
            lab.evaluate(&sc, &dims)?
        };
        t.row(vec![
            config.into(),
            format!("{:.1}M", dims.params_count() as f64 / 1e6),
            "-".into(),
            f(minus.ppl_wiki, 2),
            f(minus.ppl_c4, 2),
        ]);
        let (ad, _) = lab.compensate(&dims, &teacher, &st, &ad_svd, "model_gt", "nf2-svdinit")?;
        let plus = {
            let sc = lab.student_scorer(&dims, &teacher, &st, &ad)?;
            lab.evaluate(&sc, &dims)?
        };
        t.row(vec![
            config.into(),
            format!("{:.1}M", dims.params_count() as f64 / 1e6),
            "yes".into(),
            f(plus.ppl_wiki, 2),
            f(plus.ppl_c4, 2),
        ]);
    }
    t.note("paper shape: RILQ recovers PPL at every scale");
    Ok(vec![t])
}

/// Table 10: calibration budget (samples × optimization steps) vs PPL and
/// wall time. The paper sweeps samples × sequence length; sequence length
/// is baked into the static HLO shapes here, so the token-budget axis is
/// swept via samples × steps (documented in DESIGN.md).
pub fn table10(lab: &mut Lab) -> Result<Vec<Table>> {
    let (dims, teacher, _) = lab.teacher("small")?;
    let rank = 16;
    let student = lab.quantize(&dims, &teacher, "rtn", 2)?;
    let mut t = Table::new(
        "Table 10 — calibration budget vs PPL and wall time (RTN W2, rank=16)",
        &["samples", "steps", "Wiki2-PPL", "C4-PPL", "wall (s)"],
    );

    // no compensation baseline
    {
        let zeros = AdapterSet::zeros(&dims, rank);
        let sc = lab.student_scorer(&dims, &teacher, &student, &zeros)?;
        let ev = lab.evaluate(&sc, &dims)?;
        t.row(vec![
            "-".into(),
            "0".into(),
            f(ev.ppl_wiki, 2),
            f(ev.ppl_c4, 2),
            "0.0".into(),
        ]);
    }
    // SVD reference
    {
        let t0 = std::time::Instant::now();
        let (st, ad) = lab.loftq(&dims, &teacher, "rtn", 2, rank, 1)?;
        let wall = t0.elapsed().as_secs_f64();
        let sc = lab.student_scorer(&dims, &teacher, &st, &ad)?;
        let ev = lab.evaluate(&sc, &dims)?;
        t.row(vec![
            "SVD".into(),
            "-".into(),
            f(ev.ppl_wiki, 2),
            f(ev.ppl_c4, 2),
            f(wall, 1),
        ]);
    }

    let base_steps = lab.calib.max_steps;
    for (samples, steps) in [
        (16usize, base_steps / 2),
        (32, base_steps),
        (64, base_steps),
        (64, base_steps * 2),
    ] {
        let mut cfg = lab.calib.clone();
        cfg.n_samples = samples;
        cfg.max_steps = steps;
        cfg.patience = steps; // fixed budget, no early stop
        let init = lab.default_adapters(&dims, rank);
        let res =
            Driver::new(lab.rt).calibrate(&dims, &teacher, &student, &init, "model_gt", &cfg)?;
        let ad = AdapterSet::from_flat(&dims, rank, &res.adapters_flat)?;
        let sc = lab.student_scorer(&dims, &teacher, &student, &ad)?;
        let ev = lab.evaluate(&sc, &dims)?;
        t.row(vec![
            samples.to_string(),
            steps.to_string(),
            f(ev.ppl_wiki, 2),
            f(ev.ppl_c4, 2),
            f(res.wall_secs, 1),
        ]);
    }
    t.note(
        "paper shape: PPL improves with budget with diminishing returns; default budget suffices",
    );
    Ok(vec![t])
}

/// Table 11: Model-Loss optimization target — final decoder activation vs
/// logits.
pub fn table11(lab: &mut Lab) -> Result<Vec<Table>> {
    let (dims, teacher, _) = lab.teacher("small")?;
    let rank = 16;
    let student = lab.quantize(&dims, &teacher, "omniquant", 2)?;
    let mut t = Table::new(
        "Table 11 — Model-Loss target: final activation vs logits (OmniQuant-sim W2)",
        &["target", "Wiki2-PPL", "C4-PPL"],
    );
    for (label, scope) in [("final decoder activation", "model"), ("logits", "model_logit")] {
        let init = lab.default_adapters(&dims, rank);
        let (ad, _) = lab.compensate(&dims, &teacher, &student, &init, scope, "omni2")?;
        let sc = lab.student_scorer(&dims, &teacher, &student, &ad)?;
        let ev = lab.evaluate(&sc, &dims)?;
        t.row(vec![label.into(), f(ev.ppl_wiki, 2), f(ev.ppl_c4, 2)]);
    }
    t.note("paper shape: near-tie; the cheaper final-activation target is the default");
    Ok(vec![t])
}

/// Table 12: fine-tuning memory analysis — measured on the simulated
/// configs and extrapolated analytically to LLaMA-2-7B geometry.
pub fn table12(lab: &mut Lab) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Table 12 — fine-tuning memory (weights + adapter grads + Adam + activations)",
        &["model", "method", "weights", "ad grads", "optim", "act", "total"],
    );

    let gib = |b: f64| format!("{:.3} GiB", b / (1u64 << 30) as f64);
    let mib = |b: f64| format!("{:.2} MiB", b / (1 << 20) as f64);

    // measured on `small`
    {
        let (dims, teacher, _) = lab.teacher("small")?;
        let student = lab.quantize(&dims, &teacher, "rtn", 2)?;
        let rank = 16;
        let ad = AdapterSet::zeros(&dims, rank);
        let ad_bytes = (ad.params_count() * 4) as f64;
        let act_bytes = (dims.batch * dims.seq * dims.d_model * dims.n_layers * 4) as f64;
        for (method, weights) in [
            ("FP16 LoRA", fp16_bytes(&dims) as f64),
            ("W2A16 QLoRA", quantized_model_bytes(&dims, &student) as f64),
            ("W2A16 RILQ", quantized_model_bytes(&dims, &student) as f64),
        ] {
            t.row(vec![
                "small (measured)".into(),
                method.into(),
                mib(weights),
                mib(ad_bytes),
                mib(2.0 * ad_bytes),
                mib(act_bytes),
                mib(weights + 3.0 * ad_bytes + act_bytes),
            ]);
        }
    }

    // analytic LLaMA-2-7B geometry (paper's Table 12 setting, rank 64)
    {
        let dims = ModelDims {
            name: "llama2-7b".into(),
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            d_ff: 11008,
            vocab: 32000,
            seq: 384,
            batch: 16,
            group_size: 64,
        };
        let rank = 64;
        let lin_params: usize = crate::model::LINEARS
            .iter()
            .map(|n| {
                let (di, do_) = dims.linear_dims(n);
                di * do_ * dims.n_layers
            })
            .sum();
        let other = dims.params_count() - lin_params;
        let ad_params: usize = crate::model::LINEARS
            .iter()
            .map(|n| {
                let (di, do_) = dims.linear_dims(n);
                (di + do_) * rank * dims.n_layers
            })
            .sum();
        let ad_bytes = (ad_params * 4) as f64;
        let act = (dims.batch * dims.seq * dims.d_model * dims.n_layers) as f64; // fp8-ish ckpt
        for (method, weights) in [
            ("FP16 LoRA", ((lin_params + other) * 2) as f64),
            ("W2A16 QLoRA", lin_params as f64 * 0.25 * 1.25 + (other * 2) as f64),
            ("W2A16 RILQ", lin_params as f64 * 0.25 * 1.25 + (other * 2) as f64),
        ] {
            t.row(vec![
                "LLaMA-2-7B (analytic)".into(),
                method.into(),
                gib(weights),
                gib(ad_bytes),
                gib(2.0 * ad_bytes),
                gib(act),
                gib(weights + 3.0 * ad_bytes + act),
            ]);
        }
    }
    t.note(
        "paper shape: W2 fine-tuning (QLoRA = RILQ) needs ~1/4 of FP16 LoRA's memory; \
         RILQ adds nothing over QLoRA",
    );
    Ok(vec![t])
}
