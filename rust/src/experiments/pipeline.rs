//! The shared experiment pipeline ("Lab"): pretrained teachers, quantized
//! students, compensated adapters, and evaluation bundles — all cached
//! under `runs/` so the dozens of table/figure reproductions share work.

use anyhow::{anyhow, Result};

use crate::coordinator::driver::{CalibConfig, CalibResult, Driver, PretrainConfig};
use crate::coordinator::RunCache;
use crate::data::tasks::{gen_gsm, gen_mc, GsmItem, McItem, TaskKind};
use crate::data::{Corpus, Profile, Vocab};
use crate::eval::{gsm_accuracy, mc_accuracy, perplexity, HloScorer, Scorer};
use crate::lqec::svd_init::{adapters_from_presvd, loftq_model, loftq_presvd};
use crate::lqec::AdapterSet;
use crate::model::backend::BackendKind;
use crate::model::forward::CalibStats;
use crate::model::weights::TensorFile;
use crate::model::{ModelDims, StudentWeights, TeacherParams, LINEARS};
use crate::quant::{by_name, CalibCtx};
use crate::runtime::Runtime;
use crate::tensor::Rng;

/// Evaluation bundle sizes (scaled-down analogues of the paper's setup).
pub const EVAL_SEQS: usize = 12;
pub const MC_ITEMS: usize = 40;
pub const GSM_ITEMS: usize = 40;

/// Result row every experiment shares: per-task accuracy + PPLs.
#[derive(Clone, Debug)]
pub struct EvalBundle {
    pub task_accs: Vec<(&'static str, f64)>,
    pub avg_acc: f64,
    pub ppl_wiki: f64,
    pub ppl_c4: f64,
}

pub struct Lab<'r> {
    pub rt: &'r Runtime,
    pub cache: RunCache,
    pub seed: u64,
    /// override for calibration budget (None = default)
    pub calib: CalibConfig,
    pub pretrain_steps_override: Option<usize>,
    /// execution engine for student evaluation (CLI `--backend`); see
    /// [`crate::model::backend`]
    pub backend: BackendKind,
    /// in-memory cache of single-iteration LoftQ residual SVDs, shared by
    /// the rank sweeps (Fig. 3(a), Tables 4/5/9)
    svd_cache: std::cell::RefCell<
        std::collections::HashMap<
            (String, String, u8),
            std::rc::Rc<(StudentWeights, Vec<Vec<crate::tensor::Svd>>)>,
        >,
    >,
}

impl<'r> Lab<'r> {
    pub fn new(rt: &'r Runtime) -> Lab<'r> {
        let mut calib = CalibConfig::default();
        calib.max_steps = 40;
        calib.n_samples = 64;
        calib.patience = 20;
        calib.lr = 2e-3;
        Lab {
            rt,
            cache: RunCache::new("runs"),
            seed: 20250710,
            calib,
            pretrain_steps_override: None,
            backend: BackendKind::Dense,
            svd_cache: Default::default(),
        }
    }

    pub fn dims(&self, config: &str) -> Result<ModelDims> {
        Ok(self.rt.manifest.dims(config)?.clone())
    }

    // ---------------------------------------------------------------------
    // stage: pretrained teacher (cached)
    // ---------------------------------------------------------------------

    pub fn pretrain_config(&self, dims: &ModelDims) -> PretrainConfig {
        let steps = self.pretrain_steps_override.unwrap_or(match dims.name.as_str() {
            "tiny" => 300,
            "small" => 700,
            _ => 250,
        });
        PretrainConfig { steps, seed: self.seed ^ 0x11, ..Default::default() }
    }

    /// Pretrained teacher for a config (runs once, cached on disk).
    /// Returns (params, loss curve).
    pub fn teacher(&self, config: &str) -> Result<(ModelDims, TeacherParams, Vec<f32>)> {
        let dims = self.dims(config)?;
        let pcfg = self.pretrain_config(&dims);
        let key = format!(
            "teacher:{config}:steps={}:lr={}:seed={}:v2",
            pcfg.steps, pcfg.lr, pcfg.seed
        );
        let tf = self.cache.get_or_compute(&key, || {
            log::info!("pretraining {config} teacher ({} steps)…", pcfg.steps);
            let mut rng = Rng::seed(self.seed ^ 0xbeef);
            let init = TeacherParams::init(&dims, &mut rng);
            let (trained, losses) = Driver::new(self.rt).pretrain(&dims, &init, &pcfg)?;
            let mut tf = TensorFile::new();
            for (name, buf) in crate::runtime::bindings::teacher_names()
                .iter()
                .zip(trained.to_flat())
            {
                tf.insert(format!("p.{name}"), vec![buf.len()], buf);
            }
            tf.insert("losses", vec![losses.len()], losses);
            Ok(tf)
        })?;
        let flat: Vec<Vec<f32>> = crate::runtime::bindings::teacher_names()
            .iter()
            .map(|n| tf.get(&format!("p.{n}")).map(|t| t.1.clone()))
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| anyhow!("corrupt teacher cache"))?;
        let losses = tf.get("losses").map(|t| t.1.clone()).unwrap_or_default();
        Ok((dims.clone(), TeacherParams::from_flat(&dims, &flat)?, losses))
    }

    // ---------------------------------------------------------------------
    // stage: quantized student
    // ---------------------------------------------------------------------

    /// Calibration activation statistics (for OmniQuant/GPTQ/QuaRot).
    pub fn calib_stats(&self, dims: &ModelDims, teacher: &TeacherParams) -> CalibStats {
        let mut corpus = Corpus::new(
            Vocab::new(dims.vocab, self.seed ^ 0x11),
            Profile::C4Sim,
            self.seed ^ 0xca11b,
        );
        let seqs: Vec<Vec<u32>> = (0..8).map(|_| corpus.sample_seq(dims.seq)).collect();
        CalibStats::collect(dims, teacher, &seqs, 128)
    }

    /// Quantize the teacher with a named quantizer.
    pub fn quantize(
        &self,
        dims: &ModelDims,
        teacher: &TeacherParams,
        quantizer: &str,
        bits: u8,
    ) -> Result<StudentWeights> {
        let q = by_name(quantizer, bits, dims.group_size)
            .ok_or_else(|| anyhow!("unknown quantizer {quantizer}"))?;
        let needs_calib = matches!(quantizer, "omniquant" | "gptq" | "quarot");
        let stats = if needs_calib {
            Some(self.calib_stats(dims, teacher))
        } else {
            None
        };
        let seed = self.seed;
        Ok(StudentWeights::quantize(dims, teacher, q.as_ref(), &|f, l| match &stats {
            Some(s) => CalibCtx {
                x_sq_mean: Some(s.x_sq_mean[f][l].clone()),
                x_samples: Some(s.samples[f][l].clone()),
                seed,
            },
            None => CalibCtx::with_seed(seed),
        }))
    }

    /// LoftQ (iterative Weight-SVD) student + adapters. `iters == 1` uses
    /// a rank-independent residual SVD cached in memory, so rank sweeps
    /// cost one SVD pass per (quantizer, bits); `iters > 1` runs the full
    /// alternating refinement.
    pub fn loftq(
        &self,
        dims: &ModelDims,
        teacher: &TeacherParams,
        quantizer: &str,
        bits: u8,
        rank: usize,
        iters: usize,
    ) -> Result<(StudentWeights, AdapterSet)> {
        let q = by_name(quantizer, bits, dims.group_size)
            .ok_or_else(|| anyhow!("unknown quantizer {quantizer}"))?;
        let seed = self.seed;
        if iters > 1 {
            return Ok(loftq_model(
                dims,
                teacher,
                q.as_ref(),
                &|_, _| CalibCtx::with_seed(seed),
                rank,
                iters,
            ));
        }
        let key = (dims.name.clone(), quantizer.to_string(), bits);
        let entry = {
            let cached = self.svd_cache.borrow().get(&key).cloned();
            match cached {
                Some(e) => e,
                None => {
                    let e = std::rc::Rc::new(loftq_presvd(
                        dims,
                        teacher,
                        q.as_ref(),
                        &|_, _| CalibCtx::with_seed(seed),
                    ));
                    self.svd_cache.borrow_mut().insert(key, e.clone());
                    e
                }
            }
        };
        let adapters = adapters_from_presvd(dims, &entry.1, rank);
        Ok((entry.0.clone(), adapters))
    }

    // ---------------------------------------------------------------------
    // stage: LQEC calibration (cached)
    // ---------------------------------------------------------------------

    /// Gradient-based compensation with a given loss scope (RILQ =
    /// "model_gt"). Adapters start from `init` (default-init or SVD-init).
    pub fn compensate(
        &self,
        dims: &ModelDims,
        teacher: &TeacherParams,
        student: &StudentWeights,
        init: &AdapterSet,
        scope: &str,
        cache_tag: &str,
    ) -> Result<(AdapterSet, CalibResult)> {
        let cfg = &self.calib;
        let key = format!(
            "calib:{}:{cache_tag}:scope={scope}:r={}:steps={}:lr={}:n={}:seed={}:v2",
            dims.name, init.rank, cfg.max_steps, cfg.lr, cfg.n_samples, cfg.seed
        );
        let mut meta_losses: Option<(Vec<f32>, Vec<f32>, Vec<f32>, f64, usize)> = None;
        let tf = self.cache.get_or_compute(&key, || {
            log::info!("calibrating {} scope={scope} r={} ({})", dims.name, init.rank, cache_tag);
            let res = Driver::new(self.rt).calibrate(dims, teacher, student, init, scope, cfg)?;
            let mut tf = TensorFile::new();
            for (i, buf) in res.adapters_flat.iter().enumerate() {
                tf.insert(format!("ad.{i:02}"), vec![buf.len()], buf.clone());
            }
            tf.insert("losses", vec![res.losses.len()], res.losses.clone());
            tf.insert("model_losses", vec![res.model_losses.len()], res.model_losses.clone());
            tf.insert("gt_losses", vec![res.gt_losses.len()], res.gt_losses.clone());
            tf.insert("wall", vec![1], vec![res.wall_secs as f32]);
            meta_losses = Some((
                res.losses.clone(),
                res.model_losses.clone(),
                res.gt_losses.clone(),
                res.wall_secs,
                res.steps,
            ));
            Ok(tf)
        })?;
        let flat: Vec<Vec<f32>> = (0..14)
            .map(|i| tf.get(&format!("ad.{i:02}")).map(|t| t.1.clone()))
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| anyhow!("corrupt calib cache"))?;
        let adapters = AdapterSet::from_flat(dims, init.rank, &flat)?;
        let losses = tf.get("losses").map(|t| t.1.clone()).unwrap_or_default();
        let (model_losses, gt_losses) = (
            tf.get("model_losses").map(|t| t.1.clone()).unwrap_or_default(),
            tf.get("gt_losses").map(|t| t.1.clone()).unwrap_or_default(),
        );
        let wall = tf.get("wall").map(|t| t.1[0] as f64).unwrap_or(0.0);
        let steps = losses.len();
        let _ = meta_losses;
        Ok((
            adapters,
            CalibResult {
                adapters_flat: flat,
                losses,
                model_losses,
                gt_losses,
                steps,
                wall_secs: wall,
                stopped_early: false,
            },
        ))
    }

    // ---------------------------------------------------------------------
    // stage: evaluation
    // ---------------------------------------------------------------------

    /// Held-out evaluation sequences (seed disjoint from calibration).
    pub fn eval_seqs(&self, dims: &ModelDims, profile: Profile, n: usize) -> Vec<Vec<u32>> {
        let mut corpus = Corpus::new(
            Vocab::new(dims.vocab, self.seed ^ 0x11),
            profile,
            self.seed ^ 0xe7a1,
        );
        (0..n).map(|_| corpus.sample_seq(dims.seq)).collect()
    }

    pub fn mc_suite(&self, dims: &ModelDims) -> Vec<(&'static str, Vec<McItem>)> {
        let vocab = Vocab::new(dims.vocab, self.seed ^ 0x11);
        TaskKind::ALL
            .iter()
            .map(|&k| (k.label(), gen_mc(k, &vocab, MC_ITEMS, self.seed ^ 0x7a57 ^ k as u64)))
            .collect()
    }

    pub fn gsm_items(&self, dims: &ModelDims) -> Vec<GsmItem> {
        let vocab = Vocab::new(dims.vocab, self.seed ^ 0x11);
        gen_gsm(&vocab, GSM_ITEMS, 1, self.seed ^ 0x65e8)
    }

    /// Scorer for the fp teacher.
    pub fn teacher_scorer(
        &self,
        dims: &ModelDims,
        teacher: &TeacherParams,
    ) -> Result<HloScorer<'r>> {
        let name = format!("teacher_fwd_{}", dims.name);
        HloScorer::new(self.rt, &name, |b| {
            b.teacher(teacher);
        })
    }

    /// Scorer for a (student, adapters) pair under the lab's execution
    /// backend: `dense` runs the HLO student artifact when lowered (the
    /// historical path), `packed`/`merged` run the native
    /// [`crate::model::backend`] engine. Selection lives in
    /// [`Driver::student_scorer`].
    pub fn student_scorer(
        &self,
        dims: &ModelDims,
        teacher: &TeacherParams,
        student: &StudentWeights,
        adapters: &AdapterSet,
    ) -> Result<Box<dyn Scorer + 'r>> {
        Driver::new(self.rt)
            .with_backend(self.backend)
            .student_scorer(dims, teacher, student, adapters)
    }

    /// Full evaluation bundle: 5-task CSQA accuracy + two perplexities.
    pub fn evaluate(&self, scorer: &dyn Scorer, dims: &ModelDims) -> Result<EvalBundle> {
        let suite = self.mc_suite(dims);
        let mut task_accs = Vec::new();
        for (label, items) in &suite {
            task_accs.push((*label, mc_accuracy(scorer, items, false)?));
        }
        let avg_acc = task_accs.iter().map(|(_, a)| a).sum::<f64>() / task_accs.len() as f64;
        let wiki = self.eval_seqs(dims, Profile::WikiSim, EVAL_SEQS);
        let c4 = self.eval_seqs(dims, Profile::C4Sim, EVAL_SEQS);
        Ok(EvalBundle {
            task_accs,
            avg_acc,
            ppl_wiki: perplexity(scorer, &wiki)?,
            ppl_c4: perplexity(scorer, &c4)?,
        })
    }

    /// gsm-sim accuracy for a scorer.
    pub fn evaluate_gsm(&self, scorer: &dyn Scorer, dims: &ModelDims) -> Result<f64> {
        gsm_accuracy(scorer, &self.gsm_items(dims))
    }

    /// Probe artifact metrics (Fig. 4): per-layer relative error + head
    /// relative error for a (student, adapters) pair.
    pub fn probe(
        &self,
        dims: &ModelDims,
        teacher: &TeacherParams,
        student: &StudentWeights,
        adapters: &AdapterSet,
    ) -> Result<(Vec<f32>, f32)> {
        let name = format!("probe_{}_r{}", dims.name, adapters.rank);
        let spec = self.rt.manifest.artifact(&name)?.clone();
        let batch: Vec<Vec<u32>> = self
            .eval_seqs(dims, Profile::WikiSim, dims.batch)
            .into_iter()
            .collect();
        let mut b = crate::runtime::Bindings::new();
        b.teacher(teacher)
            .qweights(student)
            .adapters("ad.", &adapters.to_flat())
            .tokens(&batch, dims);
        let outs = self.rt.run(&name, &b.to_literals(&spec)?)?;
        let layer_rel =
            crate::runtime::bindings::output_f32(&spec, &outs, "layer_rel")?;
        let head_rel =
            crate::runtime::bindings::output_scalar(&spec, &outs, "head_rel")?;
        Ok((layer_rel, head_rel))
    }

    /// Default zero-shot adapter init (A gaussian, B zero) — the paper's
    /// "LoRA without RILQ" baseline init.
    pub fn default_adapters(&self, dims: &ModelDims, rank: usize) -> AdapterSet {
        let mut rng = Rng::seed(self.seed ^ 0xada9);
        AdapterSet::init_default(dims, rank, &mut rng, 0.01)
    }

    /// Task-specific fine-tuning data (CSQA-sim / gsm-sim windows).
    pub fn ft_seqs(&self, dims: &ModelDims, task: &str, n_windows: usize) -> Vec<Vec<u32>> {
        let vocab = Vocab::new(dims.vocab, self.seed ^ 0x11);
        match task {
            "gsm" => {
                crate::data::tasks::gsm_train_seqs(&vocab, n_windows, dims.seq, 1, self.seed ^ 3)
            }
            _ => crate::data::tasks::csqa_train_seqs(&vocab, n_windows, dims.seq, self.seed ^ 4),
        }
    }
}

/// Storage accounting helper shared by Table 12 and README claims.
pub fn fp16_bytes(dims: &ModelDims) -> usize {
    dims.params_count() * 2
}

/// Quantized linear storage + fp embed/norm/head at fp16.
pub fn quantized_model_bytes(dims: &ModelDims, student: &StudentWeights) -> usize {
    let fp_part = dims.params_count()
        - LINEARS
            .iter()
            .map(|n| {
                let (di, do_) = dims.linear_dims(n);
                di * do_ * dims.n_layers
            })
            .sum::<usize>();
    fp_part * 2 + student.storage_bytes()
}
