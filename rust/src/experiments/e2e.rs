//! End-to-end validation driver (`rilq experiment e2e` and
//! `examples/end_to_end.rs`): exercises every layer of the stack on one
//! real small workload and reports the paper's headline metric.
//!
//! Pipeline: pretrain the `base` model on the synthetic corpus (loss curve
//! logged) → quantize to W2 (RTN) → compensate with Weight-SVD vs RILQ at
//! a small rank → evaluate PPL + CSQA + packed-serving parity.

use anyhow::Result;

use crate::lqec::AdapterSet;
use crate::report::table::f;
use crate::report::Table;

use super::pipeline::Lab;

pub fn run(lab: &mut Lab) -> Result<Vec<Table>> {
    // `base` exercises the largest artifacts; fall back to `small` if the
    // manifest was built without it.
    let config = match std::env::var("RILQ_E2E_CONFIG") {
        Ok(c) => Box::leak(c.into_boxed_str()) as &str,
        Err(_) if lab.rt.manifest.configs.contains_key("base") => "base",
        Err(_) => "small",
    };
    let (dims, teacher, pre_losses) = lab.teacher(config)?;
    let rank = *lab.rt.manifest.ranks[config].iter().min().unwrap();

    // loss curve (logged in the report; EXPERIMENTS.md references it)
    let mut curve = Table::new(
        format!("e2e — pretraining loss curve ({config}, {} params)", dims.params_count()),
        &["step", "loss"],
    );
    let stride = (pre_losses.len() / 20).max(1);
    for (i, &l) in pre_losses.iter().enumerate() {
        if i % stride == 0 || i + 1 == pre_losses.len() {
            curve.row(vec![i.to_string(), f(l as f64, 4)]);
        }
    }

    let mut t = Table::new(
        format!("e2e — headline result ({config}, W2/RTN, rank={rank})"),
        &["model", "CSQA avg", "Wiki2-PPL", "C4-PPL"],
    );

    // fp16 teacher
    let base_ev = {
        let sc = lab.teacher_scorer(&dims, &teacher)?;
        lab.evaluate(&sc, &dims)?
    };
    t.row(vec![
        "fp16 teacher".into(),
        f(base_ev.avg_acc * 100.0, 2),
        f(base_ev.ppl_wiki, 2),
        f(base_ev.ppl_c4, 2),
    ]);

    // W2, no compensation
    let student = lab.quantize(&dims, &teacher, "rtn", 2)?;
    let zeros = AdapterSet::zeros(&dims, rank);
    let q_ev = {
        let sc = lab.student_scorer(&dims, &teacher, &student, &zeros)?;
        lab.evaluate(&sc, &dims)?
    };
    t.row(vec![
        "W2 (no LQEC)".into(),
        f(q_ev.avg_acc * 100.0, 2),
        f(q_ev.ppl_wiki, 2),
        f(q_ev.ppl_c4, 2),
    ]);

    // Weight-SVD baseline
    let (st_svd, ad_svd) = lab.loftq(&dims, &teacher, "rtn", 2, rank, 1)?;
    let svd_ev = {
        let sc = lab.student_scorer(&dims, &teacher, &st_svd, &ad_svd)?;
        lab.evaluate(&sc, &dims)?
    };
    t.row(vec![
        "W2 + Weight-SVD".into(),
        f(svd_ev.avg_acc * 100.0, 2),
        f(svd_ev.ppl_wiki, 2),
        f(svd_ev.ppl_c4, 2),
    ]);

    // RILQ
    let init = lab.default_adapters(&dims, rank);
    let (ad, res) = lab.compensate(&dims, &teacher, &student, &init, "model_gt", "rtn2")?;
    let rilq_ev = {
        let sc = lab.student_scorer(&dims, &teacher, &student, &ad)?;
        lab.evaluate(&sc, &dims)?
    };
    t.row(vec![
        "W2 + RILQ".into(),
        f(rilq_ev.avg_acc * 100.0, 2),
        f(rilq_ev.ppl_wiki, 2),
        f(rilq_ev.ppl_c4, 2),
    ]);

    let gap = q_ev.ppl_wiki - base_ev.ppl_wiki;
    if gap > 0.05 * base_ev.ppl_wiki {
        t.note(format!(
            "RILQ calibration: {} steps, {:.1}s wall; recovers {:.0}% of the W2 Wiki2-PPL gap \
             (SVD recovers {:.0}%)",
            res.steps,
            res.wall_secs,
            (q_ev.ppl_wiki - rilq_ev.ppl_wiki) / gap * 100.0,
            (q_ev.ppl_wiki - svd_ev.ppl_wiki) / gap * 100.0,
        ));
    } else {
        t.note(format!(
            "RILQ calibration: {} steps, {:.1}s wall. NOTE: at this simulation scale the \
             teacher sits near the synthetic corpus's entropy floor, so W2 quantization \
             costs only {:.2} PPL ({:.1}%) — far from the paper's catastrophic 7B regime. \
             RILQ still improves over both W2 and SVD (Δ Wiki2 {:.2} vs quantized); see \
             EXPERIMENTS.md for the regime discussion.",
            res.steps,
            res.wall_secs,
            gap,
            gap / base_ev.ppl_wiki * 100.0,
            q_ev.ppl_wiki - rilq_ev.ppl_wiki,
        ));
    }

    Ok(vec![curve, t])
}
