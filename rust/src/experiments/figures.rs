//! Figure reproductions (Fig. 3 and Fig. 4 of the paper). Rendered as
//! tables: each column series corresponds to one curve of the figure.

use anyhow::Result;

use crate::lqec::svd_init::min_rank_for_target;
use crate::lqec::AdapterSet;
use crate::model::LINEARS;
use crate::quant::{by_name, CalibCtx};
use crate::report::table::f;
use crate::report::Table;
use crate::tensor::svd_jacobi;

use super::pipeline::Lab;

/// Fig. 3(a): average CSQA accuracy vs adapter rank for the three baseline
/// LQEC scopes (Weight-SVD / Linear-Loss / Layer-Loss) at W2 (NF2 base).
/// Shape check: all three degrade as rank shrinks; Layer > Linear > SVD.
pub fn fig3a(lab: &mut Lab) -> Result<Vec<Table>> {
    let (dims, teacher, _) = lab.teacher("small")?;
    let ranks: Vec<usize> = vec![4, 16, 64]; // paper 16..256 scaled to d_model
    // CSQA-sim accuracy saturates for compensated models at this scale
    // (EXPERIMENTS.md note), so the figure's rank-sensitivity curve is
    // reported in Wiki2-PPL — the same quality axis, graded.
    let mut t = Table::new(
        "Fig 3(a) — Wiki2-PPL vs rank for baseline LQEC scopes (W2/NF2, config=small)",
        &["rank", "Weight-SVD", "Linear-Loss", "Layer-Loss"],
    );
    for &rank in &ranks {
        // Weight-SVD (LoftQ)
        let (st_svd, ad_svd) = lab.loftq(&dims, &teacher, "nf", 2, rank, 1)?;
        let svd_ppl = {
            let sc = lab.student_scorer(&dims, &teacher, &st_svd, &ad_svd)?;
            lab.evaluate(&sc, &dims)?.ppl_wiki
        };
        // gradient scopes on the plain NF2 student
        let student = lab.quantize(&dims, &teacher, "nf", 2)?;
        let mut ppls = Vec::new();
        for scope in ["linear", "layer"] {
            let init = lab.default_adapters(&dims, rank);
            let (ad, _) =
                lab.compensate(&dims, &teacher, &student, &init, scope, "nf2")?;
            let sc = lab.student_scorer(&dims, &teacher, &student, &ad)?;
            ppls.push(lab.evaluate(&sc, &dims)?.ppl_wiki);
        }
        t.row(vec![
            rank.to_string(),
            f(svd_ppl, 2),
            f(ppls[0], 2),
            f(ppls[1], 2),
        ]);
    }
    t.note(
        "Paper shape: quality falls (PPL rises) as rank shrinks for all three baselines at 2-bit.",
    );
    Ok(vec![t])
}

/// Fig. 3(b): normalized weight discrepancy ‖W−Q‖F vs bit-width per linear
/// family, normalized to 1.0 at 4-bit. Shape check: sharp jump at 2-bit,
/// consistent across families and model sizes.
pub fn fig3b(lab: &mut Lab) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    for config in ["small", "tiny"] {
        let (dims, teacher, _) = lab.teacher(config)?;
        let mut t = Table::new(
            format!("Fig 3(b) — normalized ‖W−Q‖F vs bits (NF, config={config})"),
            &["module", "4-bit", "3-bit", "2-bit"],
        );
        for (fam, name) in LINEARS.iter().enumerate() {
            let mut per_bit = Vec::new();
            for bits in [4u8, 3, 2] {
                let q = by_name("nf", bits, dims.group_size).unwrap();
                let mut err = 0.0f64;
                for l in 0..dims.n_layers {
                    let w = teacher.linear(fam, l);
                    err += q.weight_discrepancy(w, &CalibCtx::default()) as f64;
                }
                per_bit.push(err / dims.n_layers as f64);
            }
            let norm = per_bit[0].max(1e-12);
            t.row(vec![
                name.to_string(),
                f(per_bit[0] / norm, 2),
                f(per_bit[1] / norm, 2),
                f(per_bit[2] / norm, 2),
            ]);
        }
        t.note("normalized so 4-bit = 1.00; the 2-bit jump is the paper's headline observation");
        tables.push(t);
    }
    Ok(tables)
}

/// Fig. 3(c): minimum SVD rank needed to bring the W2/W3 residual below
/// the 4-bit discrepancy, per linear family. Shape check: 3-bit needs a
/// small rank; 2-bit needs a rank far beyond the usual LoRA budget.
pub fn fig3c(lab: &mut Lab) -> Result<Vec<Table>> {
    let (dims, teacher, _) = lab.teacher("small")?;
    let mut t = Table::new(
        "Fig 3(c) — min rank to reach 4-bit discrepancy (NF, config=small)",
        &["module", "min rank @3-bit", "min rank @2-bit", "dim budget"],
    );
    for (fam, name) in LINEARS.iter().enumerate() {
        let (di, do_) = dims.linear_dims(name);
        let max_rank = di.min(do_);
        let mut per_bit = Vec::new();
        for bits in [3u8, 2] {
            let q = by_name("nf", bits, dims.group_size).unwrap();
            let q4 = by_name("nf", 4, dims.group_size).unwrap();
            let mut rank_sum = 0usize;
            for l in 0..dims.n_layers {
                let w = teacher.linear(fam, l);
                let target = q4.weight_discrepancy(w, &CalibCtx::default());
                let deq = q.quantize(w, &CalibCtx::default()).dequant();
                rank_sum += min_rank_for_target(w, &deq, target, max_rank);
            }
            per_bit.push(rank_sum / dims.n_layers);
        }
        t.row(vec![
            name.to_string(),
            per_bit[0].to_string(),
            per_bit[1].to_string(),
            max_rank.to_string(),
        ]);
    }
    t.note("2-bit errors are high-rank: typical LoRA ranks cannot absorb them via SVD");
    Ok(vec![t])
}

/// Fig. 4(a): rank sensitivity — relative error at the LM head across
/// scope x rank (OmniQuant-sim W2). Shape check: Model-Loss lowest and
/// flat across ranks.
pub fn fig4a(lab: &mut Lab) -> Result<Vec<Table>> {
    let (dims, teacher, _) = lab.teacher("small")?;
    let student = lab.quantize(&dims, &teacher, "omniquant", 2)?;
    let ranks: Vec<usize> = vec![4, 16, 64]; // paper 16..256 scaled to d_model
    let mut t = Table::new(
        "Fig 4(a) — LM-head relative error vs rank (OmniQuant-sim W2)",
        &["rank", "Linear-Loss", "Layer-Loss", "Model-Loss"],
    );
    for &rank in &ranks {
        let mut row = vec![rank.to_string()];
        for scope in ["linear", "layer", "model"] {
            let init = lab.default_adapters(&dims, rank);
            let (ad, _) =
                lab.compensate(&dims, &teacher, &student, &init, scope, "omni2")?;
            let (_, head_rel) = lab.probe(&dims, &teacher, &student, &ad)?;
            row.push(f(head_rel as f64, 4));
        }
        t.row(row);
    }
    t.note("paper shape: error shrinks with scope; Model-Loss stays low even at the smallest rank");
    Ok(vec![t])
}

/// Fig. 4(b): per-layer relative error profile at a fixed small rank.
/// Shape check: Model-Loss drifts in intermediate layers but lands lowest
/// at the head.
pub fn fig4b(lab: &mut Lab) -> Result<Vec<Table>> {
    let (dims, teacher, _) = lab.teacher("small")?;
    let student = lab.quantize(&dims, &teacher, "omniquant", 2)?;
    let rank = 16;
    let mut series = Vec::new();
    for scope in ["linear", "layer", "model"] {
        let init = lab.default_adapters(&dims, rank);
        let (ad, _) = lab.compensate(&dims, &teacher, &student, &init, scope, "omni2")?;
        series.push(lab.probe(&dims, &teacher, &student, &ad)?);
    }
    let mut t = Table::new(
        "Fig 4(b) — per-layer relative error (OmniQuant-sim W2, rank=16)",
        &["layer", "Linear-Loss", "Layer-Loss", "Model-Loss"],
    );
    for l in 0..dims.n_layers {
        t.row(vec![
            l.to_string(),
            f(series[0].0[l] as f64, 4),
            f(series[1].0[l] as f64, 4),
            f(series[2].0[l] as f64, 4),
        ]);
    }
    t.row(vec![
        "LM-head".into(),
        f(series[0].1 as f64, 4),
        f(series[1].1 as f64, 4),
        f(series[2].1 as f64, 4),
    ]);
    t.note("Model-Loss tolerates internal drift to align the final output (paper Fig. 4(b))");
    Ok(vec![t])
}

/// Fig. 4(c): singular-value mass of the learned adapters — Q-proj vs FFN1
/// (gate) under Linear-Loss vs Model-Loss. Shape check: Model-Loss boosts
/// the FFN1 adapter's singular mass relative to Q-proj's.
pub fn fig4c(lab: &mut Lab) -> Result<Vec<Table>> {
    let (dims, teacher, _) = lab.teacher("small")?;
    let student = lab.quantize(&dims, &teacher, "omniquant", 2)?;
    let rank = 16;
    let mut per_scope: Vec<AdapterSet> = Vec::new();
    for scope in ["linear", "model"] {
        let init = lab.default_adapters(&dims, rank);
        let (ad, _) = lab.compensate(&dims, &teacher, &student, &init, scope, "omni2")?;
        per_scope.push(ad);
    }
    let layer = dims.n_layers / 2;
    let fam_q = LINEARS.iter().position(|&n| n == "wq").unwrap();
    let fam_f = LINEARS.iter().position(|&n| n == "wg").unwrap();
    let sv = |ad: &AdapterSet, fam: usize| -> Vec<f32> {
        let delta = ad.delta(fam, layer);
        let svd = svd_jacobi(&delta);
        svd.s.iter().take(rank).copied().collect()
    };
    let mut t = Table::new(
        format!("Fig 4(c) — adapter singular values (layer {layer}, rank=16)"),
        &["k", "Q-proj/Linear", "Q-proj/Model", "FFN1/Linear", "FFN1/Model"],
    );
    let cols = [
        sv(&per_scope[0], fam_q),
        sv(&per_scope[1], fam_q),
        sv(&per_scope[0], fam_f),
        sv(&per_scope[1], fam_f),
    ];
    for k in 0..rank {
        t.row(vec![
            k.to_string(),
            f(cols[0].get(k).copied().unwrap_or(0.0) as f64, 4),
            f(cols[1].get(k).copied().unwrap_or(0.0) as f64, 4),
            f(cols[2].get(k).copied().unwrap_or(0.0) as f64, 4),
            f(cols[3].get(k).copied().unwrap_or(0.0) as f64, 4),
        ]);
    }
    let mass = |v: &[f32]| v.iter().map(|&x| x as f64).sum::<f64>();
    t.note(format!(
        "singular mass — Q-proj: Linear {:.3} vs Model {:.3}; FFN1: Linear {:.3} vs Model {:.3} \
         (paper shape: Model-Loss amplifies the rank-critical FFN side)",
        mass(&cols[0]),
        mass(&cols[1]),
        mass(&cols[2]),
        mass(&cols[3]),
    ));
    Ok(vec![t])
}
