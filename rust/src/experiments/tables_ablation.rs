//! Ablations: Tables 4–8.

use anyhow::Result;

use crate::coordinator::driver::Driver;
use crate::lqec::ralora;
use crate::lqec::{AdapterSet, GroupedAdapterSet};
use crate::report::table::f;
use crate::report::Table;
use crate::tensor::std_dev;

use super::pipeline::Lab;

/// Table 4: rank sensitivity — SVD (LoftQ) vs RILQ across ranks, for the
/// NormalFloat and OmniQuant-sim base quantizers.
pub fn table4(lab: &mut Lab) -> Result<Vec<Table>> {
    let (dims, teacher, _) = lab.teacher("small")?;
    let ranks: Vec<usize> = vec![4, 16, 64]; // paper 16..256 scaled to d_model
    let mut t = Table::new(
        "Table 4 — SVD vs RILQ across ranks (W2, config=small)",
        &["quantizer", "rank", "LQEC", "CSQA avg", "Wiki2-PPL", "C4-PPL"],
    );
    for qname in ["nf", "omniquant"] {
        let student = lab.quantize(&dims, &teacher, qname, 2)?;
        for &rank in &ranks {
            // SVD
            let (st_svd, ad_svd) = lab.loftq(&dims, &teacher, qname, 2, rank, 1)?;
            let ev = {
                let sc = lab.student_scorer(&dims, &teacher, &st_svd, &ad_svd)?;
                lab.evaluate(&sc, &dims)?
            };
            t.row(vec![
                qname.into(),
                rank.to_string(),
                "SVD".into(),
                f(ev.avg_acc * 100.0, 2),
                f(ev.ppl_wiki, 2),
                f(ev.ppl_c4, 2),
            ]);
            // RILQ
            let init = lab.default_adapters(&dims, rank);
            let (ad, _) = lab.compensate(
                &dims,
                &teacher,
                &student,
                &init,
                "model_gt",
                &format!("{qname}2"),
            )?;
            let ev = {
                let sc = lab.student_scorer(&dims, &teacher, &student, &ad)?;
                lab.evaluate(&sc, &dims)?
            };
            t.row(vec![
                qname.into(),
                rank.to_string(),
                "RILQ".into(),
                f(ev.avg_acc * 100.0, 2),
                f(ev.ppl_wiki, 2),
                f(ev.ppl_c4, 2),
            ]);
        }
    }
    t.note("paper shape: RILQ at the lowest rank beats SVD at the highest rank at 2-bit");
    Ok(vec![t])
}

/// Table 5: C4 PPL σ across ranks, W2 vs W3 — the rank-insensitivity
/// headline. RILQ's σ collapses at W2 while SVD's stays large.
pub fn table5(lab: &mut Lab) -> Result<Vec<Table>> {
    let (dims, teacher, _) = lab.teacher("small")?;
    let ranks: Vec<usize> = vec![4, 16, 64]; // paper 16..256 scaled to d_model
    let mut t = Table::new(
        "Table 5 — C4 PPL across ranks and bit-widths (OmniQuant-sim, config=small)",
        &{
            let mut h = vec!["LQEC", "bits"];
            let rank_hdrs: Vec<String> = ranks.iter().map(|r| format!("r={r}")).collect();
            h.extend(rank_hdrs.iter().map(|s| Box::leak(s.clone().into_boxed_str()) as &str));
            h.push("sigma");
            h
        },
    );
    for method in ["SVD", "RILQ"] {
        for bits in [3u8, 2] {
            let mut ppls = Vec::new();
            for &rank in &ranks {
                let ppl = if method == "SVD" {
                    let (st, ad) = lab.loftq(&dims, &teacher, "omniquant", bits, rank, 1)?;
                    let sc = lab.student_scorer(&dims, &teacher, &st, &ad)?;
                    lab.evaluate(&sc, &dims)?.ppl_c4
                } else {
                    let student = lab.quantize(&dims, &teacher, "omniquant", bits)?;
                    let init = lab.default_adapters(&dims, rank);
                    let (ad, _) = lab.compensate(
                        &dims,
                        &teacher,
                        &student,
                        &init,
                        "model_gt",
                        &format!("omniquant{bits}"),
                    )?;
                    let sc = lab.student_scorer(&dims, &teacher, &student, &ad)?;
                    lab.evaluate(&sc, &dims)?.ppl_c4
                };
                ppls.push(ppl);
            }
            let sigma = std_dev(&ppls);
            let mut row = vec![method.to_string(), format!("W{bits}A16")];
            row.extend(ppls.iter().map(|&p| f(p, 2)));
            row.push(f(sigma, 3));
            t.row(row);
        }
    }
    t.note("paper shape: σ(SVD, W2) >> σ(RILQ, W2); both tiny at W3");
    Ok(vec![t])
}

/// Table 6: QA-LoRA vs RA-LoRA vs RILQ under the group-merge setting at
/// the minimum rank (RTN W2).
pub fn table6(lab: &mut Lab) -> Result<Vec<Table>> {
    let (dims, teacher, _) = lab.teacher("small")?;
    let rank = 4; // the paper's rank=16 scaled by d_model ratio
    let student = lab.quantize(&dims, &teacher, "rtn", 2)?;
    let mut t = Table::new(
        "Table 6 — QA-LoRA vs RA-LoRA vs RILQ (RTN W2, rank-min, config=small)",
        &["method", "PIQA", "Arc-c", "Arc-e", "Avg(3)"],
    );

    let eval3 = |lab: &Lab, ad: &AdapterSet| -> Result<[f64; 3]> {
        let sc = lab.student_scorer(&dims, &teacher, &student, ad)?;
        let ev = lab.evaluate(&sc, &dims)?;
        let get = |l: &str| {
            ev.task_accs
                .iter()
                .find(|(n, _)| *n == l)
                .map(|(_, a)| *a)
                .unwrap_or(0.0)
        };
        Ok([get("PIQA"), get("Arc-c"), get("Arc-e")])
    };

    // QA-LoRA baseline: GT-loss tuning with the group constraint (project
    // each step is approximated by projecting the final adapters)
    {
        let init = lab.default_adapters(&dims, rank);
        let (ad, _) = lab.compensate(&dims, &teacher, &student, &init, "gt", "rtn2")?;
        let grouped = GroupedAdapterSet::project(&dims, &ad).expand(&dims);
        let a = eval3(lab, &grouped)?;
        t.row(vec![
            "QA-LoRA (baseline)".into(),
            f(a[0] * 100.0, 2),
            f(a[1] * 100.0, 2),
            f(a[2] * 100.0, 2),
            f((a[0] + a[1] + a[2]) / 3.0 * 100.0, 2),
        ]);
    }
    // RA-LoRA: sensitivity-allocated SVD ranks under the same budget
    {
        let plan = ralora::allocate(&dims, &teacher, &student, rank, 0.5);
        let mut ad = AdapterSet::zeros(&dims, rank);
        for fam in 0..7 {
            for l in 0..dims.n_layers {
                let resid = teacher.linear(fam, l).sub(&student.q[fam][l].dequant());
                let svd = crate::tensor::svd_jacobi(&resid);
                let (a, b) = svd.lora_factors(plan.ranks[fam][l]);
                ad.pairs[fam][l] = (a, b);
            }
        }
        // evaluate natively: per-pair ranks differ, so merge into dense
        let dense = crate::model::forward::effective_weights(&student, Some(&ad));
        let sc = crate::eval::NativeScorer {
            dims: dims.clone(),
            teacher: teacher.clone(),
            dense: Some(dense),
        };
        let ev = lab.evaluate(&sc, &dims)?;
        let get = |l: &str| {
            ev.task_accs
                .iter()
                .find(|(n, _)| *n == l)
                .map(|(_, a)| *a)
                .unwrap_or(0.0)
        };
        let a = [get("PIQA"), get("Arc-c"), get("Arc-e")];
        t.row(vec![
            "RA-LoRA".into(),
            f(a[0] * 100.0, 2),
            f(a[1] * 100.0, 2),
            f(a[2] * 100.0, 2),
            f((a[0] + a[1] + a[2]) / 3.0 * 100.0, 2),
        ]);
    }
    // RILQ (uniform rank, model+gt loss, group-projected for parity)
    {
        let init = lab.default_adapters(&dims, rank);
        let (ad, _) = lab.compensate(&dims, &teacher, &student, &init, "model_gt", "rtn2")?;
        let grouped = GroupedAdapterSet::project(&dims, &ad).expand(&dims);
        let a = eval3(lab, &grouped)?;
        t.row(vec![
            "RILQ".into(),
            f(a[0] * 100.0, 2),
            f(a[1] * 100.0, 2),
            f(a[2] * 100.0, 2),
            f((a[0] + a[1] + a[2]) / 3.0 * 100.0, 2),
        ]);
    }
    t.note("paper shape: RILQ > RA-LoRA > QA-LoRA at the lowest rank");
    Ok(vec![t])
}

/// Table 7: loss-scope ablation (Linear/Layer/Model × Act/GT).
pub fn table7(lab: &mut Lab) -> Result<Vec<Table>> {
    let (dims, teacher, _) = lab.teacher("small")?;
    let rank = 16;
    let student = lab.quantize(&dims, &teacher, "rtn", 2)?;
    let mut t = Table::new(
        "Table 7 — discrepancy-loss scope ablation (RTN W2, rank=16)",
        &["scope", "Act", "GT", "WG", "PIQA", "HS", "Arc-c", "Arc-e", "Avg"],
    );
    let rows: [(&str, &str, &str, &str); 5] = [
        ("Linear", "yes", "-", "linear"),
        ("Layer", "yes", "-", "layer"),
        ("Model", "yes", "-", "model"),
        ("Model", "-", "yes", "gt"),
        ("Model", "yes", "yes", "model_gt"),
    ];
    for (scope_label, act, gt, scope) in rows {
        let init = lab.default_adapters(&dims, rank);
        let (ad, _) = lab.compensate(&dims, &teacher, &student, &init, scope, "rtn2")?;
        let sc = lab.student_scorer(&dims, &teacher, &student, &ad)?;
        let ev = lab.evaluate(&sc, &dims)?;
        let mut row = vec![scope_label.to_string(), act.into(), gt.into()];
        row.extend(ev.task_accs.iter().map(|(_, a)| f(a * 100.0, 2)));
        row.push(f(ev.avg_acc * 100.0, 2));
        t.row(row);
    }
    t.note("paper shape: accuracy grows with scope; Model+GT (=RILQ) best overall");
    Ok(vec![t])
}

/// Table 8: QuIP#-sim end-to-end FT × RILQ cross effects.
/// "FT" (LayerNorm/head end-to-end fine-tuning in the paper) is simulated
/// by GT-scope adapter tuning — the same non-discrepancy e2e objective.
pub fn table8(lab: &mut Lab) -> Result<Vec<Table>> {
    let (dims, teacher, _) = lab.teacher("small")?;
    let rank = 16;
    let student = lab.quantize(&dims, &teacher, "quip", 2)?;
    let mut t = Table::new(
        "Table 8 — QuIP#-sim FT x RILQ (W2, config=small)",
        &["FT", "RILQ", "CSQA avg", "Wiki2-PPL", "C4-PPL"],
    );
    for (ft, rilq) in [(false, false), (false, true), (true, false), (true, true)] {
        let ad = match (ft, rilq) {
            (false, false) => AdapterSet::zeros(&dims, rank),
            (false, true) => {
                let init = lab.default_adapters(&dims, rank);
                lab.compensate(&dims, &teacher, &student, &init, "model_gt", "quip2")?.0
            }
            (true, false) => {
                let init = lab.default_adapters(&dims, rank);
                lab.compensate(&dims, &teacher, &student, &init, "gt", "quip2")?.0
            }
            (true, true) => {
                // FT then RILQ: continue model_gt from the gt-tuned state
                let init = lab.default_adapters(&dims, rank);
                let (ft_ad, _) =
                    lab.compensate(&dims, &teacher, &student, &init, "gt", "quip2")?;
                let cfg = lab.calib.clone();
                let res = Driver::new(lab.rt).calibrate(
                    &dims, &teacher, &student, &ft_ad, "model_gt", &cfg,
                )?;
                AdapterSet::from_flat(&dims, rank, &res.adapters_flat)?
            }
        };
        let sc = lab.student_scorer(&dims, &teacher, &student, &ad)?;
        let ev = lab.evaluate(&sc, &dims)?;
        t.row(vec![
            if ft { "yes".into() } else { "-".into() },
            if rilq { "yes".into() } else { "-".into() },
            f(ev.avg_acc * 100.0, 2),
            f(ev.ppl_wiki, 2),
            f(ev.ppl_c4, 2),
        ]);
    }
    t.note("paper shape: RILQ helps with and without e2e FT; the combination is best");
    Ok(vec![t])
}
