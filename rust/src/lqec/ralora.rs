//! RA-LoRA-style rank allocation (Table 6 baseline).
//!
//! RA-LoRA observes that linear modules have skewed rank demands for QEC
//! (Q-proj low-rank, FFN1 high-rank) and re-distributes a fixed adapter
//! parameter budget accordingly. We reproduce its sensitivity-based
//! allocator: per-module sensitivity is the effective rank of the
//! quantization residual `W − Q` (how many singular directions carry
//! `1 − τ` of its energy), and ranks are assigned proportionally under the
//! same total-parameter budget as a uniform-rank configuration.

use crate::model::{ModelDims, StudentWeights, TeacherParams, LINEARS};
use crate::tensor::svd_jacobi;

/// Per-(family, layer) rank assignment.
#[derive(Clone, Debug)]
pub struct RankPlan {
    pub ranks: Vec<Vec<usize>>,
    pub uniform_equivalent: usize,
}

/// Energy-based effective rank: smallest r with Σ_{k≤r} σ_k² ≥ τ·Σ σ_k².
fn energy_rank(sigmas: &[f32], tau: f64) -> usize {
    let total: f64 = sigmas.iter().map(|&s| (s as f64).powi(2)).sum();
    if total <= 0.0 {
        return 1;
    }
    let mut acc = 0.0;
    for (k, &s) in sigmas.iter().enumerate() {
        acc += (s as f64).powi(2);
        if acc >= tau * total {
            return k + 1;
        }
    }
    sigmas.len()
}

/// Compute a rank plan matching the parameter budget of `uniform_rank`.
pub fn allocate(
    dims: &ModelDims,
    teacher: &TeacherParams,
    student: &StudentWeights,
    uniform_rank: usize,
    tau: f64,
) -> RankPlan {
    // sensitivity per module
    let mut sens = vec![vec![0f64; dims.n_layers]; LINEARS.len()];
    // per-rank parameter cost per module: d_in + d_out
    let mut cost = vec![vec![0f64; dims.n_layers]; LINEARS.len()];
    let mut budget = 0f64;
    for (f, name) in LINEARS.iter().enumerate() {
        let (di, do_) = dims.linear_dims(name);
        for l in 0..dims.n_layers {
            let resid = teacher.linear(f, l).sub(&student.q[f][l].dequant());
            let svd = svd_jacobi(&resid);
            sens[f][l] = energy_rank(&svd.s, tau) as f64;
            cost[f][l] = (di + do_) as f64;
            budget += uniform_rank as f64 * cost[f][l];
        }
    }
    // proportional allocation under the budget: rank_m ∝ sens_m, scaled so
    // Σ rank_m · cost_m = budget
    let weighted: f64 = sens
        .iter()
        .zip(&cost)
        .flat_map(|(sf, cf)| sf.iter().zip(cf).map(|(&s, &c)| s * c))
        .sum();
    let scale = if weighted > 0.0 { budget / weighted } else { 1.0 };
    let ranks = sens
        .iter()
        .map(|sf| {
            sf.iter()
                .map(|&s| ((s * scale).round() as usize).clamp(1, 4 * uniform_rank))
                .collect()
        })
        .collect();
    RankPlan { ranks, uniform_equivalent: uniform_rank }
}

impl RankPlan {
    /// Total adapter parameters under this plan.
    pub fn params_count(&self, dims: &ModelDims) -> usize {
        let mut total = 0;
        for (f, name) in LINEARS.iter().enumerate() {
            let (di, do_) = dims.linear_dims(name);
            for l in 0..dims.n_layers {
                total += self.ranks[f][l] * (di + do_);
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{CalibCtx, Rtn};
    use crate::tensor::Rng;

    fn dims() -> ModelDims {
        ModelDims {
            name: "unit".into(),
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            vocab: 32,
            seq: 12,
            batch: 2,
            group_size: 8,
        }
    }

    #[test]
    fn energy_rank_basics() {
        assert_eq!(energy_rank(&[1.0, 0.0, 0.0], 0.9), 1);
        assert_eq!(energy_rank(&[1.0, 1.0, 1.0, 1.0], 0.99), 4);
    }

    #[test]
    fn allocation_respects_budget_roughly() {
        let d = dims();
        let mut rng = Rng::seed(141);
        let p = TeacherParams::init(&d, &mut rng);
        let q = Rtn::new(2, 8);
        let sw = StudentWeights::quantize(&d, &p, &q, &|_, _| CalibCtx::default());
        let plan = allocate(&d, &p, &sw, 4, 0.5);
        let uniform_params: usize = LINEARS
            .iter()
            .map(|n| {
                let (di, do_) = d.linear_dims(n);
                2 * 4 * (di + do_)
            })
            .sum();
        let got = plan.params_count(&d);
        // within 50% of the uniform budget (rounding + clamping slack)
        assert!(
            (got as f64) < 1.5 * uniform_params as f64
                && (got as f64) > 0.5 * uniform_params as f64,
            "got={got} uniform={uniform_params}"
        );
    }

    #[test]
    fn all_ranks_positive() {
        let d = dims();
        let mut rng = Rng::seed(142);
        let p = TeacherParams::init(&d, &mut rng);
        let q = Rtn::new(2, 8);
        let sw = StudentWeights::quantize(&d, &p, &q, &|_, _| CalibCtx::default());
        let plan = allocate(&d, &p, &sw, 4, 0.5);
        assert!(plan.ranks.iter().flatten().all(|&r| r >= 1));
    }
}
