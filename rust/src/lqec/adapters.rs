//! LoRA adapter containers.
//!
//! One `(A: [d_in, r], B: [d_out, r])` pair per quantized linear, with the
//! `Y = X(Q + A·Bᵀ)` convention of the paper. Flattening matches the
//! artifact layout from `python/compile/model.py::adapter_shapes`: for each
//! linear family, `<name>.a` is the stacked `[L, d_in, r]` buffer and
//! `<name>.b` the stacked `[L, d_out, r]` buffer.

use anyhow::{bail, Result};

use crate::model::{ModelDims, LINEARS};
use crate::tensor::{Mat, Rng};

/// All adapters of a model, indexed `[family][layer]`.
#[derive(Clone, Debug)]
pub struct AdapterSet {
    /// `(A, B)` per (family, layer); ranks may vary per pair (RA-LoRA).
    pub pairs: Vec<Vec<(Mat, Mat)>>,
    /// nominal rank (uniform case; per-pair ranks may differ)
    pub rank: usize,
}

impl AdapterSet {
    /// Default LoRA init: A ~ N(0, scale²), B = 0 — so A·Bᵀ = 0 initially.
    pub fn init_default(dims: &ModelDims, rank: usize, rng: &mut Rng, scale: f32) -> AdapterSet {
        let mut pairs = Vec::new();
        for name in LINEARS {
            let (di, do_) = dims.linear_dims(name);
            let per: Vec<(Mat, Mat)> = (0..dims.n_layers)
                .map(|_| (Mat::randn(di, rank, rng).scale(scale), Mat::zeros(do_, rank)))
                .collect();
            pairs.push(per);
        }
        AdapterSet { pairs, rank }
    }

    /// All-zero adapters (A = B = 0).
    pub fn zeros(dims: &ModelDims, rank: usize) -> AdapterSet {
        let mut pairs = Vec::new();
        for name in LINEARS {
            let (di, do_) = dims.linear_dims(name);
            let per: Vec<(Mat, Mat)> = (0..dims.n_layers)
                .map(|_| (Mat::zeros(di, rank), Mat::zeros(do_, rank)))
                .collect();
            pairs.push(per);
        }
        AdapterSet { pairs, rank }
    }

    pub fn get(&self, family: usize, layer: usize) -> (&Mat, &Mat) {
        let (a, b) = &self.pairs[family][layer];
        (a, b)
    }

    pub fn set(&mut self, family: usize, layer: usize, a: Mat, b: Mat) {
        assert_eq!(a.cols(), b.cols(), "A/B rank mismatch");
        self.pairs[family][layer] = (a, b);
    }

    pub fn n_layers(&self) -> usize {
        self.pairs[0].len()
    }

    /// Dense correction `A·Bᵀ` for one linear.
    pub fn delta(&self, family: usize, layer: usize) -> Mat {
        let (a, b) = self.get(family, layer);
        a.matmul_t(b)
    }

    /// Owned `(A, B)` clone for one linear, or `None` when the pair is
    /// all-zero (the "no compensation" baseline) — the form the
    /// [`crate::model::backend`] execution engines consume.
    pub fn lora_pair(&self, family: usize, layer: usize) -> Option<(Mat, Mat)> {
        let (a, b) = self.get(family, layer);
        let nonzero = |m: &Mat| m.data().iter().any(|&v| v != 0.0);
        if nonzero(a) && nonzero(b) {
            Some((a.clone(), b.clone()))
        } else {
            None
        }
    }

    /// Merge every correction into dense weights in place:
    /// `dense[f][l] += A[f][l]·B[f][l]ᵀ` (the `MergedDenseLinear` /
    /// QA-LoRA-style deployment form).
    pub fn merge_into(&self, dense: &mut [Vec<Mat>]) {
        for (f, layers) in dense.iter_mut().enumerate() {
            for (l, w) in layers.iter_mut().enumerate() {
                let (a, b) = self.get(f, l);
                *w = w.add(&a.matmul_t(b));
            }
        }
    }

    /// Number of adapter parameters.
    pub fn params_count(&self) -> usize {
        self.pairs
            .iter()
            .flatten()
            .map(|(a, b)| a.len() + b.len())
            .sum()
    }

    /// Flatten to artifact layout: 14 buffers in the order
    /// `wq.a, wq.b, wk.a, ..., wd.b` with `[L, ., r]` stacking.
    /// Requires uniform rank.
    pub fn to_flat(&self) -> Vec<Vec<f32>> {
        let mut out = Vec::with_capacity(14);
        for f in 0..LINEARS.len() {
            let mut a_buf = Vec::new();
            let mut b_buf = Vec::new();
            for (a, b) in &self.pairs[f] {
                assert_eq!(a.cols(), self.rank, "to_flat needs uniform rank");
                a_buf.extend_from_slice(a.data());
                b_buf.extend_from_slice(b.data());
            }
            out.push(a_buf);
            out.push(b_buf);
        }
        out
    }

    /// Inverse of [`to_flat`].
    pub fn from_flat(dims: &ModelDims, rank: usize, flat: &[Vec<f32>]) -> Result<AdapterSet> {
        if flat.len() != 14 {
            bail!("expected 14 adapter buffers, got {}", flat.len());
        }
        let l = dims.n_layers;
        let mut pairs = Vec::new();
        for (f, name) in LINEARS.iter().enumerate() {
            let (di, do_) = dims.linear_dims(name);
            let a_buf = &flat[2 * f];
            let b_buf = &flat[2 * f + 1];
            let pa = di * rank;
            let pb = do_ * rank;
            let per: Vec<(Mat, Mat)> = (0..l)
                .map(|i| {
                    (
                        Mat::from_vec(di, rank, a_buf[i * pa..(i + 1) * pa].to_vec()),
                        Mat::from_vec(do_, rank, b_buf[i * pb..(i + 1) * pb].to_vec()),
                    )
                })
                .collect();
            pairs.push(per);
        }
        Ok(AdapterSet { pairs, rank })
    }

    /// Adam moment buffers with the same geometry, zero-initialized
    /// (flattened alongside adapters in train-step artifacts).
    pub fn zeros_like_flat(&self) -> Vec<Vec<f32>> {
        self.to_flat().into_iter().map(|b| vec![0.0; b.len()]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims {
            name: "unit".into(),
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            vocab: 32,
            seq: 12,
            batch: 2,
            group_size: 8,
        }
    }

    #[test]
    fn default_init_is_identity_correction() {
        let d = dims();
        let mut rng = Rng::seed(111);
        let ad = AdapterSet::init_default(&d, 4, &mut rng, 0.01);
        // B = 0 -> delta = 0
        for f in 0..7 {
            for l in 0..2 {
                assert!(ad.delta(f, l).fro_norm() < 1e-9);
            }
        }
    }

    #[test]
    fn flat_roundtrip() {
        let d = dims();
        let mut rng = Rng::seed(112);
        let mut ad = AdapterSet::init_default(&d, 4, &mut rng, 0.01);
        // make B nonzero so the roundtrip is non-trivial
        ad.set(3, 1, Mat::randn(16, 4, &mut rng), Mat::randn(16, 4, &mut rng));
        let flat = ad.to_flat();
        assert_eq!(flat.len(), 14);
        let ad2 = AdapterSet::from_flat(&d, 4, &flat).unwrap();
        for f in 0..7 {
            for l in 0..2 {
                let (a1, b1) = ad.get(f, l);
                let (a2, b2) = ad2.get(f, l);
                assert!(a1.fro_dist(a2) < 1e-7);
                assert!(b1.fro_dist(b2) < 1e-7);
            }
        }
    }

    #[test]
    fn merge_into_matches_delta() {
        let d = dims();
        let mut rng = Rng::seed(113);
        let mut ad = AdapterSet::zeros(&d, 3);
        ad.set(2, 0, Mat::randn(16, 3, &mut rng), Mat::randn(16, 3, &mut rng));
        let mut dense: Vec<Vec<Mat>> = (0..7)
            .map(|f| {
                let (di, do_) = d.linear_dims(crate::model::LINEARS[f]);
                (0..2).map(|_| Mat::zeros(di, do_)).collect()
            })
            .collect();
        ad.merge_into(&mut dense);
        assert!(dense[2][0].fro_dist(&ad.delta(2, 0)) < 1e-6);
        assert!(dense[3][1].fro_norm() < 1e-9);
        // zero pairs yield no lora_pair; the touched one does
        assert!(ad.lora_pair(0, 0).is_none());
        assert!(ad.lora_pair(2, 0).is_some());
    }

    #[test]
    fn params_count() {
        let d = dims();
        let ad = AdapterSet::zeros(&d, 4);
        // per layer: 4x(16+16)*4 attn + (16+32)*4 g + (16+32)*4 u + (32+16)*4 d
        let per_layer = 4 * (16 + 16) * 4 + 2 * (16 + 32) * 4 + (32 + 16) * 4;
        assert_eq!(ad.params_count(), 2 * per_layer);
    }
}
