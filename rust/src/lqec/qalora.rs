//! QA-LoRA-style group-pooled adapters (Table 3 / Table 6 baseline).
//!
//! QA-LoRA constrains the adapter input side to be constant within each
//! quantization group (it pools the input activations group-wise), which
//! makes the learned correction `A·Bᵀ` *exactly absorbable* into the
//! per-group zero-points of the quantized weights — adapter-free inference.
//!
//! Representation: `a_group: [d_in/gs, r]` per linear; the effective dense
//! A expands each group row by `1/gs` so that `X·A_eff = pool(X)·A_group`.

use crate::model::{ModelDims, LINEARS};
use crate::quant::QuantizedTensor;
use crate::tensor::{Mat, Rng};

use super::AdapterSet;

/// Group-constrained adapter set.
#[derive(Clone, Debug)]
pub struct GroupedAdapterSet {
    /// `(A_group: [d_in/gs, r], B: [d_out, r])` per `[family][layer]`
    pub pairs: Vec<Vec<(Mat, Mat)>>,
    pub rank: usize,
    pub group_size: usize,
}

impl GroupedAdapterSet {
    pub fn init_default(dims: &ModelDims, rank: usize, rng: &mut Rng, scale: f32) -> Self {
        let gs = dims.group_size;
        let mut pairs = Vec::new();
        for name in LINEARS {
            let (di, do_) = dims.linear_dims(name);
            assert!(di % gs == 0);
            let per: Vec<(Mat, Mat)> = (0..dims.n_layers)
                .map(|_| (Mat::randn(di / gs, rank, rng).scale(scale), Mat::zeros(do_, rank)))
                .collect();
            pairs.push(per);
        }
        GroupedAdapterSet { pairs, rank, group_size: gs }
    }

    /// Expand to an unconstrained [`AdapterSet`] (each group row repeated,
    /// scaled by 1/gs so the correction equals pooled-input semantics).
    pub fn expand(&self, dims: &ModelDims) -> AdapterSet {
        let gs = self.group_size;
        let mut out = AdapterSet::zeros(dims, self.rank);
        for (f, name) in LINEARS.iter().enumerate() {
            let (di, _) = dims.linear_dims(name);
            for l in 0..dims.n_layers {
                let (ag, b) = &self.pairs[f][l];
                let a = Mat::from_fn(di, self.rank, |i, r| ag[(i / gs, r)] / gs as f32);
                out.set(f, l, a, b.clone());
            }
        }
        out
    }

    /// Project an unconstrained adapter pair onto the group constraint
    /// (mean over each group of input rows, times gs) — used to convert
    /// RILQ-tuned adapters into mergeable form.
    pub fn project(dims: &ModelDims, ad: &AdapterSet) -> GroupedAdapterSet {
        let gs = dims.group_size;
        let rank = ad.rank;
        let mut pairs = Vec::new();
        for (f, name) in LINEARS.iter().enumerate() {
            let (di, _) = dims.linear_dims(name);
            let per: Vec<(Mat, Mat)> = (0..dims.n_layers)
                .map(|l| {
                    let (a, b) = ad.get(f, l);
                    let ag = Mat::from_fn(di / gs, rank, |g, r| {
                        let mut s = 0.0;
                        for i in g * gs..(g + 1) * gs {
                            s += a[(i, r)];
                        }
                        s // sum = mean * gs; expand divides by gs again
                    });
                    (ag, b.clone())
                })
                .collect();
            pairs.push(per);
        }
        GroupedAdapterSet { pairs, rank, group_size: gs }
    }

    /// Merge one linear's grouped correction exactly into the quantized
    /// tensor's zero-points: `z'[g, j] = z[g, j] + (1/gs)·A_group[g]·B[j]`.
    /// After this, adapter-free dequantization reproduces
    /// `deq(Q) + A_eff·Bᵀ` exactly.
    pub fn merge_into(&self, family: usize, layer: usize, q: &mut QuantizedTensor) {
        let (ag, b) = &self.pairs[family][layer];
        assert_eq!(q.group_size, self.group_size, "merge needs matching groups");
        let n_groups = q.d_in / q.group_size;
        assert_eq!(ag.rows(), n_groups);
        for g in 0..n_groups {
            let arow = ag.row(g);
            for j in 0..q.d_out {
                let brow = b.row(j);
                let dot: f32 = arow.iter().zip(brow).map(|(&x, &y)| x * y).sum();
                q.zeros[(g, j)] += dot / self.group_size as f32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{CalibCtx, Quantizer, Rtn};

    fn dims() -> ModelDims {
        ModelDims {
            name: "unit".into(),
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            vocab: 32,
            seq: 12,
            batch: 2,
            group_size: 8,
        }
    }

    #[test]
    fn expand_is_group_constant() {
        let d = dims();
        let mut rng = Rng::seed(131);
        let mut g = GroupedAdapterSet::init_default(&d, 4, &mut rng, 0.1);
        g.pairs[0][0].1 = Mat::randn(16, 4, &mut rng); // nonzero B
        let ad = g.expand(&d);
        let (a, _) = ad.get(0, 0);
        // rows within a group are identical
        for grp in 0..2 {
            for i in 1..8 {
                for r in 0..4 {
                    assert!((a[(grp * 8, r)] - a[(grp * 8 + i, r)]).abs() < 1e-7);
                }
            }
        }
    }

    #[test]
    fn merge_is_exact() {
        let d = dims();
        let mut rng = Rng::seed(132);
        let w = Mat::randn(16, 16, &mut rng);
        let quant = Rtn::new(2, 8);
        let qr = quant.quantize(&w, &CalibCtx::default());
        let mut q = qr.as_scalar().unwrap().clone();

        let mut g = GroupedAdapterSet::init_default(&d, 4, &mut rng, 0.1);
        g.pairs[0][0].1 = Mat::randn(16, 4, &mut rng);
        let ad = g.expand(&d);
        let expected = q.dequant().add(&ad.delta(0, 0));

        g.merge_into(0, 0, &mut q);
        let merged = q.dequant();
        assert!(merged.fro_dist(&expected) < 1e-4, "dist={}", merged.fro_dist(&expected));
    }

    #[test]
    fn project_expand_identity_on_constrained() {
        let d = dims();
        let mut rng = Rng::seed(133);
        let mut g = GroupedAdapterSet::init_default(&d, 4, &mut rng, 0.1);
        for f in 0..7 {
            let (_, ref mut b) = g.pairs[f][0];
            *b = Mat::randn(b.rows(), 4, &mut rng);
        }
        let ad = g.expand(&d);
        let g2 = GroupedAdapterSet::project(&d, &ad);
        let ad2 = g2.expand(&d);
        for f in 0..7 {
            let (a1, b1) = ad.get(f, 0);
            let (a2, b2) = ad2.get(f, 0);
            assert!(a1.fro_dist(a2) < 1e-5);
            assert!(b1.fro_dist(b2) < 1e-5);
        }
    }
}
