//! LoftQ-style iterative Weight-SVD compensation (Eq. 2 of the paper):
//!
//! ```text
//! repeat T times:
//!     Q   = Quant(W − A·Bᵀ)
//!     A,B = SVD_r(W − deq(Q))
//! ```
//!
//! This is the `SVD` column of Tables 4/5/10 and (with the NormalFloat
//! base quantizer) the `LoftQ` rows of Tables 1/9. It also powers the
//! min-rank analysis of Fig. 3(c).

use crate::model::{ModelDims, StudentWeights, TeacherParams, LINEARS};
use crate::quant::{CalibCtx, QuantResult, Quantizer};
use crate::tensor::{svd_jacobi, Mat};

use super::AdapterSet;

/// Result of compensating one matrix.
pub struct SvdCompensation {
    pub q: QuantResult,
    pub a: Mat,
    pub b: Mat,
    /// `‖W − (Q + A·Bᵀ)‖_F` after the final iteration
    pub residual: f32,
}

/// LoftQ iteration for a single weight matrix.
pub fn loftq_single(
    w: &Mat,
    quantizer: &dyn Quantizer,
    ctx: &CalibCtx,
    rank: usize,
    iters: usize,
) -> SvdCompensation {
    let (d_in, d_out) = w.shape();
    let mut a = Mat::zeros(d_in, rank);
    let mut b = Mat::zeros(d_out, rank);
    let mut q = quantizer.quantize(w, ctx);
    for _ in 0..iters.max(1) {
        // Q = Quant(W - A Bᵀ)
        let target = w.sub(&a.matmul(&b.t()));
        q = quantizer.quantize(&target, ctx);
        // A,B = SVD_r(W - deq(Q))
        let resid = w.sub(&q.dequant());
        let svd = svd_jacobi(&resid);
        let (l1, l2) = svd.lora_factors(rank);
        a = l1;
        b = l2;
    }
    let residual = w.fro_dist(&q.dequant().add(&a.matmul(&b.t())));
    SvdCompensation { q, a, b, residual }
}

/// Apply LoftQ to every linear of the teacher; returns the quantized
/// student plus the SVD-initialized adapters.
pub fn loftq_model(
    dims: &ModelDims,
    teacher: &TeacherParams,
    quantizer: &dyn Quantizer,
    calib: &(dyn Fn(usize, usize) -> CalibCtx + Sync),
    rank: usize,
    iters: usize,
) -> (StudentWeights, AdapterSet) {
    let l = dims.n_layers;
    let cells = LINEARS.len() * l;
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let comps = crate::tensor::parallel_map(cells, workers, |i| {
        let (f, li) = (i / l, i % l);
        loftq_single(teacher.linear(f, li), quantizer, &calib(f, li), rank, iters)
    });
    let mut q: Vec<Vec<crate::quant::QuantResult>> =
        (0..LINEARS.len()).map(|_| Vec::new()).collect();
    let mut ad = AdapterSet::zeros(dims, rank);
    for (i, comp) in comps.into_iter().enumerate() {
        let (f, li) = (i / l, i % l);
        ad.set(f, li, comp.a, comp.b);
        q[f].push(comp.q);
    }
    (
        StudentWeights { q, quantizer: quantizer.name().to_string(), bits: quantizer.bits() },
        ad,
    )
}

/// Single-iteration LoftQ with a reusable residual SVD: with one iteration,
/// `Q = Quant(W)` and `A,B = SVD_r(W − deq(Q))` — the SVD is
/// rank-independent, so rank sweeps (Fig. 3(a), Tables 4/5) compute each
/// matrix's SVD once and slice factors per rank.
pub fn loftq_presvd(
    dims: &ModelDims,
    teacher: &TeacherParams,
    quantizer: &dyn Quantizer,
    calib: &(dyn Fn(usize, usize) -> CalibCtx + Sync),
) -> (StudentWeights, Vec<Vec<crate::tensor::Svd>>) {
    let student = StudentWeights::quantize(dims, teacher, quantizer, calib);
    let l = dims.n_layers;
    let cells = LINEARS.len() * l;
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let svds = crate::tensor::parallel_map(cells, workers, |i| {
        let (f, li) = (i / l, i % l);
        let resid = teacher.linear(f, li).sub(&student.q[f][li].dequant());
        svd_jacobi(&resid)
    });
    let mut out: Vec<Vec<crate::tensor::Svd>> = (0..LINEARS.len()).map(|_| Vec::new()).collect();
    for (i, svd) in svds.into_iter().enumerate() {
        out[i / l].push(svd);
    }
    (student, out)
}

/// Adapters at a given rank from a [`loftq_presvd`] result.
pub fn adapters_from_presvd(
    dims: &ModelDims,
    svds: &[Vec<crate::tensor::Svd>],
    rank: usize,
) -> AdapterSet {
    let mut ad = AdapterSet::zeros(dims, rank);
    for f in 0..LINEARS.len() {
        for l in 0..dims.n_layers {
            let (a, b) = svds[f][l].lora_factors(rank);
            ad.set(f, l, a, b);
        }
    }
    ad
}

/// Fig. 3(c): the minimum adapter rank needed for SVD compensation of
/// `W − Q` to bring the *residual* discrepancy below `target` (typically
/// the 4-bit quantization discrepancy of the same matrix).
pub fn min_rank_for_target(w: &Mat, q_deq: &Mat, target: f32, max_rank: usize) -> usize {
    let resid = w.sub(q_deq);
    let svd = svd_jacobi(&resid);
    // residual after removing the top-r singular directions:
    // ‖resid − SVD_r‖² = Σ_{k>r} σ_k²
    let total: f64 = svd.s.iter().map(|&s| (s as f64) * (s as f64)).sum();
    let mut tail = total;
    for r in 0..=max_rank.min(svd.s.len()) {
        if tail.sqrt() as f32 <= target {
            return r;
        }
        if r < svd.s.len() {
            tail -= (svd.s[r] as f64) * (svd.s[r] as f64);
        }
    }
    max_rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{NormalFloat, Rtn};
    use crate::tensor::Rng;

    #[test]
    fn loftq_reduces_residual_vs_plain_quant() {
        let mut rng = Rng::seed(121);
        let w = Mat::randn(64, 32, &mut rng);
        let quant = NormalFloat::new(2, 32);
        let ctx = CalibCtx::default();
        let plain = quant.quantize(&w, &ctx).dequant().fro_dist(&w);
        let comp = loftq_single(&w, &quant, &ctx, 8, 3);
        assert!(comp.residual < plain, "residual={} plain={plain}", comp.residual);
    }

    #[test]
    fn higher_rank_lower_residual() {
        let mut rng = Rng::seed(122);
        let w = Mat::randn(64, 32, &mut rng);
        let quant = Rtn::new(2, 32);
        let ctx = CalibCtx::default();
        let r4 = loftq_single(&w, &quant, &ctx, 4, 2).residual;
        let r16 = loftq_single(&w, &quant, &ctx, 16, 2).residual;
        assert!(r16 <= r4 + 1e-4, "r4={r4} r16={r16}");
    }

    #[test]
    fn min_rank_monotone_in_target() {
        let mut rng = Rng::seed(123);
        let w = Mat::randn(48, 48, &mut rng);
        let q = Rtn::new(2, 16).quantize(&w, &CalibCtx::default()).dequant();
        let err = w.fro_dist(&q);
        let easy = min_rank_for_target(&w, &q, err * 0.9, 48);
        let hard = min_rank_for_target(&w, &q, err * 0.3, 48);
        assert!(hard >= easy, "easy={easy} hard={hard}");
        // the headline effect: tight targets need large ranks at 2-bit
        assert!(hard > 4);
    }

    #[test]
    fn loftq_model_shapes() {
        use crate::model::TeacherParams;
        let dims = ModelDims {
            name: "unit".into(),
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            vocab: 32,
            seq: 12,
            batch: 2,
            group_size: 8,
        };
        let mut rng = Rng::seed(124);
        let p = TeacherParams::init(&dims, &mut rng);
        let quant = Rtn::new(2, 8);
        let (sw, ad) = loftq_model(&dims, &p, &quant, &|_, _| CalibCtx::default(), 4, 1);
        assert_eq!(sw.q.len(), 7);
        assert_eq!(ad.rank, 4);
        // adapters should now be non-trivial
        assert!(ad.delta(0, 0).fro_norm() > 0.0);
    }
}
