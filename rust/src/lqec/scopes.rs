//! Discrepancy-loss scopes (Fig. 2(b–e) of the paper). The Rust side uses
//! these to select train-step artifacts and to label experiments; the
//! actual losses live in `python/compile/model.py::scope_loss` and are
//! baked into the lowered HLO.

use std::fmt;

use anyhow::{bail, Result};

/// Optimization scope for LQEC adapter tuning.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scope {
    /// Eq. 3 — per-linear output discrepancy (ApiQ-style).
    Linear,
    /// Eq. 4 — per-Transformer-layer discrepancy (QLLM-style).
    Layer,
    /// Eq. 5 — model-level discrepancy at the final decoder output.
    Model,
    /// Eq. 6 — causal-LM ground-truth loss only.
    Gt,
    /// RILQ: 0.5·Model + 0.5·GT.
    ModelGt,
    /// Table 11 variant: Model-Loss applied at the logits.
    ModelLogit,
}

impl Scope {
    /// All scopes in paper order (Table 7 rows).
    pub const ALL: [Scope; 6] = [
        Scope::Linear,
        Scope::Layer,
        Scope::Model,
        Scope::Gt,
        Scope::ModelGt,
        Scope::ModelLogit,
    ];

    /// The artifact-name fragment (`train_step_<cfg>_r<r>_<this>`).
    pub fn artifact_key(&self) -> &'static str {
        match self {
            Scope::Linear => "linear",
            Scope::Layer => "layer",
            Scope::Model => "model",
            Scope::Gt => "gt",
            Scope::ModelGt => "model_gt",
            Scope::ModelLogit => "model_logit",
        }
    }

    pub fn parse(s: &str) -> Result<Scope> {
        Ok(match s {
            "linear" => Scope::Linear,
            "layer" => Scope::Layer,
            "model" => Scope::Model,
            "gt" => Scope::Gt,
            "model_gt" | "rilq" => Scope::ModelGt,
            "model_logit" => Scope::ModelLogit,
            other => bail!("unknown scope '{other}'"),
        })
    }

    /// Human-readable name used in report tables.
    pub fn label(&self) -> &'static str {
        match self {
            Scope::Linear => "Linear-Loss",
            Scope::Layer => "Layer-Loss",
            Scope::Model => "Model-Loss",
            Scope::Gt => "GT-Loss",
            Scope::ModelGt => "RILQ (Model+GT)",
            Scope::ModelLogit => "Model-Loss@logits",
        }
    }
}

impl fmt::Display for Scope {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.artifact_key())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in Scope::ALL {
            assert_eq!(Scope::parse(s.artifact_key()).unwrap(), s);
        }
        assert_eq!(Scope::parse("rilq").unwrap(), Scope::ModelGt);
        assert!(Scope::parse("bogus").is_err());
    }
}
