//! LoRA-based Quantization Error Compensation (LQEC) substrates:
//!
//! * [`adapters`] — the adapter container (one (A, B) pair per quantized
//!   linear), init schemes, flattening to artifact layout, merging;
//! * [`svd_init`] — LoftQ-style iterative Weight-SVD compensation (the
//!   paper's main baseline, Fig. 2(b) / Eq. 2);
//! * [`qalora`] — QA-LoRA's group-pooled adapters that merge exactly into
//!   quantized zero-points (Table 3);
//! * [`ralora`] — RA-LoRA's sensitivity-based rank allocator (Table 6);
//! * [`scopes`] — the discrepancy-loss scope taxonomy shared with the L2
//!   training artifacts (Linear/Layer/Model/GT/Model+GT = RILQ).

pub mod adapters;
pub mod qalora;
pub mod ralora;
pub mod scopes;
pub mod svd_init;

pub use adapters::AdapterSet;
pub use qalora::GroupedAdapterSet;
pub use scopes::Scope;
