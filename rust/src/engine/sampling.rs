//! Token selection: greedy argmax and seeded stochastic sampling
//! (temperature / top-k / top-p) over one logits row.
//!
//! Determinism contract: greedy selection (`temperature == 0`, the
//! default) involves no randomness at all — ties break toward the
//! **lowest token id** — so `seed: None` is fully reproducible in greedy
//! mode. Stochastic sampling draws from a per-request [`Rng`]; with
//! `seed: Some(s)` the whole generation is a pure function of `(prompt,
//! params, model)`, and with `seed: None` a fixed default seed is used so
//! even "unseeded" sampling replays identically.

use anyhow::{ensure, Result};

use crate::model::forward::row_logp;
use crate::tensor::Rng;

/// The seed used for stochastic sampling when
/// [`SamplingParams::seed`] is `None` — sampling stays reproducible even
/// without an explicit seed.
pub const DEFAULT_SAMPLING_SEED: u64 = 0x5a3d_517e;

/// How `Generate` requests pick tokens. The default is greedy decoding
/// (`temperature == 0`), bitwise-identical to
/// [`crate::eval::greedy_decode`].
#[derive(Clone, Debug, PartialEq)]
pub struct SamplingParams {
    /// Token budget: generation stops after this many tokens (0 = answer
    /// immediately with an empty generation).
    pub max_new: usize,
    /// Softmax temperature; `0.0` selects greedy argmax decoding.
    pub temperature: f32,
    /// Keep only the `top_k` highest-logit tokens before sampling
    /// (`0` disables the filter).
    pub top_k: usize,
    /// Nucleus filter: keep the smallest set of tokens whose probability
    /// mass reaches `top_p` (`1.0` disables the filter).
    pub top_p: f32,
    /// RNG seed for stochastic sampling. `None` uses
    /// [`DEFAULT_SAMPLING_SEED`]; greedy mode never draws randomness.
    pub seed: Option<u64>,
    /// Stop tokens: generation halts as soon as one of these is sampled.
    /// The stop token is **included** in the output (its logp aligns with
    /// the token list).
    pub stop: Vec<u32>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            max_new: 16,
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            seed: None,
            stop: Vec::new(),
        }
    }
}

impl SamplingParams {
    /// Greedy decoding with a token budget — the configuration whose
    /// output is pinned bitwise against [`crate::eval::greedy_decode`].
    pub fn greedy(max_new: usize) -> SamplingParams {
        SamplingParams { max_new, ..SamplingParams::default() }
    }

    /// True when token selection is deterministic argmax.
    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }

    /// Admission-time validation (the engine answers `Err` instead of
    /// sampling from a malformed distribution).
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.temperature.is_finite() && self.temperature >= 0.0,
            "temperature must be finite and >= 0, got {}",
            self.temperature
        );
        ensure!(
            self.top_p > 0.0 && self.top_p <= 1.0,
            "top_p must be in (0, 1], got {}",
            self.top_p
        );
        Ok(())
    }

    /// The per-request RNG this configuration samples from.
    pub fn rng(&self) -> Rng {
        Rng::seed(self.seed.unwrap_or(DEFAULT_SAMPLING_SEED))
    }
}

/// Greedy pick from one logits row: the argmax token and its log-prob
/// under the full distribution.
///
/// Tie-breaking is **explicitly deterministic: the lowest token id
/// wins** (strict `>` comparison scanning ids in ascending order), so
/// greedy decoding with `seed: None` reproduces exactly — across runs,
/// backends, and batch compositions.
// lint: allow(indexing) — `best` is always a prior enumerate index of `row`
pub fn argmax_logp(row: &[f32]) -> (u32, f32) {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate().skip(1) {
        // strict >: an equal later logit never displaces an earlier one
        if v > row[best] {
            best = i;
        }
    }
    (best as u32, row_logp(row, best as u32))
}

/// Pick one token from a logits row under `params`, advancing `rng` only
/// in stochastic mode. Returns `(token, logp)` where `logp` is the
/// token's log-prob under the **full** (unfiltered, untempered)
/// distribution — the same quantity greedy decoding reports, so
/// generation log-probs are comparable across sampling configurations.
///
/// Stochastic selection: logits are divided by `temperature`, the
/// candidate list is sorted by descending logit (ties toward the lowest
/// id, mirroring [`argmax_logp`]), truncated to `top_k`, then to the
/// smallest prefix whose softmax mass reaches `top_p`, and the token is
/// drawn from the renormalized remainder.
// lint: allow(indexing) — `ids` holds indices of `row` by construction and is
// only ever truncated; `sample_weighted` returns an index into `probs`, which
// stays the same length as `ids`
pub fn sample_token(row: &[f32], params: &SamplingParams, rng: &mut Rng) -> (u32, f32) {
    if params.is_greedy() {
        return argmax_logp(row);
    }
    // candidates ordered by (logit desc, id asc) — a total, deterministic
    // order, so the same seed replays the same choices. With top-k active
    // the top k are partitioned out first (O(V) select) so the sort only
    // ever touches k elements, not the whole vocabulary.
    let by_logit_then_id = |&a: &usize, &b: &usize| {
        row[b].partial_cmp(&row[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    };
    let mut ids: Vec<usize> = (0..row.len()).collect();
    if params.top_k > 0 && params.top_k < ids.len() {
        // the comparator is a total order, so the selected top-k SET is
        // unique and the subsequent sort keeps determinism
        ids.select_nth_unstable_by(params.top_k - 1, by_logit_then_id);
        ids.truncate(params.top_k);
    }
    ids.sort_unstable_by(by_logit_then_id);
    // tempered softmax over the kept candidates (max-subtracted). A tiny
    // temperature can overflow 1/T — or the scaled max logit — to
    // infinity, which would NaN every probability via inf - inf; the
    // T -> 0 limit is argmax, so take it directly in that regime.
    let inv_t = 1.0 / params.temperature;
    let maxl = row[ids[0]] * inv_t;
    if !maxl.is_finite() {
        return argmax_logp(row);
    }
    let mut probs: Vec<f64> = ids.iter().map(|&i| ((row[i] * inv_t - maxl) as f64).exp()).collect();
    let total: f64 = probs.iter().sum();
    if params.top_p < 1.0 {
        // nucleus: smallest prefix reaching top_p of the kept mass
        let mut acc = 0.0f64;
        let mut keep = probs.len();
        for (n, &p) in probs.iter().enumerate() {
            acc += p;
            if acc >= params.top_p as f64 * total {
                keep = n + 1;
                break;
            }
        }
        ids.truncate(keep);
        probs.truncate(keep);
    }
    let tok = ids[rng.sample_weighted(&probs)] as u32;
    (tok, row_logp(row, tok))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_ties_break_toward_lowest_token_id() {
        let (tok, _) = argmax_logp(&[1.0, 3.0, 3.0, 2.0]);
        assert_eq!(tok, 1, "equal logits must resolve to the lowest id");
        let (tok, _) = argmax_logp(&[5.0, 5.0, 5.0]);
        assert_eq!(tok, 0);
    }

    #[test]
    fn greedy_logp_is_full_distribution_logp() {
        let row = [0.0f32, 2.0, -1.0];
        let (tok, lp) = argmax_logp(&row);
        assert_eq!(tok, 1);
        assert!((lp - row_logp(&row, 1)).abs() == 0.0);
        assert!(lp < 0.0);
    }

    #[test]
    fn zero_temperature_never_touches_the_rng() {
        let row = [0.1f32, 0.9, 0.5];
        let params = SamplingParams::greedy(4);
        let mut rng = Rng::seed(1);
        let before = rng.clone();
        let (tok, _) = sample_token(&row, &params, &mut rng);
        assert_eq!(tok, 1);
        // the rng state is untouched: greedy is reproducible with seed=None
        let mut a = rng;
        let mut b = before;
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn peaked_distribution_always_samples_the_peak() {
        let mut row = vec![0.0f32; 16];
        row[7] = 50.0; // ~e^50 more likely than anything else
        let params = SamplingParams {
            temperature: 1.0,
            ..SamplingParams::greedy(1)
        };
        let mut rng = Rng::seed(3);
        for _ in 0..64 {
            assert_eq!(sample_token(&row, &params, &mut rng).0, 7);
        }
    }

    #[test]
    fn top_k_restricts_the_candidate_set() {
        let row = [1.0f32, 8.0, 7.5, 1.0, 0.0, 6.0];
        let params = SamplingParams {
            temperature: 2.0,
            top_k: 2,
            ..SamplingParams::greedy(1)
        };
        let mut rng = Rng::seed(4);
        for _ in 0..128 {
            let (tok, _) = sample_token(&row, &params, &mut rng);
            assert!(tok == 1 || tok == 2, "token {tok} outside top-2");
        }
    }

    #[test]
    fn tiny_top_p_degenerates_to_argmax() {
        let row = [0.3f32, 0.1, 0.9, 0.2];
        let params = SamplingParams {
            temperature: 1.5,
            top_p: 1e-6,
            ..SamplingParams::greedy(1)
        };
        let mut rng = Rng::seed(5);
        for _ in 0..32 {
            assert_eq!(sample_token(&row, &params, &mut rng).0, 2);
        }
    }

    #[test]
    fn subnormal_temperature_degenerates_to_argmax() {
        // 1/T overflows f32 to infinity here; sampling must take the
        // T -> 0 limit (argmax) instead of NaN-ing the distribution
        let row = [0.3f32, 0.1, 0.9, 0.2];
        let params = SamplingParams { temperature: 1e-39, ..SamplingParams::greedy(1) };
        assert!(!params.is_greedy());
        let mut rng = Rng::seed(11);
        for _ in 0..16 {
            let (tok, lp) = sample_token(&row, &params, &mut rng);
            assert_eq!(tok, 2);
            assert!(lp.is_finite());
        }
    }

    #[test]
    fn same_seed_replays_identically() {
        let mut rng = Rng::seed(6);
        let row: Vec<f32> = (0..32).map(|_| rng.next_gaussian()).collect();
        let params = SamplingParams {
            temperature: 1.0,
            top_k: 8,
            top_p: 0.9,
            seed: Some(99),
            ..SamplingParams::greedy(1)
        };
        let draw = |seed: u64| -> Vec<u32> {
            let mut r = Rng::seed(seed);
            (0..20).map(|_| sample_token(&row, &params, &mut r).0).collect()
        };
        assert_eq!(draw(99), draw(99));
    }

    #[test]
    fn unseeded_params_fall_back_to_the_default_seed() {
        let p = SamplingParams::default();
        let mut a = p.rng();
        let mut b = Rng::seed(DEFAULT_SAMPLING_SEED);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn validate_rejects_malformed_params() {
        let bad_t = SamplingParams { temperature: f32::NAN, ..Default::default() };
        assert!(bad_t.validate().is_err());
        let bad_p = SamplingParams { top_p: 0.0, ..Default::default() };
        assert!(bad_p.validate().is_err());
        let bad_p2 = SamplingParams { top_p: 1.5, ..Default::default() };
        assert!(bad_p2.validate().is_err());
        assert!(SamplingParams::default().validate().is_ok());
    }

    #[test]
    fn sampled_logp_reports_the_full_distribution() {
        let row = [0.5f32, 1.5, -0.5, 2.5];
        let params = SamplingParams {
            temperature: 0.7,
            ..SamplingParams::greedy(1)
        };
        let mut rng = Rng::seed(8);
        let (tok, lp) = sample_token(&row, &params, &mut rng);
        assert!((lp - row_logp(&row, tok)).abs() == 0.0);
    }
}
