//! Deterministic fault injection for serving tests (`ChaosScorer`).
//!
//! Test support only: wraps any [`Scorer`] and injects faults — `Err`
//! returns, delays, or panics — at scheduled forward-call ordinals, so
//! the fault-tolerance suite (`tests/chaos_serving.rs`, `serve-bench
//! --chaos`) can prove the engine's invariants under failure: every
//! pending request resolves, KV arena blocks drain to zero, and
//! retried work is bitwise-identical to a fault-free run.
//!
//! The schedule is either hand-placed ([`ChaosScorer::with_fault`]) or
//! derived from a seed ([`ChaosScorer::seeded`]); both are fully
//! deterministic, so a failing chaos run reproduces exactly.
//!
//! The injected `panic!` below is the **only sanctioned panic source on
//! the serving path** (see the invariant catalog in `lib.rs`): it
//! exists precisely to exercise the engine's catch-unwind supervision,
//! and is annotated for rilq-lint R1 accordingly.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::eval::scorer::Scorer;
use crate::model::kv::KvCache;
use crate::model::ModelDims;
use crate::tensor::{Mat, Rng};

use super::caps::EngineCaps;

/// One injected failure mode.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fault {
    /// The scorer call returns `Err` (transient failure; retryable).
    Err,
    /// The scorer call succeeds after sleeping this long (latency
    /// fault; trips deadlines without corrupting results).
    Delay(Duration),
    /// The scorer call panics (crash fault; the engine's supervision
    /// must catch it and mark the replica unhealthy).
    Panic,
}

/// A [`Scorer`] wrapper that injects [`Fault`]s at scheduled call
/// ordinals. Calls are counted across *all* scoring entry points
/// (`score_batch`, `score_choices`, `cache_forward`,
/// `cache_forward_batch`); the first call is ordinal 1. Unscheduled
/// calls delegate untouched, so results that do come back are exactly
/// the inner scorer's.
pub struct ChaosScorer<S> {
    inner: S,
    calls: AtomicUsize,
    injected: AtomicUsize,
    schedule: Mutex<BTreeMap<usize, Fault>>,
}

impl<S> ChaosScorer<S> {
    /// Wrap `inner` with an empty fault schedule.
    pub fn new(inner: S) -> ChaosScorer<S> {
        ChaosScorer {
            inner,
            calls: AtomicUsize::new(0),
            injected: AtomicUsize::new(0),
            schedule: Mutex::new(BTreeMap::new()),
        }
    }

    /// Schedule `fault` at the `nth` scorer call (1-based). Later
    /// entries for the same ordinal replace earlier ones.
    pub fn with_fault(self, nth: usize, fault: Fault) -> ChaosScorer<S> {
        {
            let mut sched = self.schedule.lock().unwrap_or_else(|e| e.into_inner());
            sched.insert(nth.max(1), fault);
        }
        self
    }

    /// Derive `n_faults` scheduled faults from `seed`, at distinct call
    /// ordinals in `1..=window`. Fault kinds alternate between `Err`
    /// and short `Delay`s; when `with_panics` is set every third fault
    /// is a `Panic` instead (only sensible with ≥ 2 replicas — a
    /// single-replica fleet has nowhere to fail over to).
    pub fn seeded(self, seed: u64, n_faults: usize, window: usize, with_panics: bool) -> Self {
        let mut rng = Rng::seed(seed);
        let window = window.max(1);
        {
            let mut sched = self.schedule.lock().unwrap_or_else(|e| e.into_inner());
            let mut placed = 0usize;
            // Bounded draw budget: distinct-ordinal placement can stall
            // when n_faults approaches window.
            for draw in 0..(n_faults * 16).max(16) {
                if placed >= n_faults {
                    break;
                }
                let nth = (rng.next_u32() as usize) % window + 1;
                if sched.contains_key(&nth) {
                    continue;
                }
                let fault = if with_panics && placed % 3 == 2 {
                    Fault::Panic
                } else if draw % 2 == 0 {
                    Fault::Err
                } else {
                    Fault::Delay(Duration::from_millis(1 + (rng.next_u32() % 5) as u64))
                };
                sched.insert(nth, fault);
                placed += 1;
            }
        }
        self
    }

    /// Total scorer calls observed so far (including faulted ones).
    pub fn calls(&self) -> usize {
        self.calls.load(Ordering::Acquire)
    }

    /// How many scheduled faults have fired.
    pub fn injected(&self) -> usize {
        self.injected.load(Ordering::Acquire)
    }

    /// The remaining (unfired) schedule, ordered by call ordinal — lets
    /// tests pin that seeding is deterministic.
    pub fn schedule(&self) -> Vec<(usize, Fault)> {
        let sched = self.schedule.lock().unwrap_or_else(|e| e.into_inner());
        sched.iter().map(|(&n, &f)| (n, f)).collect()
    }

    /// Count this call and fire its scheduled fault, if any.
    fn faulted(&self) -> Result<()> {
        let call = self.calls.fetch_add(1, Ordering::AcqRel) + 1;
        let fault = {
            let mut sched = self.schedule.lock().unwrap_or_else(|e| e.into_inner());
            sched.remove(&call)
        };
        match fault {
            None => Ok(()),
            Some(Fault::Delay(d)) => {
                self.injected.fetch_add(1, Ordering::AcqRel);
                std::thread::sleep(d);
                Ok(())
            }
            Some(Fault::Err) => {
                self.injected.fetch_add(1, Ordering::AcqRel);
                Err(anyhow!("chaos: injected fault at call {call}"))
            }
            Some(Fault::Panic) => {
                self.injected.fetch_add(1, Ordering::AcqRel);
                // lint: allow(panic) — deliberate injected crash; test-support code whose whole
                // purpose is to exercise the engine's catch-unwind supervision (see module docs)
                panic!("chaos: injected panic at call {call}")
            }
        }
    }
}

impl<S: Scorer> Scorer for ChaosScorer<S> {
    fn dims(&self) -> &ModelDims {
        self.inner.dims()
    }

    fn caps(&self) -> EngineCaps {
        self.inner.caps()
    }

    fn score_batch(&self, batch: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        self.faulted()?;
        self.inner.score_batch(batch)
    }

    fn score_choices(&self, prompt: &[u32], choices: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        self.faulted()?;
        self.inner.score_choices(prompt, choices)
    }

    fn cache_forward(&self, new_tokens: &[u32], cache: &mut KvCache) -> Result<Mat> {
        self.faulted()?;
        self.inner.cache_forward(new_tokens, cache)
    }

    fn cache_forward_batch(
        &self,
        news: &[Vec<u32>],
        caches: &mut [&mut KvCache],
    ) -> Result<Vec<Mat>> {
        self.faulted()?;
        self.inner.cache_forward_batch(news, caches)
    }
    // score_all is left at its trait default so chunked scoring routes
    // through the counted score_batch above.
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal deterministic inner scorer: echoes sequence lengths.
    struct Echo {
        dims: ModelDims,
    }

    impl Echo {
        fn new() -> Echo {
            Echo {
                dims: ModelDims {
                    name: "echo".into(),
                    d_model: 4,
                    n_layers: 1,
                    n_heads: 1,
                    d_ff: 8,
                    vocab: 16,
                    seq: 8,
                    batch: 2,
                    group_size: 4,
                },
            }
        }
    }

    impl Scorer for Echo {
        fn dims(&self) -> &ModelDims {
            &self.dims
        }

        fn score_batch(&self, batch: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
            Ok(batch.iter().map(|t| vec![-(t.len() as f32); t.len().saturating_sub(1)]).collect())
        }
    }

    #[test]
    fn unscheduled_calls_delegate_untouched() {
        let c = ChaosScorer::new(Echo::new());
        let out = c.score_batch(&[vec![1, 2, 3]]).unwrap();
        assert_eq!(out, vec![vec![-3.0, -3.0]]);
        assert_eq!(c.calls(), 1);
        assert_eq!(c.injected(), 0);
    }

    #[test]
    fn scheduled_err_fires_once_at_its_ordinal() {
        let c = ChaosScorer::new(Echo::new()).with_fault(2, Fault::Err);
        assert!(c.score_batch(&[vec![1, 2]]).is_ok());
        let err = c.score_batch(&[vec![1, 2]]).unwrap_err();
        assert!(format!("{err}").contains("chaos: injected fault at call 2"), "{err}");
        assert!(c.score_batch(&[vec![1, 2]]).is_ok(), "fault is consumed, call 3 is clean");
        assert_eq!(c.injected(), 1);
        assert!(c.schedule().is_empty());
    }

    #[test]
    fn delay_fault_returns_the_real_answer() {
        let c = ChaosScorer::new(Echo::new()).with_fault(1, Fault::Delay(Duration::from_millis(1)));
        let out = c.score_batch(&[vec![1, 2, 3]]).unwrap();
        assert_eq!(out, vec![vec![-3.0, -3.0]]);
        assert_eq!(c.injected(), 1);
    }

    #[test]
    fn panic_fault_panics_with_the_chaos_marker() {
        let c = ChaosScorer::new(Echo::new()).with_fault(1, Fault::Panic);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = c.score_batch(&[vec![1, 2]]);
        }));
        let payload = caught.unwrap_err();
        let msg = payload.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("chaos: injected panic at call 1"), "{msg}");
    }

    #[test]
    fn seeded_schedule_is_deterministic_and_windowed() {
        let a = ChaosScorer::new(Echo::new()).seeded(0x5eed, 4, 16, true);
        let b = ChaosScorer::new(Echo::new()).seeded(0x5eed, 4, 16, true);
        assert_eq!(a.schedule(), b.schedule());
        assert_eq!(a.schedule().len(), 4);
        assert!(a.schedule().iter().all(|&(n, _)| (1..=16).contains(&n)));
        assert!(
            a.schedule().iter().any(|&(_, f)| f == Fault::Panic),
            "with_panics schedules at least one panic: {:?}",
            a.schedule()
        );
        let no_panics = ChaosScorer::new(Echo::new()).seeded(0x5eed, 4, 16, false);
        assert!(no_panics.schedule().iter().all(|&(_, f)| f != Fault::Panic));
    }
}
