//! Cross-request radix prefix cache: fleet-wide KV reuse over the paged
//! arena.
//!
//! Shared-prompt traffic (system prompts, few-shot headers) re-prefills
//! the same token prefix on every request. PR 3's prefix reuse only
//! lives *within* one `Choices` item; this index makes committed KV
//! blocks reusable *across* requests: a token-id radix trie maps runs of
//! committed positions to the [`KvArena`] blocks that already hold their
//! K/V, so a new sequence attaches the longest cached prefix and
//! chunk-prefills only the suffix.
//!
//! # Block-granular radix trie
//!
//! The trie's alphabet is whole blocks: every edge label is a run of
//! `block_size` token ids per held block, children of a node differ in
//! their first block, and splits happen only at block boundaries. That
//! granularity is forced by correctness, not convenience — a partially
//! filled boundary block cannot be shared (its tail rows would be
//! clobbered by one holder while another reads), so the engine attaches
//! whole blocks and re-prefills the remainder privately. Since a
//! committed block's rotated-K/V planes are a pure function of the token
//! prefix (chunked prefill is bitwise-pinned equal to one-shot), a
//! cache-hit prefill produces logits `to_bits`-identical to a cold one.
//!
//! # Ownership and pinning
//!
//! The index holds one refcounted handle per block it publishes
//! ([`KvArena::retain`]); attaching a prefix adds the sequence as
//! another holder. A block is "pinned" while any live cache shares it
//! (arena refcount > 1): [`PrefixIndex::evict_lru`] skips pinned blocks
//! — releasing them would free no capacity — and frees the
//! least-recently-used leaf's unpinned suffix first, so trie entries are
//! always evicted *before* the scheduler's preemption path has to fire.
//! Preemption itself never steals a pinned block: a preempted cache
//! merely drops its own holds and the index's holds keep the blocks
//! resident.
//!
//! # Locking discipline (R4)
//!
//! The index is deliberately **lock-free at this layer**: it is owned by
//! one engine loop and touched only between scheduler phases, never from
//! request threads. The only lock in play is the arena's own allocator
//! mutex, confined inside `retain`/`release`/`handle_refs` — no guard
//! here can span a forward call, which is exactly the R4 rule rilq-lint
//! enforces for this file.

use std::sync::Arc;

use crate::model::kv::{KvArena, KvBlock, KvCache};

/// One trie node: an edge label of whole-block token runs plus the
/// blocks holding their committed K/V. `tokens.len()` is always
/// `blocks.len() * block_size`; every held block appears in exactly one
/// node, so the index's holder-count per block is exactly one.
struct Node {
    tokens: Vec<u32>,
    blocks: Vec<Arc<KvBlock>>,
    /// logical LRU stamp — larger is more recent
    last_used: u64,
    children: Vec<Node>,
}

/// First whole block of `child`'s label equals the first whole block of
/// `rest` (false when either side is shorter than one block).
fn first_block_matches(child: &Node, rest: &[u32], bs: usize) -> bool {
    match (child.tokens.get(..bs), rest.get(..bs)) {
        (Some(a), Some(b)) => a == b,
        _ => false,
    }
}

/// Number of whole blocks shared between `child`'s label and `rest`.
fn matched_blocks(child: &Node, rest: &[u32], bs: usize) -> usize {
    child
        .tokens
        .chunks(bs)
        .zip(rest.chunks(bs))
        .take_while(|(a, b)| a.len() == bs && b.len() == bs && a == b)
        .count()
}

/// Radix index over committed KV block runs, keyed by token ids.
///
/// Owned by one engine loop (see the module docs for why there is no
/// lock). All block ownership flows through the arena's refcounts:
/// `insert` retains, `evict_lru` and `Drop` release, `attach` retains on
/// behalf of the receiving cache — so "decrement exactly once per
/// holder" is structural no matter how a sequence ends (finish, cancel,
/// deadline abort, preemption, failover).
pub struct PrefixIndex {
    arena: Arc<KvArena>,
    block_size: usize,
    children: Vec<Node>,
    clock: u64,
    blocks_held: usize,
}

impl PrefixIndex {
    /// Empty index over `arena`'s blocks.
    pub fn new(arena: Arc<KvArena>) -> PrefixIndex {
        let block_size = arena.block_size();
        PrefixIndex { arena, block_size, children: Vec::new(), clock: 0, blocks_held: 0 }
    }

    /// Blocks currently pinned by the index (each counted once — a block
    /// lives in exactly one node). This is the `serve.kv_blocks_pinned`
    /// gauge.
    pub fn blocks_held(&self) -> usize {
        self.blocks_held
    }

    /// Nodes in the trie (diagnostics/tests).
    pub fn node_count(&self) -> usize {
        fn walk(nodes: &[Node]) -> usize {
            nodes.iter().map(|n| 1 + walk(&n.children)).sum()
        }
        walk(&self.children)
    }

    /// Longest cached prefix of `tokens`, in positions, without touching
    /// recency — block-granular and capped at `limit` positions (the
    /// scheduler caps one position short of a full prompt so a sampling
    /// prefill still forwards at least one row). Used to price a
    /// candidate's first step before admission.
    pub fn peek(&self, tokens: &[u32], limit: usize) -> usize {
        let bs = self.block_size;
        let mut budget = limit.min(tokens.len()) / bs;
        let mut matched = 0usize;
        let mut nodes = &self.children;
        let mut rest = tokens;
        while budget > 0 {
            let Some(child) = nodes.iter().find(|c| first_block_matches(c, rest, bs)) else {
                break;
            };
            let m = matched_blocks(child, rest, bs).min(budget);
            matched += m;
            budget -= m;
            if m * bs < child.tokens.len() {
                break; // partial edge match — usable, but nothing deeper
            }
            rest = rest.get(m * bs..).unwrap_or(&[]);
            nodes = &child.children;
        }
        matched * bs
    }

    /// Attach the longest cached prefix of `tokens` (≤ `limit`
    /// positions, whole blocks) to an **empty** `cache`, adding the
    /// cache as a holder of every shared block. Returns the attached
    /// position count (0 ⇒ cold miss, cache untouched). Touches the
    /// matched path's recency.
    pub fn attach(&mut self, tokens: &[u32], limit: usize, cache: &mut KvCache) -> usize {
        let bs = self.block_size;
        let mut budget = limit.min(tokens.len()) / bs;
        if budget == 0 {
            return 0;
        }
        self.clock += 1;
        let stamp = self.clock;
        let mut picked: Vec<Arc<KvBlock>> = Vec::new();
        let mut nodes = &mut self.children;
        let mut rest = tokens;
        while budget > 0 {
            let Some(pos) = nodes.iter().position(|c| first_block_matches(c, rest, bs)) else {
                break;
            };
            let Some(child) = nodes.get_mut(pos) else { break };
            child.last_used = stamp;
            let m = matched_blocks(child, rest, bs).min(budget);
            picked.extend(child.blocks.iter().take(m).cloned());
            budget -= m;
            if budget == 0 || m * bs < child.tokens.len() {
                break;
            }
            rest = rest.get(m * bs..).unwrap_or(&[]);
            nodes = &mut child.children;
        }
        let n_blocks = picked.len();
        if n_blocks == 0 {
            return 0;
        }
        let retained = self.arena.retain(&picked);
        cache.attach_prefix(retained, n_blocks * bs);
        n_blocks * bs
    }

    /// Publish the committed prefix of `cache` (whole blocks only) under
    /// its token sequence `tokens` (`tokens.len() <= cache.len()`,
    /// position `i` of the cache holding the K/V of `tokens[i]`).
    /// Descends the trie, splits edges at block boundaries, and retains
    /// only blocks for paths not already present — an existing path's
    /// blocks win, so re-inserting a known prefix is a recency touch.
    pub fn insert(&mut self, tokens: &[u32], cache: &KvCache) {
        let bs = self.block_size;
        let handles = cache.block_handles();
        let nb = (tokens.len().min(cache.len()) / bs).min(handles.len());
        if nb == 0 {
            return;
        }
        let mut rest_t = tokens.get(..nb * bs).unwrap_or(&[]);
        let mut rest_b = handles.get(..nb).unwrap_or(&[]);
        self.clock += 1;
        let stamp = self.clock;
        let mut added = 0usize;
        let mut nodes = &mut self.children;
        loop {
            let Some(pos) = nodes.iter().position(|c| first_block_matches(c, rest_t, bs)) else {
                // nothing shares the next block: new leaf takes the rest
                let blocks = self.arena.retain(rest_b);
                added += blocks.len();
                nodes.push(Node {
                    tokens: rest_t.to_vec(),
                    blocks,
                    last_used: stamp,
                    children: Vec::new(),
                });
                break;
            };
            let Some(child) = nodes.get_mut(pos) else { break };
            let m = matched_blocks(child, rest_t, bs);
            if m * bs < child.tokens.len() {
                // split at the divergence boundary: the old tail becomes a
                // grandchild keeping the child's pre-touch recency
                let tail_tokens = child.tokens.split_off(m * bs);
                let tail_blocks = child.blocks.split_off(m);
                let tail_children = std::mem::take(&mut child.children);
                child.children.push(Node {
                    tokens: tail_tokens,
                    blocks: tail_blocks,
                    last_used: child.last_used,
                    children: tail_children,
                });
            }
            child.last_used = stamp;
            if rest_t.len() > m * bs {
                rest_t = rest_t.get(m * bs..).unwrap_or(&[]);
                rest_b = rest_b.get(m..).unwrap_or(&[]);
                nodes = &mut child.children;
                continue;
            }
            break; // fully contained: pure recency touch
        }
        self.blocks_held += added;
    }

    /// Refresh the recency of the longest cached prefix of `tokens`
    /// without attaching anything — how Score traffic (which needs
    /// logits at every position and therefore always full-forwards)
    /// still keeps hot shared prompts resident.
    pub fn touch(&mut self, tokens: &[u32]) {
        let bs = self.block_size;
        self.clock += 1;
        let stamp = self.clock;
        let mut nodes = &mut self.children;
        let mut rest = tokens;
        loop {
            let Some(pos) = nodes.iter().position(|c| first_block_matches(c, rest, bs)) else {
                break;
            };
            let Some(child) = nodes.get_mut(pos) else { break };
            child.last_used = stamp;
            let m = matched_blocks(child, rest, bs);
            if m * bs < child.tokens.len() {
                break;
            }
            rest = rest.get(m * bs..).unwrap_or(&[]);
            nodes = &mut child.children;
        }
    }

    /// Free at least `want` arena blocks if the trie can spare them,
    /// least-recently-used leaves first; within a leaf only the unpinned
    /// suffix (arena refcount 1 — no live cache shares it) is released.
    /// Returns the number of blocks actually freed, possibly short of
    /// `want` when everything left is pinned. The scheduler calls this
    /// *before* resorting to preemption, so cached-but-idle prefixes are
    /// always the first residency sacrificed.
    pub fn evict_lru(&mut self, want: usize) -> usize {
        let mut freed = 0usize;
        let mut floor = 0u64;
        while freed < want {
            let Some(target) = min_leaf_stamp(&self.children, floor) else { break };
            match evict_leaf(&mut self.children, &self.arena, self.block_size, target) {
                Some(f) if f > 0 => {
                    freed += f;
                    // a removed leaf can expose an older parent as a new
                    // evictable leaf: restart the stamp scan from the bottom
                    floor = 0;
                }
                _ => floor = target.saturating_add(1), // pinned leaf: skip past it
            }
        }
        self.blocks_held -= freed;
        freed
    }
}

/// Smallest `last_used` over all leaves with stamp ≥ `floor`.
fn min_leaf_stamp(nodes: &[Node], floor: u64) -> Option<u64> {
    let mut best: Option<u64> = None;
    for n in nodes {
        let cand = if n.children.is_empty() {
            (n.last_used >= floor).then_some(n.last_used)
        } else {
            min_leaf_stamp(&n.children, floor)
        };
        if let Some(v) = cand {
            best = Some(best.map_or(v, |b| b.min(v)));
        }
    }
    best
}

/// Find the leaf stamped `target` and release its unpinned block suffix;
/// a fully-released leaf is removed from its parent. `Some(freed)` once
/// the leaf was found (freed may be 0 when every block is pinned),
/// `None` when no leaf in this subtree carries the stamp.
fn evict_leaf(nodes: &mut Vec<Node>, arena: &KvArena, bs: usize, target: u64) -> Option<usize> {
    for i in 0..nodes.len() {
        let Some(n) = nodes.get_mut(i) else { break };
        if n.children.is_empty() {
            if n.last_used != target {
                continue;
            }
            let mut keep = n.blocks.len();
            while keep > 0
                && n.blocks.get(keep - 1).is_some_and(|b| arena.handle_refs(b) == 1)
            {
                keep -= 1;
            }
            let dropped = n.blocks.split_off(keep);
            let freed = dropped.len();
            arena.release(dropped);
            n.tokens.truncate(keep * bs);
            if keep == 0 {
                nodes.swap_remove(i); // sibling order is not meaningful
            }
            return Some(freed);
        }
        if let Some(freed) = evict_leaf(&mut n.children, arena, bs, target) {
            return Some(freed);
        }
    }
    None
}

impl Drop for PrefixIndex {
    /// Release every held block back to the arena (shared blocks stay
    /// resident for the caches still holding them). Dropping the index at
    /// engine-loop exit is what lets `blocks_in_use` drain to zero after
    /// shutdown.
    fn drop(&mut self) {
        let mut stack = std::mem::take(&mut self.children);
        while let Some(mut n) = stack.pop() {
            stack.append(&mut n.children);
            self.arena.release(std::mem::take(&mut n.blocks));
        }
        self.blocks_held = 0;
    }
}
