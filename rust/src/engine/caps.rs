//! Capability descriptor for scorers/backends.
//!
//! Before the engine existed, the [`crate::eval::Scorer`] trait grew one
//! probe method per capability (`fixed_geometry`, `supports_cache`,
//! `supports_prefix_reuse`, …) and every caller re-interrogated the
//! booleans it cared about. [`EngineCaps`] replaces that sprawl: a
//! backend declares *once* what it can do, and the scheduler/eval paths
//! consult the one descriptor.

/// What a scorer implementation can execute. Returned once by
/// [`crate::eval::Scorer::caps`]; the engine's admission scheduler and
/// the eval harness branch on the descriptor instead of probing
/// per-capability methods.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineCaps {
    /// Only the exact lowered geometry is accepted — `batch.len() ==
    /// dims().batch`, every sequence exactly `dims().seq` tokens (the HLO
    /// artifact path). Ragged scorers take any batch of any lengths
    /// `<= dims().seq` directly.
    pub fixed_geometry: bool,
    /// Incremental KV-cache forwards ([`crate::eval::Scorer::cache_forward`]
    /// and the batched variant) are implemented — the engine can admit
    /// `Generate` requests and run chunked prefill + decode steps.
    pub incremental: bool,
    /// [`crate::eval::Scorer::score_choices`] prefills a shared prompt
    /// once and scores each choice suffix against the cached prefix
    /// (`mc_accuracy` routes per-item when set).
    pub prefix_reuse: bool,
}

impl EngineCaps {
    /// A ragged batch scorer with no cache support (the trait default).
    pub fn ragged() -> EngineCaps {
        EngineCaps::default()
    }

    /// The fixed-geometry HLO artifact path: exact `[batch, seq]` token
    /// buffers, no incremental execution.
    pub fn fixed() -> EngineCaps {
        EngineCaps { fixed_geometry: true, ..EngineCaps::default() }
    }

    /// A native cache-capable scorer: ragged batches, incremental
    /// decode, and prefix-reuse choice scoring.
    pub fn incremental() -> EngineCaps {
        EngineCaps { fixed_geometry: false, incremental: true, prefix_reuse: true }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_declare_coherent_capability_sets() {
        let r = EngineCaps::ragged();
        assert!(!r.fixed_geometry && !r.incremental && !r.prefix_reuse);
        let f = EngineCaps::fixed();
        assert!(f.fixed_geometry && !f.incremental && !f.prefix_reuse);
        let i = EngineCaps::incremental();
        assert!(!i.fixed_geometry && i.incremental && i.prefix_reuse);
    }
}
