//! Seeded, deterministic serving-workload traces.
//!
//! `probe_throughput`'s uniform mix is not production traffic. This
//! module generates the kind that kills schedulers: Poisson or ON-OFF
//! **bursty** arrivals, heavy-tailed (bounded-Pareto) prompt and
//! generation lengths, and multiple tenant classes with distinct
//! [`Priority`] levels — all replayable **bit-for-bit** from a seed, so
//! every admission/routing policy change is measurable against the
//! exact same traffic.
//!
//! Three layers, pure to impure:
//!
//! 1. [`generate_trace`] — a pure function of [`TraceConfig`]: the same
//!    seed always yields the identical `Vec<TraceEvent>`.
//! 2. [`OverloadSim`] — a virtual-time mirror of the engine's admission
//!    policy (token buckets, watermark shedding lowest-priority-first,
//!    least-loaded routing). Pure function of (sim config, trace):
//!    identical inputs yield identical [`Decision`] sequences, which is
//!    what "the same seed replays to identical admission/shed/route
//!    decisions" pins in tests without depending on wall-clock timing.
//! 3. [`replay_trace`] — drives a live [`EngineClient`] with the trace
//!    (scaled inter-arrival sleeps), classifying every answer into a
//!    per-tenant [`TenantStats`] via the typed [`Overloaded`] error.
//!
//! The live engine's decisions depend on real thread timing, so layer 3
//! asserts *behavioral invariants* (everything resolves, shedding hits
//! low priority first, arenas drain); bit-exact replay determinism is
//! layer 1+2's job.

use std::cmp::Reverse;
use std::collections::BTreeMap;
use std::collections::HashMap;
use std::collections::VecDeque;
use std::time::Duration;

use super::core::EngineClient;
use super::request::{OverloadKind, Overloaded, Priority, SubmitOptions};
use super::sampling::SamplingParams;
use crate::tensor::Rng;

/// Arrival process of a trace.
#[derive(Clone, Debug, PartialEq)]
pub enum Arrivals {
    /// Memoryless arrivals at `rate` requests/second.
    Poisson { rate: f64 },
    /// Alternating ON/OFF phases (the classic bursty model): Poisson at
    /// `on_rate` for `on_secs`, then at `off_rate` (often 0) for
    /// `off_secs`, repeating. Bursts are what expose watermark/brownout
    /// behavior a steady Poisson stream never triggers.
    OnOff { on_rate: f64, off_rate: f64, on_secs: f64, off_secs: f64 },
}

/// Bounded-Pareto length distribution over `[lo, hi]` with tail index
/// `alpha` (smaller `alpha` = heavier tail). Production prompt/output
/// lengths are heavy-tailed; the bound keeps every sample inside the
/// model window.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BoundedPareto {
    pub alpha: f64,
    pub lo: usize,
    pub hi: usize,
}

impl BoundedPareto {
    /// Inverse-CDF sample, clamped into `[lo, hi]` (`lo` floors at 1).
    fn sample(&self, rng: &mut Rng) -> usize {
        let lo = self.lo.max(1) as f64;
        let hi = self.hi.max(self.lo.max(1)) as f64;
        if hi <= lo {
            return lo as usize;
        }
        let a = if self.alpha > 0.0 { self.alpha } else { 1.0 };
        let u = rng.next_f64();
        let la = lo.powf(-a);
        let ha = hi.powf(-a);
        let x = (la - u * (la - ha)).powf(-1.0 / a);
        (x as usize).clamp(lo as usize, hi as usize)
    }
}

/// One tenant class of the trace mix.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantClass {
    /// Billing identity carried on [`SubmitOptions::tenant`].
    pub name: String,
    /// Scheduling class carried on [`SubmitOptions::priority`].
    pub priority: Priority,
    /// Relative share of arrivals this class receives.
    pub weight: f64,
}

/// Everything [`generate_trace`] needs; equal configs generate equal
/// traces.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceConfig {
    pub seed: u64,
    /// Trace horizon in (virtual) seconds.
    pub duration_secs: f64,
    pub arrivals: Arrivals,
    pub tenants: Vec<TenantClass>,
    /// Prompt-length distribution (tokens).
    pub prompt: BoundedPareto,
    /// Generation-length (`max_new`) distribution (tokens).
    pub gen: BoundedPareto,
    /// Vocabulary size prompt tokens are drawn from.
    pub vocab: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            seed: 0,
            duration_secs: 10.0,
            arrivals: Arrivals::Poisson { rate: 8.0 },
            tenants: vec![TenantClass {
                name: "default".to_string(),
                priority: Priority::Normal,
                weight: 1.0,
            }],
            prompt: BoundedPareto { alpha: 1.5, lo: 4, hi: 64 },
            gen: BoundedPareto { alpha: 1.5, lo: 2, hi: 32 },
            vocab: 256,
        }
    }
}

/// One request of a generated trace.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Arrival offset from trace start, in virtual seconds (ascending).
    pub at_secs: f64,
    pub tenant: String,
    pub priority: Priority,
    pub prompt: Vec<u32>,
    pub max_new: usize,
}

/// Exponential inter-arrival gap at `rate` (memoryless). A zero/negative
/// rate yields `f64::INFINITY` — "no arrivals in this phase".
fn exp_gap(rng: &mut Rng, rate: f64) -> f64 {
    if rate <= 0.0 {
        return f64::INFINITY;
    }
    let u = rng.next_f64();
    -(1.0 - u).ln() / rate
}

/// Generate the full trace for `cfg` — a pure function: the same config
/// (seed included) always produces the identical event list.
pub fn generate_trace(cfg: &TraceConfig) -> Vec<TraceEvent> {
    let mut rng = Rng::seed(cfg.seed);
    let weights: Vec<f64> = cfg.tenants.iter().map(|t| t.weight.max(0.0)).collect();
    let vocab = cfg.vocab.max(2);
    let mut out = Vec::new();
    let mut t = 0.0_f64;
    // ON-OFF phase tracking (ignored for Poisson)
    let mut phase_on = true;
    // phase spans floor at 1ms so degenerate configs (0-length phases)
    // still advance virtual time and the generator always terminates
    let mut phase_end = match cfg.arrivals {
        Arrivals::Poisson { .. } => f64::INFINITY,
        Arrivals::OnOff { on_secs, .. } => on_secs.max(1e-3),
    };
    while t < cfg.duration_secs {
        let rate = match cfg.arrivals {
            Arrivals::Poisson { rate } => rate,
            Arrivals::OnOff { on_rate, off_rate, .. } => {
                if phase_on {
                    on_rate
                } else {
                    off_rate
                }
            }
        };
        let next = t + exp_gap(&mut rng, rate);
        if next >= phase_end {
            // the draw crosses a phase boundary: jump to the boundary
            // and redraw under the new phase's rate (valid by the
            // exponential's memorylessness, and far simpler than
            // thinning)
            match cfg.arrivals {
                // a Poisson trace has no boundary: this is `rate <= 0`,
                // which never arrives — an empty trace, not a spin
                Arrivals::Poisson { .. } => break,
                Arrivals::OnOff { on_secs, off_secs, .. } => {
                    t = phase_end;
                    phase_on = !phase_on;
                    let span = if phase_on { on_secs } else { off_secs };
                    phase_end += span.max(1e-3);
                }
            }
            continue;
        }
        t = next;
        if t >= cfg.duration_secs {
            break;
        }
        let class = cfg
            .tenants
            .get(rng.sample_weighted(&weights))
            .cloned()
            .unwrap_or_else(|| TenantClass {
                name: "default".to_string(),
                priority: Priority::Normal,
                weight: 1.0,
            });
        let plen = cfg.prompt.sample(&mut rng);
        let prompt: Vec<u32> = (0..plen).map(|_| rng.below(vocab) as u32).collect();
        let max_new = cfg.gen.sample(&mut rng);
        out.push(TraceEvent {
            at_secs: t,
            tenant: class.name,
            priority: class.priority,
            prompt,
            max_new,
        });
    }
    out
}

/// Admission-policy knobs of the virtual-time simulator — the same
/// shape as the corresponding [`super::EngineConfig`] fields.
#[derive(Clone, Debug, PartialEq)]
pub struct SimConfig {
    pub n_replicas: usize,
    /// Per-replica queue capacity (the watermark's denominator).
    pub queue_cap: usize,
    /// Fraction of `queue_cap` at which shedding engages (`<= 0` off).
    pub shed_watermark: f64,
    /// Token-bucket refill in requests/sec per tenant (`<= 0` off).
    pub tenant_rate: f64,
    /// Bucket capacity (`<= 0` defaults to `max(tenant_rate, 1)`).
    pub tenant_burst: f64,
    /// Requests/second one replica completes (virtual drain rate).
    pub service_rate: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            n_replicas: 1,
            queue_cap: 32,
            shed_watermark: 0.0,
            tenant_rate: 0.0,
            tenant_burst: 0.0,
            service_rate: 16.0,
        }
    }
}

/// What the simulator decided for one [`TraceEvent`], in trace order.
#[derive(Clone, Debug, PartialEq)]
pub enum Decision {
    /// Admitted onto `replica`'s queue.
    Admit { replica: usize },
    /// Rejected by `tenant`'s empty token bucket.
    RateLimited { tenant: String },
    /// Watermark shed: nothing cheaper was queued, the arrival itself
    /// was answered [`Overloaded`].
    ShedArrival { priority: Priority },
    /// Watermark shed: admitted onto `replica` by displacing its
    /// youngest queued job of the (strictly lower) `victim` class.
    Displace { replica: usize, victim: Priority },
}

/// Virtual-time mirror of the engine's admission control: per-tenant
/// token buckets, high-watermark shedding (lowest-priority-first,
/// youngest-of-class victim), and least-loaded routing. Time is the
/// trace's own `at_secs`, so runs are a pure function of
/// `(SimConfig, trace)` — no threads, no clocks — which makes the
/// "same seed, identical decisions" acceptance criterion assertable as
/// plain `Vec` equality.
///
/// The sim intentionally models *queues*, not decode slots: it mirrors
/// the policy's decision shape, not the engine's token-level schedule.
pub struct OverloadSim {
    cfg: SimConfig,
}

impl OverloadSim {
    pub fn new(cfg: SimConfig) -> OverloadSim {
        OverloadSim { cfg }
    }

    /// Run the trace through the admission mirror, one [`Decision`] per
    /// event.
    pub fn run(&self, trace: &[TraceEvent]) -> Vec<Decision> {
        let n = self.cfg.n_replicas.max(1);
        let cap = self.cfg.queue_cap.max(1);
        let shed_at = if self.cfg.shed_watermark <= 0.0 {
            usize::MAX
        } else {
            ((cap as f64 * self.cfg.shed_watermark).ceil() as usize).clamp(1, cap)
        };
        let burst = if self.cfg.tenant_burst > 0.0 {
            self.cfg.tenant_burst
        } else {
            self.cfg.tenant_rate.max(1.0)
        };
        // per-replica queue of priorities (front = oldest) + drain credit
        let mut queues: Vec<VecDeque<Priority>> = (0..n).map(|_| VecDeque::new()).collect();
        let mut credit: Vec<f64> = vec![0.0; n];
        let mut last = 0.0_f64;
        // tenant → (bucket level, last refill time)
        let mut buckets: HashMap<String, (f64, f64)> = HashMap::new();
        let mut out = Vec::with_capacity(trace.len());
        for ev in trace {
            let now = ev.at_secs.max(last);
            // drain every replica by elapsed virtual time
            let dt = now - last;
            for (q, c) in queues.iter_mut().zip(credit.iter_mut()) {
                *c += dt * self.cfg.service_rate.max(0.0);
                while *c >= 1.0 && !q.is_empty() {
                    q.pop_front();
                    *c -= 1.0;
                }
                if q.is_empty() {
                    // credit does not bank across idle periods
                    *c = c.min(1.0);
                }
            }
            last = now;
            // token bucket (mirrors `TenantBuckets::try_take`)
            if self.cfg.tenant_rate > 0.0 {
                let (level, at) = buckets
                    .entry(ev.tenant.clone())
                    .or_insert((burst, now));
                *level = (*level + (now - *at) * self.cfg.tenant_rate).min(burst);
                *at = now;
                if *level >= 1.0 {
                    *level -= 1.0;
                } else {
                    out.push(Decision::RateLimited { tenant: ev.tenant.clone() });
                    continue;
                }
            }
            // least-loaded routing (ties → lowest index, like LoadAware)
            let ri = queues
                .iter()
                .enumerate()
                .min_by_key(|(_, q)| q.len())
                .map(|(i, _)| i)
                .unwrap_or(0);
            let depth = queues.get(ri).map(VecDeque::len).unwrap_or(0);
            if depth >= shed_at {
                // watermark: displace the youngest of the lowest class,
                // only if strictly below the arrival's priority
                let victim = queues.get(ri).and_then(|q| {
                    q.iter()
                        .enumerate()
                        .min_by_key(|(i, p)| (**p, Reverse(*i)))
                        .filter(|(_, p)| **p < ev.priority)
                        .map(|(i, p)| (i, *p))
                });
                match victim {
                    Some((vi, vp)) => {
                        if let Some(q) = queues.get_mut(ri) {
                            q.remove(vi);
                            q.push_back(ev.priority);
                        }
                        out.push(Decision::Displace { replica: ri, victim: vp });
                    }
                    None => out.push(Decision::ShedArrival { priority: ev.priority }),
                }
                continue;
            }
            if let Some(q) = queues.get_mut(ri) {
                q.push_back(ev.priority);
            }
            out.push(Decision::Admit { replica: ri });
        }
        out
    }
}

/// Per-tenant outcome counters of a live [`replay_trace`] run.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TenantStats {
    pub submitted: usize,
    /// Completed `Ok` (token count alongside).
    pub ok: usize,
    pub tokens: usize,
    /// Typed [`Overloaded`] with [`OverloadKind::QueueFull`].
    pub shed: usize,
    /// Typed [`Overloaded`] with [`OverloadKind::RateLimited`].
    pub rate_limited: usize,
    /// Deadline expiries (queue sheds and mid-generation aborts).
    pub deadline: usize,
    /// Everything else (validation, retries exhausted, shutdown).
    pub other_err: usize,
}

/// Outcome of replaying a trace against a live engine.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TraceOutcome {
    /// Keyed by tenant name (BTreeMap: deterministic iteration).
    pub tenants: BTreeMap<String, TenantStats>,
}

impl TraceOutcome {
    pub fn tenant(&self, name: &str) -> TenantStats {
        self.tenants.get(name).cloned().unwrap_or_default()
    }

    /// Sum of a stat across tenants, for whole-run assertions.
    pub fn total(&self, f: impl Fn(&TenantStats) -> usize) -> usize {
        self.tenants.values().map(f).sum()
    }

    /// Every submission resolved into exactly one counter?
    pub fn fully_resolved(&self) -> bool {
        self.tenants.values().all(|t| {
            t.ok + t.shed + t.rate_limited + t.deadline + t.other_err == t.submitted
        })
    }
}

/// Replay `trace` against a live engine through the normal
/// [`EngineClient`] surface. Inter-arrival gaps are multiplied by
/// `time_scale` (`0.0` = fire as fast as possible); `deadline`, when
/// set, rides on every submission. Blocks until every answer lands
/// (bounded by `wait_timeout`, so a wedged engine fails fast instead
/// of hanging the harness) and classifies each into [`TenantStats`].
pub fn replay_trace(
    client: &EngineClient,
    trace: &[TraceEvent],
    time_scale: f64,
    deadline: Option<Duration>,
) -> TraceOutcome {
    let mut outcome = TraceOutcome::default();
    let mut pending = Vec::with_capacity(trace.len());
    let mut prev = 0.0_f64;
    for ev in trace {
        if time_scale > 0.0 {
            let gap = (ev.at_secs - prev).max(0.0) * time_scale;
            if gap > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(gap.min(1.0)));
            }
            prev = ev.at_secs;
        }
        let mut opts = SubmitOptions::default().priority(ev.priority).tenant(ev.tenant.clone());
        if let Some(d) = deadline {
            opts = opts.deadline(d);
        }
        let stats = outcome.tenants.entry(ev.tenant.clone()).or_default();
        stats.submitted += 1;
        match client.generate_with(
            ev.prompt.clone(),
            SamplingParams::greedy(ev.max_new.max(1)),
            &opts,
        ) {
            Ok(p) => pending.push((ev.tenant.clone(), p)),
            Err(_) => stats.other_err += 1,
        }
    }
    for (tenant, p) in pending {
        let stats = outcome.tenants.entry(tenant).or_default();
        match p.wait_timeout(Duration::from_secs(60)) {
            Ok(g) => {
                stats.ok += 1;
                stats.tokens += g.tokens.len();
            }
            Err(e) => match e.downcast_ref::<Overloaded>() {
                Some(o) if o.kind == OverloadKind::RateLimited => stats.rate_limited += 1,
                Some(_) => stats.shed += 1,
                None if format!("{e}").contains("deadline") => stats.deadline += 1,
                None => stats.other_err += 1,
            },
        }
    }
    outcome
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_class_cfg(seed: u64) -> TraceConfig {
        TraceConfig {
            seed,
            duration_secs: 20.0,
            arrivals: Arrivals::OnOff {
                on_rate: 40.0,
                off_rate: 2.0,
                on_secs: 2.0,
                off_secs: 3.0,
            },
            tenants: vec![
                TenantClass { name: "paid".into(), priority: Priority::High, weight: 0.2 },
                TenantClass { name: "free".into(), priority: Priority::Low, weight: 0.8 },
            ],
            prompt: BoundedPareto { alpha: 1.2, lo: 4, hi: 48 },
            gen: BoundedPareto { alpha: 1.5, lo: 2, hi: 16 },
            vocab: 128,
        }
    }

    #[test]
    fn same_seed_replays_bit_for_bit() {
        let cfg = two_class_cfg(42);
        let a = generate_trace(&cfg);
        let b = generate_trace(&cfg);
        assert_eq!(a, b, "trace generation must be a pure function of the config");
        assert!(!a.is_empty());
        let sim = OverloadSim::new(SimConfig {
            n_replicas: 2,
            queue_cap: 8,
            shed_watermark: 0.75,
            tenant_rate: 10.0,
            tenant_burst: 4.0,
            service_rate: 10.0,
        });
        assert_eq!(sim.run(&a), sim.run(&b), "identical admission/shed/route decisions");
        // a different seed produces a different trace
        let c = generate_trace(&two_class_cfg(43));
        assert_ne!(a, c);
    }

    #[test]
    fn traces_are_ordered_bounded_and_mixed() {
        let cfg = two_class_cfg(7);
        let trace = generate_trace(&cfg);
        let mut prev = 0.0;
        for ev in &trace {
            assert!(ev.at_secs >= prev && ev.at_secs < cfg.duration_secs);
            prev = ev.at_secs;
            assert!((cfg.prompt.lo..=cfg.prompt.hi).contains(&ev.prompt.len()));
            assert!((cfg.gen.lo..=cfg.gen.hi).contains(&ev.max_new));
            assert!(ev.prompt.iter().all(|&t| (t as usize) < cfg.vocab));
        }
        let paid = trace.iter().filter(|e| e.tenant == "paid").count();
        let free = trace.iter().filter(|e| e.tenant == "free").count();
        assert!(paid > 0 && free > 0, "both classes appear (paid={paid} free={free})");
        assert!(free > paid, "weights steer the mix");
        assert!(
            trace.iter().all(|e| (e.tenant == "paid") == (e.priority == Priority::High)),
            "priority rides with the class"
        );
    }

    #[test]
    fn poisson_arrival_count_tracks_the_rate() {
        let cfg = TraceConfig {
            seed: 11,
            duration_secs: 50.0,
            arrivals: Arrivals::Poisson { rate: 10.0 },
            ..TraceConfig::default()
        };
        let n = generate_trace(&cfg).len() as f64;
        let expect = 10.0 * 50.0;
        assert!(
            (n - expect).abs() < expect * 0.2,
            "got {n} arrivals, expected ~{expect}"
        );
    }

    #[test]
    fn onoff_bursts_cluster_in_on_phases() {
        let cfg = TraceConfig {
            seed: 5,
            duration_secs: 30.0,
            arrivals: Arrivals::OnOff {
                on_rate: 30.0,
                off_rate: 0.0,
                on_secs: 1.0,
                off_secs: 4.0,
            },
            ..TraceConfig::default()
        };
        let trace = generate_trace(&cfg);
        assert!(!trace.is_empty());
        // with off_rate 0 every arrival must land inside an ON window
        for ev in &trace {
            let phase = ev.at_secs % 5.0;
            assert!(phase < 1.0, "arrival at {:.3}s is outside every ON phase", ev.at_secs);
        }
    }

    #[test]
    fn bounded_pareto_is_heavy_tailed_within_bounds() {
        let mut rng = Rng::seed(3);
        let d = BoundedPareto { alpha: 1.1, lo: 4, hi: 512 };
        let xs: Vec<usize> = (0..4000).map(|_| d.sample(&mut rng)).collect();
        assert!(xs.iter().all(|&x| (4..=512).contains(&x)));
        let small = xs.iter().filter(|&&x| x <= 16).count();
        let big = xs.iter().filter(|&&x| x >= 128).count();
        assert!(small > xs.len() / 2, "most mass near lo (small={small})");
        assert!(big > 0, "but the tail reaches far (big={big})");
        // degenerate bounds collapse to a point
        let point = BoundedPareto { alpha: 1.5, lo: 8, hi: 8 };
        assert_eq!(point.sample(&mut rng), 8);
    }

    #[test]
    fn sim_sheds_low_priority_first_under_overload() {
        let cfg = two_class_cfg(21);
        let trace = generate_trace(&cfg);
        // The queue is sized so the watermark strictly exceeds the high
        // class's TOTAL event count — then a queue at the shed mark can
        // never be all-High (even if every paid event sat in it), so an
        // over-watermark High arrival always finds a Low victim and the
        // "never shed the high class" assertion is structural, not a
        // timing accident. (An undersized queue genuinely can fill with
        // displaced-into Highs and shed a High arrival — the policy is
        // working as specified there; it is the config that has already
        // spent its entire priority budget.) serve-bench sizes its
        // overload fleet with the same rule.
        let paid = trace.iter().filter(|e| e.priority == Priority::High).count();
        let sim = OverloadSim::new(SimConfig {
            n_replicas: 2,
            queue_cap: (paid + 4) * 4 / 3 + 1,
            shed_watermark: 0.75,
            tenant_rate: 0.0,
            tenant_burst: 0.0,
            // far below the 40 rps ON-phase rate: a genuine deep overload
            service_rate: 1.0,
        });
        let decisions = sim.run(&trace);
        let sheds = decisions
            .iter()
            .filter(|d| matches!(d, Decision::ShedArrival { .. } | Decision::Displace { .. }))
            .count();
        assert!(sheds > 0, "the overload trace must actually shed");
        for d in &decisions {
            match d {
                Decision::Displace { victim, .. } => {
                    assert_eq!(*victim, Priority::Low, "only the low class is displaced")
                }
                Decision::ShedArrival { priority } => {
                    assert_eq!(*priority, Priority::Low, "high arrivals displace, never shed")
                }
                _ => {}
            }
        }
    }

    #[test]
    fn sim_rate_limits_only_the_flooding_tenant() {
        let cfg = two_class_cfg(31);
        let trace = generate_trace(&cfg);
        let sim = OverloadSim::new(SimConfig {
            n_replicas: 2,
            queue_cap: 64,
            shed_watermark: 0.0,
            tenant_rate: 2.0,
            tenant_burst: 2.0,
            service_rate: 1000.0,
        });
        let limited: Vec<&str> = sim
            .run(&trace)
            .iter()
            .filter_map(|d| match d {
                Decision::RateLimited { tenant } => Some(tenant.as_str()),
                _ => None,
            })
            .map(|t| if t == "free" { "free" } else { "paid" })
            .collect();
        assert!(!limited.is_empty(), "2 rps cannot carry an ON-phase burst");
        let free = limited.iter().filter(|t| **t == "free").count();
        assert!(
            free * 2 > limited.len(),
            "the heavier class eats most rate-limit rejections ({free}/{})",
            limited.len()
        );
    }

    #[test]
    fn sim_admits_everything_when_no_limits_are_set() {
        let cfg = two_class_cfg(9);
        let trace = generate_trace(&cfg);
        let sim = OverloadSim::new(SimConfig {
            n_replicas: 3,
            queue_cap: 1_000_000,
            shed_watermark: 0.0,
            tenant_rate: 0.0,
            tenant_burst: 0.0,
            service_rate: 0.0,
        });
        let decisions = sim.run(&trace);
        assert_eq!(decisions.len(), trace.len());
        assert!(decisions.iter().all(|d| matches!(d, Decision::Admit { .. })));
    }

    #[test]
    fn trace_outcome_partition_accounting() {
        let mut o = TraceOutcome::default();
        let s = o.tenants.entry("t".to_string()).or_default();
        s.submitted = 5;
        s.ok = 2;
        s.shed = 1;
        s.rate_limited = 1;
        s.deadline = 1;
        assert!(o.fully_resolved());
        assert_eq!(o.tenant("t").ok, 2);
        assert_eq!(o.tenant("missing"), TenantStats::default());
        assert_eq!(o.total(|t| t.submitted), 5);
        if let Some(s) = o.tenants.get_mut("t") {
            s.other_err = 3;
        }
        assert!(!o.fully_resolved(), "over-counting is caught");
    }
}
