//! The request-lifecycle engine: typed admission, two-queue scheduling,
//! chunked prefill, lockstep decode, streaming delivery, and the
//! fault-tolerance layer (deadlines, cancellation, replica failover).
//!
//! One [`Engine`] owns one supervised loop thread per scorer replica.
//! Each loop iteration is one scheduler round:
//!
//! 1. **intake** — drain the bounded submission channel into two
//!    internal queues (score/choices work vs. generations waiting for a
//!    decode slot), validating at admission so malformed requests are
//!    answered immediately without touching the model. Because waiting
//!    generations park in their own queue, score traffic behind them is
//!    *not* head-of-line blocked while every decode slot is full;
//! 2. **reap** — shed cancelled or deadline-expired work before it
//!    costs a forward: queued jobs past their deadline are answered
//!    `Err` without ever reaching the model, an abandoned or expired
//!    generation is aborted at this step boundary and its arena blocks
//!    freed (see [`Pending::cancel`] and [`SubmitOptions::deadline`]);
//! 3. **promote** — move waiting generations into free decode slots
//!    (at most [`EngineConfig::max_active`] resident sequences),
//!    resuming preempted generations ahead of fresh admissions. Every
//!    candidate is gated on the replica's [`KvArena`] having blocks for
//!    its next prefill chunk beyond what the already-active set needs
//!    for its own next step (promotion never forces an eviction) —
//!    residency is priced at blocks *actually held*, not `max_active ×`
//!    the full-window worst case;
//! 4. **score** — one coalesced `score_batch` over up to
//!    [`EngineConfig::max_batch`] queued scoring requests (plus any
//!    choice-scoring jobs, which prefix-reuse backends run with one
//!    prompt prefill each);
//! 5. **step** — one fused forward over every active generation: decode
//!    sequences contribute their last sampled token, sequences still
//!    prefilling contribute their next [`EngineConfig::prefill_chunk`]
//!    prompt tokens. Chunking bounds the rows any single iteration
//!    forwards, so a long prompt cannot stall decode steps (or newly
//!    admitted traffic) behind one monolithic prefill — and because
//!    every kernel in the forward is row-independent, chunked prefill
//!    is bitwise identical to the one-shot prefill. If the step's block
//!    growth would overrun the arena, the scheduler first **preempts**
//!    the longest generation (ties broken toward the least replay
//!    progress, so an eviction never destroys the replay closest to
//!    sampling) — its blocks return to the pool and it later resumes by
//!    replaying `prompt ++ sampled` through chunked prefill, which is
//!    bit-exact with never having been evicted.
//!
//! **Failure handling.** Every scorer call runs under a catch-unwind
//! guard, so a panicking or erring scorer never kills the loop thread:
//! the fault is recorded in the fleet's shared [`HealthView`] (a panic
//! marks the replica unhealthy immediately; plain `Err`s after
//! [`EngineConfig::unhealthy_after`] consecutive failures), and the
//! affected work is retried with bounded exponential backoff
//! ([`EngineConfig::max_retries`] / [`EngineConfig::retry_backoff`]).
//! Score/Choices jobs are idempotent and simply re-queue — locally
//! while the replica stays healthy, otherwise handed to a healthy peer
//! over the same submission channels. A mid-decode generation first
//! preempts (freeing its blocks; a torn half-appended cache is cleared
//! wholesale, so arena accounting stays exact) and then either resumes
//! locally or fails over to a peer via [`Msg::Resume`], carrying the
//! prompt, the sampled-so-far tokens, and the live RNG state — the
//! PR-6 replay path, so a failed-over generation is bitwise identical
//! to one that never saw a fault (replicas serve identical weights).
//! Work that exhausts its retry budget, and work that no healthy
//! replica can take, resolves `Err`; a [`Pending`] never hangs.
//!
//! Sampled tokens stream to [`TokenStream`] subscribers the moment they
//! are committed; the final [`Generated`] answer arrives on the
//! request's [`Pending`].

use std::cmp::Reverse;
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, Sender, SyncSender, TryRecvError, TrySendError,
};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::serve::ServeSummary;
use crate::coordinator::Metrics;
use crate::eval::scorer::{check_input, check_seq};
use crate::eval::Scorer;
use crate::model::kv::{KvArena, KvCache, DEFAULT_BLOCK_POSITIONS};
use crate::model::ModelDims;
use crate::tensor::Rng;

use super::dispatch::{Dispatch, LoadAware, LoadView, PrefixAffinity, RoundRobin};
use super::health::HealthView;
use super::prefix::PrefixIndex;
use super::request::{
    CancelCell, Generated, OverloadKind, Overloaded, Pending, Priority, Request, Response,
    SubmitOptions, TokenEvent, TokenStream,
};
use super::sampling::{sample_token, SamplingParams};

/// Engine scheduling knobs.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Coalesce at most this many scoring requests into one forward.
    pub max_batch: usize,
    /// Bounded submission-queue depth (backpressure: submit blocks
    /// beyond it). Also caps each internal waiting queue, so engine
    /// memory stays constant no matter how fast clients push.
    pub queue_capacity: usize,
    /// Maximum concurrently resident decode sequences (KV caches).
    /// Excess generations wait in the admission queue — without
    /// blocking score traffic behind them.
    pub max_active: usize,
    /// Prefill slice size in tokens: long prompts enter the KV cache in
    /// chunks of this many tokens, interleaved with decode steps of the
    /// other active sequences (`0` = unchunked single-shot prefill).
    pub prefill_chunk: usize,
    /// Positions per KV arena block (`0` = the
    /// [`crate::model::kv::DEFAULT_BLOCK_POSITIONS`] default). Smaller
    /// blocks track actual residency more tightly at the cost of more
    /// block-table entries per sequence.
    pub kv_block: usize,
    /// Total blocks in the per-replica KV arena (`0` = auto: enough for
    /// `max_active` full-window sequences — the pre-paged worst case, so
    /// preemption never triggers). Sizing the arena *below* the worst
    /// case is the point of paging: short-sequence traffic packs more
    /// concurrent decodes into the same bytes, and the scheduler preempts
    /// (evict + bit-exact re-prefill) on the rare burst that overflows.
    pub arena_blocks: usize,
    /// Deadline applied to every submission that does not carry its own
    /// [`SubmitOptions::deadline`] (`None` = no default deadline).
    /// Expired queued work is shed with `Err` before any forward; an
    /// expired generation is aborted at the next step boundary and its
    /// arena blocks freed.
    pub default_deadline: Option<Duration>,
    /// Retry budget per request for scorer faults (`Err` returns and
    /// caught panics). Score/Choices retries re-run the idempotent
    /// forward; a generation retry resumes via the bit-exact replay
    /// path. `0` disables retries: the first fault resolves the
    /// request `Err`.
    pub max_retries: usize,
    /// Consecutive scorer `Err`s before the replica is marked unhealthy
    /// in the fleet's [`HealthView`] (a caught panic marks it
    /// immediately). Values below 1 behave as 1.
    pub unhealthy_after: usize,
    /// Base retry backoff: attempt `n` waits `retry_backoff · 2^(n-1)`,
    /// capped at 100ms (`Duration::ZERO` disables the wait). The sleep
    /// happens on the engine loop between rounds, so it also rate-limits
    /// how fast a persistently failing scorer is re-asked.
    pub retry_backoff: Duration,
    /// Keep a cross-request radix prefix index
    /// ([`crate::engine::PrefixIndex`]) over committed KV blocks, so a
    /// prompt sharing a block-aligned prefix with earlier traffic
    /// attaches the cached blocks and prefills only its suffix (bitwise
    /// identical to a cold prefill). Costs nothing when no prefix ever
    /// repeats; disable to reserve every arena block for live sequences.
    pub prefix_cache: bool,
    /// Queue high-watermark as a fraction of each waiting queue's
    /// capacity (`0.0` disables shedding — arrivals beyond the cap block
    /// in the bounded channel, the pre-PR-10 backpressure behavior).
    /// When a queue sits at or above `shed_watermark × capacity`, an
    /// arrival sheds the **lowest-priority** work instead of blocking:
    /// a queued entry of strictly lower [`Priority`] than the arrival
    /// is displaced (answered with a typed [`Overloaded`] error), ties
    /// shed the arrival itself so admitted work is never reordered
    /// within a class. A displaced request past its deadline counts in
    /// `serve.shed`, not `serve.overload_sheds` — deadline wins, each
    /// request is counted exactly once.
    pub shed_watermark: f64,
    /// Per-tenant token-bucket refill rate in requests/second (`0.0`
    /// disables tenant rate limiting). Each named
    /// [`SubmitOptions::tenant`] is charged one token at admission; an
    /// empty bucket answers a typed [`Overloaded`] error
    /// (`serve.rate_limited`). Buckets are **per replica** — the fleet-
    /// wide rate a tenant can sustain is `tenant_rate × healthy
    /// replicas`. Tenantless submissions are exempt (still subject to
    /// watermark shedding).
    pub tenant_rate: f64,
    /// Token-bucket capacity (burst allowance) per tenant. `0.0`
    /// defaults to one second of refill (`max(tenant_rate, 1)`).
    pub tenant_burst: f64,
    /// Brownout trigger: a generation backlog (waiting + preempted) at
    /// or above this for [`EngineConfig::brownout_after`] consecutive
    /// scheduler rounds enters brownout — [`Priority::Low`] generations
    /// are admitted with `max_new` capped at
    /// [`EngineConfig::brownout_max_new`] instead of being shed
    /// outright (`serve.brownouts` counts each capped admission). `0`
    /// disables brownout. The mode exits as soon as the backlog drops
    /// below the trigger.
    pub brownout_backlog: usize,
    /// Consecutive over-backlog rounds before brownout engages (values
    /// below 1 behave as 1) — a one-round spike never browns out.
    pub brownout_after: usize,
    /// `max_new` cap applied to low-priority generations admitted
    /// during brownout (values below 1 behave as 1).
    pub brownout_max_new: usize,
    /// Slow-replica watchdog: a timed forward longer than this counts
    /// in `serve.slow_forwards` and extends the replica's slow streak
    /// ([`HealthView::slow_streak`] — load-aware dispatch deprioritizes
    /// streaking replicas). `Duration::ZERO` disables the watchdog.
    pub slow_forward_threshold: Duration,
    /// Consecutive slow forwards before the replica is marked
    /// unhealthy — sticky, mirroring
    /// [`EngineConfig::unhealthy_after`]. `0` never trips (the streak
    /// still feeds dispatch penalties).
    pub slow_streak_limit: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_batch: 8,
            queue_capacity: 32,
            max_active: 8,
            prefill_chunk: 32,
            kv_block: 0,
            arena_blocks: 0,
            default_deadline: None,
            max_retries: 2,
            unhealthy_after: 3,
            retry_backoff: Duration::from_millis(1),
            prefix_cache: true,
            shed_watermark: 0.0,
            tenant_rate: 0.0,
            tenant_burst: 0.0,
            brownout_backlog: 0,
            brownout_after: 2,
            brownout_max_new: 4,
            slow_forward_threshold: Duration::ZERO,
            slow_streak_limit: 3,
        }
    }
}

/// Reply plumbing + bookkeeping shared by every job kind: when it was
/// submitted, when it must be answered by, how often it has been
/// retried, the out-of-band cancellation cell, and the response sender.
struct JobMeta {
    enqueued: Instant,
    deadline: Option<Instant>,
    retries: usize,
    /// Scheduling class: watermark shedding displaces the lowest
    /// priority first, brownout caps [`Priority::Low`] generations.
    priority: Priority,
    /// Billing identity for per-tenant token buckets (and the typed
    /// [`Overloaded`] error a shed answers with).
    tenant: Option<String>,
    cancel: Arc<CancelCell>,
    resp: Sender<Result<Response>>,
}

impl JobMeta {
    fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// One submission: the typed request plus its reply plumbing.
struct Submission {
    req: Request,
    meta: JobMeta,
    stream: Option<Sender<TokenEvent>>,
}

/// A generation failing over between replicas: everything needed to
/// resume it bit-exact on the receiver — the prompt, the sampled-so-far
/// tokens/logps, and the live RNG state. The receiver rebuilds the KV
/// prefix via the PR-6 replay path (chunked prefill of
/// `prompt ++ tokens[..k-1]`), which is bitwise identical to never
/// having moved, provided the replicas serve identical weights.
struct ResumeGen {
    prompt: Vec<u32>,
    tokens: Vec<u32>,
    logps: Vec<f32>,
    params: SamplingParams,
    rng: Rng,
    meta: JobMeta,
    stream: Option<Sender<TokenEvent>>,
}

enum Msg {
    Sub(Submission),
    Resume(Box<ResumeGen>),
    Shutdown,
}

/// Cheap, cloneable submission handle onto a running [`Engine`].
#[derive(Clone)]
pub struct EngineClient {
    txs: Vec<SyncSender<Msg>>,
    dispatch: Arc<dyn Dispatch>,
    metrics: Arc<Metrics>,
    health: Arc<HealthView>,
    default_deadline: Option<Duration>,
}

impl EngineClient {
    fn submit_raw(
        &self,
        req: Request,
        stream: Option<Sender<TokenEvent>>,
        opts: &SubmitOptions,
    ) -> Result<(Receiver<Result<Response>>, Arc<CancelCell>)> {
        let (resp, rx) = channel();
        if self.txs.is_empty() {
            return Err(anyhow!("engine stopped"));
        }
        // the Dispatch return value is a hint: an out-of-range or
        // unhealthy index re-routes to the next healthy replica instead
        // of being silently %-clamped into a slot whose loop may be dead
        let hint = self.dispatch.route(&req, &self.health);
        let replica = if hint < self.txs.len() && self.health.is_healthy(hint) {
            hint
        } else {
            self.health
                .next_healthy(hint % self.txs.len())
                .ok_or_else(|| anyhow!("engine has no healthy replica to take this request"))?
        };
        let now = Instant::now();
        let deadline =
            opts.deadline.or(self.default_deadline).and_then(|d| now.checked_add(d));
        let cancel = Arc::new(CancelCell::default());
        let meta = JobMeta {
            enqueued: now,
            deadline,
            retries: 0,
            priority: opts.priority,
            tenant: opts.tenant.clone(),
            cancel: cancel.clone(),
            resp,
        };
        self.metrics.gauge_add("serve.queue_depth", 1.0);
        let sent = match self.txs.get(replica) {
            Some(tx) => tx.send(Msg::Sub(Submission { req, meta, stream })),
            None => {
                self.metrics.gauge_add("serve.queue_depth", -1.0);
                return Err(anyhow!("engine stopped"));
            }
        };
        if sent.is_err() {
            self.metrics.gauge_add("serve.queue_depth", -1.0);
            return Err(anyhow!("engine stopped"));
        }
        Ok((rx, cancel))
    }

    /// Submit any [`Request`]; blocks while the bounded queue is full
    /// (backpressure), errs once the engine has shut down.
    pub fn submit(&self, req: Request) -> Result<Pending<Response>> {
        self.submit_with(req, &SubmitOptions::default())
    }

    /// [`EngineClient::submit`] with explicit per-request options.
    pub fn submit_with(&self, req: Request, opts: &SubmitOptions) -> Result<Pending<Response>> {
        let (rx, cancel) = self.submit_raw(req, None, opts)?;
        Ok(Pending::new(rx, cancel, Ok))
    }

    /// Enqueue a sequence for scoring.
    pub fn score(&self, tokens: Vec<u32>) -> Result<Pending<Vec<f32>>> {
        self.score_with(tokens, &SubmitOptions::default())
    }

    /// [`EngineClient::score`] with explicit per-request options.
    pub fn score_with(
        &self,
        tokens: Vec<u32>,
        opts: &SubmitOptions,
    ) -> Result<Pending<Vec<f32>>> {
        let (rx, cancel) = self.submit_raw(Request::Score { tokens }, None, opts)?;
        Ok(Pending::new(rx, cancel, Response::into_scored))
    }

    /// Enqueue choice scoring: per-choice log-probs of each candidate
    /// continuation of one shared prompt.
    pub fn choices(
        &self,
        prompt: Vec<u32>,
        choices: Vec<Vec<u32>>,
    ) -> Result<Pending<Vec<Vec<f32>>>> {
        self.choices_with(prompt, choices, &SubmitOptions::default())
    }

    /// [`EngineClient::choices`] with explicit per-request options.
    pub fn choices_with(
        &self,
        prompt: Vec<u32>,
        choices: Vec<Vec<u32>>,
        opts: &SubmitOptions,
    ) -> Result<Pending<Vec<Vec<f32>>>> {
        let (rx, cancel) =
            self.submit_raw(Request::Choices { prompt, choices }, None, opts)?;
        Ok(Pending::new(rx, cancel, Response::into_choices))
    }

    /// Enqueue a generation under `params` (greedy when
    /// `params.temperature == 0`).
    pub fn generate(&self, prompt: Vec<u32>, params: SamplingParams) -> Result<Pending<Generated>> {
        self.generate_with(prompt, params, &SubmitOptions::default())
    }

    /// [`EngineClient::generate`] with explicit per-request options.
    pub fn generate_with(
        &self,
        prompt: Vec<u32>,
        params: SamplingParams,
        opts: &SubmitOptions,
    ) -> Result<Pending<Generated>> {
        let (rx, cancel) =
            self.submit_raw(Request::Generate { prompt, params }, None, opts)?;
        Ok(Pending::new(rx, cancel, Response::into_generated))
    }

    /// Like [`EngineClient::generate`], but also deliver each token the
    /// moment it is sampled. The stream drains independently of the
    /// final answer; collected stream tokens always equal
    /// `Generated::tokens` of the paired [`Pending`].
    pub fn generate_stream(
        &self,
        prompt: Vec<u32>,
        params: SamplingParams,
    ) -> Result<(TokenStream, Pending<Generated>)> {
        self.generate_stream_with(prompt, params, &SubmitOptions::default())
    }

    /// [`EngineClient::generate_stream`] with explicit per-request
    /// options.
    pub fn generate_stream_with(
        &self,
        prompt: Vec<u32>,
        params: SamplingParams,
        opts: &SubmitOptions,
    ) -> Result<(TokenStream, Pending<Generated>)> {
        let (tx, rx) = channel();
        let (resp, cancel) =
            self.submit_raw(Request::Generate { prompt, params }, Some(tx), opts)?;
        Ok((TokenStream { rx }, Pending::new(resp, cancel, Response::into_generated)))
    }
}

/// The running engine: one supervised scheduler loop per scorer replica,
/// a shared metrics sink, a fleet [`HealthView`], and a [`Dispatch`]
/// policy placing submissions. Dropping the engine initiates shutdown:
/// requests already queued are drained and answered, later submissions
/// err.
pub struct Engine {
    txs: Option<Vec<SyncSender<Msg>>>,
    workers: Vec<JoinHandle<()>>,
    dispatch: Arc<dyn Dispatch>,
    metrics: Arc<Metrics>,
    health: Arc<HealthView>,
    load: Arc<LoadView>,
    affinity: Arc<PrefixAffinity>,
    arenas: Vec<Arc<KvArena>>,
    cfg: EngineConfig,
}

impl Engine {
    /// Spawn the engine over an owned scorer.
    pub fn start<S: Scorer + Send + Sync + 'static>(scorer: S, cfg: EngineConfig) -> Engine {
        Engine::start_shared(Arc::new(scorer), cfg)
    }

    /// Spawn the engine over a shared scorer (read-only at serving time).
    pub fn start_shared(scorer: Arc<dyn Scorer + Send + Sync>, cfg: EngineConfig) -> Engine {
        Engine::start_sharded(vec![scorer], cfg, Arc::new(RoundRobin::new()))
    }

    /// Spawn one supervised scheduler loop per scorer replica, routing
    /// submissions through `dispatch`. All replicas share one metrics
    /// sink, so [`Engine::summary`] aggregates the fleet — and one
    /// [`HealthView`], so routing and peer-failover skip replicas whose
    /// loop died or whose scorer keeps failing. Failover assumes the
    /// replicas serve identical weights (the bitwise-resume guarantee is
    /// meaningless otherwise).
    pub fn start_sharded(
        scorers: Vec<Arc<dyn Scorer + Send + Sync>>,
        cfg: EngineConfig,
        dispatch: Arc<dyn Dispatch>,
    ) -> Engine {
        Engine::start_inner(scorers, cfg, move |_, _| dispatch)
    }

    /// [`Engine::start_sharded`] with the built-in load-aware policy:
    /// routing reads the fleet's shared [`LoadView`] (queue depth,
    /// active decodes, free KV blocks — published by every engine loop
    /// once per round) and the [`PrefixAffinity`] map (a prompt whose
    /// prefix some replica's [`PrefixIndex`] caches routes there), so
    /// bursty traffic spreads by actual load instead of blind rotation.
    pub fn start_balanced(
        scorers: Vec<Arc<dyn Scorer + Send + Sync>>,
        cfg: EngineConfig,
    ) -> Engine {
        Engine::start_inner(scorers, cfg, |load, affinity| {
            Arc::new(LoadAware::new(load.clone(), affinity.clone()))
        })
    }

    /// Shared constructor body: the load/affinity views exist before the
    /// dispatch policy is built, so a policy can capture them.
    fn start_inner(
        scorers: Vec<Arc<dyn Scorer + Send + Sync>>,
        cfg: EngineConfig,
        make_dispatch: impl FnOnce(&Arc<LoadView>, &Arc<PrefixAffinity>) -> Arc<dyn Dispatch>,
    ) -> Engine {
        // lint: allow(panic) — construction-time contract, before any request exists
        assert!(!scorers.is_empty(), "engine needs at least one scorer replica");
        let metrics = Arc::new(Metrics::new());
        let health = Arc::new(HealthView::new(scorers.len()));
        let load = Arc::new(LoadView::new(scorers.len()));
        let affinity = Arc::new(PrefixAffinity::new());
        let dispatch = make_dispatch(&load, &affinity);
        metrics.gauge_set("serve.replicas_healthy", scorers.len() as f64);
        // all channels exist before any loop spawns, so every replica
        // holds a sender to every peer (its failover targets)
        let mut txs = Vec::with_capacity(scorers.len());
        let mut rxs = Vec::with_capacity(scorers.len());
        for _ in 0..scorers.len() {
            let (tx, rx) = sync_channel(cfg.queue_capacity.max(1));
            txs.push(tx);
            rxs.push(rx);
        }
        let mut arenas = Vec::with_capacity(scorers.len());
        let mut workers = Vec::with_capacity(scorers.len());
        for (i, (scorer, rx)) in scorers.into_iter().zip(rxs).enumerate() {
            let arena = build_arena(&cfg, scorer.dims());
            arenas.push(arena.clone());
            let ctx = ReplicaCtx {
                scorer,
                cfg: cfg.clone(),
                metrics: metrics.clone(),
                arena,
                health: health.clone(),
                load: load.clone(),
                affinity: affinity.clone(),
                peers: txs.clone(),
                index: i,
            };
            #[allow(clippy::expect_used)]
            workers.push(
                std::thread::Builder::new()
                    .name(format!("rilq-engine-{i}"))
                    .spawn(move || supervised_loop(ctx, rx))
                    // lint: allow(panic) — construction-time: the process cannot serve without its scheduler threads
                    .expect("spawn engine loop"),
            );
        }
        Engine { txs: Some(txs), workers, dispatch, metrics, health, load, affinity, arenas, cfg }
    }

    pub fn client(&self) -> EngineClient {
        EngineClient {
            // `txs` is only `None` mid-drop; a client minted then gets the
            // empty set and every submission answers `Err("engine stopped")`
            txs: self.txs.clone().unwrap_or_default(),
            dispatch: self.dispatch.clone(),
            metrics: self.metrics.clone(),
            health: self.health.clone(),
            default_deadline: self.cfg.default_deadline,
        }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    pub fn n_replicas(&self) -> usize {
        self.txs.as_ref().map(Vec::len).unwrap_or(0)
    }

    /// The fleet's shared health registry (clone survives shutdown, so
    /// tests can assert post-drain replica state).
    pub fn health(&self) -> Arc<HealthView> {
        self.health.clone()
    }

    /// The fleet's shared load registry — each engine loop publishes its
    /// queue depth / active decodes / free KV blocks here once per round,
    /// and [`LoadAware`] dispatch reads it on every submission.
    pub fn load_view(&self) -> Arc<LoadView> {
        self.load.clone()
    }

    /// The fleet's shared prefix-affinity map — each loop publishes the
    /// prefixes its [`super::PrefixIndex`] caches, so dispatch can route
    /// a prompt to the replica that already holds its KV prefix.
    pub fn affinity(&self) -> Arc<PrefixAffinity> {
        self.affinity.clone()
    }

    /// The per-replica KV arenas, indexed like the scorer replicas.
    /// Cloning an entry keeps it alive past [`Engine::shutdown`] — the
    /// drain invariant `blocks_in_use() == 0` is assertable there.
    pub fn arenas(&self) -> &[Arc<KvArena>] {
        &self.arenas
    }

    /// Snapshot of the throughput/latency counters.
    pub fn summary(&self) -> ServeSummary {
        ServeSummary::from_metrics(&self.metrics)
    }

    /// Drain the queues, stop every loop, and return the final counters.
    pub fn shutdown(mut self) -> ServeSummary {
        self.stop();
        ServeSummary::from_metrics(&self.metrics)
    }

    fn stop(&mut self) {
        if let Some(txs) = self.txs.take() {
            for tx in &txs {
                // the sentinel queues behind every already-submitted
                // request, so shutdown drains gracefully
                let _ = tx.send(Msg::Shutdown);
            }
            drop(txs);
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Size a replica's KV arena from the config (same policy the loop used
/// before arenas moved out to [`Engine::arenas`]): `kv_block == 0`
/// takes the library default, `arena_blocks == 0` auto-sizes to the
/// pre-paged worst case.
fn build_arena(cfg: &EngineConfig, dims: &ModelDims) -> Arc<KvArena> {
    let max_active = cfg.max_active.max(1);
    let kv_block = if cfg.kv_block == 0 { DEFAULT_BLOCK_POSITIONS } else { cfg.kv_block };
    let kv_block = kv_block.clamp(1, dims.seq.max(1));
    let arena_blocks = if cfg.arena_blocks == 0 {
        max_active * dims.seq.div_ceil(kv_block)
    } else {
        cfg.arena_blocks.max(1)
    };
    KvArena::new(dims, kv_block, arena_blocks)
}

/// Everything one replica's loop needs, bundled for the spawn.
struct ReplicaCtx {
    scorer: Arc<dyn Scorer + Send + Sync>,
    cfg: EngineConfig,
    metrics: Arc<Metrics>,
    arena: Arc<KvArena>,
    health: Arc<HealthView>,
    /// fleet load registry this loop publishes its own row into
    load: Arc<LoadView>,
    /// fleet prefix-affinity map this loop publishes cached prefixes into
    affinity: Arc<PrefixAffinity>,
    /// senders to every replica (self included): the failover targets
    peers: Vec<SyncSender<Msg>>,
    index: usize,
}

/// Drop guard around one replica loop: a panic that somehow escapes the
/// per-call catch-unwind guards (or fires between them) still marks the
/// replica unhealthy on thread unwind, so the fleet stops routing to a
/// slot nobody serves. The dying loop's queued messages drop with the
/// thread, resolving their `Pending`s `Err` via the dropped senders.
struct Sentinel {
    health: Arc<HealthView>,
    metrics: Arc<Metrics>,
    index: usize,
}

impl Drop for Sentinel {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.health.mark_unhealthy(self.index);
            self.metrics
                .gauge_set("serve.replicas_healthy", self.health.healthy_count() as f64);
        }
    }
}

fn supervised_loop(ctx: ReplicaCtx, rx: Receiver<Msg>) {
    let _sentinel =
        Sentinel { health: ctx.health.clone(), metrics: ctx.metrics.clone(), index: ctx.index };
    engine_loop(ctx, rx);
}

/// A queued scoring-side job (plain score or choice scoring).
enum ScoreJob {
    Plain { tokens: Vec<u32>, meta: JobMeta },
    Choices { prompt: Vec<u32>, choices: Vec<Vec<u32>>, meta: JobMeta },
}

impl ScoreJob {
    fn meta(&self) -> &JobMeta {
        match self {
            ScoreJob::Plain { meta, .. } | ScoreJob::Choices { meta, .. } => meta,
        }
    }

    fn meta_mut(&mut self) -> &mut JobMeta {
        match self {
            ScoreJob::Plain { meta, .. } | ScoreJob::Choices { meta, .. } => meta,
        }
    }

    /// Back into the wire form, for handing the job to a peer replica.
    fn into_parts(self) -> (Request, JobMeta) {
        match self {
            ScoreJob::Plain { tokens, meta } => (Request::Score { tokens }, meta),
            ScoreJob::Choices { prompt, choices, meta } => {
                (Request::Choices { prompt, choices }, meta)
            }
        }
    }

    fn into_meta(self) -> JobMeta {
        self.into_parts().1
    }
}

/// A validated generation waiting for a decode slot.
struct GenJob {
    prompt: Vec<u32>,
    params: SamplingParams,
    meta: JobMeta,
    stream: Option<Sender<TokenEvent>>,
}

/// One resident generation: its KV cache (a block table over the
/// replica's shared [`KvArena`]), prefill progress, and the tokens
/// sampled so far (the last one not yet fed back).
struct ActiveGen {
    cache: KvCache,
    /// the original request prompt (kept so a preemption can rebuild the
    /// replay prefix)
    prompt: Vec<u32>,
    /// the token prefix currently being prefilled: the prompt for a
    /// fresh generation, `prompt ++ tokens[..k-1]` when resuming after a
    /// preemption (everything the evicted cache held)
    prefill: Vec<u32>,
    /// prefill positions already in the cache; decoding (has) begun once
    /// `done == prefill.len()`
    done: usize,
    /// sample from the last prefill row once prefill completes? True for
    /// a fresh prompt; false on resume-after-preemption, where the token
    /// after the replayed prefix was already sampled (it is
    /// `tokens.last()`, waiting to be fed back).
    sample_after_prefill: bool,
    tokens: Vec<u32>,
    logps: Vec<f32>,
    params: SamplingParams,
    rng: Rng,
    meta: JobMeta,
    stream: Option<Sender<TokenEvent>>,
}

impl ActiveGen {
    fn admit(g: GenJob, arena: &Arc<KvArena>) -> ActiveGen {
        let rng = g.params.rng();
        ActiveGen {
            cache: arena.new_cache(),
            prefill: g.prompt.clone(),
            prompt: g.prompt,
            done: 0,
            sample_after_prefill: true,
            tokens: Vec::new(),
            logps: Vec::new(),
            params: g.params,
            rng,
            meta: g.meta,
            stream: g.stream,
        }
    }

    /// Rebuild a generation that failed over from a peer replica: fresh
    /// cache, then [`ActiveGen::preempt`] derives the replay prefix —
    /// the single source of truth for resume state, so a failover
    /// continues bit-exact just like a local preemption.
    fn resume(r: ResumeGen, arena: &Arc<KvArena>) -> ActiveGen {
        let ResumeGen { prompt, tokens, logps, params, rng, meta, stream } = r;
        let mut a = ActiveGen {
            cache: arena.new_cache(),
            prefill: Vec::new(),
            prompt,
            done: 0,
            sample_after_prefill: true,
            tokens,
            logps,
            params,
            rng,
            meta,
            stream,
        };
        a.preempt();
        a
    }

    /// Tokens the next scheduler step will feed for this sequence: the
    /// next prefill chunk, or one decode token.
    fn next_feed(&self, chunk: usize) -> usize {
        if self.done < self.prefill.len() {
            self.done.saturating_add(chunk).min(self.prefill.len()) - self.done
        } else {
            1
        }
    }

    /// Evict this generation from the arena: free every block and reset
    /// prefill state so the sequence later resumes by replaying
    /// `prompt ++ tokens[..k-1]` through chunked prefill. Chunked prefill
    /// is bitwise identical to the uninterrupted forward and the sampling
    /// RNG / logps / stream are untouched, so a resumed generation is
    /// bit-exact with one that was never preempted.
    fn preempt(&mut self) {
        self.cache.clear();
        self.prefill = self.prompt.clone();
        if let Some((_, fed)) = self.tokens.split_last() {
            // the last sampled token was never fed back: it is replayed
            // by the decode step after the prefix prefill, not here
            self.prefill.extend_from_slice(fed);
            self.sample_after_prefill = false;
        } else {
            self.sample_after_prefill = true;
        }
        self.done = 0;
    }

    /// Commit one sampled token: record it, stream it.
    fn push(&mut self, tok: u32, lp: f32) {
        self.tokens.push(tok);
        self.logps.push(lp);
        if let Some(tx) = &self.stream {
            // a dropped stream receiver is not an error — the final
            // answer still goes out on `resp`
            let _ = tx.send(TokenEvent { token: tok, logp: lp });
        }
    }

    fn finished(&self) -> bool {
        self.tokens.len() >= self.params.max_new
            || self.tokens.last().is_some_and(|t| self.params.stop.contains(t))
    }
}

/// Record one `serve.kernel_gflops` sample: the compute rate the
/// quantized linears sustained over a timed forward of `rows` activation
/// rows (`rows * ModelDims::linear_flops_per_token / secs`). Zero-row or
/// unmeasurably fast calls are skipped — no sample beats a fabricated
/// rate (the `Metrics::percentile` None-over-0.0 convention).
fn observe_gflops(metrics: &Metrics, rows: usize, flops_per_row: f64, secs: f64) {
    if rows > 0 && secs > 0.0 {
        metrics.observe("serve.kernel_gflops", rows as f64 * flops_per_row / secs / 1e9);
    }
}

/// Answer a finished generation and publish its committed KV prefix
/// (prompt ++ sampled tokens actually fed back, whole blocks only) into
/// the prefix index for cross-request reuse. Publication retains the
/// blocks *before* the cache drops, so the handoff never releases a
/// block another request is about to attach.
fn finish_gen(
    a: ActiveGen,
    metrics: &Metrics,
    prefix: &mut Option<PrefixIndex>,
    affinity: &PrefixAffinity,
    index: usize,
) {
    if let Some(ix) = prefix.as_mut() {
        // cache position i holds the K/V of (prompt ++ tokens)[i]; the
        // final sampled token was never fed back, so it is not cached
        let committed = a.cache.len();
        let mut seq = a.prompt.clone();
        seq.extend_from_slice(&a.tokens);
        seq.truncate(committed);
        ix.insert(&seq, &a.cache);
        affinity.publish(&seq, index);
    }
    metrics.add("serve.gen_requests", 1.0);
    metrics.add("serve.gen_tokens", a.tokens.len() as f64);
    metrics.observe("serve.latency_secs", a.meta.enqueued.elapsed().as_secs_f64());
    observe_goodput(metrics, &a.meta);
    let _ = a
        .meta
        .resp
        .send(Ok(Response::Generated(Generated { tokens: a.tokens, logps: a.logps })));
}

/// Attach the longest cached prefix of a just-promoted generation's
/// prefill to its (empty) cache, advancing `done` past the attached
/// rows. A generation that will sample from its last prefill row keeps
/// at least one row to forward (`limit = len - 1`); a replay
/// (`sample_after_prefill == false`) may attach the whole prefix.
/// Hit/miss counters only move for fresh admissions — replays count
/// their rows into `serve.prefix_tokens_saved` without skewing the hit
/// rate.
fn attach_cached_prefix(
    prefix: &mut Option<PrefixIndex>,
    a: &mut ActiveGen,
    fresh: bool,
    metrics: &Metrics,
) {
    let Some(ix) = prefix.as_mut() else {
        return;
    };
    let limit = if a.sample_after_prefill {
        a.prefill.len().saturating_sub(1)
    } else {
        a.prefill.len()
    };
    let matched = ix.attach(&a.prefill, limit, &mut a.cache);
    if matched > 0 {
        a.done = matched;
        metrics.add("serve.prefix_tokens_saved", matched as f64);
    }
    if fresh {
        metrics.incr(if matched > 0 { "serve.prefix_hits" } else { "serve.prefix_misses" });
    }
}

/// Relieve arena pressure by evicting LRU *unpinned* prefix-index
/// entries — always tried before a generation is preempted (and before
/// promotion gives up on a candidate). Returns whether any block was
/// actually freed; the caller re-evaluates pressure rather than trusting
/// the count, since eviction is block-granular.
fn try_index_evict(prefix: &mut Option<PrefixIndex>, deficit: usize, metrics: &Metrics) -> bool {
    let Some(ix) = prefix.as_mut() else {
        return false;
    };
    let freed = ix.evict_lru(deficit);
    if freed > 0 {
        metrics.add("serve.prefix_evictions", freed as f64);
        return true;
    }
    false
}

/// Blocks the active set must pull from the arena to advance one fused
/// step: each sequence appends [`ActiveGen::next_feed`] positions, and
/// growth inside a block the sequence already holds costs nothing.
fn step_block_need(arena: &KvArena, active: &[ActiveGen], chunk: usize) -> usize {
    active
        .iter()
        .map(|a| {
            arena
                .blocks_for(a.cache.len() + a.next_feed(chunk))
                .saturating_sub(a.cache.blocks_held())
        })
        .sum()
}

/// Admission validation for a `Choices` request (window + vocabulary),
/// mirroring what [`crate::eval::Scorer::score_choices`] requires.
fn validate_choices(dims: &ModelDims, prompt: &[u32], choices: &[Vec<u32>]) -> Result<()> {
    if prompt.is_empty() {
        bail!("choice scoring needs a non-empty prompt");
    }
    check_seq(dims, 0, prompt)?;
    for (ci, c) in choices.iter().enumerate() {
        if prompt.len() + c.len() > dims.seq {
            bail!(
                "choice {ci}: {} prompt + {} choice tokens exceed the model window of {}",
                prompt.len(),
                c.len(),
                dims.seq
            );
        }
        check_seq(dims, ci, c)?;
    }
    Ok(())
}

/// Run one scorer call under a catch-unwind guard: a panicking scorer
/// becomes `(Err, true)` instead of killing the loop thread. The bool
/// distinguishes a crash (immediate unhealthy) from a plain `Err`
/// (counted against [`EngineConfig::unhealthy_after`]). Any state the
/// closure touched (KV caches mid-append) is presumed torn — callers
/// preempt/clear before reuse, which is what makes the unwind-safety
/// assertion sound.
fn catch_fault<T>(f: impl FnOnce() -> Result<T>) -> (Result<T>, bool) {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(r) => (r, false),
        Err(payload) => {
            let what = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string());
            (Err(anyhow!("scorer panicked: {what}")), true)
        }
    }
}

/// The loop-local slice of fleet state the retry/failover helpers need.
struct FleetCtx<'a> {
    cfg: &'a EngineConfig,
    metrics: &'a Metrics,
    health: &'a HealthView,
    peers: &'a [SyncSender<Msg>],
    index: usize,
}

/// Record a scorer fault against this replica's health: a caught panic
/// marks it unhealthy immediately, a plain `Err` counts toward the
/// consecutive-error threshold.
fn record_fault(fleet: &FleetCtx, panicked: bool) {
    if panicked {
        fleet.health.mark_unhealthy(fleet.index);
    } else {
        fleet.health.record_err(fleet.index, fleet.cfg.unhealthy_after);
    }
    fleet.metrics.gauge_set("serve.replicas_healthy", fleet.health.healthy_count() as f64);
}

/// Terminal failure: count it and resolve the caller's `Pending`.
fn fail_request(meta: JobMeta, metrics: &Metrics, msg: &str) {
    metrics.incr("serve.errors");
    let _ = meta.resp.send(Err(anyhow!("{msg}")));
}

/// Exponential retry backoff: attempt `n` waits `base · 2^(n-1)`,
/// capped at 100ms. Sleeping on the loop thread is deliberate — it also
/// rate-limits how fast a persistently failing scorer is re-asked.
fn backoff(cfg: &EngineConfig, attempt: usize) {
    if cfg.retry_backoff.is_zero() {
        return;
    }
    let factor = 1u32 << attempt.saturating_sub(1).min(6) as u32;
    std::thread::sleep((cfg.retry_backoff * factor).min(Duration::from_millis(100)));
}

/// Hand a message to a healthy peer replica, walking the fleet from the
/// slot after ours. `try_send` only: a blocking cross-send between two
/// mutually-failing replicas could deadlock both loops, so a peer whose
/// queue is full is simply skipped. Returns the message when no healthy
/// peer could take it.
fn send_to_peer(fleet: &FleetCtx, msg: Msg) -> std::result::Result<(), Msg> {
    let n = fleet.peers.len();
    let mut msg = msg;
    for k in 1..n {
        let i = (fleet.index + k) % n;
        if !fleet.health.is_healthy(i) {
            continue;
        }
        let Some(tx) = fleet.peers.get(i) else { continue };
        match tx.try_send(msg) {
            Ok(()) => return Ok(()),
            Err(TrySendError::Full(m)) | Err(TrySendError::Disconnected(m)) => msg = m,
        }
    }
    Err(msg)
}

/// Retry an idempotent Score/Choices job after a scorer fault: back
/// onto the local queue while this replica is still healthy, otherwise
/// over to a healthy peer. Exhausted budgets and peerless fleets
/// resolve the request `Err`.
fn retry_score_job(
    mut job: ScoreJob,
    err: &str,
    score_q: &mut VecDeque<ScoreJob>,
    fleet: &FleetCtx,
) {
    if job.meta().retries >= fleet.cfg.max_retries {
        fail_request(job.into_meta(), fleet.metrics, &format!("{err} (retries exhausted)"));
        return;
    }
    job.meta_mut().retries += 1;
    fleet.metrics.incr("serve.retries");
    backoff(fleet.cfg, job.meta().retries);
    if fleet.health.is_healthy(fleet.index) {
        score_q.push_back(job);
        return;
    }
    let (req, meta) = job.into_parts();
    fleet.metrics.gauge_add("serve.queue_depth", 1.0);
    match send_to_peer(fleet, Msg::Sub(Submission { req, meta, stream: None })) {
        Ok(()) => {}
        Err(Msg::Sub(sub)) => {
            fleet.metrics.gauge_add("serve.queue_depth", -1.0);
            fail_request(
                sub.meta,
                fleet.metrics,
                &format!("{err} (no healthy replica could take the retry)"),
            );
        }
        // send_to_peer returns exactly the message it was handed
        Err(_) => {}
    }
}

/// Retry a generation after a scorer fault. The caller has already
/// preempted it (blocks freed, replay prefix rebuilt), so retrying is
/// the PR-6 resume path: locally via the preempted queue while this
/// replica is healthy, otherwise failing over to a peer with the full
/// replay state ([`Msg::Resume`]).
fn retry_gen(mut a: ActiveGen, err: &str, preempted: &mut VecDeque<ActiveGen>, fleet: &FleetCtx) {
    if a.meta.retries >= fleet.cfg.max_retries {
        fail_request(a.meta, fleet.metrics, &format!("{err} (retries exhausted)"));
        return;
    }
    a.meta.retries += 1;
    fleet.metrics.incr("serve.retries");
    backoff(fleet.cfg, a.meta.retries);
    if fleet.health.is_healthy(fleet.index) {
        preempted.push_back(a);
        return;
    }
    let ActiveGen { prompt, tokens, logps, params, rng, meta, stream, .. } = a;
    fleet.metrics.gauge_add("serve.queue_depth", 1.0);
    let resume = Box::new(ResumeGen { prompt, tokens, logps, params, rng, meta, stream });
    match send_to_peer(fleet, Msg::Resume(resume)) {
        Ok(()) => {}
        Err(Msg::Resume(r)) => {
            fleet.metrics.gauge_add("serve.queue_depth", -1.0);
            fail_request(
                r.meta,
                fleet.metrics,
                &format!("{err} (no healthy replica could take the failover)"),
            );
        }
        // send_to_peer returns exactly the message it was handed
        Err(_) => {}
    }
}

/// What the reap pass decides about one job at a step boundary.
enum Verdict {
    Live,
    Cancelled,
    Expired,
}

fn reap_verdict(meta: &JobMeta, now: Instant) -> Verdict {
    if meta.cancel.abandoned() {
        Verdict::Cancelled
    } else if meta.expired(now) {
        Verdict::Expired
    } else {
        Verdict::Live
    }
}

fn deadline_err(meta: &JobMeta) -> anyhow::Error {
    anyhow!(
        "deadline expired {:?} after submission (request shed before any forward)",
        meta.enqueued.elapsed()
    )
}

/// Answer a reaped generation (active or preempted — decode has begun,
/// so an expiry here is a mid-generation abort, not a queue shed).
/// Dropping the `ActiveGen` returns its arena blocks.
fn abort_gen(a: ActiveGen, verdict: Verdict, metrics: &Metrics) {
    match verdict {
        Verdict::Live => {}
        Verdict::Cancelled => {
            metrics.incr("serve.cancelled");
            let _ = a.meta.resp.send(Err(anyhow!(
                "request cancelled after {} sampled token(s)",
                a.tokens.len()
            )));
        }
        Verdict::Expired => {
            metrics.incr("serve.deadline_aborts");
            let _ = a.meta.resp.send(Err(anyhow!(
                "deadline expired mid-generation after {} sampled token(s)",
                a.tokens.len()
            )));
        }
    }
}

/// Queue length at which the high-watermark shed policy engages:
/// `frac` of the queue's capacity `cap`, at least 1. `frac <= 0`
/// disables shedding (`usize::MAX` — the stash/backpressure path of
/// PR 8 handles full queues instead, exactly as before this knob).
fn watermark_level(frac: f64, cap: usize) -> usize {
    if frac <= 0.0 {
        return usize::MAX;
    }
    ((cap as f64 * frac).ceil() as usize).clamp(1, cap)
}

/// Per-tenant token buckets for admission-time rate limiting. One set
/// lives in each replica loop (loop-local by design — no lock), so a
/// tenant's *fleet-wide* effective rate is `tenant_rate × healthy
/// replicas`; see [`EngineConfig::tenant_rate`].
struct TenantBuckets {
    rate: f64,
    burst: f64,
    /// tenant → (current token level, last refill instant)
    buckets: HashMap<String, (f64, Instant)>,
}

impl TenantBuckets {
    fn new(rate: f64, burst: f64) -> TenantBuckets {
        TenantBuckets {
            rate,
            // an unset burst admits one-second bursts (and at least one
            // request, or a sub-1.0 rate could never admit anything)
            burst: if burst > 0.0 { burst } else { rate.max(1.0) },
            buckets: HashMap::new(),
        }
    }

    /// Take one token from `tenant`'s bucket, refilling by wall time
    /// elapsed since the last take. `true` admits. Rate limiting off
    /// (`rate <= 0`) and tenant-less submissions always admit.
    fn try_take(&mut self, tenant: Option<&str>, now: Instant) -> bool {
        if self.rate <= 0.0 {
            return true;
        }
        let Some(t) = tenant else { return true };
        let (level, last) = self
            .buckets
            .entry(t.to_string())
            .or_insert((self.burst, now));
        *level = (*level + now.duration_since(*last).as_secs_f64() * self.rate).min(self.burst);
        *last = now;
        if *level >= 1.0 {
            *level -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Resolve a submission rejected by admission control (token bucket or
/// queue watermark) with a typed [`Overloaded`] error. A request that
/// is *also* cancelled or past its deadline counts there instead
/// (cancel > deadline > overload), so every rejection lands in exactly
/// one counter family and
/// `cancelled + shed + rate_limited + overload_sheds` partitions them.
fn shed_overloaded(meta: JobMeta, kind: OverloadKind, metrics: &Metrics) {
    if meta.cancel.abandoned() {
        metrics.incr("serve.cancelled");
        let _ = meta.resp.send(Err(anyhow!("request cancelled before admission")));
        return;
    }
    if meta.expired(Instant::now()) {
        metrics.incr("serve.shed");
        let e = deadline_err(&meta);
        let _ = meta.resp.send(Err(e));
        return;
    }
    match kind {
        OverloadKind::RateLimited => metrics.incr("serve.rate_limited"),
        OverloadKind::QueueFull => {
            metrics.incr("serve.overload_sheds");
            // per-class counters back the "shedding hits low-priority
            // first" assertion in serve-bench and the chaos tests
            metrics.incr(&format!("serve.overload_sheds_{}", meta.priority.name()));
        }
    }
    let err = Overloaded { kind, priority: meta.priority, tenant: meta.tenant.clone() };
    let _ = meta.resp.send(Err(anyhow::Error::new(err)));
}

/// First sampled token of a generation: record time-to-first-token,
/// overall and for the high-priority class (the SLO series
/// [`crate::coordinator::ServeSummary`] reads p50/p99 from).
fn observe_ttft(metrics: &Metrics, meta: &JobMeta) {
    let ttft = meta.enqueued.elapsed().as_secs_f64();
    metrics.observe("serve.ttft_secs", ttft);
    if meta.priority == Priority::High {
        metrics.observe("serve.ttft_high_secs", ttft);
    }
}

/// Count an `Ok` answer toward goodput when it beat its deadline: raw
/// throughput counts every request, goodput only the ones whose caller
/// was still inside its SLO when the answer landed.
fn observe_goodput(metrics: &Metrics, meta: &JobMeta) {
    if !meta.expired(Instant::now()) {
        metrics.incr("serve.goodput_requests");
    }
}

/// Slow-replica watchdog: compare one timed scorer call against
/// [`EngineConfig::slow_forward_threshold`] (zero disables). A slow
/// forward counts into `serve.slow_forwards` and extends the replica's
/// slow streak; sustained streaks trip sticky-unhealthy via
/// [`HealthView::record_slow`] (mirroring `unhealthy_after`), and
/// load-aware dispatch penalizes nonzero streaks before the trip. A
/// `ChaosScorer` `Delay` fault can no longer stall a replica the fleet
/// still routes to.
fn observe_pace(fleet: &FleetCtx, secs: f64) {
    if fleet.cfg.slow_forward_threshold.is_zero() {
        return;
    }
    if secs > fleet.cfg.slow_forward_threshold.as_secs_f64() {
        fleet.metrics.incr("serve.slow_forwards");
        if !fleet.health.record_slow(fleet.index, fleet.cfg.slow_streak_limit) {
            fleet
                .metrics
                .gauge_set("serve.replicas_healthy", fleet.health.healthy_count() as f64);
        }
    } else {
        fleet.health.record_fast(fleet.index);
    }
}

// lint: allow(indexing) — every subscript in the loop is bounded by `active`
// (`news`/`lgs`/`refs` are rebuilt 1:1 from it each step, so `[i]` shares its
// range) or is a prefill range clamped with `.min(prefill.len())`
fn engine_loop(ctx: ReplicaCtx, rx: Receiver<Msg>) {
    let ReplicaCtx { scorer, cfg, metrics, arena, health, load, affinity, peers, index } = ctx;
    let max_batch = cfg.max_batch.max(1);
    let max_active = cfg.max_active.max(1);
    // the scoring queue must hold at least a full batch, or a small
    // queue_capacity silently caps coalescing below max_batch
    let score_cap = cfg.queue_capacity.max(max_batch);
    let gen_cap = cfg.queue_capacity.max(1);
    let chunk = if cfg.prefill_chunk == 0 { usize::MAX } else { cfg.prefill_chunk };
    let dims = scorer.dims().clone();
    let caps = scorer.caps();
    // numerator of the serve.kernel_gflops observation series: FLOPs one
    // activation row spends in the quantized linears + LM head
    let flops_per_row = dims.linear_flops_per_token() as f64;
    let fleet =
        FleetCtx { cfg: &cfg, metrics: &metrics, health: &health, peers: &peers, index };
    // the cross-request prefix index: loop-local by design (no lock — see
    // `engine::prefix`), holding refcounted pins on committed arena blocks
    let mut prefix: Option<PrefixIndex> =
        if cfg.prefix_cache { Some(PrefixIndex::new(arena.clone())) } else { None };
    // ---- admission-control state (all off by default — see EngineConfig)
    let shed_score_at = watermark_level(cfg.shed_watermark, score_cap);
    let shed_gen_at = watermark_level(cfg.shed_watermark, gen_cap);
    let mut buckets = TenantBuckets::new(cfg.tenant_rate, cfg.tenant_burst);
    // consecutive rounds the gen backlog sat at/over brownout_backlog
    let mut brownout_rounds: usize = 0;

    let mut score_q: VecDeque<ScoreJob> = VecDeque::new();
    let mut gen_wait: VecDeque<GenJob> = VecDeque::new();
    let mut active: Vec<ActiveGen> = Vec::new();
    // generations evicted from the arena, waiting to resume via replay
    // prefill; always resumed ahead of fresh `gen_wait` admissions
    let mut preempted: VecDeque<ActiveGen> = VecDeque::new();
    // one-slot parking spot for a drained message whose target queue is
    // full: intake pauses (bounded memory) without the full queue of one
    // request kind blocking admission of the other kind
    let mut stash: Option<Msg> = None;
    let mut shutting_down = false;

    // does this message target the generation waiting queue?
    let wants_gen = |msg: &Msg| -> bool {
        matches!(msg, Msg::Sub(Submission { req: Request::Generate { .. }, .. }))
    };
    // Admit one message: malformed requests (over-window, out-of-vocab,
    // no cache support, generation past the window, bad sampling params)
    // are answered without touching the model — and without poisoning
    // anything already queued. Cancelled or already-expired submissions
    // are shed here, before any queue time. Returns false on the
    // shutdown sentinel.
    let admit = |msg: Msg,
                 score_q: &mut VecDeque<ScoreJob>,
                 gen_wait: &mut VecDeque<GenJob>,
                 preempted: &mut VecDeque<ActiveGen>,
                 buckets: &mut TenantBuckets|
     -> bool {
        let sub = match msg {
            Msg::Shutdown => return false,
            Msg::Resume(r) => {
                // a generation failing over from a peer: rebuild it on
                // this replica's arena and park it for promotion (the
                // replay prefix makes the continuation bit-exact)
                metrics.gauge_add("serve.queue_depth", -1.0);
                preempted.push_back(ActiveGen::resume(*r, &arena));
                return true;
            }
            Msg::Sub(sub) => sub,
        };
        metrics.gauge_add("serve.queue_depth", -1.0);
        let Submission { req, meta, stream } = sub;
        if meta.cancel.abandoned() {
            metrics.incr("serve.cancelled");
            let _ = meta.resp.send(Err(anyhow!("request cancelled before admission")));
            return true;
        }
        if meta.expired(Instant::now()) {
            metrics.incr("serve.shed");
            let e = deadline_err(&meta);
            let _ = meta.resp.send(Err(e));
            return true;
        }
        // per-tenant token bucket — after the cancel/deadline checks so
        // each rejection lands in exactly one counter family
        if !buckets.try_take(meta.tenant.as_deref(), Instant::now()) {
            shed_overloaded(meta, OverloadKind::RateLimited, &metrics);
            return true;
        }
        match req {
            Request::Score { tokens } => {
                match check_input(&dims, std::slice::from_ref(&tokens)) {
                    Ok(()) => score_q.push_back(ScoreJob::Plain { tokens, meta }),
                    Err(e) => {
                        metrics.incr("serve.errors");
                        let _ = meta.resp.send(Err(e));
                    }
                }
            }
            Request::Choices { prompt, choices } => {
                match validate_choices(&dims, &prompt, &choices) {
                    Ok(()) => score_q.push_back(ScoreJob::Choices { prompt, choices, meta }),
                    Err(e) => {
                        metrics.incr("serve.errors");
                        let _ = meta.resp.send(Err(e));
                    }
                }
            }
            Request::Generate { prompt, params } => {
                let admitted: Result<()> = (|| {
                    if !caps.incremental {
                        bail!(
                            "this scorer has no KV-cache support; generate needs a \
                             native backend scorer"
                        );
                    }
                    params.validate()?;
                    if prompt.is_empty() {
                        bail!("generate needs a non-empty prompt");
                    }
                    check_seq(&dims, 0, &prompt)?;
                    if prompt.len() + params.max_new.saturating_sub(1) > dims.seq {
                        bail!(
                            "generating {} tokens from a {}-token prompt exceeds the \
                             model window of {}",
                            params.max_new,
                            prompt.len(),
                            dims.seq
                        );
                    }
                    // residency-priced admission: a generation that could
                    // never fit the arena even running alone is rejected
                    // up front instead of deadlocking the decode slots
                    let worst = arena.blocks_for(prompt.len() + params.max_new.saturating_sub(1));
                    if worst > arena.max_blocks() {
                        bail!(
                            "generation would hold {worst} KV block(s) at its longest but \
                             the arena has only {} — raise arena_blocks or shorten the request",
                            arena.max_blocks()
                        );
                    }
                    Ok(())
                })();
                match admitted {
                    Err(e) => {
                        metrics.incr("serve.errors");
                        let _ = meta.resp.send(Err(e));
                    }
                    Ok(()) if params.max_new == 0 => {
                        // nothing to decode: answer immediately (the
                        // dropped stream sender ends any TokenStream)
                        metrics.add("serve.gen_requests", 1.0);
                        metrics
                            .observe("serve.latency_secs", meta.enqueued.elapsed().as_secs_f64());
                        observe_goodput(&metrics, &meta);
                        let _ = meta.resp.send(Ok(Response::Generated(Generated {
                            tokens: Vec::new(),
                            logps: Vec::new(),
                        })));
                    }
                    Ok(()) => gen_wait.push_back(GenJob { prompt, params, meta, stream }),
                }
            }
        }
        true
    };

    // One drained message -> its queue, the stash (when that queue is
    // full), or an immediate answer via `admit`. The single copy of the
    // routing policy, shared by stash re-admission and fresh intake.
    // Returns false on the shutdown sentinel (which is never stashed).
    // A Resume bypasses the queue caps: it is bounded by the sending
    // replica's own max_active, and stalling it would strand a
    // generation that already holds sampled tokens.
    let offer = |msg: Msg,
                 score_q: &mut VecDeque<ScoreJob>,
                 gen_wait: &mut VecDeque<GenJob>,
                 preempted: &mut VecDeque<ActiveGen>,
                 stash: &mut Option<Msg>,
                 buckets: &mut TenantBuckets|
     -> bool {
        // ---- high-watermark shedding (admission control) ------------
        // Over the watermark an arrival must displace a strictly
        // lower-priority queued job — the victim is the *youngest* of
        // the lowest-priority class, so FIFO order within a class is
        // preserved — or be shed itself with a typed `Overloaded`.
        // Either way the answer is immediate: over the watermark
        // nothing stashes, so a flood can never push higher-priority
        // traffic into the backpressure path (and never hangs it).
        if let Msg::Sub(_) = &msg {
            let is_gen = wants_gen(&msg);
            let over = if is_gen {
                gen_wait.len() >= shed_gen_at
            } else {
                score_q.len() >= shed_score_at
            };
            if over {
                let arrival = match &msg {
                    Msg::Sub(s) => s.meta.priority,
                    _ => Priority::Normal,
                };
                let victim = if is_gen {
                    gen_wait
                        .iter()
                        .enumerate()
                        .min_by_key(|(i, g)| (g.meta.priority, Reverse(*i)))
                        .filter(|(_, g)| g.meta.priority < arrival)
                        .map(|(i, _)| i)
                } else {
                    score_q
                        .iter()
                        .enumerate()
                        .min_by_key(|(i, j)| (j.meta().priority, Reverse(*i)))
                        .filter(|(_, j)| j.meta().priority < arrival)
                        .map(|(i, _)| i)
                };
                match victim {
                    Some(vi) if is_gen => {
                        if let Some(g) = gen_wait.remove(vi) {
                            shed_overloaded(g.meta, OverloadKind::QueueFull, &metrics);
                        }
                    }
                    Some(vi) => {
                        if let Some(j) = score_q.remove(vi) {
                            shed_overloaded(j.into_meta(), OverloadKind::QueueFull, &metrics);
                        }
                    }
                    None => {
                        // nobody cheaper is queued: shed the arrival
                        if let Msg::Sub(sub) = msg {
                            metrics.gauge_add("serve.queue_depth", -1.0);
                            shed_overloaded(sub.meta, OverloadKind::QueueFull, &metrics);
                        }
                        return true;
                    }
                }
            }
        }
        let full = match &msg {
            Msg::Shutdown | Msg::Resume(_) => false,
            m if wants_gen(m) => gen_wait.len() >= gen_cap,
            _ => score_q.len() >= score_cap,
        };
        if full {
            *stash = Some(msg);
            true
        } else {
            admit(msg, score_q, gen_wait, preempted, buckets)
        }
    };

    loop {
        // ---- intake: admit new work between scheduler iterations -------
        // a previously stashed message re-admits as soon as its queue has
        // room (this runs even while shutting down: the stashed request
        // was submitted before the sentinel and must still be answered)
        if let Some(msg) = stash.take() {
            if !offer(msg, &mut score_q, &mut gen_wait, &mut preempted, &mut stash, &mut buckets)
            {
                shutting_down = true;
            }
        }
        if !shutting_down {
            if stash.is_none()
                && score_q.is_empty()
                && gen_wait.is_empty()
                && active.is_empty()
                && preempted.is_empty()
            {
                // completely idle: block for the next message
                match rx.recv() {
                    Ok(msg) => {
                        if !admit(msg, &mut score_q, &mut gen_wait, &mut preempted, &mut buckets)
                        {
                            shutting_down = true;
                        }
                    }
                    Err(_) => break,
                }
            }
            // drain whatever is already queued. A full set of decode slots
            // no longer pauses intake — score traffic queued behind a long
            // generation is admitted (and served) between its decode
            // steps — and the two waiting queues are bounded separately:
            // a message whose own queue is full parks in the one-slot
            // stash (pausing intake, so memory stays bounded) without the
            // other kind's queue being the reason admission stops
            while !shutting_down && stash.is_none() {
                match rx.try_recv() {
                    Ok(msg) => {
                        if !offer(
                            msg,
                            &mut score_q,
                            &mut gen_wait,
                            &mut preempted,
                            &mut stash,
                            &mut buckets,
                        ) {
                            shutting_down = true;
                        }
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        shutting_down = true;
                        break;
                    }
                }
            }
        }

        // ---- reap: shed cancelled/expired work at the step boundary ----
        // Queued jobs answer without ever costing a forward (serve.shed);
        // generations whose decode already began abort here, the only
        // place their KV blocks can be safely returned
        // (serve.deadline_aborts / serve.cancelled). The rotations are
        // order-preserving, so reaping never reorders the queues.
        let now = Instant::now();
        for _ in 0..score_q.len() {
            let Some(job) = score_q.pop_front() else { break };
            match reap_verdict(job.meta(), now) {
                Verdict::Live => score_q.push_back(job),
                Verdict::Cancelled => {
                    metrics.incr("serve.cancelled");
                    let meta = job.into_meta();
                    let _ = meta.resp.send(Err(anyhow!("request cancelled while queued")));
                }
                Verdict::Expired => {
                    metrics.incr("serve.shed");
                    let meta = job.into_meta();
                    let e = deadline_err(&meta);
                    let _ = meta.resp.send(Err(e));
                }
            }
        }
        for _ in 0..gen_wait.len() {
            let Some(g) = gen_wait.pop_front() else { break };
            match reap_verdict(&g.meta, now) {
                Verdict::Live => gen_wait.push_back(g),
                Verdict::Cancelled => {
                    metrics.incr("serve.cancelled");
                    let _ = g.meta.resp.send(Err(anyhow!("request cancelled while queued")));
                }
                Verdict::Expired => {
                    metrics.incr("serve.shed");
                    let e = deadline_err(&g.meta);
                    let _ = g.meta.resp.send(Err(e));
                }
            }
        }
        for _ in 0..preempted.len() {
            let Some(p) = preempted.pop_front() else { break };
            match reap_verdict(&p.meta, now) {
                Verdict::Live => preempted.push_back(p),
                v => abort_gen(p, v, &metrics),
            }
        }
        let mut i = 0;
        while i < active.len() {
            match reap_verdict(&active[i].meta, now) {
                Verdict::Live => i += 1,
                v => abort_gen(active.swap_remove(i), v, &metrics),
            }
        }

        // ---- promote waiting generations into free decode slots --------
        // preempted generations resume first (they were admitted before
        // anything still in gen_wait), and every candidate is gated on
        // the arena covering its next prefill chunk *on top of* the
        // blocks the already-active set needs for its own next step.
        // Without that reservation a just-promoted resume (holding zero
        // blocks) could force the eviction loop to kick out an
        // established generation, and with several replaying sequences
        // that rotation can repeat forever without anyone sampling. A
        // gated resume also blocks fresh admissions behind it, so
        // eviction can never starve a preempted sequence.
        // A candidate's first-step need is priced net of its prefix-index
        // hit: attached blocks are already resident (sharing costs no
        // capacity), so only the suffix chunk charges against the free
        // pool. When a candidate still doesn't fit, LRU unpinned index
        // entries are evicted and the gate re-evaluated before giving up.
        // ---- brownout: sustained backlog pressure dims low priority ----
        // Once the gen backlog has sat at/over `brownout_backlog` for
        // `brownout_after` consecutive rounds, low-priority generations
        // promote with `max_new` capped to `brownout_max_new` — they
        // still get an answer (unlike a watermark shed), just a shorter
        // one, shrinking their decode residency until pressure clears.
        if cfg.brownout_backlog > 0
            && gen_wait.len() + preempted.len() >= cfg.brownout_backlog
        {
            brownout_rounds = brownout_rounds.saturating_add(1);
        } else {
            brownout_rounds = 0;
        }
        let brownout = cfg.brownout_backlog > 0
            && cfg.brownout_max_new > 0
            && brownout_rounds >= cfg.brownout_after.max(1);

        while active.len() < max_active {
            let reserved = step_block_need(&arena, &active, chunk);
            if let Some(p) = preempted.front() {
                let limit = if p.sample_after_prefill {
                    p.prefill.len().saturating_sub(1)
                } else {
                    p.prefill.len()
                };
                let matched = prefix.as_ref().map_or(0, |ix| ix.peek(&p.prefill, limit));
                let feed = if matched < p.prefill.len() {
                    matched.saturating_add(chunk).min(p.prefill.len()) - matched
                } else {
                    1
                };
                let need = arena.blocks_for(matched + feed) - arena.blocks_for(matched);
                if reserved + need > arena.blocks_free() {
                    let deficit = (reserved + need) - arena.blocks_free();
                    if try_index_evict(&mut prefix, deficit, &metrics) {
                        continue;
                    }
                    break;
                }
                if let Some(mut p) = preempted.pop_front() {
                    attach_cached_prefix(&mut prefix, &mut p, false, &metrics);
                    active.push(p);
                }
                continue;
            }
            // fresh admissions promote priority-then-FIFO: the oldest of
            // the highest waiting class goes first (plain FIFO when
            // everything is Normal, so single-class traffic is
            // unchanged). This is what keeps high-priority TTFT bounded
            // under a low-priority flood — the paid request skips the
            // backlog instead of draining it.
            let best = gen_wait
                .iter()
                .enumerate()
                .max_by_key(|(i, g)| (g.meta.priority, Reverse(*i)))
                .map(|(i, _)| i);
            match best.and_then(|bi| gen_wait.get(bi).map(|g| (bi, g))) {
                Some((bi, g)) => {
                    let matched = prefix
                        .as_ref()
                        .map_or(0, |ix| ix.peek(&g.prompt, g.prompt.len().saturating_sub(1)));
                    let first = matched.saturating_add(chunk).min(g.prompt.len()) - matched;
                    let need = arena.blocks_for(matched + first) - arena.blocks_for(matched);
                    if reserved + need > arena.blocks_free() {
                        let deficit = (reserved + need) - arena.blocks_free();
                        if try_index_evict(&mut prefix, deficit, &metrics) {
                            continue;
                        }
                        break;
                    }
                    if let Some(mut g) = gen_wait.remove(bi) {
                        if brownout
                            && g.meta.priority == Priority::Low
                            && g.params.max_new > cfg.brownout_max_new
                        {
                            g.params.max_new = cfg.brownout_max_new;
                            metrics.incr("serve.brownouts");
                        }
                        let mut a = ActiveGen::admit(g, &arena);
                        attach_cached_prefix(&mut prefix, &mut a, true, &metrics);
                        active.push(a);
                    }
                }
                None => break,
            }
        }
        metrics.gauge_set("serve.gen_backlog", (gen_wait.len() + preempted.len()) as f64);
        metrics.gauge_set("serve.active_decodes", active.len() as f64);
        // publish this replica's load row for load-aware dispatch (the
        // same once-per-round cadence as the gauges above)
        load.publish(
            index,
            score_q.len() + gen_wait.len() + preempted.len(),
            active.len(),
            arena.blocks_free(),
        );
        metrics.gauge_set(
            "serve.kv_bytes",
            active.iter().map(|a| a.cache.bytes()).sum::<usize>() as f64,
        );
        metrics.gauge_set("serve.kv_blocks_used", arena.blocks_in_use() as f64);
        metrics.gauge_set("serve.kv_blocks_free", arena.blocks_free() as f64);
        metrics.gauge_set(
            "serve.kv_blocks_pinned",
            prefix.as_ref().map_or(0, PrefixIndex::blocks_held) as f64,
        );

        // ---- one coalesced scoring batch -------------------------------
        if !score_q.is_empty() {
            let take = score_q.len().min(max_batch);
            let jobs: Vec<ScoreJob> = score_q.drain(..take).collect();
            let mut plain: Vec<(Vec<u32>, JobMeta)> = Vec::new();
            let mut choice_jobs: Vec<(Vec<u32>, Vec<Vec<u32>>, JobMeta)> = Vec::new();
            for j in jobs {
                match j {
                    ScoreJob::Plain { tokens, meta } => plain.push((tokens, meta)),
                    ScoreJob::Choices { prompt, choices, meta } => {
                        choice_jobs.push((prompt, choices, meta))
                    }
                }
            }
            if !plain.is_empty() {
                let batch: Vec<Vec<u32>> =
                    plain.iter_mut().map(|(t, _)| std::mem::take(t)).collect();
                let n_tokens: usize = batch.iter().map(Vec::len).sum();
                let t0 = Instant::now();
                let (scored, panicked) = catch_fault(|| {
                    if caps.fixed_geometry {
                        // the HLO path needs exact [batch, seq] geometry;
                        // score_all pads and chunks for it
                        scorer.score_all(&batch)
                    } else {
                        scorer.score_batch(&batch)
                    }
                });
                let fsecs = t0.elapsed().as_secs_f64();
                metrics.timer_add("serve.forward", fsecs);
                observe_pace(&fleet, fsecs);
                // kernel_gflops measures the native micro-kernels only:
                // the fixed-geometry path runs padded batches through
                // PJRT, where real-token FLOPs over wall time would
                // misstate both the work and the engine that did it
                if !caps.fixed_geometry {
                    observe_gflops(&metrics, n_tokens, flops_per_row, fsecs);
                }
                match scored {
                    Ok(outs) => {
                        health.record_ok(index);
                        // Score traffic needs logits at every position, so
                        // it always full-forwards — but it still refreshes
                        // the recency of any cached prefix it shares, so
                        // hot shared prompts survive LRU eviction
                        if let Some(ix) = prefix.as_mut() {
                            for t in &batch {
                                ix.touch(t);
                            }
                        }
                        metrics.incr("serve.batches");
                        metrics.add("serve.requests", plain.len() as f64);
                        metrics.add("serve.tokens", n_tokens as f64);
                        for ((_, meta), out) in plain.into_iter().zip(outs) {
                            let waited = meta.enqueued.elapsed().as_secs_f64();
                            metrics.observe("serve.latency_secs", waited);
                            observe_goodput(&metrics, &meta);
                            let _ = meta.resp.send(Ok(Response::Scored(out)));
                        }
                    }
                    Err(e) => {
                        // batch-level fault: retry every member (their
                        // tokens come back out of the batch we built)
                        record_fault(&fleet, panicked);
                        let msg = format!("{e:#}");
                        for ((_, meta), tokens) in plain.into_iter().zip(batch) {
                            retry_score_job(
                                ScoreJob::Plain { tokens, meta },
                                &msg,
                                &mut score_q,
                                &fleet,
                            );
                        }
                    }
                }
            }
            for (prompt, choices, meta) in choice_jobs {
                // timed under its own key: serve.forward backs the
                // tokens_per_sec summary, whose numerator counts only
                // plain-score tokens
                let choice_tokens = prompt.len() + choices.iter().map(Vec::len).sum::<usize>();
                // rows actually pushed through the linears: a
                // prefix-reuse scorer prefills the prompt once, the
                // score_all fallback forwards prompt+choice per choice
                let fwd_rows = if caps.prefix_reuse {
                    choice_tokens
                } else {
                    choices.iter().map(|c| prompt.len() + c.len()).sum()
                };
                let t0 = Instant::now();
                let (scored, panicked) = catch_fault(|| scorer.score_choices(&prompt, &choices));
                let csecs = t0.elapsed().as_secs_f64();
                metrics.timer_add("serve.choice_forward", csecs);
                observe_pace(&fleet, csecs);
                if !caps.fixed_geometry {
                    observe_gflops(&metrics, fwd_rows, flops_per_row, csecs);
                }
                match scored {
                    Ok(out) => {
                        health.record_ok(index);
                        if let Some(ix) = prefix.as_mut() {
                            ix.touch(&prompt);
                        }
                        metrics.add("serve.choice_requests", 1.0);
                        metrics.add("serve.choice_tokens", choice_tokens as f64);
                        let waited = meta.enqueued.elapsed().as_secs_f64();
                        metrics.observe("serve.latency_secs", waited);
                        observe_goodput(&metrics, &meta);
                        let _ = meta.resp.send(Ok(Response::Choices(out)));
                    }
                    Err(e) => {
                        record_fault(&fleet, panicked);
                        let msg = format!("{e:#}");
                        retry_score_job(
                            ScoreJob::Choices { prompt, choices, meta },
                            &msg,
                            &mut score_q,
                            &fleet,
                        );
                    }
                }
            }
        }

        // ---- residency: make this step's block growth fit the arena ----
        // When the growth every active sequence needs this step exceeds
        // the free pool, evict the longest generation — most sampled
        // tokens, ties broken toward the LEAST replay progress (smallest
        // resident cache, frequently a just-promoted resume that holds
        // nothing yet and loses nothing). Breaking ties toward the
        // largest cache instead would destroy the most-complete replay
        // each round, which livelocks once several tied sequences are
        // replaying: each round's survivor finishes its replay only to
        // be evicted before it can sample. With least-progress ties the
        // most-complete replay always survives to sample, and a strictly
        // longest victim has by definition sampled since it last tied,
        // so tokens keep committing between evictions and every finite
        // workload drains. The victim's blocks return to the arena and
        // it parks in `preempted` to resume via replay prefill.
        while !active.is_empty() {
            let need = step_block_need(&arena, &active, chunk);
            if need <= arena.blocks_free() {
                break;
            }
            // cached-but-idle prefixes are the cheapest residency to give
            // up: evict LRU unpinned index entries and re-evaluate before
            // any generation is preempted. (Pinned blocks — shared with a
            // live cache — are skipped: releasing them frees nothing, and
            // preemption itself never steals them; a preempted cache only
            // drops its own holds, the index's pins keep the blocks
            // resident.)
            if try_index_evict(&mut prefix, need - arena.blocks_free(), &metrics) {
                continue;
            }
            if active.len() == 1 {
                // nothing left to evict: this request alone cannot fit
                // (defensive — admission bounds worst-case residency, so
                // a real scorer never lands here)
                if let Some(a) = active.pop() {
                    fail_request(
                        a.meta,
                        &metrics,
                        "KV arena exhausted: the generation needs more blocks than the arena holds",
                    );
                }
                break;
            }
            let Some(vi) = (0..active.len())
                .max_by_key(|&i| (active[i].tokens.len(), Reverse(active[i].cache.len())))
            else {
                break;
            };
            let mut v = active.swap_remove(vi);
            v.preempt();
            metrics.incr("serve.preemptions");
            preempted.push_back(v);
        }

        // ---- one fused prefill-chunk / decode step over active ---------
        if !active.is_empty() {
            let mut news: Vec<Vec<u32>> = Vec::with_capacity(active.len());
            let mut prefill_rows = 0usize;
            let mut decode_rows = 0usize;
            for a in &active {
                if a.done < a.prefill.len() {
                    let end = a.done.saturating_add(chunk).min(a.prefill.len());
                    news.push(a.prefill[a.done..end].to_vec());
                    prefill_rows += end - a.done;
                } else {
                    // lint: allow(panic) — invariant: a sequence only reaches decode after its
                    // first token was sampled at prefill completion (or replayed on resume)
                    #[allow(clippy::expect_used)]
                    news.push(vec![*a.tokens.last().expect("decoding sequence has a token")]);
                    decode_rows += 1;
                }
            }
            let t0 = Instant::now();
            let (scored, panicked) = {
                let mut refs: Vec<&mut KvCache> =
                    active.iter_mut().map(|a| &mut a.cache).collect();
                catch_fault(|| scorer.cache_forward_batch(&news, &mut refs))
            };
            let dsecs = t0.elapsed().as_secs_f64();
            metrics.timer_add("serve.decode_step", dsecs);
            observe_pace(&fleet, dsecs);
            observe_gflops(&metrics, prefill_rows + decode_rows, flops_per_row, dsecs);
            match scored {
                Ok(lgs) => {
                    health.record_ok(index);
                    metrics.incr("serve.decode_steps");
                    metrics.add("serve.prefill_tokens", prefill_rows as f64);
                    metrics.add("serve.decode_tokens", decode_rows as f64);
                    let mut committed = 0usize;
                    for (i, a) in active.iter_mut().enumerate() {
                        let n = news[i].len();
                        if a.done < a.prefill.len() {
                            a.done += n;
                            if a.done == a.prefill.len() {
                                // prefill complete: its whole committed
                                // blocks become fleet-visible for
                                // cross-request reuse right away (not only
                                // at finish), so a concurrent shared-prompt
                                // request can already attach them — and
                                // dispatch learns this replica is the
                                // prefix's affinity home
                                if let Some(ix) = prefix.as_mut() {
                                    ix.insert(&a.prefill, &a.cache);
                                    affinity.publish(&a.prefill, index);
                                }
                            }
                            if a.done == a.prefill.len() && a.sample_after_prefill {
                                // prompt complete: the first token samples
                                // from the last prompt position's logits.
                                // (On a post-preemption replay that token
                                // was already sampled — `tokens.last()` —
                                // so the resume goes straight to decode.)
                                let (tok, lp) =
                                    sample_token(lgs[i].row(n - 1), &a.params, &mut a.rng);
                                a.push(tok, lp);
                                committed += 1;
                                if a.tokens.len() == 1 {
                                    observe_ttft(&metrics, &a.meta);
                                }
                            }
                        } else {
                            let (tok, lp) = sample_token(lgs[i].row(0), &a.params, &mut a.rng);
                            a.push(tok, lp);
                            committed += 1;
                        }
                    }
                    // per-token decode latency: this fused step's wall
                    // time amortized over the tokens it committed (the
                    // SLO series behind `tok_latency_p99`)
                    if committed > 0 && dsecs > 0.0 {
                        metrics.observe("serve.tok_latency_secs", dsecs / committed as f64);
                    }
                    let mut i = 0;
                    while i < active.len() {
                        if active[i].finished() {
                            finish_gen(
                                active.swap_remove(i),
                                &metrics,
                                &mut prefix,
                                &affinity,
                                index,
                            );
                        } else {
                            i += 1;
                        }
                    }
                }
                Err(e) => {
                    // step-level fault: the caches may be torn mid-append,
                    // so every active generation preempts (wholesale clear
                    // keeps arena accounting exact, and the replay prefix
                    // is rebuilt from prompt + sampled tokens) and then
                    // retries — locally, or onto a healthy peer
                    record_fault(&fleet, panicked);
                    let msg = format!("{e:#}");
                    for mut a in active.drain(..) {
                        a.preempt();
                        retry_gen(a, &msg, &mut preempted, &fleet);
                    }
                }
            }
            metrics.gauge_set("serve.active_decodes", active.len() as f64);
            metrics.gauge_set(
                "serve.kv_bytes",
                active.iter().map(|a| a.cache.bytes()).sum::<usize>() as f64,
            );
            metrics.gauge_set("serve.kv_blocks_used", arena.blocks_in_use() as f64);
            metrics.gauge_set("serve.kv_blocks_free", arena.blocks_free() as f64);
            metrics.gauge_set(
                "serve.kv_blocks_pinned",
                prefix.as_ref().map_or(0, PrefixIndex::blocks_held) as f64,
            );
            metrics.gauge_set("serve.gen_backlog", (gen_wait.len() + preempted.len()) as f64);
            load.publish(
                index,
                score_q.len() + gen_wait.len() + preempted.len(),
                active.len(),
                arena.blocks_free(),
            );
        }

        if shutting_down
            && stash.is_none()
            && score_q.is_empty()
            && gen_wait.is_empty()
            && active.is_empty()
            && preempted.is_empty()
        {
            break;
        }
    }
    // loop exit: any messages still queued were submitted after shutdown
    // began; dropping their response senders errs the callers' `wait()`.
    // (Retried work re-enters the queues with a bounded budget and
    // failovers hand off via try_send, so the drain always terminates.)
    //
    // The prefix index is the last block holder standing: dropping it
    // releases every pinned block so the arena drains to zero and the
    // "no refcount leaks after shutdown" invariant is observable.
    drop(prefix);
    metrics.gauge_set("serve.kv_blocks_pinned", 0.0);
    metrics.gauge_set("serve.kv_blocks_used", arena.blocks_in_use() as f64);
    metrics.gauge_set("serve.kv_blocks_free", arena.blocks_free() as f64);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(priority: Priority, tenant: Option<&str>, deadline: Option<Duration>) -> (JobMeta, Receiver<Result<Response>>) {
        let (resp, rx) = channel();
        let now = Instant::now();
        let m = JobMeta {
            enqueued: now,
            deadline: deadline.and_then(|d| now.checked_add(d)),
            retries: 0,
            priority,
            tenant: tenant.map(str::to_string),
            cancel: Arc::new(CancelCell::default()),
            resp,
        };
        (m, rx)
    }

    #[test]
    fn watermark_levels_scale_with_capacity_and_zero_disables() {
        assert_eq!(watermark_level(0.0, 32), usize::MAX);
        assert_eq!(watermark_level(-1.0, 32), usize::MAX);
        assert_eq!(watermark_level(0.5, 32), 16);
        assert_eq!(watermark_level(0.9, 10), 9);
        assert_eq!(watermark_level(2.0, 10), 10, "over-1 fractions clamp to the cap");
        assert_eq!(watermark_level(0.01, 4), 1, "a tiny fraction still sheds from 1");
    }

    #[test]
    fn token_buckets_refill_over_time_and_exempt_the_tenantless() {
        let t0 = Instant::now();
        let mut b = TenantBuckets::new(10.0, 2.0);
        // burst of 2, then empty
        assert!(b.try_take(Some("acme"), t0));
        assert!(b.try_take(Some("acme"), t0));
        assert!(!b.try_take(Some("acme"), t0));
        // 100ms at 10 rps refills one token
        let t1 = t0 + Duration::from_millis(100);
        assert!(b.try_take(Some("acme"), t1));
        assert!(!b.try_take(Some("acme"), t1));
        // an independent tenant has its own bucket
        assert!(b.try_take(Some("umbrella"), t1));
        // tenantless and rate-0 submissions always admit
        assert!(b.try_take(None, t1));
        let mut off = TenantBuckets::new(0.0, 0.0);
        for _ in 0..100 {
            assert!(off.try_take(Some("acme"), t0));
        }
        // level caps at burst: a long idle gap does not bank extra burst
        let t2 = t1 + Duration::from_secs(60);
        assert!(b.try_take(Some("acme"), t2));
        assert!(b.try_take(Some("acme"), t2));
        assert!(!b.try_take(Some("acme"), t2));
    }

    #[test]
    fn unset_burst_still_admits_sub_unit_rates() {
        let t0 = Instant::now();
        let mut b = TenantBuckets::new(0.5, 0.0);
        assert!(b.try_take(Some("slow"), t0), "burst floor of 1 admits the first request");
        assert!(!b.try_take(Some("slow"), t0));
    }

    #[test]
    fn shed_overloaded_answers_typed_and_counts_once() {
        let metrics = Metrics::new();
        let (m, rx) = meta(Priority::Low, Some("acme"), None);
        shed_overloaded(m, OverloadKind::QueueFull, &metrics);
        let err = rx.recv().expect("answered").expect_err("shed is an error");
        let o = err.downcast_ref::<Overloaded>().expect("typed Overloaded");
        assert_eq!(o.kind, OverloadKind::QueueFull);
        assert_eq!(o.priority, Priority::Low);
        assert_eq!(o.tenant.as_deref(), Some("acme"));
        assert_eq!(metrics.counter("serve.overload_sheds"), 1.0);
        assert_eq!(metrics.counter("serve.overload_sheds_low"), 1.0);
        assert_eq!(metrics.counter("serve.shed"), 0.0);
        let (m, rx) = meta(Priority::High, None, None);
        shed_overloaded(m, OverloadKind::RateLimited, &metrics);
        let err = rx.recv().expect("answered").expect_err("rate limit is an error");
        assert!(err.downcast_ref::<Overloaded>().is_some());
        assert_eq!(metrics.counter("serve.rate_limited"), 1.0);
        assert_eq!(metrics.counter("serve.overload_sheds"), 1.0, "rate limit is its own family");
    }

    #[test]
    fn shed_overloaded_deadline_wins_the_double_count() {
        // a request both past deadline AND watermark-shed lands in
        // serve.shed only — the satellite regression this PR pins
        let metrics = Metrics::new();
        let (m, rx) = meta(Priority::Normal, None, Some(Duration::ZERO));
        std::thread::sleep(Duration::from_millis(2));
        shed_overloaded(m, OverloadKind::QueueFull, &metrics);
        let err = rx.recv().expect("answered").expect_err("still an error");
        assert!(err.downcast_ref::<Overloaded>().is_none(), "deadline err, not Overloaded");
        assert_eq!(metrics.counter("serve.shed"), 1.0);
        assert_eq!(metrics.counter("serve.overload_sheds"), 0.0);
        assert_eq!(metrics.counter("serve.overload_sheds_normal"), 0.0);
        // cancellation outranks both
        let (m, rx) = meta(Priority::Normal, None, Some(Duration::ZERO));
        m.cancel.cancel();
        shed_overloaded(m, OverloadKind::QueueFull, &metrics);
        assert!(rx.recv().expect("answered").is_err());
        assert_eq!(metrics.counter("serve.cancelled"), 1.0);
        assert_eq!(metrics.counter("serve.shed"), 1.0, "unchanged");
    }
}
