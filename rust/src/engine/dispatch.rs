//! Multi-replica dispatch seam.
//!
//! One engine loop drives one scorer replica; scaling past a single
//! worker means running several loops and deciding, per request, which
//! replica admits it. [`Dispatch`] is that decision point —
//! [`super::Engine::start_sharded`] routes every submission through it.
//! Per-replica KV residency (blocks actually held in the replica's
//! `KvArena`) is the placement constraint a smarter policy would
//! balance; [`RoundRobin`] is the baseline that ignores it.

use std::sync::atomic::{AtomicUsize, Ordering};

use super::request::Request;

/// Route a request to one of `n_replicas` engine loops. Implementations
/// must be cheap and thread-safe — every submission calls this once.
/// Out-of-range returns are clamped by the caller (`% n_replicas`).
pub trait Dispatch: Send + Sync {
    fn route(&self, req: &Request, n_replicas: usize) -> usize;
}

/// Baseline placement: rotate submissions across replicas regardless of
/// request kind or replica load.
#[derive(Default)]
pub struct RoundRobin {
    next: AtomicUsize,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin::default()
    }
}

impl Dispatch for RoundRobin {
    fn route(&self, _req: &Request, n_replicas: usize) -> usize {
        self.next.fetch_add(1, Ordering::Relaxed) % n_replicas.max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles_replicas() {
        let rr = RoundRobin::new();
        let req = Request::Score { tokens: vec![1] };
        let got: Vec<usize> = (0..6).map(|_| rr.route(&req, 3)).collect();
        assert_eq!(got, vec![0, 1, 2, 0, 1, 2]);
        // degenerate replica counts never panic
        assert_eq!(rr.route(&req, 1), 0);
        assert_eq!(rr.route(&req, 0), 0);
    }
}
