//! Multi-replica dispatch seam.
//!
//! One engine loop drives one scorer replica; scaling past a single
//! worker means running several loops and deciding, per request, which
//! replica admits it. [`Dispatch`] is that decision point —
//! [`super::Engine::start_sharded`] routes every submission through it.
//! Per-replica KV residency (blocks actually held in the replica's
//! `KvArena`) is the placement constraint a smarter policy would
//! balance; [`RoundRobin`] is the baseline that ignores it.
//!
//! Routing is health-aware: policies see the fleet's [`HealthView`] and
//! should avoid unhealthy replicas themselves, but the return value is
//! only a *hint*. The caller re-routes an out-of-range or unhealthy hint
//! to the next healthy replica (it never silently `%`-clamps, which
//! could land a request on a dead loop), and refuses the submission
//! when no replica is healthy.

use std::sync::atomic::{AtomicUsize, Ordering};

use super::health::HealthView;
use super::request::Request;

/// Route a request to one replica of the fleet described by `health`.
/// Implementations must be cheap and thread-safe — every submission
/// calls this once. Prefer a healthy replica; the return value is a
/// hint that the caller validates and re-routes if stale.
pub trait Dispatch: Send + Sync {
    fn route(&self, req: &Request, health: &HealthView) -> usize;
}

/// Baseline placement: rotate submissions across healthy replicas
/// regardless of request kind or replica load. Unhealthy replicas are
/// skipped (the rotation hint advances past them to the next healthy
/// slot).
#[derive(Default)]
pub struct RoundRobin {
    next: AtomicUsize,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin::default()
    }
}

impl Dispatch for RoundRobin {
    fn route(&self, _req: &Request, health: &HealthView) -> usize {
        let n = health.n_replicas().max(1);
        let hint = self.next.fetch_add(1, Ordering::Relaxed) % n;
        health.next_healthy(hint).unwrap_or(hint)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles_replicas() {
        let rr = RoundRobin::new();
        let req = Request::Score { tokens: vec![1] };
        let h = HealthView::new(3);
        let got: Vec<usize> = (0..6).map(|_| rr.route(&req, &h)).collect();
        assert_eq!(got, vec![0, 1, 2, 0, 1, 2]);
        // degenerate fleets never panic
        assert_eq!(rr.route(&req, &HealthView::new(1)), 0);
        assert_eq!(rr.route(&req, &HealthView::new(0)), 0);
    }

    #[test]
    fn round_robin_skips_unhealthy_replicas() {
        let rr = RoundRobin::new();
        let req = Request::Score { tokens: vec![1] };
        let h = HealthView::new(3);
        h.mark_unhealthy(1);
        let got: Vec<usize> = (0..6).map(|_| rr.route(&req, &h)).collect();
        assert_eq!(got, vec![0, 2, 2, 0, 2, 2], "hint 1 advances to the next healthy slot");
        assert!(!got.contains(&1));
    }
}
