//! Multi-replica dispatch seam.
//!
//! One engine loop drives one scorer replica; scaling past a single
//! worker means running several loops and deciding, per request, which
//! replica admits it. [`Dispatch`] is that decision point —
//! [`super::Engine::start_sharded`] routes every submission through it.
//!
//! Routing is health-aware: policies see the fleet's [`HealthView`] and
//! should avoid unhealthy replicas themselves, but the return value is
//! only a *hint*. The caller re-routes an out-of-range or unhealthy hint
//! to the next healthy replica (it never silently `%`-clamps, which
//! could land a request on a dead loop), and refuses the submission
//! when no replica is healthy.
//!
//! Two policies ship:
//!
//! * [`RoundRobin`] — the load-blind baseline.
//! * [`LoadAware`] — reads the shared [`LoadView`] each engine loop
//!   publishes (queue depth, active decodes, free KV blocks — the same
//!   publish-atomics pattern as [`HealthView`]) and routes to the least
//!   loaded healthy replica, after first consulting the
//!   [`PrefixAffinity`] map: a prompt whose prefix some replica's
//!   `PrefixIndex` already caches goes *there*, because a cache hit
//!   saves more prefill work than any queue-depth delta (the PR-9
//!   follow-up). Replicas with a live slow-forward streak
//!   ([`HealthView::slow_streak`]) are penalized before the watchdog
//!   retires them.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use super::health::HealthView;
use super::request::Request;

/// Route a request to one replica of the fleet described by `health`.
/// Implementations must be cheap and thread-safe — every submission
/// calls this once. Prefer a healthy replica; the return value is a
/// hint that the caller validates and re-routes if stale.
pub trait Dispatch: Send + Sync {
    fn route(&self, req: &Request, health: &HealthView) -> usize;
}

/// Baseline placement: rotate submissions across healthy replicas
/// regardless of request kind or replica load. Unhealthy replicas are
/// skipped (the rotation hint advances past them to the next healthy
/// slot).
#[derive(Default)]
pub struct RoundRobin {
    next: AtomicUsize,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin::default()
    }
}

impl Dispatch for RoundRobin {
    fn route(&self, _req: &Request, health: &HealthView) -> usize {
        let n = health.n_replicas().max(1);
        let hint = self.next.fetch_add(1, Ordering::Relaxed) % n;
        health.next_healthy(hint).unwrap_or(hint)
    }
}

/// Load snapshot of one replica, published by its engine loop once per
/// scheduler round (plain atomics — reads are advisory, a torn
/// cross-field view only misroutes a hint the caller re-validates).
#[derive(Debug, Default)]
struct ReplicaLoad {
    /// Queued submissions + queued score work + waiting generations.
    queue_depth: AtomicUsize,
    /// Generations currently holding a decode slot.
    active_decodes: AtomicUsize,
    /// Free blocks in the replica's KV arena.
    free_blocks: AtomicUsize,
}

/// Fleet-wide load registry: one entry per replica, shared via `Arc`
/// between the engine loops (writers) and the dispatch policy (reader)
/// exactly the way [`HealthView`] is.
#[derive(Debug)]
pub struct LoadView {
    replicas: Vec<ReplicaLoad>,
}

impl LoadView {
    /// A view over `n` replicas, all initially idle.
    pub fn new(n: usize) -> LoadView {
        LoadView { replicas: (0..n).map(|_| ReplicaLoad::default()).collect() }
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// One round's snapshot for replica `i` (engine-loop publisher).
    pub(crate) fn publish(&self, i: usize, queue_depth: usize, active: usize, free_blocks: usize) {
        if let Some(r) = self.replicas.get(i) {
            r.queue_depth.store(queue_depth, Ordering::Release);
            r.active_decodes.store(active, Ordering::Release);
            r.free_blocks.store(free_blocks, Ordering::Release);
        }
    }

    /// Queued work on replica `i` (0 when out of range).
    pub fn queue_depth(&self, i: usize) -> usize {
        self.replicas.get(i).map(|r| r.queue_depth.load(Ordering::Acquire)).unwrap_or(0)
    }

    /// Active decode slots held on replica `i` (0 when out of range).
    pub fn active_decodes(&self, i: usize) -> usize {
        self.replicas.get(i).map(|r| r.active_decodes.load(Ordering::Acquire)).unwrap_or(0)
    }

    /// Free KV arena blocks on replica `i` (0 when out of range).
    pub fn free_blocks(&self, i: usize) -> usize {
        self.replicas.get(i).map(|r| r.free_blocks.load(Ordering::Acquire)).unwrap_or(0)
    }
}

/// How many leading prompt tokens participate in the affinity hash.
/// Long enough to separate distinct system prompts, short enough that
/// one shared preamble with divergent user suffixes still maps to one
/// key (the shared part is what the `PrefixIndex` caches).
const AFFINITY_PREFIX_TOKENS: usize = 32;

/// Bound on retained affinity entries; at the cap the map is cleared
/// (coarse, but affinity is a routing hint — losing it costs one cold
/// prefill, never correctness).
const AFFINITY_CAP: usize = 1024;

/// Fleet-wide prefix→replica affinity map. Each engine loop publishes
/// "replica `i` now caches this prefix" whenever its `PrefixIndex`
/// inserts committed blocks; [`LoadAware`] consults it so a repeated
/// prompt routes to the replica that already holds its KV.
///
/// Keys are FNV-1a hashes of the first [`AFFINITY_PREFIX_TOKENS`]
/// prompt tokens — a deterministic hash (std's `RandomState` is seeded
/// per-process), so identically-seeded runs make identical routing
/// decisions. A stale or colliding entry is harmless: the hint is
/// re-validated against [`HealthView`] and a miss just prefills cold.
#[derive(Debug, Default)]
pub struct PrefixAffinity {
    map: Mutex<HashMap<u64, usize>>,
}

/// FNV-1a over the leading prompt tokens (deterministic across runs).
fn affinity_key(tokens: &[u32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for t in tokens.iter().take(AFFINITY_PREFIX_TOKENS) {
        for b in t.to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

impl PrefixAffinity {
    pub fn new() -> PrefixAffinity {
        PrefixAffinity::default()
    }

    /// Record that replica `i`'s prefix index now caches `tokens`'
    /// leading blocks (engine-loop publisher; last writer wins).
    pub(crate) fn publish(&self, tokens: &[u32], i: usize) {
        if tokens.is_empty() {
            return;
        }
        let mut g = self.map.lock().unwrap_or_else(|e| e.into_inner());
        if g.len() >= AFFINITY_CAP {
            g.clear();
        }
        g.insert(affinity_key(tokens), i);
    }

    /// The replica that last cached a prefix of `tokens`, if any.
    pub fn lookup(&self, tokens: &[u32]) -> Option<usize> {
        if tokens.is_empty() {
            return None;
        }
        let g = self.map.lock().unwrap_or_else(|e| e.into_inner());
        g.get(&affinity_key(tokens)).copied()
    }

    /// Retained entry count (tests + introspection).
    pub fn len(&self) -> usize {
        self.map.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Each slow-forward streak point weighs as this many queued requests
/// when comparing replicas: a replica two slow forwards into a streak
/// must look markedly worse than a clean peer with a slightly deeper
/// queue, or the watchdog retires it while traffic is still arriving.
const SLOW_STREAK_PENALTY: usize = 4;

/// Load-aware routing over a shared [`LoadView`] + [`PrefixAffinity`].
///
/// Policy, in order:
/// 1. **Prefix affinity** — a `Generate`/`Score`/`Choices` prompt whose
///    leading tokens some healthy replica's index caches routes there
///    (a KV cache hit beats any load delta the fleet can express).
/// 2. **Least load** — otherwise the healthy replica minimizing
///    `queue_depth + active_decodes + SLOW_STREAK_PENALTY × slow_streak`,
///    ties broken toward more free KV blocks, then the lowest index
///    (deterministic for identically-published views).
pub struct LoadAware {
    load: std::sync::Arc<LoadView>,
    affinity: std::sync::Arc<PrefixAffinity>,
}

impl LoadAware {
    pub fn new(
        load: std::sync::Arc<LoadView>,
        affinity: std::sync::Arc<PrefixAffinity>,
    ) -> LoadAware {
        LoadAware { load, affinity }
    }
}

/// The prompt tokens routing should key affinity on.
fn prompt_of(req: &Request) -> &[u32] {
    match req {
        Request::Score { tokens } => tokens,
        Request::Choices { prompt, .. } => prompt,
        Request::Generate { prompt, .. } => prompt,
    }
}

impl Dispatch for LoadAware {
    fn route(&self, req: &Request, health: &HealthView) -> usize {
        if let Some(i) = self.affinity.lookup(prompt_of(req)) {
            if health.is_healthy(i) {
                return i;
            }
        }
        let n = health.n_replicas();
        let mut best: Option<(usize, usize, usize)> = None; // (cost, -free via Reverse, idx)
        for i in 0..n {
            if !health.is_healthy(i) {
                continue;
            }
            let cost = self
                .load
                .queue_depth(i)
                .saturating_add(self.load.active_decodes(i))
                .saturating_add(SLOW_STREAK_PENALTY.saturating_mul(health.slow_streak(i)));
            let free = self.load.free_blocks(i);
            let better = match best {
                None => true,
                // lower cost wins; tie → more free blocks; tie → lower index
                Some((bc, bf, _)) => cost < bc || (cost == bc && free > bf),
            };
            if better {
                best = Some((cost, free, i));
            }
        }
        best.map(|(_, _, i)| i).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles_replicas() {
        let rr = RoundRobin::new();
        let req = Request::Score { tokens: vec![1] };
        let h = HealthView::new(3);
        let got: Vec<usize> = (0..6).map(|_| rr.route(&req, &h)).collect();
        assert_eq!(got, vec![0, 1, 2, 0, 1, 2]);
        // degenerate fleets never panic
        assert_eq!(rr.route(&req, &HealthView::new(1)), 0);
        assert_eq!(rr.route(&req, &HealthView::new(0)), 0);
    }

    #[test]
    fn round_robin_skips_unhealthy_replicas() {
        let rr = RoundRobin::new();
        let req = Request::Score { tokens: vec![1] };
        let h = HealthView::new(3);
        h.mark_unhealthy(1);
        let got: Vec<usize> = (0..6).map(|_| rr.route(&req, &h)).collect();
        assert_eq!(got, vec![0, 2, 2, 0, 2, 2], "hint 1 advances to the next healthy slot");
        assert!(!got.contains(&1));
    }

    fn fleet(n: usize) -> (std::sync::Arc<LoadView>, std::sync::Arc<PrefixAffinity>, LoadAware) {
        let load = std::sync::Arc::new(LoadView::new(n));
        let aff = std::sync::Arc::new(PrefixAffinity::new());
        let la = LoadAware::new(load.clone(), aff.clone());
        (load, aff, la)
    }

    #[test]
    fn load_aware_picks_the_least_loaded_replica() {
        let (load, _aff, la) = fleet(3);
        let h = HealthView::new(3);
        let req = Request::Score { tokens: vec![9, 9] };
        load.publish(0, 5, 2, 10);
        load.publish(1, 1, 0, 10);
        load.publish(2, 3, 1, 10);
        assert_eq!(la.route(&req, &h), 1);
        // ties break toward more free KV blocks, then the lowest index
        load.publish(0, 1, 0, 4);
        load.publish(1, 1, 0, 9);
        load.publish(2, 1, 0, 9);
        assert_eq!(la.route(&req, &h), 1, "equal cost: most free blocks wins, lowest index");
        // degenerate fleets never panic
        assert_eq!(la.route(&req, &HealthView::new(0)), 0);
    }

    #[test]
    fn load_aware_skips_unhealthy_and_penalizes_slow_streaks() {
        let (load, _aff, la) = fleet(3);
        let h = HealthView::new(3);
        let req = Request::Score { tokens: vec![7] };
        load.publish(0, 0, 0, 10);
        load.publish(1, 2, 0, 10);
        load.publish(2, 9, 0, 10);
        h.mark_unhealthy(0);
        assert_eq!(la.route(&req, &h), 1, "idle-but-dead replica 0 is skipped");
        // a slow streak outweighs a small queue-depth advantage
        for _ in 0..2 {
            h.record_slow(1, 0);
        }
        assert_eq!(
            la.route(&req, &h),
            2,
            "streak of 2 costs {} — more than replica 2's deeper queue",
            2 * SLOW_STREAK_PENALTY
        );
    }

    #[test]
    fn prefix_affinity_routes_home_unless_the_replica_died() {
        let (load, aff, la) = fleet(3);
        let h = HealthView::new(3);
        let prompt: Vec<u32> = (0..8).collect();
        let req = Request::Generate {
            prompt: prompt.clone(),
            params: crate::engine::SamplingParams::greedy(4),
        };
        // replica 2 is the busiest, but it caches the prefix
        load.publish(0, 0, 0, 10);
        load.publish(1, 0, 0, 10);
        load.publish(2, 50, 4, 0);
        aff.publish(&prompt, 2);
        assert_eq!(la.route(&req, &h), 2, "cache hit beats load");
        // a dead home replica falls back to least-load
        h.mark_unhealthy(2);
        assert_eq!(la.route(&req, &h), 0);
        // last writer wins on republish
        aff.publish(&prompt, 1);
        assert_eq!(la.route(&req, &h), 1);
    }

    #[test]
    fn affinity_keys_are_deterministic_and_prefix_windowed() {
        let aff = PrefixAffinity::new();
        let long_a: Vec<u32> = (0..64).collect();
        // same first AFFINITY_PREFIX_TOKENS tokens, different tail:
        // one key (the shared preamble is what the index caches)
        let mut long_b = long_a.clone();
        long_b[63] = 999;
        aff.publish(&long_a, 1);
        assert_eq!(aff.lookup(&long_b), Some(1));
        assert_eq!(affinity_key(&long_a), affinity_key(&long_b));
        assert_ne!(affinity_key(&[1, 2, 3]), affinity_key(&[1, 2, 4]));
        // empty prompts neither publish nor match
        aff.publish(&[], 0);
        assert_eq!(aff.lookup(&[]), None);
        assert_eq!(aff.len(), 1);
    }

    #[test]
    fn affinity_map_is_bounded() {
        let aff = PrefixAffinity::new();
        for i in 0..(AFFINITY_CAP as u32 + 10) {
            aff.publish(&[i, i + 1, i + 2], 0);
        }
        assert!(aff.len() <= AFFINITY_CAP, "cap overflow: {} entries", aff.len());
        assert!(!aff.is_empty());
    }
}
