//! The typed request lifecycle: what enters the engine ([`Request`]),
//! what comes back ([`Response`] through a [`Pending`] handle), and the
//! incremental token channel ([`TokenStream`]) for generation.

use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::sampling::SamplingParams;

/// Scheduling class of a submission. Under overload the engine sheds
/// lowest-priority work first (queue high-watermark) and brownouts cap
/// [`SamplingParams::max_new`] for [`Priority::Low`] generations before
/// anything is shed at all; dispatch and admission never reorder work
/// *within* a class, so FIFO fairness holds per priority level.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Best-effort: first to brownout, first to shed.
    Low = 0,
    /// The default class for unannotated traffic.
    #[default]
    Normal = 1,
    /// Latency-sensitive: protected from shedding while any
    /// lower-priority work remains to shed instead.
    High = 2,
}

impl Priority {
    /// Stable short name for metrics keys and summaries.
    pub fn name(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }
}

/// Why an [`Overloaded`] rejection fired.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverloadKind {
    /// The submitting tenant's token bucket was empty.
    RateLimited,
    /// The replica queue crossed its shed watermark and this request was
    /// (or displaced) the lowest-priority work in it.
    QueueFull,
}

/// Typed admission-control rejection: the engine is shedding load and
/// this request lost. Always an immediate `Err` — never a hang, never a
/// panic (R1). Recover the structure from an `anyhow::Error` with
/// `err.downcast_ref::<Overloaded>()`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Overloaded {
    pub kind: OverloadKind,
    pub priority: Priority,
    /// The tenant the rejection was charged to, when one was named.
    pub tenant: Option<String>,
}

impl fmt::Display for Overloaded {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let what = match self.kind {
            OverloadKind::RateLimited => "tenant rate limit exceeded",
            OverloadKind::QueueFull => "queue over shed watermark",
        };
        write!(f, "overloaded: {what} ({} priority", self.priority.name())?;
        match &self.tenant {
            Some(t) => write!(f, ", tenant {t})"),
            None => write!(f, ")"),
        }
    }
}

impl std::error::Error for Overloaded {}

/// One unit of work submitted to the engine.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Score a token sequence: log-prob of each realized next token
    /// (`[len-1]` values). Answered with [`Response::Scored`].
    Score { tokens: Vec<u32> },
    /// Score several candidate continuations of one shared prompt
    /// (the CSQA protocol). Prefix-reuse backends prefill the prompt
    /// once. Answered with [`Response::Choices`].
    Choices { prompt: Vec<u32>, choices: Vec<Vec<u32>> },
    /// Generate up to `params.max_new` tokens from `prompt` under the
    /// sampling configuration. Answered with [`Response::Generated`];
    /// submit via [`super::EngineClient::generate_stream`] to also
    /// receive each token as it is sampled. Scheduling is transparent to
    /// the caller: a generation preempted from the KV arena under
    /// memory pressure resumes bit-exact, with the same [`Pending`] /
    /// [`TokenStream`] and no token replayed or dropped.
    Generate { prompt: Vec<u32>, params: SamplingParams },
}

/// A finished generation: the sampled tokens and each one's log-prob
/// under the full distribution it was drawn from.
#[derive(Clone, Debug, PartialEq)]
pub struct Generated {
    pub tokens: Vec<u32>,
    pub logps: Vec<f32>,
}

/// The engine's answer to a [`Request`] (variants correspond 1:1).
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Scored(Vec<f32>),
    Choices(Vec<Vec<f32>>),
    Generated(Generated),
}

impl Response {
    pub(crate) fn into_scored(self) -> Result<Vec<f32>> {
        match self {
            Response::Scored(v) => Ok(v),
            other => Err(anyhow!("engine answered a Score request with {other:?}")),
        }
    }

    pub(crate) fn into_choices(self) -> Result<Vec<Vec<f32>>> {
        match self {
            Response::Choices(v) => Ok(v),
            other => Err(anyhow!("engine answered a Choices request with {other:?}")),
        }
    }

    pub(crate) fn into_generated(self) -> Result<Generated> {
        match self {
            Response::Generated(g) => Ok(g),
            other => Err(anyhow!("engine answered a Generate request with {other:?}")),
        }
    }
}

/// Per-submission options beyond the request payload itself. Every
/// plain submitter uses the default; the `*_with` variants
/// ([`super::EngineClient::score_with`] / … ) take an explicit one.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SubmitOptions {
    /// Answer-by budget, measured from submission. `None` falls back to
    /// [`super::EngineConfig::default_deadline`]. Expired queued work is
    /// shed with `Err` before it costs a forward; an expired generation
    /// is aborted at the next step boundary and its KV arena blocks
    /// freed.
    pub deadline: Option<Duration>,
    /// Scheduling class: under overload the engine sheds
    /// [`Priority::Low`] before [`Priority::Normal`] before
    /// [`Priority::High`], and brownouts cap low-priority generation
    /// lengths before shedding anything.
    pub priority: Priority,
    /// Billing/fairness identity for per-tenant token-bucket rate
    /// limits ([`super::EngineConfig::tenant_rate`]). `None` is exempt
    /// from per-tenant limits (still subject to watermark shedding).
    pub tenant: Option<String>,
}

impl SubmitOptions {
    pub fn with_deadline(deadline: Duration) -> SubmitOptions {
        SubmitOptions { deadline: Some(deadline), ..SubmitOptions::default() }
    }

    /// Builder-style: set the scheduling class.
    pub fn priority(mut self, priority: Priority) -> SubmitOptions {
        self.priority = priority;
        self
    }

    /// Builder-style: attribute the submission to a tenant.
    pub fn tenant(mut self, tenant: impl Into<String>) -> SubmitOptions {
        self.tenant = Some(tenant.into());
        self
    }

    /// Builder-style: set the answer-by budget.
    pub fn deadline(mut self, deadline: Duration) -> SubmitOptions {
        self.deadline = Some(deadline);
        self
    }
}

/// Shared liveness cell between a [`Pending`] handle and the engine loop
/// serving its request. An mpsc sender cannot observe receiver
/// disconnection without sending, so abandonment travels out-of-band:
/// [`Pending::cancel`] and [`Pending`]'s `Drop` both flip it here, and
/// the loop polls it at admission and at every scheduler round — an
/// abandoned generation stops holding a decode slot and KV blocks at the
/// next step boundary instead of decoding to completion.
#[derive(Debug, Default)]
pub(crate) struct CancelCell {
    cancelled: AtomicBool,
    dropped: AtomicBool,
}

impl CancelCell {
    pub(crate) fn cancel(&self) {
        self.cancelled.store(true, Ordering::Release);
    }

    pub(crate) fn mark_dropped(&self) {
        self.dropped.store(true, Ordering::Release);
    }

    pub(crate) fn cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Acquire)
    }

    /// Cancelled or no longer awaited — either way the engine stops
    /// spending forwards (and KV blocks) on the request.
    pub(crate) fn abandoned(&self) -> bool {
        self.cancelled() || self.dropped.load(Ordering::Acquire)
    }
}

/// A submitted request's pending answer (one-shot). The typed
/// convenience submitters ([`super::EngineClient::score`] /
/// [`super::EngineClient::generate`] / …) return a `Pending` already
/// projected to their payload type; [`super::EngineClient::submit`]
/// returns `Pending<Response>`.
///
/// Dropping an unresolved `Pending` abandons the request: the engine
/// notices at its next scheduler round and sheds the queued work (or
/// aborts the in-flight generation, returning its arena blocks) instead
/// of computing an answer nobody will read.
pub struct Pending<T = Vec<f32>> {
    rx: Receiver<Result<Response>>,
    project: fn(Response) -> Result<T>,
    cancel: Arc<CancelCell>,
}

impl<T> Pending<T> {
    pub(crate) fn new(
        rx: Receiver<Result<Response>>,
        cancel: Arc<CancelCell>,
        project: fn(Response) -> Result<T>,
    ) -> Self {
        Pending { rx, project, cancel }
    }

    /// Best-effort cancellation: ask the engine to abandon this request.
    /// Queued work is shed without a forward; an in-flight generation is
    /// aborted at the next step boundary and its KV blocks freed. The
    /// handle stays valid — [`Pending::wait`] resolves with the
    /// cancellation `Err` (or with `Ok` when the answer raced the
    /// cancel and won).
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// Block until the engine answers, or the per-request error.
    pub fn wait(self) -> Result<T> {
        let r = self
            .rx
            .recv()
            .map_err(|_| anyhow!("engine shut down before answering this request"))??;
        (self.project)(r)
    }

    /// Like [`Pending::wait`], but fail fast after `dur` instead of
    /// hanging on a wedged worker. A timeout consumes nothing — the
    /// handle stays valid, so callers can retry or give up.
    pub fn wait_timeout(&self, dur: Duration) -> Result<T> {
        match self.rx.recv_timeout(dur) {
            Ok(r) => (self.project)(r?),
            Err(RecvTimeoutError::Timeout) => {
                Err(anyhow!("request not answered within {dur:?} (wedged worker?)"))
            }
            Err(RecvTimeoutError::Disconnected) => {
                Err(anyhow!("engine shut down before answering this request"))
            }
        }
    }
}

impl<T> Drop for Pending<T> {
    /// Dropping the handle abandons the request (a request that already
    /// resolved is unaffected — the engine no longer tracks it).
    fn drop(&mut self) {
        self.cancel.mark_dropped();
    }
}

/// One incrementally delivered generation token.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TokenEvent {
    pub token: u32,
    /// Log-prob of `token` under the full distribution it was sampled
    /// from (same quantity as [`Generated::logps`]).
    pub logp: f32,
}

/// Incremental token delivery for one `Generate` request: each sampled
/// token arrives as a [`TokenEvent`] the moment the engine commits it.
/// The stream ends (iterator returns `None`) when the generation
/// finishes, errs, or the engine shuts down — the final
/// [`Generated`] answer (or the error) still arrives on the paired
/// [`Pending`]. The channel is unbounded, so a slow consumer never
/// stalls the engine loop.
pub struct TokenStream {
    pub(crate) rx: Receiver<TokenEvent>,
}

impl TokenStream {
    /// Block for the next token; `None` once the generation is over.
    pub fn recv(&self) -> Option<TokenEvent> {
        self.rx.recv().ok()
    }
}

impl Iterator for TokenStream {
    type Item = TokenEvent;

    fn next(&mut self) -> Option<TokenEvent> {
        self.rx.recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn pending<T>(
        rx: Receiver<Result<Response>>,
        project: fn(Response) -> Result<T>,
    ) -> Pending<T> {
        Pending::new(rx, Arc::new(CancelCell::default()), project)
    }

    #[test]
    fn pending_projects_the_matching_variant() {
        let (tx, rx) = channel();
        tx.send(Ok(Response::Scored(vec![-1.0, -2.0]))).unwrap();
        let p: Pending<Vec<f32>> = pending(rx, Response::into_scored);
        assert_eq!(p.wait().unwrap(), vec![-1.0, -2.0]);
    }

    #[test]
    fn pending_rejects_a_mismatched_variant() {
        let (tx, rx) = channel();
        tx.send(Ok(Response::Choices(vec![]))).unwrap();
        let p: Pending<Vec<f32>> = pending(rx, Response::into_scored);
        assert!(p.wait().is_err());
    }

    #[test]
    fn wait_timeout_fails_fast_and_leaves_the_handle_usable() {
        let (tx, rx) = channel();
        let p: Pending<Vec<f32>> = pending(rx, Response::into_scored);
        let err = p.wait_timeout(Duration::from_millis(10)).unwrap_err();
        assert!(format!("{err}").contains("within"), "{err}");
        // the answer can still be collected after a timeout
        tx.send(Ok(Response::Scored(vec![-3.0]))).unwrap();
        assert_eq!(p.wait_timeout(Duration::from_millis(10)).unwrap(), vec![-3.0]);
    }

    #[test]
    fn dropped_sender_reports_shutdown() {
        let (tx, rx) = channel::<Result<Response>>();
        drop(tx);
        let p: Pending<Vec<f32>> = pending(rx, Response::into_scored);
        let err = p.wait().unwrap_err();
        assert!(format!("{err}").contains("shut down"), "{err}");
    }

    #[test]
    fn cancel_and_drop_both_mark_the_shared_cell() {
        let (_tx, rx) = channel::<Result<Response>>();
        let cell = Arc::new(CancelCell::default());
        let p: Pending<Vec<f32>> = Pending::new(rx, cell.clone(), Response::into_scored);
        assert!(!cell.abandoned() && !cell.cancelled());
        p.cancel();
        assert!(cell.cancelled() && cell.abandoned());
        // dropping the handle flips the out-of-band abandonment flag the
        // engine loop polls (an mpsc sender can't see the receiver go)
        let (_tx2, rx2) = channel::<Result<Response>>();
        let cell2 = Arc::new(CancelCell::default());
        let p2: Pending<Vec<f32>> = Pending::new(rx2, cell2.clone(), Response::into_scored);
        drop(p2);
        assert!(cell2.abandoned() && !cell2.cancelled());
    }

    #[test]
    fn priority_orders_low_below_normal_below_high() {
        assert!(Priority::Low < Priority::Normal);
        assert!(Priority::Normal < Priority::High);
        assert_eq!(Priority::default(), Priority::Normal);
        assert_eq!(Priority::High.name(), "high");
    }

    #[test]
    fn submit_options_builders_compose() {
        let o = SubmitOptions::default()
            .priority(Priority::High)
            .tenant("paid")
            .deadline(Duration::from_millis(50));
        assert_eq!(o.priority, Priority::High);
        assert_eq!(o.tenant.as_deref(), Some("paid"));
        assert_eq!(o.deadline, Some(Duration::from_millis(50)));
        // the PR-8 constructor still defaults the new fields
        let d = SubmitOptions::with_deadline(Duration::from_millis(5));
        assert_eq!(d.priority, Priority::Normal);
        assert_eq!(d.tenant, None);
    }

    #[test]
    fn overloaded_downcasts_through_anyhow() {
        let e = anyhow::Error::new(Overloaded {
            kind: OverloadKind::QueueFull,
            priority: Priority::Low,
            tenant: Some("free".into()),
        });
        let o = e.downcast_ref::<Overloaded>().expect("typed overload must survive anyhow");
        assert_eq!(o.kind, OverloadKind::QueueFull);
        assert_eq!(o.priority, Priority::Low);
        let msg = format!("{e}");
        assert!(msg.contains("overloaded") && msg.contains("watermark"), "{msg}");
        let rl = Overloaded {
            kind: OverloadKind::RateLimited,
            priority: Priority::Normal,
            tenant: None,
        };
        assert!(format!("{rl}").contains("rate limit"), "{rl}");
    }

    #[test]
    fn token_stream_iterates_until_the_sender_drops() {
        let (tx, rx) = channel();
        tx.send(TokenEvent { token: 3, logp: -0.5 }).unwrap();
        tx.send(TokenEvent { token: 9, logp: -1.5 }).unwrap();
        drop(tx);
        let stream = TokenStream { rx };
        let toks: Vec<u32> = stream.map(|e| e.token).collect();
        assert_eq!(toks, vec![3, 9]);
    }
}
