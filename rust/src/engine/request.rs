//! The typed request lifecycle: what enters the engine ([`Request`]),
//! what comes back ([`Response`] through a [`Pending`] handle), and the
//! incremental token channel ([`TokenStream`]) for generation.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::sampling::SamplingParams;

/// One unit of work submitted to the engine.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Score a token sequence: log-prob of each realized next token
    /// (`[len-1]` values). Answered with [`Response::Scored`].
    Score { tokens: Vec<u32> },
    /// Score several candidate continuations of one shared prompt
    /// (the CSQA protocol). Prefix-reuse backends prefill the prompt
    /// once. Answered with [`Response::Choices`].
    Choices { prompt: Vec<u32>, choices: Vec<Vec<u32>> },
    /// Generate up to `params.max_new` tokens from `prompt` under the
    /// sampling configuration. Answered with [`Response::Generated`];
    /// submit via [`super::EngineClient::generate_stream`] to also
    /// receive each token as it is sampled. Scheduling is transparent to
    /// the caller: a generation preempted from the KV arena under
    /// memory pressure resumes bit-exact, with the same [`Pending`] /
    /// [`TokenStream`] and no token replayed or dropped.
    Generate { prompt: Vec<u32>, params: SamplingParams },
}

/// A finished generation: the sampled tokens and each one's log-prob
/// under the full distribution it was drawn from.
#[derive(Clone, Debug, PartialEq)]
pub struct Generated {
    pub tokens: Vec<u32>,
    pub logps: Vec<f32>,
}

/// The engine's answer to a [`Request`] (variants correspond 1:1).
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Scored(Vec<f32>),
    Choices(Vec<Vec<f32>>),
    Generated(Generated),
}

impl Response {
    pub(crate) fn into_scored(self) -> Result<Vec<f32>> {
        match self {
            Response::Scored(v) => Ok(v),
            other => Err(anyhow!("engine answered a Score request with {other:?}")),
        }
    }

    pub(crate) fn into_choices(self) -> Result<Vec<Vec<f32>>> {
        match self {
            Response::Choices(v) => Ok(v),
            other => Err(anyhow!("engine answered a Choices request with {other:?}")),
        }
    }

    pub(crate) fn into_generated(self) -> Result<Generated> {
        match self {
            Response::Generated(g) => Ok(g),
            other => Err(anyhow!("engine answered a Generate request with {other:?}")),
        }
    }
}

/// A submitted request's pending answer (one-shot). The typed
/// convenience submitters ([`super::EngineClient::score`] /
/// [`super::EngineClient::generate`] / …) return a `Pending` already
/// projected to their payload type; [`super::EngineClient::submit`]
/// returns `Pending<Response>`.
pub struct Pending<T = Vec<f32>> {
    rx: Receiver<Result<Response>>,
    project: fn(Response) -> Result<T>,
}

impl<T> Pending<T> {
    pub(crate) fn new(rx: Receiver<Result<Response>>, project: fn(Response) -> Result<T>) -> Self {
        Pending { rx, project }
    }

    /// Block until the engine answers, or the per-request error.
    pub fn wait(self) -> Result<T> {
        let r = self
            .rx
            .recv()
            .map_err(|_| anyhow!("engine shut down before answering this request"))??;
        (self.project)(r)
    }

    /// Like [`Pending::wait`], but fail fast after `dur` instead of
    /// hanging on a wedged worker. A timeout consumes nothing — the
    /// handle stays valid, so callers can retry or give up.
    pub fn wait_timeout(&self, dur: Duration) -> Result<T> {
        match self.rx.recv_timeout(dur) {
            Ok(r) => (self.project)(r?),
            Err(RecvTimeoutError::Timeout) => {
                Err(anyhow!("request not answered within {dur:?} (wedged worker?)"))
            }
            Err(RecvTimeoutError::Disconnected) => {
                Err(anyhow!("engine shut down before answering this request"))
            }
        }
    }
}

/// One incrementally delivered generation token.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TokenEvent {
    pub token: u32,
    /// Log-prob of `token` under the full distribution it was sampled
    /// from (same quantity as [`Generated::logps`]).
    pub logp: f32,
}

/// Incremental token delivery for one `Generate` request: each sampled
/// token arrives as a [`TokenEvent`] the moment the engine commits it.
/// The stream ends (iterator returns `None`) when the generation
/// finishes, errs, or the engine shuts down — the final
/// [`Generated`] answer (or the error) still arrives on the paired
/// [`Pending`]. The channel is unbounded, so a slow consumer never
/// stalls the engine loop.
pub struct TokenStream {
    pub(crate) rx: Receiver<TokenEvent>,
}

impl TokenStream {
    /// Block for the next token; `None` once the generation is over.
    pub fn recv(&self) -> Option<TokenEvent> {
        self.rx.recv().ok()
    }
}

impl Iterator for TokenStream {
    type Item = TokenEvent;

    fn next(&mut self) -> Option<TokenEvent> {
        self.rx.recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn pending_projects_the_matching_variant() {
        let (tx, rx) = channel();
        tx.send(Ok(Response::Scored(vec![-1.0, -2.0]))).unwrap();
        let p: Pending<Vec<f32>> = Pending::new(rx, Response::into_scored);
        assert_eq!(p.wait().unwrap(), vec![-1.0, -2.0]);
    }

    #[test]
    fn pending_rejects_a_mismatched_variant() {
        let (tx, rx) = channel();
        tx.send(Ok(Response::Choices(vec![]))).unwrap();
        let p: Pending<Vec<f32>> = Pending::new(rx, Response::into_scored);
        assert!(p.wait().is_err());
    }

    #[test]
    fn wait_timeout_fails_fast_and_leaves_the_handle_usable() {
        let (tx, rx) = channel();
        let p: Pending<Vec<f32>> = Pending::new(rx, Response::into_scored);
        let err = p.wait_timeout(Duration::from_millis(10)).unwrap_err();
        assert!(format!("{err}").contains("within"), "{err}");
        // the answer can still be collected after a timeout
        tx.send(Ok(Response::Scored(vec![-3.0]))).unwrap();
        assert_eq!(p.wait_timeout(Duration::from_millis(10)).unwrap(), vec![-3.0]);
    }

    #[test]
    fn dropped_sender_reports_shutdown() {
        let (tx, rx) = channel::<Result<Response>>();
        drop(tx);
        let p: Pending<Vec<f32>> = Pending::new(rx, Response::into_scored);
        let err = p.wait().unwrap_err();
        assert!(format!("{err}").contains("shut down"), "{err}");
    }

    #[test]
    fn token_stream_iterates_until_the_sender_drops() {
        let (tx, rx) = channel();
        tx.send(TokenEvent { token: 3, logp: -0.5 }).unwrap();
        tx.send(TokenEvent { token: 9, logp: -1.5 }).unwrap();
        drop(tx);
        let stream = TokenStream { rx };
        let toks: Vec<u32> = stream.map(|e| e.token).collect();
        assert_eq!(toks, vec![3, 9]);
    }
}
