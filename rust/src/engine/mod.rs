//! Request-lifecycle serving engine — the typed API every workload
//! (perplexity scoring, multiple-choice eval, sampled generation)
//! programs against.
//!
//! ```text
//!   EngineClient            Engine (one supervised loop per replica)
//!   ────────────            ───────────────────────────────────────────
//!   submit(Request) ──┐     ┌ intake ── validate ──┬─▶ score/choices q
//!     Score{..}       │     │  (bounded channel,   └─▶ gen waiting q
//!     Choices{..}     ├────▶│   Dispatch hints,         │
//!     Generate{..}    │     │   client re-routes        ▼ reap: shed
//!       + Sampling-   │     │   past unhealthy      cancelled/expired
//!         Params      │     │   replicas)           work, free blocks
//!       + Submit-     │     │                           │
//!         Options     │     │                           ▼ promote while
//!       (deadline)    │     │                      decode slots free
//!                     │     │                      (≤ max_active seqs,
//!   Pending<Response> │     │                       preempted resume
//!     .wait()         ◀─────┤                       first, gated on
//!     .wait_timeout() │     │                       free KvArena blocks;
//!     .cancel()       │     │                       prompts attach their
//!     (drop ⇒ abandon)│     │                       longest PrefixIndex
//!   TokenStream ◀─────┘     │                       hit — whole committed
//!     (per-token events)    │                       blocks — and prefill
//!                           │                       only the suffix)
//!                           ├ score: one coalesced score_batch
//!                           │   (≤ max_batch requests per round)
//!                           │ step: one fused cache_forward_batch —
//!                           │   decode seqs feed their last token,
//!                           │   prefilling seqs feed the next
//!                           │   prefill_chunk tokens; arena overflow
//!                           │   evicts LRU unpinned PrefixIndex entries
//!                           │   first, then preempts the longest
//!                           │   generation; a finishing sequence
//!                           │   publishes its committed blocks back
//!                           │   into the index for the next request
//!                           └ repeat — new traffic admits BETWEEN steps
//!
//!   supervision/failover (per fleet, shared HealthView):
//!   ┌ every scorer call runs under catch-unwind; a panic marks the
//!   │ replica unhealthy at once, persistent Errs after unhealthy_after
//!   ├ faulted Score/Choices retry with bounded backoff — locally, or
//!   │ onto a healthy peer (idempotent re-run)
//!   ├ faulted generations preempt (blocks freed) and resume via the
//!   │ bit-exact replay path — locally, or failing over with Msg::Resume
//!   └ routing + retries skip unhealthy replicas; none left ⇒ Err
//! ```
//!
//! The scheduler round structure is what kills head-of-line blocking:
//! score traffic is served between decode iterations of long
//! generations, and long prompts prefill in chunks instead of
//! monopolizing an iteration. Backends declare capabilities once via
//! [`EngineCaps`] (see [`crate::eval::Scorer::caps`]) instead of being
//! probed per-capability; [`Dispatch`] is the placement seam for
//! multi-replica serving, with per-replica KV residency (blocks held in
//! the replica's [`crate::model::KvArena`] — not the
//! `max_active × full-window` worst case) as the constraint.
//!
//! Cross-request KV reuse rides the same round structure: the loop owns
//! a [`PrefixIndex`] — a block-granular radix trie over committed arena
//! blocks — so shared system prompts prefill once fleet-wide and every
//! later request attaches the cached prefix and forwards only its
//! suffix (bitwise identical to a cold prefill; see `engine::prefix`).
//!
//! Fault tolerance is part of the same lifecycle: requests carry
//! optional deadlines ([`SubmitOptions`]), a [`Pending`] can be
//! cancelled (or simply dropped) to abandon its request, replica health
//! lives in a shared [`HealthView`] consulted by routing and failover,
//! and the deterministic [`ChaosScorer`] fault injector drives the
//! chaos suite that proves no `Pending` ever hangs and the KV arena
//! always drains.
//!
//! **Overload robustness** (PR 10) sits in front of all of that, at
//! admission:
//!
//! * **Tenants and priorities** — [`SubmitOptions`] carries an optional
//!   tenant name (the billing identity) and a three-level [`Priority`]
//!   (`Low`/`Normal`/`High`). Decode promotion is priority-then-FIFO —
//!   the oldest of the highest waiting class goes first — so paid
//!   traffic's first token never queues behind a free-tier backlog.
//!   Both default off/`Normal`, so tenantless traffic behaves exactly
//!   as before.
//! * **Token buckets** — [`EngineConfig::tenant_rate`] gives each named
//!   tenant a per-replica token bucket; an empty bucket answers a typed
//!   [`Overloaded`] error immediately instead of queueing work a flood
//!   already doomed.
//! * **Watermark shedding** — past
//!   [`EngineConfig::shed_watermark`] × queue capacity, an arrival
//!   displaces the queue's *youngest lowest-priority* entry if it
//!   strictly outranks it, otherwise it is shed itself. Sheds answer
//!   `Err(Overloaded)` at once: under overload the engine degrades by
//!   rejecting cheap work, never by hanging anyone (R1).
//! * **Brownout** — sustained backlog
//!   ([`EngineConfig::brownout_backlog`] for `brownout_after` rounds)
//!   caps `max_new` of [`Priority::Low`] generations at
//!   [`EngineConfig::brownout_max_new`]: the free tier gets shorter
//!   answers instead of no answers, shrinking decode residency until
//!   pressure clears.
//! * **Load-aware dispatch** — every loop publishes queue depth, active
//!   decodes and free KV blocks into a shared [`LoadView`] (and its
//!   cached prefixes into [`PrefixAffinity`]); [`LoadAware`]
//!   ([`Engine::start_balanced`]) routes to the prefix-affine or
//!   least-loaded healthy replica instead of blind rotation, and the
//!   slow-replica watchdog ([`EngineConfig::slow_forward_threshold`])
//!   deprioritizes — then retires — replicas whose forwards drag.
//! * **Traces** — [`workload`] generates seeded Poisson/ON-OFF bursty
//!   multi-tenant traces and mirrors the admission policy in virtual
//!   time ([`workload::OverloadSim`]), so "same seed ⇒ same decisions"
//!   is assertable bit-for-bit.
//!
//! The legacy [`crate::coordinator::serve::ServeClient`] verbs survive
//! as deprecated shims over [`EngineClient`].

// The serving surface answers `Err`, it does not die: R1 of the invariant
// catalog (see the crate docs), statically backed by clippy on top of the
// rilq-lint pass. Test modules are excused via clippy.toml. The one
// sanctioned panic source on this path is the injected `ChaosScorer`
// crash — which exists to prove the catch-unwind supervision works.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod caps;
pub mod chaos;
pub mod core;
pub mod dispatch;
pub mod health;
pub mod prefix;
pub mod request;
pub mod sampling;
pub mod workload;

pub use self::caps::EngineCaps;
pub use self::chaos::{ChaosScorer, Fault};
pub use self::core::{Engine, EngineClient, EngineConfig};
pub use self::dispatch::{Dispatch, LoadAware, LoadView, PrefixAffinity, RoundRobin};
pub use self::health::HealthView;
pub use self::prefix::PrefixIndex;
pub use self::request::{
    Generated, OverloadKind, Overloaded, Pending, Priority, Request, Response, SubmitOptions,
    TokenEvent, TokenStream,
};
pub use self::sampling::{argmax_logp, sample_token, SamplingParams, DEFAULT_SAMPLING_SEED};
pub use self::workload::{
    generate_trace, replay_trace, Arrivals, BoundedPareto, Decision, OverloadSim, SimConfig,
    TenantClass, TraceConfig, TraceEvent, TraceOutcome,
};
