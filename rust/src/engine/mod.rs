//! Request-lifecycle serving engine — the typed API every workload
//! (perplexity scoring, multiple-choice eval, sampled generation)
//! programs against.
//!
//! ```text
//!   EngineClient            Engine (one loop per scorer replica)
//!   ────────────            ───────────────────────────────────────────
//!   submit(Request) ──┐     ┌ intake ── validate ──┬─▶ score/choices q
//!     Score{..}       │     │  (bounded channel,   └─▶ gen waiting q
//!     Choices{..}     ├────▶│   Dispatch picks          │
//!     Generate{..}    │     │   the replica)            ▼ promote while
//!       + Sampling-   │     │                      decode slots free
//!         Params      │     │                      (≤ max_active seqs,
//!                     │     │                       preempted resume
//!                     │     │                       first, gated on
//!                     │     │                       free KvArena blocks)
//!                     │     ├ score: one coalesced score_batch
//!   Pending<Response> │     │   (≤ max_batch requests per round)
//!     .wait()         ◀─────┤ step: one fused cache_forward_batch —
//!     .wait_timeout() │     │   decode seqs feed their last token,
//!   TokenStream ◀─────┘     │   prefilling seqs feed the next
//!     (per-token events)    │   prefill_chunk tokens; arena overflow
//!                           │   preempts the longest generation
//!                           └ repeat — new traffic admits BETWEEN steps
//! ```
//!
//! The scheduler round structure is what kills head-of-line blocking:
//! score traffic is served between decode iterations of long
//! generations, and long prompts prefill in chunks instead of
//! monopolizing an iteration. Backends declare capabilities once via
//! [`EngineCaps`] (see [`crate::eval::Scorer::caps`]) instead of being
//! probed per-capability; [`Dispatch`] is the placement seam for
//! multi-replica serving, with per-replica KV residency (blocks held in
//! the replica's [`crate::model::KvArena`] — not the
//! `max_active × full-window` worst case) as the constraint.
//!
//! The legacy [`crate::coordinator::serve::ServeClient`] verbs survive
//! as deprecated shims over [`EngineClient`].

// The serving surface answers `Err`, it does not die: R1 of the invariant
// catalog (see the crate docs), statically backed by clippy on top of the
// rilq-lint pass. Test modules are excused via clippy.toml.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod caps;
pub mod core;
pub mod dispatch;
pub mod request;
pub mod sampling;

pub use self::caps::EngineCaps;
pub use self::core::{Engine, EngineClient, EngineConfig};
pub use self::dispatch::{Dispatch, RoundRobin};
pub use self::request::{Generated, Pending, Request, Response, TokenEvent, TokenStream};
pub use self::sampling::{argmax_logp, sample_token, SamplingParams, DEFAULT_SAMPLING_SEED};
