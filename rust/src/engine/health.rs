//! Shared replica-health registry for sharded serving.
//!
//! One [`HealthView`] is shared between every engine loop, the
//! [`super::EngineClient`], and the [`super::Dispatch`] policy. Each
//! loop records the outcome of its scorer calls; a loop whose scorer
//! panics (caught at the call site) or returns
//! [`super::EngineConfig::unhealthy_after`] consecutive errors marks its
//! replica unhealthy, and routing skips it from then on.
//!
//! Health is **sticky**: there is no automatic self-healing, because a
//! replica whose scorer panicked or persistently errs is presumed to
//! hold corrupted state (a torn KV append, poisoned weights). A
//! successful call resets the consecutive-error counter of a replica
//! that is still healthy, so sporadic faults below the threshold never
//! trip it.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// Liveness record for one replica.
#[derive(Debug)]
struct ReplicaHealth {
    healthy: AtomicBool,
    consecutive_errors: AtomicUsize,
    /// Consecutive timed forwards over
    /// [`super::EngineConfig::slow_forward_threshold`] — the
    /// slow-replica watchdog's streak counter. A fast forward resets
    /// it; a sustained streak trips sticky-unhealthy exactly like
    /// `consecutive_errors`, and load-aware dispatch penalizes nonzero
    /// streaks before the trip point.
    slow_streak: AtomicUsize,
}

impl ReplicaHealth {
    fn new() -> ReplicaHealth {
        ReplicaHealth {
            healthy: AtomicBool::new(true),
            consecutive_errors: AtomicUsize::new(0),
            slow_streak: AtomicUsize::new(0),
        }
    }
}

/// Fleet-wide health: one entry per replica, shared via `Arc` between
/// the engine loops, the client, and the dispatch policy.
#[derive(Debug)]
pub struct HealthView {
    replicas: Vec<ReplicaHealth>,
}

impl HealthView {
    /// A view over `n` replicas, all initially healthy.
    pub fn new(n: usize) -> HealthView {
        HealthView { replicas: (0..n).map(|_| ReplicaHealth::new()).collect() }
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Whether replica `i` is routable. Out-of-range indices are
    /// unhealthy by definition (a stale [`super::Dispatch`] hint).
    pub fn is_healthy(&self, i: usize) -> bool {
        self.replicas.get(i).map(|r| r.healthy.load(Ordering::Acquire)).unwrap_or(false)
    }

    /// How many replicas are currently routable.
    pub fn healthy_count(&self) -> usize {
        self.replicas.iter().filter(|r| r.healthy.load(Ordering::Acquire)).count()
    }

    /// Permanently remove replica `i` from routing (sticky — see the
    /// module docs for why there is no way back).
    pub fn mark_unhealthy(&self, i: usize) {
        if let Some(r) = self.replicas.get(i) {
            r.healthy.store(false, Ordering::Release);
        }
    }

    /// A successful scorer call on replica `i`: forgive prior sporadic
    /// errors (resets the consecutive-error counter; never revives an
    /// unhealthy replica).
    pub(crate) fn record_ok(&self, i: usize) {
        if let Some(r) = self.replicas.get(i) {
            r.consecutive_errors.store(0, Ordering::Release);
        }
    }

    /// A failed scorer call on replica `i`. Marks the replica unhealthy
    /// once `unhealthy_after` consecutive calls have failed; returns
    /// whether the replica is still healthy afterwards.
    pub(crate) fn record_err(&self, i: usize, unhealthy_after: usize) -> bool {
        let Some(r) = self.replicas.get(i) else { return false };
        let errs = r.consecutive_errors.fetch_add(1, Ordering::AcqRel) + 1;
        if errs >= unhealthy_after.max(1) {
            r.healthy.store(false, Ordering::Release);
        }
        r.healthy.load(Ordering::Acquire)
    }

    /// A timed forward on replica `i` exceeded the slow-forward
    /// threshold. Marks the replica unhealthy (sticky, like
    /// [`HealthView::record_err`]) once `slow_streak_limit` consecutive
    /// forwards were slow; returns whether it is still healthy
    /// afterwards. `slow_streak_limit == 0` disables the trip (the
    /// streak still accumulates for dispatch penalties).
    pub(crate) fn record_slow(&self, i: usize, slow_streak_limit: usize) -> bool {
        let Some(r) = self.replicas.get(i) else { return false };
        let streak = r.slow_streak.fetch_add(1, Ordering::AcqRel) + 1;
        if slow_streak_limit > 0 && streak >= slow_streak_limit {
            r.healthy.store(false, Ordering::Release);
        }
        r.healthy.load(Ordering::Acquire)
    }

    /// A timed forward on replica `i` came in under the threshold:
    /// the slow streak is broken (never revives an unhealthy replica).
    pub(crate) fn record_fast(&self, i: usize) {
        if let Some(r) = self.replicas.get(i) {
            r.slow_streak.store(0, Ordering::Release);
        }
    }

    /// Current consecutive-slow-forward streak of replica `i` (0 when
    /// out of range). Load-aware dispatch reads this to deprioritize a
    /// lagging replica before the watchdog retires it.
    pub fn slow_streak(&self, i: usize) -> usize {
        self.replicas.get(i).map(|r| r.slow_streak.load(Ordering::Acquire)).unwrap_or(0)
    }

    /// The first healthy replica at or after `from` (wrapping), or
    /// `None` when the whole fleet is down.
    pub fn next_healthy(&self, from: usize) -> Option<usize> {
        let n = self.replicas.len();
        if n == 0 {
            return None;
        }
        (0..n).map(|k| (from + k) % n).find(|&i| self.is_healthy(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_view_is_fully_healthy() {
        let h = HealthView::new(3);
        assert_eq!(h.n_replicas(), 3);
        assert_eq!(h.healthy_count(), 3);
        assert!(h.is_healthy(0) && h.is_healthy(1) && h.is_healthy(2));
        assert!(!h.is_healthy(3), "out-of-range indices are unhealthy");
        assert_eq!(h.next_healthy(1), Some(1));
    }

    #[test]
    fn mark_unhealthy_is_sticky_and_skipped_by_next_healthy() {
        let h = HealthView::new(3);
        h.mark_unhealthy(1);
        assert!(!h.is_healthy(1));
        assert_eq!(h.healthy_count(), 2);
        assert_eq!(h.next_healthy(1), Some(2));
        assert_eq!(h.next_healthy(3), Some(0), "scan wraps");
        // an ok on an unhealthy replica does not revive it
        h.record_ok(1);
        assert!(!h.is_healthy(1));
    }

    #[test]
    fn consecutive_errors_trip_the_threshold_and_ok_resets_it() {
        let h = HealthView::new(1);
        assert!(h.record_err(0, 3));
        assert!(h.record_err(0, 3));
        h.record_ok(0); // forgiven: counter back to zero
        assert!(h.record_err(0, 3));
        assert!(h.record_err(0, 3));
        assert!(!h.record_err(0, 3), "third consecutive error trips");
        assert!(!h.is_healthy(0));
        assert_eq!(h.next_healthy(0), None);
    }

    #[test]
    fn slow_streaks_trip_sticky_unhealthy_and_fast_forwards_reset() {
        let h = HealthView::new(2);
        assert!(h.record_slow(0, 3));
        assert!(h.record_slow(0, 3));
        assert_eq!(h.slow_streak(0), 2);
        h.record_fast(0); // a fast forward breaks the streak
        assert_eq!(h.slow_streak(0), 0);
        assert!(h.record_slow(0, 3));
        assert!(h.record_slow(0, 3));
        assert!(!h.record_slow(0, 3), "third consecutive slow forward trips");
        assert!(!h.is_healthy(0), "watchdog trip is sticky");
        h.record_fast(0);
        assert!(!h.is_healthy(0), "a later fast forward does not revive");
        // limit 0 disables the trip but keeps the streak observable
        for _ in 0..10 {
            assert!(h.record_slow(1, 0));
        }
        assert!(h.is_healthy(1));
        assert_eq!(h.slow_streak(1), 10);
        assert_eq!(h.slow_streak(7), 0, "out-of-range streak reads 0");
    }

    #[test]
    fn empty_fleet_has_no_healthy_replica() {
        let h = HealthView::new(0);
        assert_eq!(h.healthy_count(), 0);
        assert_eq!(h.next_healthy(0), None);
        assert!(!h.record_err(0, 1), "out-of-range record_err reports unhealthy");
    }
}
