//! PJRT runtime: loads the AOT-lowered HLO artifacts and executes them on
//! the CPU PJRT client from the request path (Python is never involved).
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (argument order,
//!   shapes, dtypes, config geometry);
//! * [`client`] — the [`Runtime`]: HLO-text → `XlaComputation` → compile →
//!   execute, with a compiled-executable cache keyed by artifact name;
//! * [`literal`] — marshalling between Rust buffers and `xla::Literal`s.
//!
//! Note on threading: the `xla` crate's `PjRtClient` is `Rc`-based (not
//! `Send`), so a [`Runtime`] is thread-local; the coordinator's worker pool
//! instantiates one runtime per worker thread.

pub mod bindings;
pub mod client;
pub mod literal;
pub mod manifest;

pub use bindings::Bindings;
pub use client::Runtime;
pub use literal::{lit_f32, lit_i32, lit_scalar_f32, lit_u8, to_vec_f32};
pub use manifest::{ArtifactSpec, DType, Manifest, TensorSpec};
