//! The [`Runtime`]: PJRT CPU client + compiled-executable cache.
//!
//! HLO **text** (see `aot.py` for why not serialized protos) is parsed with
//! `HloModuleProto::from_text_file`, wrapped into an `XlaComputation`,
//! compiled once per artifact, and cached for the lifetime of the runtime.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::manifest::{ArtifactSpec, DType, Manifest};

/// Thread-local PJRT runtime over one artifact directory.
pub struct Runtime {
    /// Lazily-created PJRT client: manifest inspection and the native
    /// `LinearBackend` execution paths never touch PJRT, so creation is
    /// deferred to the first compile/upload. (Also keeps `Runtime::new`
    /// usable under the vendored `xla` stub, whose client constructor
    /// errors.)
    client: RefCell<Option<Rc<PjRtClient>>>,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    /// cumulative PJRT execute wall time (perf accounting)
    exec_secs: RefCell<f64>,
    exec_count: RefCell<u64>,
}

impl Runtime {
    /// Create a CPU runtime over `artifacts/`.
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        Ok(Runtime {
            client: RefCell::new(None),
            manifest,
            cache: RefCell::new(HashMap::new()),
            exec_secs: RefCell::new(0.0),
            exec_count: RefCell::new(0),
        })
    }

    /// The PJRT client, created on first use.
    fn client(&self) -> Result<Rc<PjRtClient>> {
        let mut slot = self.client.borrow_mut();
        if slot.is_none() {
            let c = PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            *slot = Some(Rc::new(c));
        }
        Ok(slot.as_ref().expect("client slot").clone())
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn load(&self, name: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.artifact(name)?;
        let path = self.manifest.hlo_path(spec);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("loading {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client()?
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with validated inputs; returns the decomposed
    /// output tuple (one literal per manifest output).
    pub fn run(&self, name: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let spec = self.manifest.artifact(name)?.clone();
        self.validate_inputs(&spec, inputs)?;
        let exe = self.load(name)?;
        let t0 = Instant::now();
        let result = exe
            .execute::<Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        *self.exec_secs.borrow_mut() += t0.elapsed().as_secs_f64();
        *self.exec_count.borrow_mut() += 1;
        // artifacts are lowered with return_tuple=True
        let outs = lit.to_tuple().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        if outs.len() != spec.outputs.len() {
            bail!(
                "artifact {name}: {} outputs, manifest says {}",
                outs.len(),
                spec.outputs.len()
            );
        }
        Ok(outs)
    }

    fn validate_inputs(&self, spec: &ArtifactSpec, inputs: &[Literal]) -> Result<()> {
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {}: got {} inputs, manifest says {}",
                spec.name,
                inputs.len(),
                spec.inputs.len()
            );
        }
        for (i, (lit, ts)) in inputs.iter().zip(&spec.inputs).enumerate() {
            let want = ts.elements();
            let got = lit.element_count();
            if want != got {
                bail!(
                    "artifact {} input #{i} '{}': {} elements, manifest says {} ({:?})",
                    spec.name,
                    ts.name,
                    got,
                    want,
                    ts.shape
                );
            }
            let ty = lit.ty().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            let ok = matches!(
                (ts.dtype, ty),
                (DType::F32, xla::ElementType::F32)
                    | (DType::I32, xla::ElementType::S32)
                    | (DType::U8, xla::ElementType::U8)
            );
            if !ok {
                bail!(
                    "artifact {} input '{}': dtype mismatch ({:?} vs manifest {:?})",
                    spec.name,
                    ts.name,
                    ty,
                    ts.dtype
                );
            }
        }
        Ok(())
    }

    /// Upload a literal to a device-resident buffer (stays valid for the
    /// lifetime of the client; used to cache static inputs across calls).
    pub fn buffer_from_literal(&self, lit: &Literal) -> Result<PjRtBuffer> {
        self.client()?
            .buffer_from_host_literal(None, lit)
            .map_err(|e| anyhow::anyhow!("{e:?}"))
    }

    /// Execute with device-resident input buffers (the fast path: static
    /// inputs are uploaded once, only per-call tensors transfer per call).
    pub fn run_b(&self, name: &str, inputs: &[&PjRtBuffer]) -> Result<Vec<Literal>> {
        let spec = self.manifest.artifact(name)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {name}: got {} buffers, manifest says {}",
                inputs.len(),
                spec.inputs.len()
            );
        }
        let n_outputs = spec.outputs.len();
        let exe = self.load(name)?;
        let t0 = Instant::now();
        let result = exe
            .execute_b::<&PjRtBuffer>(inputs)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        *self.exec_secs.borrow_mut() += t0.elapsed().as_secs_f64();
        *self.exec_count.borrow_mut() += 1;
        let outs = lit.to_tuple().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        if outs.len() != n_outputs {
            bail!("artifact {name}: {} outputs, manifest says {}", outs.len(), n_outputs);
        }
        Ok(outs)
    }

    /// (total execute seconds, execute count) since construction.
    pub fn exec_stats(&self) -> (f64, u64) {
        (*self.exec_secs.borrow(), *self.exec_count.borrow())
    }

    /// Number of compiled executables held in cache.
    pub fn cached_executables(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Drop a compiled executable (memory control for big sweeps).
    pub fn evict(&self, name: &str) {
        self.cache.borrow_mut().remove(name);
    }
}
