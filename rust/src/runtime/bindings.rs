//! Bindings between the crate's model containers and artifact signatures.
//!
//! Artifacts take flat positional argument lists; the manifest gives each
//! position a name (`embed`, `q.wq`, `ad.wq.a`, `m.ad.wq.b`, `tokens`, …).
//! This module builds the input literal vector for any artifact from a
//! name→buffer map, and parses structured results back out of the output
//! tuple — the only place where argument-order knowledge lives on the Rust
//! side.

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{anyhow, bail, Result};
use xla::Literal;

use crate::lqec::AdapterSet;
use crate::model::{ModelDims, StudentWeights, TeacherParams, LINEARS};
use crate::quant::PackedTensor;

use super::literal::{lit_f32, lit_i32, lit_u8, to_vec_f32};
use super::manifest::{ArtifactSpec, DType};

/// A typed input buffer.
pub enum BufVal {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U8(Vec<u8>),
}

/// Name→buffer map for one artifact invocation. Buffers are `Rc`-shared
/// so a scorer can keep a base binding set (weights, adapters) and cheaply
/// derive per-call bindings that only swap the token batch.
#[derive(Default)]
pub struct Bindings {
    map: HashMap<String, Rc<BufVal>>,
}

impl Bindings {
    pub fn new() -> Bindings {
        Bindings::default()
    }

    pub fn set_f32(&mut self, name: impl Into<String>, data: Vec<f32>) -> &mut Self {
        self.map.insert(name.into(), Rc::new(BufVal::F32(data)));
        self
    }

    pub fn set_i32(&mut self, name: impl Into<String>, data: Vec<i32>) -> &mut Self {
        self.map.insert(name.into(), Rc::new(BufVal::I32(data)));
        self
    }

    pub fn set_u8(&mut self, name: impl Into<String>, data: Vec<u8>) -> &mut Self {
        self.map.insert(name.into(), Rc::new(BufVal::U8(data)));
        self
    }

    /// Cheap (Rc) copy of all bindings from another set.
    pub fn copy_from(&mut self, other: &Bindings) -> &mut Self {
        for (k, v) in &other.map {
            self.map.insert(k.clone(), v.clone());
        }
        self
    }

    /// Teacher tensors under their canonical names
    /// (`embed`, `wq`…`wd`, `ln1`, `ln2`, `fnorm`, `head`).
    pub fn teacher(&mut self, teacher: &TeacherParams) -> &mut Self {
        let flat = teacher.to_flat();
        for (name, buf) in teacher_names().iter().zip(flat) {
            self.set_f32(*name, buf);
        }
        self
    }

    /// Teacher-shaped buffers under a prefix (Adam moments of pretrain).
    pub fn teacher_shaped(&mut self, prefix: &str, flat: &[Vec<f32>]) -> &mut Self {
        assert_eq!(flat.len(), 12);
        for (name, buf) in teacher_names().iter().zip(flat) {
            self.set_f32(format!("{prefix}{name}"), buf.clone());
        }
        self
    }

    /// Dense dequantized student weights (`q.wq` … `q.wd`).
    pub fn qweights(&mut self, student: &StudentWeights) -> &mut Self {
        for (name, buf) in LINEARS.iter().zip(student.to_flat_dense()) {
            self.set_f32(format!("q.{name}"), buf);
        }
        self
    }

    /// Dense student weights from raw per-family buffers.
    pub fn qweights_flat(&mut self, flat: &[Vec<f32>]) -> &mut Self {
        assert_eq!(flat.len(), 7);
        for (name, buf) in LINEARS.iter().zip(flat) {
            self.set_f32(format!("q.{name}"), buf.clone());
        }
        self
    }

    /// Adapters under a prefix (`ad.` / `m.` / `v.` with `.a`/`.b` leaves).
    pub fn adapters(&mut self, prefix: &str, flat: &[Vec<f32>]) -> &mut Self {
        assert_eq!(flat.len(), 14);
        for (i, name) in LINEARS.iter().enumerate() {
            self.set_f32(format!("{prefix}{name}.a"), flat[2 * i].clone());
            self.set_f32(format!("{prefix}{name}.b"), flat[2 * i + 1].clone());
        }
        self
    }

    /// Packed student weights for the serving artifact
    /// (`pq.*` u8 codes, `sc.*`/`z.*` group metadata, `codebook`).
    pub fn packed(
        &mut self,
        packed: &[Vec<PackedTensor>],   // [family][layer]
        scales: &[Vec<f32>],            // stacked [L, G, d_out] per family
        zeros: &[Vec<f32>],
        codebook: &[f32],
    ) -> &mut Self {
        for (f, name) in LINEARS.iter().enumerate() {
            let mut codes = Vec::new();
            for p in &packed[f] {
                codes.extend_from_slice(&p.data);
            }
            self.set_u8(format!("pq.{name}"), codes);
            self.set_f32(format!("sc.{name}"), scales[f].clone());
            self.set_f32(format!("z.{name}"), zeros[f].clone());
        }
        self.set_f32("codebook", codebook.to_vec());
        self
    }

    /// Token batch `[batch, seq]`.
    pub fn tokens(&mut self, batch: &[Vec<u32>], dims: &ModelDims) -> &mut Self {
        assert_eq!(batch.len(), dims.batch, "batch size mismatch");
        let mut buf = Vec::with_capacity(dims.batch * dims.seq);
        for seq in batch {
            assert_eq!(seq.len(), dims.seq, "sequence length mismatch");
            buf.extend(seq.iter().map(|&t| t as i32));
        }
        self.set_i32("tokens", buf)
    }

    /// Adam step + learning rate scalars.
    pub fn step_lr(&mut self, t: f32, lr: f32) -> &mut Self {
        self.set_f32("t", vec![t]);
        self.set_f32("lr", vec![lr])
    }

    /// Assemble the positional literal list for an artifact.
    pub fn to_literals(&self, spec: &ArtifactSpec) -> Result<Vec<Literal>> {
        let mut out = Vec::with_capacity(spec.inputs.len());
        for ts in &spec.inputs {
            let val = self
                .map
                .get(&ts.name)
                .ok_or_else(|| anyhow!("artifact {}: missing binding '{}'", spec.name, ts.name))?;
            let lit = match (val.as_ref(), ts.dtype) {
                (BufVal::F32(d), DType::F32) => lit_f32(&ts.shape, d)?,
                (BufVal::I32(d), DType::I32) => lit_i32(&ts.shape, d)?,
                (BufVal::U8(d), DType::U8) => lit_u8(&ts.shape, d)?,
                _ => bail!("artifact {}: dtype mismatch for '{}'", spec.name, ts.name),
            };
            out.push(lit);
        }
        Ok(out)
    }
}

/// Device-resident bindings: static inputs are uploaded to PJRT buffers
/// once; dynamic inputs (matched by name prefix) are marshalled per call.
/// This removes the dominant per-step cost of re-uploading frozen weights
/// (see EXPERIMENTS.md §Perf).
pub struct DeviceBindings {
    slots: Vec<DeviceSlot>,
}

enum DeviceSlot {
    /// PJRT host->device transfers are asynchronous: the source literal
    /// must stay alive until the buffer's definition event completes, so
    /// it is kept alongside the buffer for the bindings' lifetime.
    Static(std::rc::Rc<xla::PjRtBuffer>, std::rc::Rc<Literal>),
    Dynamic(String),
}

/// Per-call assembled inputs; holds the dynamic literals alive for the
/// duration of the execute (same async-transfer hazard as above).
pub struct AssembledInputs {
    bufs: Vec<std::rc::Rc<xla::PjRtBuffer>>,
    _keepalive: Vec<Literal>,
}

impl AssembledInputs {
    pub fn refs(&self) -> Vec<&xla::PjRtBuffer> {
        self.bufs.iter().map(|b| b.as_ref()).collect()
    }
}

impl Bindings {
    /// Split this binding set into device-cached statics and named
    /// dynamics. A spec input is dynamic iff its name starts with one of
    /// `dynamic_prefixes`.
    pub fn to_device(
        &self,
        rt: &crate::runtime::Runtime,
        spec: &ArtifactSpec,
        dynamic_prefixes: &[&str],
    ) -> Result<DeviceBindings> {
        let mut slots = Vec::with_capacity(spec.inputs.len());
        for ts in &spec.inputs {
            if dynamic_prefixes.iter().any(|p| ts.name.starts_with(p)) {
                slots.push(DeviceSlot::Dynamic(ts.name.clone()));
                continue;
            }
            let val = self
                .map
                .get(&ts.name)
                .ok_or_else(|| {
                    anyhow!("artifact {}: missing static binding '{}'", spec.name, ts.name)
                })?;
            let lit = match (val.as_ref(), ts.dtype) {
                (BufVal::F32(d), DType::F32) => lit_f32(&ts.shape, d)?,
                (BufVal::I32(d), DType::I32) => lit_i32(&ts.shape, d)?,
                (BufVal::U8(d), DType::U8) => lit_u8(&ts.shape, d)?,
                _ => bail!("artifact {}: dtype mismatch for '{}'", spec.name, ts.name),
            };
            let buf = rt.buffer_from_literal(&lit)?;
            slots.push(DeviceSlot::Static(std::rc::Rc::new(buf), std::rc::Rc::new(lit)));
        }
        Ok(DeviceBindings { slots })
    }
}

impl DeviceBindings {
    /// Assemble the per-call buffer list: dynamic slots are marshalled and
    /// uploaded from `dyn_vals`, static slots reuse the cached buffers.
    pub fn assemble(
        &self,
        rt: &crate::runtime::Runtime,
        spec: &ArtifactSpec,
        dyn_vals: &Bindings,
    ) -> Result<AssembledInputs> {
        let mut bufs = Vec::with_capacity(self.slots.len());
        let mut keepalive = Vec::new();
        for (slot, ts) in self.slots.iter().zip(&spec.inputs) {
            match slot {
                DeviceSlot::Static(b, _lit) => bufs.push(b.clone()),
                DeviceSlot::Dynamic(name) => {
                    let val = dyn_vals
                        .map
                        .get(name)
                        .ok_or_else(|| anyhow!("missing dynamic binding '{name}'"))?;
                    let lit = match (val.as_ref(), ts.dtype) {
                        (BufVal::F32(d), DType::F32) => lit_f32(&ts.shape, d)?,
                        (BufVal::I32(d), DType::I32) => lit_i32(&ts.shape, d)?,
                        (BufVal::U8(d), DType::U8) => lit_u8(&ts.shape, d)?,
                        _ => bail!("dtype mismatch for dynamic '{name}'"),
                    };
                    bufs.push(std::rc::Rc::new(rt.buffer_from_literal(&lit)?));
                    keepalive.push(lit);
                }
            }
        }
        Ok(AssembledInputs { bufs, _keepalive: keepalive })
    }
}

pub fn teacher_names() -> [&'static str; 12] {
    ["embed", "wq", "wk", "wv", "wo", "wg", "wu", "wd", "ln1", "ln2", "fnorm", "head"]
}

/// Parse a named f32 output from an artifact result tuple.
pub fn output_f32(spec: &ArtifactSpec, outs: &[Literal], name: &str) -> Result<Vec<f32>> {
    let idx = spec.output_index(name)?;
    to_vec_f32(&outs[idx])
}

/// Parse a scalar f32 output.
pub fn output_scalar(spec: &ArtifactSpec, outs: &[Literal], name: &str) -> Result<f32> {
    let v = output_f32(spec, outs, name)?;
    v.first().copied().ok_or_else(|| anyhow!("output '{name}' empty"))
}

/// Parse the 14 adapter buffers (prefix `ad.` / `m.` / `v.`) out of a
/// train-step result.
pub fn output_adapter_flat(
    spec: &ArtifactSpec,
    outs: &[Literal],
    prefix: &str,
) -> Result<Vec<Vec<f32>>> {
    let mut flat = Vec::with_capacity(14);
    for name in LINEARS {
        flat.push(output_f32(spec, outs, &format!("{prefix}{name}.a"))?);
        flat.push(output_f32(spec, outs, &format!("{prefix}{name}.b"))?);
    }
    Ok(flat)
}

/// Parse the 12 teacher-shaped buffers (prefix `p.` / `m.` / `v.`) out of a
/// pretrain-step result.
pub fn output_teacher_flat(
    spec: &ArtifactSpec,
    outs: &[Literal],
    prefix: &str,
) -> Result<Vec<Vec<f32>>> {
    let mut flat = Vec::with_capacity(12);
    for name in teacher_names() {
        flat.push(output_f32(spec, outs, &format!("{prefix}{name}"))?);
    }
    Ok(flat)
}

/// Convenience: AdapterSet <-> flat for train-loop plumbing.
pub fn adapters_from_flat(
    dims: &ModelDims,
    rank: usize,
    flat: &[Vec<f32>],
) -> Result<AdapterSet> {
    AdapterSet::from_flat(dims, rank, flat)
}
