//! `artifacts/manifest.json` parsing. The manifest is the single source of
//! truth for artifact argument order, tensor shapes/dtypes, and model
//! geometry; it is written by `python/compile/aot.py` at `make artifacts`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::model::ModelDims;
use crate::report::Json;

/// Tensor element type (the subset the artifacts use).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U8,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "float32" => DType::F32,
            "int32" => DType::I32,
            "uint8" => DType::U8,
            other => bail!("unsupported dtype '{other}'"),
        })
    }

    pub fn size_bytes(&self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::U8 => 1,
        }
    }
}

/// One named tensor in an artifact signature.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .arr_of("shape")?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad shape entry")))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec {
            name: j.str_of("name")?.to_string(),
            shape,
            dtype: DType::parse(j.str_of("dtype")?)?,
        })
    }
}

/// One AOT artifact: an HLO file plus its flat signature.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub config: String,
    pub rank: Option<usize>,
    pub scope: Option<String>,
    pub bits: Option<usize>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    /// Index of a named input.
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| anyhow!("artifact {} has no input '{name}'", self.name))
    }

    /// Index of a named output.
    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| anyhow!("artifact {} has no output '{name}'", self.name))
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: BTreeMap<String, ModelDims>,
    pub ranks: BTreeMap<String, Vec<usize>>,
    pub scopes: BTreeMap<String, Vec<String>>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load from `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text)?;

        let mut configs = BTreeMap::new();
        if let Some(Json::Obj(map)) = j.get("configs") {
            for (k, v) in map {
                configs.insert(k.clone(), ModelDims::from_json(v)?);
            }
        }
        let mut ranks = BTreeMap::new();
        if let Some(Json::Obj(map)) = j.get("ranks") {
            for (k, v) in map {
                let rs = v
                    .as_arr()
                    .ok_or_else(|| anyhow!("ranks not an array"))?
                    .iter()
                    .filter_map(|x| x.as_usize())
                    .collect();
                ranks.insert(k.clone(), rs);
            }
        }
        let mut scopes = BTreeMap::new();
        if let Some(Json::Obj(map)) = j.get("scopes") {
            for (k, v) in map {
                let ss = v
                    .as_arr()
                    .ok_or_else(|| anyhow!("scopes not an array"))?
                    .iter()
                    .filter_map(|x| x.as_str().map(String::from))
                    .collect();
                scopes.insert(k.clone(), ss);
            }
        }

        let mut artifacts = BTreeMap::new();
        for a in j.arr_of("artifacts")? {
            let meta = a.req("meta")?;
            let spec = ArtifactSpec {
                name: a.str_of("name")?.to_string(),
                file: a.str_of("file")?.to_string(),
                kind: meta.str_of("kind")?.to_string(),
                config: meta.str_of("config")?.to_string(),
                rank: meta.get("rank").and_then(|v| v.as_usize()),
                scope: meta.get("scope").and_then(|v| v.as_str().map(String::from)),
                bits: meta.get("bits").and_then(|v| v.as_usize()),
                inputs: a
                    .arr_of("inputs")?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()?,
                outputs: a
                    .arr_of("outputs")?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()?,
            };
            artifacts.insert(spec.name.clone(), spec);
        }

        Ok(Manifest { dir, configs, ranks, scopes, artifacts })
    }

    pub fn dims(&self, config: &str) -> Result<&ModelDims> {
        self.configs
            .get(config)
            .ok_or_else(|| anyhow!("config '{config}' not in manifest"))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    /// Train-step artifact name for (config, rank, scope).
    pub fn train_step_name(config: &str, rank: usize, scope: &str) -> String {
        format!("train_step_{config}_r{rank}_{scope}")
    }

    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Parses the real manifest when artifacts exist (CI runs after
    /// `make artifacts`); skips otherwise.
    #[test]
    fn parses_real_manifest() {
        let dir = std::path::Path::new("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(dir).unwrap();
        assert!(m.configs.contains_key("tiny"));
        assert!(m.configs.contains_key("small"));
        let tiny = m.dims("tiny").unwrap();
        assert_eq!(tiny.d_model, 64);
        let ts = m
            .artifact(&Manifest::train_step_name("tiny", 4, "model_gt"))
            .unwrap();
        assert_eq!(ts.kind, "train_step");
        assert_eq!(ts.rank, Some(4));
        // teacher params (12) + qweights (7) + 3*adapters (42) + t + lr + tokens
        assert_eq!(ts.inputs.len(), 12 + 7 + 42 + 3);
        assert!(ts.outputs.len() == 42 + 3);
        // tokens input is int32 [batch, seq]
        let tok = &ts.inputs[ts.input_index("tokens").unwrap()];
        assert_eq!(tok.dtype, DType::I32);
        assert_eq!(tok.shape, vec![tiny.batch, tiny.seq]);
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("float32").unwrap(), DType::F32);
        assert!(DType::parse("float64").is_err());
    }
}
