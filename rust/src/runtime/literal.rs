//! Marshalling between Rust buffers and `xla::Literal`s.

use anyhow::{bail, Result};
use xla::{ElementType, Literal};

use super::manifest::{DType, TensorSpec};

/// f32 literal with an explicit shape.
pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("lit_f32: shape {:?} ({} elems) vs buffer {}", shape, n, data.len());
    }
    // SAFETY: reinterprets the initialized, live `&[f32]` as bytes — every
    // f32 bit pattern is a valid u8 sequence, alignment 4 satisfies u8's 1,
    // and len*4 is the exact byte span. PJRT copies out of the borrow
    // before this function returns.
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Literal::create_from_shape_and_untyped_data(ElementType::F32, shape, bytes)
        .map_err(|e| anyhow::anyhow!("{e:?}"))
}

/// i32 literal with an explicit shape.
pub fn lit_i32(shape: &[usize], data: &[i32]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("lit_i32: shape {:?} vs buffer {}", shape, data.len());
    }
    // SAFETY: as in [`lit_f32`] — initialized `&[i32]` viewed as its exact
    // byte span (alignment 4 → 1, len*4 bytes), copied out before return.
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Literal::create_from_shape_and_untyped_data(ElementType::S32, shape, bytes)
        .map_err(|e| anyhow::anyhow!("{e:?}"))
}

/// u8 literal with an explicit shape.
pub fn lit_u8(shape: &[usize], data: &[u8]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("lit_u8: shape {:?} vs buffer {}", shape, data.len());
    }
    Literal::create_from_shape_and_untyped_data(ElementType::U8, shape, data)
        .map_err(|e| anyhow::anyhow!("{e:?}"))
}

/// Scalar f32 literal.
pub fn lit_scalar_f32(v: f32) -> Literal {
    Literal::scalar(v)
}

/// Copy a literal back to a `Vec<f32>`.
pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))
}

/// Build a literal for a manifest tensor spec from an untyped f32 buffer
/// (f32 specs) — used for the bulk of artifact inputs.
pub fn lit_for_spec_f32(spec: &TensorSpec, data: &[f32]) -> Result<Literal> {
    match spec.dtype {
        DType::F32 => lit_f32(&spec.shape, data),
        other => bail!("spec {} is {:?}, not f32", spec.name, other),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = lit_f32(&[2, 3], &data).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(to_vec_f32(&lit).unwrap(), data);
    }

    #[test]
    fn i32_roundtrip() {
        let data = vec![1i32, -2, 3];
        let lit = lit_i32(&[3], &data).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data);
    }

    #[test]
    fn u8_roundtrip() {
        let data = vec![0u8, 127, 255];
        let lit = lit_u8(&[3], &data).unwrap();
        assert_eq!(lit.to_vec::<u8>().unwrap(), data);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(lit_f32(&[2, 2], &[1.0, 2.0]).is_err());
    }
}
