//! QuaRot-style rotation quantizer: a randomized (block-)Hadamard rotation
//! redistributes weight outliers into a near-Gaussian spectrum, the rotated
//! matrix is quantized with GPTQ (matching the paper's setup), and the
//! rotation is folded back so downstream consumers see an effective dense
//! matrix in the original basis.
//!
//! Simulation notes (DESIGN.md substitution table): real QuaRot fuses the
//! rotation into adjacent ops at inference; numerically the effective
//! weight is `R_in · Q(R_inᵀ W R_out) · R_outᵀ`, which is exactly what we
//! materialize. For non-power-of-two dims we use a block-diagonal Hadamard
//! (largest power-of-two divisor) with a random ±1 diagonal, which is still
//! orthogonal and mixes outliers within blocks.

use super::{CalibCtx, Gptq, QuantResult, Quantizer};
use crate::tensor::{hadamard_matrix, Mat, Rng};

#[derive(Clone, Debug)]
pub struct QuaRot {
    pub bits: u8,
    pub group_size: usize,
}

impl QuaRot {
    pub fn new(bits: u8, group_size: usize) -> QuaRot {
        QuaRot { bits, group_size }
    }
}

/// Largest power-of-two divisor of `n` (the Hadamard block size).
fn pow2_block(n: usize) -> usize {
    let mut b = 1;
    while n % (b * 2) == 0 {
        b *= 2;
    }
    b
}

/// Randomized block-Hadamard rotation `R = D · blockdiag(H_b, ...)` with a
/// random ±1 diagonal `D`. Orthogonal: `R Rᵀ = I`.
pub fn randomized_hadamard(n: usize, rng: &mut Rng) -> Mat {
    let b = pow2_block(n);
    let h = hadamard_matrix(b);
    let mut r = Mat::zeros(n, n);
    for blk in 0..n / b {
        r.set_block(blk * b, blk * b, &h);
    }
    // random signs on the input side
    for i in 0..n {
        let sign = if rng.next_f32() < 0.5 { -1.0 } else { 1.0 };
        for j in 0..n {
            r[(i, j)] *= sign;
        }
    }
    r
}

impl Quantizer for QuaRot {
    fn name(&self) -> &'static str {
        "quarot"
    }

    fn bits(&self) -> u8 {
        self.bits
    }

    fn quantize(&self, w: &Mat, ctx: &CalibCtx) -> QuantResult {
        let (d_in, d_out) = w.shape();
        let mut rng = Rng::seed(ctx.seed ^ ROT_SEED_MIX);
        let r_in = randomized_hadamard(d_in, &mut rng);
        let r_out = randomized_hadamard(d_out, &mut rng);

        // rotate: Ŵ = R_inᵀ W R_out
        let w_rot = r_in.t().matmul(w).matmul(&r_out);

        // rotate calibration statistics into the same basis
        let ctx_rot = match &ctx.x_samples {
            Some(x) => CalibCtx {
                x_samples: Some(x.matmul(&r_in)),
                x_sq_mean: None,
                seed: ctx.seed,
            },
            None => CalibCtx::with_seed(ctx.seed),
        };

        let inner = Gptq::new(self.bits, self.group_size);
        let q_rot = inner.quantize(&w_rot, &ctx_rot).dequant();

        // fold back: Q_eff = R_in Q̂ R_outᵀ
        let q_eff = r_in.matmul(&q_rot).matmul(&r_out.t());
        let storage = d_in * d_out * self.bits as usize / 8
            + 2 * (d_in / self.group_size) * d_out * 4;
        QuantResult::Dense { w: q_eff, bits: self.bits, storage_bytes: storage }
    }
}

/// Seed-mixing constant so QuaRot's rotation stream is independent of other
/// consumers of the experiment seed.
const ROT_SEED_MIX: u64 = 0x9a40_7b1d_3c5e_2f61;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Rtn;

    #[test]
    fn rotation_is_orthogonal() {
        let mut rng = Rng::seed(71);
        for &n in &[16usize, 24, 64, 192] {
            let r = randomized_hadamard(n, &mut rng);
            assert!(r.matmul(&r.t()).fro_dist(&Mat::eye(n)) < 1e-3, "n={n}");
        }
    }

    #[test]
    fn pow2_block_values() {
        assert_eq!(super::pow2_block(64), 64);
        assert_eq!(super::pow2_block(192), 64);
        assert_eq!(super::pow2_block(24), 8);
        assert_eq!(super::pow2_block(7), 1);
    }

    /// QuaRot's claim: rotation gaussianizes heavy-tailed weights, so
    /// quantizing the rotated matrix beats quantizing the raw one at 2
    /// bits. Heavy tails are the LLM weight pattern QuaRot targets — rare
    /// large entries blow up the per-group absmax/minmax range.
    #[test]
    fn rotation_helps_on_heavy_tails() {
        let mut rng = Rng::seed(72);
        // cubed gaussians: kurtosis >> 3, per-group range dominated by
        // rare large entries
        let w = Mat::from_fn(64, 64, |_, _| {
            let g = rng.next_gaussian();
            g * g * g
        });
        let ctx = CalibCtx::with_seed(7);
        let e_rot = QuaRot::new(2, 32).quantize(&w, &ctx).dequant().fro_dist(&w);
        let e_rtn = Rtn::new(2, 32).quantize(&w, &ctx).dequant().fro_dist(&w);
        assert!(e_rot < e_rtn, "quarot={e_rot} rtn={e_rtn}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::seed(73);
        let w = Mat::randn(32, 32, &mut rng);
        let ctx = CalibCtx::with_seed(11);
        let a = QuaRot::new(2, 16).quantize(&w, &ctx).dequant();
        let b = QuaRot::new(2, 16).quantize(&w, &ctx).dequant();
        assert!(a.fro_dist(&b) < 1e-6);
    }
}
