//! GPTQ (OPTQ) — Hessian-aware sequential rounding. Used standalone and as
//! the inner quantizer of the QuaRot-style pipeline (as in the paper's
//! setup: "Following the original work, we apply GPTQ on QuaRot").
//!
//! Algorithm (Frantar et al. 2023), adapted to the `[d_in, d_out]`
//! convention: the Hessian of the layer-reconstruction objective is
//! `H = 2 X Xᵀ` over input dims. Input dims are quantized sequentially;
//! after fixing dim *i*, the residual error is propagated into the
//! not-yet-quantized dims via the Cholesky factor of `H⁻¹`.

use super::rtn::quantize_uniform;
use super::{CalibCtx, QuantResult, QuantizedTensor, Quantizer};
use crate::tensor::Mat;

#[derive(Clone, Debug)]
pub struct Gptq {
    pub bits: u8,
    pub group_size: usize,
    /// Hessian dampening fraction (λ = percdamp · mean(diag H))
    pub percdamp: f32,
}

impl Gptq {
    pub fn new(bits: u8, group_size: usize) -> Gptq {
        Gptq { bits, group_size, percdamp: 0.01 }
    }
}

/// Upper-triangular Cholesky of the inverse Hessian, following the GPTQ
/// reference implementation: `H⁻¹ = (Lᵀ L)` path via
/// `cholesky(inverse(H), upper)`.
fn cholesky_inv_upper(h: &Mat) -> Mat {
    let n = h.rows();
    // invert via Gauss-Jordan with partial pivoting (f64 accumulation)
    let mut a: Vec<f64> = h.data().iter().map(|&x| x as f64).collect();
    let mut inv: Vec<f64> = vec![0.0; n * n];
    for i in 0..n {
        inv[i * n + i] = 1.0;
    }
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in col + 1..n {
            if a[r * n + col].abs() > a[piv * n + col].abs() {
                piv = r;
            }
        }
        if piv != col {
            for j in 0..n {
                a.swap(col * n + j, piv * n + j);
                inv.swap(col * n + j, piv * n + j);
            }
        }
        let d = a[col * n + col];
        assert!(d.abs() > 1e-12, "singular Hessian even after dampening");
        for j in 0..n {
            a[col * n + j] /= d;
            inv[col * n + j] /= d;
        }
        for r in 0..n {
            if r == col {
                continue;
            }
            let f = a[r * n + col];
            if f == 0.0 {
                continue;
            }
            for j in 0..n {
                a[r * n + j] -= f * a[col * n + j];
                inv[r * n + j] -= f * inv[col * n + j];
            }
        }
    }
    // upper Cholesky of inv: inv = Uᵀ U with U upper triangular
    let mut u = vec![0.0f64; n * n];
    for i in 0..n {
        for j in i..n {
            let mut sum = inv[i * n + j];
            for k in 0..i {
                sum -= u[k * n + i] * u[k * n + j];
            }
            if i == j {
                u[i * n + j] = sum.max(1e-12).sqrt();
            } else {
                u[i * n + j] = sum / u[i * n + i];
            }
        }
    }
    Mat::from_vec(n, n, u.into_iter().map(|x| x as f32).collect())
}

impl Quantizer for Gptq {
    fn name(&self) -> &'static str {
        "gptq"
    }

    fn bits(&self) -> u8 {
        self.bits
    }

    fn quantize(&self, w: &Mat, ctx: &CalibCtx) -> QuantResult {
        // ragged d_in is fine: the group grids come from quantize_uniform,
        // which sizes a partial final group, and `i / group_size` below
        // indexes those grids consistently
        let (d_in, d_out) = w.shape();

        // Hessian H = X Xᵀ (+ dampening). Without calibration samples fall
        // back to the diagonal proxy (equivalent to per-dim weighted RTN
        // with error feedback disabled across dims).
        let mut h = match &ctx.x_samples {
            Some(x) => {
                assert_eq!(x.cols(), d_in);
                let xt = x.t();
                xt.matmul(x) // [d_in, d_in]
            }
            None => {
                let diag = ctx.diag_h(d_in);
                Mat::from_fn(d_in, d_in, |i, j| if i == j { diag[i] } else { 0.0 })
            }
        };
        let mean_diag: f32 =
            (0..d_in).map(|i| h[(i, i)]).sum::<f32>() / d_in as f32;
        let damp = self.percdamp * mean_diag.max(1e-8);
        for i in 0..d_in {
            h[(i, i)] += damp;
        }
        let hinv_u = cholesky_inv_upper(&h);

        // Group grids come from the *original* weights (standard GPTQ uses
        // the running group as it quantizes; original-W grids are the
        // common static-groups variant).
        let grids = quantize_uniform(w, self.bits, self.group_size, None);
        let levels = ((1u32 << self.bits) - 1) as f32;

        let mut work = w.clone(); // mutated with error feedback
        let mut codes = vec![0u8; d_in * d_out];

        for i in 0..d_in {
            let g = i / self.group_size;
            let dii = hinv_u[(i, i)].max(1e-9);
            for j in 0..d_out {
                let s = grids.scales[(g, j)];
                let z = grids.zeros[(g, j)];
                let v = work[(i, j)];
                let c = ((v - z) / s).round().clamp(0.0, levels);
                codes[i * d_out + j] = c as u8;
                let q = z + c * s;
                let err = (v - q) / dii;
                // propagate into remaining dims k > i
                for k in i + 1..d_in {
                    let u = hinv_u[(i, k)];
                    if u != 0.0 {
                        work[(k, j)] -= err * u;
                    }
                }
            }
        }

        QuantResult::Scalar(QuantizedTensor {
            codes,
            d_in,
            d_out,
            bits: self.bits,
            group_size: self.group_size,
            scales: grids.scales,
            zeros: grids.zeros,
            codebook: (0..=(levels as u32)).map(|c| c as f32).collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Rtn;
    use crate::tensor::Rng;

    fn calib(rng: &mut Rng, n: usize, d: usize) -> Mat {
        Mat::randn(n, d, rng)
    }

    /// GPTQ's defining property: lower *layer-output* error than RTN under
    /// the calibration distribution (weight error may be higher).
    #[test]
    fn gptq_beats_rtn_on_output_error() {
        let mut rng = Rng::seed(61);
        let d_in = 64;
        let d_out = 24;
        let w = Mat::randn(d_in, d_out, &mut rng);
        // anisotropic inputs: correlated dims make error feedback matter
        let mix = Mat::randn(d_in, d_in, &mut rng);
        let x = calib(&mut rng, 256, d_in).matmul(&mix);
        let ctx = CalibCtx { x_samples: Some(x.clone()), ..Default::default() };

        let q_gptq = Gptq::new(2, 32).quantize(&w, &ctx).dequant();
        let q_rtn = Rtn::new(2, 32).quantize(&w, &ctx).dequant();

        let y = x.matmul(&w);
        let e_gptq = x.matmul(&q_gptq).fro_dist(&y);
        let e_rtn = x.matmul(&q_rtn).fro_dist(&y);
        assert!(e_gptq < e_rtn, "gptq={e_gptq} rtn={e_rtn}");
    }

    #[test]
    fn cholesky_inv_is_factor_of_inverse() {
        let mut rng = Rng::seed(62);
        let a = Mat::randn(12, 12, &mut rng);
        let mut h = a.t().matmul(&a);
        for i in 0..12 {
            h[(i, i)] += 1.0;
        }
        let u = cholesky_inv_upper(&h);
        // Uᵀ U should equal H⁻¹, i.e. H (Uᵀ U) ≈ I
        let utu = u.t().matmul(&u);
        let prod = h.matmul(&utu);
        assert!(prod.fro_dist(&Mat::eye(12)) < 1e-2, "dist={}", prod.fro_dist(&Mat::eye(12)));
    }

    #[test]
    fn no_calibration_falls_back_cleanly() {
        let mut rng = Rng::seed(63);
        let w = Mat::randn(32, 8, &mut rng);
        let q = Gptq::new(4, 16).quantize(&w, &CalibCtx::default());
        let rel = q.dequant().fro_dist(&w) / w.fro_norm();
        assert!(rel < 0.2, "rel={rel}");
    }

    #[test]
    fn codes_in_range() {
        let mut rng = Rng::seed(64);
        let w = Mat::randn(32, 8, &mut rng);
        let x = calib(&mut rng, 64, 32);
        let ctx = CalibCtx { x_samples: Some(x), ..Default::default() };
        let qr = Gptq::new(2, 16).quantize(&w, &ctx);
        let q = qr.as_scalar().unwrap();
        assert!(q.codes.iter().all(|&c| c < 4));
    }
}
