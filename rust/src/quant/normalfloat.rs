//! NormalFloat quantization (QLoRA's NF4 generalized to NF2/NF3) — the base
//! quantizer under LoftQ in the paper (NF2 for the W2A16 rows of Tables 1,
//! 4, 9).
//!
//! The codebook is built from quantiles of the standard normal: weights are
//! assumed ≈ N(0, σ) per group, normalized by the group absmax, and snapped
//! to the nearest codebook level. Like QLoRA we force an exact-zero level
//! and make the codebook asymmetric (more negative levels map the heavier
//! negative tail of trained weights — here we follow the symmetric-halves
//! construction of the QLoRA paper).

use super::{CalibCtx, QuantResult, QuantizedTensor, Quantizer};
use crate::tensor::Mat;

#[derive(Clone, Debug)]
pub struct NormalFloat {
    pub bits: u8,
    pub group_size: usize,
}

impl NormalFloat {
    pub fn new(bits: u8, group_size: usize) -> NormalFloat {
        assert!((2..=4).contains(&bits), "NF supports 2..4 bits");
        NormalFloat { bits, group_size }
    }

    /// The NF codebook for a bit width, sorted ascending, normalized to
    /// `[-1, 1]`, containing an exact 0.
    pub fn codebook(bits: u8) -> Vec<f32> {
        let n = 1usize << bits;
        // QLoRA construction: negative half from n/2+1 quantiles of N(0,1)
        // over (δ, 1/2], positive half from n/2 quantiles over [1/2, 1-δ),
        // yielding n levels including exactly one zero.
        let delta = 0.5 * (1.0 / 30.0 + 1.0 / 32.0); // QLoRA's offset choice
        let neg_cnt = n / 2;
        let pos_cnt = n - neg_cnt; // includes the zero level
        let mut levels = Vec::with_capacity(n);
        for k in 0..neg_cnt {
            let p = delta + (0.5 - delta) * (k as f64) / (neg_cnt as f64);
            levels.push(probit(p) as f32);
        }
        for k in 0..pos_cnt {
            let p = 0.5 + (0.5 - delta) * (k as f64) / ((pos_cnt - 1).max(1) as f64);
            levels.push(probit(p) as f32);
        }
        let maxabs = levels.iter().fold(0.0f32, |m, &x| m.max(x.abs())).max(1e-9);
        for l in &mut levels {
            *l /= maxabs;
            if l.abs() < 1e-7 {
                *l = 0.0;
            }
        }
        levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
        levels
    }
}

/// Acklam's rational approximation to the inverse normal CDF.
/// Max abs error ~1.15e-9 — far below quantization granularity.
pub fn probit(p: f64) -> f64 {
    debug_assert!(p > 0.0 && p < 1.0);
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let p_low = 0.02425;
    if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        -probit(1.0 - p)
    }
}

impl Quantizer for NormalFloat {
    fn name(&self) -> &'static str {
        "nf"
    }

    fn bits(&self) -> u8 {
        self.bits
    }

    fn quantize(&self, w: &Mat, _ctx: &CalibCtx) -> QuantResult {
        let (d_in, d_out) = w.shape();
        // ragged final group when d_in is not a multiple of group_size
        let n_groups = d_in.div_ceil(self.group_size);
        let cb = Self::codebook(self.bits);
        let mut codes = vec![0u8; d_in * d_out];
        let mut scales = Mat::zeros(n_groups, d_out);
        let zeros = Mat::zeros(n_groups, d_out); // NF is absmax-scaled, zero offset

        for g in 0..n_groups {
            let r0 = g * self.group_size;
            let r1 = (r0 + self.group_size).min(d_in);
            for j in 0..d_out {
                let mut absmax = 0.0f32;
                for i in r0..r1 {
                    absmax = absmax.max(w[(i, j)].abs());
                }
                let s = absmax.max(1e-9);
                scales[(g, j)] = s;
                for i in r0..r1 {
                    let target = w[(i, j)] / s;
                    // codebook is sorted: binary search + neighbor compare
                    let idx = nearest_level(&cb, target);
                    codes[i * d_out + j] = idx as u8;
                }
            }
        }

        QuantResult::Scalar(QuantizedTensor {
            codes,
            d_in,
            d_out,
            bits: self.bits,
            group_size: self.group_size,
            scales,
            zeros,
            codebook: cb,
        })
    }
}

/// Index of the nearest value in a sorted codebook.
pub fn nearest_level(cb: &[f32], x: f32) -> usize {
    let mut lo = 0usize;
    let mut hi = cb.len();
    while lo < hi {
        let mid = (lo + hi) / 2;
        if cb[mid] < x {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    if lo == 0 {
        0
    } else if lo >= cb.len() {
        cb.len() - 1
    } else if (x - cb[lo - 1]).abs() <= (cb[lo] - x).abs() {
        lo - 1
    } else {
        lo
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn probit_matches_known_points() {
        assert!((probit(0.5)).abs() < 1e-9);
        assert!((probit(0.975) - 1.959964).abs() < 1e-4);
        assert!((probit(0.025) + 1.959964).abs() < 1e-4);
    }

    #[test]
    fn codebook_properties() {
        for bits in [2u8, 3, 4] {
            let cb = NormalFloat::codebook(bits);
            assert_eq!(cb.len(), 1 << bits);
            assert!(cb.windows(2).all(|w| w[0] < w[1]), "sorted {cb:?}");
            assert!(cb.iter().any(|&x| x == 0.0), "has zero {cb:?}");
            assert!((cb.iter().fold(0.0f32, |m, &x| m.max(x.abs())) - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn nf_beats_symmetric_uniform_on_gaussian_weights() {
        // NF's raison d'être (QLoRA §3): lower MSE than *symmetric absmax*
        // uniform quantization on normal-distributed weights at the same
        // bit width. (Asymmetric min/max RTN is a stronger baseline and can
        // edge NF out at 4-bit; the paper's LoftQ rows use NF regardless.)
        let mut rng = Rng::seed(41);
        let w = Mat::randn(256, 64, &mut rng);
        let ctx = CalibCtx::default();
        let nf = NormalFloat::new(4, 64).quantize(&w, &ctx).dequant().fro_dist(&w);

        // symmetric absmax uniform, same grouping
        let group = 64;
        let mut err2 = 0.0f64;
        for g in 0..256 / group {
            for j in 0..64 {
                let mut absmax = 0.0f32;
                for i in g * group..(g + 1) * group {
                    absmax = absmax.max(w[(i, j)].abs());
                }
                let s = 2.0 * absmax / 15.0; // 4-bit symmetric: 16 levels
                for i in g * group..(g + 1) * group {
                    let v = w[(i, j)];
                    let q = ((v + absmax) / s).round().clamp(0.0, 15.0) * s - absmax;
                    err2 += ((v - q) as f64).powi(2);
                }
            }
        }
        let uniform = (err2.sqrt()) as f32;
        assert!(nf < uniform, "nf={nf} uniform={uniform}");
    }

    #[test]
    fn nearest_level_boundaries() {
        let cb = [-1.0f32, 0.0, 1.0];
        assert_eq!(nearest_level(&cb, -5.0), 0);
        assert_eq!(nearest_level(&cb, 5.0), 2);
        assert_eq!(nearest_level(&cb, 0.4), 1);
        assert_eq!(nearest_level(&cb, 0.6), 2);
    }

    #[test]
    fn nf2_roundtrip_reasonable() {
        let mut rng = Rng::seed(42);
        let w = Mat::randn(128, 32, &mut rng);
        let q = NormalFloat::new(2, 32).quantize(&w, &CalibCtx::default());
        let rel = q.dequant().fro_dist(&w) / w.fro_norm();
        // 2-bit is lossy but must stay in a sane band
        assert!(rel > 0.05 && rel < 0.8, "rel={rel}");
    }
}
