//! Round-to-nearest (RTN) uniform asymmetric quantization — Eq. 1 of the
//! paper with γ = β = 1: per-group min/max determine scale and zero-point.
//! This is the weakest baseline and the quantizer under Table 6/10.

use super::{CalibCtx, QuantResult, QuantizedTensor, Quantizer};
use crate::tensor::Mat;

#[derive(Clone, Debug)]
pub struct Rtn {
    pub bits: u8,
    pub group_size: usize,
}

impl Rtn {
    pub fn new(bits: u8, group_size: usize) -> Rtn {
        assert!((2..=8).contains(&bits));
        Rtn { bits, group_size }
    }
}

/// Core uniform-grid quantization of one `[d_in, d_out]` matrix with
/// per-(group, column) clipping strengths γ (max side) and β (min side).
/// Shared with the OmniQuant-style quantizer which searches γ/β.
pub fn quantize_uniform(
    w: &Mat,
    bits: u8,
    group_size: usize,
    gamma_beta: Option<&dyn Fn(usize, usize) -> (f32, f32)>,
) -> QuantizedTensor {
    let (d_in, d_out) = w.shape();
    // ragged final group when d_in is not a multiple of group_size
    let n_groups = d_in.div_ceil(group_size);
    let levels = (1u32 << bits) - 1;
    let mut codes = vec![0u8; d_in * d_out];
    let mut scales = Mat::zeros(n_groups, d_out);
    let mut zeros = Mat::zeros(n_groups, d_out);

    for g in 0..n_groups {
        let r0 = g * group_size;
        let r1 = (r0 + group_size).min(d_in);
        for j in 0..d_out {
            let mut wmin = f32::INFINITY;
            let mut wmax = f32::NEG_INFINITY;
            for i in r0..r1 {
                let v = w[(i, j)];
                wmin = wmin.min(v);
                wmax = wmax.max(v);
            }
            let (gamma, beta) = gamma_beta.map(|f| f(g, j)).unwrap_or((1.0, 1.0));
            let hi = gamma * wmax;
            let lo = beta * wmin;
            let range = (hi - lo).max(1e-8);
            let s = range / levels as f32;
            scales[(g, j)] = s;
            zeros[(g, j)] = lo;
            for i in r0..r1 {
                let v = w[(i, j)];
                let c = ((v - lo) / s).round().clamp(0.0, levels as f32) as u8;
                codes[i * d_out + j] = c;
            }
        }
    }

    QuantizedTensor {
        codes,
        d_in,
        d_out,
        bits,
        group_size,
        scales,
        zeros,
        codebook: (0..=levels).map(|c| c as f32).collect(),
    }
}

impl Quantizer for Rtn {
    fn name(&self) -> &'static str {
        "rtn"
    }

    fn bits(&self) -> u8 {
        self.bits
    }

    fn quantize(&self, w: &Mat, _ctx: &CalibCtx) -> QuantResult {
        QuantResult::Scalar(quantize_uniform(w, self.bits, self.group_size, None))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn rtn_8bit_nearly_lossless() {
        let mut rng = Rng::seed(31);
        let w = Mat::randn(64, 16, &mut rng);
        let q = Rtn::new(8, 32).quantize(&w, &CalibCtx::default());
        let rel = q.dequant().fro_dist(&w) / w.fro_norm();
        assert!(rel < 0.01, "rel={rel}");
    }

    #[test]
    fn error_grows_as_bits_shrink() {
        let mut rng = Rng::seed(32);
        let w = Mat::randn(128, 32, &mut rng);
        let ctx = CalibCtx::default();
        let errs: Vec<f32> = [2u8, 3, 4, 8]
            .iter()
            .map(|&b| Rtn::new(b, 32).quantize(&w, &ctx).dequant().fro_dist(&w))
            .collect();
        assert!(errs[0] > errs[1] && errs[1] > errs[2] && errs[2] > errs[3], "{errs:?}");
    }

    #[test]
    fn per_element_error_bounded_by_half_step() {
        let mut rng = Rng::seed(33);
        let w = Mat::randn(32, 8, &mut rng);
        let qr = Rtn::new(4, 16).quantize(&w, &CalibCtx::default());
        let q = qr.as_scalar().unwrap();
        let deq = q.dequant();
        for i in 0..32 {
            let g = i / 16;
            for j in 0..8 {
                let step = q.scales[(g, j)];
                let err = (deq[(i, j)] - w[(i, j)]).abs();
                assert!(err <= 0.5 * step + 1e-5, "err {err} > step/2 {}", step / 2.0);
            }
        }
    }

    #[test]
    fn constant_group_is_exact() {
        let w = Mat::full(16, 4, 0.7);
        let q = Rtn::new(2, 16).quantize(&w, &CalibCtx::default());
        assert!(q.dequant().fro_dist(&w) < 1e-5);
    }

    /// property: codes stay within the bit budget
    #[test]
    fn prop_codes_in_range() {
        let mut rng = Rng::seed(34);
        for _ in 0..50 {
            let bits = 2 + (rng.below(3) as u8);
            let g = [8usize, 16, 32][rng.below(3)];
            let d_in = g * (1 + rng.below(4));
            let d_out = 1 + rng.below(16);
            let w = Mat::randn(d_in, d_out, &mut rng);
            let qr = Rtn::new(bits, g).quantize(&w, &CalibCtx::default());
            let q = qr.as_scalar().unwrap();
            assert!(q.codes.iter().all(|&c| (c as u32) < (1 << bits)));
        }
    }
}
