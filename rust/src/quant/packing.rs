//! Bit-packing of quantization codes.
//!
//! Must stay bit-for-bit compatible with `python/compile/kernels/ref.py`:
//! codes are packed along the `d_in` axis, little-endian within each byte
//! (code *i* of a byte sits at bit position `i * bits`). 2-bit packs 4
//! codes/byte, 4-bit packs 2 codes/byte; 3-bit stays one code per byte
//! (cross-byte straddling isn't worth it at simulation scale — documented
//! in DESIGN.md).

/// A packed code matrix plus its logical geometry.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedTensor {
    /// row-major `[packed_rows, d_out]`
    pub data: Vec<u8>,
    pub packed_rows: usize,
    pub d_in: usize,
    pub d_out: usize,
    pub bits: u8,
}

/// Number of packed rows for a given `d_in` and bit width.
pub fn packed_rows(d_in: usize, bits: u8) -> usize {
    match bits {
        2 => {
            assert!(d_in % 4 == 0, "2-bit packing needs d_in % 4 == 0");
            d_in / 4
        }
        4 => {
            assert!(d_in % 2 == 0, "4-bit packing needs d_in % 2 == 0");
            d_in / 2
        }
        3 => d_in,
        b => panic!("unsupported bits={b}"),
    }
}

/// Pack codes (`[d_in, d_out]` row-major, one code per byte) along `d_in`.
pub fn pack_codes(codes: &[u8], d_in: usize, d_out: usize, bits: u8) -> PackedTensor {
    assert_eq!(codes.len(), d_in * d_out);
    let rows = packed_rows(d_in, bits);
    let mut data = vec![0u8; rows * d_out];
    match bits {
        2 => {
            for pr in 0..rows {
                for j in 0..d_out {
                    let mut byte = 0u8;
                    for k in 0..4 {
                        let c = codes[(pr * 4 + k) * d_out + j];
                        debug_assert!(c < 4);
                        byte |= c << (2 * k);
                    }
                    data[pr * d_out + j] = byte;
                }
            }
        }
        4 => {
            for pr in 0..rows {
                for j in 0..d_out {
                    let lo = codes[(pr * 2) * d_out + j];
                    let hi = codes[(pr * 2 + 1) * d_out + j];
                    debug_assert!(lo < 16 && hi < 16);
                    data[pr * d_out + j] = lo | (hi << 4);
                }
            }
        }
        3 => data.copy_from_slice(codes),
        _ => unreachable!(),
    }
    PackedTensor { data, packed_rows: rows, d_in, d_out, bits }
}

/// Unpack back to one code per byte, `[d_in, d_out]` row-major.
pub fn unpack_codes(p: &PackedTensor) -> Vec<u8> {
    let mut codes = vec![0u8; p.d_in * p.d_out];
    match p.bits {
        2 => {
            for pr in 0..p.packed_rows {
                for j in 0..p.d_out {
                    let byte = p.data[pr * p.d_out + j];
                    for k in 0..4 {
                        codes[(pr * 4 + k) * p.d_out + j] = (byte >> (2 * k)) & 0x3;
                    }
                }
            }
        }
        4 => {
            for pr in 0..p.packed_rows {
                for j in 0..p.d_out {
                    let byte = p.data[pr * p.d_out + j];
                    codes[(pr * 2) * p.d_out + j] = byte & 0xF;
                    codes[(pr * 2 + 1) * p.d_out + j] = byte >> 4;
                }
            }
        }
        3 => codes.copy_from_slice(&p.data),
        _ => unreachable!(),
    }
    codes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn random_codes(d_in: usize, d_out: usize, bits: u8, rng: &mut Rng) -> Vec<u8> {
        (0..d_in * d_out).map(|_| rng.below(1 << bits) as u8).collect()
    }

    #[test]
    fn roundtrip_2bit() {
        let mut rng = Rng::seed(21);
        let codes = random_codes(16, 5, 2, &mut rng);
        let p = pack_codes(&codes, 16, 5, 2);
        assert_eq!(p.packed_rows, 4);
        assert_eq!(unpack_codes(&p), codes);
    }

    #[test]
    fn roundtrip_4bit() {
        let mut rng = Rng::seed(22);
        let codes = random_codes(10, 7, 4, &mut rng);
        let p = pack_codes(&codes, 10, 7, 4);
        assert_eq!(p.packed_rows, 5);
        assert_eq!(unpack_codes(&p), codes);
    }

    #[test]
    fn roundtrip_3bit_identity() {
        let mut rng = Rng::seed(23);
        let codes = random_codes(6, 3, 3, &mut rng);
        let p = pack_codes(&codes, 6, 3, 3);
        assert_eq!(p.data, codes);
        assert_eq!(unpack_codes(&p), codes);
    }

    /// property: roundtrip over 100 random geometries
    #[test]
    fn prop_roundtrip() {
        let mut rng = Rng::seed(24);
        for case in 0..100 {
            let bits = [2u8, 3, 4][case % 3];
            let mult = match bits {
                2 => 4,
                4 => 2,
                _ => 1,
            };
            let d_in = mult * (1 + rng.below(16));
            let d_out = 1 + rng.below(24);
            let codes = random_codes(d_in, d_out, bits, &mut rng);
            let p = pack_codes(&codes, d_in, d_out, bits);
            assert_eq!(unpack_codes(&p), codes, "bits={bits} d_in={d_in} d_out={d_out}");
        }
    }

    /// the documented bit layout, pinned so Python/Rust stay in sync
    #[test]
    fn bit_layout_pinned() {
        // d_in=4, d_out=1, codes [1,2,3,0] -> byte 0b00_11_10_01
        let p = pack_codes(&[1, 2, 3, 0], 4, 1, 2);
        assert_eq!(p.data, vec![0b0011_1001]);
        // 4-bit: [0xA, 0x5] -> 0x5A
        let p = pack_codes(&[0xA, 0x5], 2, 1, 4);
        assert_eq!(p.data, vec![0x5A]);
    }

    #[test]
    #[should_panic]
    fn misaligned_2bit_rejected() {
        pack_codes(&[0; 6], 6, 1, 2);
    }
}
