//! Bit-packing of quantization codes.
//!
//! Must stay bit-for-bit compatible with `python/compile/kernels/ref.py`:
//! codes are packed along the `d_in` axis, little-endian within each byte
//! (code *i* of a byte sits at bit position `i * bits`). 2-bit packs 4
//! codes/byte, 4-bit packs 2 codes/byte; 3-bit stays one code per byte
//! (cross-byte straddling isn't worth it at simulation scale — documented
//! in DESIGN.md).
//!
//! Ragged lengths: when `d_in` is not a multiple of the codes-per-byte
//! factor, the final packed row is zero-padded (code 0 in the unused
//! lanes) and [`unpack_codes`] truncates back to `d_in` rows. Aligned
//! shapes produce byte-identical output to the Python reference, which
//! asserts alignment instead of padding.

/// A packed code matrix plus its logical geometry.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedTensor {
    /// row-major `[packed_rows, d_out]`
    pub data: Vec<u8>,
    pub packed_rows: usize,
    pub d_in: usize,
    pub d_out: usize,
    pub bits: u8,
}

impl PackedTensor {
    /// Bytes of packed code storage (group metadata excluded).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }
}

/// Codes stored per packed byte at a bit width.
pub fn codes_per_byte(bits: u8) -> usize {
    match bits {
        2 => 4,
        4 => 2,
        3 => 1,
        b => panic!("unsupported bits={b}"),
    }
}

/// Number of packed rows for a given `d_in` and bit width (final row
/// zero-padded when `d_in` is not a multiple of the packing factor).
pub fn packed_rows(d_in: usize, bits: u8) -> usize {
    d_in.div_ceil(codes_per_byte(bits))
}

/// Pack codes (`[d_in, d_out]` row-major, one code per byte) along `d_in`.
pub fn pack_codes(codes: &[u8], d_in: usize, d_out: usize, bits: u8) -> PackedTensor {
    assert_eq!(codes.len(), d_in * d_out);
    let per = codes_per_byte(bits);
    let rows = packed_rows(d_in, bits);
    let mut data = vec![0u8; rows * d_out];
    if bits == 3 {
        data.copy_from_slice(codes);
        return PackedTensor { data, packed_rows: rows, d_in, d_out, bits };
    }
    let shift = bits as usize;
    for pr in 0..rows {
        for j in 0..d_out {
            let mut byte = 0u8;
            for k in 0..per {
                let i = pr * per + k;
                if i >= d_in {
                    break; // zero-padded tail lanes
                }
                let c = codes[i * d_out + j];
                debug_assert!((c as u32) < (1u32 << bits));
                byte |= c << (shift * k);
            }
            data[pr * d_out + j] = byte;
        }
    }
    PackedTensor { data, packed_rows: rows, d_in, d_out, bits }
}

/// Unpack back to one code per byte, `[d_in, d_out]` row-major (padding
/// lanes of a ragged final row are dropped).
pub fn unpack_codes(p: &PackedTensor) -> Vec<u8> {
    let mut codes = vec![0u8; p.d_in * p.d_out];
    if p.bits == 3 {
        codes.copy_from_slice(&p.data);
        return codes;
    }
    let per = codes_per_byte(p.bits);
    let shift = p.bits as usize;
    let mask = ((1u16 << p.bits) - 1) as u8;
    for pr in 0..p.packed_rows {
        for j in 0..p.d_out {
            let byte = p.data[pr * p.d_out + j];
            for k in 0..per {
                let i = pr * per + k;
                if i >= p.d_in {
                    break;
                }
                codes[i * p.d_out + j] = (byte >> (shift * k)) & mask;
            }
        }
    }
    codes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn random_codes(d_in: usize, d_out: usize, bits: u8, rng: &mut Rng) -> Vec<u8> {
        (0..d_in * d_out).map(|_| rng.below(1 << bits) as u8).collect()
    }

    #[test]
    fn roundtrip_2bit() {
        let mut rng = Rng::seed(21);
        let codes = random_codes(16, 5, 2, &mut rng);
        let p = pack_codes(&codes, 16, 5, 2);
        assert_eq!(p.packed_rows, 4);
        assert_eq!(unpack_codes(&p), codes);
    }

    #[test]
    fn roundtrip_4bit() {
        let mut rng = Rng::seed(22);
        let codes = random_codes(10, 7, 4, &mut rng);
        let p = pack_codes(&codes, 10, 7, 4);
        assert_eq!(p.packed_rows, 5);
        assert_eq!(unpack_codes(&p), codes);
    }

    #[test]
    fn roundtrip_3bit_identity() {
        let mut rng = Rng::seed(23);
        let codes = random_codes(6, 3, 3, &mut rng);
        let p = pack_codes(&codes, 6, 3, 3);
        assert_eq!(p.data, codes);
        assert_eq!(unpack_codes(&p), codes);
    }

    /// property: roundtrip over 200 random geometries, including lengths
    /// NOT divisible by the codes-per-byte packing factor (padded tail)
    #[test]
    fn prop_roundtrip() {
        let mut rng = Rng::seed(24);
        for case in 0..200 {
            let bits = [2u8, 3, 4][case % 3];
            let d_in = 1 + rng.below(65); // any length, aligned or ragged
            let d_out = 1 + rng.below(24);
            let codes = random_codes(d_in, d_out, bits, &mut rng);
            let p = pack_codes(&codes, d_in, d_out, bits);
            assert_eq!(p.packed_rows, packed_rows(d_in, bits));
            assert_eq!(unpack_codes(&p), codes, "bits={bits} d_in={d_in} d_out={d_out}");
        }
    }

    /// property: packed size never exceeds one extra (padded) row, and the
    /// padding lanes of a ragged final row hold zero codes
    #[test]
    fn prop_ragged_padding_is_zero() {
        let mut rng = Rng::seed(25);
        for _ in 0..50 {
            for bits in [2u8, 4] {
                let per = codes_per_byte(bits);
                let d_in = 1 + rng.below(40);
                if d_in % per == 0 {
                    continue;
                }
                let d_out = 1 + rng.below(8);
                let codes = random_codes(d_in, d_out, bits, &mut rng);
                let p = pack_codes(&codes, d_in, d_out, bits);
                let tail = d_in % per;
                let mask = ((1u16 << (bits as usize * tail)) - 1) as u8;
                for j in 0..d_out {
                    let byte = p.data[(p.packed_rows - 1) * d_out + j];
                    assert_eq!(byte & !mask, 0, "bits={bits} d_in={d_in} pad lanes nonzero");
                }
            }
        }
    }

    /// the documented bit layout, pinned so Python/Rust stay in sync
    #[test]
    fn bit_layout_pinned() {
        // d_in=4, d_out=1, codes [1,2,3,0] -> byte 0b00_11_10_01
        let p = pack_codes(&[1, 2, 3, 0], 4, 1, 2);
        assert_eq!(p.data, vec![0b0011_1001]);
        // 4-bit: [0xA, 0x5] -> 0x5A
        let p = pack_codes(&[0xA, 0x5], 2, 1, 4);
        assert_eq!(p.data, vec![0x5A]);
    }

    /// Misaligned lengths pack into a zero-padded final row (historically
    /// this was rejected with a panic; ragged linears need it).
    #[test]
    fn misaligned_2bit_pads() {
        let codes = [1u8, 2, 3, 0, 1, 2];
        let p = pack_codes(&codes, 6, 1, 2);
        assert_eq!(p.packed_rows, 2);
        assert_eq!(p.data, vec![0b0011_1001, 0b0000_1001]);
        assert_eq!(unpack_codes(&p), codes);
    }
}
