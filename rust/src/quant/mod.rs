//! Weight quantizers — every quantization substrate the paper evaluates
//! RILQ on top of, reimplemented from scratch:
//!
//! * [`rtn`] — round-to-nearest uniform quantization (Eq. 1 with γ=β=1)
//! * [`normalfloat`] — QLoRA/LoftQ NormalFloat NF2/NF3/NF4 codebooks
//! * [`omniquant`] — OmniQuant-style learnable clipping (γ, β searched per
//!   group against an activation-weighted reconstruction objective)
//! * [`gptq`] — GPTQ Hessian-aware column-sequential rounding
//! * [`quarot`] — QuaRot-style randomized (block-)Hadamard rotation
//!   wrapping GPTQ
//! * [`vq`] — QuIP#-style codebook vector quantizer (incoherence rotation +
//!   k-means-learned 4-d codebook)
//!
//! All quantizers consume a weight matrix in the `[d_in, d_out]` (x @ W)
//! convention and produce a [`QuantResult`]: either a scalar-codebook
//! [`QuantizedTensor`] (packable for the W2A16 serving path and expressible
//! in the shared `zero + scale * codebook[code]` dequant form that the
//! Pallas kernel implements) or an effective dense matrix (rotation / VQ
//! methods whose dequant is not per-scalar).

pub mod gptq;
pub mod normalfloat;
pub mod omniquant;
pub mod packing;
pub mod quarot;
pub mod rtn;
pub mod vq;

use crate::tensor::Mat;

pub use gptq::Gptq;
pub use normalfloat::NormalFloat;
pub use omniquant::OmniQuant;
pub use packing::{pack_codes, unpack_codes, PackedTensor};
pub use quarot::QuaRot;
pub use rtn::Rtn;
pub use vq::VectorQuant;

/// Scalar-codebook quantized tensor in the shared dequant form
/// `w[i,j] = zeros[g,j] + scales[g,j] * codebook[codes[i,j]]`,
/// `g = i / group_size`. Matches `python/compile/kernels/ref.py`.
#[derive(Clone, Debug)]
pub struct QuantizedTensor {
    /// one code per weight, row-major `[d_in, d_out]`, values `< 2^bits`
    pub codes: Vec<u8>,
    pub d_in: usize,
    pub d_out: usize,
    pub bits: u8,
    pub group_size: usize,
    /// `[d_in/group_size, d_out]`
    pub scales: Mat,
    /// `[d_in/group_size, d_out]`
    pub zeros: Mat,
    /// `[2^bits]`
    pub codebook: Vec<f32>,
}

impl QuantizedTensor {
    /// Number of (scale, zero) groups along `d_in` (final group may be
    /// ragged when `d_in % group_size != 0`).
    pub fn n_groups(&self) -> usize {
        self.d_in.div_ceil(self.group_size)
    }

    /// Dense dequantization.
    pub fn dequant(&self) -> Mat {
        let g = self.group_size;
        let mut w = Mat::zeros(self.d_in, self.d_out);
        for i in 0..self.d_in {
            let gi = i / g;
            let srow = self.scales.row(gi);
            let zrow = self.zeros.row(gi);
            let wrow = w.row_mut(i);
            let crow = &self.codes[i * self.d_out..(i + 1) * self.d_out];
            for j in 0..self.d_out {
                wrow[j] = zrow[j] + srow[j] * self.codebook[crow[j] as usize];
            }
        }
        w
    }

    /// Bit-pack the codes along `d_in` (see [`packing`]).
    pub fn pack(&self) -> PackedTensor {
        pack_codes(&self.codes, self.d_in, self.d_out, self.bits)
    }

    /// Serialized size in bytes of the quantized representation
    /// (packed codes + group metadata), for the memory-cost analysis.
    pub fn storage_bytes(&self) -> usize {
        let code_bits = self.d_in * self.d_out * self.bits as usize;
        let meta = 2 * self.n_groups() * self.d_out * 4;
        code_bits / 8 + meta + self.codebook.len() * 4
    }
}

/// Output of a quantizer.
#[derive(Clone, Debug)]
pub enum QuantResult {
    /// Scalar-codebook form (RTN, NF, OmniQuant, GPTQ): packable.
    Scalar(QuantizedTensor),
    /// Only an effective dense matrix is available (QuaRot, VQ): the
    /// rotation / vector codebook has been folded in.
    Dense { w: Mat, bits: u8, storage_bytes: usize },
}

impl QuantResult {
    pub fn dequant(&self) -> Mat {
        match self {
            QuantResult::Scalar(q) => q.dequant(),
            QuantResult::Dense { w, .. } => w.clone(),
        }
    }

    pub fn bits(&self) -> u8 {
        match self {
            QuantResult::Scalar(q) => q.bits,
            QuantResult::Dense { bits, .. } => *bits,
        }
    }

    pub fn storage_bytes(&self) -> usize {
        match self {
            QuantResult::Scalar(q) => q.storage_bytes(),
            QuantResult::Dense { storage_bytes, .. } => *storage_bytes,
        }
    }

    pub fn as_scalar(&self) -> Option<&QuantizedTensor> {
        match self {
            QuantResult::Scalar(q) => Some(q),
            _ => None,
        }
    }
}

/// Calibration context handed to quantizers that are activation-aware.
#[derive(Clone, Debug, Default)]
pub struct CalibCtx {
    /// `E[x_i^2]` per input dim (diagonal Hessian proxy), length `d_in`.
    pub x_sq_mean: Option<Vec<f32>>,
    /// Raw calibration activations `[n_samples, d_in]` (GPTQ Hessian).
    pub x_samples: Option<Mat>,
    /// Seed for stochastic quantizers (rotations, k-means init).
    pub seed: u64,
}

impl CalibCtx {
    pub fn with_seed(seed: u64) -> CalibCtx {
        CalibCtx { seed, ..Default::default() }
    }

    /// Diagonal Hessian proxy, defaulting to all-ones when no calibration
    /// data is attached.
    pub fn diag_h(&self, d_in: usize) -> Vec<f32> {
        if let Some(d) = &self.x_sq_mean {
            assert_eq!(d.len(), d_in);
            return d.clone();
        }
        if let Some(x) = &self.x_samples {
            assert_eq!(x.cols(), d_in);
            let n = x.rows().max(1) as f32;
            let mut d = vec![0.0f32; d_in];
            for r in 0..x.rows() {
                let row = x.row(r);
                for (j, &v) in row.iter().enumerate() {
                    d[j] += v * v / n;
                }
            }
            return d;
        }
        vec![1.0; d_in]
    }
}

/// The quantizer interface every method implements.
pub trait Quantizer: Send + Sync {
    fn name(&self) -> &'static str;
    fn bits(&self) -> u8;
    fn quantize(&self, w: &Mat, ctx: &CalibCtx) -> QuantResult;

    /// Quantization error `‖W − Q‖_F` (Fig. 3(b) metric).
    fn weight_discrepancy(&self, w: &Mat, ctx: &CalibCtx) -> f32 {
        self.quantize(w, ctx).dequant().fro_dist(w)
    }
}

/// Registry used by the CLI / experiment runner.
pub fn by_name(name: &str, bits: u8, group_size: usize) -> Option<Box<dyn Quantizer>> {
    match name {
        "rtn" => Some(Box::new(Rtn::new(bits, group_size))),
        "nf" | "normalfloat" | "loftq-base" => {
            Some(Box::new(NormalFloat::new(bits, group_size)))
        }
        "omniquant" => Some(Box::new(OmniQuant::new(bits, group_size))),
        "gptq" => Some(Box::new(Gptq::new(bits, group_size))),
        "quarot" => Some(Box::new(QuaRot::new(bits, group_size))),
        "quip" | "vq" => Some(Box::new(VectorQuant::new(bits))),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn registry_resolves_all() {
        for name in ["rtn", "nf", "omniquant", "gptq", "quarot", "vq"] {
            assert!(by_name(name, 2, 32).is_some(), "{name}");
        }
        assert!(by_name("nope", 2, 32).is_none());
    }

    #[test]
    fn storage_bytes_scale_with_bits() {
        let mut rng = Rng::seed(5);
        let w = Mat::randn(64, 32, &mut rng);
        let q2 = Rtn::new(2, 32).quantize(&w, &CalibCtx::default());
        let q4 = Rtn::new(4, 32).quantize(&w, &CalibCtx::default());
        assert!(q4.storage_bytes() > q2.storage_bytes());
        // packed codes dominate: 2-bit ≈ d_in*d_out/4 bytes
        assert!(q2.storage_bytes() >= 64 * 32 / 4);
    }
}
