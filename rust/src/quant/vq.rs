//! QuIP#-style codebook vector quantizer.
//!
//! QuIP# combines (i) Hadamard incoherence processing and (ii) non-uniform
//! *vector* quantization against an E8-lattice codebook. The simulation
//! keeps both mechanisms with simulated parts documented in DESIGN.md:
//! incoherence uses the same randomized block-Hadamard as [`super::quarot`],
//! and the lattice codebook is replaced by a k-means codebook over `VDIM`-d
//! weight vectors learned per matrix (the lattice is itself a fixed
//! near-optimal codebook for Gaussianized weights; k-means converges to the
//! same rate-distortion regime at these dimensions).
//!
//! Bit accounting: `VDIM * bits` bits index `2^(VDIM*bits)` centroids, i.e.
//! an effective `bits` bits/weight plus per-group scale metadata — the same
//! budget as the scalar quantizers.

use super::quarot::randomized_hadamard;
use super::{CalibCtx, QuantResult, Quantizer};
use crate::tensor::{Mat, Rng};

/// Vector length of each codeword (QuIP# uses 8-d E8; 4-d keeps the
/// codebook k-means tractable at 2 bits/weight: 2^(4*2) = 256 centroids).
pub const VDIM: usize = 4;

#[derive(Clone, Debug)]
pub struct VectorQuant {
    pub bits: u8,
    pub kmeans_iters: usize,
}

impl VectorQuant {
    pub fn new(bits: u8) -> VectorQuant {
        assert!((2..=3).contains(&bits), "VQ supports 2-3 bits/weight");
        VectorQuant { bits, kmeans_iters: 12 }
    }

    fn n_centroids(&self) -> usize {
        1usize << (VDIM * self.bits as usize)
    }
}

/// Plain Lloyd k-means over rows of `data` (`[n, VDIM]`), k-means++-ish
/// seeding from the RNG.
fn kmeans(data: &Mat, k: usize, iters: usize, rng: &mut Rng) -> Mat {
    let n = data.rows();
    let d = data.cols();
    let mut centroids = Mat::zeros(k, d);
    // seed: random distinct-ish rows
    for c in 0..k {
        let row = data.row(rng.below(n));
        centroids.row_mut(c).copy_from_slice(row);
    }
    let mut assign = vec![0usize; n];
    for _ in 0..iters {
        // assignment
        for i in 0..n {
            let row = data.row(i);
            let mut best = f32::INFINITY;
            for c in 0..k {
                let crow = centroids.row(c);
                let mut dist = 0.0;
                for t in 0..d {
                    let dd = row[t] - crow[t];
                    dist += dd * dd;
                }
                if dist < best {
                    best = dist;
                    assign[i] = c;
                }
            }
        }
        // update
        let mut sums = Mat::zeros(k, d);
        let mut counts = vec![0usize; k];
        for i in 0..n {
            let c = assign[i];
            counts[c] += 1;
            let row = data.row(i);
            let srow = sums.row_mut(c);
            for t in 0..d {
                srow[t] += row[t];
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // re-seed empty centroid
                let row = data.row(rng.below(n));
                centroids.row_mut(c).copy_from_slice(row);
            } else {
                let inv = 1.0 / counts[c] as f32;
                let srow = sums.row(c);
                let crow = centroids.row_mut(c);
                for t in 0..d {
                    crow[t] = srow[t] * inv;
                }
            }
        }
    }
    centroids
}

impl Quantizer for VectorQuant {
    fn name(&self) -> &'static str {
        "quip"
    }

    fn bits(&self) -> u8 {
        self.bits
    }

    fn quantize(&self, w: &Mat, ctx: &CalibCtx) -> QuantResult {
        let (d_in, d_out) = w.shape();
        assert!(d_in % VDIM == 0, "d_in must be divisible by VDIM={VDIM}");
        let mut rng = Rng::seed(ctx.seed ^ 0x51e2_c4b7_88aa_1013);

        // incoherence processing
        let r_in = randomized_hadamard(d_in, &mut rng);
        let r_out = randomized_hadamard(d_out, &mut rng);
        let w_rot = r_in.t().matmul(w).matmul(&r_out);

        // per-column normalization (QuIP# uses a global scale; per-column
        // keeps parity with the group metadata of the scalar quantizers)
        let mut col_scale = vec![0.0f32; d_out];
        for j in 0..d_out {
            let mut ss = 0.0f32;
            for i in 0..d_in {
                ss += w_rot[(i, j)] * w_rot[(i, j)];
            }
            col_scale[j] = (ss / d_in as f32).sqrt().max(1e-9);
        }

        // gather normalized VDIM-vectors along d_in
        let n_vecs = (d_in / VDIM) * d_out;
        let mut vecs = Mat::zeros(n_vecs, VDIM);
        let mut idx = 0;
        for j in 0..d_out {
            for vi in 0..d_in / VDIM {
                let vrow = vecs.row_mut(idx);
                for t in 0..VDIM {
                    vrow[t] = w_rot[(vi * VDIM + t, j)] / col_scale[j];
                }
                idx += 1;
            }
        }

        // learn codebook, encode
        let k = self.n_centroids();
        let centroids = kmeans(&vecs, k, self.kmeans_iters, &mut rng);
        let mut q_rot = Mat::zeros(d_in, d_out);
        let mut idx = 0;
        for j in 0..d_out {
            for vi in 0..d_in / VDIM {
                let row = vecs.row(idx);
                let mut best = f32::INFINITY;
                let mut bc = 0usize;
                for c in 0..k {
                    let crow = centroids.row(c);
                    let mut dist = 0.0;
                    for t in 0..VDIM {
                        let dd = row[t] - crow[t];
                        dist += dd * dd;
                    }
                    if dist < best {
                        best = dist;
                        bc = c;
                    }
                }
                let crow = centroids.row(bc);
                for t in 0..VDIM {
                    q_rot[(vi * VDIM + t, j)] = crow[t] * col_scale[j];
                }
                idx += 1;
            }
        }

        // fold rotations back
        let q_eff = r_in.matmul(&q_rot).matmul(&r_out.t());
        let storage = d_in * d_out * self.bits as usize / 8 // code indices
            + k * VDIM * 4                                  // codebook
            + d_out * 4;                                    // column scales
        QuantResult::Dense { w: q_eff, bits: self.bits, storage_bytes: storage }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{NormalFloat, Quantizer, Rtn};

    #[test]
    fn kmeans_recovers_clusters() {
        let mut rng = Rng::seed(81);
        // two well-separated clusters in 4-d
        let mut data = Mat::zeros(100, 4);
        for i in 0..100 {
            let base = if i % 2 == 0 { 5.0 } else { -5.0 };
            let row = data.row_mut(i);
            for t in 0..4 {
                row[t] = base + 0.1 * rng.next_gaussian();
            }
        }
        let c = kmeans(&data, 2, 10, &mut rng);
        let m0 = c.row(0)[0];
        let m1 = c.row(1)[0];
        assert!((m0 - 5.0).abs() < 0.5 && (m1 + 5.0).abs() < 0.5
            || (m0 + 5.0).abs() < 0.5 && (m1 - 5.0).abs() < 0.5,
            "centroids {m0} {m1}");
    }

    /// QuIP#'s claim: at 2 bits, vector quantization beats scalar methods.
    #[test]
    fn vq_beats_scalar_at_2bit() {
        let mut rng = Rng::seed(82);
        let w = Mat::randn(64, 48, &mut rng);
        let ctx = CalibCtx::with_seed(3);
        let e_vq = VectorQuant::new(2).quantize(&w, &ctx).dequant().fro_dist(&w);
        let e_rtn = Rtn::new(2, 32).quantize(&w, &ctx).dequant().fro_dist(&w);
        let e_nf = NormalFloat::new(2, 32).quantize(&w, &ctx).dequant().fro_dist(&w);
        assert!(e_vq < e_rtn, "vq={e_vq} rtn={e_rtn}");
        assert!(e_vq < e_nf, "vq={e_vq} nf={e_nf}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::seed(83);
        let w = Mat::randn(32, 16, &mut rng);
        let ctx = CalibCtx::with_seed(5);
        let a = VectorQuant::new(2).quantize(&w, &ctx).dequant();
        let b = VectorQuant::new(2).quantize(&w, &ctx).dequant();
        assert!(a.fro_dist(&b) < 1e-6);
    }
}
