//! OmniQuant-style quantizer: uniform grid with *learnable weight clipping*
//! (the γ/β of Eq. 1). The original learns clip strengths by SGD on a
//! block-wise reconstruction loss; at simulation scale an exhaustive
//! coordinate search over a (γ, β) grid against an activation-weighted
//! reconstruction objective reaches the same optimum class (the search
//! space per (group, column) is tiny and the objective is piecewise
//! smooth). The activation weighting uses the diagonal Hessian proxy
//! `E[x_i²]` from the calibration context — the same signal OmniQuant's
//! block loss provides.

use super::rtn::quantize_uniform;
use super::{CalibCtx, QuantResult, QuantizedTensor, Quantizer};
use crate::tensor::Mat;

#[derive(Clone, Debug)]
pub struct OmniQuant {
    pub bits: u8,
    pub group_size: usize,
    /// candidate clip strengths searched for both γ and β
    pub grid: Vec<f32>,
}

impl OmniQuant {
    pub fn new(bits: u8, group_size: usize) -> OmniQuant {
        OmniQuant {
            bits,
            group_size,
            grid: vec![0.70, 0.75, 0.80, 0.85, 0.90, 0.95, 1.00],
        }
    }
}

impl Quantizer for OmniQuant {
    fn name(&self) -> &'static str {
        "omniquant"
    }

    fn bits(&self) -> u8 {
        self.bits
    }

    fn quantize(&self, w: &Mat, ctx: &CalibCtx) -> QuantResult {
        let (d_in, d_out) = w.shape();
        // ragged final group when d_in is not a multiple of group_size
        let n_groups = d_in.div_ceil(self.group_size);
        let diag_h = ctx.diag_h(d_in);
        let levels = ((1u32 << self.bits) - 1) as f32;

        // Per-(group, column) best clip pair.
        let mut best_gamma = Mat::full(n_groups, d_out, 1.0);
        let mut best_beta = Mat::full(n_groups, d_out, 1.0);

        for g in 0..n_groups {
            let r0 = g * self.group_size;
            let r1 = (r0 + self.group_size).min(d_in);
            for j in 0..d_out {
                let mut wmin = f32::INFINITY;
                let mut wmax = f32::NEG_INFINITY;
                for i in r0..r1 {
                    let v = w[(i, j)];
                    wmin = wmin.min(v);
                    wmax = wmax.max(v);
                }
                let mut best = f32::INFINITY;
                for &gam in &self.grid {
                    for &bet in &self.grid {
                        let hi = gam * wmax;
                        let lo = bet * wmin;
                        let s = ((hi - lo) / levels).max(1e-9);
                        // weighted reconstruction error of this clip pair
                        let mut err = 0.0f32;
                        for i in r0..r1 {
                            let v = w[(i, j)];
                            let c = ((v - lo) / s).round().clamp(0.0, levels);
                            let d = v - (lo + c * s);
                            err += diag_h[i] * d * d;
                        }
                        if err < best {
                            best = err;
                            best_gamma[(g, j)] = gam;
                            best_beta[(g, j)] = bet;
                        }
                    }
                }
            }
        }

        let gb = |g: usize, j: usize| (best_gamma[(g, j)], best_beta[(g, j)]);
        let q: QuantizedTensor = quantize_uniform(w, self.bits, self.group_size, Some(&gb));
        QuantResult::Scalar(q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Rtn;
    use crate::tensor::Rng;

    /// OmniQuant's whole point: with outliers present, learned clipping
    /// beats γ=β=1 RTN.
    #[test]
    fn clipping_beats_rtn_with_outliers() {
        let mut rng = Rng::seed(51);
        let mut w = Mat::randn(128, 32, &mut rng);
        // inject sparse outliers (3% of entries, 8x scale)
        for _ in 0..(128 * 32) / 32 {
            let i = rng.below(128);
            let j = rng.below(32);
            w[(i, j)] *= 8.0;
        }
        let ctx = CalibCtx::default();
        let e_omni = OmniQuant::new(2, 64).quantize(&w, &ctx).dequant().fro_dist(&w);
        let e_rtn = Rtn::new(2, 64).quantize(&w, &ctx).dequant().fro_dist(&w);
        assert!(e_omni < e_rtn, "omni={e_omni} rtn={e_rtn}");
    }

    #[test]
    fn activation_weighting_prefers_hot_dims() {
        // With a hot input dim, the weighted objective should sacrifice
        // accuracy on cold dims: weighted error must be <= the error of the
        // unweighted search evaluated under the same weighting.
        let mut rng = Rng::seed(52);
        let mut w = Mat::randn(64, 8, &mut rng);
        for j in 0..8 {
            w[(0, j)] *= 6.0; // outlier in the hot dim
        }
        let mut hot = vec![1.0f32; 64];
        hot[0] = 100.0;
        let ctx_hot = CalibCtx { x_sq_mean: Some(hot.clone()), ..Default::default() };
        let ctx_flat = CalibCtx::default();
        let q_hot = OmniQuant::new(2, 64).quantize(&w, &ctx_hot).dequant();
        let q_flat = OmniQuant::new(2, 64).quantize(&w, &ctx_flat).dequant();
        let weighted = |q: &Mat| -> f32 {
            let mut e = 0.0;
            for i in 0..64 {
                for j in 0..8 {
                    let d = q[(i, j)] - w[(i, j)];
                    e += hot[i] * d * d;
                }
            }
            e
        };
        assert!(weighted(&q_hot) <= weighted(&q_flat) + 1e-4);
    }

    #[test]
    fn produces_scalar_form() {
        let mut rng = Rng::seed(53);
        let w = Mat::randn(64, 8, &mut rng);
        let q = OmniQuant::new(2, 32).quantize(&w, &CalibCtx::default());
        assert!(q.as_scalar().is_some());
    }
}
