//! Minimal CLI argument parser (the offline crate set has no `clap`).
//!
//! Grammar: `rilq <subcommand> [positional...] [--flag[=value]]`.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::model::backend::BackendKind;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn parse(argv: impl Iterator<Item = String>) -> Args {
        let mut out = Args::default();
        for (i, arg) in argv.enumerate() {
            if let Some(flag) = arg.strip_prefix("--") {
                match flag.split_once('=') {
                    Some((k, v)) => {
                        out.flags.insert(k.to_string(), v.to_string());
                    }
                    None => {
                        out.flags.insert(flag.to_string(), "true".to_string());
                    }
                }
            } else if i == 0 && out.subcommand.is_empty() {
                out.subcommand = arg;
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).map(|v| v != "false").unwrap_or(false)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn opt_usize(&self, name: &str) -> Result<Option<usize>> {
        self.flags
            .get(name)
            .map(|v| v.parse::<usize>().map_err(|_| anyhow!("--{name} must be an integer")))
            .transpose()
    }

    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(String::as_str)
    }

    /// `--backend {dense,packed,merged}` — the execution engine for
    /// quantized linears (defaults to `dense`, the historical behavior).
    pub fn backend(&self) -> Result<BackendKind> {
        match self.opt("backend") {
            Some(s) => BackendKind::parse(s),
            None => Ok(BackendKind::Dense),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = parse("experiment table1 --fast --steps=20");
        assert_eq!(a.subcommand, "experiment");
        assert_eq!(a.pos(0), Some("table1"));
        assert!(a.flag("fast"));
        assert_eq!(a.opt_usize("steps").unwrap(), Some(20));
    }

    #[test]
    fn empty_ok() {
        let a = parse("");
        assert_eq!(a.subcommand, "");
        assert!(!a.flag("fast"));
    }

    #[test]
    fn backend_flag() {
        use crate::model::backend::BackendKind;
        assert_eq!(parse("eval").backend().unwrap(), BackendKind::Dense);
        assert_eq!(parse("eval --backend=packed").backend().unwrap(), BackendKind::Packed);
        assert_eq!(parse("eval --backend=merged").backend().unwrap(), BackendKind::Merged);
        assert!(parse("eval --backend=gpu").backend().is_err());
    }
}
