//! # RILQ — Rank-Insensitive LoRA-based Quantization Error Compensation
//!
//! Full-system reproduction of "RILQ: Rank-Insensitive LoRA-Based Quantization
//! Error Compensation for Boosting 2-Bit Large Language Model Accuracy"
//! (AAAI 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the calibration/evaluation coordinator:
//!   experiment scheduling, streaming calibration batcher with backpressure,
//!   early stopping, adapter state management, metrics, and report emission.
//! * **Layer 2 (python/compile/model.py)** — a LLaMA-style transformer in JAX
//!   (fp teacher + quantized student with LoRA adapters) plus the five
//!   discrepancy-loss scopes (Linear/Layer/Model/GT/Model+GT = RILQ),
//!   AOT-lowered to HLO text artifacts.
//! * **Layer 1 (python/compile/kernels/)** — a Pallas kernel fusing int-code
//!   dequantization, matmul, and the low-rank LoRA correction.
//!
//! Python never runs on the request path: `make artifacts` lowers every model
//! variant once; this crate loads the HLO via PJRT (`xla` crate) and drives
//! calibration/eval loops natively.

pub mod tensor;
pub mod quant;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod lqec;
pub mod model;
pub mod report;
pub mod runtime;

pub use tensor::{Mat, Rng};
