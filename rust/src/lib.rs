//! # RILQ — Rank-Insensitive LoRA-based Quantization Error Compensation
//!
//! Full-system reproduction of "RILQ: Rank-Insensitive LoRA-Based Quantization
//! Error Compensation for Boosting 2-Bit Large Language Model Accuracy"
//! (AAAI 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the calibration/evaluation coordinator:
//!   experiment scheduling, streaming calibration batcher with backpressure,
//!   early stopping, adapter state management, metrics, and report emission.
//! * **Layer 2 (python/compile/model.py)** — a LLaMA-style transformer in JAX
//!   (fp teacher + quantized student with LoRA adapters) plus the five
//!   discrepancy-loss scopes (Linear/Layer/Model/GT/Model+GT = RILQ),
//!   AOT-lowered to HLO text artifacts.
//! * **Layer 1 (python/compile/kernels/)** — a Pallas kernel fusing int-code
//!   dequantization, matmul, and the low-rank LoRA correction.
//!
//! Python never runs on the request path: `make artifacts` lowers every model
//! variant once; this crate loads the HLO via PJRT (`xla` crate) and drives
//! calibration/eval loops natively.
//!
//! ## Execution backends (the serving architecture)
//!
//! Quantized linears execute through the [`model::backend::LinearBackend`]
//! trait — the seam every scaling direction (batching, sharding,
//! multi-backend PJRT) plugs into. Three engines implement it:
//!
//! ```text
//!                    ┌──────────────────────────────────────────────┐
//!   teacher fp  ───▶ │ Mat (plain dense matmul, threaded when big)  │
//!                    ├──────────────────────────────────────────────┤
//!   --backend dense  │ DenseLinear:   y = x·deq(Q) + (x·A)·Bᵀ       │
//!     (default)      │   f32 dequant held resident; LoRA unmerged   │
//!                    │   (HLO student artifact used when lowered)   │
//!                    ├──────────────────────────────────────────────┤
//!   --backend packed │ PackedLoraLinear:                            │
//!     (serving form) │   y = Σ_g [ s_g·Σ_{i∈g} x_i·cb[code_ij]      │
//!                    │          + z_g·Σ_{i∈g} x_i ]  + (x·A)·Bᵀ     │
//!                    │   2/3/4-bit codes dequantized inside the     │
//!                    │   blocked matmul loop; resident weights are  │
//!                    │   the packed footprint (<1/4 of f32 at 2-bit)│
//!                    ├──────────────────────────────────────────────┤
//!   --backend merged │ MergedDenseLinear: W = Q + A·Bᵀ materialized │
//!     (oracle)       │   once — the parity/testing reference        │
//!                    └──────────────────────────────────────────────┘
//! ```
//!
//! Selection is threaded end-to-end: CLI `--backend` →
//! [`experiments::pipeline::Lab::backend`] →
//! [`coordinator::driver::Driver::student_scorer`] (the single dispatch
//! point, which also prefers the HLO artifact for `dense` when lowered) →
//! [`eval::BackendScorer`] → `TeacherParams::view_backends` → the shared
//! [`model::forward::forward_trace`]. `packed` mirrors the
//! `python/compile/kernels/lora_qmm.py` Pallas kernel natively; parity
//! tests (`tests/backend_parity.rs`) pin all three engines to each other
//! and to the dequant oracle. Rotation/VQ quantizers (QuaRot, QuIP#)
//! carry no scalar codes and therefore only run `dense`/`merged`.
//!
//! ## Serving (continuous batching)
//!
//! On top of the engines sits the native serving stack — ragged requests
//! in, coalesced forwards out, no PAD-dummy filler anywhere:
//!
//! ```text
//!   clients ──submit──▶ bounded queue (backpressure, sync_channel)
//!                            │  coordinator::serve::Server
//!                            ▼
//!                greedy coalesce ≤ max_batch ragged requests
//!                            │
//!                            ▼
//!        eval::Scorer::score_batch (BackendScorer: one
//!        model::forward::forward_trace_batch over [Σ lenᵢ, d] —
//!        every LinearBackend::forward runs once per layer for the
//!        whole batch; packed group tiles decode once per row-chunk)
//!                            │
//!                            ▼
//!        per-request logp answers + coordinator::Metrics
//!        (serve.requests / batches / tokens / latency / forward)
//! ```
//!
//! The matmul/packed kernels fan out on a **persistent worker pool**
//! ([`tensor::pool`], dispatch ≈ a condvar wakeup instead of a per-call
//! thread spawn), so small serving-size matmuls scale too. `rilq
//! serve-bench` measures batched-vs-per-sequence throughput natively
//! (PJRT-free); `tests/serve_loop.rs` pins the loop's semantics and
//! `tests/backend_parity.rs` pins batched == per-sequence logits.
//!
//! ## KV cache: incremental decode + prefix reuse
//!
//! Attention used to recompute the whole O(S²) causal triangle per
//! request. [`model::kv::KvCache`] stores each layer's rotated-K / V rows
//! per sequence so the forward only ever pushes *new* rows through the
//! linears ([`model::forward::forward_trace_with_cache`] /
//! [`model::forward::forward_step`]; RoPE angles come from one shared
//! [`model::kv::RopeTable`] instead of per-element `powf` + `sin_cos`):
//!
//! ```text
//!   prefill (once)                   decode (per token)
//!   tokens[0..P] ──▶ forward ──┐     last tok ──▶ forward (1 row/linear)
//!                              ▼                      │
//!              KvCache: per layer, rotated K + V      │ argmax / logp
//!              [n_heads, seq, head_dim] planes   ◀────┘ appended
//!                              │
//!   score_choices: truncate(P) ├──▶ choice A suffix  (cache reuse:
//!   between choices — prompt   ├──▶ choice B suffix   prompt forwarded
//!   prefilled exactly once     └──▶ ...               once per item)
//! ```
//!
//! The serve loop schedules decode traffic too ([`ServeClient::generate`]
//! → greedy generation): freshly admitted prompts prefill as one
//! coalesced batch, then all active sequences advance **one token per
//! iteration in lockstep round-robin** — each step is a single
//! `[n_active, d_model]` forward, so the packed group-tile dequant keeps
//! amortizing. At most `ServeConfig::max_active` KV caches are resident;
//! while the slots are full the loop stops draining the bounded queue, so
//! backpressure reaches submitters (cache-capacity accounting). Latency
//! p50/p95, queue-depth, and KV-residency gauges land in
//! [`coordinator::Metrics`]; `rilq serve-bench` and `cargo bench --bench
//! bench_runtime` report prefill-vs-incremental tok/s, and
//! `tests/kv_cache.rs` pins incremental == full-forward logits.
//!
//! [`ServeClient::generate`]: coordinator::serve::ServeClient::generate
//! [`ServeConfig::max_active`]: coordinator::serve::ServeConfig::max_active

// Clippy style-lint allowances for the numeric kernels live in
// Cargo.toml's `[lints.clippy]` table so they cover tests/benches too.

pub mod tensor;
pub mod quant;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod lqec;
pub mod model;
pub mod report;
pub mod runtime;

pub use tensor::{Mat, Rng};
