//! # RILQ — Rank-Insensitive LoRA-based Quantization Error Compensation
//!
//! Full-system reproduction of "RILQ: Rank-Insensitive LoRA-Based Quantization
//! Error Compensation for Boosting 2-Bit Large Language Model Accuracy"
//! (AAAI 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the calibration/evaluation coordinator:
//!   experiment scheduling, streaming calibration batcher with backpressure,
//!   early stopping, adapter state management, metrics, and report emission.
//! * **Layer 2 (python/compile/model.py)** — a LLaMA-style transformer in JAX
//!   (fp teacher + quantized student with LoRA adapters) plus the five
//!   discrepancy-loss scopes (Linear/Layer/Model/GT/Model+GT = RILQ),
//!   AOT-lowered to HLO text artifacts.
//! * **Layer 1 (python/compile/kernels/)** — a Pallas kernel fusing int-code
//!   dequantization, matmul, and the low-rank LoRA correction.
//!
//! Python never runs on the request path: `make artifacts` lowers every model
//! variant once; this crate loads the HLO via PJRT (`xla` crate) and drives
//! calibration/eval loops natively.
//!
//! ## Execution backends (the serving architecture)
//!
//! Quantized linears execute through the [`model::backend::LinearBackend`]
//! trait — the seam every scaling direction (batching, sharding,
//! multi-backend PJRT) plugs into. Three engines implement it:
//!
//! ```text
//!                    ┌──────────────────────────────────────────────┐
//!   teacher fp  ───▶ │ Mat (plain dense matmul, threaded when big)  │
//!                    ├──────────────────────────────────────────────┤
//!   --backend dense  │ DenseLinear:   y = x·deq(Q) + (x·A)·Bᵀ       │
//!     (default)      │   f32 dequant held resident; LoRA unmerged   │
//!                    │   (HLO student artifact used when lowered)   │
//!                    ├──────────────────────────────────────────────┤
//!   --backend packed │ PackedLoraLinear:                            │
//!     (serving form) │   y = Σ_g [ s_g·Σ_{i∈g} x_i·cb[code_ij]      │
//!                    │          + z_g·Σ_{i∈g} x_i ]  + (x·A)·Bᵀ     │
//!                    │   2/3/4-bit codes dequantized inside the     │
//!                    │   blocked matmul loop; resident weights are  │
//!                    │   the packed footprint (<1/4 of f32 at 2-bit)│
//!                    ├──────────────────────────────────────────────┤
//!   --backend merged │ MergedDenseLinear: W = Q + A·Bᵀ materialized │
//!     (oracle)       │   once — the parity/testing reference        │
//!                    └──────────────────────────────────────────────┘
//! ```
//!
//! Selection is threaded end-to-end: CLI `--backend` →
//! [`experiments::pipeline::Lab::backend`] →
//! [`coordinator::driver::Driver::student_scorer`] (the single dispatch
//! point, which also prefers the HLO artifact for `dense` when lowered) →
//! [`eval::BackendScorer`] → `TeacherParams::view_backends` → the shared
//! [`model::forward::forward_trace`]. `packed` mirrors the
//! `python/compile/kernels/lora_qmm.py` Pallas kernel natively; parity
//! tests (`tests/backend_parity.rs`) pin all three engines to each other
//! and to the dequant oracle. Rotation/VQ quantizers (QuaRot, QuIP#)
//! carry no scalar codes and therefore only run `dense`/`merged`.
//!
//! ## Serving (continuous batching)
//!
//! On top of the engines sits the native serving stack — ragged requests
//! in, coalesced forwards out, no PAD-dummy filler anywhere:
//!
//! ```text
//!   clients ──submit──▶ bounded queue (backpressure, sync_channel)
//!                            │  coordinator::serve::Server
//!                            ▼
//!                greedy coalesce ≤ max_batch ragged requests
//!                            │
//!                            ▼
//!        eval::Scorer::score_batch (BackendScorer: one
//!        model::forward::forward_trace_batch over [Σ lenᵢ, d] —
//!        every LinearBackend::forward runs once per layer for the
//!        whole batch; packed group tiles decode once per row-chunk)
//!                            │
//!                            ▼
//!        per-request logp answers + coordinator::Metrics
//!        (serve.requests / batches / tokens / latency / forward)
//! ```
//!
//! The matmul/packed kernels fan out on a **persistent worker pool**
//! ([`tensor::pool`], dispatch ≈ a condvar wakeup instead of a per-call
//! thread spawn), so small serving-size matmuls scale too. `rilq
//! serve-bench` measures batched-vs-per-sequence throughput natively
//! (PJRT-free); `tests/serve_loop.rs` pins the loop's semantics and
//! `tests/backend_parity.rs` pins batched == per-sequence logits.

pub mod tensor;
pub mod quant;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod lqec;
pub mod model;
pub mod report;
pub mod runtime;

pub use tensor::{Mat, Rng};
