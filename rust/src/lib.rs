//! # RILQ — Rank-Insensitive LoRA-based Quantization Error Compensation
//!
//! Full-system reproduction of "RILQ: Rank-Insensitive LoRA-Based Quantization
//! Error Compensation for Boosting 2-Bit Large Language Model Accuracy"
//! (AAAI 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the calibration/evaluation coordinator:
//!   experiment scheduling, streaming calibration batcher with backpressure,
//!   early stopping, adapter state management, metrics, and report emission.
//! * **Layer 2 (python/compile/model.py)** — a LLaMA-style transformer in JAX
//!   (fp teacher + quantized student with LoRA adapters) plus the five
//!   discrepancy-loss scopes (Linear/Layer/Model/GT/Model+GT = RILQ),
//!   AOT-lowered to HLO text artifacts.
//! * **Layer 1 (python/compile/kernels/)** — a Pallas kernel fusing int-code
//!   dequantization, matmul, and the low-rank LoRA correction.
//!
//! Python never runs on the request path: `make artifacts` lowers every model
//! variant once; this crate loads the HLO via PJRT (`xla` crate) and drives
//! calibration/eval loops natively.
//!
//! ## Execution backends (the serving architecture)
//!
//! Quantized linears execute through the [`model::backend::LinearBackend`]
//! trait — the seam every scaling direction (batching, sharding,
//! multi-backend PJRT) plugs into. Three engines implement it:
//!
//! ```text
//!                    ┌──────────────────────────────────────────────┐
//!   teacher fp  ───▶ │ Mat (plain dense matmul, threaded when big)  │
//!                    ├──────────────────────────────────────────────┤
//!   --backend dense  │ DenseLinear:   y = x·deq(Q) + (x·A)·Bᵀ       │
//!     (default)      │   f32 dequant held resident; LoRA unmerged   │
//!                    │   (HLO student artifact used when lowered)   │
//!                    ├──────────────────────────────────────────────┤
//!   --backend packed │ PackedLoraLinear:                            │
//!     (serving form) │   y = Σ_g [ s_g·Σ_{i∈g} x_i·cb[code_ij]      │
//!                    │          + z_g·Σ_{i∈g} x_i ]  + (x·A)·Bᵀ     │
//!                    │   2/3/4-bit codes dequantized inside the     │
//!                    │   blocked matmul loop; resident weights are  │
//!                    │   the packed footprint (<1/4 of f32 at 2-bit)│
//!                    ├──────────────────────────────────────────────┤
//!   --backend merged │ MergedDenseLinear: W = Q + A·Bᵀ materialized │
//!     (oracle)       │   once — the parity/testing reference        │
//!                    └──────────────────────────────────────────────┘
//! ```
//!
//! Selection is threaded end-to-end: CLI `--backend` →
//! [`experiments::pipeline::Lab::backend`] →
//! [`coordinator::driver::Driver::student_scorer`] (the single dispatch
//! point, which also prefers the HLO artifact for `dense` when lowered) →
//! [`eval::BackendScorer`] → `TeacherParams::view_backends` → the shared
//! [`model::forward::forward_trace`]. `packed` mirrors the
//! `python/compile/kernels/lora_qmm.py` Pallas kernel natively; parity
//! tests (`tests/backend_parity.rs`) pin all three engines to each other
//! and to the dequant oracle. Rotation/VQ quantizers (QuaRot, QuIP#)
//! carry no scalar codes and therefore only run `dense`/`merged`.
//!
//! ## Serving: the request-lifecycle engine
//!
//! On top of the execution backends sits [`engine::Engine`] — the typed
//! serving surface every workload programs against. Requests are an
//! explicit lifecycle (no PAD-dummy filler anywhere):
//!
//! ```text
//!   EngineClient::submit(Request)          Engine loop (per replica,
//!     Score    { tokens }                   placed by engine::Dispatch)
//!     Choices  { prompt, choices }         ───────────────────────────
//!     Generate { prompt, SamplingParams }   1 intake: validate, split
//!        │  bounded queue (backpressure)      ├▶ score/choices queue
//!        └────────────────────────────────▶   └▶ gen waiting queue
//!                                            2 promote gens while KV
//!   answers flow back:                         slots free (≤ max_active
//!     Pending<Response>::wait /                resident KvCaches)
//!       wait_timeout(dur)                    3 score: ONE coalesced
//!     TokenStream (per-token events            score_batch ≤ max_batch
//!       while Generate runs)                 4 step: ONE fused forward —
//!                                              decode seqs feed their
//!   capabilities consulted once via            last token, prefilling
//!   eval::Scorer::caps() → EngineCaps          seqs their next
//!   (fixed_geometry / incremental /            prefill_chunk tokens
//!    prefix_reuse) — no boolean probing      5 repeat: new traffic is
//!                                              admitted BETWEEN steps
//! ```
//!
//! Two properties fall out of the round structure: score traffic queued
//! behind long generations is served between decode iterations (no
//! head-of-line blocking when every decode slot is busy), and long
//! prompts prefill in `prefill_chunk` slices so one request can't
//! monopolize an iteration. Greedy generation (`SamplingParams::greedy`)
//! is bitwise-identical to [`eval::greedy_decode`]; temperature /
//! top-k / top-p sampling is seeded and reproducible
//! ([`engine::sampling`]). Scoring forwards coalesce exactly as before:
//! one [`model::forward::forward_trace_batch`] over `[Σ lenᵢ, d]`, so
//! the packed group-tile dequant amortizes across the batch. The
//! pre-engine `coordinator::serve::ServeClient` verbs survive as
//! deprecated shims.
//!
//! The matmul/packed kernels fan out on a **persistent worker pool**
//! ([`tensor::pool`], dispatch ≈ a condvar wakeup instead of a per-call
//! thread spawn), so small serving-size matmuls scale too. `rilq
//! serve-bench` measures batched-vs-per-sequence throughput natively
//! (PJRT-free); `tests/serve_loop.rs` pins the loop's semantics and
//! `tests/backend_parity.rs` pins batched == per-sequence logits.
//!
//! Since PR 8 the lifecycle is **fault-tolerant**. Every submission can
//! carry a deadline ([`engine::SubmitOptions`], or
//! `EngineConfig::default_deadline` fleet-wide): expired queued work is
//! shed with `Err` before any forward, and an expired generation is
//! aborted at the next step boundary with its arena blocks freed. A
//! [`engine::Pending`] can be cancelled explicitly (`Pending::cancel`)
//! or just dropped — both abort the request at the next boundary, so an
//! abandoned client never leaks KV residency. Each replica loop runs
//! **supervised**: scorer calls are wrapped in `catch_unwind`, a panic
//! (or `EngineConfig::unhealthy_after` consecutive `Err`s) marks the
//! replica unhealthy in the shared [`engine::HealthView`] — sticky, no
//! self-healing — and [`engine::Dispatch`] hints are validated against
//! it, re-routing instead of %-clamping into a dead slot. Idempotent
//! Score/Choices work retries with bounded exponential backoff
//! (`EngineConfig::max_retries`) onto healthy replicas; an in-flight
//! generation **fails over** through the PR 6 replay path, so the
//! resumed output is bitwise-identical to a run that never crashed
//! (identical weights across replicas assumed). The deterministic
//! fault-injection harness [`engine::ChaosScorer`] drives
//! `tests/chaos_serving.rs`, which pins the three serving invariants:
//! every `Pending` resolves, `KvArena::blocks_in_use` drains to zero,
//! and fault-surviving answers are bitwise-identical to fault-free runs.
//! Shed/cancel/retry/abort counts surface as `serve.shed`,
//! `serve.cancelled`, `serve.retries`, `serve.deadline_aborts` and the
//! `serve.replicas_healthy` gauge in the serve summaries.
//!
//! Since PR 10 the lifecycle is **overload-robust**: an admission layer
//! sits between the client and the round loop, and dispatch routes on
//! live load instead of round-robin position:
//!
//! ```text
//!   EngineClient::submit(Request + SubmitOptions{tenant, priority})
//!        │
//!        ▼
//!   admission (per replica, before intake)        engine::Dispatch
//!     1 token bucket per tenant                   ──────────────────
//!       (EngineConfig::tenant_rate/burst)         LoadAware routing:
//!       over budget ⇒ Err(Overloaded::RateLimited)  each loop publishes
//!     2 queue watermark                             queue depth + KV
//!       (EngineConfig::shed_watermark)              residency to a shared
//!       over the mark ⇒ shed the *lowest-priority*  LoadView; submits go
//!       youngest queued request — the arrival       to the least-loaded
//!       itself only when nothing lower is queued    healthy replica,
//!       ⇒ Err(Overloaded::QueueFull)                prefix-affinity
//!     3 brownout under sustained backlog            steers shared-prompt
//!       (EngineConfig::brownout_backlog/after)      waves to the replica
//!       low-priority max_new capped, High exempt    holding the cached
//!   decode promotion: priority-then-FIFO            prefix blocks
//! ```
//!
//! Rejections are typed ([`engine::Overloaded`] with an
//! [`engine::OverloadKind`]) and always an `Err` answer — never a hang,
//! never a panic (invariant R1). The seeded workload harness
//! ([`engine::workload`]) generates multi-tenant bursty traces (Poisson
//! and ON-OFF arrivals, bounded-Pareto lengths) that replay bit-for-bit,
//! and `rilq serve-bench --trace=burst` self-asserts the acceptance bar:
//! shedding hits low-priority first, high-priority TTFT p99 stays within
//! 2x the uncontended baseline, and the same seed replays identical
//! admission decisions. SLO accounting lands in the serve summaries:
//! `serve.ttft_*` percentiles, `serve.goodput_requests` (completions
//! that beat their deadline) vs raw tok/s, `serve.overload_sheds{,_high}`,
//! `serve.rate_limited`, `serve.brownouts`, and `serve.slow_forwards`
//! from the slow-replica watchdog (`EngineConfig::slow_forward_threshold`
//! — streaks trip the same sticky [`engine::HealthView`] as crashes).
//!
//! ## Micro-kernel layer (the FLOP path)
//!
//! Below the backends sits one vectorized primitive set,
//! [`tensor::kernels`]: 8-wide unrolled multiply-add lanes over
//! `chunks_exact(8)` (auto-vectorized to AVX/NEON on stable Rust — no
//! `std::simd`, no `mul_add` libm traps) behind `dot` / `dot4` / `axpy`
//! / `scale_zero_combine`. Everything hot composes from them:
//!
//! ```text
//!   Mat::matmul      j/k-tiled, RHS packed into a transposed L1 panel ┐
//!   Mat::matmul_t    j/k-tiled, RHS already transposed                ├─ 4-row
//!   PackedLoraLinear byte→f32 LUT dequant (one 256-entry table per    │  micro-
//!     forward_rows   packed-code lane, process-shared per codebook;   │  tiles +
//!                    group tile + partial sums in thread-local        │  dot/axpy
//!                    scratch — zero allocs per chunk)                 │  lanes
//!   attention        rotated-Q·K dots, weighted-V axpy               ─┘
//! ```
//!
//! Two contracts keep this safe to parallelize: each kernel's per-row
//! reduction order is **fixed** (a row's bits never depend on which
//! micro-tile, chunk, or thread computed it), and `parallel_rows`
//! publishes *several small chunks per lane* to the pool's atomic task
//! cursor (work-stealing), so ragged decode batches stop tail-stalling
//! on a static split. The pre-vectorization scalar kernels survive as
//! `*_naive` test references (vectorized == naive ≤1e-5; LUT decode ==
//! shift/mask bitwise), and `cargo bench --bench bench_runtime --
//! --json <path>` emits the machine-readable perf record (tok/s,
//! per-kernel GFLOP/s, speedup ratios; `BENCH_PR6.json` in CI) with the
//! live `serve.kernel_gflops` series feeding the serve summaries.
//! CI gates the record against the committed `BENCH_BASELINE.json`
//! (absolute packed tok/s plus the machine-relative speedup ratios).
//!
//! ## KV cache: paged arena + incremental decode + prefix reuse
//!
//! Attention used to recompute the whole O(S²) causal triangle per
//! request. [`model::kv::KvCache`] stores each layer's rotated-K / V rows
//! per sequence so the forward only ever pushes *new* rows through the
//! linears ([`model::forward::forward_trace_with_cache`] /
//! [`model::forward::forward_step`]; RoPE angles come from one shared
//! [`model::kv::RopeTable`] instead of per-element `powf` + `sin_cos`):
//!
//! ```text
//!   prefill (once)                   decode (per token)
//!   tokens[0..P] ──▶ forward ──┐     last tok ──▶ forward (1 row/linear)
//!                              ▼                      │
//!              KvCache: block table over a shared     │ argmax / logp
//!              KvArena (fixed-size position blocks,   │ appended
//!              rotated K + V head-major planes)  ◀────┘
//!                              │
//!   score_choices: truncate(P) ├──▶ choice A suffix  (cache reuse:
//!   between choices — prompt   ├──▶ choice B suffix   prompt forwarded
//!   prefilled exactly once     └──▶ ...               once per item)
//! ```
//!
//! Since PR 6 the cache storage is **paged**: an engine-owned
//! [`model::kv::KvArena`] hands out fixed-size position blocks
//! (`EngineConfig::kv_block`) from one recycled pool, and each
//! [`model::kv::KvCache`] is just a block table over it. Attention walks
//! the table in ascending-position order with the same per-row reduction
//! order as a contiguous buffer, so paged logits are **bitwise
//! identical** to the contiguous path (`tests/kv_cache.rs` pins this).
//! A standalone `KvCache::new` gets a private full-window arena, so
//! non-engine callers are unchanged.
//!
//! The engine schedules decode traffic over the same cache machinery
//! ([`engine::EngineClient::generate`]): admitted prompts enter the KV
//! cache in `prefill_chunk` slices, then every active sequence advances
//! **one token per scheduler step** — each step is a single fused
//! `[Σ newᵢ, d_model]` forward mixing prefill chunks and decode tokens,
//! so the packed group-tile dequant keeps amortizing. Admission prices a
//! generation at the blocks it *actually holds*, not its worst case, so
//! short generations pack beyond `EngineConfig::max_active`'s worst-case
//! budget; when the arena runs dry mid-decode the scheduler **preempts**
//! the longest generation, ties broken toward the least replay progress
//! (frees its blocks, replays it later via bit-exact chunked re-prefill
//! — resumed output is bitwise identical to an uninterrupted run).
//! Preempted resumes are promoted ahead of fresh admissions — gated so
//! promotion never forces an eviction — nothing starves, and score
//! traffic is never
//! head-of-line blocked behind generations. Latency p50/p95,
//! queue-depth, KV block/byte residency (`serve.kv_bytes`,
//! `serve.kv_blocks_free`), preemption counts, and gen-backlog gauges
//! land in [`coordinator::Metrics`]; `rilq serve-bench` and `cargo bench
//! --bench bench_runtime` report prefill-vs-incremental tok/s and
//! bytes-per-generated-token, and `tests/kv_cache.rs` +
//! `tests/engine_api.rs` + `tests/serve_loop.rs` pin incremental ==
//! full-forward logits, engine greedy == `greedy_decode`, and
//! preempt→resume bitwise parity.
//!
//! Since PR 9 KV reuse is **cross-request**: each replica keeps a
//! [`engine::PrefixIndex`] — a token-id radix trie whose alphabet is
//! whole committed arena blocks — so later requests sharing a prompt
//! prefix (system prompts, few-shot preambles) attach the cached blocks
//! instead of re-prefilling them:
//!
//! ```text
//!              PrefixIndex (per replica, block-granular radix trie)
//!              ┌───────────────────────────────────────────────────┐
//!   finish ──▶ │ [sys prompt........][few-shot]      refcounted    │
//!   insert     │        ├─[user A suffix]           Arc<KvBlock>   │
//!              │        └─[user B suffix]           (arena refs)   │
//!              └───────────────────────────────────────────────────┘
//!   admit(prompt) ──▶ longest block-aligned match ──▶ KvCache starts
//!                     (attach pins blocks: refs+1)    mid-prompt; only
//!                                                     the suffix
//!                                                     chunk-prefills
//! ```
//!
//! Sharing is copy-on-write at the tail: only *whole* committed blocks
//! are ever shared (the partially-filled boundary block is re-prefilled
//! privately), appends go into freshly reserved sole-owner blocks, and
//! `Arc::get_mut` backstops the invariant. Because committed block
//! planes are a pure function of the token prefix and `attend_cached`
//! walks blocks in ascending-position order, a cache-hit prefill is
//! **bitwise identical** to a cold one (`tests/prefix_cache.rs` pins
//! this across all three backends). Under arena pressure the scheduler
//! reclaims **unpinned index entries (LRU) before preempting any live
//! decode**, and eviction skips blocks an active cache still pins.
//! Hit/miss/saved-token counters (`serve.prefix_hits`,
//! `serve.prefix_misses`, `serve.prefix_tokens_saved`,
//! `serve.prefix_evictions`) and the `serve.kv_blocks_pinned` gauge
//! land in the serve summaries; `rilq serve-bench --shared-prefix=N`
//! drives a shared-prompt workload and asserts the cache fired — with
//! `--chaos`, under injected faults too (every abort/failover path
//! releases its shared pins exactly once, so the arena still drains to
//! zero).
//!
//! ## Invariant catalog (enforced by `rilq-lint`)
//!
//! Five repo-wide invariants are machine-checked by the zero-dependency
//! workspace linter at `tools/rilq-lint` (`cargo run -p rilq-lint`,
//! blocking in CI; `cargo test -p rilq-lint` runs its fixture suite and
//! a self-check that this tree is clean):
//!
//! * **R1 — no-panic serving surface.** `engine/`, `coordinator/serve.rs`,
//!   `model/forward.rs`, `model/kv.rs` and `model/backend.rs` may not
//!   `unwrap`/`expect`/`panic!`/`assert!` or index slices directly: a
//!   malformed request must answer `Err`, never kill a scheduler thread.
//!   `debug_assert!` is exempt, as is `.unwrap()` directly on `lock()`
//!   (a poisoned mutex means a sibling thread already panicked — the
//!   PR 2 no-poison convention). The one *sanctioned* panic source on
//!   the serving path is the annotated injected panic in
//!   `engine/chaos.rs` ([`engine::ChaosScorer`]): it exists precisely
//!   to prove the supervision layer survives a crashing scorer, and the
//!   engine's `catch_unwind` guard is what keeps R1's promise when it
//!   fires.
//! * **R2 — bitwise-pin guard.** `tensor/kernels.rs`, `tensor/mat.rs`
//!   and `model/backend.rs` may not introduce `mul_add`, iterator
//!   `.sum()`/`.fold(`, or `par_*` reductions: every hot kernel keeps a
//!   fixed per-row reduction order so row bits never depend on chunking
//!   or threading. Every pin comment must name a test that exists.
//! * **R3 — hot-loop allocation.** Functions annotated as hot may not
//!   call `Vec::new`/`vec!`/`to_vec`/`clone`/`Mat::from_fn`; scratch is
//!   thread-local and reused (`PACKED_SCRATCH`, `ATTN_SCRATCH`).
//! * **R4 — lock discipline.** A mutex guard may not live across a
//!   forward/backend call: scorer calls run lock-free or the engine
//!   serializes on the slowest request.
//! * **R5 — unsafe audit.** Every `unsafe` block carries a `SAFETY:`
//!   comment within the six preceding lines, and
//!   `#![deny(unsafe_op_in_unsafe_fn)]` holds crate-wide.
//!
//! Annotation grammar (all comments; the linter only reads comments that
//! *start* with the marker, so this prose is inert): a line-level
//! `lint: allow(panic) — <reason>` on or directly above the line it
//! excuses; a function-level `lint: allow(indexing) — <reason>` or
//! `lint: hot — <reason>` directly above the `fn` it governs (attributes
//! and doc lines may intervene); `bitwise-pin: <test_name>, ...` above a
//! kernel names the tests pinning its bit-exactness; `lint:
//! allow(reduce) — <reason>` excuses one diagnostics-only reduction.
//! A reason is mandatory — `allow(...)` without one is itself an error.

#![deny(unsafe_op_in_unsafe_fn)]

// Clippy style-lint allowances for the numeric kernels live in
// Cargo.toml's `[lints.clippy]` table so they cover tests/benches too.

pub mod tensor;
pub mod quant;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod eval;
pub mod experiments;
pub mod lqec;
pub mod model;
pub mod report;
pub mod runtime;

pub use tensor::{Mat, Rng};
