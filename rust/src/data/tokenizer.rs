//! Synthetic vocabulary with a semantic region layout.
//!
//! Region layout (scaled to the model's vocab size):
//!
//! ```text
//! 0..4        special: <pad> <bos> <eos> <sep>
//! 4..14       digits 0-9
//! 14..18      operators: + - = ?
//! classes     C noun classes x (nouns | verbs | adjectives)
//! tail        noise tokens (c4-sim flavor)
//! ```
//!
//! Word *strings* are generated deterministically (CV syllables) so
//! examples can print readable text, but the pipeline operates on ids.

use crate::tensor::Rng;

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;
pub const SEP: u32 = 3;
pub const DIGIT0: u32 = 4;
pub const OP_PLUS: u32 = 14;
pub const OP_MINUS: u32 = 15;
pub const OP_EQ: u32 = 16;
pub const OP_Q: u32 = 17;
const FIRST_CLASS_TOKEN: u32 = 18;

/// Per-class region sizes (scaled by vocab).
#[derive(Clone, Copy, Debug)]
pub struct ClassLayout {
    pub n_classes: usize,
    pub nouns_per_class: usize,
    pub verbs_per_class: usize,
    pub adjs_per_class: usize,
}

/// The vocabulary: region layout + generated word strings.
#[derive(Clone, Debug)]
pub struct Vocab {
    pub size: usize,
    pub layout: ClassLayout,
    words: Vec<String>,
    /// first noise token id (noise region runs to `size`)
    noise_start: u32,
}

impl Vocab {
    /// Build a vocabulary for a model vocab size (>= 64).
    pub fn new(size: usize, seed: u64) -> Vocab {
        assert!(size >= 64, "vocab too small: {size}");
        // scale class structure to the vocab budget
        let budget = size - FIRST_CLASS_TOKEN as usize;
        let n_classes = if size >= 1024 {
            8
        } else if size >= 512 {
            6
        } else if size >= 256 {
            4
        } else {
            2
        };
        // per class: nouns + verbs + adjs; reserve ~15% of budget as noise
        let per_class = budget * 85 / 100 / n_classes;
        let nouns = (per_class * 50 / 100).max(2);
        let verbs = (per_class * 30 / 100).max(2);
        let adjs = per_class - nouns - verbs;
        let layout = ClassLayout {
            n_classes,
            nouns_per_class: nouns,
            verbs_per_class: verbs,
            adjs_per_class: adjs.max(1),
        };
        let noise_start =
            FIRST_CLASS_TOKEN + (n_classes * (nouns + verbs + adjs.max(1))) as u32;
        assert!((noise_start as usize) < size, "layout overflow");

        // generate word strings: CV syllable soup, deterministic
        let mut rng = Rng::seed(seed ^ 0x70ce_ab1e);
        let consonants = ["b", "d", "f", "g", "k", "l", "m", "n", "p", "r", "s", "t", "v", "z"];
        let vowels = ["a", "e", "i", "o", "u"];
        let mut words = Vec::with_capacity(size);
        for id in 0..size as u32 {
            let w = match id {
                PAD => "<pad>".to_string(),
                BOS => "<bos>".to_string(),
                EOS => "<eos>".to_string(),
                SEP => "<sep>".to_string(),
                d if (DIGIT0..DIGIT0 + 10).contains(&d) => (d - DIGIT0).to_string(),
                OP_PLUS => "+".to_string(),
                OP_MINUS => "-".to_string(),
                OP_EQ => "=".to_string(),
                OP_Q => "?".to_string(),
                _ => {
                    let syls = 2 + rng.below(2);
                    let mut w = String::new();
                    for _ in 0..syls {
                        w.push_str(consonants[rng.below(consonants.len())]);
                        w.push_str(vowels[rng.below(vowels.len())]);
                    }
                    w
                }
            };
            words.push(w);
        }
        Vocab { size, layout, words, noise_start }
    }

    fn class_block(&self) -> usize {
        self.layout.nouns_per_class + self.layout.verbs_per_class + self.layout.adjs_per_class
    }

    /// Noun `k` of class `c`.
    pub fn noun(&self, c: usize, k: usize) -> u32 {
        debug_assert!(c < self.layout.n_classes && k < self.layout.nouns_per_class);
        FIRST_CLASS_TOKEN + (c * self.class_block() + k) as u32
    }

    /// Verb `k` of class `c` (agreement: verbs only co-occur with their
    /// class's subjects in grammatical text).
    pub fn verb(&self, c: usize, k: usize) -> u32 {
        debug_assert!(c < self.layout.n_classes && k < self.layout.verbs_per_class);
        FIRST_CLASS_TOKEN
            + (c * self.class_block() + self.layout.nouns_per_class + k) as u32
    }

    /// Adjective `k` of class `c`.
    pub fn adj(&self, c: usize, k: usize) -> u32 {
        debug_assert!(c < self.layout.n_classes && k < self.layout.adjs_per_class);
        FIRST_CLASS_TOKEN
            + (c * self.class_block()
                + self.layout.nouns_per_class
                + self.layout.verbs_per_class
                + k) as u32
    }

    /// Digit token.
    pub fn digit(&self, d: usize) -> u32 {
        debug_assert!(d < 10);
        DIGIT0 + d as u32
    }

    /// A random noise token (c4-sim flavor).
    pub fn noise(&self, rng: &mut Rng) -> u32 {
        let span = self.size as u32 - self.noise_start;
        if span == 0 {
            return self.noun(rng.below(self.layout.n_classes), 0);
        }
        self.noise_start + rng.below(span as usize) as u32
    }

    /// Which class a token belongs to (None for non-class tokens).
    pub fn class_of(&self, tok: u32) -> Option<usize> {
        if tok < FIRST_CLASS_TOKEN || tok >= self.noise_start {
            return None;
        }
        Some((tok - FIRST_CLASS_TOKEN) as usize / self.class_block())
    }

    /// Is this token a verb?
    pub fn is_verb(&self, tok: u32) -> bool {
        if tok < FIRST_CLASS_TOKEN || tok >= self.noise_start {
            return false;
        }
        let off = (tok - FIRST_CLASS_TOKEN) as usize % self.class_block();
        off >= self.layout.nouns_per_class
            && off < self.layout.nouns_per_class + self.layout.verbs_per_class
    }

    /// Readable rendering of a token sequence.
    pub fn render(&self, toks: &[u32]) -> String {
        toks.iter()
            .map(|&t| self.words.get(t as usize).map(String::as_str).unwrap_or("<?>"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layouts_fit_all_config_vocabs() {
        for &size in &[256usize, 512, 1024] {
            let v = Vocab::new(size, 1);
            assert_eq!(v.words.len(), size);
            let c = v.layout.n_classes - 1;
            let last_adj = v.adj(c, v.layout.adjs_per_class - 1);
            assert!((last_adj as usize) < size);
            assert!(v.noise_start as usize <= size);
        }
    }

    #[test]
    fn class_of_inverts_constructors() {
        let v = Vocab::new(512, 2);
        for c in 0..v.layout.n_classes {
            assert_eq!(v.class_of(v.noun(c, 0)), Some(c));
            assert_eq!(v.class_of(v.verb(c, 1)), Some(c));
            assert_eq!(v.class_of(v.adj(c, 0)), Some(c));
            assert!(v.is_verb(v.verb(c, 0)));
            assert!(!v.is_verb(v.noun(c, 0)));
        }
        assert_eq!(v.class_of(PAD), None);
        assert_eq!(v.class_of(DIGIT0), None);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Vocab::new(256, 7);
        let b = Vocab::new(256, 7);
        assert_eq!(a.words, b.words);
        let c = Vocab::new(256, 8);
        assert_ne!(a.words, c.words);
    }

    #[test]
    fn render_specials() {
        let v = Vocab::new(256, 1);
        assert_eq!(v.render(&[BOS, DIGIT0 + 3, OP_PLUS, DIGIT0 + 4, OP_EQ]), "<bos> 3 + 4 =");
    }
}
