//! Data substrate: synthetic corpora and evaluation tasks.
//!
//! The paper calibrates on C4 and evaluates perplexity on WikiText-2/C4
//! plus zero-shot CSQA accuracy and GSM8K. None of those are available in
//! this environment (repro band 0), so we build controlled analogues
//! (DESIGN.md substitution table):
//!
//! * [`tokenizer`] — a synthetic word-level vocabulary laid out into
//!   semantic regions (special, digits, operators, noun/verb classes, …);
//! * [`corpus`] — a seeded probabilistic grammar with subject–verb
//!   agreement (the learnable structure), in two profiles: `wiki-sim`
//!   (clean, narrow) and `c4-sim` (noisy, broad);
//! * [`tasks`] — five CSQA-style multiple-choice cloze-ranking tasks of
//!   graded difficulty plus `gsm-sim` arithmetic items.

pub mod corpus;
pub mod tasks;
pub mod tokenizer;

pub use corpus::{Corpus, Profile};
pub use tasks::{GsmItem, McItem, TaskKind};
pub use tokenizer::Vocab;
