//! Evaluation task generators: five CSQA-style multiple-choice tasks of
//! graded difficulty (the paper's WinoGrande / PIQA / HellaSwag / ARC-e /
//! ARC-c suite) and `gsm-sim` arithmetic (the GSM8K analogue).
//!
//! All MC tasks are *cloze ranking*: the model scores each candidate
//! continuation by total log-likelihood, exactly like lm-eval-harness's
//! CSQA scoring path. Correct answers are grammar-consistent; distractors
//! violate the agreement rule or plausibility at task-specific strength.

use crate::tensor::Rng;

use super::corpus::{Corpus, Profile};
use super::tokenizer::{Vocab, BOS, OP_EQ, OP_PLUS, SEP};

/// The five CSQA-sim tasks, in paper column order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskKind {
    /// WinoGrande-sim: binary verb-agreement choice.
    WgSim,
    /// PIQA-sim: binary object-plausibility choice.
    PiqaSim,
    /// HellaSwag-sim: 4-way full-sentence continuation.
    HsSim,
    /// ARC-challenge-sim: 4-way, same-class near-miss distractors.
    ArcCSim,
    /// ARC-easy-sim: 4-way, random-word distractors.
    ArcESim,
}

impl TaskKind {
    pub const ALL: [TaskKind; 5] =
        [TaskKind::WgSim, TaskKind::PiqaSim, TaskKind::HsSim, TaskKind::ArcCSim, TaskKind::ArcESim];

    pub fn label(&self) -> &'static str {
        match self {
            TaskKind::WgSim => "WG",
            TaskKind::PiqaSim => "PIQA",
            TaskKind::HsSim => "HS",
            TaskKind::ArcCSim => "Arc-c",
            TaskKind::ArcESim => "Arc-e",
        }
    }
}

/// One multiple-choice item.
#[derive(Clone, Debug)]
pub struct McItem {
    pub prompt: Vec<u32>,
    pub choices: Vec<Vec<u32>>,
    pub correct: usize,
}

/// One gsm-sim item: prompt ends with `=`, answer is a single digit token.
#[derive(Clone, Debug)]
pub struct GsmItem {
    pub prompt: Vec<u32>,
    pub answer: u32,
}

/// Context sentences prepended to each MC prompt (few tokens of topical
/// context make the task depend on more than the last bigram).
fn context(corpus: &mut Corpus, sentences: usize) -> Vec<u32> {
    let mut out = vec![BOS];
    for _ in 0..sentences {
        corpus.sentence(&mut out);
    }
    out
}

/// Context pinned to topic class `c`: sentences `ADJ_c NOUN_c VERB_c
/// [NOUN_c] SEP`, so the topical-consistency tasks have an unambiguous
/// ground-truth topic.
fn context_topic(corpus: &mut Corpus, sentences: usize, c: usize) -> Vec<u32> {
    let lay = corpus.vocab.layout;
    let mut out = vec![BOS];
    for _ in 0..sentences {
        let v = corpus.vocab.clone();
        let rng = corpus.rng();
        out.push(v.adj(c, rng.below(lay.adjs_per_class)));
        out.push(v.noun(c, rng.below(lay.nouns_per_class)));
        out.push(v.verb(c, rng.below(lay.verbs_per_class)));
        if rng.next_f32() < 0.8 {
            out.push(v.noun(c, rng.below(lay.nouns_per_class)));
        }
        out.push(SEP);
    }
    out
}

/// Generate `n` items of one task kind.
pub fn gen_mc(kind: TaskKind, vocab: &Vocab, n: usize, seed: u64) -> Vec<McItem> {
    let mut corpus = Corpus::new(vocab.clone(), Profile::WikiSim, seed ^ 0x7a5c);
    let mut rng = Rng::seed(seed ^ 0x11c5);
    let lay = vocab.layout;
    let mut items = Vec::with_capacity(n);
    while items.len() < n {
        let c = rng.below(lay.n_classes);
        let other = (c + 1 + rng.below(lay.n_classes - 1)) % lay.n_classes;
        // Difficulty calibration: direct agreement bigrams (noun -> verb)
        // are learned so hard that even uncompensated W2 models keep them
        // at ceiling; the graded tasks below query *topical consistency*
        // across sentence boundaries — the signal is statistical (the
        // grammar's topic chain persists w.p. ~0.85), so the optimal
        // predictor sits below 100% and degradation/recovery is visible.
        match kind {
            TaskKind::WgSim => {
                // binary: after a topic-c sentence, which ADJ opens the
                // next sentence? (reverse-direction, cross-sentence)
                let prompt = context_topic(&mut corpus, 2, c);
                let good = vec![vocab.adj(c, rng.below(lay.adjs_per_class))];
                let bad = vec![vocab.adj(other, rng.below(lay.adjs_per_class))];
                push_shuffled(&mut items, prompt, vec![good, bad], &mut rng);
            }
            TaskKind::PiqaSim => {
                // binary: topic-consistent next-sentence SUBJECT noun vs a
                // far-class noun
                let prompt = context_topic(&mut corpus, 1, c);
                let good = vec![vocab.noun(c, rng.below(lay.nouns_per_class))];
                let bad = vec![vocab.noun(other, rng.below(lay.nouns_per_class))];
                push_shuffled(&mut items, prompt, vec![good, bad], &mut rng);
            }
            TaskKind::HsSim => {
                // 4-way: full next-sentence continuations; one stays on
                // topic, three switch topic (all internally grammatical)
                let prompt = context_topic(&mut corpus, 2, c);
                let mk = |rng: &mut Rng, sc: usize, vocab: &Vocab| {
                    vec![
                        vocab.adj(sc, rng.below(lay.adjs_per_class)),
                        vocab.noun(sc, rng.below(lay.nouns_per_class)),
                        vocab.verb(sc, rng.below(lay.verbs_per_class)),
                        SEP,
                    ]
                };
                let good = mk(&mut rng, c, vocab);
                let mut choices = vec![good];
                for k in 0..3 {
                    let oc = (c + 1 + k) % lay.n_classes;
                    choices.push(mk(&mut rng, oc % lay.n_classes, vocab));
                }
                push_shuffled(&mut items, prompt, choices, &mut rng);
            }
            TaskKind::ArcCSim => {
                // hard 4-way: next-sentence ADJ with three topic-switched
                // distractors (reverse-direction + 4 candidates)
                let prompt = context_topic(&mut corpus, 1, c);
                let good = vec![vocab.adj(c, rng.below(lay.adjs_per_class))];
                let mut choices = vec![good];
                for k in 0..3 {
                    let oc = (c + 1 + k) % lay.n_classes;
                    choices
                        .push(vec![vocab.adj(oc % lay.n_classes, rng.below(lay.adjs_per_class))]);
                }
                push_shuffled(&mut items, prompt, choices, &mut rng);
            }
            TaskKind::ArcESim => {
                // easy 4-way: direct verb agreement with the subject (the
                // strongly-trained bigram) — near-ceiling for good models,
                // still collapses under severe quantization
                let mut prompt = context(&mut corpus, 1);
                prompt.push(vocab.noun(c, rng.below(lay.nouns_per_class)));
                let good = vec![vocab.verb(c, rng.below(lay.verbs_per_class))];
                let mut choices = vec![good];
                for k in 0..3 {
                    let oc = (c + 1 + k) % lay.n_classes;
                    choices
                        .push(vec![vocab.verb(oc % lay.n_classes, rng.below(lay.verbs_per_class))]);
                }
                push_shuffled(&mut items, prompt, choices, &mut rng);
            }
        }
    }
    items
}

fn push_shuffled(
    items: &mut Vec<McItem>,
    prompt: Vec<u32>,
    mut choices: Vec<Vec<u32>>,
    rng: &mut Rng,
) {
    // choice 0 is correct pre-shuffle
    let mut order: Vec<usize> = (0..choices.len()).collect();
    rng.shuffle(&mut order);
    let correct = order.iter().position(|&i| i == 0).unwrap();
    let mut shuffled = Vec::with_capacity(choices.len());
    for &i in &order {
        shuffled.push(std::mem::take(&mut choices[i]));
    }
    items.push(McItem { prompt, choices: shuffled, correct });
}

/// Generate gsm-sim items. `steps` = number of additions chained (1 or 2).
pub fn gen_gsm(vocab: &Vocab, n: usize, steps: usize, seed: u64) -> Vec<GsmItem> {
    let mut rng = Rng::seed(seed ^ 0x65e8);
    let mut items = Vec::with_capacity(n);
    for _ in 0..n {
        let mut total = rng.below(10);
        let mut prompt = vec![BOS, vocab.digit(total)];
        for _ in 0..steps {
            let b = rng.below(10);
            prompt.push(OP_PLUS);
            prompt.push(vocab.digit(b));
            total = (total + b) % 10;
        }
        prompt.push(OP_EQ);
        items.push(GsmItem { prompt, answer: vocab.digit(total) });
    }
    items
}

/// gsm-sim *fine-tuning* sequences: prompt + answer + SEP, padded into
/// fixed-length training windows by concatenation.
pub fn gsm_train_seqs(
    vocab: &Vocab,
    n_windows: usize,
    len: usize,
    steps: usize,
    seed: u64,
) -> Vec<Vec<u32>> {
    let items = gen_gsm(vocab, n_windows * len / 8 + 16, steps, seed);
    let mut stream = Vec::new();
    for it in &items {
        stream.extend(&it.prompt[1..]); // drop per-item BOS
        stream.push(it.answer);
        stream.push(SEP);
    }
    let mut out = Vec::with_capacity(n_windows);
    let mut pos = 0;
    for _ in 0..n_windows {
        let mut seq = vec![BOS];
        while seq.len() < len {
            seq.push(stream[pos % stream.len()]);
            pos += 1;
        }
        out.push(seq);
    }
    out
}

/// CSQA-style fine-tuning sequences: correct-completion text only.
pub fn csqa_train_seqs(vocab: &Vocab, n_windows: usize, len: usize, seed: u64) -> Vec<Vec<u32>> {
    let mut stream = Vec::new();
    for kind in TaskKind::ALL {
        for it in gen_mc(kind, vocab, n_windows.max(8), seed ^ kind as u64) {
            stream.extend(&it.prompt[1..]);
            stream.extend(&it.choices[it.correct]);
            stream.push(SEP);
        }
    }
    let mut out = Vec::with_capacity(n_windows);
    let mut pos = 0;
    for _ in 0..n_windows {
        let mut seq = vec![BOS];
        while seq.len() < len {
            seq.push(stream[pos % stream.len()]);
            pos += 1;
        }
        out.push(seq);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mc_items_well_formed() {
        let v = Vocab::new(256, 1);
        for kind in TaskKind::ALL {
            let items = gen_mc(kind, &v, 20, 3);
            assert_eq!(items.len(), 20);
            for it in &items {
                assert!(it.correct < it.choices.len());
                assert!(!it.prompt.is_empty() && it.prompt[0] == BOS);
                let expected = match kind {
                    TaskKind::WgSim | TaskKind::PiqaSim => 2,
                    _ => 4,
                };
                assert_eq!(it.choices.len(), expected, "{kind:?}");
            }
        }
    }

    #[test]
    fn wg_correct_choice_is_topic_consistent() {
        let v = Vocab::new(256, 1);
        for it in gen_mc(TaskKind::WgSim, &v, 30, 4) {
            // prompt is a topic-pinned context ending in SEP; the topic is
            // the class of the first content token after BOS
            let topic = v.class_of(it.prompt[1]).unwrap();
            let good = it.choices[it.correct][0];
            assert_eq!(v.class_of(good), Some(topic));
            let bad = it.choices[1 - it.correct][0];
            assert_ne!(v.class_of(bad), Some(topic));
        }
    }

    #[test]
    fn gsm_answers_correct() {
        let v = Vocab::new(256, 1);
        for it in gen_gsm(&v, 50, 2, 9) {
            // prompt: BOS d (+ d)* =
            let digits: Vec<u32> = it
                .prompt
                .iter()
                .filter(|&&t| (4..14).contains(&t))
                .map(|&t| t - 4)
                .collect();
            let total: u32 = digits.iter().sum::<u32>() % 10;
            assert_eq!(it.answer, v.digit(total as usize));
        }
    }

    #[test]
    fn train_seqs_exact_length() {
        let v = Vocab::new(256, 1);
        for seq in gsm_train_seqs(&v, 4, 64, 1, 5) {
            assert_eq!(seq.len(), 64);
        }
        for seq in csqa_train_seqs(&v, 4, 64, 5) {
            assert_eq!(seq.len(), 64);
        }
    }

    #[test]
    fn correct_index_uniformish() {
        // shuffle must not leave the correct answer always at index 0
        let v = Vocab::new(256, 1);
        let items = gen_mc(TaskKind::HsSim, &v, 100, 11);
        let zeros = items.iter().filter(|i| i.correct == 0).count();
        assert!(zeros > 5 && zeros < 50, "zeros={zeros}");
    }
}
