//! Seeded probabilistic-grammar corpus generator.
//!
//! Sentences follow `[ADJ_c] NOUN_c VERB_c [NOUN_c'] [SEP]` where the verb
//! *must* agree with the subject's class — this agreement is the structure
//! the models learn during pretraining and what the CSQA-sim tasks query.
//! A topic Markov chain correlates adjacent sentences (long-range signal),
//! and an arithmetic sub-stream (`a + b = c`) teaches digit addition for
//! gsm-sim.
//!
//! Two profiles reproduce the paper's calibration/eval distribution gap:
//! `wiki-sim` is clean and narrow; `c4-sim` injects noise tokens and a
//! broader topic distribution (so models calibrated on one see a mild
//! shift on the other, like C4-calibration → WikiText-2 eval).

use crate::tensor::Rng;

use super::tokenizer::{Vocab, BOS, EOS, OP_EQ, OP_PLUS, SEP};

/// Corpus flavor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Profile {
    /// clean, narrow topic distribution (WikiText-2 analogue)
    WikiSim,
    /// noisy, broad (C4 analogue — the paper's calibration set)
    C4Sim,
}

/// A seeded corpus stream over a vocabulary.
#[derive(Clone, Debug)]
pub struct Corpus {
    pub vocab: Vocab,
    pub profile: Profile,
    rng: Rng,
    topic: usize,
}

impl Corpus {
    pub fn new(vocab: Vocab, profile: Profile, seed: u64) -> Corpus {
        let salt = match profile {
            Profile::WikiSim => 0x11aa,
            Profile::C4Sim => 0x22bb,
        };
        Corpus { vocab, profile, rng: Rng::seed(seed ^ salt), topic: 0 }
    }

    fn noise_prob(&self) -> f32 {
        match self.profile {
            Profile::WikiSim => 0.02,
            Profile::C4Sim => 0.10,
        }
    }

    fn topic_switch_prob(&self) -> f32 {
        match self.profile {
            Profile::WikiSim => 0.15,
            Profile::C4Sim => 0.35,
        }
    }

    fn arithmetic_prob(&self) -> f32 {
        0.12
    }

    /// Emit one sentence (without BOS), honoring the agreement grammar.
    pub fn sentence(&mut self, out: &mut Vec<u32>) {
        let v = &self.vocab;
        let lay = v.layout;
        // topic chain
        if self.rng.next_f32() < self.topic_switch_prob() {
            self.topic = self.rng.below(lay.n_classes);
        }
        if self.rng.next_f32() < self.arithmetic_prob() {
            // arithmetic clause: a + b = c (mod 10)
            let a = self.rng.below(10);
            let b = self.rng.below(10);
            out.extend([
                v.digit(a),
                OP_PLUS,
                v.digit(b),
                OP_EQ,
                v.digit((a + b) % 10),
                SEP,
            ]);
            return;
        }
        let c = self.topic;
        // optional adjective (agrees with subject class)
        if self.rng.next_f32() < 0.4 {
            out.push(v.adj(c, self.rng.below(lay.adjs_per_class)));
        }
        out.push(v.noun(c, self.rng.below(lay.nouns_per_class)));
        // THE agreement rule: verb from the subject's class
        out.push(v.verb(c, self.rng.below(lay.verbs_per_class)));
        // optional object: same topic w.p. 0.7, adjacent class otherwise
        if self.rng.next_f32() < 0.8 {
            let oc = if self.rng.next_f32() < 0.7 {
                c
            } else {
                (c + 1) % lay.n_classes
            };
            out.push(v.noun(oc, self.rng.below(lay.nouns_per_class)));
        }
        // profile noise
        if self.rng.next_f32() < self.noise_prob() {
            let tok = v.noise(&mut self.rng);
            out.push(tok);
        }
        out.push(SEP);
    }

    /// A fixed-length token sequence starting with BOS (training/eval
    /// window). Always exactly `len` tokens.
    pub fn sample_seq(&mut self, len: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(len + 8);
        out.push(BOS);
        while out.len() < len {
            self.sentence(&mut out);
        }
        out.truncate(len);
        out
    }

    /// A batch of sequences.
    pub fn sample_batch(&mut self, batch: usize, len: usize) -> Vec<Vec<u32>> {
        (0..batch).map(|_| self.sample_seq(len)).collect()
    }

    /// A complete document (sentence stream terminated by EOS), for
    /// examples and debugging.
    pub fn sample_doc(&mut self, approx_len: usize) -> Vec<u32> {
        let mut out = vec![BOS];
        while out.len() < approx_len {
            self.sentence(&mut out);
        }
        out.push(EOS);
        out
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus(profile: Profile) -> Corpus {
        Corpus::new(Vocab::new(256, 1), profile, 42)
    }

    #[test]
    fn sequences_have_exact_length() {
        let mut c = corpus(Profile::WikiSim);
        for len in [16usize, 64, 128] {
            assert_eq!(c.sample_seq(len).len(), len);
        }
    }

    #[test]
    fn tokens_in_vocab_range() {
        let mut c = corpus(Profile::C4Sim);
        let seq = c.sample_seq(512);
        assert!(seq.iter().all(|&t| (t as usize) < 256));
    }

    #[test]
    fn agreement_holds_in_grammar() {
        // every (noun, verb) bigram must agree on class
        let mut c = corpus(Profile::WikiSim);
        let seq = c.sample_seq(2000);
        let v = &c.vocab;
        let mut checked = 0;
        for w in seq.windows(2) {
            if let (Some(nc), true) = (v.class_of(w[0]), v.is_verb(w[1])) {
                if !v.is_verb(w[0]) {
                    let vc = v.class_of(w[1]).unwrap();
                    assert_eq!(nc, vc, "agreement violated: {:?}", v.render(w));
                    checked += 1;
                }
            }
        }
        assert!(checked > 20, "too few bigrams checked: {checked}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Corpus::new(Vocab::new(256, 1), Profile::WikiSim, 5);
        let mut b = Corpus::new(Vocab::new(256, 1), Profile::WikiSim, 5);
        assert_eq!(a.sample_seq(64), b.sample_seq(64));
    }

    #[test]
    fn profiles_differ() {
        let mut a = Corpus::new(Vocab::new(256, 1), Profile::WikiSim, 5);
        let mut b = Corpus::new(Vocab::new(256, 1), Profile::C4Sim, 5);
        assert_ne!(a.sample_seq(64), b.sample_seq(64));
    }

    #[test]
    fn arithmetic_clauses_are_correct() {
        let mut c = corpus(Profile::WikiSim);
        let seq = c.sample_seq(4000);
        let mut found = 0;
        for w in seq.windows(5) {
            if w[1] == OP_PLUS && w[3] == OP_EQ {
                let a = w[0].checked_sub(super::super::tokenizer::DIGIT0);
                let b = w[2].checked_sub(super::super::tokenizer::DIGIT0);
                let s = w[4].checked_sub(super::super::tokenizer::DIGIT0);
                if let (Some(a), Some(b), Some(s)) = (a, b, s) {
                    if a < 10 && b < 10 {
                        assert_eq!((a + b) % 10, s);
                        found += 1;
                    }
                }
            }
        }
        assert!(found > 10, "no arithmetic clauses found");
    }
}
