//! `rilq` — Layer-3 coordinator binary.
//!
//! ```text
//! rilq list                         list experiments (paper table/figure map)
//! rilq experiment <id>|all [--fast] reproduce a paper table/figure -> reports/
//! rilq pretrain <config> [--steps=N]   pretrain + cache a teacher
//! rilq eval <config> [--quant=rtn --bits=2 --rank=16 --scope=model_gt]
//!                    [--backend={dense|packed|merged}]
//!                                   quantize+compensate+evaluate one cell
//! rilq serve-bench [--backend=packed --batch=8 --requests=64 --seq=64
//!                   --gen=N --sample --stream --shared-prefix=N
//!                   --trace={burst|poisson} --smoke]
//!                                   request-lifecycle engine benchmark:
//!                                   continuous batching, KV-cache decode,
//!                                   sampling + streaming, and seeded
//!                                   multi-tenant overload traces
//!                                   (native, PJRT-free)
//! rilq inspect                      print manifest / artifact inventory
//! ```

use anyhow::{anyhow, Result};

use rilq::cli::Args;
use rilq::coordinator::{probe_decode, probe_throughput};
use rilq::engine::{ChaosScorer, Engine, EngineConfig, Fault, SamplingParams, TokenEvent};
use rilq::eval::{BackendScorer, Scorer};
use rilq::experiments::pipeline::Lab;
use rilq::experiments::{catalog, run_experiment};
use rilq::lqec::AdapterSet;
use rilq::model::backend::BackendKind;
use rilq::model::{ModelDims, StudentWeights, TeacherParams, LINEARS};
use rilq::quant::{by_name, CalibCtx};
use rilq::runtime::Runtime;
use rilq::tensor::{Mat, Rng};

fn main() {
    init_logger();
    let args = Args::parse(std::env::args().skip(1));
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:?}");
        std::process::exit(1);
    }
}

fn artifact_dir(args: &Args) -> String {
    args.opt("artifacts").unwrap_or("artifacts").to_string()
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_str() {
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        "list" => {
            println!("{:<10} {:<22} paper reference", "id", "report");
            for e in catalog() {
                println!("{:<10} reports/{:<14} {}", e.id, format!("{}.md", e.id), e.paper_ref);
            }
            Ok(())
        }
        "inspect" => {
            let rt = Runtime::new(artifact_dir(args))?;
            println!("configs:");
            for (name, d) in &rt.manifest.configs {
                println!(
                    "  {name:<6} d={} L={} H={} ff={} V={} seq={} batch={} (~{:.1}M params)",
                    d.d_model,
                    d.n_layers,
                    d.n_heads,
                    d.d_ff,
                    d.vocab,
                    d.seq,
                    d.batch,
                    d.params_count() as f64 / 1e6
                );
            }
            println!("artifacts: {}", rt.manifest.artifacts.len());
            for (name, a) in &rt.manifest.artifacts {
                println!("  {:<42} {} in / {} out", name, a.inputs.len(), a.outputs.len());
            }
            Ok(())
        }
        "experiment" => {
            let id = args.pos(0).ok_or_else(|| anyhow!("usage: rilq experiment <id>|all"))?;
            let rt = Runtime::new(artifact_dir(args))?;
            run_experiment(&rt, id, args.flag("fast"))
        }
        "pretrain" => {
            let config = args.pos(0).unwrap_or("small");
            let rt = Runtime::new(artifact_dir(args))?;
            let mut lab = Lab::new(&rt);
            if let Some(steps) = args.opt_usize("steps")? {
                lab.pretrain_steps_override = Some(steps);
            }
            let (dims, _teacher, losses) = lab.teacher(config)?;
            println!(
                "pretrained {config} ({:.1}M params): loss {:.3} -> {:.3} over {} steps",
                dims.params_count() as f64 / 1e6,
                losses.first().copied().unwrap_or(f32::NAN),
                losses.last().copied().unwrap_or(f32::NAN),
                losses.len()
            );
            Ok(())
        }
        "eval" => {
            let config = args.pos(0).unwrap_or("small");
            let quant = args.opt("quant").unwrap_or("rtn");
            let bits = args.opt_usize("bits")?.unwrap_or(2) as u8;
            let rank = args.opt_usize("rank")?.unwrap_or(16);
            let scope = args.opt("scope").unwrap_or("model_gt");
            let backend = args.backend()?;
            let rt = Runtime::new(artifact_dir(args))?;
            let mut lab = Lab::new(&rt);
            lab.backend = backend;
            if args.flag("fast") {
                lab.calib.max_steps = 60;
                lab.calib.n_samples = 64;
                lab.pretrain_steps_override = Some(200);
            }
            let (dims, teacher, _) = lab.teacher(config)?;
            let student = lab.quantize(&dims, &teacher, quant, bits)?;

            let zeros = AdapterSet::zeros(&dims, rank);
            let sc = lab.student_scorer(&dims, &teacher, &student, &zeros)?;
            let before = lab.evaluate(&sc, &dims)?;
            println!(
                "{quant} W{bits} [{backend}] (no LQEC):  CSQA {:.2}%  Wiki2 {:.2}  C4 {:.2}",
                before.avg_acc * 100.0,
                before.ppl_wiki,
                before.ppl_c4
            );

            let init = lab.default_adapters(&dims, rank);
            let (ad, res) =
                lab.compensate(&dims, &teacher, &student, &init, scope, &format!("{quant}{bits}"))?;
            let sc = lab.student_scorer(&dims, &teacher, &student, &ad)?;
            let after = lab.evaluate(&sc, &dims)?;
            println!(
                "{quant} W{bits} + {scope} [{backend}] (r={rank}, {} steps, {:.1}s): \
                 CSQA {:.2}%  Wiki2 {:.2}  C4 {:.2}",
                res.steps,
                res.wall_secs,
                after.avg_acc * 100.0,
                after.ppl_wiki,
                after.ppl_c4
            );
            Ok(())
        }
        "serve-bench" => serve_bench(args),
        other => Err(anyhow!("unknown subcommand '{other}'\n{HELP}")),
    }
}

/// Native, PJRT-free serving benchmark: per-sequence scoring vs the
/// request-lifecycle engine over the same `BackendScorer`, plus decode
/// and (with `--sample`/`--stream`) sampled/streamed generation
/// sections. `--smoke` shrinks the geometry to a CI-sized sanity run.
fn serve_bench(args: &Args) -> Result<()> {
    // serving defaults to the packed W2A16 engine; --backend overrides
    let backend = match args.opt("backend") {
        Some(s) => BackendKind::parse(s)?,
        None => BackendKind::Packed,
    };
    let smoke = args.flag("smoke");
    let bits = args.opt_usize("bits")?.unwrap_or(2) as u8;
    let max_batch = args.opt_usize("batch")?.unwrap_or(8).max(1);
    let n_requests = args.opt_usize("requests")?.unwrap_or(if smoke { 12 } else { 64 }).max(1);
    let seq = args.opt_usize("seq")?.unwrap_or(if smoke { 16 } else { 64 }).max(2);
    let n_layers = args.opt_usize("layers")?.unwrap_or(if smoke { 2 } else { 4 }).max(1);
    let rank = args.opt_usize("rank")?.unwrap_or(if smoke { 2 } else { 8 });
    let dims = ModelDims {
        name: "serve-bench".into(),
        d_model: args.opt_usize("dmodel")?.unwrap_or(if smoke { 64 } else { 256 }),
        n_layers,
        n_heads: 8,
        d_ff: args.opt_usize("dff")?.unwrap_or(if smoke { 128 } else { 512 }),
        vocab: if smoke { 128 } else { 512 },
        seq,
        batch: max_batch,
        group_size: if smoke { 32 } else { 64 },
    };

    let mut rng = Rng::seed(0x5e7e);
    let teacher = TeacherParams::init(&dims, &mut rng);
    let quant = by_name("rtn", bits, dims.group_size)?;
    let student =
        StudentWeights::quantize(&dims, &teacher, quant.as_ref(), &|_, _| CalibCtx::default());
    let mut adapters = AdapterSet::zeros(&dims, rank);
    for f in 0..LINEARS.len() {
        for l in 0..dims.n_layers {
            let (di, do_) = dims.linear_dims(LINEARS[f]);
            adapters.set(
                f,
                l,
                Mat::randn(di, rank, &mut rng).scale(0.01),
                Mat::randn(do_, rank, &mut rng).scale(0.01),
            );
        }
    }
    let scorer = std::sync::Arc::new(BackendScorer::new(
        &dims,
        &teacher,
        &student,
        Some(&adapters),
        backend,
    )?);
    println!(
        "serve-bench: {backend} W{bits} r={rank}, d={} L={} seq={seq}, \
         {n_requests} ragged requests, max_batch={max_batch}, \
         resident weights {:.2} MiB",
        dims.d_model,
        dims.n_layers,
        scorer.weight_bytes() as f64 / (1 << 20) as f64
    );

    // probe_throughput generates the ragged mix, runs both paths, and
    // verifies logp parity + zero PAD-dummy forwards before reporting
    let probe = probe_throughput(scorer.clone(), n_requests, max_batch, 0x5e7e)?;
    println!(
        "per-sequence path:  {} tokens in {:.3}s  ({:.0} tok/s)",
        probe.total_tokens,
        probe.per_seq_secs,
        probe.sequential_tok_per_sec()
    );
    println!(
        "batched serve loop: {} tokens in {:.3}s  ({:.0} tok/s)",
        probe.total_tokens,
        probe.serve_secs,
        probe.batched_tok_per_sec()
    );
    println!("  {}", probe.summary);
    println!(
        "speedup: {:.2}x (batched vs per-sequence), mean batch occupancy {:.2}",
        probe.speedup(),
        probe.summary.mean_occupancy
    );

    // decode section: prefill-once + KV-cache steps vs repeated full
    // forwards (probe_decode cross-checks token/logp parity internally)
    let prompt_len = (seq / 2).max(1);
    let gen = args
        .opt_usize("gen")?
        .unwrap_or(seq - prompt_len)
        .clamp(1, seq - prompt_len);
    let dprobe = probe_decode(&scorer, prompt_len, gen, 0xdec0)?;
    println!(
        "decode: prefill {} tok in {:.3}s ({:.0} tok/s); {} generated tok — \
         incremental {:.3}s ({:.0} tok/s) vs full-recompute {:.3}s ({:.0} tok/s)",
        dprobe.prompt_tokens,
        dprobe.prefill_secs,
        dprobe.prefill_tok_per_sec(),
        dprobe.gen_tokens,
        dprobe.incremental_secs(),
        dprobe.incremental_tok_per_sec(),
        dprobe.full_secs,
        dprobe.full_tok_per_sec()
    );
    println!(
        "decode speedup: {:.2}x (prefill + incremental steps vs quadratic recompute)",
        dprobe.speedup()
    );
    println!(
        "decode KV residency: {} B resident ({:.1} B per generated token; \
         full-window capacity {} B)",
        dprobe.kv_resident_bytes,
        dprobe.kv_bytes_per_gen_token(),
        dprobe.kv_capacity_bytes
    );

    // sampling/streaming section: generation traffic through the typed
    // engine API, with a seeded-determinism cross-check
    if args.flag("sample") || args.flag("stream") {
        let sampled = args.flag("sample");
        let params = SamplingParams {
            max_new: gen,
            temperature: if sampled { 0.8 } else { 0.0 },
            top_k: if sampled { 16 } else { 0 },
            top_p: if sampled { 0.95 } else { 1.0 },
            seed: Some(0xa11ce),
            stop: Vec::new(),
        };
        // --max-active / --arena-blocks / --kv-block size the decode slots
        // and the paged KV arena; an arena below `max_active` worst-case
        // sequences exercises the preemption path under real traffic
        let max_active = args.opt_usize("max-active")?.unwrap_or(max_batch).max(1);
        let arena_blocks = args.opt_usize("arena-blocks")?.unwrap_or(0);
        let kv_block = args.opt_usize("kv-block")?.unwrap_or(0);
        let engine = Engine::start_shared(
            scorer.clone(),
            EngineConfig {
                max_batch,
                queue_capacity: max_batch * 2,
                max_active,
                prefill_chunk: (seq / 4).max(1),
                kv_block,
                arena_blocks,
                ..EngineConfig::default()
            },
        );
        let client = engine.client();
        let mut rng = Rng::seed(0x5a3);
        let prompts: Vec<Vec<u32>> = (0..4)
            .map(|_| (0..prompt_len).map(|_| rng.below(dims.vocab) as u32).collect())
            .collect();
        let t0 = std::time::Instant::now();
        // one generation streams token-by-token, the rest run concurrently
        let (stream, first) = client.generate_stream(prompts[0].clone(), params.clone())?;
        let rest: Vec<_> = prompts[1..]
            .iter()
            .map(|p| client.generate(p.clone(), params.clone()))
            .collect::<Result<_>>()?;
        let streamed: Vec<TokenEvent> = stream.collect();
        let got = first.wait()?;
        let mut n_tokens = got.tokens.len();
        for p in rest {
            n_tokens += p.wait()?.tokens.len();
        }
        let secs = t0.elapsed().as_secs_f64();
        if streamed.iter().map(|e| e.token).collect::<Vec<_>>() != got.tokens {
            return Err(anyhow!("streamed tokens diverged from the collected generation"));
        }
        // same seed, same prompt => identical generation
        let replay = client.generate(prompts[0].clone(), params.clone())?.wait()?;
        if replay.tokens != got.tokens {
            return Err(anyhow!("seeded sampling did not replay deterministically"));
        }
        let summary = engine.shutdown();
        println!(
            "{} via engine: {} generations, {n_tokens} tokens in {secs:.3}s \
             ({:.0} tok/s); streamed == collected, seeded replay identical",
            if sampled { "sampled decode (T=0.8, top-k 16, top-p 0.95)" } else { "greedy decode" },
            prompts.len(),
            n_tokens as f64 / secs.max(1e-12)
        );
        println!("  {summary}");
        // CI runs the smoke geometry with an arena sized below the
        // concurrent worst case and asserts the eviction path actually
        // ran (a preemption-free pass would silently stop covering it)
        if args.flag("expect-preemption") && summary.preemptions < 1.0 {
            return Err(anyhow!(
                "--expect-preemption: the arena never evicted a generation \
                 (arena_blocks={arena_blocks}, kv_block={kv_block})"
            ));
        }
    }

    // chaos section: the same engine under deterministic fault injection
    // (seeded Errs + delays at scheduled forward ordinals). Proves the
    // fault-tolerance invariants on real weights: every request resolves,
    // retried scores are bitwise-identical to the fault-free forward, and
    // --expect-retries gates CI on the retry path actually firing.
    if args.flag("chaos") || args.flag("expect-retries") {
        let chaos = ChaosScorer::new(scorer.clone())
            // call 1 always faults, so --expect-retries is deterministic
            .with_fault(1, Fault::Err)
            .seeded(0xc4a05, 4, 24, false);
        let engine = Engine::start_shared(
            std::sync::Arc::new(chaos),
            EngineConfig {
                max_batch,
                queue_capacity: max_batch * 2,
                prefill_chunk: (seq / 4).max(1),
                // single replica: never retire the only scorer over
                // transient injected errors — retry through them instead
                unhealthy_after: usize::MAX,
                ..EngineConfig::default()
            },
        );
        let client = engine.client();
        let mut rng = Rng::seed(0xc4a0);
        let reqs: Vec<Vec<u32>> = (0..8)
            .map(|_| (0..prompt_len.max(2)).map(|_| rng.below(dims.vocab) as u32).collect())
            .collect();
        let pendings: Vec<_> =
            reqs.iter().map(|t| client.score(t.clone())).collect::<Result<Vec<_>>>()?;
        let gens: Vec<_> = reqs[..2]
            .iter()
            .map(|p| client.generate(p.clone(), SamplingParams::greedy(gen.min(4))))
            .collect::<Result<Vec<_>>>()?;
        let budget = std::time::Duration::from_secs(60);
        let mut unresolved = 0usize;
        for (t, p) in reqs.iter().zip(pendings) {
            match p.wait_timeout(budget) {
                Ok(out) => {
                    let clean = scorer.score_batch(std::slice::from_ref(t))?;
                    let same = clean[0].len() == out.len()
                        && clean[0].iter().zip(&out).all(|(a, b)| a.to_bits() == b.to_bits());
                    if !same {
                        return Err(anyhow!(
                            "chaos: a retried score diverged from the fault-free forward"
                        ));
                    }
                }
                // a resolved Err (retries exhausted) satisfies the
                // invariant; only a hang does not
                Err(e) if format!("{e}").contains("within") => unresolved += 1,
                Err(_) => {}
            }
        }
        for g in gens {
            if let Err(e) = g.wait_timeout(budget) {
                if format!("{e}").contains("within") {
                    unresolved += 1;
                }
            }
        }
        let summary = engine.shutdown();
        println!("chaos serve (seeded faults): {summary}");
        if unresolved > 0 {
            return Err(anyhow!("chaos: {unresolved} request(s) never resolved"));
        }
        if args.flag("expect-retries") && summary.retries < 1.0 {
            return Err(anyhow!(
                "--expect-retries: no injected fault was retried (summary: {summary})"
            ));
        }
    }

    // shared-prefix section: cross-request KV reuse through the radix
    // prefix index (--chaos re-runs the same workload under injected
    // faults — the cache must stay bitwise-invisible through retries)
    if let Some(shared) = args.opt_usize("shared-prefix")? {
        if shared > 0 {
            shared_prefix_bench(args, &scorer, &dims, shared, gen)?;
        }
    }

    // trace section: seeded bursty multi-tenant overload through the
    // admission-control + load-aware-dispatch stack, self-asserting the
    // overload-robustness invariants (see trace_bench)
    if let Some(kind) = args.opt("trace") {
        trace_bench(args, &scorer, &dims, kind)?;
    }
    Ok(())
}

/// The `--shared-prefix=<n>` serve-bench section: a seeded request mix
/// sharing an n-token system prompt, answered through the engine's
/// cross-request radix prefix cache. The first request prefills the
/// shared prompt cold and publishes its committed blocks; every later
/// shared request attaches them and forwards only its own suffix. Each
/// generation is cross-checked **bitwise** against the quadratic
/// full-recompute decode, and the run fails unless prefix hits fired,
/// tokens were actually saved, and zero pinned blocks survive shutdown
/// (the refcount-leak canary). With `--chaos` the same workload repeats
/// under seeded fault injection.
// lint: allow(indexing) — `modes` is a fixed 1- or 2-element literal
fn shared_prefix_bench(
    args: &Args,
    scorer: &std::sync::Arc<BackendScorer>,
    dims: &ModelDims,
    shared: usize,
    gen: usize,
) -> Result<()> {
    use rilq::eval::scorer::greedy_decode_recompute;
    let seq = dims.seq;
    if shared + 2 > seq {
        return Err(anyhow!(
            "--shared-prefix={shared} leaves no room for a request suffix \
             in the model window of {seq}"
        ));
    }
    // whole blocks are the sharing unit: the shared prompt must span at
    // least one block or there is nothing to reuse
    let kv_block = match args.opt_usize("kv-block")? {
        Some(n) if n > 0 => n,
        _ => 4.min(shared),
    };
    if shared < kv_block {
        return Err(anyhow!(
            "--shared-prefix={shared} is below the KV block size {kv_block}: \
             no whole block is shareable"
        ));
    }
    let max_batch = args.opt_usize("batch")?.unwrap_or(8).max(1);
    let prompt_len = shared + 2;
    let max_new = gen.clamp(1, seq - prompt_len + 1);
    let n_shared_reqs = 5usize;
    let n_cold = 3usize;
    let cfg = EngineConfig {
        max_batch,
        queue_capacity: (n_shared_reqs + n_cold + 1) * 2,
        max_active: max_batch,
        prefill_chunk: kv_block,
        kv_block,
        // single replica (chaos injects transient Errs): retry through
        unhealthy_after: usize::MAX,
        ..EngineConfig::default()
    };

    let modes: &[bool] = if args.flag("chaos") { &[false, true] } else { &[false] };
    for &chaos in modes {
        let engine = if chaos {
            let cs = ChaosScorer::new(scorer.clone())
                // call 1 always faults, so the retry assertion below is
                // deterministic
                .with_fault(1, Fault::Err)
                .seeded(0x9afe, 4, 24, false);
            Engine::start_shared(std::sync::Arc::new(cs), cfg.clone())
        } else {
            Engine::start_shared(scorer.clone(), cfg.clone())
        };
        // identical seeded workload in both modes
        let mut rng = Rng::seed(0x5ea9);
        let sys: Vec<u32> = (0..shared).map(|_| rng.below(dims.vocab) as u32).collect();
        let suffix =
            |rng: &mut Rng| -> Vec<u32> { (0..2).map(|_| rng.below(dims.vocab) as u32).collect() };
        let warm: Vec<u32> = sys.iter().copied().chain(suffix(&mut rng)).collect();
        let shared_reqs: Vec<Vec<u32>> = (0..n_shared_reqs)
            .map(|_| sys.iter().copied().chain(suffix(&mut rng)).collect())
            .collect();
        let colds: Vec<Vec<u32>> = (0..n_cold)
            .map(|_| (0..prompt_len).map(|_| rng.below(dims.vocab) as u32).collect())
            .collect();

        let client = engine.client();
        let params = SamplingParams::greedy(max_new);
        let t0 = std::time::Instant::now();
        // the warm request prefills the shared prompt cold; completing
        // its prefill publishes the committed blocks into the index, so
        // it is awaited before the mixed shared/cold wave goes in
        let got_warm = client.generate(warm.clone(), params.clone())?.wait()?;
        let pendings: Vec<_> = shared_reqs
            .iter()
            .chain(&colds)
            .map(|p| client.generate(p.clone(), params.clone()))
            .collect::<Result<Vec<_>>>()?;
        let mut answers = vec![(warm.clone(), got_warm)];
        for (p, pend) in shared_reqs.iter().chain(&colds).zip(pendings) {
            answers.push((p.clone(), pend.wait()?));
        }
        let secs = t0.elapsed().as_secs_f64();
        let summary = engine.shutdown();
        let tag = if chaos { " (chaos)" } else { "" };

        // bitwise parity: a cache-hit generation must be
        // indistinguishable from a cold one
        for (prompt, got) in &answers {
            let (toks, lps) = greedy_decode_recompute(scorer, prompt, max_new)?;
            if got.tokens != toks
                || got.logps.len() != lps.len()
                || got.logps.iter().zip(&lps).any(|(a, b)| a.to_bits() != b.to_bits())
            {
                return Err(anyhow!(
                    "shared-prefix{tag}: a cached-prefix generation diverged \
                     from the full-recompute decode"
                ));
            }
        }
        println!(
            "shared-prefix{tag}: {} requests ({} sharing a {shared}-token \
             system prompt, {n_cold} cold) in {secs:.3}s — bitwise equal \
             to full recompute",
            answers.len(),
            n_shared_reqs + 1
        );
        println!("  {summary}");
        if summary.prefix_hits < 1.0 || summary.prefix_tokens_saved < 1.0 {
            return Err(anyhow!(
                "--shared-prefix{tag}: the prefix cache never fired \
                 ({} hits, {} tokens saved)",
                summary.prefix_hits,
                summary.prefix_tokens_saved
            ));
        }
        if summary.kv_blocks_pinned != 0.0 {
            return Err(anyhow!(
                "--shared-prefix{tag}: {} KV blocks still pinned after \
                 shutdown (prefix refcount leak)",
                summary.kv_blocks_pinned
            ));
        }
        if chaos && summary.retries < 1.0 {
            return Err(anyhow!(
                "--shared-prefix --chaos: no injected fault was retried"
            ));
        }
    }
    Ok(())
}

/// The `--trace={burst|poisson}` serve-bench section: a seeded
/// two-tenant workload (paid/High at ~15% of arrivals, free/Low the
/// rest) driven through the typed engine API — once strictly
/// sequentially for an uncontended SLO baseline, then as a 2×-rate
/// flood against a deliberately tight two-replica fleet with watermark
/// shedding, brownout, and load-aware dispatch enabled. Self-asserts
/// the overload-robustness acceptance bar:
///
/// * the same seed regenerates the identical trace and the identical
///   virtual-time admission decisions, bit-for-bit;
/// * every submission resolves into exactly one outcome counter (no
///   hangs, no double counts);
/// * shedding never touches the high-priority class — structurally:
///   the queue is sized so the watermark strictly exceeds the paid
///   class's total event count, so an over-watermark paid arrival
///   always finds a free-tier victim to displace;
/// * high-priority p99 TTFT stays within 2× the uncontended baseline
///   (floored at 50 ms: at CI's smoke geometry the absolute numbers
///   sit at scheduler-jitter scale — the relative bound is what binds
///   at real geometry);
/// * both replicas' KV arenas drain to zero after shutdown.
///
/// `--expect-shedding` additionally fails the run if the overload never
/// shed anything — a silently oversized queue would stop covering the
/// admission-control path at all.
fn trace_bench(
    args: &Args,
    scorer: &std::sync::Arc<BackendScorer>,
    dims: &ModelDims,
    kind: &str,
) -> Result<()> {
    use rilq::engine::{
        generate_trace, replay_trace, Arrivals, BoundedPareto, Decision, OverloadSim, Priority,
        SimConfig, SubmitOptions, TenantClass, TraceConfig,
    };

    let seq = dims.seq;
    let max_batch = args.opt_usize("batch")?.unwrap_or(8).max(1);
    let cfg_for = |mult: f64| -> Result<TraceConfig> {
        let arrivals = match kind {
            "poisson" => Arrivals::Poisson { rate: 24.0 * mult },
            "burst" => Arrivals::OnOff {
                on_rate: 30.0 * mult,
                off_rate: 2.0 * mult,
                on_secs: 1.5,
                off_secs: 1.5,
            },
            other => return Err(anyhow!("--trace={other}: expected 'burst' or 'poisson'")),
        };
        Ok(TraceConfig {
            seed: 0x7ace,
            duration_secs: 6.0,
            arrivals,
            tenants: vec![
                TenantClass { name: "paid".into(), priority: Priority::High, weight: 0.15 },
                TenantClass { name: "free".into(), priority: Priority::Low, weight: 0.85 },
            ],
            // prompt.hi + gen.hi stays inside the model window, so no
            // trace event can fail request validation
            prompt: BoundedPareto { alpha: 1.3, lo: 3, hi: (seq / 2).max(3) },
            gen: BoundedPareto { alpha: 1.5, lo: 1, hi: (seq - seq / 2 - 1).max(1) },
            vocab: dims.vocab,
        })
    };

    // layers 1+2: "the same seed replays to identical admission/shed/
    // route decisions" — pure functions of (config, trace), so the
    // acceptance criterion is assertable as plain Vec equality before
    // any thread is involved
    let trace = generate_trace(&cfg_for(2.0)?);
    if trace != generate_trace(&cfg_for(2.0)?) {
        return Err(anyhow!("--trace: generate_trace is not a pure function of its config"));
    }
    let sim = OverloadSim::new(SimConfig {
        n_replicas: 2,
        queue_cap: 16,
        shed_watermark: 0.75,
        tenant_rate: 6.0,
        tenant_burst: 4.0,
        service_rate: 12.0,
    });
    let decisions = sim.run(&trace);
    if decisions != sim.run(&trace) {
        return Err(anyhow!("--trace: OverloadSim decisions are not deterministic"));
    }
    let paid_total = trace.iter().filter(|e| e.priority == Priority::High).count();
    let sheds_sim = decisions
        .iter()
        .filter(|d| matches!(d, Decision::ShedArrival { .. } | Decision::Displace { .. }))
        .count();
    let limited_sim =
        decisions.iter().filter(|d| matches!(d, Decision::RateLimited { .. })).count();
    println!(
        "trace [{kind}] 2x overload: {} events ({paid_total} paid/high); sim mirror \
         {sheds_sim} watermark sheds, {limited_sim} rate-limited — bit-for-bit replayable",
        trace.len()
    );

    let replicas: Vec<std::sync::Arc<dyn Scorer + Send + Sync>> =
        vec![scorer.clone(), scorer.clone()];
    // uncontended baseline: the 1x trace served strictly sequentially —
    // every TTFT is pure prefill against an empty queue
    let base_engine = Engine::start_balanced(
        replicas.clone(),
        EngineConfig {
            max_batch,
            queue_capacity: 64,
            prefill_chunk: (seq / 4).max(1),
            ..EngineConfig::default()
        },
    );
    let client = base_engine.client();
    for ev in generate_trace(&cfg_for(1.0)?).iter().take(24) {
        client
            .generate_with(
                ev.prompt.clone(),
                SamplingParams::greedy(ev.max_new.max(1)),
                &SubmitOptions::default().priority(ev.priority).tenant(ev.tenant.clone()),
            )?
            .wait()?;
    }
    let base = base_engine.shutdown();
    let base_ttft = base.ttft_p99_secs.unwrap_or(0.0);

    // the overload fleet: watermark + brownout on, and the queue sized
    // so the watermark strictly exceeds the paid class's total event
    // count — an over-watermark paid arrival then always finds a
    // free-tier victim to displace, making "the high class is never
    // shed" a structural guarantee rather than a timing accident
    let queue_cap = ((paid_total + 4) * 4 / 3 + 1).max(16);
    let engine = Engine::start_balanced(
        replicas,
        EngineConfig {
            max_batch,
            queue_capacity: queue_cap,
            prefill_chunk: (seq / 4).max(1),
            shed_watermark: 0.75,
            brownout_backlog: (queue_cap / 2).max(1),
            brownout_after: 2,
            brownout_max_new: 2,
            ..EngineConfig::default()
        },
    );
    let client = engine.client();
    let outcome = replay_trace(&client, &trace, 0.0, None);
    let arenas: Vec<_> = engine.arenas().to_vec();
    let over = engine.shutdown();

    let paid = outcome.tenant("paid");
    let free = outcome.tenant("free");
    let over_ttft = over.ttft_high_p99_secs.unwrap_or(0.0);
    println!(
        "trace overload: paid {}/{} ok ({} shed), free {}/{} ok ({} shed), \
         {} goodput tokens; high p99 TTFT {:.1}ms vs {:.1}ms uncontended",
        paid.ok,
        paid.submitted,
        paid.shed,
        free.ok,
        free.submitted,
        free.shed,
        outcome.total(|t| t.tokens),
        over_ttft * 1e3,
        base_ttft * 1e3
    );
    println!("  {over}");

    if !outcome.fully_resolved() {
        return Err(anyhow!("--trace: a submission resolved into zero or two outcome counters"));
    }
    if paid.shed != 0 || paid.rate_limited != 0 || over.overload_sheds_high != 0.0 {
        return Err(anyhow!(
            "--trace: the overload rejected {} high-priority request(s) \
             (counter {}); shedding must hit the low class first",
            paid.shed + paid.rate_limited,
            over.overload_sheds_high
        ));
    }
    if paid.ok == 0 {
        return Err(anyhow!("--trace: no high-priority request completed under overload"));
    }
    for (i, a) in arenas.iter().enumerate() {
        if a.blocks_in_use() != 0 {
            return Err(anyhow!(
                "--trace: replica {i} leaked {} KV arena block(s) through the overload",
                a.blocks_in_use()
            ));
        }
    }
    let limit = (2.0 * base_ttft).max(0.05);
    if over_ttft > limit {
        return Err(anyhow!(
            "--trace: high-priority p99 TTFT degraded {:.1}ms -> {:.1}ms under \
             2x overload (limit {:.1}ms)",
            base_ttft * 1e3,
            over_ttft * 1e3,
            limit * 1e3
        ));
    }
    if args.flag("expect-shedding") && over.overload_sheds < 1.0 {
        return Err(anyhow!(
            "--trace --expect-shedding: the 2x overload never shed \
             (queue_capacity={queue_cap}, watermark=0.75 — the admission \
             path went uncovered)"
        ));
    }
    Ok(())
}

const HELP: &str = "\
rilq — RILQ (AAAI 2025) reproduction: rank-insensitive LoRA-based
quantization error compensation for 2-bit LLMs, on a Rust + JAX + Pallas
(AOT via PJRT) stack.

USAGE:
  rilq list                           list all paper-table experiments
  rilq experiment <id>|all [--fast]   regenerate a table/figure -> reports/
  rilq pretrain <config> [--steps=N]  pretrain + cache a teacher model
  rilq eval <config> [--quant=rtn --bits=2 --rank=16 --scope=model_gt] [--fast]
                     [--backend={dense|packed|merged}]
                                      dense  = f32 dequant (HLO artifact when lowered)
                                      packed = fused packed-2-bit + LoRA serving engine
                                      merged = adapter-merged dense (parity oracle)
  rilq serve-bench [--backend={dense|packed|merged} --bits=2 --batch=8
                    --requests=64 --seq=64 --layers=4 --rank=8 --gen=N
                    --max-active=N --arena-blocks=N --kv-block=N
                    --sample --stream --expect-preemption
                    --shared-prefix=N
                    --trace={burst|poisson} --expect-shedding
                    --chaos --expect-retries --smoke]
                                      native engine serving benchmark:
                                      per-sequence vs coalesced ragged
                                      batches on one BackendScorer, a
                                      KV-cache decode section (prefill-once
                                      + incremental steps vs quadratic full
                                      recompute; --gen sets the generation
                                      length), and with --sample/--stream a
                                      sampled (T/top-k/top-p, seeded) or
                                      token-streamed generation section
                                      through the typed Engine API.
                                      --max-active sizes the decode slots,
                                      --kv-block/--arena-blocks the paged
                                      KV arena (0 = auto worst case); an
                                      undersized arena exercises eviction
                                      + bit-exact resume, and
                                      --expect-preemption fails the run if
                                      no eviction happened;
                                      --shared-prefix=N runs a request mix
                                      sharing an N-token system prompt
                                      through the cross-request prefix
                                      cache: later requests attach the
                                      cached KV blocks and prefill only
                                      their suffix (verified bitwise vs
                                      full recompute; fails unless hits
                                      fired, tokens were saved, and no
                                      pinned block survives shutdown);
                                      --trace={burst|poisson} replays a
                                      seeded two-tenant workload (Poisson
                                      or ON-OFF bursty arrivals, bounded-
                                      Pareto lengths) at 2x overload
                                      through tenant-aware admission
                                      control and load-aware dispatch:
                                      asserts bit-for-bit trace/decision
                                      replay, every submission resolves,
                                      shedding hits the low class only,
                                      high-priority p99 TTFT within 2x
                                      the uncontended baseline, and the
                                      arenas drain; --expect-shedding
                                      fails the run if nothing was shed;
                                      --chaos re-runs the engine under
                                      seeded fault injection (scheduled
                                      Errs/delays) and verifies every
                                      request resolves with retried scores
                                      bitwise-equal to the clean forward;
                                      --expect-retries (implies --chaos)
                                      additionally fails the run if no
                                      fault was retried;
                                      --smoke shrinks geometry for CI
                                      (PJRT-free; no artifacts needed)
  rilq inspect                        artifact / config inventory
  (global) --artifacts=DIR            artifact directory [default: artifacts]
";

fn init_logger() {
    struct L;
    impl log::Log for L {
        fn enabled(&self, m: &log::Metadata) -> bool {
            m.level() <= log::Level::Info
        }
        fn log(&self, r: &log::Record) {
            if self.enabled(r.metadata()) {
                eprintln!("[{}] {}", r.level().as_str().to_lowercase(), r.args());
            }
        }
        fn flush(&self) {}
    }
    let _ = log::set_logger(&L).map(|_| log::set_max_level(log::LevelFilter::Info));
}
