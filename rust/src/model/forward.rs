//! Pure-Rust reference forward pass.
//!
//! Mirrors `python/compile/model.py` op-for-op (RMSNorm → RoPE attention →
//! SwiGLU, residual stream, final norm, LM head) so it can serve as the
//! numerical oracle for the AOT artifacts (integration tests compare the
//! two to ~1e-3) and as a PJRT-free evaluation path for quantizer studies.

use std::cell::RefCell;

use anyhow::{bail, ensure, Result};

use crate::tensor::{kernels, Mat};

use super::backend::LinearBackend;
use super::kv::{KvCache, RopeTable};
use super::{ModelDims, StudentWeights, TeacherParams, LINEARS};

const EPS: f32 = 1e-6;

/// Per-layer activation captures (the teacher-side inputs each linear
/// family sees; used for Linear-Loss studies and GPTQ calibration).
#[derive(Clone, Debug)]
pub struct LayerTrace {
    /// input to wq/wk/wv: `[S, d]`
    pub x_attn: Mat,
    /// input to wo: `[S, d]`
    pub att: Mat,
    /// input to wg/wu: `[S, d]`
    pub x_ffn: Mat,
    /// input to wd: `[S, f]`
    pub mid: Mat,
    /// residual stream after the layer: `[S, d]`
    pub layer_out: Mat,
}

/// Full forward trace of one sequence.
#[derive(Clone, Debug)]
pub struct Trace {
    pub layers: Vec<LayerTrace>,
    /// post-final-RMSNorm hidden states `[S, d]`
    pub hidden: Mat,
    /// `[S, V]`
    pub logits: Mat,
}

/// Weight view used by the forward pass. Linears are [`LinearBackend`]
/// trait objects, so the fp teacher (plain `Mat`s), dense-dequantized
/// students, and the fused packed+LoRA serving engine all share one
/// forward implementation — the execution form is chosen where the view
/// is built, not inside the model code.
pub struct WeightView<'a> {
    pub linears: Vec<Vec<&'a dyn LinearBackend>>, // [family][layer]
    pub embed: &'a Mat,
    pub ln1: &'a [Vec<f32>],
    pub ln2: &'a [Vec<f32>],
    pub fnorm: &'a [f32],
    pub head: &'a Mat,
}

impl TeacherParams {
    pub fn view(&self) -> WeightView<'_> {
        WeightView {
            linears: self
                .linears
                .iter()
                .map(|ls| ls.iter().map(|m| m as &dyn LinearBackend).collect())
                .collect(),
            embed: &self.embed,
            ln1: &self.ln1,
            ln2: &self.ln2,
            fnorm: &self.fnorm,
            head: &self.head,
        }
    }

    /// View with linears replaced by dense student weights
    /// (`Q_l + A Bᵀ` must be materialized by the caller if adapters are
    /// in play — see [`crate::lqec::AdapterSet::merge_into`]).
    pub fn view_with<'a>(&'a self, dense: &'a [Vec<Mat>]) -> WeightView<'a> {
        WeightView {
            linears: dense
                .iter()
                .map(|ls| ls.iter().map(|m| m as &dyn LinearBackend).collect())
                .collect(),
            embed: &self.embed,
            ln1: &self.ln1,
            ln2: &self.ln2,
            fnorm: &self.fnorm,
            head: &self.head,
        }
    }

    /// View with linears replaced by an execution engine built with
    /// [`super::backend::student_backends`] (embed/norms/head stay fp —
    /// the paper quantizes only the seven linear families).
    pub fn view_backends<'a>(
        &'a self,
        linears: &'a [Vec<Box<dyn LinearBackend>>],
    ) -> WeightView<'a> {
        WeightView {
            linears: linears
                .iter()
                .map(|ls| ls.iter().map(|b| b.as_ref()).collect())
                .collect(),
            embed: &self.embed,
            ln1: &self.ln1,
            ln2: &self.ln2,
            fnorm: &self.fnorm,
            head: &self.head,
        }
    }
}

// lint: allow(indexing) — column loop is bounded by the row length
fn rmsnorm(x: &Mat, g: &[f32]) -> Mat {
    let mut out = Mat::zeros(x.rows(), x.cols());
    for r in 0..x.rows() {
        let row = x.row(r);
        let ms: f32 = kernels::dot(row, row) / row.len() as f32;
        let inv = 1.0 / (ms + EPS).sqrt();
        let orow = out.row_mut(r);
        for c in 0..row.len() {
            orow[c] = row[c] * inv * g[c];
        }
    }
    out
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// RoPE rotation applied in place on a `[S, hd]` head slice, position =
/// row index. Kept for unit tests / external callers; the forward paths
/// use the shared [`RopeTable`] directly.
// lint: allow(indexing) — `hd <= cols` is the documented contract of this helper
pub fn apply_rope(x: &mut Mat, hd: usize) {
    let rope = RopeTable::shared(x.rows().max(1), hd);
    for s in 0..x.rows() {
        rope.rotate(&mut x.row_mut(s)[..hd], s);
    }
}

thread_local! {
    // Attention scratch reused across calls/layers/heads: the rotated query
    // head (`head_dim` wide) and the per-position score row. Both are fully
    // overwritten before every use (`copy_from_slice` / `clear`+`resize`),
    // so reuse cannot change any computed bit, and `attend_cached` never
    // re-enters itself on a thread, so the borrow is exclusive.
    static ATTN_SCRATCH: RefCell<(Vec<f32>, Vec<f32>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
}

/// The shared causal-attention row kernel: `new` query rows at absolute
/// positions `past..past+new` attend over `past+new` key/value rows
/// presented as ordered **segments** — for each head, `segs_per_head`
/// consecutive `(k, v)` slice pairs of whole `head_dim` rows covering
/// ascending positions (a paged [`KvCache`]'s blocks via
/// `KvCache::layer_segments`, or one transient full-sequence segment per
/// head built by [`attention`]). K rows are already rotated; Q rows are
/// rotated here into one small scratch reused across heads — no per-head
/// matrix gathers are allocated.
///
/// Per-row math (ascending-position score loop, max-subtracted softmax,
/// the `w == 0` skip) is independent of how positions are cut into
/// segments, and the Q·K dots / weighted-V accumulations run on the
/// 8-wide unrolled [`kernels::dot`] / [`kernels::axpy`] primitives —
/// whose per-row reduction order is fixed (see `tensor::kernels`) — so
/// paged, contiguous, full, and incremental forwards all produce
/// bitwise-identical rows.
// lint: hot — the per-token attention kernel; all scratch is thread-local
// lint: allow(indexing) — head offsets and score positions are loop-bounded
// by construction (j <= pos < scores.len(), hoff+hd <= cols)
fn attend_cached(
    dims: &ModelDims,
    rope: &RopeTable,
    q: &Mat,
    segs: &[(&[f32], &[f32])],
    segs_per_head: usize,
    past: usize,
    out: &mut Mat,
) {
    let new = q.rows();
    if new == 0 {
        return;
    }
    let (h, hd) = (dims.n_heads, dims.head_dim());
    let scale = 1.0 / (hd as f32).sqrt();
    ATTN_SCRATCH.with(|cell| {
        let mut scratch = cell.borrow_mut();
        let (qh, scores) = &mut *scratch;
        qh.clear();
        qh.resize(hd, 0.0);
        for head in 0..h {
            let hoff = head * hd;
            let hsegs = &segs[head * segs_per_head..(head + 1) * segs_per_head];
            for i in 0..new {
                let pos = past + i;
                qh.copy_from_slice(&q.row(i)[hoff..hoff + hd]);
                rope.rotate(qh, pos);
                // causal: position pos attends to 0..=pos, walking the
                // segments in ascending-position order
                scores.clear();
                scores.resize(pos + 1, 0.0);
                let mut maxs = f32::NEG_INFINITY;
                let mut j = 0usize;
                'kseg: for (ks, _) in hsegs {
                    for krow in ks.chunks_exact(hd) {
                        if j > pos {
                            break 'kseg;
                        }
                        let sc = kernels::dot(qh, krow) * scale;
                        scores[j] = sc;
                        maxs = maxs.max(sc);
                        j += 1;
                    }
                }
                debug_assert!(j > pos, "kv segments shorter than attended span");
                let mut denom = 0.0f32;
                for sc in scores.iter_mut() {
                    *sc = (*sc - maxs).exp();
                    denom += *sc;
                }
                let orow = &mut out.row_mut(i)[hoff..hoff + hd];
                let mut j = 0usize;
                'vseg: for (_, vs) in hsegs {
                    for vrow in vs.chunks_exact(hd) {
                        if j > pos {
                            break 'vseg;
                        }
                        let w = scores[j] / denom;
                        j += 1;
                        if w == 0.0 {
                            continue;
                        }
                        kernels::axpy(w, vrow, orow);
                    }
                }
            }
        }
    });
}

/// Causal multi-head attention over `[S, d]` projections (no cache): K is
/// rotated once into a transient head-major buffer, then the shared
/// kernel runs with `past == 0` and one full-sequence segment per head.
// lint: allow(indexing) — head-major offsets are loop-bounded by the buffer size
fn attention(dims: &ModelDims, rope: &RopeTable, q: &Mat, k: &Mat, v: &Mat) -> Mat {
    let s = q.rows();
    let (h, hd) = (dims.n_heads, dims.head_dim());
    let mut kbuf = vec![0.0f32; h * s * hd];
    let mut vbuf = vec![0.0f32; h * s * hd];
    for r in 0..s {
        let krow = k.row(r);
        let vrow = v.row(r);
        for head in 0..h {
            let off = (head * s + r) * hd;
            kbuf[off..off + hd].copy_from_slice(&krow[head * hd..(head + 1) * hd]);
            rope.rotate(&mut kbuf[off..off + hd], r);
            vbuf[off..off + hd].copy_from_slice(&vrow[head * hd..(head + 1) * hd]);
        }
    }
    let segs: Vec<(&[f32], &[f32])> = (0..h)
        .map(|head| {
            let o = head * s * hd;
            (&kbuf[o..o + s * hd], &vbuf[o..o + s * hd])
        })
        .collect();
    let mut out = Mat::zeros(s, dims.d_model);
    attend_cached(dims, rope, q, &segs, 1, 0, &mut out);
    out
}

/// Forward one token sequence through a weight view, capturing activations.
// lint: allow(indexing) — family/layer/row indices are loop-bounded over
// shapes fixed at model construction
pub fn forward_trace(dims: &ModelDims, w: &WeightView<'_>, tokens: &[u32]) -> Trace {
    let s = tokens.len();
    // lint: allow(panic) — calibration entry point; serving callers validate
    // via Scorer::check_seq before any forward (doc contract)
    assert!(s <= dims.seq, "sequence longer than model seq");
    // lint: allow(panic) — membership in the static LINEARS table
    let fam = |name: &str| LINEARS.iter().position(|&n| n == name).unwrap();
    let (iq, ik, iv, io) = (fam("wq"), fam("wk"), fam("wv"), fam("wo"));
    let (ig, iu, id) = (fam("wg"), fam("wu"), fam("wd"));
    let rope = RopeTable::shared(dims.seq, dims.head_dim());

    let mut h = Mat::from_fn(s, dims.d_model, |r, c| w.embed[(tokens[r] as usize, c)]);
    let mut layers = Vec::with_capacity(dims.n_layers);

    for l in 0..dims.n_layers {
        let x1 = rmsnorm(&h, &w.ln1[l]);
        let q = w.linears[iq][l].forward(&x1);
        let k = w.linears[ik][l].forward(&x1);
        let v = w.linears[iv][l].forward(&x1);
        let att = attention(dims, &rope, &q, &k, &v);
        h = h.add(&w.linears[io][l].forward(&att));
        let x2 = rmsnorm(&h, &w.ln2[l]);
        let mut g = w.linears[ig][l].forward(&x2);
        g.map_inplace(silu);
        let u = w.linears[iu][l].forward(&x2);
        let mid = g.zip(&u, |a, b| a * b);
        h = h.add(&w.linears[id][l].forward(&mid));
        layers.push(LayerTrace {
            x_attn: x1,
            att,
            x_ffn: x2,
            mid,
            layer_out: h.clone(),
        });
    }

    let hidden = rmsnorm(&h, w.fnorm);
    let logits = LinearBackend::forward(w.head, &hidden);
    Trace { layers, hidden, logits }
}

/// Multi-sequence forward: runs every sequence of a (possibly ragged)
/// batch through each [`LinearBackend::forward`] as **one**
/// `[Σ len_i, d_model]` activation matrix, so per-call costs — pool
/// dispatch, packed group-tile dequantization, cache warming of the
/// weight stream — are paid once per layer instead of once per sequence.
/// Only attention (position-dependent: RoPE + causal mask) runs
/// per-sequence, on row slices of the shared activation buffer.
///
/// Returns one `[len_i, V]` logits matrix per input sequence. Per-row
/// kernels are independent of neighboring rows, so each sequence's
/// logits are bitwise identical to a per-sequence [`forward_trace`]
/// (pinned by `tests/backend_parity.rs`). Layer activations are not
/// captured — calibration traces go through `forward_trace`.
///
/// Panics if a sequence exceeds `dims.seq`; serving-path callers
/// validate first and surface `Err` (see `eval::Scorer::score_all`).
// lint: allow(indexing) — per-sequence offsets are accumulated from the
// input lengths; family/layer indices are loop-bounded
pub fn forward_trace_batch(dims: &ModelDims, w: &WeightView<'_>, seqs: &[Vec<u32>]) -> Vec<Mat> {
    if seqs.is_empty() {
        return Vec::new();
    }
    for s in seqs {
        // lint: allow(panic) — doc contract above: serving callers validate
        // and surface Err before reaching this batch entry point
        assert!(s.len() <= dims.seq, "sequence longer than model seq");
    }
    // lint: allow(panic) — membership in the static LINEARS table
    let fam = |name: &str| LINEARS.iter().position(|&n| n == name).unwrap();
    let (iq, ik, iv, io) = (fam("wq"), fam("wk"), fam("wv"), fam("wo"));
    let (ig, iu, id) = (fam("wg"), fam("wu"), fam("wd"));
    let rope = RopeTable::shared(dims.seq, dims.head_dim());

    // row offsets of each sequence inside the coalesced activation matrix
    let mut offsets = Vec::with_capacity(seqs.len());
    let mut total = 0usize;
    for s in seqs {
        offsets.push(total);
        total += s.len();
    }

    let d = dims.d_model;
    let mut h = Mat::zeros(total, d);
    for (si, seq) in seqs.iter().enumerate() {
        for (p, &tok) in seq.iter().enumerate() {
            let row = h.row_mut(offsets[si] + p);
            let erow = w.embed.row(tok as usize);
            row.copy_from_slice(erow);
        }
    }

    for l in 0..dims.n_layers {
        let x1 = rmsnorm(&h, &w.ln1[l]);
        let q = w.linears[iq][l].forward(&x1);
        let k = w.linears[ik][l].forward(&x1);
        let v = w.linears[iv][l].forward(&x1);
        // attention is the only position-dependent op: per-sequence slices
        let mut att = Mat::zeros(total, d);
        for (si, seq) in seqs.iter().enumerate() {
            let s = seq.len();
            if s == 0 {
                continue;
            }
            let off = offsets[si];
            let a = attention(
                dims,
                &rope,
                &q.block(off, 0, s, d),
                &k.block(off, 0, s, d),
                &v.block(off, 0, s, d),
            );
            att.set_block(off, 0, &a);
        }
        h = h.add(&w.linears[io][l].forward(&att));
        let x2 = rmsnorm(&h, &w.ln2[l]);
        let mut g = w.linears[ig][l].forward(&x2);
        g.map_inplace(silu);
        let u = w.linears[iu][l].forward(&x2);
        let mid = g.zip(&u, |a, b| a * b);
        h = h.add(&w.linears[id][l].forward(&mid));
    }

    let hidden = rmsnorm(&h, w.fnorm);
    let logits = LinearBackend::forward(w.head, &hidden);
    seqs.iter()
        .enumerate()
        .map(|(si, seq)| logits.block(offsets[si], 0, seq.len(), dims.vocab))
        .collect()
}

/// Validate that a cached forward of `new_tokens` fits the cache and the
/// vocabulary; shared by the single-sequence and batched entry points.
fn check_cache_step(
    dims: &ModelDims,
    cache: &KvCache,
    new_tokens: &[u32],
    seq_idx: usize,
) -> Result<()> {
    ensure!(
        cache.matches(dims),
        "sequence {seq_idx}: KV cache geometry does not match the model \
         (cache capacity {}, model seq {})",
        cache.capacity(),
        dims.seq
    );
    if cache.len() + new_tokens.len() > dims.seq {
        bail!(
            "sequence {seq_idx}: {} cached + {} new tokens exceed the model window of {}",
            cache.len(),
            new_tokens.len(),
            dims.seq
        );
    }
    if let Some(&t) = new_tokens.iter().find(|&&t| t as usize >= dims.vocab) {
        bail!("sequence {seq_idx}: token id {t} outside the vocabulary of {}", dims.vocab);
    }
    Ok(())
}

/// Incremental forward: push only `new_tokens` (absolute positions
/// `cache.len()..cache.len()+new`) through every linear, attending over
/// the cached K/V planes, and extend the cache. With an empty cache this
/// is the *prefill* and produces logits bitwise identical to
/// [`forward_trace`]; afterwards each call costs O(new) linear rows
/// instead of re-running the whole sequence.
///
/// Returns the `[new, V]` logits of the new positions (an empty matrix
/// for a 0-token suffix, cache untouched). Errs — never panics — when
/// the step would overflow the model window, a token id is out of
/// vocabulary, or the cache was built for a different geometry.
///
/// A "cold" cache here may already hold positions it never computed:
/// the engine's cross-request prefix cache attaches runs of **whole
/// committed blocks** from an earlier request of the same prompt (see
/// `engine::prefix` and [`KvCache::attach_prefix`]). Because committed
/// K/V planes are a pure function of the token prefix (chunked ==
/// one-shot, K rotated by absolute position) and [`attend_cached`]
/// walks segments by ascending absolute position regardless of block
/// ownership, a suffix forward over an attached prefix is bitwise
/// identical to re-prefilling the whole prompt. Any partially-filled
/// boundary block is never shared — the tail past the last whole block
/// is re-prefilled privately into freshly reserved blocks, so this
/// function only ever appends into blocks the cache exclusively owns
/// (copy-on-write, enforced by the arena's refcounts).
// lint: allow(indexing) — token rows validated by check_cache_step; family
// and layer indices are loop-bounded
pub fn forward_trace_with_cache(
    dims: &ModelDims,
    w: &WeightView<'_>,
    new_tokens: &[u32],
    cache: &mut KvCache,
) -> Result<Mat> {
    check_cache_step(dims, cache, new_tokens, 0)?;
    let n = new_tokens.len();
    if n == 0 {
        return Ok(Mat::zeros(0, dims.vocab));
    }
    // take the arena blocks for the new positions up front: an `Err`
    // (arena exhausted) leaves the cache untouched
    cache.reserve(n)?;
    // lint: allow(panic) — membership in the static LINEARS table
    let fam = |name: &str| LINEARS.iter().position(|&nm| nm == name).unwrap();
    let (iq, ik, iv, io) = (fam("wq"), fam("wk"), fam("wv"), fam("wo"));
    let (ig, iu, id) = (fam("wg"), fam("wu"), fam("wd"));
    let rope = RopeTable::shared(dims.seq, dims.head_dim());
    let past = cache.len();

    let mut h = Mat::from_fn(n, dims.d_model, |r, c| w.embed[(new_tokens[r] as usize, c)]);
    for l in 0..dims.n_layers {
        let x1 = rmsnorm(&h, &w.ln1[l]);
        let q = w.linears[iq][l].forward(&x1);
        let k = w.linears[ik][l].forward(&x1);
        let v = w.linears[iv][l].forward(&x1);
        cache.extend_layer(l, &rope, &k, &v, 0, n);
        let mut att = Mat::zeros(n, dims.d_model);
        let segs = cache.layer_segments(l);
        attend_cached(dims, &rope, &q, &segs, cache.blocks_held(), past, &mut att);
        h = h.add(&w.linears[io][l].forward(&att));
        let x2 = rmsnorm(&h, &w.ln2[l]);
        let mut g = w.linears[ig][l].forward(&x2);
        g.map_inplace(silu);
        let u = w.linears[iu][l].forward(&x2);
        let mid = g.zip(&u, |a, b| a * b);
        h = h.add(&w.linears[id][l].forward(&mid));
    }
    cache.commit(n);
    let hidden = rmsnorm(&h, w.fnorm);
    Ok(LinearBackend::forward(w.head, &hidden))
}

/// One decode step: feed a single token, get its `[V]` logits row back.
pub fn forward_step(
    dims: &ModelDims,
    w: &WeightView<'_>,
    token: u32,
    cache: &mut KvCache,
) -> Result<Vec<f32>> {
    let lg = forward_trace_with_cache(dims, w, &[token], cache)?;
    Ok(lg.row(0).to_vec())
}

/// Chunked prefill: feed `tokens` into the cache in `chunk`-sized slices
/// instead of one monolithic forward, returning the full `[len, V]`
/// logits. Slicing a long prompt bounds the rows any one forward call
/// touches, so prefill work can interleave with other traffic. Every
/// kernel in the cached forward is row-independent, so the result is
/// **bitwise identical** to a one-shot [`forward_trace_with_cache`] of
/// the whole prompt — the property pinned by the unit test here.
///
/// This is the single-sequence *reference* for that equivalence and the
/// entry point for callers prefilling one cache at a time. The engine
/// scheduler itself slices per sequence inside its fused multi-sequence
/// step (`engine::core`), feeding each chunk through
/// [`forward_batch_with_cache`]; that serving path is pinned against
/// the one-shot greedy decode end-to-end in `tests/engine_api.rs`. If
/// chunk-boundary semantics ever change, change both (and the tests
/// will catch a drift).
// lint: allow(indexing) — chunk bounds are clamped to tokens.len()
pub fn forward_prefill_chunked(
    dims: &ModelDims,
    w: &WeightView<'_>,
    tokens: &[u32],
    cache: &mut KvCache,
    chunk: usize,
) -> Result<Mat> {
    ensure!(chunk >= 1, "prefill chunk size must be at least 1 token");
    // validate the whole prompt and reserve all its arena blocks up
    // front so an `Err` never leaves the cache partially extended
    check_cache_step(dims, cache, tokens, 0)?;
    cache.reserve(tokens.len())?;
    let mut out = Mat::zeros(tokens.len(), dims.vocab);
    let mut done = 0usize;
    while done < tokens.len() {
        let end = (done + chunk).min(tokens.len());
        let lg = forward_trace_with_cache(dims, w, &tokens[done..end], cache)?;
        out.set_block(done, 0, &lg);
        done = end;
    }
    Ok(out)
}

/// Batched incremental forward over several independent sequences: the
/// active sequences' new tokens are coalesced into **one**
/// `[Σ new_i, d_model]` activation matrix per linear — the packed
/// group-tile dequant amortizes across the whole decode batch exactly as
/// in [`forward_trace_batch`] — while attention runs per sequence against
/// its own cache. Per-sequence results are bitwise identical to calling
/// [`forward_trace_with_cache`] one sequence at a time.
///
/// All sequences are validated before any cache is touched, so an `Err`
/// (whose message names the offending sequence index) leaves every cache
/// unchanged.
// lint: allow(indexing) — news/caches lengths are checked equal up front;
// offsets are accumulated from the input lengths
pub fn forward_batch_with_cache(
    dims: &ModelDims,
    w: &WeightView<'_>,
    news: &[Vec<u32>],
    caches: &mut [&mut KvCache],
) -> Result<Vec<Mat>> {
    ensure!(
        news.len() == caches.len(),
        "forward_batch_with_cache: {} token lists but {} caches",
        news.len(),
        caches.len()
    );
    for (i, (seq, cache)) in news.iter().zip(caches.iter()).enumerate() {
        check_cache_step(dims, cache, seq, i)?;
    }
    // reserve every sequence's arena blocks before touching any cache;
    // if one reservation fails, hand back what the earlier ones took so
    // the `Err` leaves every cache (and the arena) unchanged
    for i in 0..news.len() {
        if let Err(e) = caches[i].reserve(news[i].len()) {
            for c in caches[..i].iter_mut() {
                c.release_uncommitted();
            }
            bail!("sequence {i}: {e}");
        }
    }
    // lint: allow(panic) — membership in the static LINEARS table
    let fam = |name: &str| LINEARS.iter().position(|&nm| nm == name).unwrap();
    let (iq, ik, iv, io) = (fam("wq"), fam("wk"), fam("wv"), fam("wo"));
    let (ig, iu, id) = (fam("wg"), fam("wu"), fam("wd"));
    let rope = RopeTable::shared(dims.seq, dims.head_dim());

    let mut offsets = Vec::with_capacity(news.len());
    let mut total = 0usize;
    for seq in news {
        offsets.push(total);
        total += seq.len();
    }
    if total == 0 {
        return Ok(news.iter().map(|_| Mat::zeros(0, dims.vocab)).collect());
    }

    let d = dims.d_model;
    let mut h = Mat::zeros(total, d);
    for (si, seq) in news.iter().enumerate() {
        for (p, &tok) in seq.iter().enumerate() {
            h.row_mut(offsets[si] + p).copy_from_slice(w.embed.row(tok as usize));
        }
    }

    for l in 0..dims.n_layers {
        let x1 = rmsnorm(&h, &w.ln1[l]);
        let q = w.linears[iq][l].forward(&x1);
        let k = w.linears[ik][l].forward(&x1);
        let v = w.linears[iv][l].forward(&x1);
        let mut att = Mat::zeros(total, d);
        for (si, seq) in news.iter().enumerate() {
            let n = seq.len();
            if n == 0 {
                continue;
            }
            let cache = &mut *caches[si];
            let past = cache.len();
            cache.extend_layer(l, &rope, &k, &v, offsets[si], n);
            let qb = q.block(offsets[si], 0, n, d);
            let mut ab = Mat::zeros(n, d);
            let segs = cache.layer_segments(l);
            attend_cached(dims, &rope, &qb, &segs, cache.blocks_held(), past, &mut ab);
            att.set_block(offsets[si], 0, &ab);
        }
        h = h.add(&w.linears[io][l].forward(&att));
        let x2 = rmsnorm(&h, &w.ln2[l]);
        let mut g = w.linears[ig][l].forward(&x2);
        g.map_inplace(silu);
        let u = w.linears[iu][l].forward(&x2);
        let mid = g.zip(&u, |a, b| a * b);
        h = h.add(&w.linears[id][l].forward(&mid));
    }
    for (si, seq) in news.iter().enumerate() {
        caches[si].commit(seq.len());
    }
    let hidden = rmsnorm(&h, w.fnorm);
    let logits = LinearBackend::forward(w.head, &hidden);
    Ok(news
        .iter()
        .enumerate()
        .map(|(si, seq)| logits.block(offsets[si], 0, seq.len(), dims.vocab))
        .collect())
}

/// Log-prob of one token under a single `[V]` logits row
/// (max-subtracted log-sum-exp — the same math [`token_logp`] applies
/// per position, so prefix-reuse scoring matches it bitwise).
// lint: allow(indexing) — token ids are vocabulary-validated at admission
// (check_cache_step / Scorer::check_seq) before any scoring reaches here
pub fn row_logp(row: &[f32], token: u32) -> f32 {
    let maxv = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let lse: f32 = row.iter().map(|&v| (v - maxv).exp()).sum::<f32>().ln() + maxv;
    row[token as usize] - lse
}

/// Log-prob of the realized next token at each position: `[S-1]`
/// (empty for sequences of fewer than two tokens).
// lint: allow(indexing) — pos+1 < s by the loop bound
pub fn token_logp(logits: &Mat, tokens: &[u32]) -> Vec<f32> {
    let s = tokens.len();
    if s < 2 {
        return Vec::new();
    }
    let mut out = Vec::with_capacity(s - 1);
    for pos in 0..s - 1 {
        out.push(row_logp(logits.row(pos), tokens[pos + 1]));
    }
    out
}

/// Mean negative log-likelihood over a sequence.
pub fn nll(logits: &Mat, tokens: &[u32]) -> f32 {
    let lp = token_logp(logits, tokens);
    -lp.iter().sum::<f32>() / lp.len() as f32
}

/// Calibration statistics collected from teacher traces: per-(family,
/// layer) `E[x_i²]` and optional raw sample rows for GPTQ Hessians.
#[derive(Clone, Debug)]
pub struct CalibStats {
    /// `[family][layer]` -> length-d_in vector
    pub x_sq_mean: Vec<Vec<Vec<f32>>>,
    /// `[family][layer]` -> `[n_kept, d_in]` subsampled input rows
    pub samples: Vec<Vec<Mat>>,
}

impl CalibStats {
    /// Run the teacher over calibration sequences, accumulating per-linear
    /// input statistics. `keep_rows` bounds the stored sample rows per
    /// linear (Hessian cost is O(d_in²) regardless).
    // lint: allow(indexing) — offline calibration path; family/layer indices
    // are loop-bounded over LINEARS and n_layers
    pub fn collect(
        dims: &ModelDims,
        params: &TeacherParams,
        seqs: &[Vec<u32>],
        keep_rows: usize,
    ) -> CalibStats {
        let view = params.view();
        let nfam = LINEARS.len();
        let mut sums: Vec<Vec<Vec<f64>>> = (0..nfam)
            .map(|f| {
                let (di, _) = dims.linear_dims(LINEARS[f]);
                vec![vec![0.0; di]; dims.n_layers]
            })
            .collect();
        let mut counts = vec![vec![0usize; dims.n_layers]; nfam];
        let mut kept: Vec<Vec<Vec<f32>>> = (0..nfam)
            .map(|_| vec![Vec::new(); dims.n_layers])
            .collect();
        let mut kept_rows = vec![vec![0usize; dims.n_layers]; nfam];

        for seq in seqs {
            let trace = forward_trace(dims, &view, seq);
            for (l, lt) in trace.layers.iter().enumerate() {
                let inputs: [(usize, &Mat); 7] = [
                    (0, &lt.x_attn),
                    (1, &lt.x_attn),
                    (2, &lt.x_attn),
                    (3, &lt.att),
                    (4, &lt.x_ffn),
                    (5, &lt.x_ffn),
                    (6, &lt.mid),
                ];
                for (f, x) in inputs {
                    for r in 0..x.rows() {
                        let row = x.row(r);
                        for (i, &v) in row.iter().enumerate() {
                            sums[f][l][i] += (v * v) as f64;
                        }
                        counts[f][l] += 1;
                        if kept_rows[f][l] < keep_rows {
                            kept[f][l].extend_from_slice(row);
                            kept_rows[f][l] += 1;
                        }
                    }
                }
            }
        }

        let x_sq_mean = sums
            .iter()
            .enumerate()
            .map(|(f, per_layer)| {
                per_layer
                    .iter()
                    .enumerate()
                    .map(|(l, s)| {
                        let n = counts[f][l].max(1) as f64;
                        s.iter().map(|&v| (v / n) as f32).collect()
                    })
                    .collect()
            })
            .collect();
        let samples = kept
            .into_iter()
            .enumerate()
            .map(|(f, per_layer)| {
                let (di, _) = dims.linear_dims(LINEARS[f]);
                per_layer
                    .into_iter()
                    .enumerate()
                    .map(|(l, buf)| Mat::from_vec(kept_rows[f][l], di, buf))
                    .collect()
            })
            .collect();
        CalibStats { x_sq_mean, samples }
    }
}

/// Materialize dense student weights with merged adapters:
/// `W_eff[f][l] = Q[f][l] + A[f][l] · B[f][l]ᵀ` (adapters optional).
pub fn effective_weights(
    student: &StudentWeights,
    adapters: Option<&crate::lqec::AdapterSet>,
) -> Vec<Vec<Mat>> {
    let mut dense = student.dense();
    if let Some(ad) = adapters {
        ad.merge_into(&mut dense);
    }
    dense
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn dims() -> ModelDims {
        ModelDims {
            name: "unit".into(),
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            vocab: 32,
            seq: 12,
            batch: 2,
            group_size: 8,
        }
    }

    #[test]
    fn forward_shapes() {
        let d = dims();
        let mut rng = Rng::seed(101);
        let p = TeacherParams::init(&d, &mut rng);
        let tokens: Vec<u32> = (0..10).map(|_| rng.below(32) as u32).collect();
        let t = forward_trace(&d, &p.view(), &tokens);
        assert_eq!(t.layers.len(), 2);
        assert_eq!(t.hidden.shape(), (10, 16));
        assert_eq!(t.logits.shape(), (10, 32));
        assert_eq!(t.layers[0].mid.shape(), (10, 32));
    }

    #[test]
    fn batch_forward_matches_per_sequence() {
        // ragged lengths (including degenerate 0- and 1-token sequences)
        // must reproduce the per-sequence forward exactly
        let d = dims();
        let mut rng = Rng::seed(106);
        let p = TeacherParams::init(&d, &mut rng);
        let lens = [10usize, 3, 12, 0, 1, 7];
        let seqs: Vec<Vec<u32>> = lens
            .iter()
            .map(|&n| (0..n).map(|_| rng.below(32) as u32).collect())
            .collect();
        let batched = forward_trace_batch(&d, &p.view(), &seqs);
        assert_eq!(batched.len(), seqs.len());
        for (seq, lg) in seqs.iter().zip(&batched) {
            assert_eq!(lg.shape(), (seq.len(), 32));
            if seq.is_empty() {
                continue;
            }
            let solo = forward_trace(&d, &p.view(), seq);
            assert!(
                solo.logits.fro_dist(lg) < 1e-6,
                "len {}: batched diverged from per-sequence",
                seq.len()
            );
        }
    }

    #[test]
    fn token_logp_handles_degenerate_lengths() {
        let lg = Mat::zeros(0, 4);
        assert!(token_logp(&lg, &[]).is_empty());
        let lg = Mat::zeros(1, 4);
        assert!(token_logp(&lg, &[2]).is_empty());
    }

    #[test]
    fn logp_is_normalized() {
        let d = dims();
        let mut rng = Rng::seed(102);
        let p = TeacherParams::init(&d, &mut rng);
        let tokens: Vec<u32> = (0..8).map(|_| rng.below(32) as u32).collect();
        let t = forward_trace(&d, &p.view(), &tokens);
        // sum over vocab of exp(logp) at each position == 1
        for pos in 0..7 {
            let row = t.logits.row(pos);
            let maxv = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let z: f32 = row.iter().map(|&v| (v - maxv).exp()).sum();
            assert!(z.is_finite() && z > 0.0);
        }
        let lp = token_logp(&t.logits, &tokens);
        assert_eq!(lp.len(), 7);
        assert!(lp.iter().all(|&x| x < 0.0));
    }

    #[test]
    fn causality_prefix_invariance() {
        // logits at position i must not depend on tokens after i
        let d = dims();
        let mut rng = Rng::seed(103);
        let p = TeacherParams::init(&d, &mut rng);
        let t1: Vec<u32> = (0..10).map(|_| rng.below(32) as u32).collect();
        let mut t2 = t1.clone();
        t2[9] = (t2[9] + 1) % 32;
        let a = forward_trace(&d, &p.view(), &t1);
        let b = forward_trace(&d, &p.view(), &t2);
        for pos in 0..9 {
            let ra = a.logits.row(pos);
            let rb = b.logits.row(pos);
            for c in 0..32 {
                assert!((ra[c] - rb[c]).abs() < 1e-5, "pos {pos} leaked");
            }
        }
    }

    #[test]
    fn cached_prefill_plus_steps_match_full_forward() {
        let d = dims();
        let mut rng = Rng::seed(107);
        let p = TeacherParams::init(&d, &mut rng);
        let tokens: Vec<u32> = (0..d.seq).map(|_| rng.below(32) as u32).collect();
        let view = p.view();
        let full = forward_trace(&d, &view, &tokens).logits;
        let mut cache = super::KvCache::new(&d);
        let prefix = 5;
        let prefill = forward_trace_with_cache(&d, &view, &tokens[..prefix], &mut cache).unwrap();
        for r in 0..prefix {
            for c in 0..d.vocab {
                assert!((prefill[(r, c)] - full[(r, c)]).abs() <= 1e-6, "prefill row {r}");
            }
        }
        for (i, &t) in tokens[prefix..].iter().enumerate() {
            let row = forward_step(&d, &view, t, &mut cache).unwrap();
            let pos = prefix + i;
            for c in 0..d.vocab {
                assert!((row[c] - full[(pos, c)]).abs() <= 1e-6, "step pos {pos}");
            }
        }
        assert_eq!(cache.len(), d.seq);
    }

    #[test]
    fn chunked_prefill_is_bitwise_identical_to_one_shot() {
        let d = dims();
        let mut rng = Rng::seed(109);
        let p = TeacherParams::init(&d, &mut rng);
        let view = p.view();
        let tokens: Vec<u32> = (0..11).map(|_| rng.below(32) as u32).collect();
        let mut one_shot = super::KvCache::new(&d);
        let want = forward_trace_with_cache(&d, &view, &tokens, &mut one_shot).unwrap();
        for chunk in [1usize, 3, 4, 11, 64] {
            let mut cache = super::KvCache::new(&d);
            let got = forward_prefill_chunked(&d, &view, &tokens, &mut cache, chunk).unwrap();
            assert_eq!(cache.len(), tokens.len());
            assert_eq!(got.shape(), want.shape());
            for r in 0..tokens.len() {
                for c in 0..d.vocab {
                    assert!(
                        got[(r, c)].to_bits() == want[(r, c)].to_bits(),
                        "chunk {chunk}: row {r} col {c} not bitwise identical"
                    );
                }
            }
        }
        // over-window prompt: Err before the cache is touched
        let mut cache = super::KvCache::new(&d);
        let long: Vec<u32> = (0..d.seq + 1).map(|_| rng.below(32) as u32).collect();
        assert!(forward_prefill_chunked(&d, &view, &long, &mut cache, 4).is_err());
        assert_eq!(cache.len(), 0);
    }

    #[test]
    fn batched_cache_forward_handles_empty_and_matches_solo() {
        let d = dims();
        let mut rng = Rng::seed(108);
        let p = TeacherParams::init(&d, &mut rng);
        let view = p.view();
        let news: Vec<Vec<u32>> = vec![
            (0..4).map(|_| rng.below(32) as u32).collect(),
            Vec::new(),
            (0..7).map(|_| rng.below(32) as u32).collect(),
        ];
        let mut caches: Vec<super::KvCache> =
            news.iter().map(|_| super::KvCache::new(&d)).collect();
        let mut refs: Vec<&mut super::KvCache> = caches.iter_mut().collect();
        let lgs = forward_batch_with_cache(&d, &view, &news, &mut refs).unwrap();
        assert_eq!(lgs[1].shape(), (0, d.vocab));
        for (seq, lg) in news.iter().zip(&lgs) {
            if seq.is_empty() {
                continue;
            }
            let mut solo = super::KvCache::new(&d);
            let want = forward_trace_with_cache(&d, &view, seq, &mut solo).unwrap();
            assert!(want.fro_dist(lg) < 1e-7, "batched cached forward diverged");
        }
        assert_eq!(caches[0].len(), 4);
        assert_eq!(caches[1].len(), 0);
        assert_eq!(caches[2].len(), 7);
    }

    #[test]
    fn rope_preserves_norm() {
        let mut rng = Rng::seed(104);
        let mut x = Mat::randn(6, 8, &mut rng);
        let before: Vec<f32> = (0..6).map(|r| x.row(r).iter().map(|v| v * v).sum()).collect();
        apply_rope(&mut x, 8);
        for r in 0..6 {
            let after: f32 = x.row(r).iter().map(|v| v * v).sum();
            assert!((after - before[r]).abs() < 1e-4);
        }
    }

    #[test]
    fn calib_stats_shapes() {
        let d = dims();
        let mut rng = Rng::seed(105);
        let p = TeacherParams::init(&d, &mut rng);
        let seqs: Vec<Vec<u32>> =
            (0..3).map(|_| (0..8).map(|_| rng.below(32) as u32).collect()).collect();
        let cs = CalibStats::collect(&d, &p, &seqs, 16);
        assert_eq!(cs.x_sq_mean.len(), 7);
        assert_eq!(cs.x_sq_mean[6][0].len(), 32); // wd has d_in = d_ff
        assert_eq!(cs.samples[0][0].cols(), 16);
        assert!(cs.samples[0][0].rows() <= 16);
        assert!(cs.x_sq_mean[0][0].iter().all(|&v| v >= 0.0));
    }
}
