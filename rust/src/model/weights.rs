//! Binary checkpoint IO: a tiny named-tensor container used to cache
//! pretrained teachers, quantized students and tuned adapters under
//! `runs/<key>/`. Format (little-endian):
//!
//! ```text
//! magic "RILQWT01" | u32 count | count x { u32 name_len | name bytes |
//!                                          u32 ndims | u64 dims[ndims] |
//!                                          f32 data[prod(dims)] }
//! ```

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Result};

const MAGIC: &[u8; 8] = b"RILQWT01";

/// An ordered named-tensor container.
#[derive(Clone, Debug, Default)]
pub struct TensorFile {
    pub tensors: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
}

impl TensorFile {
    pub fn new() -> TensorFile {
        TensorFile::default()
    }

    pub fn insert(&mut self, name: impl Into<String>, dims: Vec<usize>, data: Vec<f32>) {
        let n: usize = dims.iter().product();
        assert_eq!(n, data.len(), "dims/data mismatch");
        self.tensors.insert(name.into(), (dims, data));
    }

    pub fn get(&self, name: &str) -> Option<&(Vec<usize>, Vec<f32>)> {
        self.tensors.get(name)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, (dims, data)) in &self.tensors {
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            w.write_all(&(dims.len() as u32).to_le_bytes())?;
            for &d in dims {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            // bulk write of the f32 payload
            // SAFETY: reinterprets an initialized, live `&[f32]` as bytes:
            // every f32 bit pattern is a valid u8 sequence, f32's alignment
            // (4) satisfies u8's (1), and len*4 is the exact byte span of
            // the borrowed buffer. The borrow outlives the write call.
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
            };
            w.write_all(bytes)?;
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<TensorFile> {
        let mut r = BufReader::new(File::open(path.as_ref())?);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad magic in {:?}", path.as_ref());
        }
        let count = read_u32(&mut r)? as usize;
        let mut tf = TensorFile::new();
        for _ in 0..count {
            let name_len = read_u32(&mut r)? as usize;
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let ndims = read_u32(&mut r)? as usize;
            let mut dims = Vec::with_capacity(ndims);
            for _ in 0..ndims {
                let mut b = [0u8; 8];
                r.read_exact(&mut b)?;
                dims.push(u64::from_le_bytes(b) as usize);
            }
            let n: usize = dims.iter().product();
            let mut bytes = vec![0u8; n * 4];
            r.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tf.insert(String::from_utf8(name)?, dims, data);
        }
        Ok(tf)
    }
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("rilq_test_weights");
        let path = dir.join("t.bin");
        let mut tf = TensorFile::new();
        tf.insert("a", vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        tf.insert("b.c", vec![4], vec![0.5; 4]);
        tf.save(&path).unwrap();
        let tf2 = TensorFile::load(&path).unwrap();
        assert_eq!(tf2.tensors.len(), 2);
        let (dims, data) = tf2.get("a").unwrap();
        assert_eq!(dims, &vec![2, 3]);
        assert_eq!(data[5], 6.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("rilq_test_weights2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOTAFILE").unwrap();
        assert!(TensorFile::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
