//! The simulated LLaMA-style model substrate on the Rust side:
//!
//! * [`ModelDims`] — static geometry, parsed from `artifacts/manifest.json`
//!   so Rust and the AOT-lowered HLO can never disagree;
//! * [`TeacherParams`] / [`StudentWeights`] — parameter containers whose
//!   flattening order matches the artifact argument lists;
//! * [`forward`] — a pure-Rust reference forward pass (test oracle for the
//!   HLO artifacts + native evaluation path for quantizer studies that
//!   don't need PJRT);
//! * [`backend`] — the linear execution engine ([`backend::LinearBackend`]):
//!   dense, adapter-merged, or fused packed-2-bit + LoRA serving form;
//! * [`kv`] — per-sequence KV cache over a shared block arena
//!   ([`kv::KvArena`]) + shared RoPE table: incremental decode
//!   ([`forward::forward_step`]) and shared-prompt prefix reuse without
//!   quadratic recompute, with residency paid per block actually held;
//! * [`weights`] — binary checkpoint IO for run caching.

pub mod backend;
pub mod forward;
pub mod kv;
pub mod weights;

pub use backend::{BackendKind, LinearBackend};
pub use kv::{KvArena, KvCache, RopeTable};

use anyhow::{anyhow, Result};

use crate::quant::{CalibCtx, QuantResult, Quantizer};
use crate::report::Json;
use crate::tensor::{Mat, Rng};

/// The seven quantized linear families, in canonical (artifact) order.
/// Matches `python/compile/model.py::LINEARS`.
pub const LINEARS: [&str; 7] = ["wq", "wk", "wv", "wo", "wg", "wu", "wd"];

/// Static model geometry (mirrors `python/compile/configs.py`).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelDims {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    pub group_size: usize,
}

impl ModelDims {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// `(d_in, d_out)` of a linear family.
    pub fn linear_dims(&self, name: &str) -> (usize, usize) {
        let (d, f) = (self.d_model, self.d_ff);
        match name {
            "wq" | "wk" | "wv" | "wo" => (d, d),
            "wg" | "wu" => (d, f),
            "wd" => (f, d),
            other => panic!("unknown linear family {other}"),
        }
    }

    pub fn params_count(&self) -> usize {
        let (d, f, v, l) = (self.d_model, self.d_ff, self.vocab, self.n_layers);
        v * d + l * (4 * d * d + 3 * d * f + 2 * d) + d + d * v
    }

    /// FLOPs one activation row (token) spends in the seven quantized
    /// linear families plus the LM head — 2 FLOPs (multiply + add) per
    /// resident weight. This is the numerator behind the
    /// `serve.kernel_gflops` observation series and the bench GFLOP/s
    /// columns. Embedding (a gather), norms, and attention (cost grows
    /// with position, data-dependent) are excluded, so reported GFLOP/s
    /// slightly *undercount* the true arithmetic — a conservative
    /// efficiency figure.
    pub fn linear_flops_per_token(&self) -> usize {
        let (d, f, v, l) = (self.d_model, self.d_ff, self.vocab, self.n_layers);
        2 * (l * (4 * d * d + 3 * d * f) + d * v)
    }

    /// Parse from a manifest `configs.<name>` object.
    pub fn from_json(j: &Json) -> Result<ModelDims> {
        Ok(ModelDims {
            name: j.str_of("name")?.to_string(),
            d_model: j.usize_of("d_model")?,
            n_layers: j.usize_of("n_layers")?,
            n_heads: j.usize_of("n_heads")?,
            d_ff: j.usize_of("d_ff")?,
            vocab: j.usize_of("vocab")?,
            seq: j.usize_of("seq")?,
            batch: j.usize_of("batch")?,
            group_size: j.usize_of("group_size")?,
        })
    }
}

/// Full-precision teacher parameters. Per-layer weights are kept as one
/// `Mat` per layer; `stacked()` produces the `[L, ...]` flat buffers the
/// artifacts take.
#[derive(Clone, Debug)]
pub struct TeacherParams {
    pub embed: Mat,            // [V, d]
    /// indexed `[linear_family][layer]`, each `[d_in, d_out]`
    pub linears: Vec<Vec<Mat>>,
    pub ln1: Vec<Vec<f32>>,    // [L][d]
    pub ln2: Vec<Vec<f32>>,    // [L][d]
    pub fnorm: Vec<f32>,       // [d]
    pub head: Mat,             // [d, V]
}

impl TeacherParams {
    /// He-style random init (the coordinator pretrains from this).
    pub fn init(dims: &ModelDims, rng: &mut Rng) -> TeacherParams {
        let scaled = |r: usize, c: usize, rng: &mut Rng| {
            let std = (2.0 / r as f32).sqrt() * 0.5;
            Mat::randn(r, c, rng).scale(std)
        };
        let mut linears = Vec::new();
        for name in LINEARS {
            let (di, do_) = dims.linear_dims(name);
            linears.push((0..dims.n_layers).map(|_| scaled(di, do_, rng)).collect());
        }
        TeacherParams {
            embed: scaled(dims.vocab, dims.d_model, rng),
            linears,
            ln1: vec![vec![1.0; dims.d_model]; dims.n_layers],
            ln2: vec![vec![1.0; dims.d_model]; dims.n_layers],
            fnorm: vec![1.0; dims.d_model],
            head: scaled(dims.d_model, dims.vocab, rng),
        }
    }

    /// Clone with the seven linear families dropped (empty per-family
    /// vecs) — for consumers that execute linears through another engine
    /// and only need embed/norms/head (see `eval::BackendScorer`).
    /// Keeping the dense fp32 linears out of the clone is what preserves
    /// the packed backend's resident-memory win.
    pub fn without_linears(&self) -> TeacherParams {
        TeacherParams {
            embed: self.embed.clone(),
            linears: (0..LINEARS.len()).map(|_| Vec::new()).collect(),
            ln1: self.ln1.clone(),
            ln2: self.ln2.clone(),
            fnorm: self.fnorm.clone(),
            head: self.head.clone(),
        }
    }

    pub fn linear(&self, family: usize, layer: usize) -> &Mat {
        &self.linears[family][layer]
    }

    pub fn linear_by_name(&self, name: &str, layer: usize) -> &Mat {
        let idx = LINEARS.iter().position(|&n| n == name).expect("family");
        &self.linears[idx][layer]
    }

    /// Flat `[L, d_in, d_out]` buffer for one family (artifact layout).
    pub fn stacked_linear(&self, family: usize) -> Vec<f32> {
        let mut out = Vec::new();
        for l in &self.linears[family] {
            out.extend_from_slice(l.data());
        }
        out
    }

    /// Flat `[L, d]` buffer for ln1/ln2.
    pub fn stacked_norm(norms: &[Vec<f32>]) -> Vec<f32> {
        norms.iter().flat_map(|v| v.iter().copied()).collect()
    }

    /// All teacher tensors in artifact order:
    /// embed, wq..wd, ln1, ln2, fnorm, head (shapes implied by dims).
    pub fn to_flat(&self) -> Vec<Vec<f32>> {
        let mut out = vec![self.embed.data().to_vec()];
        for f in 0..LINEARS.len() {
            out.push(self.stacked_linear(f));
        }
        out.push(Self::stacked_norm(&self.ln1));
        out.push(Self::stacked_norm(&self.ln2));
        out.push(self.fnorm.clone());
        out.push(self.head.data().to_vec());
        out
    }

    /// Inverse of [`to_flat`].
    pub fn from_flat(dims: &ModelDims, flat: &[Vec<f32>]) -> Result<TeacherParams> {
        if flat.len() != 12 {
            return Err(anyhow!("expected 12 teacher tensors, got {}", flat.len()));
        }
        let l = dims.n_layers;
        let d = dims.d_model;
        let embed = Mat::from_vec(dims.vocab, d, flat[0].clone());
        let mut linears = Vec::new();
        for (f, name) in LINEARS.iter().enumerate() {
            let (di, do_) = dims.linear_dims(name);
            let buf = &flat[1 + f];
            let per = di * do_;
            let mats = (0..l)
                .map(|i| Mat::from_vec(di, do_, buf[i * per..(i + 1) * per].to_vec()))
                .collect();
            linears.push(mats);
        }
        let unstack = |buf: &[f32]| -> Vec<Vec<f32>> {
            (0..l).map(|i| buf[i * d..(i + 1) * d].to_vec()).collect()
        };
        Ok(TeacherParams {
            embed,
            linears,
            ln1: unstack(&flat[8]),
            ln2: unstack(&flat[9]),
            fnorm: flat[10].clone(),
            head: Mat::from_vec(d, dims.vocab, flat[11].clone()),
        })
    }
}

/// Quantized student weights: one [`QuantResult`] per (family, layer).
#[derive(Clone, Debug)]
pub struct StudentWeights {
    /// indexed `[family][layer]`
    pub q: Vec<Vec<QuantResult>>,
    pub quantizer: String,
    pub bits: u8,
}

impl StudentWeights {
    /// Quantize every linear of the teacher. `calib` optionally supplies a
    /// per-(family, layer) calibration context builder.
    pub fn quantize(
        dims: &ModelDims,
        teacher: &TeacherParams,
        quantizer: &dyn Quantizer,
        calib: &(dyn Fn(usize, usize) -> CalibCtx + Sync),
    ) -> StudentWeights {
        // each (family, layer) quantizes independently — parallel map
        let l = dims.n_layers;
        let cells = LINEARS.len() * l;
        let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        let flat = crate::tensor::parallel_map(cells, workers, |i| {
            let (f, li) = (i / l, i % l);
            quantizer.quantize(teacher.linear(f, li), &calib(f, li))
        });
        let mut q: Vec<Vec<QuantResult>> = (0..LINEARS.len()).map(|_| Vec::new()).collect();
        for (i, r) in flat.into_iter().enumerate() {
            q[i / l].push(r);
        }
        StudentWeights { q, quantizer: quantizer.name().to_string(), bits: quantizer.bits() }
    }

    /// Dense dequantized weights as flat stacked buffers (artifact layout,
    /// one `[L, d_in, d_out]` buffer per family).
    pub fn to_flat_dense(&self) -> Vec<Vec<f32>> {
        self.q
            .iter()
            .map(|layers| {
                let mut buf = Vec::new();
                for qr in layers {
                    buf.extend_from_slice(qr.dequant().data());
                }
                buf
            })
            .collect()
    }

    /// Dense per-layer matrices for the reference forward.
    pub fn dense(&self) -> Vec<Vec<Mat>> {
        self.q.iter().map(|ls| ls.iter().map(|q| q.dequant()).collect()).collect()
    }

    /// Total packed storage in bytes (memory-cost analysis).
    pub fn storage_bytes(&self) -> usize {
        self.q.iter().flatten().map(|q| q.storage_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Rtn;

    pub fn tiny_dims() -> ModelDims {
        ModelDims {
            name: "unit".into(),
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            vocab: 32,
            seq: 12,
            batch: 2,
            group_size: 8,
        }
    }

    #[test]
    fn flat_roundtrip() {
        let dims = tiny_dims();
        let mut rng = Rng::seed(91);
        let p = TeacherParams::init(&dims, &mut rng);
        let flat = p.to_flat();
        assert_eq!(flat.len(), 12);
        let p2 = TeacherParams::from_flat(&dims, &flat).unwrap();
        assert!(p.embed.fro_dist(&p2.embed) < 1e-7);
        assert!(p.linear(6, 1).fro_dist(p2.linear(6, 1)) < 1e-7);
        assert_eq!(p.ln2, p2.ln2);
    }

    #[test]
    fn params_count_matches() {
        let dims = tiny_dims();
        let mut rng = Rng::seed(92);
        let p = TeacherParams::init(&dims, &mut rng);
        let total: usize = p.to_flat().iter().map(|b| b.len()).sum();
        assert_eq!(total, dims.params_count());
    }

    #[test]
    fn quantize_all_linears() {
        let dims = tiny_dims();
        let mut rng = Rng::seed(93);
        let p = TeacherParams::init(&dims, &mut rng);
        let q = Rtn::new(2, 8);
        let sw = StudentWeights::quantize(&dims, &p, &q, &|_, _| CalibCtx::default());
        assert_eq!(sw.q.len(), 7);
        assert_eq!(sw.q[0].len(), 2);
        let flat = sw.to_flat_dense();
        assert_eq!(flat[0].len(), 2 * 16 * 16);
        assert_eq!(flat[6].len(), 2 * 32 * 16);
    }

    #[test]
    fn dims_from_json() {
        let j = Json::parse(
            r#"{"name":"x","d_model":8,"n_layers":1,"n_heads":2,"d_ff":16,
                "vocab":32,"seq":8,"batch":2,"group_size":4}"#,
        )
        .unwrap();
        let d = ModelDims::from_json(&j).unwrap();
        assert_eq!(d.head_dim(), 4);
        assert_eq!(d.linear_dims("wd"), (16, 8));
    }
}
