//! Per-sequence KV cache and the shared RoPE angle table.
//!
//! The serving forward historically recomputed full causal attention over
//! the whole sequence for every request — O(S²) work to score one more
//! token. [`KvCache`] stores each layer's rotated K and raw V rows so a
//! sequence can grow incrementally: prefill once, then push only the new
//! rows through every linear (see `forward::forward_trace_with_cache` /
//! `forward::forward_step`). [`RopeTable`] hoists the rotary-embedding
//! angle computation (previously `powf` + `sin_cos` per (position,
//! channel-pair) per head per layer) into one table shared across heads,
//! layers, and sequences.
//!
//! Cache layout is head-major per layer: `[n_heads, capacity, head_dim]`,
//! so the attention inner loop streams contiguous `head_dim`-float rows
//! exactly like the old per-head gather copies did — without the copies.
//! K rows are stored *already rotated* (a row's rotation depends only on
//! its own absolute position, which never changes as the sequence grows).
//!
//! [`KvCache::truncate`] rolls the cache back to a shorter prefix, which
//! is what makes shared-prompt scoring cheap: `mc_accuracy` prefills the
//! prompt once, scores one choice's suffix, truncates back to the prompt,
//! and scores the next choice — bitwise-stable across choices because
//! truncation restores the exact buffer state.

use std::sync::{Arc, Mutex, OnceLock};

use super::ModelDims;
use crate::tensor::Mat;

/// Precomputed `(sin, cos)` rotary table for positions `0..max_pos` and
/// `head_dim / 2` channel pairs. One table serves every head, layer, and
/// sequence of a model geometry; [`RopeTable::shared`] memoizes tables
/// process-wide so repeated forwards don't even pay the table build.
pub struct RopeTable {
    head_dim: usize,
    half: usize,
    max_pos: usize,
    sin: Vec<f32>,
    cos: Vec<f32>,
}

impl RopeTable {
    /// Build the table: `freq_k = 10000^(-2k / head_dim)`, angle
    /// `pos * freq_k` — the same formula the per-element path used, so
    /// rotated values are bitwise identical to the historical ones.
    pub fn new(max_pos: usize, head_dim: usize) -> RopeTable {
        let half = head_dim / 2;
        let mut sin = Vec::with_capacity(max_pos * half);
        let mut cos = Vec::with_capacity(max_pos * half);
        for pos in 0..max_pos {
            for k in 0..half {
                let freq = 10000f32.powf(-(2.0 * k as f32) / head_dim as f32);
                let (s, c) = (pos as f32 * freq).sin_cos();
                sin.push(s);
                cos.push(c);
            }
        }
        RopeTable { head_dim, half, max_pos, sin, cos }
    }

    /// Process-wide memoized lookup: any existing table with the same
    /// `head_dim` and at least `max_pos` positions is reused.
    pub fn shared(max_pos: usize, head_dim: usize) -> Arc<RopeTable> {
        static REGISTRY: OnceLock<Mutex<Vec<Arc<RopeTable>>>> = OnceLock::new();
        let reg = REGISTRY.get_or_init(|| Mutex::new(Vec::new()));
        let mut g = reg.lock().unwrap();
        if let Some(t) = g.iter().find(|t| t.head_dim == head_dim && t.max_pos >= max_pos) {
            return t.clone();
        }
        let t = Arc::new(RopeTable::new(max_pos, head_dim));
        g.push(t.clone());
        t
    }

    pub fn max_pos(&self) -> usize {
        self.max_pos
    }

    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    /// Rotate one `[head_dim]` slice in place for absolute position `pos`
    /// ((even, odd) channel-pair layout, matching the python model).
    #[inline]
    pub fn rotate(&self, head: &mut [f32], pos: usize) {
        debug_assert!(pos < self.max_pos, "position {} outside rope table", pos);
        debug_assert_eq!(head.len(), self.head_dim);
        let base = pos * self.half;
        for k in 0..self.half {
            let (sin, cos) = (self.sin[base + k], self.cos[base + k]);
            let a = head[2 * k];
            let b = head[2 * k + 1];
            head[2 * k] = a * cos - b * sin;
            head[2 * k + 1] = a * sin + b * cos;
        }
    }
}

/// Growable per-sequence key/value cache: for each layer, the rotated K
/// and raw V projections of every position seen so far. Storage is
/// allocated once at construction (`capacity == dims.seq`), so append and
/// truncate never reallocate — `bytes()` is the constant resident
/// footprint a serving scheduler accounts against.
pub struct KvCache {
    d_model: usize,
    n_layers: usize,
    n_heads: usize,
    head_dim: usize,
    capacity: usize,
    len: usize,
    /// per layer, head-major `[n_heads, capacity, head_dim]`
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl KvCache {
    /// Empty cache with room for `dims.seq` positions.
    pub fn new(dims: &ModelDims) -> KvCache {
        let size = dims.seq * dims.d_model;
        KvCache {
            d_model: dims.d_model,
            n_layers: dims.n_layers,
            n_heads: dims.n_heads,
            head_dim: dims.head_dim(),
            capacity: dims.seq,
            len: 0,
            k: (0..dims.n_layers).map(|_| vec![0.0; size]).collect(),
            v: (0..dims.n_layers).map(|_| vec![0.0; size]).collect(),
        }
    }

    /// Cached positions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum positions this cache can hold (`dims.seq` at build time).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Positions still available before the window is full.
    pub fn remaining(&self) -> usize {
        self.capacity - self.len
    }

    /// True when the cache was built for this model geometry.
    pub fn matches(&self, dims: &ModelDims) -> bool {
        self.d_model == dims.d_model
            && self.n_layers == dims.n_layers
            && self.n_heads == dims.n_heads
            && self.capacity == dims.seq
    }

    /// Roll back to a shorter prefix (`n <= len`). Rows past `n` are
    /// logically discarded; the next append overwrites them, so replaying
    /// the same suffix reproduces bitwise-identical state.
    pub fn truncate(&mut self, n: usize) {
        assert!(n <= self.len, "truncate({n}) past cache length {}", self.len);
        self.len = n;
    }

    pub fn clear(&mut self) {
        self.len = 0;
    }

    /// Resident memory of the cache buffers in bytes (constant — the
    /// full-capacity K and V planes of every layer).
    pub fn bytes(&self) -> usize {
        4 * (self.n_layers * 2 * self.capacity * self.d_model)
    }

    /// Append `n` new rows (taken from `k`/`v` starting at row `r0`) to
    /// one layer's planes at positions `len..len+n`, rotating K by each
    /// row's absolute position. Every layer of a forward step appends
    /// with the *same* base position; [`KvCache::commit`] advances `len`
    /// once after all layers ran.
    pub(crate) fn extend_layer(
        &mut self,
        layer: usize,
        rope: &RopeTable,
        k: &Mat,
        v: &Mat,
        r0: usize,
        n: usize,
    ) {
        debug_assert!(self.len + n <= self.capacity, "kv cache overflow");
        let (hd, cap) = (self.head_dim, self.capacity);
        let kb = &mut self.k[layer];
        let vb = &mut self.v[layer];
        for i in 0..n {
            let pos = self.len + i;
            let krow = k.row(r0 + i);
            let vrow = v.row(r0 + i);
            for h in 0..self.n_heads {
                let off = (h * cap + pos) * hd;
                kb[off..off + hd].copy_from_slice(&krow[h * hd..(h + 1) * hd]);
                rope.rotate(&mut kb[off..off + hd], pos);
                vb[off..off + hd].copy_from_slice(&vrow[h * hd..(h + 1) * hd]);
            }
        }
    }

    /// Advance the cached length after every layer appended its rows.
    pub(crate) fn commit(&mut self, n: usize) {
        debug_assert!(self.len + n <= self.capacity);
        self.len += n;
    }

    /// One layer's rotated-K plane (`[n_heads, capacity, head_dim]`).
    pub(crate) fn layer_k(&self, layer: usize) -> &[f32] {
        &self.k[layer]
    }

    /// One layer's V plane (`[n_heads, capacity, head_dim]`).
    pub(crate) fn layer_v(&self, layer: usize) -> &[f32] {
        &self.v[layer]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims {
            name: "kv".into(),
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            vocab: 32,
            seq: 12,
            batch: 2,
            group_size: 8,
        }
    }

    #[test]
    fn rope_table_matches_reference_formula() {
        let hd = 8;
        let t = RopeTable::new(6, hd);
        for pos in 0..6 {
            for k in 0..hd / 2 {
                let freq = 10000f32.powf(-(2.0 * k as f32) / hd as f32);
                let (s, c) = (pos as f32 * freq).sin_cos();
                let mut probe = vec![0.0f32; hd];
                probe[2 * k] = 1.0;
                t.rotate(&mut probe, pos);
                assert_eq!(probe[2 * k], c, "pos {pos} k {k}");
                assert_eq!(probe[2 * k + 1], s, "pos {pos} k {k}");
            }
        }
    }

    #[test]
    fn shared_tables_are_reused_and_cover_smaller_requests() {
        let a = RopeTable::shared(10, 8);
        let b = RopeTable::shared(6, 8);
        assert!(b.max_pos() >= 6);
        assert_eq!(a.head_dim(), b.head_dim());
        // a table for a different head_dim is a different table
        let c = RopeTable::shared(10, 4);
        assert_eq!(c.head_dim(), 4);
    }

    #[test]
    fn cache_len_truncate_and_bytes() {
        let d = dims();
        let mut c = KvCache::new(&d);
        assert_eq!(c.len(), 0);
        assert!(c.is_empty());
        assert_eq!(c.capacity(), d.seq);
        assert_eq!(c.remaining(), d.seq);
        assert!(c.matches(&d));
        // append 3 rows to every layer, then commit
        let rope = RopeTable::new(d.seq, d.head_dim());
        let k = Mat::full(3, d.d_model, 1.0);
        let v = Mat::full(3, d.d_model, 2.0);
        for l in 0..d.n_layers {
            c.extend_layer(l, &rope, &k, &v, 0, 3);
        }
        c.commit(3);
        assert_eq!(c.len(), 3);
        assert_eq!(c.remaining(), d.seq - 3);
        c.truncate(1);
        assert_eq!(c.len(), 1);
        c.clear();
        assert!(c.is_empty());
        // bytes is the constant full-capacity footprint
        assert_eq!(c.bytes(), 4 * 2 * d.n_layers * d.seq * d.d_model);
    }

    #[test]
    #[should_panic(expected = "truncate")]
    fn truncate_past_len_panics() {
        let mut c = KvCache::new(&dims());
        c.truncate(1);
    }
}
