//! Per-sequence KV cache over a shared block arena, plus the shared RoPE
//! angle table.
//!
//! The serving forward historically recomputed full causal attention over
//! the whole sequence for every request — O(S²) work to score one more
//! token. [`KvCache`] stores each layer's rotated K and raw V rows so a
//! sequence can grow incrementally: prefill once, then push only the new
//! rows through every linear (see `forward::forward_trace_with_cache` /
//! `forward::forward_step`). [`RopeTable`] hoists the rotary-embedding
//! angle computation (previously `powf` + `sin_cos` per (position,
//! channel-pair) per head per layer) into one table shared across heads,
//! layers, and sequences.
//!
//! # Paged storage
//!
//! Storage is *paged* (the PagedAttention insight, CPU-side): a
//! [`KvArena`] owns a bounded pool of fixed-size **position blocks** —
//! [`KvArena::block_size`] positions × per-layer head-major K/V planes —
//! and each [`KvCache`] is a block table over that pool, growing one
//! block at a time via [`KvCache::reserve`] as the sequence extends. A
//! cache therefore pays only for the positions it actually holds
//! ([`KvCache::bytes`] is blocks-in-use, not the worst-case window), so a
//! scheduler can admit sequences against *actual* residency and reclaim
//! blocks the moment a sequence finishes, truncates, or is preempted.
//! [`KvCache::new`] builds a solo single-owner arena sized for the full
//! window, preserving the old "one cache, full capacity" behavior for
//! offline scoring; the engine shares one arena across every active
//! sequence via [`KvArena::new_cache`].
//!
//! Within a block, each layer's planes are head-major
//! `[n_heads, block_size, head_dim]`, so the attention inner loop still
//! streams contiguous `head_dim`-float rows exactly like the contiguous
//! cache did — the block walk only changes *where* consecutive rows
//! live, never the per-row reduction order, which keeps paged attention
//! bitwise identical to the contiguous path. K rows are stored *already
//! rotated* (a row's rotation depends only on its own absolute position,
//! which never changes as the sequence grows).
//!
//! [`KvCache::truncate`] rolls the cache back to a shorter prefix
//! (returning now-unused whole blocks to the arena), which is what makes
//! shared-prompt scoring cheap: `mc_accuracy` prefills the prompt once,
//! scores one choice's suffix, truncates back to the prompt, and scores
//! the next choice — bitwise-stable across choices because every row is
//! fully rewritten before it is ever read back.
//!
//! # Block sharing and refcounts
//!
//! Blocks are handed out as [`Arc`] handles and the arena keeps a
//! per-block reference count, so one committed block can back **many**
//! sequences at once — the substrate of the cross-request prefix cache
//! (`engine::prefix`). [`KvArena::retain`] adds a holder to an
//! already-allocated block; every release path ([`KvCache::truncate`],
//! [`KvCache::clear`], `Drop`, the prefix index evicting an entry) only
//! *decrements*, and a block returns to the free pool exactly when the
//! last holder lets go. Sharing is copy-on-write at the tail:
//! [`KvCache::extend_layer`] refuses (panics, see below) to write a block
//! it does not exclusively own, so a sequence extending a shared prefix
//! must grow with freshly reserved private blocks — the engine attaches
//! only *whole* shared blocks and re-prefills any partially-filled
//! boundary privately, which is what keeps a cache-hit prefill bitwise
//! identical to a cold one. Attaching a shared block costs no arena
//! capacity: `blocks_in_use` counts *distinct* resident blocks, not
//! holders.

use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Result};

use super::ModelDims;
use crate::tensor::Mat;

/// Precomputed `(sin, cos)` rotary table for positions `0..max_pos` and
/// `head_dim / 2` channel pairs. One table serves every head, layer, and
/// sequence of a model geometry; [`RopeTable::shared`] memoizes tables
/// process-wide so repeated forwards don't even pay the table build.
pub struct RopeTable {
    head_dim: usize,
    half: usize,
    max_pos: usize,
    sin: Vec<f32>,
    cos: Vec<f32>,
}

impl RopeTable {
    /// Build the table: `freq_k = 10000^(-2k / head_dim)`, angle
    /// `pos * freq_k` — the same formula the per-element path used, so
    /// rotated values are bitwise identical to the historical ones.
    pub fn new(max_pos: usize, head_dim: usize) -> RopeTable {
        let half = head_dim / 2;
        let mut sin = Vec::with_capacity(max_pos * half);
        let mut cos = Vec::with_capacity(max_pos * half);
        for pos in 0..max_pos {
            for k in 0..half {
                let freq = 10000f32.powf(-(2.0 * k as f32) / head_dim as f32);
                let (s, c) = (pos as f32 * freq).sin_cos();
                sin.push(s);
                cos.push(c);
            }
        }
        RopeTable { head_dim, half, max_pos, sin, cos }
    }

    /// Process-wide memoized lookup: any existing table with the same
    /// `head_dim` and at least `max_pos` positions is reused.
    pub fn shared(max_pos: usize, head_dim: usize) -> Arc<RopeTable> {
        static REGISTRY: OnceLock<Mutex<Vec<Arc<RopeTable>>>> = OnceLock::new();
        let reg = REGISTRY.get_or_init(|| Mutex::new(Vec::new()));
        let mut g = reg.lock().unwrap();
        if let Some(t) = g.iter().find(|t| t.head_dim == head_dim && t.max_pos >= max_pos) {
            return t.clone();
        }
        let t = Arc::new(RopeTable::new(max_pos, head_dim));
        g.push(t.clone());
        t
    }

    pub fn max_pos(&self) -> usize {
        self.max_pos
    }

    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    /// Rotate one `[head_dim]` slice in place for absolute position `pos`
    /// ((even, odd) channel-pair layout, matching the python model).
    // lint: allow(indexing) — 2k+1 < head_dim and base+k < table length by
    // the debug-checked geometry (half = head_dim/2, pos < max_pos)
    #[inline]
    pub fn rotate(&self, head: &mut [f32], pos: usize) {
        debug_assert!(pos < self.max_pos, "position {} outside rope table", pos);
        debug_assert_eq!(head.len(), self.head_dim);
        let base = pos * self.half;
        for k in 0..self.half {
            let (sin, cos) = (self.sin[base + k], self.cos[base + k]);
            let a = head[2 * k];
            let b = head[2 * k + 1];
            head[2 * k] = a * cos - b * sin;
            head[2 * k + 1] = a * sin + b * cos;
        }
    }
}

/// Default positions per arena block. 32 positions keeps the block small
/// enough that short sequences waste little (< one block of slack per
/// sequence) while each (head, block) K/V segment is still a long
/// contiguous run for the attention kernel.
pub const DEFAULT_BLOCK_POSITIONS: usize = 32;

/// One fixed-size arena block: for every layer, a rotated-K and a raw-V
/// plane of `block_size` positions in head-major layout
/// `[n_heads, block_size, head_dim]`. Blocks live behind [`Arc`] handles
/// so a committed block can be shared by several caches and the prefix
/// index at once; the arena tracks one refcount per block `id` and moves
/// a block back to the free pool only when the last holder releases it.
/// Contents are *not* cleared on free — every position is fully
/// overwritten by `extend_layer` before attention ever reads it.
pub(crate) struct KvBlock {
    /// dense index into the arena's refcount table, assigned at creation
    id: usize,
    /// per layer, head-major `[n_heads, block_size, head_dim]`
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

struct ArenaState {
    free: Vec<Arc<KvBlock>>,
    /// blocks materialized so far (free + in use); bounded by
    /// `max_blocks`, and the bound the no-leak test pins
    created: usize,
    /// distinct blocks with refcount >= 1 (holders beyond the first are
    /// residency-free: sharing a block never consumes arena capacity)
    in_use: usize,
    /// per-block holder counts, indexed by `KvBlock::id`
    refs: Vec<usize>,
}

/// Shared bounded pool of KV position blocks for one model geometry.
///
/// The arena is the residency authority for a serving engine: it hands
/// out blocks ([`KvCache::reserve`]) until `max_blocks` are in use, and
/// takes them back when caches truncate, clear, or drop. Allocation is
/// all-or-nothing under one lock, so concurrent callers can never
/// observe a partially granted reservation. Freed blocks are recycled
/// (stale contents are safe — see [`KvBlock`]), so steady-state serving
/// allocates no new storage. Shared holders ([`KvArena::retain`]) only
/// add refcount; a block is freed exactly once, by its last release.
pub struct KvArena {
    d_model: usize,
    n_layers: usize,
    n_heads: usize,
    head_dim: usize,
    /// per-sequence position window (`dims.seq`)
    window: usize,
    block_size: usize,
    max_blocks: usize,
    inner: Mutex<ArenaState>,
}

impl KvArena {
    /// Arena for `max_blocks` blocks of `block_size` positions each.
    /// `block_size` is clamped to `1..=dims.seq`; blocks are materialized
    /// lazily on first use and recycled thereafter.
    pub fn new(dims: &ModelDims, block_size: usize, max_blocks: usize) -> Arc<KvArena> {
        let bs = block_size.clamp(1, dims.seq.max(1));
        Arc::new(KvArena {
            d_model: dims.d_model,
            n_layers: dims.n_layers,
            n_heads: dims.n_heads,
            head_dim: dims.head_dim(),
            window: dims.seq,
            block_size: bs,
            max_blocks,
            inner: Mutex::new(ArenaState {
                free: Vec::new(),
                created: 0,
                in_use: 0,
                refs: Vec::new(),
            }),
        })
    }

    /// An empty cache drawing its blocks from this arena.
    pub fn new_cache(self: &Arc<Self>) -> KvCache {
        KvCache { arena: self.clone(), blocks: Vec::new(), len: 0 }
    }

    /// Positions per block.
    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Total blocks this arena may hand out.
    pub fn max_blocks(&self) -> usize {
        self.max_blocks
    }

    /// Distinct blocks currently resident (held by at least one cache or
    /// by the prefix index). Extra holders of a shared block don't count.
    pub fn blocks_in_use(&self) -> usize {
        self.inner.lock().unwrap().in_use
    }

    /// Blocks still available for reservation.
    pub fn blocks_free(&self) -> usize {
        self.max_blocks - self.blocks_in_use()
    }

    /// Blocks materialized over the arena's lifetime — stays put once
    /// steady-state reuse kicks in (the no-leak pin).
    pub fn blocks_created(&self) -> usize {
        self.inner.lock().unwrap().created
    }

    /// Resident bytes of one block (all layers, K and V planes).
    pub fn block_bytes(&self) -> usize {
        4 * self.n_layers * 2 * self.block_size * self.d_model
    }

    /// Blocks needed to hold `positions` cached positions.
    pub fn blocks_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.block_size)
    }

    fn fresh_block(&self, id: usize) -> KvBlock {
        let plane = self.n_heads * self.block_size * self.head_dim;
        KvBlock {
            id,
            k: (0..self.n_layers).map(|_| vec![0.0; plane]).collect(),
            v: (0..self.n_layers).map(|_| vec![0.0; plane]).collect(),
        }
    }

    /// Take `n` blocks, all or nothing: `None` leaves the arena unchanged.
    /// Each granted block starts with refcount 1 (the caller).
    // lint: allow(indexing) — block ids are dense indices into `refs` by
    // construction (id < created == refs.len())
    fn alloc_n(&self, n: usize) -> Option<Vec<Arc<KvBlock>>> {
        let mut g = self.inner.lock().unwrap();
        if g.in_use + n > self.max_blocks {
            return None;
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let b = match g.free.pop() {
                Some(b) => b,
                None => {
                    let id = g.created;
                    g.created += 1;
                    g.refs.push(0);
                    Arc::new(self.fresh_block(id))
                }
            };
            g.refs[b.id] = 1;
            out.push(b);
        }
        g.in_use += n;
        Some(out)
    }

    /// Add one holder to each already-resident block and return the new
    /// handles. Costs no arena capacity: the blocks are already counted
    /// in `blocks_in_use`. This is how the prefix index pins committed
    /// blocks and how a cache attaches a shared prefix.
    // lint: allow(indexing) — block ids are dense indices into `refs` by
    // construction (id < created == refs.len())
    pub(crate) fn retain(&self, blocks: &[Arc<KvBlock>]) -> Vec<Arc<KvBlock>> {
        let mut g = self.inner.lock().unwrap();
        let mut out = Vec::with_capacity(blocks.len());
        for b in blocks {
            debug_assert!(g.refs[b.id] > 0, "retain of a non-resident block");
            g.refs[b.id] += 1;
            out.push(b.clone());
        }
        out
    }

    /// Drop one holder per handle. A block whose refcount reaches zero
    /// returns to the free pool (its `Arc` then has a single strong
    /// reference again, so the next allocator may write it); a block with
    /// surviving holders stays resident, untouched. Every release path —
    /// cache drop/clear/truncate, prefix-index eviction — funnels here,
    /// which is what makes "decrement exactly once per holder"
    /// structural.
    // lint: allow(indexing) — block ids are dense indices into `refs` by
    // construction (id < created == refs.len())
    pub(crate) fn release(&self, blocks: Vec<Arc<KvBlock>>) {
        if blocks.is_empty() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        for b in blocks {
            debug_assert!(g.refs[b.id] > 0, "release of a non-resident block");
            g.refs[b.id] -= 1;
            if g.refs[b.id] == 0 {
                g.in_use -= 1;
                g.free.push(b);
            }
        }
    }

    /// Current holder count of one block handle. `1` means the caller is
    /// the sole holder (the block is unpinned and evicting it would
    /// actually free arena capacity); `> 1` means it is shared with a
    /// live cache.
    // lint: allow(indexing) — block ids are dense indices into `refs` by
    // construction (id < created == refs.len())
    pub(crate) fn handle_refs(&self, block: &Arc<KvBlock>) -> usize {
        self.inner.lock().unwrap().refs[block.id]
    }
}

/// Growable per-sequence key/value cache: for each layer, the rotated K
/// and raw V projections of every position seen so far, stored as a
/// table of [`KvArena`] blocks. [`KvCache::bytes`] is the *blocks-held*
/// resident footprint — the number a residency-priced scheduler accounts
/// against — and grows by one [`KvArena::block_bytes`] step per
/// [`KvArena::block_size`] positions. A cache may share whole committed
/// blocks with other holders (see [`KvCache::attach_prefix`]); it only
/// ever *writes* blocks it exclusively owns.
pub struct KvCache {
    arena: Arc<KvArena>,
    blocks: Vec<Arc<KvBlock>>,
    len: usize,
}

impl KvCache {
    /// Empty cache with room for `dims.seq` positions, backed by its own
    /// single-owner arena (block size [`DEFAULT_BLOCK_POSITIONS`], enough
    /// blocks for the full window) — reservation within the window can
    /// never fail, matching the old contiguous-cache behavior for
    /// offline scoring and solo decode.
    pub fn new(dims: &ModelDims) -> KvCache {
        let bs = DEFAULT_BLOCK_POSITIONS.clamp(1, dims.seq.max(1));
        KvArena::new(dims, bs, dims.seq.div_ceil(bs)).new_cache()
    }

    /// The arena this cache draws blocks from.
    pub fn arena(&self) -> &Arc<KvArena> {
        &self.arena
    }

    /// Cached positions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum positions this cache can hold (`dims.seq` at build time).
    pub fn capacity(&self) -> usize {
        self.arena.window
    }

    /// Positions still available before the window is full.
    pub fn remaining(&self) -> usize {
        self.arena.window - self.len
    }

    /// Arena blocks currently held by this cache.
    pub fn blocks_held(&self) -> usize {
        self.blocks.len()
    }

    /// True when the cache was built for this model geometry.
    pub fn matches(&self, dims: &ModelDims) -> bool {
        self.arena.d_model == dims.d_model
            && self.arena.n_layers == dims.n_layers
            && self.arena.n_heads == dims.n_heads
            && self.arena.window == dims.seq
    }

    /// Ensure blocks are held for `n_new` more positions, drawing from
    /// the arena. All-or-nothing: `Err` (arena exhausted) leaves both the
    /// cache and the arena unchanged. Returns the number of blocks newly
    /// taken (0 when the held blocks already cover the growth).
    pub fn reserve(&mut self, n_new: usize) -> Result<usize> {
        let needed = self.arena.blocks_for(self.len + n_new);
        if needed <= self.blocks.len() {
            return Ok(0);
        }
        let add = needed - self.blocks.len();
        match self.arena.alloc_n(add) {
            Some(blocks) => {
                self.blocks.extend(blocks);
                Ok(add)
            }
            None => bail!(
                "KV arena exhausted: need {add} more block(s) for {n_new} new position(s), \
                 {} of {} free",
                self.arena.blocks_free(),
                self.arena.max_blocks
            ),
        }
    }

    /// Seed an **empty** cache with already-committed shared blocks
    /// covering `positions` positions (a whole number of blocks — partial
    /// boundary blocks are never shared; the engine re-prefills them
    /// privately). The handles must already carry this holder's refcount
    /// (come from [`KvArena::retain`]); attaching consumes no arena
    /// capacity. Subsequent appends land in freshly reserved private
    /// blocks, so the copy-on-write rule of [`KvCache::extend_layer`]
    /// holds by construction.
    pub(crate) fn attach_prefix(&mut self, blocks: Vec<Arc<KvBlock>>, positions: usize) {
        debug_assert!(self.blocks.is_empty() && self.len == 0, "attach into a non-empty cache");
        debug_assert_eq!(
            positions,
            blocks.len() * self.arena.block_size,
            "attached prefix must be whole blocks"
        );
        debug_assert!(positions <= self.arena.window);
        self.blocks = blocks;
        self.len = positions;
    }

    /// The block handles backing this cache, in position order — what the
    /// prefix index retains when a finished sequence's committed prefix
    /// is published for reuse.
    pub(crate) fn block_handles(&self) -> &[Arc<KvBlock>] {
        &self.blocks
    }

    /// Return any blocks not needed to hold the committed `len` positions
    /// to the arena (undo of a [`KvCache::reserve`] that was never
    /// committed — the batched forward's error path). Shared blocks are
    /// merely released (refcount decrement), never clobbered.
    pub(crate) fn release_uncommitted(&mut self) {
        let keep = self.arena.blocks_for(self.len);
        if self.blocks.len() > keep {
            let excess = self.blocks.split_off(keep);
            self.arena.release(excess);
        }
    }

    /// Roll back to a shorter prefix (`n <= len`). Rows past `n` are
    /// logically discarded and whole blocks past the prefix are released
    /// to the arena (a *decrement* — blocks also pinned by the prefix
    /// index stay resident for other holders); the next append overwrites
    /// every surviving stale row before it is read, so replaying the same
    /// suffix reproduces bitwise-identical state.
    pub fn truncate(&mut self, n: usize) {
        // lint: allow(panic) — caller contract (n <= len), pinned by the
        // should_panic unit test below; engine callers truncate to their
        // own recorded prefix lengths
        assert!(n <= self.len, "truncate({n}) past cache length {}", self.len);
        self.len = n;
        self.release_uncommitted();
    }

    /// Drop every cached position and release all held blocks (shared
    /// ones stay resident for their other holders).
    pub fn clear(&mut self) {
        self.len = 0;
        let blocks = std::mem::take(&mut self.blocks);
        self.arena.release(blocks);
    }

    /// Resident memory held via this cache right now, in bytes: blocks
    /// held × [`KvArena::block_bytes`]. Grows and shrinks with the
    /// sequence — this is the number `serve.kv_bytes` tracks. (Blocks
    /// shared with other holders are counted by each holder; the
    /// deduplicated fleet number is `blocks_in_use × block_bytes`.)
    pub fn bytes(&self) -> usize {
        self.blocks.len() * self.arena.block_bytes()
    }

    /// Worst-case resident bytes if the cache grew to the full window —
    /// the old constant `bytes()` the pre-paged scheduler priced
    /// admission with.
    pub fn capacity_bytes(&self) -> usize {
        self.arena.blocks_for(self.arena.window) * self.arena.block_bytes()
    }

    /// Append `n` new rows (taken from `k`/`v` starting at row `r0`) to
    /// one layer's planes at positions `len..len+n`, rotating K by each
    /// row's absolute position. The caller must have
    /// [`KvCache::reserve`]d the growth. Every layer of a forward step
    /// appends with the *same* base position; [`KvCache::commit`]
    /// advances `len` once after all layers ran.
    ///
    /// Copy-on-write enforcement: a write targets `Arc::get_mut`, which
    /// only yields the block when this cache is its sole holder. Shared
    /// prefixes are attached whole-block ([`KvCache::attach_prefix`]) and
    /// appends start past them in freshly reserved private blocks, so the
    /// exclusive-ownership check holds on every correct path.
    // lint: allow(indexing) — block/row offsets are bounded by the
    // debug-checked reserve contract (blocks_for(len+n) <= blocks.len())
    pub(crate) fn extend_layer(
        &mut self,
        layer: usize,
        rope: &RopeTable,
        k: &Mat,
        v: &Mat,
        r0: usize,
        n: usize,
    ) {
        debug_assert!(self.len + n <= self.arena.window, "kv cache overflow");
        debug_assert!(
            self.arena.blocks_for(self.len + n) <= self.blocks.len(),
            "kv cache append without reserve"
        );
        let (hd, bs) = (self.arena.head_dim, self.arena.block_size);
        for i in 0..n {
            let pos = self.len + i;
            let row = pos % bs;
            let block = match Arc::get_mut(&mut self.blocks[pos / bs]) {
                Some(b) => b,
                // lint: allow(panic) — copy-on-write backstop: appends only
                // target positions past the whole-block attach boundary, in
                // freshly reserved sole-owner blocks; writing a shared block
                // is a scheduler bug, not a servable state (should_panic test)
                None => panic!("KV copy-on-write violation: append into shared block at {pos}"),
            };
            let kb = &mut block.k[layer];
            let vb = &mut block.v[layer];
            let krow = k.row(r0 + i);
            let vrow = v.row(r0 + i);
            for h in 0..self.arena.n_heads {
                let off = (h * bs + row) * hd;
                kb[off..off + hd].copy_from_slice(&krow[h * hd..(h + 1) * hd]);
                rope.rotate(&mut kb[off..off + hd], pos);
                vb[off..off + hd].copy_from_slice(&vrow[h * hd..(h + 1) * hd]);
            }
        }
    }

    /// Advance the cached length after every layer appended its rows.
    pub(crate) fn commit(&mut self, n: usize) {
        debug_assert!(self.len + n <= self.arena.window);
        self.len += n;
    }

    /// One layer's K/V row segments over every held block, grouped
    /// head-major then ascending position: for each head, each block
    /// contributes one `(k, v)` pair of [`KvArena::block_size`] whole
    /// `head_dim` rows ([`KvCache::blocks_held`] segments per head).
    /// Rows beyond the valid length are garbage the attention kernel
    /// never reads (it stops at the causal bound). Shared blocks read
    /// exactly like private ones — attention never writes.
    // lint: allow(indexing) — layer < n_layers and o+seg <= plane length by
    // arena construction
    pub(crate) fn layer_segments(&self, layer: usize) -> Vec<(&[f32], &[f32])> {
        let (hd, bs) = (self.arena.head_dim, self.arena.block_size);
        let seg = bs * hd;
        let mut out = Vec::with_capacity(self.arena.n_heads * self.blocks.len());
        for h in 0..self.arena.n_heads {
            let o = h * seg;
            for b in &self.blocks {
                out.push((&b.k[layer][o..o + seg], &b.v[layer][o..o + seg]));
            }
        }
        out
    }
}

impl Drop for KvCache {
    fn drop(&mut self) {
        let blocks = std::mem::take(&mut self.blocks);
        self.arena.release(blocks);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims {
            name: "kv".into(),
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            vocab: 32,
            seq: 12,
            batch: 2,
            group_size: 8,
        }
    }

    #[test]
    fn rope_table_matches_reference_formula() {
        let hd = 8;
        let t = RopeTable::new(6, hd);
        for pos in 0..6 {
            for k in 0..hd / 2 {
                let freq = 10000f32.powf(-(2.0 * k as f32) / hd as f32);
                let (s, c) = (pos as f32 * freq).sin_cos();
                let mut probe = vec![0.0f32; hd];
                probe[2 * k] = 1.0;
                t.rotate(&mut probe, pos);
                assert_eq!(probe[2 * k], c, "pos {pos} k {k}");
                assert_eq!(probe[2 * k + 1], s, "pos {pos} k {k}");
            }
        }
    }

    #[test]
    fn shared_tables_are_reused_and_cover_smaller_requests() {
        let a = RopeTable::shared(10, 8);
        let b = RopeTable::shared(6, 8);
        assert!(b.max_pos() >= 6);
        assert_eq!(a.head_dim(), b.head_dim());
        // a table for a different head_dim is a different table
        let c = RopeTable::shared(10, 4);
        assert_eq!(c.head_dim(), 4);
    }

    #[test]
    fn cache_len_truncate_and_bytes() {
        let d = dims();
        let mut c = KvCache::new(&d);
        assert_eq!(c.len(), 0);
        assert!(c.is_empty());
        assert_eq!(c.capacity(), d.seq);
        assert_eq!(c.remaining(), d.seq);
        assert!(c.matches(&d));
        // an empty cache holds no blocks: zero resident bytes
        assert_eq!(c.bytes(), 0);
        assert_eq!(c.capacity_bytes(), 4 * 2 * d.n_layers * d.seq * d.d_model);
        // append 3 rows to every layer, then commit
        let rope = RopeTable::new(d.seq, d.head_dim());
        let k = Mat::full(3, d.d_model, 1.0);
        let v = Mat::full(3, d.d_model, 2.0);
        c.reserve(3).unwrap();
        for l in 0..d.n_layers {
            c.extend_layer(l, &rope, &k, &v, 0, 3);
        }
        c.commit(3);
        assert_eq!(c.len(), 3);
        assert_eq!(c.remaining(), d.seq - 3);
        // bytes is blocks-in-use (seq 12 fits one default-size block here)
        assert_eq!(c.bytes(), c.blocks_held() * c.arena().block_bytes());
        assert!(c.bytes() > 0 && c.bytes() <= c.capacity_bytes());
        c.truncate(1);
        assert_eq!(c.len(), 1);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.bytes(), 0);
    }

    #[test]
    fn arena_alloc_is_all_or_nothing_and_blocks_are_recycled() {
        let d = dims();
        // 3 blocks of 4 positions: window 12, deliberately tight
        let arena = KvArena::new(&d, 4, 3);
        assert_eq!(arena.block_size(), 4);
        assert_eq!(arena.blocks_for(0), 0);
        assert_eq!(arena.blocks_for(1), 1);
        assert_eq!(arena.blocks_for(4), 1);
        assert_eq!(arena.blocks_for(5), 2);

        let mut a = arena.new_cache();
        let mut b = arena.new_cache();
        a.reserve(8).unwrap(); // 2 blocks
        assert_eq!(a.blocks_held(), 2);
        assert_eq!(arena.blocks_free(), 1);
        // b wants 2 blocks but only 1 is free: Err, nothing granted
        let err = b.reserve(8).unwrap_err();
        assert!(format!("{err}").contains("arena exhausted"), "{err}");
        assert_eq!(b.blocks_held(), 0);
        assert_eq!(arena.blocks_free(), 1);
        // the single free block is still grantable
        b.reserve(4).unwrap();
        assert_eq!(arena.blocks_free(), 0);

        // freeing via truncate/clear/drop returns blocks for reuse
        b.clear();
        assert_eq!(arena.blocks_free(), 1);
        drop(a);
        assert_eq!(arena.blocks_free(), 3);
        assert_eq!(arena.blocks_in_use(), 0);
        // churn more caches through: no new blocks beyond the 3 created
        let created = arena.blocks_created();
        for _ in 0..5 {
            let mut c = arena.new_cache();
            c.reserve(12).unwrap();
            c.commit(12);
            c.truncate(3);
            assert_eq!(c.blocks_held(), 1);
        }
        assert_eq!(arena.blocks_created(), created);
        assert!(created <= 3);
    }

    #[test]
    fn shared_blocks_free_only_after_last_release() {
        let d = dims();
        let arena = KvArena::new(&d, 4, 3);
        let mut a = arena.new_cache();
        a.reserve(8).unwrap();
        a.commit(8);
        assert_eq!(arena.blocks_in_use(), 2);

        // pin both committed blocks as a second holder (the prefix-index
        // role): no extra arena capacity is consumed
        let pinned = arena.retain(a.block_handles());
        assert_eq!(arena.blocks_in_use(), 2);
        assert_eq!(arena.handle_refs(&pinned[0]), 2);

        // the first holder leaving keeps the blocks resident
        drop(a);
        assert_eq!(arena.blocks_in_use(), 2);
        assert_eq!(arena.blocks_free(), 1);
        assert_eq!(arena.handle_refs(&pinned[0]), 1);

        // a newcomer can take the one truly free block, but not the two
        // still pinned: the shared blocks are reused only after the LAST
        // release
        let mut c = arena.new_cache();
        c.reserve(4).unwrap();
        assert!(c.reserve(8).is_err());
        arena.release(pinned);
        assert_eq!(arena.blocks_in_use(), 1);
        c.reserve(8).unwrap();
        assert_eq!(arena.blocks_in_use(), 3);
        // recycling, not growth: the churn stayed within the 3 ever created
        assert!(arena.blocks_created() <= 3);
    }

    #[test]
    fn attach_prefix_shares_committed_blocks_positionally() {
        let d = dims();
        let arena = KvArena::new(&d, 4, 4);
        let rope = RopeTable::new(d.seq, d.head_dim());
        let k = Mat::full(8, d.d_model, 1.0);
        let v = Mat::full(8, d.d_model, 2.0);
        let mut a = arena.new_cache();
        a.reserve(8).unwrap();
        for l in 0..d.n_layers {
            a.extend_layer(l, &rope, &k, &v, 0, 8);
        }
        a.commit(8);

        // attach the two committed whole blocks to a fresh cache
        let shared = arena.retain(a.block_handles());
        let mut b = arena.new_cache();
        b.attach_prefix(shared, 8);
        assert_eq!(b.len(), 8);
        assert_eq!(b.blocks_held(), 2);
        assert_eq!(arena.blocks_in_use(), 2);
        // both caches read identical bits from the shared planes
        for l in 0..d.n_layers {
            let sa = a.layer_segments(l);
            let sb = b.layer_segments(l);
            assert_eq!(sa.len(), sb.len());
            for ((ka, va), (kb, vb)) in sa.iter().zip(sb.iter()) {
                assert!(std::ptr::eq(*ka, *kb) && std::ptr::eq(*va, *vb));
            }
        }
        // b grows past the shared prefix into its own private block
        b.reserve(2).unwrap();
        for l in 0..d.n_layers {
            b.extend_layer(l, &rope, &k, &v, 0, 2);
        }
        b.commit(2);
        assert_eq!(b.len(), 10);
        assert_eq!(arena.blocks_in_use(), 3);
        drop(b);
        assert_eq!(arena.blocks_in_use(), 2);
        drop(a);
        assert_eq!(arena.blocks_in_use(), 0);
    }

    #[test]
    #[should_panic(expected = "copy-on-write")]
    fn writing_a_shared_block_panics() {
        let d = dims();
        let arena = KvArena::new(&d, 4, 4);
        let rope = RopeTable::new(d.seq, d.head_dim());
        let k = Mat::full(4, d.d_model, 1.0);
        let v = Mat::full(4, d.d_model, 2.0);
        let mut a = arena.new_cache();
        a.reserve(4).unwrap();
        for l in 0..d.n_layers {
            a.extend_layer(l, &rope, &k, &v, 0, 4);
        }
        a.commit(4);
        let mut b = arena.new_cache();
        b.attach_prefix(arena.retain(a.block_handles()), 4);
        // roll b back INTO the shared block and try to append over it
        b.truncate(2);
        b.extend_layer(0, &rope, &k, &v, 0, 1);
    }

    #[test]
    #[should_panic(expected = "truncate")]
    fn truncate_past_len_panics() {
        let mut c = KvCache::new(&dims());
        c.truncate(1);
    }
}
