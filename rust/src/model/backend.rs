//! The linear execution engine: how `y = x · W_eff` actually runs.
//!
//! RILQ's deployable artifact is an adapter-merged *quantized* model
//! (`W_eff = deq(Q) + A·Bᵀ`), but historically the Rust evaluation path
//! always materialized dense f32 weights first. This module makes the
//! execution form a first-class choice behind one trait:
//!
//! * [`DenseLinear`] — dense f32 `Q` plus an *unmerged* rank-r correction
//!   `(x·A)·Bᵀ`; the native mirror of the `lora_mm` Pallas kernel. This is
//!   the only form available to rotation/VQ quantizers (QuaRot, QuIP#),
//!   whose dequant is not per-scalar, and to the fp teacher (a plain
//!   [`Mat`] also implements the trait).
//! * [`PackedLoraLinear`] — the W2A16 serving form and the native mirror of
//!   the `lora_qmm_packed` Pallas kernel: bit-packed codes are dequantized
//!   *group-by-group into a transient tile* that every activation row of
//!   the call then streams dense multiply-adds against (the full f32
//!   weight matrix is never materialized, and the decode cost amortizes
//!   across the rows a batched forward coalesces), followed by the same
//!   rank-r correction.
//!   Resident weight memory is the packed footprint: `bits`/8 bytes per
//!   weight + group (scale, zero) metadata + the scalar codebook.
//! * [`MergedDenseLinear`] — `Q + A·Bᵀ` materialized once; the parity
//!   oracle the other two backends are tested against, and the fastest
//!   form when memory is not a constraint.
//!
//! [`student_backends`] builds the per-(family, layer) engine for a
//! quantized student, and `TeacherParams::view_backends` (see
//! [`super::forward`]) plugs it into the shared forward pass. Everything
//! downstream — `Lab`, the coordinator driver, the CLI `--backend` flag,
//! and the runtime benches — selects an execution form via [`BackendKind`].

use std::cell::RefCell;
use std::fmt;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

use crate::lqec::AdapterSet;
use crate::quant::packing::codes_per_byte;
use crate::quant::{PackedTensor, QuantResult, QuantizedTensor};
use crate::tensor::{kernels, suggested_workers, Mat};

use super::StudentWeights;

/// Which execution engine to run quantized linears through.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Dense f32 dequantized weights + unmerged LoRA (current/default).
    Dense,
    /// Fused packed-code streaming dequant + LoRA (the serving form).
    Packed,
    /// Adapter-merged dense weights (parity oracle / fastest).
    Merged,
}

impl BackendKind {
    pub const ALL: [BackendKind; 3] =
        [BackendKind::Dense, BackendKind::Packed, BackendKind::Merged];

    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Dense => "dense",
            BackendKind::Packed => "packed",
            BackendKind::Merged => "merged",
        }
    }

    /// Parse a `--backend` flag value.
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "dense" => Ok(BackendKind::Dense),
            "packed" => Ok(BackendKind::Packed),
            "merged" => Ok(BackendKind::Merged),
            other => Err(anyhow!("unknown backend '{other}' (expected dense|packed|merged)")),
        }
    }
}

impl fmt::Display for BackendKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One linear layer's execution engine: `y = x · W_eff` for activations
/// `x: [tokens, d_in]`.
pub trait LinearBackend: Send + Sync {
    fn d_in(&self) -> usize;
    fn d_out(&self) -> usize;

    /// `y = x · W_eff`, `x: [T, d_in]` → `[T, d_out]`.
    fn forward(&self, x: &Mat) -> Mat;

    /// Resident weight-memory footprint in bytes (codes + metadata +
    /// adapters for packed; f32 matrices for dense forms).
    fn weight_bytes(&self) -> usize;

    /// Short engine label for reports/benches.
    fn label(&self) -> &'static str;
}

/// Dense matmul with a size-aware threading heuristic — shared by the
/// teacher path (`Mat` as a backend) and [`DenseLinear`].
fn dense_matmul(x: &Mat, w: &Mat) -> Mat {
    let workers = suggested_workers(x.rows() * w.rows() * w.cols());
    if workers > 1 {
        x.matmul_threaded(w, workers)
    } else {
        x.matmul(w)
    }
}

/// Add the rank-r correction `(x·A)·Bᵀ` into `y` — two skinny matmuls,
/// `A·Bᵀ` is never materialized (the `lora_mm` contraction order).
fn add_lora_correction(y: &mut Mat, x: &Mat, a: &Mat, b: &Mat) {
    let xa = dense_matmul(x, a); // [T, r]
    let r = a.cols();
    let workers = suggested_workers(x.rows() * r * b.rows());
    let corr = if workers > 1 {
        xa.matmul_t_threaded(b, workers)
    } else {
        xa.matmul_t(b)
    };
    y.axpy(1.0, &corr);
}

fn lora_bytes(lora: &Option<(Mat, Mat)>) -> usize {
    lora.as_ref().map(|(a, b)| 4 * (a.len() + b.len())).unwrap_or(0)
}

/// The fp teacher's linears execute as plain dense matmuls.
impl LinearBackend for Mat {
    fn d_in(&self) -> usize {
        self.rows()
    }

    fn d_out(&self) -> usize {
        self.cols()
    }

    fn forward(&self, x: &Mat) -> Mat {
        dense_matmul(x, self)
    }

    fn weight_bytes(&self) -> usize {
        4 * self.len()
    }

    fn label(&self) -> &'static str {
        "fp32"
    }
}

/// Dense f32 quantized weights with an optional *unmerged* LoRA pair:
/// `y = x·Q + (x·A)·Bᵀ`. `A: [d_in, r]`, `B: [d_out, r]`.
pub struct DenseLinear {
    pub w: Mat,
    pub lora: Option<(Mat, Mat)>,
}

impl DenseLinear {
    pub fn new(w: Mat, lora: Option<(Mat, Mat)>) -> DenseLinear {
        if let Some((a, b)) = &lora {
            // lint: allow(panic) — construction-time shape contract
            assert_eq!(a.rows(), w.rows(), "A rows must match d_in");
            // lint: allow(panic) — construction-time shape contract
            assert_eq!(b.rows(), w.cols(), "B rows must match d_out");
            // lint: allow(panic) — construction-time shape contract
            assert_eq!(a.cols(), b.cols(), "A/B rank mismatch");
        }
        DenseLinear { w, lora }
    }
}

impl LinearBackend for DenseLinear {
    fn d_in(&self) -> usize {
        self.w.rows()
    }

    fn d_out(&self) -> usize {
        self.w.cols()
    }

    fn forward(&self, x: &Mat) -> Mat {
        let mut y = dense_matmul(x, &self.w);
        if let Some((a, b)) = &self.lora {
            add_lora_correction(&mut y, x, a, b);
        }
        y
    }

    fn weight_bytes(&self) -> usize {
        4 * self.w.len() + lora_bytes(&self.lora)
    }

    fn label(&self) -> &'static str {
        "dense"
    }
}

/// Adapter-merged dense weights: `W_eff = Q + A·Bᵀ` materialized once.
pub struct MergedDenseLinear {
    pub w: Mat,
}

impl MergedDenseLinear {
    /// Merge `q + a·bᵀ` (either side optional for the no-adapter case).
    pub fn merge(q: Mat, lora: Option<(&Mat, &Mat)>) -> MergedDenseLinear {
        let w = match lora {
            Some((a, b)) => q.add(&a.matmul_t(b)),
            None => q,
        };
        MergedDenseLinear { w }
    }
}

impl LinearBackend for MergedDenseLinear {
    fn d_in(&self) -> usize {
        self.w.rows()
    }

    fn d_out(&self) -> usize {
        self.w.cols()
    }

    fn forward(&self, x: &Mat) -> Mat {
        dense_matmul(x, &self.w)
    }

    fn weight_bytes(&self) -> usize {
        4 * self.w.len()
    }

    fn label(&self) -> &'static str {
        "merged"
    }
}

/// The W2A16 serving engine: bit-packed codes with group-wise (scale,
/// zero) and a scalar codebook, dequantized *inside* the blocked matmul
/// inner loop, plus the rank-r LoRA correction.
///
/// Per output row the contraction is factored by group `g`:
///
/// ```text
/// y[t,j] = Σ_g ( scale[g,j] · Σ_{i∈g} x[t,i]·cb[code[i,j]]
///              + zero[g,j]  · Σ_{i∈g} x[t,i] )            + (x·A)·Bᵀ
/// ```
///
/// so the zero-point term costs one group-sum of `x` instead of a full
/// rank-1 pass, and scales/zeros are applied once per group rather than
/// per weight — the same factorization the Pallas kernel exploits with
/// `jnp.repeat`-free group metadata.
pub struct PackedLoraLinear {
    packed: PackedTensor,
    /// `[n_groups, d_out]`
    scales: Mat,
    /// `[n_groups, d_out]`
    zeros: Mat,
    /// `[2^bits]`
    codebook: Vec<f32>,
    /// One 256-entry dequant LUT per code lane of a packed byte
    /// (`codes_per_byte(bits)` lanes): `byte_luts[lane][byte] =
    /// codebook[(byte >> bits*lane) & mask]`. Decoding becomes a single
    /// indexed load per element — no shift, mask, or second codebook
    /// indirection in the inner loop — and stays **bitwise** the
    /// shift/mask decode by construction (pinned in the tests below).
    /// Process-shared per distinct `(bits, codebook)` — see
    /// [`shared_byte_luts`].
    byte_luts: Arc<Vec<[f32; 256]>>,
    group_size: usize,
    bits: u8,
    d_in: usize,
    d_out: usize,
    /// optional `(A: [d_in, r], B: [d_out, r])`
    pub lora: Option<(Mat, Mat)>,
}

/// Process-shared memo of [`build_byte_luts`] results, keyed by
/// `(bits, codebook)`: every linear quantized by the same method shares
/// one 1–4 KiB table set (the `RopeTable::shared` idiom), which is why
/// the LUTs are not part of per-linear [`LinearBackend::weight_bytes`]
/// accounting.
fn shared_byte_luts(codebook: &[f32], bits: u8) -> Arc<Vec<[f32; 256]>> {
    static MEMO: Mutex<Vec<(u8, Vec<u32>, Arc<Vec<[f32; 256]>>)>> = Mutex::new(Vec::new());
    let key: Vec<u32> = codebook.iter().map(|v| v.to_bits()).collect();
    let mut memo = MEMO.lock().unwrap();
    if let Some((_, _, luts)) = memo.iter().find(|(b, k, _)| *b == bits && *k == key) {
        return luts.clone();
    }
    let luts = Arc::new(build_byte_luts(codebook, bits));
    memo.push((bits, key, luts.clone()));
    luts
}

/// Build the per-lane byte→value dequant LUTs for a scalar codebook.
/// 2-bit: 4 lanes × 256; 4-bit: 2 lanes × 256; 3-bit (one code per
/// byte): 1 lane whose live entries are the 8-entry codebook itself.
// lint: allow(indexing) — the lane mask keeps `code < 2^bits <= codebook.len()`
fn build_byte_luts(codebook: &[f32], bits: u8) -> Vec<[f32; 256]> {
    let lanes = codes_per_byte(bits);
    let mask = (1usize << bits) - 1;
    (0..lanes)
        .map(|lane| {
            let shift = bits as usize * lane;
            let mut tab = [0.0f32; 256];
            for (byte, t) in tab.iter_mut().enumerate() {
                // the lane mask keeps `code < 2^bits`, so every entry is a
                // real codebook value (byte values that cannot occur in
                // the packed stream just repeat the table cyclically)
                let code = (byte >> shift) & mask;
                *t = codebook[code];
            }
            tab
        })
        .collect()
}

thread_local! {
    /// Per-thread dequant scratch for [`PackedLoraLinear::forward_rows`]:
    /// the group tile (`group_size * d_out`) plus the per-row partial-sum
    /// row (`d_out`), reused across every group, call, and layer instead
    /// of a fresh `Vec` per row-chunk — single-row decode steps no longer
    /// pay an allocation per (group, chunk). One buffer per pool worker;
    /// `forward_rows` never re-enters itself on a thread, so the borrow
    /// is exclusive for the kernel's duration.
    static PACKED_SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

impl PackedLoraLinear {
    /// Pack a scalar-codebook quantized tensor into the serving form.
    pub fn from_quantized(q: &QuantizedTensor, lora: Option<(Mat, Mat)>) -> PackedLoraLinear {
        if let Some((a, b)) = &lora {
            // lint: allow(panic) — construction-time shape contract
            assert_eq!(a.rows(), q.d_in, "A rows must match d_in");
            // lint: allow(panic) — construction-time shape contract
            assert_eq!(b.rows(), q.d_out, "B rows must match d_out");
            // lint: allow(panic) — construction-time shape contract
            assert_eq!(a.cols(), b.cols(), "A/B rank mismatch");
        }
        // lint: allow(panic) — construction-time shape contract
        assert_eq!(q.scales.rows(), q.n_groups(), "scales/groups mismatch");
        PackedLoraLinear {
            packed: q.pack(),
            scales: q.scales.clone(),
            zeros: q.zeros.clone(),
            byte_luts: shared_byte_luts(&q.codebook, q.bits),
            codebook: q.codebook.clone(),
            group_size: q.group_size,
            bits: q.bits,
            d_in: q.d_in,
            d_out: q.d_out,
            lora,
        }
    }

    /// Decode the packed codes of input rows `[r0, r1)` (one quantization
    /// group) into `tile`: `(r1-r0) x d_out` raw codebook values, scale
    /// and zero NOT applied (they are factored out per group in
    /// [`Self::forward_rows`]).
    ///
    /// Dequant is a pure table lookup (see [`build_byte_luts`]): on the
    /// byte-aligned fast path each packed byte is loaded **once** and
    /// scatters all of its `codes_per_byte` rows through the per-lane
    /// LUTs — no shift, mask, or codebook indirection in the inner loop.
    /// Group boundaries landing mid-byte (ragged `d_in`, group sizes not
    /// divisible by the packing factor) fall back to lane-at-a-time
    /// lookups of the same tables, so both paths stay **bitwise** the
    /// shift/mask reference ([`Self::decode_group_naive`], pinned below).
    // bitwise-pin: lut_decode_is_bitwise_shift_mask_decode
    // lint: hot — per-group dequant on the decode path; writes only into
    // the caller's tile
    // lint: allow(indexing) — row/lane offsets are bounded by the packed
    // geometry (r1 <= d_in, lane < codes_per_byte, byte indexes a [_; 256])
    fn decode_group(&self, r0: usize, r1: usize, tile: &mut [f32]) {
        let d_out = self.d_out;
        let data = &self.packed.data;
        let luts = &self.byte_luts[..];
        let per = codes_per_byte(self.bits);
        if per == 1 {
            // 3-bit: one code per byte — a direct gather through the LUT
            for i in r0..r1 {
                let prow = &data[i * d_out..(i + 1) * d_out];
                let trow = &mut tile[(i - r0) * d_out..(i - r0 + 1) * d_out];
                for (t, &c) in trow.iter_mut().zip(prow) {
                    *t = luts[0][c as usize];
                }
            }
            return;
        }
        let mut i = r0;
        while i < r1 {
            let prow = &data[(i / per) * d_out..(i / per + 1) * d_out];
            if i % per == 0 && i + per <= r1 {
                let base = (i - r0) * d_out;
                if per == 4 {
                    let (t0, rest) = tile[base..base + 4 * d_out].split_at_mut(d_out);
                    let (t1, rest) = rest.split_at_mut(d_out);
                    let (t2, t3) = rest.split_at_mut(d_out);
                    for (j, &b) in prow.iter().enumerate() {
                        let b = b as usize;
                        t0[j] = luts[0][b];
                        t1[j] = luts[1][b];
                        t2[j] = luts[2][b];
                        t3[j] = luts[3][b];
                    }
                } else {
                    let (t0, t1) = tile[base..base + 2 * d_out].split_at_mut(d_out);
                    for (j, &b) in prow.iter().enumerate() {
                        let b = b as usize;
                        t0[j] = luts[0][b];
                        t1[j] = luts[1][b];
                    }
                }
                i += per;
            } else {
                let lut = &luts[i % per];
                let trow = &mut tile[(i - r0) * d_out..(i - r0 + 1) * d_out];
                for (t, &b) in trow.iter_mut().zip(prow) {
                    *t = lut[b as usize];
                }
                i += 1;
            }
        }
    }

    /// The pre-LUT shift/mask/codebook decode, kept as the bitwise
    /// reference [`Self::decode_group`] is pinned against.
    #[cfg(test)]
    fn decode_group_naive(&self, r0: usize, r1: usize, tile: &mut [f32]) {
        let d_out = self.d_out;
        let cb = &self.codebook;
        let data = &self.packed.data;
        match self.bits {
            2 => {
                for i in r0..r1 {
                    let pr = i / 4;
                    let sh = 2 * (i % 4);
                    let prow = &data[pr * d_out..pr * d_out + d_out];
                    let trow = &mut tile[(i - r0) * d_out..(i - r0 + 1) * d_out];
                    for (t, &byte) in trow.iter_mut().zip(prow) {
                        *t = cb[((byte >> sh) & 3) as usize];
                    }
                }
            }
            4 => {
                for i in r0..r1 {
                    let pr = i / 2;
                    let sh = 4 * (i % 2);
                    let prow = &data[pr * d_out..pr * d_out + d_out];
                    let trow = &mut tile[(i - r0) * d_out..(i - r0 + 1) * d_out];
                    for (t, &byte) in trow.iter_mut().zip(prow) {
                        *t = cb[((byte >> sh) & 0xF) as usize];
                    }
                }
            }
            3 => {
                for i in r0..r1 {
                    let prow = &data[i * d_out..i * d_out + d_out];
                    let trow = &mut tile[(i - r0) * d_out..(i - r0 + 1) * d_out];
                    for (t, &code) in trow.iter_mut().zip(prow) {
                        *t = cb[code as usize];
                    }
                }
            }
            b => panic!("unsupported packed bits={b}"),
        }
    }

    /// The fused kernel over token rows `[t0, t1)`, accumulating into
    /// `out` (`(t1-t0) * d_out` zeroed floats).
    ///
    /// Group-tile structure: each group's codes are decoded **once per
    /// row-chunk** into an f32 tile (LUT decode, see
    /// [`Self::decode_group`]), then every row in the chunk streams
    /// 8-wide unrolled multiply-adds against the hot tile
    /// ([`kernels::axpy`] / [`kernels::scale_zero_combine`]). Per-token
    /// dequant cost is `d_in·d_out / chunk_rows` — it amortizes toward
    /// zero as the batched forward coalesces more rows per call. The
    /// tile and the per-row partial-sum row live in one thread-local
    /// scratch ([`PACKED_SCRATCH`]) reused across groups, calls, and
    /// layers — single-row decode steps no longer pay a fresh `Vec`
    /// per chunk. The per-group factorization
    /// `y += s_g·Σ x_i·cb[code] + z_g·Σ x_i` is unchanged.
    // bitwise-pin: packed_matches_dequant_dense, kernel_rows_are_chunk_invariant_bitwise
    // lint: hot — the packed serving kernel; scratch is thread-local
    // lint: allow(indexing) — group/row offsets are bounded by the packed
    // geometry (r1 <= d_in <= xrow.len(), tile/out sized by the caller)
    fn forward_rows(&self, x: &Mat, t0: usize, t1: usize, out: &mut [f32]) {
        if t0 == t1 {
            return;
        }
        let d_out = self.d_out;
        let gs = self.group_size;
        let n_groups = self.scales.rows();
        PACKED_SCRATCH.with(|cell| {
            let mut buf = cell.borrow_mut();
            let need = gs * d_out + d_out;
            if buf.len() < need {
                buf.resize(need, 0.0);
            }
            let (tile, rest) = buf.split_at_mut(gs * d_out);
            // per-(row, group) partial sums Σ x_i·cb[code_ij]
            let tmp = &mut rest[..d_out];
            for g in 0..n_groups {
                let r0 = g * gs;
                let r1 = (r0 + gs).min(self.d_in);
                self.decode_group(r0, r1, tile);
                let srow = self.scales.row(g);
                let zrow = self.zeros.row(g);
                for t in t0..t1 {
                    let xrow = x.row(t);
                    tmp.fill(0.0);
                    let mut xsum = 0.0f32;
                    for i in r0..r1 {
                        let xi = xrow[i];
                        xsum += xi;
                        if xi == 0.0 {
                            continue;
                        }
                        kernels::axpy(xi, &tile[(i - r0) * d_out..(i - r0 + 1) * d_out], tmp);
                    }
                    let orow = &mut out[(t - t0) * d_out..(t - t0) * d_out + d_out];
                    kernels::scale_zero_combine(orow, srow, tmp, xsum, zrow);
                }
            }
        });
    }
}

impl LinearBackend for PackedLoraLinear {
    fn d_in(&self) -> usize {
        self.d_in
    }

    fn d_out(&self) -> usize {
        self.d_out
    }

    fn forward(&self, x: &Mat) -> Mat {
        // lint: allow(panic) — activation geometry is fixed by the model
        // dims the caller validated at admission
        assert_eq!(x.cols(), self.d_in, "packed forward shape mismatch");
        let t = x.rows();
        let workers = suggested_workers(t * self.d_in * self.d_out);
        let data = crate::tensor::parallel_rows(t, self.d_out, workers, |r0, r1, out| {
            self.forward_rows(x, r0, r1, out)
        });
        let mut y = Mat::from_vec(t, self.d_out, data);
        if let Some((a, b)) = &self.lora {
            add_lora_correction(&mut y, x, a, b);
        }
        y
    }

    fn weight_bytes(&self) -> usize {
        self.packed.bytes()
            + 4 * (self.scales.len() + self.zeros.len() + self.codebook.len())
            + lora_bytes(&self.lora)
    }

    fn label(&self) -> &'static str {
        "packed"
    }
}

/// Build the per-(family, layer) execution engines for a quantized
/// student under the chosen backend. Adapters are optional; an all-zero
/// pair (the "no LQEC" baseline) skips the correction entirely.
///
/// `Packed` requires every linear to be in scalar-codebook form —
/// rotation/VQ quantizers (QuaRot, QuIP#) only produce effective dense
/// matrices and must run `dense`/`merged`.
pub fn student_backends(
    student: &StudentWeights,
    adapters: Option<&AdapterSet>,
    kind: BackendKind,
) -> Result<Vec<Vec<Box<dyn LinearBackend>>>> {
    let mut out: Vec<Vec<Box<dyn LinearBackend>>> = Vec::with_capacity(student.q.len());
    for (f, layers) in student.q.iter().enumerate() {
        let mut per: Vec<Box<dyn LinearBackend>> = Vec::with_capacity(layers.len());
        for (l, qr) in layers.iter().enumerate() {
            let lora = adapters.and_then(|ad| ad.lora_pair(f, l));
            let backend: Box<dyn LinearBackend> = match kind {
                BackendKind::Dense => Box::new(DenseLinear::new(qr.dequant(), lora)),
                BackendKind::Merged => Box::new(MergedDenseLinear::merge(
                    qr.dequant(),
                    lora.as_ref().map(|(a, b)| (a, b)),
                )),
                BackendKind::Packed => match qr {
                    QuantResult::Scalar(q) => Box::new(PackedLoraLinear::from_quantized(q, lora)),
                    QuantResult::Dense { .. } => bail!(
                        "quantizer '{}' produces no scalar codes (family {f}, layer {l}); \
                         the packed backend needs a scalar-codebook quantizer — \
                         use --backend dense or merged",
                        student.quantizer
                    ),
                },
            };
            per.push(backend);
        }
        out.push(per);
    }
    Ok(out)
}

/// Total resident weight memory of a built execution engine.
pub fn model_weight_bytes(linears: &[Vec<Box<dyn LinearBackend>>]) -> usize {
    // lint: allow(reduce) — usize byte count: exact, order-insensitive
    linears.iter().flatten().map(|b| b.weight_bytes()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{CalibCtx, Quantizer, Rtn};
    use crate::tensor::Rng;

    fn quantized(
        d_in: usize,
        d_out: usize,
        bits: u8,
        gs: usize,
        seed: u64,
    ) -> (Mat, QuantizedTensor) {
        let mut rng = Rng::seed(seed);
        let w = Mat::randn(d_in, d_out, &mut rng);
        let q = match Rtn::new(bits, gs).quantize(&w, &CalibCtx::default()) {
            QuantResult::Scalar(q) => q,
            _ => unreachable!(),
        };
        (w, q)
    }

    #[test]
    fn packed_matches_dequant_dense() {
        let mut rng = Rng::seed(201);
        for (d_in, gs, bits) in [(32, 8, 2), (24, 8, 3), (16, 16, 4), (40, 16, 2), (37, 16, 2)] {
            let (_, q) = quantized(d_in, 6, bits, gs, 300 + d_in as u64 + bits as u64);
            let x = Mat::randn(5, d_in, &mut rng);
            let dense = x.matmul(&q.dequant());
            let packed = PackedLoraLinear::from_quantized(&q, None).forward(&x);
            let rel = dense.fro_dist(&packed) / dense.fro_norm().max(1e-6);
            assert!(rel < 1e-5, "d_in={d_in} gs={gs} bits={bits} rel={rel}");
        }
    }

    /// PR-5 pin: the byte-LUT decode is BITWISE the shift/mask/codebook
    /// decode, for every bit width, on aligned groups, groups whose
    /// boundaries land mid-byte, and ragged final groups.
    #[test]
    fn lut_decode_is_bitwise_shift_mask_decode() {
        for (case, (bits, d_in, d_out, gs)) in [
            (0u64, (2u8, 64usize, 9usize, 16usize)), // aligned fast path
            (1, (2, 37, 5, 16)),                     // ragged final group
            (2, (2, 26, 3, 10)),                     // group boundary mid-byte
            (3, (3, 23, 4, 8)),                      // one code per byte
            (4, (4, 31, 6, 16)),                     // 2-lane packing, ragged
            (5, (4, 9, 3, 5)),                       // 2-lane, mid-byte groups
        ] {
            let (_, q) = quantized(d_in, d_out, bits, gs, 0x107 + case);
            let p = PackedLoraLinear::from_quantized(&q, None);
            for g in 0..q.n_groups() {
                let r0 = g * gs;
                let r1 = (r0 + gs).min(d_in);
                let mut lut = vec![0.0f32; (r1 - r0) * d_out];
                let mut naive = vec![0.0f32; (r1 - r0) * d_out];
                p.decode_group(r0, r1, &mut lut);
                p.decode_group_naive(r0, r1, &mut naive);
                for (a, b) in lut.iter().zip(&naive) {
                    assert_eq!(a.to_bits(), b.to_bits(), "bits={bits} d_in={d_in} group={g}");
                }
            }
        }
    }

    /// PR-5 property grid: the packed kernel matches the dense dequant
    /// oracle ≤1e-5 across odd token/shape counts for every bit width
    /// (token rows and d_out straddle the 8-lane unroll and the 4-row
    /// micro-tile; d_in straddles group and byte boundaries).
    #[test]
    fn packed_forward_property_grid() {
        let mut rng = Rng::seed(0x9a1d);
        for bits in [2u8, 3, 4] {
            for &t in &[1usize, 3, 7] {
                for &(d_in, gs) in &[(7usize, 8usize), (64, 16), (100, 16)] {
                    for &d_out in &[1usize, 3, 64, 100] {
                        let seed = 0x500 + bits as u64 + (t * d_in * d_out) as u64;
                        let (_, q) = quantized(d_in, d_out, bits, gs, seed);
                        let x = Mat::randn(t, d_in, &mut rng);
                        let dense = x.matmul(&q.dequant());
                        let packed = PackedLoraLinear::from_quantized(&q, None).forward(&x);
                        let rel = dense.fro_dist(&packed) / dense.fro_norm().max(1e-6);
                        assert!(
                            rel < 1e-5,
                            "bits={bits} t={t} d_in={d_in} gs={gs} d_out={d_out} rel={rel}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn packed_lora_matches_merged() {
        let mut rng = Rng::seed(202);
        let (_, q) = quantized(32, 10, 2, 8, 77);
        let a = Mat::randn(32, 4, &mut rng);
        let b = Mat::randn(10, 4, &mut rng);
        let x = Mat::randn(7, 32, &mut rng);
        let merged = MergedDenseLinear::merge(q.dequant(), Some((&a, &b))).forward(&x);
        let packed = PackedLoraLinear::from_quantized(&q, Some((a.clone(), b.clone()))).forward(&x);
        let dense = DenseLinear::new(q.dequant(), Some((a, b))).forward(&x);
        assert!(merged.fro_dist(&packed) / merged.fro_norm() < 1e-5);
        assert!(merged.fro_dist(&dense) / merged.fro_norm() < 1e-5);
    }

    #[test]
    fn packed_memory_is_fraction_of_dense_at_2bit() {
        let (_, q) = quantized(256, 64, 2, 64, 88);
        let packed = PackedLoraLinear::from_quantized(&q, None);
        let dense = DenseLinear::new(q.dequant(), None);
        assert!(
            packed.weight_bytes() * 4 < dense.weight_bytes(),
            "packed={} dense={}",
            packed.weight_bytes(),
            dense.weight_bytes()
        );
    }

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::parse("packed").unwrap(), BackendKind::Packed);
        assert_eq!(BackendKind::parse("dense").unwrap(), BackendKind::Dense);
        assert_eq!(BackendKind::parse("merged").unwrap(), BackendKind::Merged);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(BackendKind::Packed.to_string(), "packed");
    }

    #[test]
    fn mat_is_a_backend() {
        let mut rng = Rng::seed(203);
        let w = Mat::randn(12, 5, &mut rng);
        let x = Mat::randn(3, 12, &mut rng);
        let via_trait = LinearBackend::forward(&w, &x);
        assert!(via_trait.fro_dist(&x.matmul(&w)) < 1e-6);
        assert_eq!(w.weight_bytes(), 4 * 12 * 5);
    }
}
