//! End-to-end experiment benchmarks — one timed entry per paper table /
//! figure (the harness of deliverable (d)). Each case runs the same code
//! path as `rilq experiment <id>` against the shared run cache, so cold
//! timings reflect full regeneration cost and warm timings the cached
//! pipeline. Select a subset: `cargo bench --bench bench_tables -- fig3b`.

use rilq::experiments::catalog;
use rilq::experiments::pipeline::Lab;
use rilq::report::bench::fmt_time;
use rilq::runtime::Runtime;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping bench_tables: run `make artifacts` first");
        return;
    }
    let filter: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let rt = Runtime::new("artifacts").expect("runtime");

    // bench-mode lab settings: small budgets so a full sweep is feasible
    for exp in catalog() {
        if !filter.is_empty() && !filter.iter().any(|f| exp.id.contains(f.as_str())) {
            continue;
        }
        // heavy experiments are included only when explicitly filtered
        if filter.is_empty() && matches!(exp.id, "table9" | "e2e" | "table2" | "table3") {
            println!("bench tables/{:<8} skipped by default (pass `-- {}` to run)", exp.id, exp.id);
            continue;
        }
        let mut lab = Lab::new(&rt);
        lab.calib.max_steps = 40;
        lab.calib.n_samples = 64;
        let t0 = std::time::Instant::now();
        match (exp.run)(&mut lab) {
            Ok(tables) => {
                println!(
                    "bench tables/{:<8} {:>12}   ({} table(s), {})",
                    exp.id,
                    fmt_time(t0.elapsed().as_secs_f64()),
                    tables.len(),
                    exp.paper_ref
                );
            }
            Err(e) => println!("bench tables/{:<8} FAILED: {e:?}", exp.id),
        }
    }
}
