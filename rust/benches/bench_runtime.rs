//! Runtime (L3 hot path) benchmarks.
//!
//! Section 1 (always runs, PJRT-free): the native `LinearBackend`
//! execution engines — dense vs fused packed-2-bit + LoRA vs
//! adapter-merged — with tokens/s throughput, the resident weight-memory
//! comparison (the W2A16 claim: packed < 1/4 of dense f32), and the
//! threaded-vs-single-threaded tiled matmul.
//!
//! Section 2 (requires `make artifacts`): PJRT execute latency for the
//! forward and train-step artifacts and marshalling overhead.

use rilq::eval::{BackendScorer, Scorer};
use rilq::lqec::AdapterSet;
use rilq::model::backend::BackendKind;
use rilq::model::{ModelDims, StudentWeights, TeacherParams};
use rilq::quant::{CalibCtx, Rtn};
use rilq::report::Bench;
use rilq::runtime::bindings::Bindings;
use rilq::runtime::Runtime;
use rilq::tensor::{Mat, Rng};

fn main() {
    bench_native_backends();
    bench_threaded_matmul();

    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping PJRT section of bench_runtime: run `make artifacts` first");
        return;
    }
    let rt = Runtime::new("artifacts").expect("runtime");
    for config in ["tiny", "small"] {
        bench_config(&rt, config);
    }
    let (secs, count) = rt.exec_stats();
    println!("total PJRT execute: {count} calls, {secs:.2}s");
}

/// Geometry for the native-engine section: big enough that weight
/// streaming dominates, grouped like the paper's W2 g64/g128 setups.
fn native_dims() -> ModelDims {
    ModelDims {
        name: "bench".into(),
        d_model: 256,
        n_layers: 4,
        n_heads: 8,
        d_ff: 512,
        vocab: 512,
        seq: 64,
        batch: 4,
        group_size: 64,
    }
}

fn bench_native_backends() {
    let dims = native_dims();
    let mut rng = Rng::seed(0xba9e);
    let teacher = TeacherParams::init(&dims, &mut rng);
    let quant = Rtn::new(2, dims.group_size);
    let student = StudentWeights::quantize(&dims, &teacher, &quant, &|_, _| CalibCtx::default());
    // nonzero adapters so the rank-r correction is actually exercised
    let rank = 8;
    let mut adapters = AdapterSet::zeros(&dims, rank);
    for f in 0..7 {
        for l in 0..dims.n_layers {
            let (di, do_) = dims.linear_dims(rilq::model::LINEARS[f]);
            adapters.set(
                f,
                l,
                Mat::randn(di, rank, &mut rng).scale(0.01),
                Mat::randn(do_, rank, &mut rng).scale(0.01),
            );
        }
    }
    let batch: Vec<Vec<u32>> = (0..dims.batch)
        .map(|_| (0..dims.seq).map(|_| rng.below(dims.vocab) as u32).collect())
        .collect();
    let tokens_per_exec = (dims.batch * dims.seq) as f64;

    let b = Bench::new("native_backend").iters(2, 8);
    let mut weight_bytes = Vec::new();
    for kind in BackendKind::ALL {
        let scorer = BackendScorer::new(&dims, &teacher, &student, Some(&adapters), kind)
            .expect("backend build");
        weight_bytes.push((kind, scorer.weight_bytes()));
        b.run_throughput(&format!("student_fwd_{kind} tokens/s"), tokens_per_exec, || {
            scorer.score_batch(&batch).unwrap()
        });
    }

    // the W2A16 memory claim: packed resident weights < 1/4 of dense f32
    let dense = weight_bytes
        .iter()
        .find(|(k, _)| *k == BackendKind::Dense)
        .map(|(_, n)| *n)
        .unwrap();
    for (kind, bytes) in &weight_bytes {
        println!(
            "weight-memory {kind:<7} {:>10} bytes  ({:.2}x vs dense f32)",
            bytes,
            *bytes as f64 / dense as f64
        );
    }
    let packed = weight_bytes
        .iter()
        .find(|(k, _)| *k == BackendKind::Packed)
        .map(|(_, n)| *n)
        .unwrap();
    assert!(
        packed * 4 < dense,
        "packed weight memory ({packed}) must be < 1/4 of dense ({dense})"
    );
}

fn bench_threaded_matmul() {
    let mut rng = Rng::seed(0x7ead);
    let x = Mat::randn(256, 1024, &mut rng);
    let w = Mat::randn(1024, 1024, &mut rng);
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let b = Bench::new("tiled_matmul").iters(2, 8);
    let single = b.run("single-thread 256x1024x1024", || x.matmul(&w));
    let threaded = b.run(&format!("threaded({workers}) 256x1024x1024"), || {
        x.matmul_threaded(&w, workers)
    });
    let bt = w.t();
    b.run("matmul_t blocked 256x1024x1024", || x.matmul_t(&bt));
    println!(
        "threaded speedup: {:.2}x over single-threaded (p50)",
        single.summary.p50 / threaded.summary.p50.max(1e-12)
    );
}

fn bench_config(rt: &Runtime, config: &str) {
    let dims = rt.manifest.dims(config).unwrap().clone();
    let mut rng = Rng::seed(0xbe9c);
    let teacher = TeacherParams::init(&dims, &mut rng);
    let quant = Rtn::new(2, dims.group_size);
    let student = StudentWeights::quantize(&dims, &teacher, &quant, &|_, _| CalibCtx::default());
    let rank = *rt.manifest.ranks[config].iter().min().unwrap();
    let adapters = AdapterSet::init_default(&dims, rank, &mut rng, 0.01);
    let batch: Vec<Vec<u32>> = (0..dims.batch)
        .map(|_| (0..dims.seq).map(|_| rng.below(dims.vocab) as u32).collect())
        .collect();
    let tokens_per_exec = (dims.batch * dims.seq) as f64;

    // ---- teacher forward ----------------------------------------------
    let tname = format!("teacher_fwd_{config}");
    let tspec = rt.manifest.artifact(&tname).unwrap().clone();
    let mut base = Bindings::new();
    base.teacher(&teacher);
    rt.load(&tname).unwrap();
    let b = Bench::new(format!("exec_{config}")).iters(2, 10);
    b.run_throughput("teacher_fwd tokens/s", tokens_per_exec, || {
        let mut bi = Bindings::new();
        bi.copy_from(&base).tokens(&batch, &dims);
        rt.run(&tname, &bi.to_literals(&tspec).unwrap()).unwrap()
    });

    // marshalling alone (literal creation for the full input list)
    b.run("teacher_fwd marshalling-only", || {
        let mut bi = Bindings::new();
        bi.copy_from(&base).tokens(&batch, &dims);
        bi.to_literals(&tspec).unwrap()
    });

    // §Perf A/B: device-cached static inputs (weights uploaded once; only
    // the token batch transfers per call) vs the literal path above
    let dev = base.to_device(rt, &tspec, &["tokens"]).unwrap();
    b.run_throughput("teacher_fwd DEVICE-CACHED tokens/s", tokens_per_exec, || {
        let mut dynb = Bindings::new();
        dynb.tokens(&batch, &dims);
        let asm = dev.assemble(rt, &tspec, &dynb).unwrap();
        rt.run_b(&tname, &asm.refs()).unwrap()
    });

    // ---- student forward: dense vs packed (the W2A16 serving claim) ----
    let sname = format!("student_fwd_{config}_r{rank}");
    let sspec = rt.manifest.artifact(&sname).unwrap().clone();
    let mut sbase = Bindings::new();
    sbase.teacher(&teacher).qweights(&student).adapters("ad.", &adapters.to_flat());
    rt.load(&sname).unwrap();
    b.run_throughput("student_fwd_dense tokens/s", tokens_per_exec, || {
        let mut bi = Bindings::new();
        bi.copy_from(&sbase).tokens(&batch, &dims);
        rt.run(&sname, &bi.to_literals(&sspec).unwrap()).unwrap()
    });

    let pname = format!("student_fwd_packed_{config}_r{rank}_w2");
    if let Ok(pspec) = rt.manifest.artifact(&pname).map(Clone::clone) {
        let mut packed = Vec::new();
        let mut scales = Vec::new();
        let mut zeros = Vec::new();
        let mut codebook = Vec::new();
        for fam in 0..7 {
            let mut fp = Vec::new();
            let mut fs = Vec::new();
            let mut fz = Vec::new();
            for l in 0..dims.n_layers {
                let q = student.q[fam][l].as_scalar().unwrap();
                fp.push(q.pack());
                fs.extend_from_slice(q.scales.data());
                fz.extend_from_slice(q.zeros.data());
                codebook = q.codebook.clone();
            }
            packed.push(fp);
            scales.push(fs);
            zeros.push(fz);
        }
        let mut pbase = Bindings::new();
        pbase
            .teacher(&teacher)
            .packed(&packed, &scales, &zeros, &codebook)
            .adapters("ad.", &adapters.to_flat());
        rt.load(&pname).unwrap();
        b.run_throughput("student_fwd_packed tokens/s", tokens_per_exec, || {
            let mut bi = Bindings::new();
            bi.copy_from(&pbase).tokens(&batch, &dims);
            rt.run(&pname, &bi.to_literals(&pspec).unwrap()).unwrap()
        });
    }

    // ---- train step (the calibration loop body) -------------------------
    let trname = format!(
        "train_step_{config}_r{rank}_{}",
        rt.manifest.scopes[config].first().map(String::as_str).unwrap_or("model_gt")
    );
    if let Ok(trspec) = rt.manifest.artifact(&trname).map(Clone::clone) {
        let ad_flat = adapters.to_flat();
        let m_flat = adapters.zeros_like_flat();
        let v_flat = adapters.zeros_like_flat();
        rt.load(&trname).unwrap();
        let mut tb = Bindings::new();
        tb.teacher(&teacher).qweights(&student);
        b.run_throughput("train_step tokens/s", tokens_per_exec, || {
            let mut bi = Bindings::new();
            bi.copy_from(&tb)
                .adapters("ad.", &ad_flat)
                .adapters("m.", &m_flat)
                .adapters("v.", &v_flat)
                .step_lr(1.0, 1e-3)
                .tokens(&batch, &dims);
            rt.run(&trname, &bi.to_literals(&trspec).unwrap()).unwrap()
        });
    }
}
