//! Runtime (L3 hot path) benchmarks: PJRT execute latency for the forward
//! and train-step artifacts, marshalling overhead, and the packed-vs-dense
//! serving comparison (the W2A16 claim). Requires `make artifacts`.

use rilq::lqec::AdapterSet;
use rilq::model::{StudentWeights, TeacherParams};
use rilq::quant::{CalibCtx, Rtn};
use rilq::report::Bench;
use rilq::runtime::bindings::Bindings;
use rilq::runtime::Runtime;
use rilq::tensor::Rng;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping bench_runtime: run `make artifacts` first");
        return;
    }
    let rt = Runtime::new("artifacts").expect("runtime");
    for config in ["tiny", "small"] {
        bench_config(&rt, config);
    }
    let (secs, count) = rt.exec_stats();
    println!("total PJRT execute: {count} calls, {secs:.2}s");
}

fn bench_config(rt: &Runtime, config: &str) {
    let dims = rt.manifest.dims(config).unwrap().clone();
    let mut rng = Rng::seed(0xbe9c);
    let teacher = TeacherParams::init(&dims, &mut rng);
    let quant = Rtn::new(2, dims.group_size);
    let student = StudentWeights::quantize(&dims, &teacher, &quant, &|_, _| CalibCtx::default());
    let rank = *rt.manifest.ranks[config].iter().min().unwrap();
    let adapters = AdapterSet::init_default(&dims, rank, &mut rng, 0.01);
    let batch: Vec<Vec<u32>> = (0..dims.batch)
        .map(|_| (0..dims.seq).map(|_| rng.below(dims.vocab) as u32).collect())
        .collect();
    let tokens_per_exec = (dims.batch * dims.seq) as f64;

    // ---- teacher forward ----------------------------------------------
    let tname = format!("teacher_fwd_{config}");
    let tspec = rt.manifest.artifact(&tname).unwrap().clone();
    let mut base = Bindings::new();
    base.teacher(&teacher);
    rt.load(&tname).unwrap();
    let b = Bench::new(format!("exec_{config}")).iters(2, 10);
    b.run_throughput("teacher_fwd tokens/s", tokens_per_exec, || {
        let mut bi = Bindings::new();
        bi.copy_from(&base).tokens(&batch, &dims);
        rt.run(&tname, &bi.to_literals(&tspec).unwrap()).unwrap()
    });

    // marshalling alone (literal creation for the full input list)
    b.run("teacher_fwd marshalling-only", || {
        let mut bi = Bindings::new();
        bi.copy_from(&base).tokens(&batch, &dims);
        bi.to_literals(&tspec).unwrap()
    });

    // §Perf A/B: device-cached static inputs (weights uploaded once; only
    // the token batch transfers per call) vs the literal path above
    let dev = base.to_device(rt, &tspec, &["tokens"]).unwrap();
    b.run_throughput("teacher_fwd DEVICE-CACHED tokens/s", tokens_per_exec, || {
        let mut dynb = Bindings::new();
        dynb.tokens(&batch, &dims);
        let asm = dev.assemble(rt, &tspec, &dynb).unwrap();
        rt.run_b(&tname, &asm.refs()).unwrap()
    });

    // ---- student forward: dense vs packed (the W2A16 serving claim) ----
    let sname = format!("student_fwd_{config}_r{rank}");
    let sspec = rt.manifest.artifact(&sname).unwrap().clone();
    let mut sbase = Bindings::new();
    sbase.teacher(&teacher).qweights(&student).adapters("ad.", &adapters.to_flat());
    rt.load(&sname).unwrap();
    b.run_throughput("student_fwd_dense tokens/s", tokens_per_exec, || {
        let mut bi = Bindings::new();
        bi.copy_from(&sbase).tokens(&batch, &dims);
        rt.run(&sname, &bi.to_literals(&sspec).unwrap()).unwrap()
    });

    let pname = format!("student_fwd_packed_{config}_r{rank}_w2");
    if let Ok(pspec) = rt.manifest.artifact(&pname).map(Clone::clone) {
        let mut packed = Vec::new();
        let mut scales = Vec::new();
        let mut zeros = Vec::new();
        let mut codebook = Vec::new();
        for fam in 0..7 {
            let mut fp = Vec::new();
            let mut fs = Vec::new();
            let mut fz = Vec::new();
            for l in 0..dims.n_layers {
                let q = student.q[fam][l].as_scalar().unwrap();
                fp.push(q.pack());
                fs.extend_from_slice(q.scales.data());
                fz.extend_from_slice(q.zeros.data());
                codebook = q.codebook.clone();
            }
            packed.push(fp);
            scales.push(fs);
            zeros.push(fz);
        }
        let mut pbase = Bindings::new();
        pbase
            .teacher(&teacher)
            .packed(&packed, &scales, &zeros, &codebook)
            .adapters("ad.", &adapters.to_flat());
        rt.load(&pname).unwrap();
        b.run_throughput("student_fwd_packed tokens/s", tokens_per_exec, || {
            let mut bi = Bindings::new();
            bi.copy_from(&pbase).tokens(&batch, &dims);
            rt.run(&pname, &bi.to_literals(&pspec).unwrap()).unwrap()
        });
    }

    // ---- train step (the calibration loop body) -------------------------
    let trname = format!(
        "train_step_{config}_r{rank}_{}",
        rt.manifest.scopes[config].first().map(String::as_str).unwrap_or("model_gt")
    );
    if let Ok(trspec) = rt.manifest.artifact(&trname).map(Clone::clone) {
        let ad_flat = adapters.to_flat();
        let m_flat = adapters.zeros_like_flat();
        let v_flat = adapters.zeros_like_flat();
        rt.load(&trname).unwrap();
        let mut tb = Bindings::new();
        tb.teacher(&teacher).qweights(&student);
        b.run_throughput("train_step tokens/s", tokens_per_exec, || {
            let mut bi = Bindings::new();
            bi.copy_from(&tb)
                .adapters("ad.", &ad_flat)
                .adapters("m.", &m_flat)
                .adapters("v.", &v_flat)
                .step_lr(1.0, 1e-3)
                .tokens(&batch, &dims);
            rt.run(&trname, &bi.to_literals(&trspec).unwrap()).unwrap()
        });
    }
}
