//! Runtime (L3 hot path) benchmarks.
//!
//! Section 1 (always runs, PJRT-free): the native `LinearBackend`
//! execution engines — dense vs fused packed-2-bit + LoRA vs
//! adapter-merged — with tokens/s throughput **and per-kernel GFLOP/s**,
//! the resident weight-memory comparison (the W2A16 claim: packed < 1/4
//! of dense f32), the continuous-batching serve loop vs the per-sequence
//! scoring path, the threaded-vs-single-threaded tiled matmul, and a
//! seeded two-tenant overload trace replayed through the load-aware
//! engine (SLO goodput, sheds by class, TTFT percentiles).
//!
//! Section 2 (requires `make artifacts`): PJRT execute latency for the
//! forward and train-step artifacts and marshalling overhead.
//!
//! `--smoke` (used by CI) shrinks the geometry and iteration counts so
//! the native sections compile and execute in seconds, and skips the
//! PJRT section.
//!
//! `--json <path>` writes the whole run as a machine-readable perf
//! record (`BENCH_PR6.json` in CI, uploaded as a workflow artifact) so
//! the perf trajectory is recorded instead of scrolling away in logs;
//! `--baseline <path>` loads a previous record (CI passes the committed
//! `BENCH_BASELINE.json`) and **fails the run** when packed tok/s or the
//! machine-relative ratios (packed/merged, serve speedup, decode
//! speedup) regress past their floors.

use rilq::coordinator::{probe_decode, probe_throughput};
use rilq::engine::{Engine, EngineConfig, SamplingParams};
use rilq::eval::{BackendScorer, Scorer};
use rilq::lqec::AdapterSet;
use rilq::model::backend::BackendKind;
use rilq::model::{ModelDims, StudentWeights, TeacherParams};
use rilq::quant::{CalibCtx, Rtn};
use rilq::report::{Bench, Json};
use rilq::runtime::bindings::Bindings;
use rilq::runtime::Runtime;
use rilq::tensor::{Mat, Rng};

/// Regression floor for the packed engine relative to the merged-dense
/// oracle at the same geometry (asserted in smoke mode too, so CI fails
/// loudly). Pre-PR-5 the packed kernel sustained roughly 0.3–0.5x of
/// merged tok/s here; with LUT dequant + the vectorized micro-tiles it
/// sits well above that. 0.20 only trips on an order-of-magnitude
/// kernel regression (losing group-tile amortization, LUT decode, or
/// the vectorized inner loops), not on CI timer noise.
const MIN_PACKED_VS_MERGED: f64 = 0.20;

/// `--baseline` floor for absolute packed tok/s. The committed
/// `BENCH_BASELINE.json` carries a deliberately conservative value (a
/// floor, not one machine's snapshot), so with this multiplier the check
/// only trips on an order-of-magnitude throughput collapse — never on
/// runner-to-runner hardware variance.
const MIN_TOKS_VS_BASELINE: f64 = 0.35;

/// `--baseline` floor for the machine-relative ratios (packed/merged
/// tok-rate, batched-serve speedup, incremental-decode speedup). Ratios
/// divide out the hardware, so 0.5x of the recorded value is already a
/// structural regression, not noise.
const MIN_RATIO_VS_BASELINE: f64 = 0.5;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = opt_value(&args, "--json");
    let baseline_path = opt_value(&args, "--baseline");
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    let native = bench_native_backends(smoke);
    let serve = bench_serve_loop(smoke);
    let decode = bench_decode(smoke);
    let matmul = bench_threaded_matmul(smoke);
    let trace = bench_trace(smoke);

    let mut root: Vec<(&str, Json)> = vec![
        ("bench", Json::str("bench_runtime")),
        ("smoke", Json::Bool(smoke)),
        ("cores", Json::num(cores as f64)),
    ];
    let mut regressions: Vec<String> = Vec::new();
    if let Some(bp) = &baseline_path {
        match std::fs::read_to_string(bp).ok().and_then(|t| Json::parse(&t).ok()) {
            Some(base) => {
                check_vs_baseline(
                    "packed tok/s",
                    "packed_speedup_vs_baseline",
                    get_path(&native, &["backends", "packed", "tokens_per_sec"]),
                    get_path(&base, &["native_backends", "backends", "packed", "tokens_per_sec"]),
                    MIN_TOKS_VS_BASELINE,
                    &mut root,
                    &mut regressions,
                );
                check_vs_baseline(
                    "packed/merged ratio",
                    "packed_vs_merged_vs_baseline",
                    get_path(&native, &["packed_vs_merged_ratio"]),
                    get_path(&base, &["native_backends", "packed_vs_merged_ratio"]),
                    MIN_RATIO_VS_BASELINE,
                    &mut root,
                    &mut regressions,
                );
                check_vs_baseline(
                    "serve speedup",
                    "serve_speedup_vs_baseline",
                    get_path(&serve, &["speedup"]),
                    get_path(&base, &["serve_loop", "speedup"]),
                    MIN_RATIO_VS_BASELINE,
                    &mut root,
                    &mut regressions,
                );
                check_vs_baseline(
                    "decode speedup",
                    "decode_speedup_vs_baseline",
                    get_path(&decode, &["speedup"]),
                    get_path(&base, &["decode", "speedup"]),
                    MIN_RATIO_VS_BASELINE,
                    &mut root,
                    &mut regressions,
                );
            }
            None => eprintln!("could not parse baseline {bp}; skipping compare"),
        }
    }
    root.push(("native_backends", native));
    root.push(("serve_loop", serve));
    root.push(("decode", decode));
    root.push(("matmul", matmul));
    root.push(("trace", trace));

    if let Some(path) = &json_path {
        let record = Json::obj(root);
        std::fs::write(path, record.to_string_pretty())
            .unwrap_or_else(|e| panic!("writing perf record {path}: {e}"));
        println!("perf record written to {path}");
    }

    // fail AFTER the record is on disk, so CI still uploads the artifact
    // that shows what regressed
    assert!(
        regressions.is_empty(),
        "perf regression vs baseline:\n  {}",
        regressions.join("\n  ")
    );

    if smoke {
        println!("--smoke: skipping PJRT section");
        return;
    }
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping PJRT section of bench_runtime: run `make artifacts` first");
        return;
    }
    let rt = Runtime::new("artifacts").expect("runtime");
    for config in ["tiny", "small"] {
        bench_config(&rt, config);
    }
    let (secs, count) = rt.exec_stats();
    println!("total PJRT execute: {count} calls, {secs:.2}s");
}

/// `--key value` or `--key=value` from the raw bench arg list.
fn opt_value(args: &[String], key: &str) -> Option<String> {
    let prefix = format!("{key}=");
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if a == key {
            return it.next().cloned();
        }
        if let Some(v) = a.strip_prefix(&prefix) {
            return Some(v.to_string());
        }
    }
    None
}

/// Walk nested JSON objects and read a number.
fn get_path(j: &Json, path: &[&str]) -> Option<f64> {
    let mut cur = j;
    for k in path {
        cur = cur.get(k)?;
    }
    cur.as_f64()
}

/// Compare one metric against the committed baseline: print the ratio,
/// record it in the JSON root, and queue a failure when it falls below
/// `floor`. Missing values on either side skip the check with a note
/// (old baselines predate some metrics) instead of failing the run.
fn check_vs_baseline(
    label: &str,
    key: &'static str,
    cur: Option<f64>,
    prev: Option<f64>,
    floor: f64,
    root: &mut Vec<(&'static str, Json)>,
    regressions: &mut Vec<String>,
) {
    let (Some(cur), Some(prev)) = (cur, prev) else {
        eprintln!("baseline compare: {label} missing on one side; skipping");
        return;
    };
    if prev <= 0.0 {
        return;
    }
    let ratio = cur / prev;
    println!("{label} vs baseline: {cur:.2} / {prev:.2} = {ratio:.2}x (floor {floor})");
    root.push((key, Json::num(ratio)));
    if ratio < floor {
        regressions.push(format!(
            "{label} fell to {ratio:.2}x of baseline ({cur:.2} vs {prev:.2}, floor {floor})"
        ));
    }
}

/// Geometry for the native-engine section: big enough that weight
/// streaming dominates, grouped like the paper's W2 g64/g128 setups.
/// `--smoke` shrinks it to a compile-and-run sanity size.
fn native_dims(smoke: bool) -> ModelDims {
    ModelDims {
        name: "bench".into(),
        d_model: if smoke { 64 } else { 256 },
        n_layers: if smoke { 2 } else { 4 },
        n_heads: 8,
        d_ff: if smoke { 128 } else { 512 },
        vocab: if smoke { 128 } else { 512 },
        seq: if smoke { 16 } else { 64 },
        batch: 4,
        group_size: if smoke { 32 } else { 64 },
    }
}

fn bench_native_backends(smoke: bool) -> Json {
    let dims = native_dims(smoke);
    let mut rng = Rng::seed(0xba9e);
    let teacher = TeacherParams::init(&dims, &mut rng);
    let quant = Rtn::new(2, dims.group_size);
    let student = StudentWeights::quantize(&dims, &teacher, &quant, &|_, _| CalibCtx::default());
    // nonzero adapters so the rank-r correction is actually exercised
    // (smoke shrinks the rank too: at the tiny geometry r=8 f32 adapters
    // would dominate the packed footprint and void the memory assert)
    let rank = if smoke { 2 } else { 8 };
    let mut adapters = AdapterSet::zeros(&dims, rank);
    for f in 0..7 {
        for l in 0..dims.n_layers {
            let (di, do_) = dims.linear_dims(rilq::model::LINEARS[f]);
            adapters.set(
                f,
                l,
                Mat::randn(di, rank, &mut rng).scale(0.01),
                Mat::randn(do_, rank, &mut rng).scale(0.01),
            );
        }
    }
    let batch: Vec<Vec<u32>> = (0..dims.batch)
        .map(|_| (0..dims.seq).map(|_| rng.below(dims.vocab) as u32).collect())
        .collect();
    let tokens_per_exec = (dims.batch * dims.seq) as f64;
    let flops_per_exec = tokens_per_exec * dims.linear_flops_per_token() as f64;

    // smoke still takes a handful of samples (not the old 2): the
    // packed/merged ratio tripwire below needs a noise-robust estimate
    // on shared CI runners, and the geometry is tiny enough that the
    // extra iterations cost well under a second
    let b = if smoke {
        Bench::new("native_backend").iters(2, 5)
    } else {
        Bench::new("native_backend").iters(2, 8)
    };
    let mut weight_bytes = Vec::new();
    let mut tok_rates: Vec<(BackendKind, f64)> = Vec::new();
    let mut backends_json: Vec<(&str, Json)> = Vec::new();
    for kind in BackendKind::ALL {
        let scorer = BackendScorer::new(&dims, &teacher, &student, Some(&adapters), kind)
            .expect("backend build");
        weight_bytes.push((kind, scorer.weight_bytes()));
        let res = b.run_throughput(&format!("student_fwd_{kind} tokens/s"), tokens_per_exec, || {
            scorer.score_batch(&batch).unwrap()
        });
        let p50 = res.summary.p50.max(1e-12);
        let toks = tokens_per_exec / p50;
        let gflops = flops_per_exec / p50 / 1e9;
        println!("kernel-gflops {kind:<7} {gflops:>8.2} GFLOP/s (linears + head, p50)");
        // the ratio tripwire uses each backend's FASTEST iteration: min
        // wall time is the least-noise throughput estimator (any slow
        // sample is contention, never the kernel being faster)
        tok_rates.push((kind, tokens_per_exec / res.summary.min.max(1e-12)));
        backends_json.push((
            kind.name(),
            Json::obj(vec![
                ("tokens_per_sec", Json::num(toks)),
                ("kernel_gflops", Json::num(gflops)),
                ("weight_bytes", Json::num(scorer.weight_bytes() as f64)),
            ]),
        ));
    }

    // the W2A16 memory claim: packed resident weights < 1/4 of dense f32
    let dense = weight_bytes
        .iter()
        .find(|(k, _)| *k == BackendKind::Dense)
        .map(|(_, n)| *n)
        .unwrap();
    for (kind, bytes) in &weight_bytes {
        println!(
            "weight-memory {kind:<7} {:>10} bytes  ({:.2}x vs dense f32)",
            bytes,
            *bytes as f64 / dense as f64
        );
    }
    let packed = weight_bytes
        .iter()
        .find(|(k, _)| *k == BackendKind::Packed)
        .map(|(_, n)| *n)
        .unwrap();
    assert!(
        packed * 4 < dense,
        "packed weight memory ({packed}) must be < 1/4 of dense ({dense})"
    );

    // the PR-5 kernel-regression tripwire: packed throughput must stay
    // within MIN_PACKED_VS_MERGED of the merged-dense oracle (runs in
    // smoke mode too, so CI catches dequant/micro-kernel regressions)
    let packed_toks = tok_rates.iter().find(|(k, _)| *k == BackendKind::Packed).unwrap().1;
    let merged_toks = tok_rates.iter().find(|(k, _)| *k == BackendKind::Merged).unwrap().1;
    let ratio = packed_toks / merged_toks.max(1e-12);
    println!("packed/merged tok-rate ratio: {ratio:.2} (min-time, floor {MIN_PACKED_VS_MERGED})");
    assert!(
        ratio >= MIN_PACKED_VS_MERGED,
        "packed backend fell to {ratio:.2}x of merged tok/s (floor \
         {MIN_PACKED_VS_MERGED}) — LUT dequant or the vectorized \
         micro-kernels regressed"
    );

    Json::obj(vec![
        ("tokens_per_exec", Json::num(tokens_per_exec)),
        ("flops_per_token", Json::num(dims.linear_flops_per_token() as f64)),
        ("backends", Json::obj(backends_json)),
        ("packed_vs_merged_ratio", Json::num(ratio)),
    ])
}

/// The serving claim: coalescing ragged requests into one batched forward
/// beats scoring them sequence-by-sequence on the same `BackendScorer`
/// (pool dispatch + packed group-tile dequant amortize across the batch).
/// `probe_throughput` (shared with `rilq serve-bench`) verifies logp
/// parity and that no PAD-dummy tokens were forwarded.
fn bench_serve_loop(smoke: bool) -> Json {
    let dims = native_dims(smoke);
    let mut rng = Rng::seed(0x5e7e);
    let teacher = TeacherParams::init(&dims, &mut rng);
    let quant = Rtn::new(2, dims.group_size);
    let student = StudentWeights::quantize(&dims, &teacher, &quant, &|_, _| CalibCtx::default());
    let scorer = std::sync::Arc::new(
        BackendScorer::new(&dims, &teacher, &student, None, BackendKind::Packed)
            .expect("packed scorer"),
    );

    let n_requests = if smoke { 12 } else { 64 };
    let probe = probe_throughput(scorer.clone(), n_requests, 8, 0x5e7e).expect("serve probe");
    assert_eq!(probe.summary.requests as usize, n_requests, "serve loop lost requests");
    println!(
        "serve_loop[packed]: per-sequence {:.0} tok/s, batched {:.0} tok/s, \
         speedup {:.2}x (occupancy {:.2}, kernel {} GFLOP/s p50)",
        probe.sequential_tok_per_sec(),
        probe.batched_tok_per_sec(),
        probe.speedup(),
        probe.summary.mean_occupancy,
        probe.summary.kernel_gflops_p50.map(|g| format!("{g:.2}")).unwrap_or_else(|| "-".into())
    );
    // the ≥2x acceptance claim needs real cores and the full geometry;
    // smoke/CI boxes only check the loop runs and wastes no PAD forwards
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if !smoke && cores >= 4 {
        assert!(
            probe.speedup() >= 2.0,
            "batched serving should be >= 2x per-sequence at batch >= 4 \
             (got {:.2}x)",
            probe.speedup()
        );
    }
    // arena-residency segment: run a burst of generations through the
    // engine on an undersized-but-sufficient paged arena and record the
    // block gauges the serve path now exports (kv_blocks_peak /
    // preemptions / per-slot resident bytes)
    let kv_block = (dims.seq / 4).max(1);
    let worst_blocks = dims.seq.div_ceil(kv_block);
    let max_active = 4usize;
    let engine = Engine::start_shared(
        scorer,
        EngineConfig {
            max_batch: 8,
            queue_capacity: 16,
            max_active,
            prefill_chunk: kv_block,
            kv_block,
            // roughly half the worst-case demand of `max_active` full
            // windows: generations pack by actual residency, not by slot
            arena_blocks: 2 * worst_blocks + 1,
            ..EngineConfig::default()
        },
    );
    let client = engine.client();
    let n_gens = 6usize;
    let prompt_len = (dims.seq / 4).max(1);
    let max_new = dims.seq / 2;
    let mut grng = Rng::seed(0x6e9e);
    let mut pending = Vec::new();
    for _ in 0..n_gens {
        let prompt: Vec<u32> = (0..prompt_len).map(|_| grng.below(dims.vocab) as u32).collect();
        pending.push(client.generate(prompt, SamplingParams::greedy(max_new)).expect("submit"));
    }
    for p in pending {
        p.wait().expect("generation");
    }
    let summary = engine.shutdown();
    assert_eq!(summary.gen_requests, n_gens as f64, "engine lost generations");
    assert_eq!(summary.errors, 0.0, "engine generation errored");
    let resident_per_slot = summary.kv_bytes_peak / max_active as f64;
    println!(
        "serve_arena[packed]: {n_gens} generations on {} blocks (worst-case {worst_blocks} \
         per gen): KV peak {:.0} B / {:.0} blocks ({resident_per_slot:.0} B per active slot), \
         {} preemptions",
        2 * worst_blocks + 1,
        summary.kv_bytes_peak,
        summary.kv_blocks_peak,
        summary.preemptions
    );

    let gflops = probe.summary.kernel_gflops_p50.map(Json::num).unwrap_or(Json::Null);
    Json::obj(vec![
        ("requests", Json::num(n_requests as f64)),
        ("total_tokens", Json::num(probe.total_tokens as f64)),
        ("sequential_tok_per_sec", Json::num(probe.sequential_tok_per_sec())),
        ("batched_tok_per_sec", Json::num(probe.batched_tok_per_sec())),
        ("speedup", Json::num(probe.speedup())),
        ("mean_occupancy", Json::num(probe.summary.mean_occupancy)),
        ("kernel_gflops_p50", gflops),
        ("gen_requests", Json::num(summary.gen_requests)),
        ("gen_tokens", Json::num(summary.gen_tokens)),
        ("kv_bytes_peak", Json::num(summary.kv_bytes_peak)),
        ("kv_blocks_peak", Json::num(summary.kv_blocks_peak)),
        ("kv_resident_bytes_per_slot", Json::num(resident_per_slot)),
        ("preemptions", Json::num(summary.preemptions)),
    ])
}

/// The KV-cache claim: prefill-once + incremental single-token steps beat
/// re-running the full forward for every generated token (O(S) vs O(S²)
/// linear rows). `probe_decode` (shared with `rilq serve-bench`) verifies
/// token/logp parity between the two paths internally before reporting.
fn bench_decode(smoke: bool) -> Json {
    let dims = native_dims(smoke);
    let mut rng = Rng::seed(0xdec0);
    let teacher = TeacherParams::init(&dims, &mut rng);
    let quant = Rtn::new(2, dims.group_size);
    let student = StudentWeights::quantize(&dims, &teacher, &quant, &|_, _| CalibCtx::default());
    let scorer = BackendScorer::new(&dims, &teacher, &student, None, BackendKind::Packed)
        .expect("packed scorer");

    // generation length >= 32 at full geometry (seq 64: 32 prompt + 32 new)
    let prompt_len = dims.seq / 2;
    let gen_len = dims.seq - prompt_len;
    let probe = probe_decode(&scorer, prompt_len, gen_len, 0xdec0).expect("decode probe");
    println!(
        "decode[packed]: prefill {} tok in {:.3}s ({:.0} tok/s), \
         incremental {} tok at {:.0} tok/s, full-recompute {:.0} tok/s, \
         speedup {:.2}x",
        probe.prompt_tokens,
        probe.prefill_secs,
        probe.prefill_tok_per_sec(),
        probe.gen_tokens,
        probe.incremental_tok_per_sec(),
        probe.full_tok_per_sec(),
        probe.speedup()
    );
    println!(
        "decode KV residency: {} B resident ({:.1} B per generated token; \
         full-window capacity {} B)",
        probe.kv_resident_bytes,
        probe.kv_bytes_per_gen_token(),
        probe.kv_capacity_bytes
    );
    // the >= 3x acceptance claim needs real cores and the full geometry;
    // smoke/CI boxes only check the two decode paths agree
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if !smoke && cores >= 4 {
        assert!(
            probe.speedup() >= 3.0,
            "prefill + incremental decode should be >= 3x repeated full \
             forwards at generation length {gen_len} (got {:.2}x)",
            probe.speedup()
        );
    }
    Json::obj(vec![
        ("prompt_tokens", Json::num(probe.prompt_tokens as f64)),
        ("gen_tokens", Json::num(probe.gen_tokens as f64)),
        ("prefill_tok_per_sec", Json::num(probe.prefill_tok_per_sec())),
        ("incremental_tok_per_sec", Json::num(probe.incremental_tok_per_sec())),
        ("full_recompute_tok_per_sec", Json::num(probe.full_tok_per_sec())),
        ("speedup", Json::num(probe.speedup())),
        ("kv_resident_bytes", Json::num(probe.kv_resident_bytes as f64)),
        ("kv_capacity_bytes", Json::num(probe.kv_capacity_bytes as f64)),
        ("kv_bytes_per_gen_token", Json::num(probe.kv_bytes_per_gen_token())),
    ])
}

/// PR 10: trace-driven overload section. Replays a seeded two-tenant
/// bursty trace (ON/OFF arrivals, bounded-Pareto lengths) through the
/// load-aware two-replica engine with admission control armed, and
/// records SLO-style numbers next to the raw-throughput sections:
/// goodput (completions that beat their deadline), sheds by class,
/// rate-limit/brownout activity, and TTFT percentiles. The trace itself
/// is bit-for-bit seeded; wall-clock figures vary by machine, so this
/// section is recorded for the CI artifact trajectory rather than
/// floor-checked — except the structural invariant that shedding never
/// touches the high-priority class, which holds on any machine.
fn bench_trace(smoke: bool) -> Json {
    use rilq::engine::{
        generate_trace, replay_trace, Arrivals, BoundedPareto, Priority, TenantClass, TraceConfig,
    };
    let dims = native_dims(smoke);
    let mut rng = Rng::seed(0x7ace);
    let teacher = TeacherParams::init(&dims, &mut rng);
    let quant = Rtn::new(2, dims.group_size);
    let student = StudentWeights::quantize(&dims, &teacher, &quant, &|_, _| CalibCtx::default());
    let scorer: std::sync::Arc<dyn Scorer + Send + Sync> = std::sync::Arc::new(
        BackendScorer::new(&dims, &teacher, &student, None, BackendKind::Packed)
            .expect("packed scorer"),
    );
    let cfg = TraceConfig {
        seed: 0x7ace,
        duration_secs: if smoke { 1.0 } else { 2.0 },
        arrivals: Arrivals::OnOff {
            on_rate: if smoke { 30.0 } else { 60.0 },
            off_rate: 2.0,
            on_secs: 0.4,
            off_secs: 0.4,
        },
        tenants: vec![
            TenantClass { name: "paid".into(), priority: Priority::High, weight: 0.2 },
            TenantClass { name: "free".into(), priority: Priority::Low, weight: 0.8 },
        ],
        // prompt.hi + gen.hi stays inside the model window
        prompt: BoundedPareto { alpha: 1.3, lo: 2, hi: (dims.seq / 2).max(2) },
        gen: BoundedPareto { alpha: 1.5, lo: 1, hi: (dims.seq - dims.seq / 2 - 1).max(1) },
        vocab: dims.vocab,
    };
    let trace = generate_trace(&cfg);
    // size the queue so total paid arrivals stay under the shed mark:
    // with fewer queued highs than the watermark, a paid arrival over the
    // mark always finds a low-priority victim, so sheds-hit-low-first is
    // structural (timing-independent) and safe to assert in a bench
    let paid_total = trace.iter().filter(|e| e.priority == Priority::High).count();
    let queue_cap = ((paid_total + 4) * 4 / 3 + 1).max(16);
    let replicas: Vec<std::sync::Arc<dyn Scorer + Send + Sync>> = vec![scorer.clone(), scorer];
    let engine = Engine::start_balanced(
        replicas,
        EngineConfig {
            max_batch: 8,
            queue_capacity: queue_cap,
            max_active: 4,
            prefill_chunk: 4,
            kv_block: 4,
            shed_watermark: 0.75,
            brownout_backlog: (queue_cap / 2).max(1),
            brownout_after: 2,
            brownout_max_new: 2,
            ..EngineConfig::default()
        },
    );
    let client = engine.client();
    // time_scale 0 floods the whole trace at once — this section measures
    // behavior *under* overload, not the arrival process itself
    let outcome = replay_trace(&client, &trace, 0.0, None);
    let summary = engine.shutdown();
    assert!(outcome.fully_resolved(), "every trace submission must resolve exactly once");
    assert_eq!(
        summary.overload_sheds_high, 0.0,
        "admission control shed a high-priority request while low-priority work was queued"
    );
    let paid = outcome.tenant("paid");
    let free = outcome.tenant("free");
    let secs = |o: Option<f64>| o.map(|s| format!("{s:.4}s")).unwrap_or_else(|| "-".into());
    println!(
        "trace[packed x2]: {} events ({} paid / {} free), goodput {:.0} reqs \
         ({:.0} gen tokens raw), sheds {:.0} (high {:.0}), rate-limited {:.0}, \
         brownouts {:.0}, TTFT p50 {} p99 {} (high p99 {})",
        trace.len(),
        paid.submitted,
        free.submitted,
        summary.goodput_requests,
        summary.gen_tokens,
        summary.overload_sheds,
        summary.overload_sheds_high,
        summary.rate_limited,
        summary.brownouts,
        secs(summary.ttft_p50_secs),
        secs(summary.ttft_p99_secs),
        secs(summary.ttft_high_p99_secs),
    );
    let num_opt = |o: Option<f64>| o.map(Json::num).unwrap_or(Json::Null);
    Json::obj(vec![
        ("events", Json::num(trace.len() as f64)),
        ("paid_submitted", Json::num(paid.submitted as f64)),
        ("free_submitted", Json::num(free.submitted as f64)),
        ("paid_ok", Json::num(paid.ok as f64)),
        ("free_ok", Json::num(free.ok as f64)),
        ("goodput_requests", Json::num(summary.goodput_requests)),
        ("gen_tokens", Json::num(summary.gen_tokens)),
        ("overload_sheds", Json::num(summary.overload_sheds)),
        ("overload_sheds_high", Json::num(summary.overload_sheds_high)),
        ("rate_limited", Json::num(summary.rate_limited)),
        ("brownouts", Json::num(summary.brownouts)),
        ("ttft_p50_secs", num_opt(summary.ttft_p50_secs)),
        ("ttft_p99_secs", num_opt(summary.ttft_p99_secs)),
        ("ttft_high_p99_secs", num_opt(summary.ttft_high_p99_secs)),
        ("tok_latency_p99_secs", num_opt(summary.tok_latency_p99_secs)),
    ])
}

fn bench_threaded_matmul(smoke: bool) -> Json {
    let mut rng = Rng::seed(0x7ead);
    let size = if smoke { 128 } else { 1024 };
    let m = if smoke { 32 } else { 256 };
    let x = Mat::randn(m, size, &mut rng);
    let w = Mat::randn(size, size, &mut rng);
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let b = if smoke {
        Bench::new("tiled_matmul").iters(1, 2)
    } else {
        Bench::new("tiled_matmul").iters(2, 8)
    };
    let flops = 2.0 * (m * size * size) as f64;
    let gflops = |p50: f64| flops / p50.max(1e-12) / 1e9;
    let shape = format!("{m}x{size}x{size}");
    let single = b.run(&format!("single-thread {shape}"), || x.matmul(&w));
    let threaded = b.run(&format!("threaded({workers}) {shape}"), || {
        x.matmul_threaded(&w, workers)
    });
    let bt = w.t();
    let mt = b.run(&format!("matmul_t blocked {shape}"), || x.matmul_t(&bt));
    let speedup = single.summary.p50 / threaded.summary.p50.max(1e-12);
    println!(
        "matmul {shape}: single {:.2} GFLOP/s, threaded({workers}) {:.2} GFLOP/s \
         ({speedup:.2}x), matmul_t {:.2} GFLOP/s",
        gflops(single.summary.p50),
        gflops(threaded.summary.p50),
        gflops(mt.summary.p50)
    );
    Json::obj(vec![
        ("shape", Json::str(shape)),
        ("single_gflops", Json::num(gflops(single.summary.p50))),
        ("threaded_gflops", Json::num(gflops(threaded.summary.p50))),
        ("matmul_t_gflops", Json::num(gflops(mt.summary.p50))),
        ("threaded_speedup", Json::num(speedup)),
        ("workers", Json::num(workers as f64)),
    ])
}

fn bench_config(rt: &Runtime, config: &str) {
    let dims = rt.manifest.dims(config).unwrap().clone();
    let mut rng = Rng::seed(0xbe9c);
    let teacher = TeacherParams::init(&dims, &mut rng);
    let quant = Rtn::new(2, dims.group_size);
    let student = StudentWeights::quantize(&dims, &teacher, &quant, &|_, _| CalibCtx::default());
    let rank = *rt.manifest.ranks[config].iter().min().unwrap();
    let adapters = AdapterSet::init_default(&dims, rank, &mut rng, 0.01);
    let batch: Vec<Vec<u32>> = (0..dims.batch)
        .map(|_| (0..dims.seq).map(|_| rng.below(dims.vocab) as u32).collect())
        .collect();
    let tokens_per_exec = (dims.batch * dims.seq) as f64;

    // ---- teacher forward ----------------------------------------------
    let tname = format!("teacher_fwd_{config}");
    let tspec = rt.manifest.artifact(&tname).unwrap().clone();
    let mut base = Bindings::new();
    base.teacher(&teacher);
    rt.load(&tname).unwrap();
    let b = Bench::new(format!("exec_{config}")).iters(2, 10);
    b.run_throughput("teacher_fwd tokens/s", tokens_per_exec, || {
        let mut bi = Bindings::new();
        bi.copy_from(&base).tokens(&batch, &dims);
        rt.run(&tname, &bi.to_literals(&tspec).unwrap()).unwrap()
    });

    // marshalling alone (literal creation for the full input list)
    b.run("teacher_fwd marshalling-only", || {
        let mut bi = Bindings::new();
        bi.copy_from(&base).tokens(&batch, &dims);
        bi.to_literals(&tspec).unwrap()
    });

    // §Perf A/B: device-cached static inputs (weights uploaded once; only
    // the token batch transfers per call) vs the literal path above
    let dev = base.to_device(rt, &tspec, &["tokens"]).unwrap();
    b.run_throughput("teacher_fwd DEVICE-CACHED tokens/s", tokens_per_exec, || {
        let mut dynb = Bindings::new();
        dynb.tokens(&batch, &dims);
        let asm = dev.assemble(rt, &tspec, &dynb).unwrap();
        rt.run_b(&tname, &asm.refs()).unwrap()
    });

    // ---- student forward: dense vs packed (the W2A16 serving claim) ----
    let sname = format!("student_fwd_{config}_r{rank}");
    let sspec = rt.manifest.artifact(&sname).unwrap().clone();
    let mut sbase = Bindings::new();
    sbase.teacher(&teacher).qweights(&student).adapters("ad.", &adapters.to_flat());
    rt.load(&sname).unwrap();
    b.run_throughput("student_fwd_dense tokens/s", tokens_per_exec, || {
        let mut bi = Bindings::new();
        bi.copy_from(&sbase).tokens(&batch, &dims);
        rt.run(&sname, &bi.to_literals(&sspec).unwrap()).unwrap()
    });

    let pname = format!("student_fwd_packed_{config}_r{rank}_w2");
    if let Ok(pspec) = rt.manifest.artifact(&pname).map(Clone::clone) {
        let mut packed = Vec::new();
        let mut scales = Vec::new();
        let mut zeros = Vec::new();
        let mut codebook = Vec::new();
        for fam in 0..7 {
            let mut fp = Vec::new();
            let mut fs = Vec::new();
            let mut fz = Vec::new();
            for l in 0..dims.n_layers {
                let q = student.q[fam][l].as_scalar().unwrap();
                fp.push(q.pack());
                fs.extend_from_slice(q.scales.data());
                fz.extend_from_slice(q.zeros.data());
                codebook = q.codebook.clone();
            }
            packed.push(fp);
            scales.push(fs);
            zeros.push(fz);
        }
        let mut pbase = Bindings::new();
        pbase
            .teacher(&teacher)
            .packed(&packed, &scales, &zeros, &codebook)
            .adapters("ad.", &adapters.to_flat());
        rt.load(&pname).unwrap();
        b.run_throughput("student_fwd_packed tokens/s", tokens_per_exec, || {
            let mut bi = Bindings::new();
            bi.copy_from(&pbase).tokens(&batch, &dims);
            rt.run(&pname, &bi.to_literals(&pspec).unwrap()).unwrap()
        });
    }

    // ---- train step (the calibration loop body) -------------------------
    let trname = format!(
        "train_step_{config}_r{rank}_{}",
        rt.manifest.scopes[config].first().map(String::as_str).unwrap_or("model_gt")
    );
    if let Ok(trspec) = rt.manifest.artifact(&trname).map(Clone::clone) {
        let ad_flat = adapters.to_flat();
        let m_flat = adapters.zeros_like_flat();
        let v_flat = adapters.zeros_like_flat();
        rt.load(&trname).unwrap();
        let mut tb = Bindings::new();
        tb.teacher(&teacher).qweights(&student);
        b.run_throughput("train_step tokens/s", tokens_per_exec, || {
            let mut bi = Bindings::new();
            bi.copy_from(&tb)
                .adapters("ad.", &ad_flat)
                .adapters("m.", &m_flat)
                .adapters("v.", &v_flat)
                .step_lr(1.0, 1e-3)
                .tokens(&batch, &dims);
            rt.run(&trname, &bi.to_literals(&trspec).unwrap()).unwrap()
        });
    }
}
