//! Coordinator benchmarks: batcher throughput under backpressure, cache
//! hit latency, grid-scheduler overhead, adapter (de)flattening.

use rilq::coordinator::batcher::BatchStream;
use rilq::coordinator::RunCache;
use rilq::data::{Profile, Vocab};
use rilq::lqec::AdapterSet;
use rilq::model::weights::TensorFile;
use rilq::model::ModelDims;
use rilq::report::Bench;
use rilq::tensor::Rng;

fn main() {
    let vocab = Vocab::new(512, 1);

    // batcher throughput (tokens/s through the bounded channel)
    let b = Bench::new("batcher").iters(1, 5);
    let tokens = (50 * 8 * 128) as f64;
    b.run_throughput("stream_50x8x128 tokens/s", tokens, || {
        let mut s = BatchStream::spawn(vocab.clone(), Profile::C4Sim, 7, 8, 128, 50, 4);
        let mut n = 0;
        while let Some(batch) = s.next() {
            n += batch.len();
        }
        n
    });
    // tight capacity (max backpressure) for comparison
    b.run_throughput("stream_capacity1 tokens/s", tokens, || {
        let mut s = BatchStream::spawn(vocab.clone(), Profile::C4Sim, 7, 8, 128, 50, 1);
        let mut n = 0;
        while let Some(batch) = s.next() {
            n += batch.len();
        }
        n
    });

    // run-cache: cold write vs hot read of a small-model-sized checkpoint
    let dims = ModelDims {
        name: "bench".into(),
        d_model: 192,
        n_layers: 4,
        n_heads: 4,
        d_ff: 512,
        vocab: 512,
        seq: 128,
        batch: 8,
        group_size: 64,
    };
    let mut rng = Rng::seed(3);
    let ad = AdapterSet::init_default(&dims, 16, &mut rng, 0.01);
    let tmp = std::env::temp_dir().join(format!("rilq_bench_cache_{}", std::process::id()));
    let cache = RunCache::new(&tmp);
    let cb = Bench::new("run_cache").iters(1, 8);
    let flat = ad.to_flat();
    cb.run("cold_write", || {
        let key = format!("k{}", rng.next_u64());
        cache
            .get_or_compute(&key, || {
                let mut tf = TensorFile::new();
                for (i, b) in flat.iter().enumerate() {
                    tf.insert(format!("ad.{i:02}"), vec![b.len()], b.clone());
                }
                Ok(tf)
            })
            .unwrap()
    });
    cache
        .get_or_compute("hot", || {
            let mut tf = TensorFile::new();
            for (i, b) in flat.iter().enumerate() {
                tf.insert(format!("ad.{i:02}"), vec![b.len()], b.clone());
            }
            Ok(tf)
        })
        .unwrap();
    cb.run("hot_read", || cache.get_or_compute("hot", || unreachable!()).unwrap());
    std::fs::remove_dir_all(&tmp).ok();

    // adapter (de)flattening — per-train-step CPU cost in the loop
    let fb = Bench::new("adapters").iters(3, 20);
    fb.run("to_flat_r16_small", || ad.to_flat());
    let flat2 = ad.to_flat();
    fb.run("from_flat_r16_small", || {
        AdapterSet::from_flat(&dims, 16, &flat2).unwrap()
    });
}
