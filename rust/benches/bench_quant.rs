//! Quantizer micro-benchmarks: per-matrix quantization latency at the
//! `small` config's largest linear (512x192), plus packing throughput and
//! SVD cost. Criterion-style output via `report::Bench` (criterion itself
//! is not in the offline crate set).

use rilq::quant::{by_name, pack_codes, CalibCtx};
use rilq::report::Bench;
use rilq::tensor::{svd_jacobi, Mat, Rng};

fn main() {
    let mut rng = Rng::seed(0xbe7c);
    let w = Mat::randn(512, 192, &mut rng);
    let x = Mat::randn(256, 512, &mut rng);
    let ctx_plain = CalibCtx::with_seed(1);
    let ctx_calib = CalibCtx { x_samples: Some(x), x_sq_mean: None, seed: 1 };

    let b = Bench::new("quantize_512x192_w2").iters(1, 5);
    for name in ["rtn", "nf", "omniquant", "quarot", "quip"] {
        let q = by_name(name, 2, 64).unwrap();
        let ctx = if matches!(name, "omniquant" | "gptq" | "quarot") {
            &ctx_calib
        } else {
            &ctx_plain
        };
        b.run(name, || q.quantize(&w, ctx));
    }
    // GPTQ separately (heaviest: Hessian inverse)
    let gptq = by_name("gptq", 2, 64).unwrap();
    Bench::new("quantize_512x192_w2").iters(0, 3).run("gptq", || gptq.quantize(&w, &ctx_calib));

    // packing throughput
    let rtn = by_name("rtn", 2, 64).unwrap();
    let qt = rtn.quantize(&w, &ctx_plain);
    let scalar = qt.as_scalar().unwrap();
    let n_codes = (512 * 192) as f64;
    Bench::new("packing").iters(3, 20).run_throughput("pack_2bit_512x192", n_codes, || {
        pack_codes(&scalar.codes, 512, 192, 2)
    });
    let packed = scalar.pack();
    Bench::new("packing").iters(3, 20).run_throughput("unpack_2bit_512x192", n_codes, || {
        rilq::quant::unpack_codes(&packed)
    });
    Bench::new("packing").iters(3, 20).run("dequant_512x192", || scalar.dequant());

    // SVD (LoftQ inner loop cost)
    Bench::new("svd").iters(0, 3).run("jacobi_512x192", || svd_jacobi(&w));

    // dense matmul baseline for roofline context
    let a = Mat::randn(256, 512, &mut rng);
    let flops = 2.0 * 256.0 * 512.0 * 192.0;
    Bench::new("matmul").iters(2, 10).run_throughput("f32_256x512x192_flops", flops, || {
        a.matmul(&w)
    });
}
