//! KV-cache acceptance tests: incremental logits (prefill + N
//! `forward_step`s) match the full `forward_trace` logits across all
//! three backends, cache edge cases err instead of panicking, prefix
//! reuse across choices is bitwise-stable, and `mc_accuracy` with prefix
//! reuse forwards measurably fewer linear rows than the full-recompute
//! path while scoring identically.

use anyhow::Result;
use rilq::eval::{greedy_decode, greedy_decode_recompute, mc_accuracy, BackendScorer, Scorer};
use rilq::model::backend::{student_backends, BackendKind};
use rilq::model::forward::{forward_step, forward_trace, forward_trace_with_cache};
use rilq::model::{KvArena, KvCache, ModelDims, StudentWeights, TeacherParams};
use rilq::quant::{by_name, CalibCtx};
use rilq::tensor::Rng;

fn dims() -> ModelDims {
    ModelDims {
        name: "kv".into(),
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        vocab: 48,
        seq: 16,
        batch: 2,
        group_size: 8,
    }
}

fn student(d: &ModelDims, seed: u64) -> (TeacherParams, StudentWeights) {
    let mut rng = Rng::seed(seed);
    let teacher = TeacherParams::init(d, &mut rng);
    let quant = by_name("rtn", 2, d.group_size).unwrap();
    let student = StudentWeights::quantize(d, &teacher, quant.as_ref(), &|_, _| {
        CalibCtx::default()
    });
    (teacher, student)
}

fn packed_scorer(seed: u64) -> BackendScorer {
    let d = dims();
    let (teacher, sw) = student(&d, seed);
    BackendScorer::new(&d, &teacher, &sw, None, BackendKind::Packed).unwrap()
}

/// Acceptance: prefill + N single-token steps reproduce the full-forward
/// logits within 1e-5 at every position, for dense, packed, and merged.
#[test]
fn incremental_logits_match_full_forward_all_backends() {
    let d = dims();
    let (teacher, sw) = student(&d, 61);
    let mut rng = Rng::seed(62);
    let tokens: Vec<u32> = (0..d.seq).map(|_| rng.below(d.vocab) as u32).collect();
    let prefix = 6usize;
    for kind in BackendKind::ALL {
        let engines = student_backends(&sw, None, kind).unwrap();
        let view = teacher.view_backends(&engines);
        let full = forward_trace(&d, &view, &tokens).logits;

        let mut cache = KvCache::new(&d);
        let prefill =
            forward_trace_with_cache(&d, &view, &tokens[..prefix], &mut cache).unwrap();
        let mut rows: Vec<Vec<f32>> = (0..prefix).map(|r| prefill.row(r).to_vec()).collect();
        for &t in &tokens[prefix..] {
            rows.push(forward_step(&d, &view, t, &mut cache).unwrap());
        }
        assert_eq!(cache.len(), tokens.len());
        for (pos, row) in rows.iter().enumerate() {
            let frow = full.row(pos);
            let max_abs = row
                .iter()
                .zip(frow)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                max_abs <= 1e-5,
                "backend {kind}, pos {pos}: incremental vs full max diff {max_abs}"
            );
        }
    }
}

/// An empty prefix is just a prefill: a cached forward of the whole
/// sequence from an empty cache equals `forward_trace` exactly.
#[test]
fn empty_prefix_prefill_equals_full_forward() {
    let d = dims();
    let (teacher, _) = student(&d, 63);
    let mut rng = Rng::seed(64);
    let tokens: Vec<u32> = (0..10).map(|_| rng.below(d.vocab) as u32).collect();
    let view = teacher.view();
    let full = forward_trace(&d, &view, &tokens).logits;
    let mut cache = KvCache::new(&d);
    let cached = forward_trace_with_cache(&d, &view, &tokens, &mut cache).unwrap();
    assert_eq!(full.shape(), cached.shape());
    assert!(full.fro_dist(&cached) < 1e-7, "prefill diverged from forward_trace");
}

/// Window edge cases: a prefix exactly at `dims.seq` is fine, a 0-token
/// suffix at the full window is fine (and a no-op), and any step past
/// the window is an `Err`, not a panic.
#[test]
fn window_boundary_and_zero_suffix() {
    let d = dims();
    let (teacher, _) = student(&d, 65);
    let mut rng = Rng::seed(66);
    let tokens: Vec<u32> = (0..d.seq).map(|_| rng.below(d.vocab) as u32).collect();
    let view = teacher.view();
    let mut cache = KvCache::new(&d);
    let lg = forward_trace_with_cache(&d, &view, &tokens, &mut cache).unwrap();
    assert_eq!(lg.shape(), (d.seq, d.vocab));
    assert_eq!(cache.len(), d.seq);
    assert_eq!(cache.remaining(), 0);

    // degenerate 0-token suffix: empty logits, cache untouched
    let empty = forward_trace_with_cache(&d, &view, &[], &mut cache).unwrap();
    assert_eq!(empty.shape(), (0, d.vocab));
    assert_eq!(cache.len(), d.seq);

    // one token past the window: Err, cache untouched
    let err = forward_step(&d, &view, 1, &mut cache).unwrap_err();
    assert!(format!("{err}").contains("window"), "{err}");
    assert_eq!(cache.len(), d.seq);

    // out-of-vocab token id: Err naming the vocabulary, not a panic
    cache.truncate(4);
    let err = forward_step(&d, &view, d.vocab as u32, &mut cache).unwrap_err();
    assert!(format!("{err}").contains("vocabulary"), "{err}");
    assert_eq!(cache.len(), 4);

    // a cache built for a different geometry is rejected
    let mut small = ModelDims { seq: 8, ..d.clone() };
    small.name = "other".into();
    let mut wrong = KvCache::new(&small);
    let err = forward_step(&d, &view, 1, &mut wrong).unwrap_err();
    assert!(format!("{err}").contains("geometry"), "{err}");
}

/// Cache reuse across choices is bitwise-stable: scoring the same
/// choices twice through the prefix-reuse path produces identical bits,
/// and matches the full-recompute default path within 1e-5.
#[test]
fn choice_scoring_prefix_reuse_is_stable_and_correct() {
    let sc = packed_scorer(67);
    let d = sc.dims().clone();
    let mut rng = Rng::seed(68);
    let prompt: Vec<u32> = (0..8).map(|_| rng.below(d.vocab) as u32).collect();
    let choices: Vec<Vec<u32>> = vec![
        (0..3).map(|_| rng.below(d.vocab) as u32).collect(),
        (0..5).map(|_| rng.below(d.vocab) as u32).collect(),
        vec![rng.below(d.vocab) as u32],
        Vec::new(), // degenerate 0-token choice
    ];
    let a = sc.score_choices(&prompt, &choices).unwrap();
    let b = sc.score_choices(&prompt, &choices).unwrap();
    assert_eq!(a, b, "prefix-reuse scoring must be bitwise-stable across runs");
    assert!(a[3].is_empty());

    // parity vs the default full-recompute path
    struct NoPrefix<'s>(&'s BackendScorer);
    impl Scorer for NoPrefix<'_> {
        fn dims(&self) -> &ModelDims {
            self.0.dims()
        }
        fn score_batch(&self, batch: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
            self.0.score_batch(batch)
        }
    }
    let full = NoPrefix(&sc).score_choices(&prompt, &choices).unwrap();
    for (ci, (x, y)) in a.iter().zip(&full).enumerate() {
        assert_eq!(x.len(), y.len(), "choice {ci} length");
        for (p, q) in x.iter().zip(y) {
            assert!((p - q).abs() <= 1e-5, "choice {ci}: {p} vs {q}");
        }
    }
}

/// Acceptance: `mc_accuracy` through the prefix-reuse path forwards
/// measurably fewer linear rows than the full-recompute path (the
/// row-counter idiom of the serve loop's PAD-waste check) and scores
/// identically.
#[test]
fn mc_accuracy_prefix_reuse_forwards_fewer_rows() {
    use rilq::data::tasks::{gen_mc, TaskKind};
    use rilq::data::tokenizer::Vocab;

    let d = ModelDims {
        name: "mc".into(),
        d_model: 16,
        n_layers: 1,
        n_heads: 2,
        d_ff: 32,
        vocab: 256,
        seq: 32,
        batch: 4,
        group_size: 8,
    };
    let (teacher, sw) = student(&d, 69);
    let reuse = BackendScorer::new(&d, &teacher, &sw, None, BackendKind::Packed).unwrap();
    let naive = BackendScorer::new(&d, &teacher, &sw, None, BackendKind::Packed).unwrap();

    struct NoPrefix(BackendScorer);
    impl Scorer for NoPrefix {
        fn dims(&self) -> &ModelDims {
            self.0.dims()
        }
        fn score_batch(&self, batch: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
            self.0.score_batch(batch)
        }
    }
    let naive = NoPrefix(naive);

    let v = Vocab::new(256, 1);
    let items = gen_mc(TaskKind::ArcESim, &v, 20, 5);
    let acc_reuse = mc_accuracy(&reuse, &items, false).unwrap();
    let acc_naive = mc_accuracy(&naive, &items, false).unwrap();
    assert_eq!(acc_reuse, acc_naive, "prefix reuse changed the accuracy");

    let rows_reuse = reuse.rows_forwarded();
    let rows_naive = naive.0.rows_forwarded();
    // prefix reuse: prompt + Σ choice per item; naive: Σ (prompt + choice)
    assert!(
        rows_reuse < rows_naive,
        "prefix reuse must forward fewer rows ({rows_reuse} vs {rows_naive})"
    );
    let saved: usize = items
        .iter()
        .map(|it| it.prompt.len() * (it.choices.len() - 1))
        .sum();
    assert_eq!(
        rows_naive - rows_reuse,
        saved,
        "row saving must equal the re-prefilled prompt rows"
    );
}

/// Batched cached forward (the decode scheduler's coalesced step) is
/// bitwise identical to stepping each sequence's cache individually.
#[test]
fn batched_cache_forward_matches_individual() {
    let sc = packed_scorer(70);
    let d = sc.dims().clone();
    let mut rng = Rng::seed(71);
    let prompts: Vec<Vec<u32>> = [4usize, 7, 1]
        .iter()
        .map(|&n| (0..n).map(|_| rng.below(d.vocab) as u32).collect())
        .collect();
    let suffixes: Vec<Vec<u32>> = [3usize, 2, 4]
        .iter()
        .map(|&n| (0..n).map(|_| rng.below(d.vocab) as u32).collect())
        .collect();

    // individual path
    let mut solo_lgs = Vec::new();
    for (p, s) in prompts.iter().zip(&suffixes) {
        let mut cache = sc.new_cache();
        sc.cache_forward(p, &mut cache).unwrap();
        solo_lgs.push(sc.cache_forward(s, &mut cache).unwrap());
    }

    // batched path: coalesced prefill, then coalesced suffix step
    let mut caches: Vec<KvCache> = prompts.iter().map(|_| sc.new_cache()).collect();
    {
        let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        sc.cache_forward_batch(&prompts, &mut refs).unwrap();
    }
    let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
    let batch_lgs = sc.cache_forward_batch(&suffixes, &mut refs).unwrap();

    for (si, (a, b)) in solo_lgs.iter().zip(&batch_lgs).enumerate() {
        assert_eq!(a.shape(), b.shape());
        assert!(
            a.fro_dist(b) < 1e-6,
            "sequence {si}: batched cached step diverged from individual"
        );
    }

    // a batch where one sequence would overflow leaves every cache intact
    let lens_before: Vec<usize> = caches.iter().map(|c| c.len()).collect();
    let over: Vec<Vec<u32>> = vec![
        vec![1],
        (0..d.seq).map(|_| 1u32).collect(), // overflows its cache
        vec![2],
    ];
    let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
    let err = sc.cache_forward_batch(&over, &mut refs).unwrap_err();
    assert!(format!("{err}").contains("sequence 1"), "{err}");
    let lens_after: Vec<usize> = caches.iter().map(|c| c.len()).collect();
    assert_eq!(lens_before, lens_after, "failed batch must not touch any cache");
}

/// Greedy decode helpers: the cached path and the quadratic recompute
/// baseline generate identical tokens, and the cached path runs a
/// linear number of forwarded rows.
#[test]
fn greedy_decode_cached_matches_recompute() {
    let sc = packed_scorer(72);
    let d = sc.dims().clone();
    let mut rng = Rng::seed(73);
    let prompt: Vec<u32> = (0..6).map(|_| rng.below(d.vocab) as u32).collect();
    let gen = 8usize;

    let before = sc.rows_forwarded();
    let (toks_full, lps_full) = greedy_decode_recompute(&sc, &prompt, gen).unwrap();
    let full_rows = sc.rows_forwarded() - before;

    let before = sc.rows_forwarded();
    let (toks_inc, lps_inc) = greedy_decode(&sc, &prompt, gen).unwrap();
    let inc_rows = sc.rows_forwarded() - before;

    assert_eq!(toks_full, toks_inc, "decode paths diverged");
    for (a, b) in lps_full.iter().zip(&lps_inc) {
        assert!((a - b).abs() <= 1e-5, "{a} vs {b}");
    }
    assert_eq!(toks_inc.len(), gen);
    // incremental: prompt + (gen-1) rows; recompute: Σ (prompt + i) rows
    assert_eq!(inc_rows, prompt.len() + gen - 1);
    assert!(
        full_rows > 3 * inc_rows,
        "recompute baseline should forward many times more rows \
         ({full_rows} vs {inc_rows})"
    );

    // over-window budgets err instead of panicking
    let err = greedy_decode(&sc, &prompt, d.seq).unwrap_err();
    assert!(format!("{err}").contains("window"), "{err}");
}

/// Tentpole acceptance: the paged attention walk over small arena
/// blocks is *bitwise* identical to the contiguous single-block cache —
/// including attention spans that straddle block boundaries (3-position
/// blocks never align with the prefix lengths used here).
#[test]
fn paged_cache_is_bitwise_identical_to_contiguous() {
    let d = dims();
    let (teacher, _) = student(&d, 76);
    let view = teacher.view();
    let mut rng = Rng::seed(77);
    let tokens: Vec<u32> = (0..d.seq).map(|_| rng.below(d.vocab) as u32).collect();
    let prefix = 7usize; // prefill alone crosses two block boundaries

    // contiguous oracle: the default solo cache holds the full window in
    // one block, so its K/V planes are exactly the pre-paging layout
    let mut solo = KvCache::new(&d);
    let prefill = forward_trace_with_cache(&d, &view, &tokens[..prefix], &mut solo).unwrap();
    let mut want: Vec<Vec<f32>> = (0..prefix).map(|r| prefill.row(r).to_vec()).collect();
    for &t in &tokens[prefix..] {
        want.push(forward_step(&d, &view, t, &mut solo).unwrap());
    }
    assert_eq!(solo.blocks_held(), 1, "the solo cache must be a single block");

    let arena = KvArena::new(&d, 3, d.seq.div_ceil(3));
    let mut paged = arena.new_cache();
    let prefill = forward_trace_with_cache(&d, &view, &tokens[..prefix], &mut paged).unwrap();
    let mut got: Vec<Vec<f32>> = (0..prefix).map(|r| prefill.row(r).to_vec()).collect();
    for &t in &tokens[prefix..] {
        got.push(forward_step(&d, &view, t, &mut paged).unwrap());
    }
    assert_eq!(paged.blocks_held(), d.seq.div_ceil(3));

    for (pos, (g, w)) in got.iter().zip(&want).enumerate() {
        for (i, (a, b)) in g.iter().zip(w).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "pos {pos}, logit {i}: paged {a} vs contiguous {b} — not bitwise"
            );
        }
    }
}

/// The engine's fused batch step over paged caches drawing from one
/// shared arena (interleaved block allocation) is bitwise identical to
/// the same step over contiguous solo caches — and a batch that
/// exhausts the arena errs cleanly, leaving every cache and the arena
/// untouched.
#[test]
fn batched_paged_step_is_bitwise_identical_to_contiguous() {
    let sc = packed_scorer(78);
    let d = sc.dims().clone();
    let mut rng = Rng::seed(79);
    let prompts: Vec<Vec<u32>> = [5usize, 2, 9]
        .iter()
        .map(|&n| (0..n).map(|_| rng.below(d.vocab) as u32).collect())
        .collect();
    let suffixes: Vec<Vec<u32>> = [3usize, 2, 4]
        .iter()
        .map(|&n| (0..n).map(|_| rng.below(d.vocab) as u32).collect())
        .collect();

    let run = |caches: &mut Vec<KvCache>| {
        {
            let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
            sc.cache_forward_batch(&prompts, &mut refs).unwrap();
        }
        let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        sc.cache_forward_batch(&suffixes, &mut refs).unwrap()
    };

    let mut solo: Vec<KvCache> = prompts.iter().map(|_| sc.new_cache()).collect();
    let want = run(&mut solo);

    // 2-position blocks, all three sequences interleaving one pool
    let arena = KvArena::new(&d, 2, 3 * d.seq.div_ceil(2));
    let mut paged: Vec<KvCache> = prompts.iter().map(|_| arena.new_cache()).collect();
    let got = run(&mut paged);

    for (si, (a, b)) in got.iter().zip(&want).enumerate() {
        assert_eq!(a.shape(), b.shape());
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!(
                x.to_bits() == y.to_bits(),
                "sequence {si}: paged batch step not bitwise ({x} vs {y})"
            );
        }
    }

    // arena exhaustion inside a batch: Err names the sequence, and the
    // all-or-nothing reservation leaves every cache (and the pool) as it
    // was — no leaked blocks, no partially extended cache
    let tight = KvArena::new(&d, 2, 2);
    let mut a = tight.new_cache();
    let mut b = tight.new_cache();
    let mut refs: Vec<&mut KvCache> = vec![&mut a, &mut b];
    let err = sc
        .cache_forward_batch(&[vec![1], vec![1, 2, 3, 4]], &mut refs)
        .unwrap_err();
    assert!(format!("{err}").contains("sequence 1"), "{err}");
    assert!(format!("{err}").contains("arena exhausted"), "{err}");
    assert_eq!((a.len(), b.len()), (0, 0));
    assert_eq!(tight.blocks_in_use(), 0, "failed batch leaked arena blocks");
}

/// A scorer drives an empty-choice list and single-choice lists through
/// the prefix path without surprises.
#[test]
fn score_choices_degenerate_inputs() {
    let sc = packed_scorer(74);
    let d = sc.dims().clone();
    let mut rng = Rng::seed(75);
    let prompt: Vec<u32> = (0..4).map(|_| rng.below(d.vocab) as u32).collect();
    assert!(sc.score_choices(&prompt, &[]).unwrap().is_empty());
    let one = sc.score_choices(&prompt, &[vec![1, 2]]).unwrap();
    assert_eq!(one.len(), 1);
    assert_eq!(one[0].len(), 2);
    // empty prompt: Err (first choice token has no conditioning position)
    let err = sc.score_choices(&[], &[vec![1]]).unwrap_err();
    assert!(format!("{err}").contains("non-empty"), "{err}");
    // over-window prompt+choice: Err naming the window
    let long: Vec<u32> = (0..d.seq).map(|_| 1).collect();
    let err = sc.score_choices(&long, &[vec![1]]).unwrap_err();
    assert!(format!("{err}").contains("window"), "{err}");
}
